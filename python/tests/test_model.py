"""Layer-2 correctness: the model entry points vs. composed oracles, and
the AOT lowering path (HLO text must be produced and be well-formed)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def make_raw(n, seed=0):
    rng = np.random.default_rng(seed)
    raw = rng.integers(-2000, 2000, size=(n, 8)).astype(np.float32)
    idx = rng.permutation(n).astype(np.float32)
    scale = rng.uniform(1e-4, 1e-2, size=(8,)).astype(np.float32)
    offset = np.concatenate([[1.0], rng.normal(size=7)]).astype(np.float32)
    return map(jnp.asarray, (raw, idx, scale, offset))


class TestIngest:
    def test_matches_composed_reference(self):
        raw, idx, scale, offset = make_raw(300)
        fields, total, com = model.ingest_step(raw, idx, scale, offset)
        want = ref.permute_ref(ref.decode_ref(raw, scale, offset), idx.astype(jnp.int32))
        np.testing.assert_allclose(fields, want, rtol=1e-5, atol=1e-5)
        wt, wc = ref.moments_ref(want[:, 1:4], want[:, 0])
        np.testing.assert_allclose(total, wt, rtol=1e-4)
        np.testing.assert_allclose(com, wc, rtol=1e-3, atol=1e-3)

    def test_shapes(self):
        raw, idx, scale, offset = make_raw(256)
        fields, total, com = model.ingest_step(raw, idx, scale, offset)
        assert fields.shape == (256, 8)
        assert total.shape == (1,)
        assert com.shape == (3,)


class TestGravityStep:
    def test_matches_leapfrog_ref(self):
        rng = np.random.default_rng(4)
        n = 200
        pos = jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32))
        vel = jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32) * 0.1)
        mass = jnp.asarray(rng.uniform(0.5, 1.5, size=(n,)).astype(np.float32))
        dt = jnp.float32(1e-3)
        p2, v2, acc, an = model.gravity_step(pos, vel, mass, dt)
        rp, rv, racc = ref.leapfrog_ref(pos, vel, mass, dt)
        np.testing.assert_allclose(acc, racc, rtol=5e-4, atol=5e-4)
        np.testing.assert_allclose(v2, rv, rtol=5e-4, atol=5e-4)
        np.testing.assert_allclose(p2, rp, rtol=5e-4, atol=5e-4)
        assert an.shape == (1,)
        assert float(an[0]) > 0

    def test_energy_decay_sanity(self):
        # A bound two-body system should keep |acc| finite over steps.
        pos = jnp.array([[0.0, 0, 0], [1.0, 0, 0]], dtype=jnp.float32)
        vel = jnp.array([[0.0, 0.3, 0], [0.0, -0.3, 0]], dtype=jnp.float32)
        mass = jnp.array([1.0, 1.0], dtype=jnp.float32)
        dt = jnp.float32(1e-2)
        for _ in range(20):
            pos, vel, _, an = model.gravity_step(pos, vel, mass, dt)
            assert np.isfinite(float(an[0]))


class TestAot:
    def test_lowering_produces_hlo_text(self):
        arts = dict(aot.lower_all(sizes=(64,)))
        assert set(arts) == {"ingest_n64", "gravity_n64"}
        for name, text in arts.items():
            assert "HloModule" in text, name
            assert "ENTRY" in text, name
            # return_tuple=True => root is a tuple
            assert "tuple(" in text, name

    def test_compiled_aot_numerics_match_eager(self):
        # Execute the AOT-lowered computation (the exact path the Rust
        # runtime uses, minus the text round-trip which the Rust tests
        # cover) and compare against eager execution.
        n = 64
        lowered = jax.jit(model.gravity_step).lower(*model.gravity_spec(n))
        compiled = lowered.compile()
        rng = np.random.default_rng(9)
        pos = jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32))
        vel = jnp.zeros((n, 3), jnp.float32)
        mass = jnp.ones((n,), jnp.float32)
        dt = jnp.float32(1e-3)
        got = compiled(pos, vel, mass, dt)
        want = model.gravity_step(pos, vel, mass, dt)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-4, atol=1e-5)

    def test_hlo_text_mentions_all_params(self):
        # The gravity artifact must take 4 parameters (pos, vel, mass, dt)
        # so the Rust TensorF32 marshaling stays in sync.
        arts = dict(aot.lower_all(sizes=(64,)))
        grav = arts["gravity_n64"]
        for p in ["parameter(0)", "parameter(1)", "parameter(2)", "parameter(3)"]:
            assert p in grav
        assert "parameter(4)" not in grav
