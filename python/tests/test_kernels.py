"""Layer-1 correctness: every Pallas kernel against its pure-jnp oracle,
including hypothesis sweeps over shapes and tile sizes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import decode as kdecode
from compile.kernels import gravity as kgravity
from compile.kernels import permute as kpermute
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def particles(n, seed=0):
    rng = np.random.default_rng(seed)
    pos = rng.normal(size=(n, 3)).astype(np.float32)
    mass = rng.uniform(0.1, 2.0, size=(n,)).astype(np.float32)
    return jnp.asarray(pos), jnp.asarray(mass)


# ----------------------------------------------------------------------
# gravity
# ----------------------------------------------------------------------

class TestGravity:
    def test_matches_ref_basic(self):
        pos, mass = particles(256)
        got = kgravity.gravity(pos, mass)
        want = ref.gravity_ref(pos, mass)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_non_tile_multiple(self):
        pos, mass = particles(300)  # not a multiple of 256
        got = kgravity.gravity(pos, mass)
        want = ref.gravity_ref(pos, mass)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_two_bodies_attract(self):
        pos = jnp.array([[0.0, 0.0, 0.0], [1.0, 0.0, 0.0]], dtype=jnp.float32)
        mass = jnp.array([1.0, 1.0], dtype=jnp.float32)
        acc = kgravity.gravity(pos, mass)
        assert acc[0, 0] > 0  # body 0 pulled toward +x
        assert acc[1, 0] < 0
        np.testing.assert_allclose(acc[0], -acc[1], rtol=1e-5, atol=1e-6)

    def test_momentum_conserved(self):
        pos, mass = particles(128, seed=3)
        acc = kgravity.gravity(pos, mass)
        # sum_i m_i a_i = 0 for pairwise forces.
        net = jnp.sum(mass[:, None] * acc, axis=0)
        np.testing.assert_allclose(net, jnp.zeros(3), atol=1e-2)

    @settings(max_examples=12, deadline=None)
    @given(
        n=st.integers(min_value=2, max_value=700),
        ti=st.sampled_from([8, 64, 256]),
        tj=st.sampled_from([8, 64, 256]),
        seed=st.integers(min_value=0, max_value=2**16),
    )
    def test_hypothesis_shapes_and_tiles(self, n, ti, tj, seed):
        pos, mass = particles(n, seed=seed)
        got = kgravity.gravity(pos, mass, tile_i=ti, tile_j=tj)
        want = ref.gravity_ref(pos, mass)
        np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)

    def test_vmem_estimate_within_budget(self):
        # Default BlockSpec must fit a TPU core's VMEM comfortably.
        assert kgravity.vmem_bytes() < 4 << 20
        assert 0.2 < kgravity.mxu_flops_fraction() < 1.0


# ----------------------------------------------------------------------
# permute
# ----------------------------------------------------------------------

class TestPermute:
    def test_identity(self):
        x = jnp.arange(64 * 8, dtype=jnp.float32).reshape(64, 8)
        idx = jnp.arange(64, dtype=jnp.int32)
        np.testing.assert_array_equal(kpermute.permute(x, idx), x)

    def test_reverse(self):
        x = jnp.arange(100 * 4, dtype=jnp.float32).reshape(100, 4)
        idx = jnp.arange(99, -1, -1, dtype=jnp.int32)
        np.testing.assert_array_equal(kpermute.permute(x, idx), x[::-1])

    def test_matches_ref_random_permutation(self):
        rng = np.random.default_rng(7)
        x = jnp.asarray(rng.normal(size=(513, 8)).astype(np.float32))
        idx = jnp.asarray(rng.permutation(513).astype(np.int32))
        got = kpermute.permute(x, idx)
        want = ref.permute_ref(x, idx)
        np.testing.assert_array_equal(got, want)

    def test_gather_with_repeats(self):
        x = jnp.arange(32 * 2, dtype=jnp.float32).reshape(32, 2)
        idx = jnp.zeros(32, dtype=jnp.int32)
        got = kpermute.permute(x, idx)
        np.testing.assert_array_equal(got, jnp.tile(x[0], (32, 1)))

    @settings(max_examples=12, deadline=None)
    @given(
        n=st.integers(min_value=1, max_value=600),
        f=st.sampled_from([1, 3, 8]),
        to=st.sampled_from([8, 128, 256]),
        ts=st.sampled_from([8, 128, 256]),
        seed=st.integers(min_value=0, max_value=2**16),
    )
    def test_hypothesis_shapes(self, n, f, to, ts, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(n, f)).astype(np.float32))
        idx = jnp.asarray(rng.integers(0, n, size=(n,)).astype(np.int32))
        got = kpermute.permute(x, idx, tile_out=to, tile_src=ts)
        want = ref.permute_ref(x, idx)
        np.testing.assert_array_equal(got, want)


# ----------------------------------------------------------------------
# decode
# ----------------------------------------------------------------------

class TestDecode:
    def test_matches_ref(self):
        rng = np.random.default_rng(1)
        raw = jnp.asarray(rng.integers(-1000, 1000, size=(777, 8)).astype(np.float32))
        scale = jnp.asarray(rng.uniform(1e-4, 1e-2, size=(8,)).astype(np.float32))
        offset = jnp.asarray(rng.normal(size=(8,)).astype(np.float32))
        got = kdecode.decode(raw, scale, offset)
        want = ref.decode_ref(raw, scale, offset)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)

    @settings(max_examples=10, deadline=None)
    @given(
        n=st.integers(min_value=1, max_value=1500),
        f=st.sampled_from([2, 8]),
        tr=st.sampled_from([8, 512]),
    )
    def test_hypothesis_shapes(self, n, f, tr):
        rng = np.random.default_rng(n * 31 + f)
        raw = jnp.asarray(rng.integers(-64, 64, size=(n, f)).astype(np.float32))
        scale = jnp.asarray(np.full((f,), 0.5, np.float32))
        offset = jnp.asarray(np.zeros((f,), np.float32))
        got = kdecode.decode(raw, scale, offset, tile_rows=tr)
        np.testing.assert_allclose(got, raw * 0.5, rtol=1e-6)
