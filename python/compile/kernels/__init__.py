"""Layer-1 Pallas kernels and their pure-jnp oracles."""

from . import decode, gravity, permute, ref  # noqa: F401
