"""Fixed-point particle decode as a Pallas kernel (Layer 1).

Tipsy-style records arrive as quantized fields; decoding is a pure
elementwise dequantize (VPU work, tiled rows through VMEM):

    out[n, f] = raw[n, f] * scale[f] + offset[f]
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_ROWS = 512


def _decode_kernel(raw_ref, scale_ref, offset_ref, out_ref):
    raw = raw_ref[...]
    out_ref[...] = raw * scale_ref[...][None, :] + offset_ref[...][None, :]


def decode(raw, scale, offset, *, tile_rows: int = TILE_ROWS):
    """raw (N, F) f32 (integer-valued), scale/offset (F,) f32."""
    n, f = raw.shape
    tr = min(tile_rows, max(8, n))
    pad = (-n) % tr
    raw_p = jnp.concatenate([raw, jnp.zeros((pad, f), raw.dtype)], axis=0) if pad else raw
    npadded = raw_p.shape[0]

    out = pl.pallas_call(
        _decode_kernel,
        grid=(npadded // tr,),
        in_specs=[
            pl.BlockSpec((tr, f), lambda i: (i, 0)),
            pl.BlockSpec((f,), lambda i: (0,)),
            pl.BlockSpec((f,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tr, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((npadded, f), jnp.float32),
        interpret=True,
    )(raw_p, scale, offset)
    return out[:n]
