"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: every kernel in this package is
tested against these via ``pytest`` (including hypothesis sweeps over
shapes) before the model is AOT-lowered. Keep them dead simple.
"""

import jax.numpy as jnp

# Softening length^2 used by the gravity kernels (Plummer softening).
EPS2 = 1e-4


def gravity_ref(pos, mass):
    """All-pairs softened gravitational acceleration.

    pos: (N, 3) f32, mass: (N,) f32 -> acc (N, 3) f32.
    a_i = sum_j m_j * (x_j - x_i) / (|x_j - x_i|^2 + eps^2)^{3/2}
    (includes j == i, whose contribution is exactly zero).
    """
    dx = pos[None, :, :] - pos[:, None, :]  # (N, N, 3), dx[i,j] = x_j - x_i
    r2 = jnp.sum(dx * dx, axis=-1) + EPS2  # (N, N)
    inv_r3 = r2 ** -1.5
    return jnp.einsum("j,ij,ijk->ik", mass, inv_r3, dx)


def decode_ref(raw, scale, offset):
    """Dequantize fixed-point particle records.

    raw: (N, F) f32 holding integer-valued fixed-point data,
    scale/offset: (F,) f32 per-field -> (N, F) f32 physical values.
    """
    return raw * scale[None, :] + offset[None, :]


def permute_ref(x, idx):
    """Gather rows: out[i] = x[idx[i]].

    x: (N, F) f32, idx: (N,) i32 -> (N, F) f32.
    """
    return jnp.take(x, idx, axis=0)


def moments_ref(pos, mass):
    """Total mass and center of mass. pos: (N,3), mass: (N,) ->
    (total (1,), com (3,))."""
    total = jnp.sum(mass)[None]
    com = jnp.sum(pos * mass[:, None], axis=0) / jnp.maximum(total, 1e-30)
    return total, com


def leapfrog_ref(pos, vel, mass, dt):
    """One kick-drift step using gravity_ref."""
    acc = gravity_ref(pos, mass)
    vel2 = vel + dt * acc
    pos2 = pos + dt * vel2
    return pos2, vel2, acc
