"""Blocked particle permutation (gather) as a Pallas kernel (Layer 1).

This is the compute-side mirror of CkIO's data-permutation phase: after
buffer chares deliver raw particle blocks, rows must be reordered into
TreePiece order. A row gather with dynamic indices does not vectorize
naturally on a systolic array, so we express each (out-tile, src-tile)
step as a one-hot matmul:

    out[i, :] += onehot(idx[i] - src_base, TS) @ src      (MXU matmul)

streaming source tiles through VMEM while the output tile accumulates.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_OUT = 256
TILE_SRC = 256


def _permute_kernel(idx_ref, src_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    idx = idx_ref[...]  # (TO,) global row ids wanted by this out tile
    src = src_ref[...]  # (TS, F) source rows [j*TS, (j+1)*TS)
    ts = src.shape[0]
    base = j * ts
    local = idx - base  # position within this source tile, if any
    hot = (local[:, None] == jnp.arange(ts)[None, :]).astype(src.dtype)  # (TO, TS)
    out_ref[...] += jnp.dot(hot, src, preferred_element_type=jnp.float32)


def permute(x, idx, *, tile_out: int = TILE_OUT, tile_src: int = TILE_SRC):
    """out[i] = x[idx[i]]; x (N, F) f32, idx (N,) i32."""
    n, f = x.shape
    to = min(tile_out, max(8, n))
    ts = min(tile_src, max(8, n))
    pad_out = (-n) % to
    pad_src = (-n) % ts
    pad = max(pad_out, pad_src)
    if pad:
        x_p = jnp.concatenate([x, jnp.zeros((pad, f), x.dtype)], axis=0)
        # Padded output rows gather row n-1 (sliced off afterwards).
        idx_p = jnp.concatenate([idx, jnp.full((pad,), n - 1, idx.dtype)], axis=0)
    else:
        x_p, idx_p = x, idx
    npadded = x_p.shape[0]
    grid = (npadded // to, npadded // ts)

    out = pl.pallas_call(
        _permute_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((to,), lambda i, j: (i,)),
            pl.BlockSpec((ts, f), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((to, f), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((npadded, f), jnp.float32),
        interpret=True,
    )(idx_p, x_p)
    return out[:n]


def vmem_bytes(tile_out: int = TILE_OUT, tile_src: int = TILE_SRC, fields: int = 8) -> int:
    """Estimated VMEM working set of one grid step (f32 data, i32 idx)."""
    idx = tile_out * 4
    src = tile_src * fields * 4
    out = tile_out * fields * 4
    hot = tile_out * tile_src * 4
    return idx + src + out + hot
