"""Tiled all-pairs gravity as a Pallas kernel (Layer 1).

TPU-style adaptation (DESIGN.md §3): the pairwise r^2 matrix for a
(TI × TJ) tile is built with the matmul expansion

    r2[i, j] = |x_i|^2 + |x_j|^2 - 2 * (x_i . x_j)

so the dominant term is a (TI,3)x(3,TJ) matmul that maps onto the MXU,
with the target tile resident in VMEM while source tiles stream through
(BlockSpec grid: targets x sources, accumulating into the output tile).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU perf is estimated from the BlockSpec (see
EXPERIMENTS.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import EPS2

# Default tile sizes: 256x256 pairwise tile = 256 KiB of f32 r2 scratch,
# comfortably inside a TPU core's ~16 MiB VMEM together with the pos/mass
# blocks and the accumulator.
TILE_I = 256
TILE_J = 256


def _gravity_kernel(pos_i_ref, pos_j_ref, mass_j_ref, acc_ref):
    """One (target-tile, source-tile) grid step."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xi = pos_i_ref[...]  # (TI, 3)
    xj = pos_j_ref[...]  # (TJ, 3)
    mj = mass_j_ref[...]  # (TJ,)

    # Difference formulation: numerically robust for close pairs (the
    # |x|^2 - 2 x.y matmul expansion cancels catastrophically when
    # r^2 ~ EPS2, which dominates the force). The (TI, TJ, 3) tile stays
    # in VMEM; the j-contraction below is the MXU-mapped hot op.
    dx = xj[None, :, :] - xi[:, None, :]  # (TI, TJ, 3)
    r2 = jnp.sum(dx * dx, axis=-1) + EPS2  # (TI, TJ)

    inv_r3 = jax.lax.rsqrt(r2) / r2  # r^-3 = rsqrt(r2) / r2
    w = mj[None, :] * inv_r3  # (TI, TJ)

    # acc_i += sum_j w[i,j] * dx[i,j,:] — a batched (1,TJ)x(TJ,3)
    # contraction per target row (MXU-mappable).
    acc_ref[...] += jnp.einsum(
        "ij,ijk->ik", w, dx, preferred_element_type=jnp.float32
    )


def gravity(pos, mass, *, tile_i: int = TILE_I, tile_j: int = TILE_J):
    """Softened all-pairs acceleration; pos (N,3) f32, mass (N,) f32.

    N is padded to tile multiples internally (padded sources get zero
    mass, so they contribute nothing; padded targets are sliced off).
    """
    n = pos.shape[0]
    ti = min(tile_i, max(8, n))
    tj = min(tile_j, max(8, n))
    npad_i = (-n) % ti
    npad_j = (-n) % tj
    npad = max(npad_i, npad_j)
    # Pad far away with zero mass: zero contribution either way.
    if npad:
        pos_p = jnp.concatenate([pos, jnp.full((npad, 3), 1e6, pos.dtype)], axis=0)
        mass_p = jnp.concatenate([mass, jnp.zeros((npad,), mass.dtype)], axis=0)
    else:
        pos_p, mass_p = pos, mass
    npadded = pos_p.shape[0]
    grid = (npadded // ti, npadded // tj)

    acc = pl.pallas_call(
        _gravity_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ti, 3), lambda i, j: (i, 0)),  # target positions
            pl.BlockSpec((tj, 3), lambda i, j: (j, 0)),  # source positions
            pl.BlockSpec((tj,), lambda i, j: (j,)),  # source masses
        ],
        out_specs=pl.BlockSpec((ti, 3), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((npadded, 3), jnp.float32),
        interpret=True,
    )(pos_p, pos_p, mass_p)
    return acc[:n]


@functools.partial(jax.jit, static_argnames=("tile_i", "tile_j"))
def gravity_jit(pos, mass, tile_i: int = TILE_I, tile_j: int = TILE_J):
    return gravity(pos, mass, tile_i=tile_i, tile_j=tile_j)


def vmem_bytes(tile_i: int = TILE_I, tile_j: int = TILE_J) -> int:
    """Estimated VMEM working set of one grid step (f32)."""
    pos_i = tile_i * 3 * 4
    pos_j = tile_j * 3 * 4
    mass_j = tile_j * 4
    acc = tile_i * 3 * 4
    dx = tile_i * tile_j * 3 * 4  # (TI, TJ, 3) difference tensor
    r2_scratch = tile_i * tile_j * 4 * 2  # r2 and w live simultaneously
    return pos_i + pos_j + mass_j + acc + dx + r2_scratch


def mxu_flops_fraction(tile_i: int = TILE_I, tile_j: int = TILE_J) -> float:
    """Fraction of the tile's FLOPs that map onto the MXU (the final
    j-contraction) vs. the VPU (dx/r2/rsqrt elementwise). Used for the
    §Perf estimate."""
    mxu = tile_i * tile_j * 3 * 2  # einsum ij,ijk->ik
    vpu = tile_i * tile_j * 12  # dx, r2, rsqrt, w (approx flop count)
    return mxu / (mxu + vpu)
