"""Build-time compile path: JAX model + Pallas kernels -> HLO artifacts."""
