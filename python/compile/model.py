"""Layer 2: the mini-ChaNGa compute graph in JAX, calling the Pallas
kernels.

Two entry points are AOT-lowered (see ``aot.py``):

* ``ingest_step(raw, idx, scale, offset)`` — what a TreePiece does with
  the bytes CkIO delivers: dequantize the fixed-point records
  (kernels.decode), permute rows into TreePiece order (kernels.permute),
  and compute mass moments for the tree build.
* ``gravity_step(pos, vel, mass, dt)`` — one kick-drift leapfrog step
  with all-pairs softened gravity (kernels.gravity), returning the new
  state plus diagnostics (|acc| sum) so the Rust driver can log a
  convergence curve.

Python never runs at request time: these are lowered once to HLO text and
executed from Rust via PJRT.
"""

import jax
import jax.numpy as jnp

from .kernels import decode as kdecode
from .kernels import gravity as kgravity
from .kernels import permute as kpermute


def moments(pos, mass):
    """Total mass (1,) and center of mass (3,)."""
    total = jnp.sum(mass)[None]
    com = jnp.sum(pos * mass[:, None], axis=0) / jnp.maximum(total, 1e-30)
    return total, com


def ingest_step(raw, idx, scale, offset):
    """raw (N,8) f32 fixed-point, idx (N,) f32 (row ids as floats so the
    whole artifact is f32-typed at the PJRT boundary), scale/offset (8,).

    Returns (particles (N,8), total_mass (1,), com (3,)).
    Field layout: [mass, x, y, z, vx, vy, vz, softening].
    """
    fields = kdecode.decode(raw, scale, offset)
    fields = kpermute.permute(fields, idx.astype(jnp.int32))
    mass = fields[:, 0]
    pos = fields[:, 1:4]
    total, com = moments(pos, mass)
    return fields, total, com


def gravity_step(pos, vel, mass, dt):
    """One leapfrog step. pos/vel (N,3), mass (N,), dt () scalar.

    Returns (pos', vel', acc, acc_norm (1,)).
    """
    acc = kgravity.gravity(pos, mass)
    vel2 = vel + dt * acc
    pos2 = pos + dt * vel2
    acc_norm = jnp.sum(jnp.sqrt(jnp.sum(acc * acc, axis=-1)))[None]
    return pos2, vel2, acc, acc_norm


def ingest_spec(n: int):
    """Example-arg specs for ``jax.jit(ingest_step).lower``."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((n, 8), f32),
        jax.ShapeDtypeStruct((n,), f32),
        jax.ShapeDtypeStruct((8,), f32),
        jax.ShapeDtypeStruct((8,), f32),
    )


def gravity_spec(n: int):
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((n, 3), f32),
        jax.ShapeDtypeStruct((n, 3), f32),
        jax.ShapeDtypeStruct((n,), f32),
        jax.ShapeDtypeStruct((), f32),
    )
