"""AOT lowering: JAX model -> HLO text artifacts for the Rust runtime.

HLO *text* is the interchange format (NOT ``lowered.compile()`` or a
serialized ``HloModuleProto``): jax >= 0.5 emits protos with 64-bit
instruction ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Usage:  python -m compile.aot --out-dir ../artifacts [--sizes 256,4096]
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

# Particle counts to specialize artifacts for. The Rust side picks the
# artifact matching its TreePiece size.
DEFAULT_SIZES = (256, 4096)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(sizes=DEFAULT_SIZES):
    """Yield (name, hlo_text) for every artifact."""
    for n in sizes:
        ingest = jax.jit(model.ingest_step).lower(*model.ingest_spec(n))
        yield f"ingest_n{n}", to_hlo_text(ingest)
        grav = jax.jit(model.gravity_step).lower(*model.gravity_spec(n))
        yield f"gravity_n{n}", to_hlo_text(grav)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sizes", default=",".join(str(s) for s in DEFAULT_SIZES))
    args = ap.parse_args()
    sizes = tuple(int(s) for s in args.sizes.split(","))
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {}
    for name, text in lower_all(sizes):
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {"bytes": len(text)}
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(
            {
                "jax": jax.__version__,
                "sizes": list(sizes),
                "artifacts": manifest,
                "format": "hlo-text (return_tuple=True)",
            },
            f,
            indent=2,
        )
    print(f"manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
