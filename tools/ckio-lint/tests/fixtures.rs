//! Integration tests: the seeded fixture tree produces exactly the
//! planted findings, and the real tree scans clean against the
//! builtin protocol table.

use std::path::Path;

use ckio::amt::protocol::{self, PayloadKind, ProtocolSpec, ProtocolTable};
use ckio::lint::{self, Check};

struct FooMsg;

const EP_DEAD: u32 = 1;
const EP_TAKES_FOO: u32 = 2;

/// The protocol the fixture tree *claims* to implement. `EP_TAKES_FOO`
/// is declared to carry `FooMsg`; the fixture handler takes `BarMsg`.
fn fixture_table() -> ProtocolTable {
    let mut t = ProtocolTable::default();
    t.push(ProtocolSpec {
        chare: "Fixture",
        module: "app.rs",
        handles: vec![
            ckio::ep_spec!(EP_DEAD, PayloadKind::Signal),
            ckio::ep_spec!(EP_TAKES_FOO, PayloadKind::of::<FooMsg>()),
        ],
        sends: vec![],
    });
    t
}

#[test]
fn fixture_tree_yields_planted_findings() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/tree");
    let (findings, scanned) = lint::scan_tree(&root, &fixture_table()).unwrap();
    assert_eq!(scanned, 2, "{findings:?}");
    let count = |c: Check| findings.iter().filter(|f| f.check == c).count();
    assert_eq!(count(Check::DeadEp), 1, "{findings:?}");
    assert_eq!(count(Check::StaleEpRef), 1, "{findings:?}");
    assert_eq!(count(Check::PayloadMismatch), 1, "{findings:?}");
    assert_eq!(count(Check::MetricsLiteral), 4, "{findings:?}");
    assert_eq!(count(Check::TraceLiteral), 1, "{findings:?}");
    assert_eq!(count(Check::StashHygiene), 1, "{findings:?}");
    assert_eq!(count(Check::SpecCoverage), 0, "{findings:?}");
    assert!(findings.iter().any(|f| f.message.contains("EP_DEAD")));
    assert!(findings.iter().any(|f| f.message.contains("EP_GHOST")));
    assert!(findings.iter().any(|f| f.message.contains("BarMsg")));
    assert!(findings.iter().any(|f| f.message.contains("ckio.rogue")));
    assert!(findings.iter().any(|f| f.message.contains("ckio.fault.rogue")));
    assert!(findings.iter().any(|f| f.message.contains("ckio.consumer.rogue")));
    assert!(findings.iter().any(|f| f.message.contains("ckio.write.rogue")));
    assert!(findings.iter().any(|f| f.message.contains("ticket/rogue")));
    assert!(findings.iter().any(|f| f.message.contains("pending_things")));
}

#[test]
fn real_tree_scans_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../../rust/src");
    let table = protocol::builtin_table();
    protocol::verify(&table).expect("builtin protocol table must be sound");
    let (findings, scanned) = lint::scan_tree(&root, &table).unwrap();
    assert!(scanned > 30, "suspiciously few files: {scanned}");
    assert!(findings.is_empty(), "tree not lint-clean:\n{findings:#?}");
}

#[test]
fn metrics_dump_covers_both_registries() {
    let md = lint::dump_metrics_markdown();
    for (key, _, _, _) in ckio::metrics::keys::catalog() {
        assert!(md.contains(key), "missing metrics key {key}");
    }
    for (name, _, _) in ckio::trace::names::catalog() {
        assert!(md.contains(name), "missing trace event {name}");
    }
}

#[test]
fn protocol_dump_covers_every_spec() {
    let table = protocol::builtin_table();
    let md = lint::dump_protocol_markdown(&table);
    for spec in &table.specs {
        assert!(md.contains(spec.chare), "missing {}", spec.chare);
        assert!(md.contains(spec.module), "missing {}", spec.module);
    }
}
