//! Lint fixture: a mock chare module with seeded protocol violations.
//! Scanned by `tests/fixtures.rs` as data — never compiled.

pub type Ep = u32;

/// Declared and matched, but nothing ever sends it.
pub const EP_DEAD: Ep = 1;
/// Sent and matched, but the handler decodes the wrong type.
pub const EP_TAKES_FOO: Ep = 2;

pub struct FooMsg {
    pub n: u64,
}

pub struct BarMsg {
    pub n: u64,
}

// The EP_GHOST ticket protocol was removed long ago; this comment
// still references it.

pub fn drive(ctx: &mut Ctx, peer: ChareRef) {
    ctx.send(peer, EP_TAKES_FOO, Payload::new(FooMsg { n: 7 }));
    ctx.metrics.incr("ckio.rogue", 1);
    ctx.metrics.incr("ckio.fault.rogue", 1);
    ctx.metrics.incr("ckio.consumer.rogue", 1);
    ctx.metrics.incr("ckio.write.rogue", 1);
    ctx.trace.instant(0, "ticket/rogue");
}

pub fn receive(msg: &mut Msg) {
    match msg.ep {
        EP_DEAD => {}
        EP_TAKES_FOO => {
            let m: BarMsg = msg.take();
            let _ = m.n;
        }
        other => panic!("unknown ep {other}"),
    }
}
