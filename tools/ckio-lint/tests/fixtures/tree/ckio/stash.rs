//! Lint fixture: a stash map with an insert site but no drain.

use std::collections::HashMap;

pub struct Stash {
    pending_things: HashMap<u32, Vec<u8>>,
    done: u64,
}

impl Stash {
    pub fn park(&mut self, id: u32, bytes: Vec<u8>) {
        self.pending_things.insert(id, bytes);
        self.done += 1;
    }
}
