//! `ckio-lint`: thin CLI wrapper over [`ckio::lint`] so CI can run the
//! source pass without building the full experiment launcher. Same
//! behavior as `ckio lint`; see `ckio::lint::cli` for args and exit
//! codes (0 clean, 1 findings, 2 usage/protocol error).

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(ckio::lint::cli(&args));
}
