//! `cargo bench` — regenerate every table/figure of the paper's
//! evaluation (DESIGN.md §5) and write CSVs to `bench_out/`.
//!
//! The offline crate set has no criterion, so this is a plain
//! harness=false binary built on `ckio::harness`. Repetitions default to
//! 3 (the error bars in Figs. 1/4 come from the PFS model's log-normal
//! service noise, seeded per rep). Set `CKIO_BENCH_REPS` / and
//! `CKIO_BENCH_TP` to override, or pass figure ids as argv to run a
//! subset: `cargo bench -- 1 4 13`.

use ckio::harness::experiments as exp;

fn main() {
    let reps: u32 = std::env::var("CKIO_BENCH_REPS").ok().and_then(|s| s.parse().ok()).unwrap_or(3);
    let n_tp: u32 =
        std::env::var("CKIO_BENCH_TP").ok().and_then(|s| s.parse().ok()).unwrap_or(1 << 16);
    let wanted: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();

    let all: Vec<(&str, Box<dyn Fn() -> ckio::harness::Table>)> = vec![
        ("fig1", Box::new(move || exp::fig1_naive_clients(reps))),
        ("fig2", Box::new(move || exp::fig2_disk_vs_net(reps))),
        ("fig4", Box::new(move || exp::fig4_ckio_vs_naive(reps))),
        ("fig7", Box::new(move || exp::fig7_mpiio_vs_ckio(reps))),
        ("fig8", Box::new(move || exp::fig8_overlap_runtime(reps))),
        ("fig9", Box::new(move || exp::fig9_overlap_fraction(reps))),
        ("fig12", Box::new(move || exp::fig12_migration(reps))),
        ("fig13", Box::new(move || exp::fig13_changa(reps, n_tp))),
        ("sec5_breakdown", Box::new(move || exp::sec5_breakdown(reps))),
        ("ablation_splinter", Box::new(move || exp::ablation_splinter(reps))),
        ("ablation_autoreaders", Box::new(move || exp::ablation_autoreaders(reps))),
        ("svc_concurrent", Box::new(move || exp::svc_concurrent(reps))),
        ("svc_shared", Box::new(move || exp::svc_shared(reps))),
        ("svc_churn", Box::new(move || exp::svc_churn(reps))),
        ("svc_locality", Box::new(move || exp::svc_locality(reps))),
        ("svc_qos", Box::new(move || exp::svc_qos(reps))),
    ];

    let total = std::time::Instant::now();
    for (slug, f) in all {
        if !wanted.is_empty() && !wanted.iter().any(|w| slug.contains(w.as_str())) {
            continue;
        }
        let started = std::time::Instant::now();
        let table = f();
        table.print();
        match table.write_csv("bench_out", slug) {
            Ok(p) => {
                println!("[csv] {} ({:.1}s wall)\n", p.display(), started.elapsed().as_secs_f64())
            }
            Err(e) => eprintln!("csv write failed for {slug}: {e}"),
        }
    }
    // Machine-readable perf anchor for the service-scaling work (PR 5:
    // svc_concurrent continuity + svc_shared dedup + svc_churn shard
    // sweep + adaptive-governor feedback + the svc_locality placement
    // pair + the svc_qos class pair, with the
    // store/governor/shard/placement/qos keys). Any svc filter triggers
    // it — the JSON has every section.
    if wanted.is_empty()
        || wanted.iter().any(|w| {
            "svc_shared".contains(w.as_str())
                || "svc_concurrent".contains(w.as_str())
                || "svc_churn".contains(w.as_str())
                || "svc_locality".contains(w.as_str())
                || "svc_qos".contains(w.as_str())
        })
    {
        match std::fs::write("BENCH_pr8.json", exp::bench_pr8_json(reps)) {
            Ok(()) => println!("[json] BENCH_pr8.json"),
            Err(e) => eprintln!("BENCH_pr8.json write failed: {e}"),
        }
    }
    println!("total bench wall time: {:.1}s", total.elapsed().as_secs_f64());
}
