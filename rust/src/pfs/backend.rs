//! The I/O interface the runtime submits reads through, plus the
//! real-disk backend (helper reader threads doing `pread`, mirroring
//! CkIO's pthread readers) used by wall-clock runs.

use std::collections::HashMap;
use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::amt::callback::Callback;
use crate::amt::topology::Pe;
use crate::util::bytes::Chunk;

use super::layout::FileId;

/// A read submitted by a chare (via `Ctx::submit_read`).
#[derive(Copy, Clone, Debug)]
pub struct ReadRequest {
    pub file: FileId,
    pub offset: u64,
    pub len: u64,
    /// Opaque tag echoed back in the result so the submitter can match
    /// completions to requests.
    pub user: u64,
}

/// A write submitted by a chare (via `Ctx::submit_write`, PR 10). The
/// output mirror of [`ReadRequest`]: the submitter owns the bytes (the
/// write plane's buffer chares keep them resident until durable), so
/// the request carries only the extent — the modeled backend accounts
/// for stripes and service time, never the payload.
#[derive(Copy, Clone, Debug)]
pub struct WriteRequest {
    pub file: FileId,
    pub offset: u64,
    pub len: u64,
    /// Opaque tag echoed back in the result so the submitter can match
    /// completions to requests.
    pub user: u64,
}

/// How a read completed. Real parallel file systems fail in more ways
/// than "never": an OST can return EIO once (transient), every time
/// (persistent media fault), or deliver fewer bytes than asked. The
/// submitter decides policy (retry, hedge, degrade) — the backend only
/// reports what happened.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum IoOutcome {
    /// Full extent delivered.
    Ok,
    /// Failed this attempt; a retry may succeed (EIO, timeout at the OST).
    TransientError,
    /// Failed and will keep failing (bad block, lost object).
    PersistentError,
    /// Delivered only the first `valid` bytes of the extent.
    Short { valid: u64 },
}

impl IoOutcome {
    pub fn is_ok(self) -> bool {
        self == IoOutcome::Ok
    }
}

/// A completed read, delivered as the payload of the completion callback.
#[derive(Debug)]
pub struct IoResult {
    pub file: FileId,
    pub offset: u64,
    pub len: u64,
    pub user: u64,
    pub chunk: Chunk,
    pub outcome: IoOutcome,
}

/// Completion record posted by real reader threads.
#[derive(Debug)]
pub struct RealCompletion {
    pub callback: Callback,
    pub pe: Pe,
    pub result: IoResult,
}

struct Job {
    path: PathBuf,
    req: ReadRequest,
    callback: Callback,
    pe: Pe,
}

enum WorkerMsg {
    Read(Job),
    Stop,
}

/// Real-disk backend: a pool of helper reader threads servicing `pread`s
/// against local files. Completions flow back over a channel the engine
/// drains — the scheduler threads never block on I/O, exactly the
/// split-phase structure CkIO's buffer chares use.
pub struct LocalDisk {
    tx: Sender<WorkerMsg>,
    pub completions: Receiver<RealCompletion>,
    workers: Vec<JoinHandle<()>>,
    files: Vec<PathBuf>,
    in_flight: usize,
}

impl LocalDisk {
    /// Spawn a pool of `threads` reader threads.
    pub fn new(threads: usize) -> LocalDisk {
        assert!(threads > 0);
        let (tx, work_rx) = channel::<WorkerMsg>();
        let work_rx = Arc::new(std::sync::Mutex::new(work_rx));
        let (done_tx, completions) = channel();
        let workers = (0..threads)
            .map(|_| {
                let work_rx = Arc::clone(&work_rx);
                let done_tx = done_tx.clone();
                std::thread::spawn(move || {
                    // Per-worker open-file cache: a migrated client keeps
                    // reading through its session; the worker re-opens
                    // lazily on whatever node (thread) serves it.
                    let mut handles: HashMap<PathBuf, File> = HashMap::new();
                    loop {
                        let msg = { work_rx.lock().unwrap().recv() };
                        match msg {
                            Ok(WorkerMsg::Read(job)) => {
                                let file = handles
                                    .entry(job.path.clone())
                                    .or_insert_with(|| {
                                        File::open(&job.path).expect("open data file")
                                    });
                                let mut buf = vec![0u8; job.req.len as usize];
                                file.seek(SeekFrom::Start(job.req.offset)).expect("seek");
                                file.read_exact(&mut buf).expect("pread");
                                let result = IoResult {
                                    file: job.req.file,
                                    offset: job.req.offset,
                                    len: job.req.len,
                                    user: job.req.user,
                                    chunk: Chunk::materialized(job.req.offset, buf.into()),
                                    outcome: IoOutcome::Ok,
                                };
                                let _ = done_tx.send(RealCompletion {
                                    callback: job.callback,
                                    pe: job.pe,
                                    result,
                                });
                            }
                            Ok(WorkerMsg::Stop) | Err(_) => break,
                        }
                    }
                })
            })
            .collect();
        LocalDisk { tx, completions, workers, files: Vec::new(), in_flight: 0 }
    }

    /// Register a real file; returns its handle.
    pub fn register_file(&mut self, path: impl Into<PathBuf>) -> FileId {
        let id = FileId(self.files.len() as u32);
        self.files.push(path.into());
        id
    }

    pub fn file_size(&self, id: FileId) -> u64 {
        std::fs::metadata(&self.files[id.0 as usize]).expect("stat").len()
    }

    /// Submit a read to the pool.
    pub fn submit(&mut self, pe: Pe, req: ReadRequest, callback: Callback) {
        let path = self.files[req.file.0 as usize].clone();
        self.in_flight += 1;
        self.tx
            .send(WorkerMsg::Read(Job { path, req, callback, pe }))
            .expect("reader pool alive");
    }

    /// Number of submitted-but-undelivered reads (the engine decrements
    /// by draining `completions`).
    pub fn note_completion(&mut self) {
        self.in_flight -= 1;
    }

    pub fn in_flight(&self) -> usize {
        self.in_flight
    }
}

impl Drop for LocalDisk {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(WorkerMsg::Stop);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pfs::pattern;

    fn temp_file(name: &str, len: u64) -> (PathBuf, FileId) {
        let dir = std::env::temp_dir().join("ckio_test_backend");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        // Write the deterministic pattern so reads are verifiable.
        pattern::write_file(&path, FileId(0), len).unwrap();
        (path, FileId(0))
    }

    #[test]
    fn reads_round_trip() {
        let (path, fid) = temp_file("roundtrip.bin", 1 << 20);
        let mut disk = LocalDisk::new(2);
        let id = disk.register_file(&path);
        assert_eq!(id, fid);
        assert_eq!(disk.file_size(id), 1 << 20);
        for i in 0..8u64 {
            disk.submit(
                Pe(0),
                ReadRequest { file: id, offset: i * 128 << 10, len: 128 << 10, user: i },
                Callback::Ignore,
            );
        }
        let mut seen = vec![false; 8];
        for _ in 0..8 {
            let c = disk.completions.recv().unwrap();
            disk.note_completion();
            let r = &c.result;
            assert_eq!(r.len, 128 << 10);
            let bytes = r.chunk.bytes.as_ref().unwrap();
            assert_eq!(
                pattern::verify(FileId(0), r.offset, bytes),
                None,
                "corrupt read at {}",
                r.offset
            );
            seen[r.user as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
        assert_eq!(disk.in_flight(), 0);
    }

    #[test]
    fn concurrent_readers_dont_interfere() {
        let (path, _) = temp_file("concurrent.bin", 4 << 20);
        let mut disk = LocalDisk::new(4);
        let id = disk.register_file(&path);
        let n = 64u64;
        let chunk = (4 << 20) / n;
        for i in 0..n {
            disk.submit(
                Pe((i % 4) as u32),
                ReadRequest { file: id, offset: i * chunk, len: chunk, user: i },
                Callback::Ignore,
            );
        }
        for _ in 0..n {
            let c = disk.completions.recv().unwrap();
            disk.note_completion();
            let bytes = c.result.chunk.bytes.as_ref().unwrap();
            assert_eq!(pattern::verify(FileId(0), c.result.offset, bytes), None);
        }
    }
}
