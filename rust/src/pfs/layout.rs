//! File metadata and striping layout (Lustre-style).

/// Handle to a file known to the (simulated or real) file system.
#[derive(Copy, Clone, Eq, PartialEq, Ord, PartialOrd, Hash, Debug)]
pub struct FileId(pub u32);

/// Metadata for one file: size and striping.
///
/// A file is striped round-robin over `stripe_count` OSTs starting at
/// `first_ost`: byte `b` lives on OST
/// `first_ost + (b / stripe_size) % stripe_count` (mod the OST pool).
#[derive(Clone, Debug)]
pub struct FileMeta {
    pub id: FileId,
    pub size: u64,
    pub stripe_size: u64,
    pub stripe_count: u32,
    pub first_ost: u32,
    /// Backing path for the real-disk backend (None in modeled runs).
    pub path: Option<std::path::PathBuf>,
}

impl FileMeta {
    /// OST index (within the global pool of `ost_pool` OSTs) holding the
    /// stripe that contains `offset`.
    pub fn ost_of(&self, offset: u64, ost_pool: u32) -> u32 {
        debug_assert!(offset < self.size, "offset {offset} beyond EOF {}", self.size);
        let stripe = offset / self.stripe_size;
        (self.first_ost + (stripe % self.stripe_count as u64) as u32) % ost_pool
    }

    /// End of the stripe containing `offset` (exclusive, clamped to EOF).
    pub fn stripe_end(&self, offset: u64) -> u64 {
        ((offset / self.stripe_size + 1) * self.stripe_size).min(self.size)
    }

    /// Split `[offset, offset+len)` into per-RPC extents: each extent lies
    /// within a single stripe and is at most `rpc_max` long. This is what
    /// a Lustre client does when it turns a read into OST RPCs.
    pub fn rpc_extents(&self, offset: u64, len: u64, rpc_max: u64) -> Vec<(u64, u64)> {
        assert!(rpc_max > 0);
        assert!(
            offset + len <= self.size,
            "read [{offset}, {}) beyond EOF {}",
            offset + len,
            self.size
        );
        let mut out = Vec::new();
        let mut pos = offset;
        let end = offset + len;
        while pos < end {
            let stripe_end = self.stripe_end(pos);
            let ext_end = end.min(stripe_end).min(pos + rpc_max);
            out.push((pos, ext_end - pos));
            pos = ext_end;
        }
        out
    }
}

/// Split `[offset, offset+len)` at stripe boundaries of width
/// `stripe_size` (PR 10). This is the write plane's coalescing grid:
/// a write buffer accumulates producer pieces per stripe-aligned extent
/// and flushes each extent as one contiguous PFS write, so the op count
/// scales with stripes covered rather than pieces produced (the MPI-IO
/// collective-buffering argument). Pure layout arithmetic — no
/// [`FileMeta`] needed, because alignment depends only on the stripe
/// width, not on which OST a stripe lands on.
pub fn stripe_extents(offset: u64, len: u64, stripe_size: u64) -> Vec<(u64, u64)> {
    assert!(stripe_size > 0, "stripe_extents needs a positive stripe width");
    if len == 0 {
        return Vec::new();
    }
    let mut out = Vec::new();
    let mut pos = offset;
    let end = offset + len;
    while pos < end {
        let stripe_end = (pos / stripe_size + 1) * stripe_size;
        let ext_end = end.min(stripe_end);
        out.push((pos, ext_end - pos));
        pos = ext_end;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> FileMeta {
        FileMeta {
            id: FileId(0),
            size: 100 << 20,
            stripe_size: 4 << 20,
            stripe_count: 4,
            first_ost: 2,
            path: None,
        }
    }

    #[test]
    fn ost_round_robin() {
        let m = meta();
        assert_eq!(m.ost_of(0, 16), 2);
        assert_eq!(m.ost_of(4 << 20, 16), 3);
        assert_eq!(m.ost_of(8 << 20, 16), 4);
        assert_eq!(m.ost_of(12 << 20, 16), 5);
        assert_eq!(m.ost_of(16 << 20, 16), 2); // wraps at stripe_count
    }

    #[test]
    fn ost_wraps_pool() {
        let m = FileMeta { first_ost: 15, stripe_count: 4, ..meta() };
        assert_eq!(m.ost_of(4 << 20, 16), 0);
    }

    #[test]
    fn extents_respect_stripes_and_rpc_max() {
        let m = meta();
        // 10 MiB starting 1 MiB into the file, rpc_max 2 MiB.
        let exts = m.rpc_extents(1 << 20, 10 << 20, 2 << 20);
        // Total length preserved and contiguous:
        let total: u64 = exts.iter().map(|&(_, l)| l).sum();
        assert_eq!(total, 10 << 20);
        let mut pos = 1 << 20;
        for &(o, l) in &exts {
            assert_eq!(o, pos);
            assert!(l <= 2 << 20);
            // never spans a stripe boundary
            assert_eq!(m.ost_of(o, 16), m.ost_of(o + l - 1, 16));
            pos = o + l;
        }
    }

    #[test]
    fn extent_at_eof() {
        let m = meta();
        let exts = m.rpc_extents((100 << 20) - 1000, 1000, 1 << 20);
        assert_eq!(exts, vec![((100 << 20) - 1000, 1000)]);
    }

    #[test]
    #[should_panic(expected = "beyond EOF")]
    fn read_past_eof_panics() {
        meta().rpc_extents(100 << 20, 1, 1 << 20);
    }

    #[test]
    fn single_byte_extent() {
        let m = meta();
        let exts = m.rpc_extents(0, 1, 1 << 20);
        assert_eq!(exts, vec![(0, 1)]);
    }

    #[test]
    fn stripe_extents_align_to_the_grid() {
        // 10 MiB starting 1 MiB in, 4 MiB stripes: the first extent runs
        // to the next boundary, interior extents are whole stripes, the
        // tail is the remainder.
        let exts = stripe_extents(1 << 20, 10 << 20, 4 << 20);
        assert_eq!(exts, vec![
            (1 << 20, 3 << 20),
            (4 << 20, 4 << 20),
            (8 << 20, 3 << 20),
        ]);
        let total: u64 = exts.iter().map(|&(_, l)| l).sum();
        assert_eq!(total, 10 << 20);
        // Already-aligned spans partition into whole stripes.
        assert_eq!(stripe_extents(8 << 20, 8 << 20, 4 << 20), vec![
            (8 << 20, 4 << 20),
            (12 << 20, 4 << 20),
        ]);
        // Sub-stripe spans stay a single extent; empty spans vanish.
        assert_eq!(stripe_extents(100, 50, 4 << 20), vec![(100, 50)]);
        assert!(stripe_extents(100, 0, 4 << 20).is_empty());
    }
}
