//! Deterministic file contents.
//!
//! Byte `o` of file `f` is a pure function of `(f, o)`, so (a) the
//! simulated backend can materialize any extent on demand without storing
//! gigabytes, (b) any consumer can verify that the bytes CkIO assembled
//! for it are exactly the bytes it asked for — end-to-end integrity is a
//! first-class test signal in both simulated and real-disk runs (the
//! real-disk writer also writes this pattern).

use super::layout::FileId;

/// 64-bit mix (splitmix64 finalizer).
#[inline]
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e3779b97f4a7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

/// The 8 pattern bytes for the word containing offset `o` (word-aligned).
#[inline]
fn word_at(file: FileId, word_index: u64) -> u64 {
    mix((file.0 as u64) << 56 ^ word_index)
}

/// Fill `buf` with the pattern of `file` starting at `offset`.
pub fn fill(file: FileId, offset: u64, buf: &mut [u8]) {
    let mut i = 0usize;
    let mut o = offset;
    // Leading partial word.
    while i < buf.len() && o % 8 != 0 {
        let w = word_at(file, o / 8).to_le_bytes();
        buf[i] = w[(o % 8) as usize];
        i += 1;
        o += 1;
    }
    // Whole words.
    while i + 8 <= buf.len() {
        buf[i..i + 8].copy_from_slice(&word_at(file, o / 8).to_le_bytes());
        i += 8;
        o += 8;
    }
    // Trailing partial word.
    while i < buf.len() {
        let w = word_at(file, o / 8).to_le_bytes();
        buf[i] = w[(o % 8) as usize];
        i += 1;
        o += 1;
    }
}

/// Allocate and fill an extent.
pub fn make(file: FileId, offset: u64, len: u64) -> std::sync::Arc<[u8]> {
    let mut v = vec![0u8; len as usize];
    fill(file, offset, &mut v);
    v.into()
}

/// Verify that `buf` matches the pattern of `file` at `offset`.
/// Returns the index of the first mismatching byte, if any.
pub fn verify(file: FileId, offset: u64, buf: &[u8]) -> Option<usize> {
    let mut expect = vec![0u8; buf.len()];
    fill(file, offset, &mut expect);
    buf.iter().zip(expect.iter()).position(|(a, b)| a != b)
}

/// Write the first `len` pattern bytes of `file` to `path` — the one
/// real-disk pattern writer. Every test or harness that needs a
/// verifiable on-disk file goes through here, so the bytes the writer
/// produces and the bytes [`verify`] expects can never diverge (they
/// are the same [`fill`]).
pub fn write_file(path: &std::path::Path, file: FileId, len: u64) -> std::io::Result<()> {
    std::fs::write(path, &make(file, 0, len))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = make(FileId(1), 1000, 64);
        let b = make(FileId(1), 1000, 64);
        assert_eq!(&a[..], &b[..]);
    }

    #[test]
    fn files_differ() {
        let a = make(FileId(1), 0, 64);
        let b = make(FileId(2), 0, 64);
        assert_ne!(&a[..], &b[..]);
    }

    #[test]
    fn unaligned_slices_consistent() {
        // Reading [100, 200) must equal bytes 100..200 of reading [0, 300).
        let whole = make(FileId(3), 0, 300);
        let part = make(FileId(3), 100, 100);
        assert_eq!(&whole[100..200], &part[..]);
    }

    #[test]
    fn odd_offsets_and_lengths() {
        for off in [0u64, 1, 7, 8, 9, 1023] {
            for len in [1u64, 3, 8, 13, 64] {
                let whole = make(FileId(4), 0, off + len + 8);
                let part = make(FileId(4), off, len);
                assert_eq!(
                    &whole[off as usize..(off + len) as usize],
                    &part[..],
                    "off={off} len={len}"
                );
            }
        }
    }

    #[test]
    fn verify_detects_corruption() {
        let mut v = make(FileId(5), 64, 128).to_vec();
        assert_eq!(verify(FileId(5), 64, &v), None);
        v[100] ^= 0xff;
        assert_eq!(verify(FileId(5), 64, &v), Some(100));
    }

    #[test]
    fn bytes_look_random() {
        // Crude entropy check: all 256 byte values appear in 64 KiB.
        let v = make(FileId(6), 0, 64 << 10);
        let mut seen = [false; 256];
        for &b in v.iter() {
            seen[b as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
