//! Parallel file system substrate (Lustre-like).
//!
//! The paper's evaluation runs on Bridges2's Lustre file system ("Ocean").
//! We cannot reproduce that hardware, so this module provides:
//!
//! * [`layout`] — files striped over object storage targets (OSTs),
//! * [`model`] — a discrete-event queueing model of the storage path:
//!   per-RPC overhead, per-OST FIFO service with stream-interleaving
//!   (seek) penalties, a bounded per-client RPC window, per-node LNET
//!   bandwidth, and a metadata server serializing opens. These are the
//!   mechanisms that produce the paper's contention shapes (Fig. 1's
//!   peaked throughput curve, Fig. 2's disk≪network gap),
//! * [`backend`] — the I/O interface used by the runtime: the simulated
//!   backend above (virtual clock) or a real local-disk backend with
//!   helper reader threads (wall clock, used by the end-to-end example),
//! * [`pattern`] — deterministic file contents so any experiment can
//!   verify end-to-end data integrity without storing gigabytes.

pub mod backend;
pub mod layout;
pub mod model;
pub mod pattern;

pub use backend::{IoOutcome, IoResult, ReadRequest, WriteRequest};
pub use layout::{FileId, FileMeta};
pub use model::{FaultPlan, PfsConfig, SimPfs, StragglerSpec};
