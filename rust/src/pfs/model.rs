//! Discrete-event queueing model of a Lustre-like storage path.
//!
//! A read becomes a sequence of OST RPCs (≤ `rpc_max_bytes`, stripe
//! aligned). The client keeps at most `client_window` RPCs in flight
//! (Lustre `max_rpcs_in_flight`). Each OST serves its queue FIFO; service
//! time is `rpc_overhead + len / ost_bw`, multiplied by log-normal noise,
//! plus a `seek_penalty` whenever the OST switches between request
//! streams — *this* term is what makes thousands of interleaved small
//! readers collapse (paper Fig. 1's right side), while the bounded client
//! window is what starves the disks when there are too few readers (the
//! left side). Completed RPCs flow back through a per-node LNET ingest
//! horizon, and opens serialize at a metadata server.

use std::collections::VecDeque;

use crate::amt::callback::Callback;
use crate::amt::time::{from_micros, from_secs, Time};
use crate::amt::topology::Pe;
use crate::metrics::{keys, Metrics};
use crate::trace::{names as trace_names, Lane as TraceLane, TraceCategory, TraceSink};
use crate::util::bytes::Chunk;
use crate::util::rng::Pcg32;

use super::backend::{IoOutcome, IoResult, ReadRequest, WriteRequest};
use super::layout::{FileId, FileMeta};
use super::pattern;

/// One OST made slow over an interval: every RPC it services with
/// `from <= now < until` takes `multiplier`× its normal service time.
/// Models a degraded disk, a rebuilding RAID set, or a noisy neighbor.
#[derive(Clone, Debug)]
pub struct StragglerSpec {
    pub ost: u32,
    pub multiplier: f64,
    pub from: Time,
    pub until: Time,
}

/// Deterministic fault schedule for the simulated PFS. All probabilities
/// are per-read; draws come from the model's seeded RNG, so a given
/// (seed, submission order) always produces the same faults. The default
/// plan injects nothing and touches no RNG state, so fault-free runs
/// replay bit-for-bit against pre-fault seeds.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// Probability a read fails with an error a retry may clear.
    pub transient_p: f64,
    /// Probability an *extent* is permanently bad: decided by hashing
    /// (file, offset, len), so every retry of the same extent re-fails.
    pub persistent_p: f64,
    /// Probability a read returns only a prefix of the requested bytes.
    pub short_p: f64,
    /// OSTs with degraded service over an interval.
    pub stragglers: Vec<StragglerSpec>,
}

impl FaultPlan {
    /// Any per-read fault configured (stragglers act on OST service,
    /// not on read outcomes, and are checked separately).
    fn read_faults(&self) -> bool {
        self.transient_p > 0.0 || self.persistent_p > 0.0 || self.short_p > 0.0
    }

    /// Anything at all configured.
    pub fn any(&self) -> bool {
        self.read_faults() || !self.stragglers.is_empty()
    }
}

/// SplitMix64-style extent hash mapped to [0, 1): the persistence oracle.
fn extent_hash(salt: u64, file: FileId, offset: u64, len: u64) -> f64 {
    let mut x = salt
        ^ (u64::from(file.0) << 32)
        ^ offset.wrapping_mul(0x9e37_79b9_7f4a_7c15)
        ^ len.rotate_left(17);
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^= x >> 31;
    (x >> 11) as f64 / (1u64 << 53) as f64
}

/// Model parameters. Defaults are calibrated in DESIGN.md §8 to match the
/// paper's *ratios* (single-stream disk ≈ 6–9× slower than the wire;
/// aggregate peak at moderate parallelism; collapse under many small
/// interleaved readers).
#[derive(Clone, Debug)]
pub struct PfsConfig {
    /// Number of OSTs in the pool.
    pub ost_count: u32,
    /// Default stripe size for new files.
    pub stripe_size: u64,
    /// Default stripe count for new files (≤ ost_count).
    pub stripe_count: u32,
    /// Max bytes per OST RPC.
    pub rpc_max_bytes: u64,
    /// Fixed service overhead per RPC (request handling, network setup).
    pub rpc_overhead: Time,
    /// Per-OST streaming bandwidth, bytes/sec.
    pub ost_bw: f64,
    /// Penalty when an OST switches streams (disk seek / readahead loss).
    pub seek_penalty: Time,
    /// Max RPCs a single client (PE) keeps in flight per request.
    pub client_window: u32,
    /// Per-node LNET ingest bandwidth, bytes/sec.
    pub lnet_bw: f64,
    /// Metadata-server service time per open.
    pub mds_open: Time,
    /// Log-normal service noise sigma (run-to-run variability).
    pub noise_sigma: f64,
    /// Materialize pattern bytes in completions (verified runs).
    pub materialize: bool,
    /// Injected fault schedule (default: none).
    pub faults: FaultPlan,
}

impl Default for PfsConfig {
    fn default() -> Self {
        PfsConfig {
            ost_count: 16,
            stripe_size: 4 << 20,
            stripe_count: 16,
            rpc_max_bytes: 4 << 20,
            rpc_overhead: from_micros(300.0),
            ost_bw: 1.5e9,
            seek_penalty: from_micros(1200.0),
            client_window: 2,
            lnet_bw: 3.0e9,
            mds_open: from_micros(40.0),
            noise_sigma: 0.05,
            materialize: false,
            faults: FaultPlan::default(),
        }
    }
}

/// Internal PFS events, scheduled on the engine's event heap.
#[derive(Copy, Clone, Debug)]
pub enum PfsEvent {
    /// An OST finished servicing an RPC.
    OstDone { ost: u32 },
    /// An RPC's payload finished arriving at the client node.
    RpcArrive { rpc: u32 },
}

/// An event the model wants scheduled at `at`.
#[derive(Copy, Clone, Debug)]
pub struct Scheduled {
    pub at: Time,
    pub ev: PfsEvent,
}

/// A finished read: deliver `result` to `callback` (on `pe`).
#[derive(Debug)]
pub struct Done {
    pub callback: Callback,
    pub pe: Pe,
    pub result: IoResult,
}

#[derive(Debug)]
struct Req {
    callback: Callback,
    pe: Pe,
    node: u32,
    file: FileId,
    offset: u64,
    len: u64,
    user: u64,
    /// Stripe-aligned extents not yet issued.
    pending: VecDeque<(u64, u64)>,
    /// RPCs issued but not yet arrived.
    in_flight: u32,
    done: bool,
    /// Issue time, for the service-time histogram and trace span.
    submitted_at: Time,
    /// Outcome decided at submission, surfaced when the read completes
    /// (errors are discovered at completion time, as on a real client).
    fault: IoOutcome,
    /// Direction (PR 10): writes ride the same OST/LNET machinery but
    /// account under `pfs.write_*` and deliver no payload.
    write: bool,
}

#[derive(Debug)]
struct Rpc {
    req: u32,
    len: u64,
}

#[derive(Debug, Default)]
struct Ost {
    queue: VecDeque<u32>,
    /// RPC currently in service (None = idle).
    current: Option<u32>,
    /// Stream key of the last serviced RPC (request id): consecutive RPCs
    /// from the same request stream avoid the seek penalty.
    last_stream: Option<u32>,
    busy_ns: u64,
}

/// The simulated PFS.
#[derive(Debug)]
pub struct SimPfs {
    pub cfg: PfsConfig,
    files: Vec<FileMeta>,
    osts: Vec<Ost>,
    node_rx_free: Vec<Time>,
    mds_free: Time,
    reqs: Vec<Req>,
    rpcs: Vec<Rpc>,
    rng: Pcg32,
    next_first_ost: u32,
    /// Reads submitted and not yet completed (the admission governor's
    /// cap is asserted against the high-water mark of this).
    active_reads: u32,
    /// Writes submitted and not yet committed (PR 10).
    active_writes: u32,
    /// Salt for the persistent-fault extent hash (the raw engine seed).
    fault_salt: u64,
    /// RPCs that hit a straggler interval (flushed to metrics as deltas
    /// at read completions — OST service has no metrics sink in scope).
    straggler_rpcs: u64,
    straggler_flushed: u64,
}

impl SimPfs {
    pub fn new(cfg: PfsConfig, nodes: u32, seed: u64) -> SimPfs {
        let osts = (0..cfg.ost_count).map(|_| Ost::default()).collect();
        SimPfs {
            cfg,
            files: Vec::new(),
            osts,
            node_rx_free: vec![0; nodes as usize],
            mds_free: 0,
            reqs: Vec::new(),
            rpcs: Vec::new(),
            rng: Pcg32::seeded(seed ^ 0x9df5),
            next_first_ost: 0,
            active_reads: 0,
            active_writes: 0,
            fault_salt: seed,
            straggler_rpcs: 0,
            straggler_flushed: 0,
        }
    }

    /// Register a file with the default striping.
    pub fn create_file(&mut self, size: u64) -> FileId {
        self.create_file_striped(size, self.cfg.stripe_count, self.cfg.stripe_size)
    }

    /// Register a file with explicit striping.
    pub fn create_file_striped(
        &mut self,
        size: u64,
        stripe_count: u32,
        stripe_size: u64,
    ) -> FileId {
        assert!(size > 0);
        let id = FileId(self.files.len() as u32);
        let first_ost = self.next_first_ost;
        self.next_first_ost = (self.next_first_ost + 1) % self.cfg.ost_count;
        self.files.push(FileMeta {
            id,
            size,
            stripe_size,
            stripe_count: stripe_count.min(self.cfg.ost_count),
            first_ost,
            path: None,
        });
        id
    }

    pub fn file(&self, id: FileId) -> &FileMeta {
        &self.files[id.0 as usize]
    }

    /// Serialize an open at the MDS; returns when it completes.
    pub fn open(&mut self, now: Time) -> Time {
        let start = self.mds_free.max(now);
        self.mds_free = start + self.cfg.mds_open;
        self.mds_free
    }

    /// Decide a submission's outcome up front. Persistent faults hash the
    /// extent (every retry of the same bytes re-fails); transient and
    /// short faults draw per-attempt from the seeded RNG. No RNG state is
    /// touched unless a read-fault probability is configured.
    fn decide_fault(&mut self, file: FileId, offset: u64, len: u64) -> IoOutcome {
        if !self.cfg.faults.read_faults() {
            return IoOutcome::Ok;
        }
        let (transient_p, persistent_p, short_p) = (
            self.cfg.faults.transient_p,
            self.cfg.faults.persistent_p,
            self.cfg.faults.short_p,
        );
        if persistent_p > 0.0 && extent_hash(self.fault_salt, file, offset, len) < persistent_p {
            return IoOutcome::PersistentError;
        }
        if transient_p > 0.0 && self.rng.gen_f64() < transient_p {
            return IoOutcome::TransientError;
        }
        if short_p > 0.0 && self.rng.gen_f64() < short_p {
            let valid = len / 2;
            if valid > 0 {
                return IoOutcome::Short { valid };
            }
            // A 1-byte short transfer has no useful prefix: surface it as
            // a plain transient failure.
            return IoOutcome::TransientError;
        }
        IoOutcome::Ok
    }

    /// Submit a read. Events to schedule are appended to `out`.
    #[allow(clippy::too_many_arguments)]
    pub fn submit(
        &mut self,
        now: Time,
        pe: Pe,
        node: u32,
        req: ReadRequest,
        callback: Callback,
        metrics: &mut Metrics,
        trace: &mut TraceSink,
        out: &mut Vec<Scheduled>,
    ) {
        let meta = self.file(req.file);
        let extents = meta.rpc_extents(req.offset, req.len, self.cfg.rpc_max_bytes);
        metrics.count(keys::PFS_RPCS, extents.len() as u64);
        metrics.count(keys::PFS_BYTES, req.len);
        self.active_reads += 1;
        metrics.set_max(keys::PFS_MAX_CONCURRENT, self.active_reads as f64);
        let rid = self.reqs.len() as u32;
        if trace.on(TraceCategory::Pfs) {
            trace.begin(
                now,
                TraceCategory::Pfs,
                trace_names::PFS_READ,
                TraceLane::Pe(pe.0),
                u64::from(rid),
                req.len,
                req.offset,
            );
        }
        let fault = self.decide_fault(req.file, req.offset, req.len);
        self.reqs.push(Req {
            callback,
            pe,
            node,
            file: req.file,
            offset: req.offset,
            len: req.len,
            user: req.user,
            pending: extents.into_iter().collect(),
            in_flight: 0,
            done: false,
            submitted_at: now,
            fault,
            write: false,
        });
        // Open the client window.
        for _ in 0..self.cfg.client_window {
            if !self.issue_next(rid, now, out) {
                break;
            }
        }
    }

    /// Submit a write (PR 10). Writes take the same path as reads — per
    /// RPC-extent OST queueing, seek penalties on stream switches, LNET
    /// serialization at the node — because the modeled costs (disk
    /// service, interleaving, wire time) are symmetric; only the
    /// accounting differs (`pfs.write_rpcs` / `pfs.bytes_written`, the
    /// `pfs/write` trace span, the write-service histogram) and the
    /// completion carries no payload. The [`FaultPlan`] applies to write
    /// RPCs too: the same probabilities decide transient, persistent and
    /// short (partial-commit) outcomes, so the PR 8 retry plane covers
    /// output as well as input.
    #[allow(clippy::too_many_arguments)]
    pub fn submit_write(
        &mut self,
        now: Time,
        pe: Pe,
        node: u32,
        req: WriteRequest,
        callback: Callback,
        metrics: &mut Metrics,
        trace: &mut TraceSink,
        out: &mut Vec<Scheduled>,
    ) {
        let meta = self.file(req.file);
        let extents = meta.rpc_extents(req.offset, req.len, self.cfg.rpc_max_bytes);
        metrics.count(keys::PFS_WRITE_RPCS, extents.len() as u64);
        metrics.count(keys::PFS_BYTES_WRITTEN, req.len);
        self.active_writes += 1;
        let rid = self.reqs.len() as u32;
        if trace.on(TraceCategory::Pfs) {
            trace.begin(
                now,
                TraceCategory::Pfs,
                trace_names::PFS_WRITE,
                TraceLane::Pe(pe.0),
                u64::from(rid),
                req.len,
                req.offset,
            );
        }
        let fault = self.decide_fault(req.file, req.offset, req.len);
        self.reqs.push(Req {
            callback,
            pe,
            node,
            file: req.file,
            offset: req.offset,
            len: req.len,
            user: req.user,
            pending: extents.into_iter().collect(),
            in_flight: 0,
            done: false,
            submitted_at: now,
            fault,
            write: true,
        });
        for _ in 0..self.cfg.client_window {
            if !self.issue_next(rid, now, out) {
                break;
            }
        }
    }

    /// Issue the next pending extent of a request to its OST.
    /// Returns false if nothing was pending.
    fn issue_next(&mut self, rid: u32, now: Time, out: &mut Vec<Scheduled>) -> bool {
        let (offset, len, file) = {
            let r = &mut self.reqs[rid as usize];
            match r.pending.pop_front() {
                Some((o, l)) => {
                    r.in_flight += 1;
                    (o, l, r.file)
                }
                None => return false,
            }
        };
        let ost = self.file(file).ost_of(offset, self.cfg.ost_count) as usize;
        let rpc_id = self.rpcs.len() as u32;
        self.rpcs.push(Rpc { req: rid, len });
        self.osts[ost].queue.push_back(rpc_id);
        if self.osts[ost].current.is_none() {
            self.start_service(ost, now, out);
        }
        true
    }

    /// Begin servicing the head of an idle OST's queue.
    fn start_service(&mut self, ost: usize, now: Time, out: &mut Vec<Scheduled>) {
        let Some(&rpc_id) = self.osts[ost].queue.front() else { return };
        self.osts[ost].queue.pop_front();
        let rpc = &self.rpcs[rpc_id as usize];
        let stream = rpc.req;
        let mut service = self.cfg.rpc_overhead
            + from_secs(rpc.len as f64 / self.cfg.ost_bw);
        if self.osts[ost].last_stream != Some(stream) {
            service += self.cfg.seek_penalty;
        }
        if self.cfg.noise_sigma > 0.0 {
            service = (service as f64 * self.rng.noise(self.cfg.noise_sigma)) as Time;
        }
        let mut straggle = None;
        for s in &self.cfg.faults.stragglers {
            if s.ost as usize == ost && now >= s.from && now < s.until {
                straggle = Some(s.multiplier);
                break;
            }
        }
        if let Some(mult) = straggle {
            service = (service as f64 * mult) as Time;
            self.straggler_rpcs += 1;
        }
        let o = &mut self.osts[ost];
        o.current = Some(rpc_id);
        o.last_stream = Some(stream);
        o.busy_ns += service;
        out.push(Scheduled { at: now + service, ev: PfsEvent::OstDone { ost: ost as u32 } });
    }

    /// Advance the model on one of its events. Completed reads are
    /// returned for the engine to deliver.
    pub fn on_event(
        &mut self,
        now: Time,
        ev: PfsEvent,
        metrics: &mut Metrics,
        trace: &mut TraceSink,
        out: &mut Vec<Scheduled>,
    ) -> Option<Done> {
        match ev {
            PfsEvent::OstDone { ost } => {
                let ost = ost as usize;
                let rpc_id = self.osts[ost].current.take().expect("OstDone on idle OST");
                metrics.charge(keys::OST_BUSY, 0); // busy accounted at start
                // Next queued RPC starts immediately.
                if !self.osts[ost].queue.is_empty() {
                    self.start_service(ost, now, out);
                }
                // Payload flows to the client node through LNET.
                let rpc = &self.rpcs[rpc_id as usize];
                let node = self.reqs[rpc.req as usize].node as usize;
                let rx = from_secs(rpc.len as f64 / self.cfg.lnet_bw);
                let start = self.node_rx_free[node].max(now);
                let arrive = start + rx;
                self.node_rx_free[node] = arrive;
                out.push(Scheduled { at: arrive, ev: PfsEvent::RpcArrive { rpc: rpc_id } });
                None
            }
            PfsEvent::RpcArrive { rpc } => {
                let rid = self.rpcs[rpc as usize].req;
                // Window slides: issue the next pending extent.
                self.issue_next(rid, now, out);
                let r = &mut self.reqs[rid as usize];
                r.in_flight -= 1;
                if r.in_flight == 0 && r.pending.is_empty() && !r.done {
                    r.done = true;
                    if r.write {
                        self.active_writes = self.active_writes.saturating_sub(1);
                    } else {
                        self.active_reads = self.active_reads.saturating_sub(1);
                    }
                    let service = now.saturating_sub(r.submitted_at);
                    metrics.record(
                        if r.write { keys::LATENCY_PFS_WRITE } else { keys::LATENCY_PFS_READ },
                        service,
                    );
                    if trace.on(TraceCategory::Pfs) {
                        trace.end(
                            now,
                            TraceCategory::Pfs,
                            if r.write { trace_names::PFS_WRITE } else { trace_names::PFS_READ },
                            TraceLane::Pe(r.pe.0),
                            u64::from(rid),
                            r.len,
                            service,
                        );
                    }
                    let outcome = r.fault;
                    let done_is_write = r.write;
                    // Errors deliver no bytes; short reads deliver the
                    // valid prefix; both still paid full modeled service
                    // time (the failure is discovered at completion).
                    // Write completions never carry a payload — the
                    // submitter owns the bytes until they are durable.
                    let chunk = if r.write {
                        Chunk::modeled(r.offset, 0)
                    } else {
                        match outcome {
                            IoOutcome::Ok if self.cfg.materialize => Chunk::materialized(
                                r.offset,
                                pattern::make(r.file, r.offset, r.len),
                            ),
                            IoOutcome::Ok => Chunk::modeled(r.offset, r.len),
                            IoOutcome::Short { valid } if self.cfg.materialize => {
                                Chunk::materialized(
                                    r.offset,
                                    pattern::make(r.file, r.offset, valid),
                                )
                            }
                            IoOutcome::Short { valid } => Chunk::modeled(r.offset, valid),
                            IoOutcome::TransientError | IoOutcome::PersistentError => {
                                Chunk::modeled(r.offset, 0)
                            }
                        }
                    };
                    let done = Done {
                        callback: r.callback.clone(),
                        pe: r.pe,
                        result: IoResult {
                            file: r.file,
                            offset: r.offset,
                            len: r.len,
                            user: r.user,
                            chunk,
                            outcome,
                        },
                    };
                    match outcome {
                        IoOutcome::Ok => {}
                        IoOutcome::TransientError => metrics.count(keys::FAULT_TRANSIENT, 1),
                        IoOutcome::PersistentError => metrics.count(keys::FAULT_PERSISTENT, 1),
                        IoOutcome::Short { .. } => metrics.count(keys::FAULT_SHORT, 1),
                    }
                    if !outcome.is_ok() && trace.on(TraceCategory::Pfs) {
                        let kind = match outcome {
                            IoOutcome::TransientError => "transient",
                            IoOutcome::PersistentError => "persistent",
                            IoOutcome::Short { .. } => "short",
                            IoOutcome::Ok => "",
                        };
                        trace.instant(
                            now,
                            TraceCategory::Pfs,
                            trace_names::PFS_FAULT,
                            TraceLane::Pe(done.pe.0),
                            u64::from(rid),
                            done.result.len,
                            kind,
                        );
                    }
                    if self.straggler_rpcs > self.straggler_flushed {
                        metrics
                            .count(keys::FAULT_STRAGGLER, self.straggler_rpcs - self.straggler_flushed);
                        self.straggler_flushed = self.straggler_rpcs;
                    }
                    metrics
                        .count(if done_is_write { "pfs.writes_done" } else { "pfs.reads_done" }, 1);
                    return Some(done);
                }
                None
            }
        }
    }

    /// Aggregate OST busy time (utilization numerator).
    pub fn total_ost_busy(&self) -> u64 {
        self.osts.iter().map(|o| o.busy_ns).sum()
    }

    /// Writes submitted and not yet committed (tests / inspection).
    pub fn active_writes(&self) -> u32 {
        self.active_writes
    }

    /// Reset all queueing state but keep files (between repetitions).
    pub fn reset(&mut self, seed: u64) {
        for o in &mut self.osts {
            *o = Ost::default();
        }
        self.node_rx_free.iter_mut().for_each(|t| *t = 0);
        self.mds_free = 0;
        self.reqs.clear();
        self.rpcs.clear();
        self.rng = Pcg32::seeded(seed ^ 0x9df5);
        self.active_reads = 0;
        self.active_writes = 0;
        self.fault_salt = seed;
        self.straggler_rpcs = 0;
        self.straggler_flushed = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_to_completion(
        pfs: &mut SimPfs,
        submits: Vec<(Time, Pe, u32, ReadRequest)>,
    ) -> Vec<(Time, Done)> {
        // Tiny standalone event loop driving just the PFS model.
        let mut metrics = Metrics::new();
        let mut trace = TraceSink::disabled();
        let mut heap: std::collections::BinaryHeap<std::cmp::Reverse<(Time, u64, usize)>> =
            Default::default();
        let mut evs: Vec<PfsEvent> = Vec::new();
        let mut seq = 0u64;
        let mut out = Vec::new();
        let mut dones = Vec::new();
        for (t, pe, node, req) in submits {
            pfs.submit(t, pe, node, req, Callback::Ignore, &mut metrics, &mut trace, &mut out);
            for s in out.drain(..) {
                evs.push(s.ev);
                heap.push(std::cmp::Reverse((s.at, seq, evs.len() - 1)));
                seq += 1;
            }
        }
        while let Some(std::cmp::Reverse((t, _, idx))) = heap.pop() {
            if let Some(d) = pfs.on_event(t, evs[idx], &mut metrics, &mut trace, &mut out) {
                dones.push((t, d));
            }
            for s in out.drain(..) {
                evs.push(s.ev);
                heap.push(std::cmp::Reverse((s.at, seq, evs.len() - 1)));
                seq += 1;
            }
        }
        dones
    }

    fn quiet(cfg: &mut PfsConfig) {
        cfg.noise_sigma = 0.0;
    }

    #[test]
    fn single_read_completes_with_correct_extent() {
        let mut cfg = PfsConfig::default();
        quiet(&mut cfg);
        cfg.materialize = true;
        let mut pfs = SimPfs::new(cfg, 2, 1);
        let f = pfs.create_file(64 << 20);
        let dones = run_to_completion(
            &mut pfs,
            vec![(0, Pe(0), 0, ReadRequest { file: f, offset: 1 << 20, len: 8 << 20, user: 7 })],
        );
        assert_eq!(dones.len(), 1);
        let (t, d) = &dones[0];
        assert!(*t > 0);
        assert_eq!(d.result.offset, 1 << 20);
        assert_eq!(d.result.len, 8 << 20);
        assert_eq!(d.result.user, 7);
        let bytes = d.result.chunk.bytes.as_ref().unwrap();
        assert_eq!(pattern::verify(f, 1 << 20, bytes), None);
    }

    #[test]
    fn writes_complete_and_account_under_write_keys() {
        let mut cfg = PfsConfig::default();
        quiet(&mut cfg);
        let mut pfs = SimPfs::new(cfg, 2, 1);
        let f = pfs.create_file(64 << 20);
        let mut metrics = Metrics::new();
        let mut trace = TraceSink::disabled();
        let mut out = Vec::new();
        pfs.submit_write(
            0,
            Pe(0),
            0,
            WriteRequest { file: f, offset: 4 << 20, len: 8 << 20, user: 3 },
            Callback::Ignore,
            &mut metrics,
            &mut trace,
            &mut out,
        );
        assert_eq!(pfs.active_writes(), 1);
        // Drive the standalone loop by hand (submit already queued events).
        let mut heap: std::collections::BinaryHeap<std::cmp::Reverse<(Time, u64, usize)>> =
            Default::default();
        let mut evs: Vec<PfsEvent> = Vec::new();
        let mut seq = 0u64;
        for s in out.drain(..) {
            evs.push(s.ev);
            heap.push(std::cmp::Reverse((s.at, seq, evs.len() - 1)));
            seq += 1;
        }
        let mut dones = Vec::new();
        while let Some(std::cmp::Reverse((t, _, idx))) = heap.pop() {
            if let Some(d) = pfs.on_event(t, evs[idx], &mut metrics, &mut trace, &mut out) {
                dones.push((t, d));
            }
            for s in out.drain(..) {
                evs.push(s.ev);
                heap.push(std::cmp::Reverse((s.at, seq, evs.len() - 1)));
                seq += 1;
            }
        }
        assert_eq!(dones.len(), 1);
        let (t, d) = &dones[0];
        assert!(*t > 0, "writes pay modeled service time");
        assert_eq!(d.result.user, 3);
        assert_eq!(d.result.outcome, IoOutcome::Ok);
        assert!(d.result.chunk.bytes.is_none(), "write completions carry no payload");
        assert_eq!(pfs.active_writes(), 0);
        // 8 MiB in 4 MiB stripes = 2 write RPCs, zero read RPCs.
        assert_eq!(metrics.counter(keys::PFS_WRITE_RPCS), 2);
        assert_eq!(metrics.counter(keys::PFS_BYTES_WRITTEN), 8 << 20);
        assert_eq!(metrics.counter(keys::PFS_RPCS), 0);
        assert_eq!(metrics.counter("pfs.writes_done"), 1);
    }

    #[test]
    fn write_faults_draw_from_the_same_plan() {
        let mut cfg = PfsConfig::default();
        quiet(&mut cfg);
        cfg.faults.transient_p = 0.3;
        let mut pfs = SimPfs::new(cfg, 16, 11);
        let f = pfs.create_file(1 << 30);
        let n = 200u64;
        let per = (1u64 << 30) / n;
        let mut metrics = Metrics::new();
        let mut trace = TraceSink::disabled();
        let mut heap: std::collections::BinaryHeap<std::cmp::Reverse<(Time, u64, usize)>> =
            Default::default();
        let mut evs: Vec<PfsEvent> = Vec::new();
        let mut seq = 0u64;
        let mut out = Vec::new();
        for i in 0..n {
            pfs.submit_write(
                0,
                Pe((i % 16) as u32),
                (i % 16) as u32,
                WriteRequest { file: f, offset: i * per, len: per, user: i },
                Callback::Ignore,
                &mut metrics,
                &mut trace,
                &mut out,
            );
            for s in out.drain(..) {
                evs.push(s.ev);
                heap.push(std::cmp::Reverse((s.at, seq, evs.len() - 1)));
                seq += 1;
            }
        }
        let mut failed = 0usize;
        let mut completed = 0usize;
        while let Some(std::cmp::Reverse((t, _, idx))) = heap.pop() {
            if let Some(d) = pfs.on_event(t, evs[idx], &mut metrics, &mut trace, &mut out) {
                completed += 1;
                if d.result.outcome == IoOutcome::TransientError {
                    failed += 1;
                }
            }
            for s in out.drain(..) {
                evs.push(s.ev);
                heap.push(std::cmp::Reverse((s.at, seq, evs.len() - 1)));
                seq += 1;
            }
        }
        assert_eq!(completed, n as usize, "faulted writes still complete");
        let rate = failed as f64 / n as f64;
        assert!((0.15..0.45).contains(&rate), "rate={rate}");
        assert_eq!(metrics.counter(keys::FAULT_TRANSIENT), failed as u64);
    }

    #[test]
    fn throughput_peaks_at_moderate_parallelism() {
        // The Fig.1 shape: 1 client < 32 clients; 4096 clients < 32 clients.
        let total: u64 = 1 << 30; // 1 GiB
        let time_for = |nclients: u64| -> f64 {
            let mut cfg = PfsConfig::default();
            quiet(&mut cfg);
            let mut pfs = SimPfs::new(cfg, 16, 1);
            let f = pfs.create_file(total);
            let per = total / nclients;
            let submits = (0..nclients)
                .map(|i| {
                    (0, Pe((i % 512) as u32), (i % 16) as u32,
                     ReadRequest { file: f, offset: i * per, len: per, user: i })
                })
                .collect();
            let dones = run_to_completion(&mut pfs, submits);
            assert_eq!(dones.len(), nclients as usize);
            dones.iter().map(|(t, _)| *t).max().unwrap() as f64 / 1e9
        };
        let t1 = time_for(1);
        let t32 = time_for(32);
        let t4096 = time_for(4096);
        assert!(t32 < t1, "32 clients ({t32}s) should beat 1 client ({t1}s)");
        assert!(t32 < t4096, "32 clients ({t32}s) should beat 4096 clients ({t4096}s)");
    }

    #[test]
    fn transient_faults_hit_at_roughly_the_configured_rate() {
        let mut cfg = PfsConfig::default();
        quiet(&mut cfg);
        cfg.faults.transient_p = 0.2;
        let mut pfs = SimPfs::new(cfg, 16, 3);
        let f = pfs.create_file(1 << 30);
        let n = 500u64;
        let per = (1u64 << 30) / n;
        let submits = (0..n)
            .map(|i| {
                (0, Pe((i % 16) as u32), (i % 16) as u32,
                 ReadRequest { file: f, offset: i * per, len: per, user: i })
            })
            .collect();
        let dones = run_to_completion(&mut pfs, submits);
        assert_eq!(dones.len(), n as usize, "faulted reads still complete");
        let failed = dones
            .iter()
            .filter(|(_, d)| d.result.outcome == IoOutcome::TransientError)
            .count();
        let rate = failed as f64 / n as f64;
        assert!((0.1..0.3).contains(&rate), "rate={rate}");
    }

    #[test]
    fn persistent_faults_refail_the_same_extent() {
        let mut cfg = PfsConfig::default();
        quiet(&mut cfg);
        cfg.faults.persistent_p = 0.3;
        let mut pfs = SimPfs::new(cfg, 1, 9);
        let f = pfs.create_file(1 << 30);
        let n = 64u64;
        let per = (1u64 << 30) / n;
        let reqs: Vec<ReadRequest> = (0..n)
            .map(|i| ReadRequest { file: f, offset: i * per, len: per, user: i })
            .collect();
        let first: Vec<IoOutcome> = run_to_completion(
            &mut pfs,
            reqs.iter().map(|r| (0, Pe(0), 0, *r)).collect(),
        )
        .iter()
        .map(|(_, d)| d.result.outcome)
        .collect();
        assert!(first.contains(&IoOutcome::PersistentError));
        assert!(first.contains(&IoOutcome::Ok));
        // "Retry" every extent: persistent verdicts must be identical.
        let mut pfs2 = SimPfs::new(
            { let mut c = PfsConfig::default(); quiet(&mut c); c.faults.persistent_p = 0.3; c },
            1,
            9,
        );
        pfs2.create_file(1 << 30);
        let again: Vec<IoOutcome> = run_to_completion(
            &mut pfs2,
            reqs.iter().map(|r| (0, Pe(0), 0, *r)).collect(),
        )
        .iter()
        .map(|(_, d)| d.result.outcome)
        .collect();
        assert_eq!(first, again);
    }

    #[test]
    fn short_reads_deliver_a_verified_prefix() {
        let mut cfg = PfsConfig::default();
        quiet(&mut cfg);
        cfg.materialize = true;
        cfg.faults.short_p = 1.0;
        let mut pfs = SimPfs::new(cfg, 1, 5);
        let f = pfs.create_file(64 << 20);
        let dones = run_to_completion(
            &mut pfs,
            vec![(0, Pe(0), 0, ReadRequest { file: f, offset: 0, len: 8 << 20, user: 0 })],
        );
        assert_eq!(dones.len(), 1);
        let d = &dones[0].1;
        let IoOutcome::Short { valid } = d.result.outcome else {
            panic!("expected short read, got {:?}", d.result.outcome);
        };
        assert_eq!(valid, 4 << 20);
        let bytes = d.result.chunk.bytes.as_ref().unwrap();
        assert_eq!(bytes.len() as u64, valid);
        assert_eq!(pattern::verify(f, 0, bytes), None);
    }

    #[test]
    fn straggler_ost_inflates_service_time() {
        let read = ReadRequest { file: FileId(0), offset: 0, len: 16 << 20, user: 0 };
        let makespan = |stragglers: Vec<StragglerSpec>| -> Time {
            let mut cfg = PfsConfig::default();
            quiet(&mut cfg);
            cfg.stripe_count = 1; // everything lands on OST 0
            cfg.faults.stragglers = stragglers;
            let mut pfs = SimPfs::new(cfg, 1, 1);
            pfs.create_file_striped(16 << 20, 1, 4 << 20);
            let dones = run_to_completion(&mut pfs, vec![(0, Pe(0), 0, read)]);
            dones[0].0
        };
        let clean = makespan(vec![]);
        let slowed = makespan(vec![StragglerSpec {
            ost: 0,
            multiplier: 8.0,
            from: 0,
            until: Time::MAX,
        }]);
        assert!(
            slowed as f64 > clean as f64 * 4.0,
            "straggler should dominate: clean={clean} slowed={slowed}"
        );
        // An interval that never overlaps the run changes nothing.
        let missed = makespan(vec![StragglerSpec {
            ost: 0,
            multiplier: 8.0,
            from: Time::MAX - 1,
            until: Time::MAX,
        }]);
        assert_eq!(missed, clean);
    }

    #[test]
    fn mds_serializes_opens() {
        let mut cfg = PfsConfig::default();
        quiet(&mut cfg);
        let mds_open = cfg.mds_open;
        let mut pfs = SimPfs::new(cfg, 1, 1);
        let a = pfs.open(0);
        let b = pfs.open(0);
        let c = pfs.open(b);
        assert_eq!(a, mds_open);
        assert_eq!(b, 2 * mds_open);
        assert_eq!(c, 3 * mds_open);
    }

    #[test]
    fn window_limits_in_flight() {
        let mut cfg = PfsConfig::default();
        quiet(&mut cfg);
        cfg.client_window = 2;
        let mut pfs = SimPfs::new(cfg, 1, 1);
        let f = pfs.create_file(64 << 20);
        let mut out = Vec::new();
        let mut metrics = Metrics::new();
        let mut trace = TraceSink::disabled();
        pfs.submit(0, Pe(0), 0,
            ReadRequest { file: f, offset: 0, len: 32 << 20, user: 0 },
            Callback::Ignore, &mut metrics, &mut trace, &mut out);
        // 8 extents of 4 MiB, but only `client_window` service starts.
        assert_eq!(out.len(), 2);
        assert_eq!(pfs.reqs[0].in_flight, 2);
        assert_eq!(pfs.reqs[0].pending.len(), 6);
    }

    #[test]
    fn sequential_stream_avoids_seeks() {
        // One client reading 64 MiB should pay ~zero seek penalties after
        // the first RPC per OST; 64 interleaved clients on the same data
        // pay one per RPC. Compare total OST busy time.
        let total: u64 = 64 << 20;
        let busy_for = |nclients: u64| -> u64 {
            let mut cfg = PfsConfig::default();
            quiet(&mut cfg);
            cfg.stripe_count = 1; // single OST: pure interleaving test
            let mut pfs = SimPfs::new(cfg, 1, 1);
            let f = pfs.create_file_striped(total, 1, 4 << 20);
            let per = total / nclients;
            let submits = (0..nclients)
                .map(|i| (0, Pe(0), 0, ReadRequest { file: f, offset: i * per, len: per, user: i }))
                .collect();
            run_to_completion(&mut pfs, submits);
            pfs.total_ost_busy()
        };
        let seq = busy_for(1);
        let inter = busy_for(16);
        assert!(inter as f64 > seq as f64 * 1.2, "seq={seq} inter={inter}");
    }

    #[test]
    fn lnet_caps_node_ingest() {
        // All data landing on one node serializes at LNET; spread across
        // 16 nodes it doesn't.
        let total: u64 = 256 << 20;
        let time_for = |nodes: u32| -> f64 {
            let mut cfg = PfsConfig::default();
            quiet(&mut cfg);
            let mut pfs = SimPfs::new(cfg, 16, 1);
            let f = pfs.create_file(total);
            let nclients = 16u64;
            let per = total / nclients;
            let submits = (0..nclients)
                .map(|i| {
                    (0, Pe(i as u32), (i % nodes as u64) as u32,
                     ReadRequest { file: f, offset: i * per, len: per, user: i })
                })
                .collect();
            let dones = run_to_completion(&mut pfs, submits);
            dones.iter().map(|(t, _)| *t).max().unwrap() as f64 / 1e9
        };
        let one_node = time_for(1);
        let many_nodes = time_for(16);
        assert!(one_node > many_nodes * 1.5, "one={one_node} many={many_nodes}");
    }
}
