//! Mini-ChaNGa: the paper's real-application evaluation (§IV-B).
//!
//! A collision-less N-body code skeleton matching ChaNGa's input
//! structure: a single Tipsy-format file read collectively by a large
//! array of TreePieces (2^16 in the paper), under three interchangeable
//! input schemes (unoptimized / hand-optimized one-reader-per-PE / CkIO),
//! followed by a compute phase that runs the AOT JAX/Pallas gravity
//! artifacts via PJRT.

pub mod driver;
pub mod gravity;
pub mod tipsy;
pub mod treepiece;

pub use driver::{run_changa_input, ChangaRun};
pub use treepiece::{ChangaConfig, InputScheme, TreePiece};
