//! Tipsy-like binary particle format (paper §IV-B).
//!
//! ChaNGa reads cosmological initial conditions in the Tipsy format; we
//! implement a compatible-in-spirit fixed-record binary layout with
//! quantized fields, so the ingest path exercises a real decode:
//!
//! ```text
//! header (80 bytes):
//!   magic   u32 = 0x7D1B51    version u32 = 1
//!   nbodies u64
//!   scale   [f32; 8]          offset  [f32; 8]
//! record (32 bytes each), fields quantized as i32:
//!   [mass, x, y, z, vx, vy, vz, softening]
//!   physical = raw * scale[f] + offset[f]
//! ```
//!
//! The same decode runs in three places and must agree: the Rust
//! reference here (tests), the Pallas `decode` kernel inside the ingest
//! artifact (request path), and the writer's inverse quantization.

use crate::util::rng::Pcg32;

pub const MAGIC: u32 = 0x7D1B51;
pub const HEADER_BYTES: u64 = 80;
pub const RECORD_BYTES: u64 = 32;
pub const FIELDS: usize = 8;

/// A physical particle.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Particle {
    pub mass: f32,
    pub pos: [f32; 3],
    pub vel: [f32; 3],
    pub softening: f32,
}

impl Particle {
    pub fn fields(&self) -> [f32; FIELDS] {
        [
            self.mass,
            self.pos[0],
            self.pos[1],
            self.pos[2],
            self.vel[0],
            self.vel[1],
            self.vel[2],
            self.softening,
        ]
    }
}

/// File header.
#[derive(Clone, Debug, PartialEq)]
pub struct Header {
    pub nbodies: u64,
    pub scale: [f32; FIELDS],
    pub offset: [f32; FIELDS],
}

impl Header {
    pub fn to_bytes(&self) -> [u8; HEADER_BYTES as usize] {
        let mut b = [0u8; HEADER_BYTES as usize];
        b[0..4].copy_from_slice(&MAGIC.to_le_bytes());
        b[4..8].copy_from_slice(&1u32.to_le_bytes());
        b[8..16].copy_from_slice(&self.nbodies.to_le_bytes());
        for f in 0..FIELDS {
            b[16 + 4 * f..20 + 4 * f].copy_from_slice(&self.scale[f].to_le_bytes());
            b[48 + 4 * f..52 + 4 * f].copy_from_slice(&self.offset[f].to_le_bytes());
        }
        b
    }

    pub fn from_bytes(b: &[u8]) -> Result<Header, String> {
        if b.len() < HEADER_BYTES as usize {
            return Err(format!("short header: {} bytes", b.len()));
        }
        let magic = u32::from_le_bytes(b[0..4].try_into().unwrap());
        if magic != MAGIC {
            return Err(format!("bad magic {magic:#x}"));
        }
        let nbodies = u64::from_le_bytes(b[8..16].try_into().unwrap());
        let mut scale = [0f32; FIELDS];
        let mut offset = [0f32; FIELDS];
        for f in 0..FIELDS {
            scale[f] = f32::from_le_bytes(b[16 + 4 * f..20 + 4 * f].try_into().unwrap());
            offset[f] = f32::from_le_bytes(b[48 + 4 * f..52 + 4 * f].try_into().unwrap());
        }
        Ok(Header { nbodies, scale, offset })
    }

    /// Byte extent of records `[lo, hi)`.
    pub fn record_extent(&self, lo: u64, hi: u64) -> (u64, u64) {
        debug_assert!(lo <= hi && hi <= self.nbodies);
        (HEADER_BYTES + lo * RECORD_BYTES, (hi - lo) * RECORD_BYTES)
    }

    pub fn file_bytes(&self) -> u64 {
        HEADER_BYTES + self.nbodies * RECORD_BYTES
    }
}

/// Quantize a particle into a 32-byte record.
pub fn encode_record(h: &Header, p: &Particle) -> [u8; RECORD_BYTES as usize] {
    let mut b = [0u8; RECORD_BYTES as usize];
    let fs = p.fields();
    for f in 0..FIELDS {
        let raw = ((fs[f] - h.offset[f]) / h.scale[f]).round() as i32;
        b[4 * f..4 * f + 4].copy_from_slice(&raw.to_le_bytes());
    }
    b
}

/// Rust-side record decode (reference for the Pallas kernel path). Also
/// returns the raw integer values as f32, which is what the ingest
/// artifact takes as input.
pub fn decode_record(h: &Header, b: &[u8]) -> ([f32; FIELDS], [f32; FIELDS]) {
    debug_assert!(b.len() >= RECORD_BYTES as usize);
    let mut raw = [0f32; FIELDS];
    let mut phys = [0f32; FIELDS];
    for f in 0..FIELDS {
        let r = i32::from_le_bytes(b[4 * f..4 * f + 4].try_into().unwrap()) as f32;
        raw[f] = r;
        phys[f] = r * h.scale[f] + h.offset[f];
    }
    (raw, phys)
}

/// Default quantization for unit-box Plummer-ish initial conditions.
pub fn default_header(nbodies: u64) -> Header {
    Header {
        nbodies,
        // mass, x, y, z, vx, vy, vz, softening
        scale: [1e-6, 1e-4, 1e-4, 1e-4, 1e-5, 1e-5, 1e-5, 1e-6],
        offset: [0.0; FIELDS],
    }
}

/// Generate a synthetic Plummer-like sphere.
pub fn generate(nbodies: u64, seed: u64) -> Vec<Particle> {
    let mut rng = Pcg32::seeded(seed);
    (0..nbodies)
        .map(|_| {
            // Radius with a soft core, isotropic direction.
            let r = 0.1 + rng.gen_f64().powf(0.7) as f32;
            let theta = (1.0 - 2.0 * rng.gen_f64()) as f32;
            let phi = (2.0 * std::f64::consts::PI * rng.gen_f64()) as f32;
            let st = (1.0 - theta * theta).max(0.0).sqrt();
            let pos = [r * st * phi.cos(), r * st * phi.sin(), r * theta];
            let vel = [
                (rng.gen_normal() * 0.05) as f32,
                (rng.gen_normal() * 0.05) as f32,
                (rng.gen_normal() * 0.05) as f32,
            ];
            Particle { mass: 1.0 / nbodies as f32, pos, vel, softening: 0.01 }
        })
        .collect()
}

/// Serialize a whole file to bytes.
pub fn write_bytes(h: &Header, particles: &[Particle]) -> Vec<u8> {
    assert_eq!(h.nbodies as usize, particles.len());
    let mut out = Vec::with_capacity(h.file_bytes() as usize);
    out.extend_from_slice(&h.to_bytes());
    for p in particles {
        out.extend_from_slice(&encode_record(h, p));
    }
    out
}

/// Write a synthetic Tipsy file to disk; returns the header.
pub fn write_file(
    path: impl AsRef<std::path::Path>,
    nbodies: u64,
    seed: u64,
) -> std::io::Result<Header> {
    let h = default_header(nbodies);
    let particles = generate(nbodies, seed);
    std::fs::write(path, write_bytes(&h, &particles))?;
    Ok(h)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_round_trips() {
        let h = default_header(12345);
        let b = h.to_bytes();
        let h2 = Header::from_bytes(&b).unwrap();
        assert_eq!(h, h2);
    }

    #[test]
    fn bad_magic_rejected() {
        let mut b = default_header(1).to_bytes();
        b[0] = 0xFF;
        assert!(Header::from_bytes(&b).is_err());
    }

    #[test]
    fn record_quantization_round_trips_within_scale() {
        let h = default_header(100);
        let particles = generate(100, 7);
        for p in &particles {
            let rec = encode_record(&h, p);
            let (_raw, phys) = decode_record(&h, &rec);
            let fs = p.fields();
            for f in 0..FIELDS {
                assert!(
                    (phys[f] - fs[f]).abs() <= h.scale[f] * 0.51,
                    "field {f}: {} vs {}",
                    phys[f],
                    fs[f]
                );
            }
        }
    }

    #[test]
    fn extents_and_sizes() {
        let h = default_header(1000);
        assert_eq!(h.file_bytes(), 80 + 1000 * 32);
        assert_eq!(h.record_extent(0, 10), (80, 320));
        assert_eq!(h.record_extent(990, 1000), (80 + 990 * 32, 320));
    }

    #[test]
    fn whole_file_round_trips() {
        let h = default_header(64);
        let ps = generate(64, 3);
        let bytes = write_bytes(&h, &ps);
        assert_eq!(bytes.len() as u64, h.file_bytes());
        let h2 = Header::from_bytes(&bytes).unwrap();
        assert_eq!(h2.nbodies, 64);
        // Decode record 10 and compare against the source particle.
        let (o, _) = h2.record_extent(10, 11);
        let (_, phys) = decode_record(&h2, &bytes[o as usize..]);
        assert!((phys[0] - ps[10].mass).abs() <= h.scale[0]);
        assert!((phys[1] - ps[10].pos[0]).abs() <= h.scale[1]);
    }

    #[test]
    fn generation_is_deterministic() {
        assert_eq!(generate(10, 5), generate(10, 5));
        assert_ne!(generate(10, 5), generate(10, 6));
    }
}
