//! TreePieces: mini-ChaNGa's over-decomposed particle owners and the
//! three input schemes the paper's Fig. 13 compares:
//!
//! 1. **Unopt** — every TreePiece reads its own records directly from
//!    the file system (per-TP open + read),
//! 2. **HandOpt** — the original ChaNGa optimization: one designated
//!    reader TreePiece per PE reads a large contiguous block and
//!    redistributes particles to their owners over the interconnect,
//! 3. **CkIo** — the paper's contribution: TreePieces read through a
//!    CkIO session; reader decomposition is independent and tunable.
//!
//! After input, in wall-clock runs each piece ingests its raw records
//! through the `ingest` artifact and advances with the `gravity`
//! artifact (see [`super::gravity`]); pieces exchange monopole moments
//! (one-level Barnes-Hut) between steps.

use crate::amt::callback::Callback;
use crate::amt::chare::{Chare, ChareRef, CollectionId};
use crate::amt::engine::Ctx;
use crate::amt::msg::{Ep, Msg, Payload};
use crate::amt::protocol::{PayloadKind, ProtocolSpec};
use crate::amt::time::Time;
use crate::ckio::{CkIo, ReadResult, Session};
use crate::impl_chare_any;
use crate::net::Transfer;
use crate::pfs::backend::{IoResult, ReadRequest};
use crate::pfs::layout::FileId;
use crate::util::bytes::Chunk;
use crate::{ep_spec, send_spec};

use super::gravity::{GravityCompute, PieceState};
use super::tipsy::{Header, HEADER_BYTES, RECORD_BYTES};

/// Start the input phase.
pub const EP_TP_GO: Ep = 1;
/// MDS open completed (unopt path).
pub const EP_TP_OPENED: Ep = 2;
/// Raw read completed (unopt / handopt reader).
pub const EP_TP_RAW: Ep = 3;
/// Redistributed particles arriving (handopt path).
pub const EP_TP_PARTICLES: Ep = 4;
/// CkIO session handle broadcast (ckio path).
pub const EP_TP_SESSION: Ep = 5;
/// CkIO read completed.
pub const EP_TP_CKDATA: Ep = 6;
/// CkIO open completed (leader only).
pub const EP_TP_CKOPENED: Ep = 7;
/// Run one gravity step (wall-mode compute phase).
pub const EP_TP_STEP: Ep = 8;
/// Other pieces' moments (monopole exchange).
pub const EP_TP_MOMENTS: Ep = 9;

/// Which input scheme a TreePiece array uses.
#[derive(Clone)]
pub enum InputScheme {
    Unopt,
    HandOpt,
    CkIo { io: CkIo },
}

/// Immutable description shared by all pieces of one run.
#[derive(Clone)]
pub struct ChangaConfig {
    pub file: FileId,
    pub header: Header,
    pub n_tp: u32,
    pub scheme: InputScheme,
    /// Modeled decode cost per byte (virtual runs), ns/B.
    pub decode_ns_per_byte: f64,
    /// Compute engine for wall-mode runs.
    pub compute: Option<GravityCompute>,
    /// Fired once per piece when its particles are resident (payload:
    /// bytes received).
    pub input_done: Callback,
}

pub struct MomentsMsg {
    pub from: u32,
    pub mass: f32,
    pub com: [f32; 3],
}

/// One TreePiece.
pub struct TreePiece {
    pub cfg: ChangaConfig,
    pub index: u32,
    /// Record range [lo, hi) owned by this piece.
    pub rec_lo: u64,
    pub rec_hi: u64,
    /// Collection (set post-creation by the driver).
    pub pieces: CollectionId,
    /// Input progress.
    received: u64,
    raw: Vec<Chunk>,
    session: Option<Session>,
    input_complete: bool,
    /// Compute state (wall mode).
    pub state: Option<PieceState>,
    far: Vec<(f32, [f32; 3])>,
    moments_seen: u32,
    /// Diagnostic per-step |acc| sums.
    pub acc_log: Vec<f32>,
    pub step_done: Option<Callback>,
}

impl TreePiece {
    pub fn new(cfg: ChangaConfig, index: u32) -> TreePiece {
        let n = cfg.header.nbodies;
        let per = n.div_ceil(cfg.n_tp as u64);
        let lo = (index as u64 * per).min(n);
        let hi = ((index as u64 + 1) * per).min(n);
        TreePiece {
            cfg,
            index,
            rec_lo: lo,
            rec_hi: hi,
            pieces: CollectionId(u32::MAX),
            received: 0,
            raw: Vec::new(),
            session: None,
            input_complete: false,
            state: None,
            far: Vec::new(),
            moments_seen: 0,
            acc_log: Vec::new(),
            step_done: None,
        }
    }

    fn my_bytes(&self) -> u64 {
        (self.rec_hi - self.rec_lo) * RECORD_BYTES
    }

    fn my_extent(&self) -> (u64, u64) {
        self.cfg.header.record_extent(self.rec_lo, self.rec_hi)
    }

    /// Am I the designated reader of my PE (handopt scheme)?
    /// Convention: the lowest TP index on each PE reads. With round-robin
    /// placement that's indices 0..npes.
    fn is_reader(&self, ctx: &Ctx<'_>) -> bool {
        self.index < ctx.topo().npes()
    }

    /// The contiguous record block a handopt reader covers.
    fn reader_block(&self, ctx: &Ctx<'_>) -> (u64, u64) {
        let npes = ctx.topo().npes() as u64;
        let n = self.cfg.header.nbodies;
        let per = n.div_ceil(npes);
        let lo = (self.index as u64 * per).min(n);
        let hi = ((self.index as u64 + 1) * per).min(n);
        (lo, hi)
    }

    /// Record range → owning TP index range (inclusive).
    fn owners_of(&self, rec_lo: u64, rec_hi: u64) -> std::ops::RangeInclusive<u32> {
        let n = self.cfg.header.nbodies;
        let per = n.div_ceil(self.cfg.n_tp as u64);
        let lo = (rec_lo / per) as u32;
        let hi = ((rec_hi - 1) / per) as u32;
        lo..=hi.min(self.cfg.n_tp - 1)
    }

    fn particles_arrived(&mut self, ctx: &mut Ctx<'_>, chunk: Chunk) {
        self.received += chunk.len;
        self.raw.push(chunk);
        debug_assert!(self.received <= self.my_bytes());
        if self.received == self.my_bytes() && !self.input_complete {
            self.input_complete = true;
            // Ingest: decode + permute + moments.
            if let Some(gc) = self.cfg.compute.clone() {
                let bytes = self.assemble_raw();
                let ing = gc
                    .ingest(&self.cfg.header, &bytes, None)
                    .expect("ingest artifact");
                let mass = ing.total_mass;
                let com = ing.com;
                self.state = Some(ing.into_state());
                // Publish my moments to the other pieces.
                for j in 0..self.cfg.n_tp {
                    if j != self.index {
                        ctx.send(
                            ChareRef::new(self.pieces, j),
                            EP_TP_MOMENTS,
                            MomentsMsg { from: self.index, mass, com },
                        );
                    }
                }
            } else {
                // Virtual runs: charge a modeled decode.
                let cost = (self.my_bytes() as f64 * self.cfg.decode_ns_per_byte) as Time;
                ctx.charge("changa.decode", cost);
            }
            let bytes = self.received;
            ctx.metrics().count("changa.pieces_done", 1);
            ctx.fire(self.cfg.input_done.clone(), Payload::new(bytes));
        }
    }

    /// Concatenate received chunks in offset order (materialized runs).
    fn assemble_raw(&self) -> Vec<u8> {
        let mut chunks: Vec<&Chunk> = self.raw.iter().collect();
        chunks.sort_by_key(|c| c.offset);
        let mut out = Vec::with_capacity(self.my_bytes() as usize);
        for c in chunks {
            out.extend_from_slice(c.bytes.as_ref().expect("materialized input"));
        }
        out
    }
}

/// The piece's declared message protocol (see [`crate::amt::protocol`]).
/// `EP_TP_CKOPENED` is `Any`: the open callback delivers the library's
/// handle-or-error payload, which this module deliberately ignores.
pub fn protocol_spec() -> ProtocolSpec {
    ProtocolSpec {
        chare: "TreePiece",
        module: "apps/changa/treepiece.rs",
        handles: vec![
            ep_spec!(EP_TP_GO, PayloadKind::Signal),
            ep_spec!(EP_TP_OPENED, PayloadKind::Signal),
            ep_spec!(EP_TP_RAW, PayloadKind::of::<IoResult>()),
            ep_spec!(EP_TP_PARTICLES, PayloadKind::of::<Chunk>()),
            ep_spec!(EP_TP_SESSION, PayloadKind::of::<Session>()),
            ep_spec!(EP_TP_CKDATA, PayloadKind::of::<ReadResult>()),
            ep_spec!(EP_TP_CKOPENED, PayloadKind::Any),
            ep_spec!(EP_TP_STEP, PayloadKind::of::<Callback>()),
            ep_spec!(EP_TP_MOMENTS, PayloadKind::of::<MomentsMsg>()),
        ],
        sends: vec![
            send_spec!("TreePiece", EP_TP_PARTICLES, PayloadKind::of::<Chunk>()),
            send_spec!("TreePiece", EP_TP_SESSION, PayloadKind::of::<Session>()),
            send_spec!("TreePiece", EP_TP_MOMENTS, PayloadKind::of::<MomentsMsg>()),
        ],
    }
}

impl Chare for TreePiece {
    fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
        match msg.ep {
            EP_TP_GO => match self.cfg.scheme.clone() {
                InputScheme::Unopt => {
                    if self.my_bytes() == 0 {
                        let done = self.cfg.input_done.clone();
                        ctx.fire(done, Payload::new(0u64));
                        return;
                    }
                    let me = ctx.me();
                    ctx.open_file(Callback::to_chare(me, EP_TP_OPENED));
                }
                InputScheme::HandOpt => {
                    if self.is_reader(ctx) {
                        let (lo, hi) = self.reader_block(ctx);
                        if lo >= hi {
                            return;
                        }
                        let me = ctx.me();
                        let (off, len) = self.cfg.header.record_extent(lo, hi);
                        ctx.open_file(Callback::Ignore); // reader's own open
                        ctx.submit_read(
                            ReadRequest { file: self.cfg.file, offset: off, len, user: lo },
                            Callback::to_chare(me, EP_TP_RAW),
                        );
                    }
                    if self.my_bytes() == 0 {
                        let done = self.cfg.input_done.clone();
                        ctx.fire(done, Payload::new(0u64));
                    }
                }
                InputScheme::CkIo { io } => {
                    if self.index == 0 {
                        let me = ctx.me();
                        let opts = crate::ckio::FileOptions::default();
                        io.open(
                            ctx,
                            self.cfg.file,
                            self.cfg.header.file_bytes(),
                            opts,
                            Callback::to_chare(me, EP_TP_CKOPENED),
                        );
                    }
                }
            },
            EP_TP_OPENED => {
                let me = ctx.me();
                let (off, len) = self.my_extent();
                ctx.submit_read(
                    ReadRequest { file: self.cfg.file, offset: off, len, user: 0 },
                    Callback::to_chare(me, EP_TP_RAW),
                );
            }
            EP_TP_RAW => {
                let r: IoResult = msg.take();
                match self.cfg.scheme {
                    InputScheme::Unopt => self.particles_arrived(ctx, r.chunk),
                    InputScheme::HandOpt => {
                        // Reader: redistribute records to their owners.
                        let blk_lo = r.user;
                        let blk_hi = blk_lo + r.len / RECORD_BYTES;
                        ctx.metrics().count("changa.reader_blocks", 1);
                        for owner in self.owners_of(blk_lo, blk_hi) {
                            let n = self.cfg.header.nbodies;
                            let per = n.div_ceil(self.cfg.n_tp as u64);
                            let o_lo = (owner as u64 * per).max(blk_lo);
                            let o_hi = ((owner as u64 + 1) * per).min(n).min(blk_hi);
                            if o_lo >= o_hi {
                                continue;
                            }
                            let (off, len) = self.cfg.header.record_extent(o_lo, o_hi);
                            let piece = r.chunk.slice(off, len);
                            let wire = piece.len;
                            ctx.send_sized(
                                ChareRef::new(self.pieces, owner),
                                EP_TP_PARTICLES,
                                Payload::new(piece),
                                wire,
                                Transfer::Eager,
                            );
                        }
                    }
                    InputScheme::CkIo { .. } => unreachable!("raw read in ckio scheme"),
                }
            }
            EP_TP_PARTICLES => {
                let chunk: Chunk = msg.take();
                self.particles_arrived(ctx, chunk);
            }
            EP_TP_CKOPENED => {
                let io = match &self.cfg.scheme {
                    InputScheme::CkIo { io } => *io,
                    _ => unreachable!(),
                };
                let me = ctx.me();
                let h = &self.cfg.header;
                io.start_read_session(
                    ctx,
                    self.cfg.file,
                    HEADER_BYTES,
                    h.nbodies * RECORD_BYTES,
                    crate::ckio::SessionOptions::default(),
                    Callback::to_chare(me, EP_TP_SESSION),
                );
            }
            EP_TP_SESSION => {
                let s: Session = msg.take();
                if self.index == 0 && self.session.is_none() {
                    // Leader: forward the handle to every piece.
                    for j in 1..self.cfg.n_tp {
                        ctx.send(ChareRef::new(self.pieces, j), EP_TP_SESSION, s);
                    }
                }
                self.session = Some(s);
                if self.my_bytes() == 0 {
                    let done = self.cfg.input_done.clone();
                    ctx.fire(done, Payload::new(0u64));
                    return;
                }
                let io = match &self.cfg.scheme {
                    InputScheme::CkIo { io } => *io,
                    _ => unreachable!(),
                };
                let me = ctx.me();
                let (off, len) = self.my_extent();
                io.read(ctx, &s, off, len, Callback::to_chare(me, EP_TP_CKDATA));
            }
            EP_TP_CKDATA => {
                let r: ReadResult = msg.take();
                self.particles_arrived(ctx, r.chunk);
            }
            EP_TP_MOMENTS => {
                let m: MomentsMsg = msg.take();
                self.far.push((m.mass, m.com));
                self.moments_seen += 1;
            }
            EP_TP_STEP => {
                let done: Callback = msg.take();
                let gc = self.cfg.compute.clone().expect("compute phase needs artifacts");
                let st = self.state.as_mut().expect("step before input");
                let an = gc.step(st, &self.far, 1e-3).expect("gravity artifact");
                self.acc_log.push(an);
                ctx.fire(done, Payload::new(an));
            }
            other => panic!("TreePiece: unknown ep {other}"),
        }
    }

    fn pack_size(&self) -> u64 {
        // Migrating a piece carries its particles.
        256 + self.state.as_ref().map_or(self.my_bytes(), |s| s.n as u64 * 28)
    }

    impl_chare_any!();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(n_tp: u32, nbodies: u64) -> ChangaConfig {
        ChangaConfig {
            file: FileId(0),
            header: super::super::tipsy::default_header(nbodies),
            n_tp,
            scheme: InputScheme::Unopt,
            decode_ns_per_byte: 0.1,
            compute: None,
            input_done: Callback::Ignore,
        }
    }

    #[test]
    fn record_ranges_partition() {
        let c = cfg(7, 1000);
        let mut pos = 0;
        for i in 0..7 {
            let tp = TreePiece::new(c.clone(), i);
            assert_eq!(tp.rec_lo, pos);
            pos = tp.rec_hi;
        }
        assert_eq!(pos, 1000);
    }

    #[test]
    fn owners_math() {
        let c = cfg(10, 1000); // 100 records each
        let tp = TreePiece::new(c, 0);
        assert_eq!(tp.owners_of(0, 100), 0..=0);
        assert_eq!(tp.owners_of(50, 150), 0..=1);
        assert_eq!(tp.owners_of(950, 1000), 9..=9);
    }

    #[test]
    fn uneven_split_last_piece_short() {
        let c = cfg(3, 10); // per = 4: 4,4,2
        let t2 = TreePiece::new(c, 2);
        assert_eq!((t2.rec_lo, t2.rec_hi), (8, 10));
        assert_eq!(t2.my_bytes(), 2 * RECORD_BYTES);
    }
}
