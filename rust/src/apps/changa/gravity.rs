//! Compute phase: execute the AOT JAX/Pallas artifacts from TreePieces.
//!
//! Each TreePiece ingests its raw records through the `ingest_n*`
//! artifact (Pallas decode + permute + moments) and advances its
//! particles with the `gravity_n*` artifact (tiled all-pairs kernel).
//! Pieces interact through a monopole approximation: every piece sees the
//! other pieces' (total mass, center of mass), i.e. a one-level
//! Barnes-Hut. Python never runs here — only PJRT executables.

use std::rc::Rc;

use anyhow::{anyhow, Result};

use crate::runtime::{ArtifactRuntime, TensorF32};

use super::tipsy::{Header, FIELDS, RECORD_BYTES};

/// Shared handle to the compiled artifacts (wall-clock runs).
#[derive(Clone)]
pub struct GravityCompute {
    rt: Rc<ArtifactRuntime>,
    /// Available artifact slot sizes, ascending (e.g. [256, 4096]).
    slots: Vec<usize>,
}

/// Result of ingesting one TreePiece's records.
#[derive(Clone, Debug)]
pub struct Ingested {
    /// (n, 8) decoded physical fields, row-major; padded rows stripped.
    pub fields: Vec<f32>,
    pub n: usize,
    pub total_mass: f32,
    pub com: [f32; 3],
}

/// One TreePiece's dynamic state.
#[derive(Clone, Debug)]
pub struct PieceState {
    pub n: usize,
    pub pos: Vec<f32>,
    pub vel: Vec<f32>,
    pub mass: Vec<f32>,
}

impl GravityCompute {
    pub fn new(rt: Rc<ArtifactRuntime>) -> Result<GravityCompute> {
        let mut slots: Vec<usize> = rt
            .names()
            .iter()
            .filter_map(|n| n.strip_prefix("gravity_n").and_then(|s| s.parse().ok()))
            .collect();
        slots.sort_unstable();
        if slots.is_empty() {
            return Err(anyhow!("no gravity_n* artifacts loaded"));
        }
        Ok(GravityCompute { rt, slots })
    }

    fn slot_for(&self, n: usize) -> Result<usize> {
        self.slots
            .iter()
            .copied()
            .find(|&s| s >= n)
            .ok_or_else(|| anyhow!("no artifact slot fits n={n} (have {:?})", self.slots))
    }

    /// Decode raw Tipsy record bytes through the ingest artifact.
    /// `order` optionally reorders rows (TreePiece-local permutation);
    /// identity if `None`.
    pub fn ingest(&self, h: &Header, bytes: &[u8], order: Option<&[u32]>) -> Result<Ingested> {
        let n = bytes.len() / RECORD_BYTES as usize;
        assert_eq!(bytes.len() as u64 % RECORD_BYTES, 0, "partial record");
        let slot = self.slot_for(n)?;
        // Unpack i32 raw values into the f32 tensor the artifact takes.
        let mut raw = vec![0f32; slot * FIELDS];
        for r in 0..n {
            for f in 0..FIELDS {
                let o = r * RECORD_BYTES as usize + 4 * f;
                raw[r * FIELDS + f] =
                    i32::from_le_bytes(bytes[o..o + 4].try_into().unwrap()) as f32;
            }
        }
        let mut idx = vec![0f32; slot];
        for i in 0..slot {
            idx[i] = if i < n {
                match order {
                    Some(ord) => ord[i] as f32,
                    None => i as f32,
                }
            } else {
                // Padded output rows gather a padded (all-zero) source
                // row so they decode to zero mass and stay inert in the
                // moments computation.
                n as f32
            };
        }
        // Padded rows decode to offset[f] — force mass scale*0+0: ensure
        // pad rows have zero mass by zeroing their raw mass field (they
        // already are zero) AND a zero mass offset; assert that here.
        assert_eq!(h.offset[0], 0.0, "mass offset must be 0 so pad rows are massless");
        let outs = self.rt.execute(
            &format!("ingest_n{slot}"),
            &[
                TensorF32::new(vec![slot as i64, FIELDS as i64], raw),
                TensorF32::new(vec![slot as i64], idx),
                TensorF32::new(vec![FIELDS as i64], h.scale.to_vec()),
                TensorF32::new(vec![FIELDS as i64], h.offset.to_vec()),
            ],
        )?;
        let fields_full = &outs[0];
        let fields = fields_full.data[..n * FIELDS].to_vec();
        let total_mass = outs[1].data[0];
        let com = [outs[2].data[0], outs[2].data[1], outs[2].data[2]];
        Ok(Ingested { fields, n, total_mass, com })
    }

    /// One leapfrog step for a piece, with a far-field monopole kick from
    /// the other pieces. Returns the piece's |acc| sum (diagnostic).
    pub fn step(
        &self,
        st: &mut PieceState,
        far: &[(f32, [f32; 3])],
        dt: f32,
    ) -> Result<f32> {
        let n = st.n;
        let slot = self.slot_for(n)?;
        let pad = slot - n;
        let mut pos = st.pos.clone();
        let mut vel = st.vel.clone();
        let mut mass = st.mass.clone();
        // Far away with zero mass: inert.
        pos.extend(std::iter::repeat_n(1e6, pad * 3));
        vel.extend(std::iter::repeat_n(0.0, pad * 3));
        mass.extend(std::iter::repeat_n(0.0, pad));
        let outs = self.rt.execute(
            &format!("gravity_n{slot}"),
            &[
                TensorF32::new(vec![slot as i64, 3], pos),
                TensorF32::new(vec![slot as i64, 3], vel),
                TensorF32::new(vec![slot as i64], mass),
                TensorF32::scalar(dt),
            ],
        )?;
        let (pos2, vel2, _acc, acc_norm) = (&outs[0], &outs[1], &outs[2], &outs[3]);
        st.pos.copy_from_slice(&pos2.data[..n * 3]);
        st.vel.copy_from_slice(&vel2.data[..n * 3]);
        // Monopole far-field kick (Rust-side: O(n * pieces), negligible).
        const EPS2: f32 = 1e-4;
        for i in 0..n {
            let mut a = [0f32; 3];
            for &(m, c) in far {
                let dx = [c[0] - st.pos[3 * i], c[1] - st.pos[3 * i + 1], c[2] - st.pos[3 * i + 2]];
                let r2 = dx[0] * dx[0] + dx[1] * dx[1] + dx[2] * dx[2] + EPS2;
                let w = m / (r2 * r2.sqrt());
                a[0] += w * dx[0];
                a[1] += w * dx[1];
                a[2] += w * dx[2];
            }
            for k in 0..3 {
                st.vel[3 * i + k] += dt * a[k];
                st.pos[3 * i + k] += dt * dt * a[k]; // consistent drift update
            }
        }
        Ok(acc_norm.data[0])
    }
}

impl Ingested {
    /// Split decoded fields into dynamic state.
    pub fn into_state(self) -> PieceState {
        let n = self.n;
        let mut pos = vec![0f32; n * 3];
        let mut vel = vec![0f32; n * 3];
        let mut mass = vec![0f32; n];
        for i in 0..n {
            mass[i] = self.fields[i * FIELDS];
            for k in 0..3 {
                pos[i * 3 + k] = self.fields[i * FIELDS + 1 + k];
                vel[i * 3 + k] = self.fields[i * FIELDS + 4 + k];
            }
        }
        PieceState { n, pos, vel, mass }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::changa::tipsy;

    fn compute() -> Option<GravityCompute> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("gravity_n256.hlo.txt").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        let mut rt = ArtifactRuntime::cpu().unwrap();
        rt.load_dir(&dir).unwrap();
        Some(GravityCompute::new(Rc::new(rt)).unwrap())
    }

    #[test]
    fn ingest_decodes_real_records() {
        let Some(gc) = compute() else { return };
        let h = tipsy::default_header(100);
        let ps = tipsy::generate(100, 11);
        let bytes = tipsy::write_bytes(&h, &ps);
        let body = &bytes[tipsy::HEADER_BYTES as usize..];
        let ing = gc.ingest(&h, body, None).unwrap();
        assert_eq!(ing.n, 100);
        // Compare a few decoded fields against the Rust-side decode.
        for r in [0usize, 57, 99] {
            let (_, phys) = tipsy::decode_record(&h, &body[r * 32..]);
            for f in 0..FIELDS {
                assert!(
                    (ing.fields[r * FIELDS + f] - phys[f]).abs() < 1e-5,
                    "rec {r} field {f}"
                );
            }
        }
        // Total mass ≈ 1 (unit-mass system).
        assert!((ing.total_mass - 1.0).abs() < 1e-2, "total={}", ing.total_mass);
    }

    #[test]
    fn step_advances_and_is_finite() {
        let Some(gc) = compute() else { return };
        let h = tipsy::default_header(200);
        let ps = tipsy::generate(200, 13);
        let bytes = tipsy::write_bytes(&h, &ps);
        let ing = gc.ingest(&h, &bytes[tipsy::HEADER_BYTES as usize..], None).unwrap();
        let mut st = ing.into_state();
        let p0 = st.pos.clone();
        let far = vec![(0.5f32, [3.0, 0.0, 0.0])];
        let mut norms = Vec::new();
        for _ in 0..3 {
            let an = gc.step(&mut st, &far, 1e-3).unwrap();
            assert!(an.is_finite() && an > 0.0);
            norms.push(an);
        }
        assert_ne!(st.pos, p0, "particles moved");
        assert!(st.pos.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn padding_is_inert() {
        let Some(gc) = compute() else { return };
        // 3 particles in a 256 slot: results match a direct computation.
        let h = tipsy::default_header(3);
        let ps = tipsy::generate(3, 17);
        let bytes = tipsy::write_bytes(&h, &ps);
        let ing = gc.ingest(&h, &bytes[tipsy::HEADER_BYTES as usize..], None).unwrap();
        let mut st = ing.into_state();
        let mass_before: f32 = st.mass.iter().sum();
        gc.step(&mut st, &[], 1e-3).unwrap();
        let mass_after: f32 = st.mass.iter().sum();
        assert_eq!(mass_before, mass_after);
        assert_eq!(st.n, 3);
    }
}
