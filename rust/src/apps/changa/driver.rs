//! Drivers that assemble a mini-ChaNGa run (used by the Fig. 13 bench,
//! the end-to-end example, and tests).

use crate::amt::callback::Callback;
use crate::amt::chare::ChareRef;
use crate::amt::engine::{Engine, EngineConfig};
use crate::amt::time::Time;
use crate::amt::topology::Placement;
use crate::ckio::CkIo;
use crate::pfs::PfsConfig;

use super::gravity::GravityCompute;
use super::tipsy;
use super::treepiece::{ChangaConfig, InputScheme, TreePiece, EP_TP_GO};

/// Which input scheme to benchmark.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Scheme {
    Unopt,
    HandOpt,
    CkIo,
}

impl Scheme {
    pub fn label(&self) -> &'static str {
        match self {
            Scheme::Unopt => "unopt",
            Scheme::HandOpt => "hand-opt",
            Scheme::CkIo => "ckio",
        }
    }
}

/// Result of one input-phase run.
pub struct ChangaRun {
    /// Virtual time at which the last TreePiece finished input.
    pub input_time: Time,
    pub total_bytes: u64,
    pub engine: Engine,
}

/// Run the mini-ChaNGa *input phase* on the simulated cluster.
///
/// Mirrors Fig. 13's setup: `n_tp` TreePieces collectively reading an
/// `nbodies`-record Tipsy file under the given scheme.
pub fn run_changa_input(
    nodes: u32,
    pes_per_node: u32,
    n_tp: u32,
    nbodies: u64,
    scheme: Scheme,
    seed: u64,
) -> ChangaRun {
    let mut eng = Engine::new(EngineConfig::sim(nodes, pes_per_node).with_seed(seed))
        .with_sim_pfs(PfsConfig::default());
    let header = tipsy::default_header(nbodies);
    let file = eng.core.sim_pfs_mut().create_file(header.file_bytes());
    let io = CkIo::boot(&mut eng);
    let fut = eng.future(n_tp);

    let cfg = ChangaConfig {
        file,
        header,
        n_tp,
        scheme: match scheme {
            Scheme::Unopt => InputScheme::Unopt,
            Scheme::HandOpt => InputScheme::HandOpt,
            Scheme::CkIo => InputScheme::CkIo { io },
        },
        decode_ns_per_byte: 0.15,
        compute: None,
        input_done: Callback::Future(fut),
    };
    let pieces =
        eng.create_array(n_tp, &Placement::RoundRobinPes, |i| TreePiece::new(cfg.clone(), i));
    for i in 0..n_tp {
        eng.chare_mut::<TreePiece>(ChareRef::new(pieces, i)).pieces = pieces;
    }
    // Kick the input phase everywhere.
    for i in 0..n_tp {
        eng.inject_signal(ChareRef::new(pieces, i), EP_TP_GO);
    }
    eng.run();
    assert!(eng.future_done(fut), "{:?}: input phase incomplete", scheme);
    let arrivals = eng.take_future(fut);
    let input_time = arrivals.iter().map(|(t, _)| *t).max().unwrap();
    let total_bytes = arrivals
        .into_iter()
        .map(|(_, mut p)| p.take::<u64>())
        .sum();
    ChangaRun { input_time, total_bytes, engine: eng }
}

/// Wall-clock end-to-end run against a real Tipsy file (used by
/// `examples/changa_e2e.rs` and integration tests): input via the chosen
/// scheme + `steps` gravity steps through the PJRT artifacts.
pub struct E2eReport {
    pub input_secs: f64,
    pub nbodies: u64,
    pub n_tp: u32,
    pub acc_norms: Vec<f32>,
    pub step_secs: Vec<f64>,
}

pub fn run_changa_e2e(
    path: &std::path::Path,
    n_tp: u32,
    scheme: Scheme,
    steps: u32,
    reader_threads: usize,
    artifact_dir: &std::path::Path,
) -> anyhow::Result<E2eReport> {
    use crate::runtime::ArtifactRuntime;
    use std::rc::Rc;

    // Parse the real header first.
    let mut head = vec![0u8; tipsy::HEADER_BYTES as usize];
    {
        use std::io::Read;
        let mut f = std::fs::File::open(path)?;
        f.read_exact(&mut head)?;
    }
    let header = tipsy::Header::from_bytes(&head).map_err(|e| anyhow::anyhow!(e))?;

    let mut rt = ArtifactRuntime::cpu()?;
    rt.load_dir(artifact_dir)?;
    let compute = GravityCompute::new(Rc::new(rt))?;

    let mut eng = Engine::new(EngineConfig::real(1, 4)).with_local_disk(reader_threads);
    let file = eng.core.local_disk_mut().register_file(path);
    let io = CkIo::boot(&mut eng);
    let fut = eng.future(n_tp);

    let cfg = ChangaConfig {
        file,
        header: header.clone(),
        n_tp,
        scheme: match scheme {
            Scheme::Unopt => InputScheme::Unopt,
            Scheme::HandOpt => InputScheme::HandOpt,
            Scheme::CkIo => InputScheme::CkIo { io },
        },
        decode_ns_per_byte: 0.0,
        compute: Some(compute),
        input_done: Callback::Future(fut),
    };
    let pieces =
        eng.create_array(n_tp, &Placement::RoundRobinPes, |i| TreePiece::new(cfg.clone(), i));
    for i in 0..n_tp {
        eng.chare_mut::<TreePiece>(ChareRef::new(pieces, i)).pieces = pieces;
    }
    let t0 = std::time::Instant::now();
    for i in 0..n_tp {
        eng.inject_signal(ChareRef::new(pieces, i), EP_TP_GO);
    }
    eng.run();
    anyhow::ensure!(eng.future_done(fut), "input phase incomplete");
    let input_secs = t0.elapsed().as_secs_f64();
    eng.take_future(fut);

    // Compute phase: `steps` synchronized gravity steps.
    let mut acc_norms = Vec::new();
    let mut step_secs = Vec::new();
    for _ in 0..steps {
        let sfut = eng.future(n_tp);
        let t = std::time::Instant::now();
        for i in 0..n_tp {
            eng.inject(
                ChareRef::new(pieces, i),
                super::treepiece::EP_TP_STEP,
                Callback::Future(sfut),
            );
        }
        eng.run();
        anyhow::ensure!(eng.future_done(sfut), "step incomplete");
        step_secs.push(t.elapsed().as_secs_f64());
        let total: f32 = eng
            .take_future(sfut)
            .into_iter()
            .map(|(_, mut p)| p.take::<f32>())
            .sum();
        acc_norms.push(total);
    }
    Ok(E2eReport { input_secs, nbodies: header.nbodies, n_tp, acc_norms, step_secs })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_three_schemes_complete_and_agree_on_bytes() {
        let nbodies = 64 << 10; // 2 MiB of records
        for scheme in [Scheme::Unopt, Scheme::HandOpt, Scheme::CkIo] {
            let run = run_changa_input(2, 4, 64, nbodies, scheme, 1);
            assert_eq!(
                run.total_bytes,
                nbodies * tipsy::RECORD_BYTES,
                "{scheme:?} delivered wrong byte count"
            );
            assert!(run.input_time > 0);
        }
    }

    #[test]
    fn overdecomposed_unopt_slower_than_ckio() {
        // The headline: with heavy over-decomposition, per-TreePiece
        // direct input collapses while CkIO stays near optimal.
        let nbodies = 2 << 20; // 64 MiB of records
        let unopt = run_changa_input(4, 8, 2048, nbodies, Scheme::Unopt, 1);
        let ckio = run_changa_input(4, 8, 2048, nbodies, Scheme::CkIo, 1);
        assert!(
            unopt.input_time > ckio.input_time,
            "unopt {} should exceed ckio {}",
            unopt.input_time,
            ckio.input_time
        );
    }
}
