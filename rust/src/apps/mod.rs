//! Applications built on the runtime.

pub mod changa;
