//! Artifact runtime: load AOT-lowered HLO *text* artifacts and execute
//! them from the coordinator's hot path.
//!
//! The JAX/Pallas model (Layer 2/1, `python/compile/`) is lowered **once**
//! at build time to HLO text (`artifacts/*.hlo.txt`). The offline build
//! environment has no PJRT / `xla_extension` shared library, so this
//! module executes artifacts with a small built-in HLO-text interpreter:
//! it supports the structural subset needed by the bundled hand-written
//! artifacts and the tests (parameters, elementwise arithmetic, tuples)
//! and returns a clear error for anything richer. The public surface
//! (`ArtifactRuntime::{cpu, load, load_dir, execute}`, [`TensorF32`]) is
//! the PJRT-shaped API, so a real PJRT client can be swapped back in
//! behind the same calls when the toolchain provides one — Python is
//! never on the request path either way.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

/// Where `make artifacts` puts the lowered models.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// A typed f32 tensor for artifact I/O.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorF32 {
    pub dims: Vec<i64>,
    pub data: Vec<f32>,
}

impl TensorF32 {
    pub fn new(dims: Vec<i64>, data: Vec<f32>) -> TensorF32 {
        let n: i64 = dims.iter().product();
        assert_eq!(n as usize, data.len(), "dims/data mismatch");
        TensorF32 { dims, data }
    }

    pub fn scalar(v: f32) -> TensorF32 {
        TensorF32 { dims: vec![], data: vec![v] }
    }
}

/// One parsed HLO instruction (the interpreter's IR).
#[derive(Debug)]
struct Instr {
    name: String,
    op: String,
    args: Vec<String>,
    /// Result dims (empty = scalar); unused for `tuple`.
    dims: Vec<i64>,
    root: bool,
}

/// A parsed ENTRY computation.
#[derive(Debug)]
struct HloProgram {
    instrs: Vec<Instr>,
}

/// A loaded artifact registry keyed by artifact name
/// (`gravity_n256` → `artifacts/gravity_n256.hlo.txt`).
pub struct ArtifactRuntime {
    exes: HashMap<String, HloProgram>,
}

impl ArtifactRuntime {
    /// Create the (interpreter-backed) CPU runtime.
    pub fn cpu() -> Result<ArtifactRuntime> {
        Ok(ArtifactRuntime { exes: HashMap::new() })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        "cpu (built-in HLO interpreter)".to_string()
    }

    /// Load and parse one HLO-text artifact under `name`.
    pub fn load(&mut self, name: &str, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading HLO text {}", path.display()))?;
        let prog = parse_hlo(&text)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        self.exes.insert(name.to_string(), prog);
        Ok(())
    }

    /// Load every `*.hlo.txt` in a directory; returns the loaded names.
    pub fn load_dir(&mut self, dir: impl AsRef<Path>) -> Result<Vec<String>> {
        let mut names = Vec::new();
        let rd = std::fs::read_dir(dir.as_ref())
            .with_context(|| format!("artifact dir {}", dir.as_ref().display()))?;
        let mut paths: Vec<PathBuf> = rd
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| {
                p.file_name().and_then(|n| n.to_str()).is_some_and(|n| n.ends_with(".hlo.txt"))
            })
            .collect();
        paths.sort();
        for p in paths {
            let name = p
                .file_name()
                .unwrap()
                .to_str()
                .unwrap()
                .trim_end_matches(".hlo.txt")
                .to_string();
            self.load(&name, &p)?;
            names.push(name);
        }
        Ok(names)
    }

    pub fn has(&self, name: &str) -> bool {
        self.exes.contains_key(name)
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.exes.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    /// Execute an artifact on f32 inputs; returns the tuple of f32
    /// outputs (artifacts are lowered with `return_tuple=True`).
    pub fn execute(&self, name: &str, inputs: &[TensorF32]) -> Result<Vec<TensorF32>> {
        let prog = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not loaded (have: {:?})", self.names()))?;
        let mut env: HashMap<&str, TensorF32> = HashMap::new();
        let mut outputs: Option<Vec<TensorF32>> = None;
        for instr in &prog.instrs {
            match instr.op.as_str() {
                "parameter" => {
                    let idx: usize = instr
                        .args
                        .first()
                        .and_then(|a| a.parse().ok())
                        .ok_or_else(|| anyhow!("bad parameter index in {name:?}"))?;
                    let t = inputs
                        .get(idx)
                        .cloned()
                        .ok_or_else(|| {
                            anyhow!(
                                "artifact {name:?} wants parameter {idx}, got {} inputs",
                                inputs.len()
                            )
                        })?;
                    env.insert(&instr.name, t);
                }
                "tuple" => {
                    let mut outs = Vec::with_capacity(instr.args.len());
                    for a in &instr.args {
                        outs.push(lookup(&env, a, name)?.clone());
                    }
                    if instr.root {
                        outputs = Some(outs);
                    }
                }
                "add" | "subtract" | "multiply" | "divide" | "maximum" | "minimum" => {
                    let a =
                        lookup(&env, instr.args.first().map(|s| s.as_str()).unwrap_or(""), name)?;
                    let b =
                        lookup(&env, instr.args.get(1).map(|s| s.as_str()).unwrap_or(""), name)?;
                    if a.data.len() != b.data.len() {
                        return Err(anyhow!(
                            "shape mismatch in {name:?}: {} vs {} elements for {}",
                            a.data.len(),
                            b.data.len(),
                            instr.name
                        ));
                    }
                    let f: fn(f32, f32) -> f32 = match instr.op.as_str() {
                        "add" => |x, y| x + y,
                        "subtract" => |x, y| x - y,
                        "multiply" => |x, y| x * y,
                        "divide" => |x, y| x / y,
                        "maximum" => f32::max,
                        _ => f32::min,
                    };
                    let data: Vec<f32> =
                        a.data.iter().zip(b.data.iter()).map(|(&x, &y)| f(x, y)).collect();
                    let t = TensorF32 { dims: instr.dims.clone(), data };
                    if instr.root {
                        outputs = Some(vec![t.clone()]);
                    }
                    env.insert(&instr.name, t);
                }
                "negate" | "exponential" | "copy" => {
                    let a =
                        lookup(&env, instr.args.first().map(|s| s.as_str()).unwrap_or(""), name)?;
                    let f: fn(f32) -> f32 = match instr.op.as_str() {
                        "negate" => |x| -x,
                        "exponential" => f32::exp,
                        _ => |x| x,
                    };
                    let data: Vec<f32> = a.data.iter().map(|&x| f(x)).collect();
                    let t = TensorF32 { dims: instr.dims.clone(), data };
                    if instr.root {
                        outputs = Some(vec![t.clone()]);
                    }
                    env.insert(&instr.name, t);
                }
                other => {
                    return Err(anyhow!(
                        "unsupported HLO op {other:?} in artifact {name:?} — the offline \
                         interpreter covers the elementwise subset only; run under a real \
                         PJRT client for full artifacts"
                    ));
                }
            }
        }
        outputs.ok_or_else(|| anyhow!("artifact {name:?} has no ROOT instruction"))
    }
}

fn lookup<'e>(
    env: &'e HashMap<&str, TensorF32>,
    name: &str,
    artifact: &str,
) -> Result<&'e TensorF32> {
    env.get(name)
        .ok_or_else(|| anyhow!("artifact {artifact:?}: operand {name:?} not defined yet"))
}

/// Parse the ENTRY computation of an HLO-text module.
fn parse_hlo(text: &str) -> Result<HloProgram> {
    let mut instrs = Vec::new();
    let mut in_entry = false;
    for raw in text.lines() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with("//") {
            continue;
        }
        if !in_entry {
            if line.starts_with("ENTRY") {
                in_entry = true;
            }
            continue;
        }
        if line.starts_with('}') {
            break;
        }
        instrs.push(parse_instr(line)?);
    }
    if instrs.is_empty() {
        return Err(anyhow!("no ENTRY computation found"));
    }
    if !instrs.iter().any(|i| i.root) {
        return Err(anyhow!("ENTRY computation has no ROOT instruction"));
    }
    Ok(HloProgram { instrs })
}

/// Parse one instruction line:
/// `[ROOT] name = shape op(arg, arg, ...)[, attr=...]`.
fn parse_instr(line: &str) -> Result<Instr> {
    let (root, line) = match line.strip_prefix("ROOT ") {
        Some(rest) => (true, rest),
        None => (false, line),
    };
    let (name, rhs) = line
        .split_once('=')
        .ok_or_else(|| anyhow!("instruction without `=`: {line:?}"))?;
    let name = name.trim().trim_start_matches('%').to_string();
    let rhs = rhs.trim();
    // Shape comes first: either a single token (`f32[4]{0}`) or a
    // parenthesized tuple shape, which may contain spaces
    // (`(f32[4]{0}, f32[4]{0})` — the return_tuple=True form every
    // lowered artifact uses). The op call follows.
    let (shape_tok, rest) = if rhs.starts_with('(') {
        let close = rhs
            .find(')')
            .ok_or_else(|| anyhow!("unterminated tuple shape: {line:?}"))?;
        (&rhs[..=close], rhs[close + 1..].trim_start())
    } else {
        rhs.split_once(char::is_whitespace)
            .ok_or_else(|| anyhow!("instruction without op: {line:?}"))?
    };
    let rest = rest.trim();
    let open = rest
        .find('(')
        .ok_or_else(|| anyhow!("op without argument list: {line:?}"))?;
    let op = rest[..open].trim().to_string();
    let close = rest[open..]
        .find(')')
        .map(|i| open + i)
        .ok_or_else(|| anyhow!("unterminated argument list: {line:?}"))?;
    let args = split_operands(&rest[open + 1..close]);
    let dims = parse_dims(shape_tok);
    Ok(Instr { name, op, args, dims, root })
}

/// Split an operand list on commas at bracket depth 0 only — typed
/// operands like `f32[128,3]{1,0} %x` (the standard XLA dump form)
/// carry commas inside their shape annotations. Each operand keeps its
/// last whitespace-separated token, minus any `%` sigil.
fn split_operands(body: &str) -> Vec<String> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut cur = String::new();
    for c in body.chars() {
        match c {
            '[' | '{' => {
                depth += 1;
                cur.push(c);
            }
            ']' | '}' => {
                depth = depth.saturating_sub(1);
                cur.push(c);
            }
            ',' if depth == 0 => parts.push(std::mem::take(&mut cur)),
            c => cur.push(c),
        }
    }
    parts.push(cur);
    parts
        .into_iter()
        .map(|a| {
            a.trim()
                .rsplit(char::is_whitespace)
                .next()
                .unwrap_or("")
                .trim_start_matches('%')
                .to_string()
        })
        .filter(|a| !a.is_empty())
        .collect()
}

/// `f32[4]{0}` → `[4]`; `f32[]` / `f32[]{}`→ `[]` (scalar); tuple shapes
/// (parenthesized) → `[]` (dims are taken from the operands).
fn parse_dims(shape: &str) -> Vec<i64> {
    let Some(lo) = shape.find('[') else { return Vec::new() };
    let Some(hi) = shape[lo..].find(']').map(|i| lo + i) else { return Vec::new() };
    shape[lo + 1..hi]
        .split(',')
        .filter_map(|d| d.trim().parse().ok())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A tiny hand-written HLO module: f(x, y) = (x + y,), f32[4].
    const ADD_HLO: &str = r#"
HloModule add4, entry_computation_layout={(f32[4]{0}, f32[4]{0})->(f32[4]{0})}

ENTRY main {
  x = f32[4]{0} parameter(0)
  y = f32[4]{0} parameter(1)
  s = f32[4]{0} add(x, y)
  ROOT t = (f32[4]{0}) tuple(s)
}
"#;

    fn write_artifact(name: &str, text: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("ckio_runtime_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        std::fs::write(&p, text).unwrap();
        p
    }

    #[test]
    fn load_and_execute_handwritten_hlo() {
        let p = write_artifact("add4.hlo.txt", ADD_HLO);
        let mut rt = ArtifactRuntime::cpu().unwrap();
        rt.load("add4", &p).unwrap();
        assert!(rt.has("add4"));
        let x = TensorF32::new(vec![4], vec![1.0, 2.0, 3.0, 4.0]);
        let y = TensorF32::new(vec![4], vec![10.0, 20.0, 30.0, 40.0]);
        let out = rt.execute("add4", &[x, y]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].data, vec![11.0, 22.0, 33.0, 44.0]);
        assert_eq!(out[0].dims, vec![4]);
    }

    #[test]
    fn missing_artifact_is_an_error() {
        let rt = ArtifactRuntime::cpu().unwrap();
        let err = rt.execute("nope", &[]).unwrap_err();
        assert!(err.to_string().contains("not loaded"));
    }

    #[test]
    fn load_dir_scans_artifacts() {
        let dir = std::env::temp_dir().join("ckio_runtime_dir_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("a.hlo.txt"), ADD_HLO).unwrap();
        std::fs::write(dir.join("ignored.txt"), "not hlo").unwrap();
        let mut rt = ArtifactRuntime::cpu().unwrap();
        let names = rt.load_dir(&dir).unwrap();
        assert_eq!(names, vec!["a"]);
    }

    #[test]
    #[should_panic(expected = "dims/data mismatch")]
    fn tensor_shape_checked() {
        TensorF32::new(vec![2, 2], vec![1.0]);
    }

    /// Multi-output modules — `(shape, shape) tuple(a, b)` with a space
    /// inside the tuple shape — are the `return_tuple=True` form every
    /// real lowered artifact uses (regression: the shape token used to
    /// be split at the first whitespace).
    #[test]
    fn multi_output_tuple_shapes_parse_and_execute() {
        const MULTI_HLO: &str = "ENTRY main {\n  x = f32[2]{0} parameter(0)\n  y = f32[2]{0} parameter(1)\n  s = f32[2]{0} add(x, y)\n  d = f32[2]{0} subtract(x, y)\n  ROOT t = (f32[2]{0}, f32[2]{0}) tuple(s, d)\n}\n";
        let p = write_artifact("multi.hlo.txt", MULTI_HLO);
        let mut rt = ArtifactRuntime::cpu().unwrap();
        rt.load("multi", &p).unwrap();
        let x = TensorF32::new(vec![2], vec![5.0, 7.0]);
        let y = TensorF32::new(vec![2], vec![1.0, 2.0]);
        let out = rt.execute("multi", &[x, y]).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].data, vec![6.0, 9.0]);
        assert_eq!(out[1].data, vec![4.0, 5.0]);
    }

    #[test]
    fn unsupported_ops_are_reported_not_miscomputed() {
        const DOT_HLO: &str = "ENTRY main {\n  x = f32[2]{0} parameter(0)\n  ROOT d = f32[] dot(x, x)\n}\n";
        let p = write_artifact("dot.hlo.txt", DOT_HLO);
        let mut rt = ArtifactRuntime::cpu().unwrap();
        rt.load("dot", &p).unwrap();
        let err = rt.execute("dot", &[TensorF32::new(vec![2], vec![1.0, 2.0])]).unwrap_err();
        assert!(err.to_string().contains("unsupported HLO op"));
    }

    /// Typed operands with multi-dimensional shapes (`f32[4,3]{1,0} %x`)
    /// carry commas inside the annotation; the operand splitter must not
    /// break on those (regression: a naive split(',') produced garbage
    /// operand names for exactly the [N,3] shapes the gravity artifacts
    /// use).
    #[test]
    fn typed_multidim_operands_parse() {
        const TYPED_HLO: &str = "ENTRY main {\n  x = f32[4,3]{1,0} parameter(0)\n  y = f32[4,3]{1,0} parameter(1)\n  ROOT s = f32[4,3]{1,0} add(f32[4,3]{1,0} %x, f32[4,3]{1,0} %y)\n}\n";
        let p = write_artifact("typed.hlo.txt", TYPED_HLO);
        let mut rt = ArtifactRuntime::cpu().unwrap();
        rt.load("typed", &p).unwrap();
        let x = TensorF32::new(vec![4, 3], (0..12).map(|i| i as f32).collect());
        let y = TensorF32::new(vec![4, 3], vec![1.0; 12]);
        let out = rt.execute("typed", &[x, y]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].dims, vec![4, 3]);
        assert_eq!(out[0].data[5], 6.0);
    }

    #[test]
    fn scalar_and_unary_ops() {
        const NEG_HLO: &str = "ENTRY main {\n  x = f32[] parameter(0)\n  ROOT n = f32[] negate(x)\n}\n";
        let p = write_artifact("neg.hlo.txt", NEG_HLO);
        let mut rt = ArtifactRuntime::cpu().unwrap();
        rt.load("neg", &p).unwrap();
        let out = rt.execute("neg", &[TensorF32::scalar(2.5)]).unwrap();
        assert_eq!(out[0].data, vec![-2.5]);
        assert!(out[0].dims.is_empty());
    }
}
