//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from
//! the coordinator's hot path.
//!
//! The JAX/Pallas model (Layer 2/1, `python/compile/`) is lowered **once**
//! at build time to HLO *text* (`artifacts/*.hlo.txt`; text rather than a
//! serialized `HloModuleProto` because jax ≥ 0.5 emits 64-bit instruction
//! ids the bundled xla_extension 0.5.1 rejects — the text parser
//! reassigns ids). This module loads those artifacts, compiles them on
//! the PJRT CPU client, and exposes typed `f32` execution. Python is
//! never on the request path.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

/// Where `make artifacts` puts the lowered models.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// A loaded, compiled artifact registry keyed by artifact name
/// (`gravity_4096` → `artifacts/gravity_4096.hlo.txt`).
pub struct ArtifactRuntime {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

/// A typed f32 tensor for artifact I/O.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorF32 {
    pub dims: Vec<i64>,
    pub data: Vec<f32>,
}

impl TensorF32 {
    pub fn new(dims: Vec<i64>, data: Vec<f32>) -> TensorF32 {
        let n: i64 = dims.iter().product();
        assert_eq!(n as usize, data.len(), "dims/data mismatch");
        TensorF32 { dims, data }
    }

    pub fn scalar(v: f32) -> TensorF32 {
        TensorF32 { dims: vec![], data: vec![v] }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        if self.dims.is_empty() {
            Ok(xla::Literal::scalar(self.data[0]))
        } else {
            Ok(lit.reshape(&self.dims)?)
        }
    }
}

impl ArtifactRuntime {
    /// Create a PJRT CPU client.
    pub fn cpu() -> Result<ArtifactRuntime> {
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(ArtifactRuntime { client, exes: HashMap::new() })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile one HLO-text artifact under `name`.
    pub fn load(&mut self, name: &str, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// Load every `*.hlo.txt` in a directory; returns the loaded names.
    pub fn load_dir(&mut self, dir: impl AsRef<Path>) -> Result<Vec<String>> {
        let mut names = Vec::new();
        let rd = std::fs::read_dir(dir.as_ref())
            .with_context(|| format!("artifact dir {}", dir.as_ref().display()))?;
        let mut paths: Vec<PathBuf> = rd
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.file_name().and_then(|n| n.to_str()).is_some_and(|n| n.ends_with(".hlo.txt")))
            .collect();
        paths.sort();
        for p in paths {
            let name = p
                .file_name()
                .unwrap()
                .to_str()
                .unwrap()
                .trim_end_matches(".hlo.txt")
                .to_string();
            self.load(&name, &p)?;
            names.push(name);
        }
        Ok(names)
    }

    pub fn has(&self, name: &str) -> bool {
        self.exes.contains_key(name)
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.exes.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    /// Execute an artifact on f32 inputs; returns the tuple of f32
    /// outputs (artifacts are lowered with `return_tuple=True`).
    pub fn execute(&self, name: &str, inputs: &[TensorF32]) -> Result<Vec<TensorF32>> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not loaded (have: {:?})", self.names()))?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        outs.into_iter()
            .map(|lit| {
                let shape = lit.array_shape()?;
                let dims: Vec<i64> = shape.dims().to_vec();
                let data = lit.to_vec::<f32>()?;
                Ok(TensorF32 { dims, data })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A tiny hand-written HLO module: f(x, y) = (x + y,), f32[4].
    const ADD_HLO: &str = r#"
HloModule add4, entry_computation_layout={(f32[4]{0}, f32[4]{0})->(f32[4]{0})}

ENTRY main {
  x = f32[4]{0} parameter(0)
  y = f32[4]{0} parameter(1)
  s = f32[4]{0} add(x, y)
  ROOT t = (f32[4]{0}) tuple(s)
}
"#;

    fn write_artifact(name: &str, text: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("ckio_runtime_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        std::fs::write(&p, text).unwrap();
        p
    }

    #[test]
    fn load_and_execute_handwritten_hlo() {
        let p = write_artifact("add4.hlo.txt", ADD_HLO);
        let mut rt = ArtifactRuntime::cpu().unwrap();
        rt.load("add4", &p).unwrap();
        assert!(rt.has("add4"));
        let x = TensorF32::new(vec![4], vec![1.0, 2.0, 3.0, 4.0]);
        let y = TensorF32::new(vec![4], vec![10.0, 20.0, 30.0, 40.0]);
        let out = rt.execute("add4", &[x, y]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].data, vec![11.0, 22.0, 33.0, 44.0]);
        assert_eq!(out[0].dims, vec![4]);
    }

    #[test]
    fn missing_artifact_is_an_error() {
        let rt = ArtifactRuntime::cpu().unwrap();
        let err = rt.execute("nope", &[]).unwrap_err();
        assert!(err.to_string().contains("not loaded"));
    }

    #[test]
    fn load_dir_scans_artifacts() {
        let dir = std::env::temp_dir().join("ckio_runtime_dir_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("a.hlo.txt"), ADD_HLO).unwrap();
        std::fs::write(dir.join("ignored.txt"), "not hlo").unwrap();
        let mut rt = ArtifactRuntime::cpu().unwrap();
        let names = rt.load_dir(&dir).unwrap();
        assert_eq!(names, vec!["a"]);
    }

    #[test]
    #[should_panic(expected = "dims/data mismatch")]
    fn tensor_shape_checked() {
        TensorF32::new(vec![2, 2], vec![1.0]);
    }
}
