//! `ckio-lint`: a std-only source pass that cross-checks the code under
//! `rust/src` against the declared protocol registry
//! ([`crate::amt::protocol`]) and a handful of repo hygiene rules.
//!
//! The boot-time verifier proves the *declared* EP graph sound; this
//! pass proves the declarations match the *source*. Seven checks:
//!
//! * **dead-ep** — every non-test `const` whose name starts with `EP_`
//!   must have a non-test send-ish use (a `ctx.send*`, `signal`,
//!   `inject`, or `Callback::to_chare` site — any occurrence that is
//!   not the definition, an import, a spec declaration, or a match
//!   arm) and a non-test receive arm (left of `=>`). The engine's
//!   migration hook EP ([`crate::amt::engine::EP_ON_MIGRATED`]) is
//!   allowlisted: the engine fires it internally.
//! * **stale-ep-ref** — any `EP_…` token in code *or comments* (not
//!   strings) must name a constant defined somewhere in the tree.
//!   Catches docs that outlive a removed protocol message.
//! * **spec-coverage** — for each declared protocol spec: its module file
//!   exists in the scanned tree, every declared handle is defined and
//!   matched in that file, and every EP constant defined in a spec'd
//!   file appears in that file's declared handles.
//! * **payload-mismatch** — inside each handle's match arm, a
//!   `msg.take…` site must decode the spec's payload type; a take in
//!   a declared-signal arm is an error. `PayloadKind::Any` skips the
//!   check, and an arm with no take (handler ignores the payload) is
//!   tolerated.
//! * **metrics-literal** — string literals starting `"ckio."` or
//!   `"amt."` in non-test code must live in `metrics::keys`, not be
//!   scattered as raw literals (files under `metrics/` and `lint/`
//!   are exempt).
//! * **trace-literal** — string literals starting with a trace-event
//!   category prefix (`"session/"`, `"ticket/"`, `"pfs/"`, `"store/"`,
//!   `"place/"`, `"governor/"`, `"sched/"`) in non-test code must live
//!   in `trace::names`, not be scattered as raw literals (files under
//!   `trace/`, `metrics/` and `lint/` are exempt) — the
//!   flight-recorder analogue of **metrics-literal**.
//! * **stash-hygiene** — collection-typed struct fields under `ckio/`
//!   named `pending*`/`parked*`/`early*` must have an in-file drain
//!   site, and `pending_`-prefixed fields must be covered by
//!   `assert_service_clean` (sub-check skipped when the tree has no
//!   such fn, e.g. lint fixtures).
//!
//! The scanner is a deliberately small hand-rolled lexer — no regex,
//! no syn — that strips strings and comments per line while carrying
//! raw-string and block-comment state across lines, then masks
//! `#[cfg(test)]` regions by brace counting. It is conservative:
//! heuristics only ever *suppress* findings (an occurrence we cannot
//! classify counts as a use), so a clean run is trustworthy and a
//! finding is actionable.
//!
//! Entry points: [`scan_sources`] (pure, in-memory — what the tests
//! drive), [`scan_tree`] (walks a directory), [`cli`] (shared by the
//! `ckio lint` subcommand and the `tools/ckio-lint` binary),
//! [`dump_protocol_markdown`] (the `--dump-protocol` mode behind
//! `docs/PROTOCOL.md`), and [`dump_metrics_markdown`] (the
//! `--dump-metrics` mode behind `docs/OBSERVABILITY.md`).

use std::collections::{HashMap, HashSet};
use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use crate::amt::protocol::{self, PayloadKind, ProtocolTable};

/// Which lint produced a [`Finding`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Check {
    DeadEp,
    StaleEpRef,
    SpecCoverage,
    PayloadMismatch,
    MetricsLiteral,
    TraceLiteral,
    StashHygiene,
}

impl Check {
    pub fn as_str(&self) -> &'static str {
        match self {
            Check::DeadEp => "dead-ep",
            Check::StaleEpRef => "stale-ep-ref",
            Check::SpecCoverage => "spec-coverage",
            Check::PayloadMismatch => "payload-mismatch",
            Check::MetricsLiteral => "metrics-literal",
            Check::TraceLiteral => "trace-literal",
            Check::StashHygiene => "stash-hygiene",
        }
    }
}

/// One violation, formatted as `file:line: [check] message`.
#[derive(Clone, Debug)]
pub struct Finding {
    pub file: String,
    /// 1-based; 0 when the finding is not anchored to a line.
    pub line: usize,
    pub check: Check,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.check.as_str(), self.message)
    }
}

// ---------------------------------------------------------------------------
// Lexer: per-line (code, comment, string-literal) split.
// ---------------------------------------------------------------------------

/// One source line with string literals stripped out of `code` (each
/// replaced by a single space) and comment text separated.
#[derive(Debug, Default)]
struct CleanLine {
    code: String,
    comment: String,
    strings: Vec<String>,
}

enum LexState {
    Code,
    /// Nested block-comment depth.
    Block(u32),
    /// Raw string with this many `#`s.
    Raw(usize),
    /// Normal string left open at end-of-line (multi-line literals,
    /// including `\`-continued ones).
    Str,
}

fn clean_source(text: &str) -> Vec<CleanLine> {
    let mut state = LexState::Code;
    let mut raw_buf = String::new();
    let mut str_buf = String::new();
    let mut out = Vec::new();
    for line in text.lines() {
        let chars: Vec<char> = line.chars().collect();
        let mut cl = CleanLine::default();
        let mut i = 0;
        while i < chars.len() {
            match state {
                LexState::Block(d) => {
                    if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                        state = LexState::Block(d + 1);
                        i += 2;
                    } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                        state = if d == 1 {
                            LexState::Code
                        } else {
                            LexState::Block(d - 1)
                        };
                        i += 2;
                    } else {
                        cl.comment.push(chars[i]);
                        i += 1;
                    }
                }
                LexState::Raw(h) => {
                    if chars[i] == '"' && (0..h).all(|k| chars.get(i + 1 + k) == Some(&'#')) {
                        cl.strings.push(std::mem::take(&mut raw_buf));
                        state = LexState::Code;
                        i += 1 + h;
                    } else {
                        raw_buf.push(chars[i]);
                        i += 1;
                    }
                }
                LexState::Str => {
                    if chars[i] == '"' {
                        cl.strings.push(std::mem::take(&mut str_buf));
                        state = LexState::Code;
                        i += 1;
                    } else if chars[i] == '\\' && i + 1 < chars.len() {
                        str_buf.push(chars[i + 1]);
                        i += 2;
                    } else {
                        str_buf.push(chars[i]);
                        i += 1;
                    }
                }
                LexState::Code => {
                    let c = chars[i];
                    if c == '/' && chars.get(i + 1) == Some(&'/') {
                        cl.comment.extend(&chars[i + 2..]);
                        break;
                    }
                    if c == '/' && chars.get(i + 1) == Some(&'*') {
                        state = LexState::Block(1);
                        i += 2;
                        continue;
                    }
                    if c == 'r' && !cl.code.ends_with(is_ident_char) {
                        let mut h = 0;
                        while chars.get(i + 1 + h) == Some(&'#') {
                            h += 1;
                        }
                        if chars.get(i + 1 + h) == Some(&'"') {
                            state = LexState::Raw(h);
                            cl.code.push(' ');
                            i += 2 + h;
                            continue;
                        }
                    }
                    if c == '"' {
                        let mut j = i + 1;
                        let mut s = String::new();
                        let mut closed = false;
                        while j < chars.len() {
                            if chars[j] == '"' {
                                closed = true;
                                break;
                            }
                            if chars[j] == '\\' && j + 1 < chars.len() {
                                s.push(chars[j + 1]);
                                j += 2;
                            } else {
                                s.push(chars[j]);
                                j += 1;
                            }
                        }
                        cl.code.push(' ');
                        if closed {
                            cl.strings.push(s);
                            i = j + 1;
                        } else {
                            str_buf = s;
                            state = LexState::Str;
                            i = j;
                        }
                        continue;
                    }
                    if c == '\'' {
                        if chars.get(i + 1) == Some(&'\\') {
                            let mut j = i + 2;
                            while j < chars.len() && chars[j] != '\'' {
                                j += 1;
                            }
                            cl.code.push(' ');
                            i = j + 1;
                        } else if chars.get(i + 2) == Some(&'\'') {
                            cl.code.push(' ');
                            i += 3;
                        } else {
                            cl.code.push('\'');
                            i += 1;
                        }
                        continue;
                    }
                    cl.code.push(c);
                    i += 1;
                }
            }
        }
        match state {
            LexState::Raw(_) => raw_buf.push('\n'),
            LexState::Str => str_buf.push('\n'),
            _ => {}
        }
        out.push(cl);
    }
    out
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Mark every line belonging to a `#[cfg(test)]` item (attribute line
/// through the item's closing brace, or its `;` for brace-less items).
fn test_mask(lines: &[CleanLine]) -> Vec<bool> {
    let mut mask = vec![false; lines.len()];
    let mut i = 0;
    while i < lines.len() {
        if !lines[i].code.contains("#[cfg(test)]") {
            i += 1;
            continue;
        }
        let mut depth = 0i64;
        let mut seen = false;
        let mut j = i;
        loop {
            mask[j] = true;
            for c in lines[j].code.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        seen = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            if (seen && depth <= 0) || (!seen && j > i && lines[j].code.contains(';')) {
                break;
            }
            j += 1;
            if j >= lines.len() {
                break;
            }
        }
        i = j + 1;
    }
    mask
}

struct CleanFile {
    path: String,
    lines: Vec<CleanLine>,
    test: Vec<bool>,
}

// ---------------------------------------------------------------------------
// Token scanning.
// ---------------------------------------------------------------------------

/// `EP_…` tokens in `s` as (char position, token). A token is `EP_`
/// plus at least one of `[A-Z0-9_]`; a lowercase tail (a mixed-case
/// identifier that merely starts with those letters) disqualifies it.
fn ep_tokens(s: &str) -> Vec<(usize, String)> {
    let b: Vec<char> = s.chars().collect();
    let mut out = Vec::new();
    let mut i = 0;
    while i < b.len() {
        let start = b[i] == 'E'
            && (i == 0 || !is_ident_char(b[i - 1]))
            && b.get(i + 1) == Some(&'P')
            && b.get(i + 2) == Some(&'_');
        if !start {
            i += 1;
            continue;
        }
        let mut j = i + 3;
        while j < b.len() && (b[j].is_ascii_uppercase() || b[j].is_ascii_digit() || b[j] == '_') {
            j += 1;
        }
        if j > i + 3 && !(j < b.len() && b[j].is_ascii_lowercase()) {
            out.push((i, b[i..j].iter().collect()));
        }
        i = j.max(i + 1);
    }
    out
}

/// Char position of the first `=>` in `code`.
fn arrow_pos(code: &str) -> Option<usize> {
    let b: Vec<char> = code.chars().collect();
    (0..b.len().saturating_sub(1)).find(|&i| b[i] == '=' && b[i + 1] == '>')
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum OccClass {
    Def,
    Import,
    Spec,
    Arm,
    Send,
}

struct EpOcc {
    file: usize,
    line: usize,
    test: bool,
    class: OccClass,
}

fn classify(code: &str, tok: &str, pos: usize) -> OccClass {
    let t = code.trim_start();
    if t.starts_with("use ") || t.starts_with("pub use ") {
        return OccClass::Import;
    }
    if code.contains("ep_spec!") || code.contains("send_spec!") {
        return OccClass::Spec;
    }
    if code.contains(&format!("const {tok}")) {
        return OccClass::Def;
    }
    if let Some(a) = arrow_pos(code) {
        if pos < a {
            return OccClass::Arm;
        }
    }
    OccClass::Send
}

// ---------------------------------------------------------------------------
// The scan.
// ---------------------------------------------------------------------------

const ALLOWED_EPS: [&str; 1] = ["EP_ON_MIGRATED"];
const METRIC_PREFIXES: [&str; 2] = ["ckio.", "amt."];
// Trace-event names are `category/event`; the slash keeps plain prose
// ("pfs.reads", "store budget") from matching.
const TRACE_PREFIXES: [&str; 7] =
    ["session/", "ticket/", "pfs/", "store/", "place/", "governor/", "sched/"];
// `metrics/` is exempt because its key catalog names emitter *files*
// ("pfs/model.rs") that collide with the prefixes.
const TRACE_EXEMPT_DIRS: [&str; 3] = ["trace", "metrics", "lint"];
const DRAIN_MARKERS: [&str; 5] = [".remove(", ".drain(", ".clear(", ".pop", "mem::take"];
const STASH_PREFIXES: [&str; 3] = ["pending", "parked", "early"];
const EXEMPT_DIRS: [&str; 2] = ["metrics", "lint"];

fn in_dir(path: &str, dir: &str) -> bool {
    path.starts_with(&format!("{dir}/")) || path.contains(&format!("/{dir}/"))
}

/// Scan in-memory sources against a protocol table. `files` pairs a
/// display path (matched against each spec's `module` by suffix) with
/// the file's text. Pure — the test surface for every check.
pub fn scan_sources(files: &[(String, String)], table: &ProtocolTable) -> Vec<Finding> {
    let cleaned: Vec<CleanFile> = files
        .iter()
        .map(|(path, text)| {
            let lines = clean_source(text);
            let test = test_mask(&lines);
            CleanFile { path: path.clone(), lines, test }
        })
        .collect();

    let mut occs: HashMap<String, Vec<EpOcc>> = HashMap::new();
    for (fi, f) in cleaned.iter().enumerate() {
        for (li, line) in f.lines.iter().enumerate() {
            for (pos, tok) in ep_tokens(&line.code) {
                let class = classify(&line.code, &tok, pos);
                occs.entry(tok).or_default().push(EpOcc {
                    file: fi,
                    line: li + 1,
                    test: f.test[li],
                    class,
                });
            }
        }
    }

    let mut findings = Vec::new();
    check_dead_eps(&cleaned, &occs, &mut findings);
    check_stale_refs(&cleaned, &occs, &mut findings);
    check_spec_coverage(&cleaned, &occs, table, &mut findings);
    check_payloads(&cleaned, table, &mut findings);
    check_metric_literals(&cleaned, &mut findings);
    check_trace_literals(&cleaned, &mut findings);
    check_stash_hygiene(&cleaned, &mut findings);
    findings
}

fn check_dead_eps(
    files: &[CleanFile],
    occs: &HashMap<String, Vec<EpOcc>>,
    out: &mut Vec<Finding>,
) {
    let mut toks: Vec<&String> = occs.keys().collect();
    toks.sort();
    for tok in toks {
        if ALLOWED_EPS.contains(&tok.as_str()) {
            continue;
        }
        let os = &occs[tok];
        let Some(def) = os.iter().find(|o| o.class == OccClass::Def && !o.test) else {
            continue;
        };
        let sent = os.iter().any(|o| o.class == OccClass::Send && !o.test);
        let armed = os.iter().any(|o| o.class == OccClass::Arm && !o.test);
        let at = &files[def.file].path;
        if !sent {
            out.push(Finding {
                file: at.clone(),
                line: def.line,
                check: Check::DeadEp,
                message: format!("{tok} is defined but has no non-test send site"),
            });
        }
        if !armed {
            out.push(Finding {
                file: at.clone(),
                line: def.line,
                check: Check::DeadEp,
                message: format!("{tok} is defined but never matched in a receive arm"),
            });
        }
    }
}

fn check_stale_refs(
    files: &[CleanFile],
    occs: &HashMap<String, Vec<EpOcc>>,
    out: &mut Vec<Finding>,
) {
    let defined: HashSet<&String> = occs
        .iter()
        .filter(|(_, os)| os.iter().any(|o| o.class == OccClass::Def))
        .map(|(tok, _)| tok)
        .collect();
    // Code references to an undefined constant (would not compile in
    // checked-in code, but fixtures and comments drift silently).
    for (tok, os) in occs {
        if defined.contains(tok) {
            continue;
        }
        for o in os {
            out.push(Finding {
                file: files[o.file].path.clone(),
                line: o.line,
                check: Check::StaleEpRef,
                message: format!("{tok} is referenced but no `const {tok}` exists in the tree"),
            });
        }
    }
    // Comment references.
    for f in files {
        for (li, line) in f.lines.iter().enumerate() {
            for (_, tok) in ep_tokens(&line.comment) {
                if !defined.contains(&tok) {
                    out.push(Finding {
                        file: f.path.clone(),
                        line: li + 1,
                        check: Check::StaleEpRef,
                        message: format!(
                            "comment mentions {tok} but no `const {tok}` exists in the tree"
                        ),
                    });
                }
            }
        }
    }
    out.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
}

/// Non-test `const EP_…` definitions in one file, with lines.
fn file_defs(f: &CleanFile) -> Vec<(String, usize)> {
    let mut defs = Vec::new();
    for (li, line) in f.lines.iter().enumerate() {
        if f.test[li] {
            continue;
        }
        for (pos, tok) in ep_tokens(&line.code) {
            if classify(&line.code, &tok, pos) == OccClass::Def {
                defs.push((tok, li + 1));
            }
        }
    }
    defs
}

/// Does `code` start a match arm for `tok` (the token left of `=>`)?
fn arm_start(code: &str, tok: &str) -> bool {
    let Some(a) = arrow_pos(code) else {
        return false;
    };
    ep_tokens(code).iter().any(|(p, t)| t == tok && *p < a)
}

/// Does a non-test line of `f` start a match arm for `tok`?
fn has_arm(f: &CleanFile, tok: &str) -> bool {
    for (li, line) in f.lines.iter().enumerate() {
        if !f.test[li] && arm_start(&line.code, tok) {
            return true;
        }
    }
    false
}

fn check_spec_coverage(
    files: &[CleanFile],
    occs: &HashMap<String, Vec<EpOcc>>,
    table: &ProtocolTable,
    out: &mut Vec<Finding>,
) {
    // Specs sharing one module file (the experiment chares all live in
    // harness/experiments.rs) pool their handles for the
    // defined-but-undeclared direction.
    let mut declared_by_file: HashMap<usize, HashSet<&str>> = HashMap::new();
    for spec in &table.specs {
        let Some(fi) = files.iter().position(|f| f.path.ends_with(spec.module)) else {
            out.push(Finding {
                file: spec.module.to_string(),
                line: 0,
                check: Check::SpecCoverage,
                message: format!("{}: declared module file was not scanned", spec.chare),
            });
            continue;
        };
        let entry = declared_by_file.entry(fi).or_default();
        for h in &spec.handles {
            entry.insert(h.name);
        }
        let defs = file_defs(&files[fi]);
        for h in &spec.handles {
            let defined_here = defs.iter().any(|(t, _)| t == h.name);
            let defined_anywhere = occs
                .get(h.name)
                .is_some_and(|os| os.iter().any(|o| o.class == OccClass::Def));
            if !defined_here && !defined_anywhere {
                out.push(Finding {
                    file: files[fi].path.clone(),
                    line: 0,
                    check: Check::SpecCoverage,
                    message: format!("{}: {} declared in spec but not defined", spec.chare, h.name),
                });
                continue;
            }
            if defined_here && !has_arm(&files[fi], h.name) {
                out.push(Finding {
                    file: files[fi].path.clone(),
                    line: 0,
                    check: Check::SpecCoverage,
                    message: format!("{}: {} has no receive arm", spec.chare, h.name),
                });
            }
        }
    }
    for (fi, declared) in declared_by_file {
        for (tok, line) in file_defs(&files[fi]) {
            if !declared.contains(tok.as_str()) {
                out.push(Finding {
                    file: files[fi].path.clone(),
                    line,
                    check: Check::SpecCoverage,
                    message: format!("{tok} is defined here but missing from the protocol spec"),
                });
            }
        }
    }
    out.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
}

/// Payload type of a `msg.take` site on one cleaned line: turbofish
/// (`msg.take::<T>()`) or let-binding (`let x: T = msg.take()`), as
/// the type's last path segment. `None` when the line has no take or
/// the form is unrecognized (conservative: unrecognized is tolerated).
fn take_type(code: &str) -> Option<String> {
    let pos = code.find("msg.take")?;
    let after = &code[pos + "msg.take".len()..];
    if let Some(rest) = after.strip_prefix("::<") {
        let mut depth = 1u32;
        let mut ty = String::new();
        for c in rest.chars() {
            match c {
                '<' => depth += 1,
                '>' => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            ty.push(c);
        }
        return Some(short_segment(&ty));
    }
    let before = &code[..pos];
    let eq = before.rfind('=')?;
    let lhs = &before[..eq];
    let b: Vec<char> = lhs.chars().collect();
    let mut colon = None;
    for i in (0..b.len()).rev() {
        if b[i] == ':' && b.get(i + 1) != Some(&':') && (i == 0 || b[i - 1] != ':') {
            colon = Some(i);
            break;
        }
    }
    let ty: String = b[colon? + 1..].iter().collect();
    Some(short_segment(&ty))
}

fn short_segment(ty: &str) -> String {
    ty.trim().rsplit("::").next().unwrap_or(ty).trim().to_string()
}

/// Line ranges (0-based, inclusive start / exclusive end) of the match
/// arms for `tok` in `f`: from each arm line to the next arm-looking
/// line or catch-all.
fn arm_regions(f: &CleanFile, tok: &str) -> Vec<(usize, usize)> {
    let mut regions = Vec::new();
    for (li, line) in f.lines.iter().enumerate() {
        if f.test[li] || !arm_start(&line.code, tok) {
            continue;
        }
        let mut end = li + 1;
        while end < f.lines.len() && !arm_boundary(&f.lines[end].code) {
            end += 1;
        }
        regions.push((li, end));
    }
    regions
}

/// Does `code` end the current arm region: the next arm (any token
/// left of `=>`) or a catch-all?
fn arm_boundary(code: &str) -> bool {
    let trimmed = code.trim_start();
    if trimmed.starts_with("_ =>") || trimmed.starts_with("other =>") {
        return true;
    }
    let Some(a) = arrow_pos(code) else {
        return false;
    };
    ep_tokens(code).iter().any(|(p, _)| *p < a)
}

fn check_payloads(files: &[CleanFile], table: &ProtocolTable, out: &mut Vec<Finding>) {
    for spec in &table.specs {
        let Some(f) = files.iter().find(|f| f.path.ends_with(spec.module)) else {
            continue;
        };
        for h in &spec.handles {
            if matches!(h.payload, PayloadKind::Any) {
                continue;
            }
            let want = h.payload.short_name();
            for (start, end) in arm_regions(f, h.name) {
                for li in start..end {
                    let Some(got) = take_type(&f.lines[li].code) else {
                        continue;
                    };
                    match h.payload {
                        PayloadKind::Signal => out.push(Finding {
                            file: f.path.clone(),
                            line: li + 1,
                            check: Check::PayloadMismatch,
                            message: format!(
                                "{}: {} is declared as a signal but its handler takes {got}",
                                spec.chare, h.name
                            ),
                        }),
                        _ if got != want => out.push(Finding {
                            file: f.path.clone(),
                            line: li + 1,
                            check: Check::PayloadMismatch,
                            message: format!(
                                "{}: {} handler takes {got} but the spec declares {want}",
                                spec.chare, h.name
                            ),
                        }),
                        _ => {}
                    }
                }
            }
        }
    }
}

fn check_metric_literals(files: &[CleanFile], out: &mut Vec<Finding>) {
    for f in files {
        if EXEMPT_DIRS.iter().any(|d| in_dir(&f.path, d)) {
            continue;
        }
        for (li, line) in f.lines.iter().enumerate() {
            if f.test[li] {
                continue;
            }
            for s in &line.strings {
                if METRIC_PREFIXES.iter().any(|p| s.starts_with(p)) {
                    out.push(Finding {
                        file: f.path.clone(),
                        line: li + 1,
                        check: Check::MetricsLiteral,
                        message: format!(
                            "metric key \"{s}\" as a raw literal — use a metrics::keys constant"
                        ),
                    });
                }
            }
        }
    }
}

fn check_trace_literals(files: &[CleanFile], out: &mut Vec<Finding>) {
    for f in files {
        if TRACE_EXEMPT_DIRS.iter().any(|d| in_dir(&f.path, d)) {
            continue;
        }
        for (li, line) in f.lines.iter().enumerate() {
            if f.test[li] {
                continue;
            }
            for s in &line.strings {
                if TRACE_PREFIXES.iter().any(|p| s.starts_with(p)) {
                    out.push(Finding {
                        file: f.path.clone(),
                        line: li + 1,
                        check: Check::TraceLiteral,
                        message: format!(
                            "trace event \"{s}\" as a raw literal — use a trace::names constant"
                        ),
                    });
                }
            }
        }
    }
}

/// A struct-field line declaring a stash collection: an identifier
/// with one of the stash prefixes, a `:`, and an owned collection
/// type. `let` bindings and fn signatures are excluded.
fn stash_field(code: &str) -> Option<String> {
    let mut t = code.trim();
    for vis in ["pub(crate) ", "pub(super) ", "pub "] {
        if let Some(rest) = t.strip_prefix(vis) {
            t = rest;
            break;
        }
    }
    if t.starts_with("let ") || t.starts_with("fn ") {
        return None;
    }
    let (name, rest) = t.split_once(':')?;
    let name = name.trim();
    if name.is_empty()
        || !name.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
        || !STASH_PREFIXES.iter().any(|p| name.starts_with(p))
    {
        return None;
    }
    const COLLECTIONS: [&str; 5] = ["HashMap<", "Vec<", "BTreeMap<", "VecDeque<", "HashSet<"];
    if !COLLECTIONS.iter().any(|c| rest.contains(c)) {
        return None;
    }
    Some(name.to_string())
}

fn check_stash_hygiene(files: &[CleanFile], out: &mut Vec<Finding>) {
    // Body of `fn assert_service_clean`, wherever it lives.
    let mut clean_body: Option<String> = None;
    for f in files {
        let start = f.lines.iter().position(|l| l.code.contains("fn assert_service_clean"));
        let Some(start) = start else {
            continue;
        };
        let mut body = String::new();
        let mut depth = 0i64;
        let mut seen = false;
        for line in &f.lines[start..] {
            body.push_str(&line.code);
            body.push('\n');
            for c in line.code.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        seen = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            if seen && depth <= 0 {
                break;
            }
        }
        clean_body = Some(body);
        break;
    }
    for f in files {
        if !in_dir(&f.path, "ckio") && !f.path.starts_with("ckio/") {
            continue;
        }
        for (li, line) in f.lines.iter().enumerate() {
            if f.test[li] {
                continue;
            }
            let Some(field) = stash_field(&line.code) else {
                continue;
            };
            let mut drained = false;
            for (dl, l) in f.lines.iter().enumerate() {
                if dl != li
                    && l.code.contains(&field)
                    && DRAIN_MARKERS.iter().any(|m| l.code.contains(m))
                {
                    drained = true;
                    break;
                }
            }
            if !drained {
                out.push(Finding {
                    file: f.path.clone(),
                    line: li + 1,
                    check: Check::StashHygiene,
                    message: format!(
                        "stash field {field} has no in-file drain site \
                         (.remove/.drain/.clear/.pop/mem::take)"
                    ),
                });
            }
            if field.starts_with("pending_") {
                if let Some(body) = &clean_body {
                    if !body.contains(&field) {
                        out.push(Finding {
                            file: f.path.clone(),
                            line: li + 1,
                            check: Check::StashHygiene,
                            message: format!(
                                "stash field {field} is not checked by assert_service_clean"
                            ),
                        });
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Tree walking, protocol dump, CLI.
// ---------------------------------------------------------------------------

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<PathBuf> =
        fs::read_dir(dir)?.map(|e| e.map(|e| e.path())).collect::<io::Result<_>>()?;
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Scan every `.rs` file under `root`. Returns the findings and the
/// number of files scanned. Paths in findings are relative to `root`.
pub fn scan_tree(root: &Path, table: &ProtocolTable) -> io::Result<(Vec<Finding>, usize)> {
    let mut paths = Vec::new();
    collect_rs(root, &mut paths)?;
    let mut files = Vec::new();
    for p in &paths {
        let rel = p.strip_prefix(root).unwrap_or(p).to_string_lossy().into_owned();
        files.push((rel, fs::read_to_string(p)?));
    }
    Ok((scan_sources(&files, table), files.len()))
}

/// Render the protocol table as Markdown — the `--dump-protocol` mode,
/// checked in as `docs/PROTOCOL.md`. Deterministic: specs, handles,
/// and sends appear in declaration order.
pub fn dump_protocol_markdown(table: &ProtocolTable) -> String {
    let mut md = String::new();
    md.push_str("# CkIO declared message protocol\n\n");
    md.push_str(
        "Generated from the in-tree protocol registry (`rust/src/amt/protocol.rs`)\n\
         by `ckio lint --dump-protocol`. Regenerate after any protocol change —\n\
         the maintenance rule in ROADMAP.md requires a chare's `protocol_spec()`\n\
         to move in the same commit as its EPs, payload types, or send sites.\n",
    );
    for spec in &table.specs {
        md.push_str(&format!("\n## {} — `{}`\n\nHandles:\n\n", spec.chare, spec.module));
        md.push_str("| EP | Constant | Payload |\n|---:|----------|---------|\n");
        for h in &spec.handles {
            let p = h.payload.short_name();
            md.push_str(&format!("| {} | `{}` | `{}` |\n", h.ep, h.name, p));
        }
        if spec.sends.is_empty() {
            md.push_str("\nSends: none (all inbound traffic arrives via callbacks).\n");
        } else {
            md.push_str("\nSends:\n\n| Target | EP | Constant | Payload |\n");
            md.push_str("|--------|---:|----------|---------|\n");
            for s in &spec.sends {
                let p = s.payload.short_name();
                md.push_str(&format!("| {} | {} | `{}` | `{}` |\n", s.target, s.ep, s.name, p));
            }
        }
    }
    md
}

/// Render the observability catalog as Markdown — the `--dump-metrics`
/// mode, checked in as `docs/OBSERVABILITY.md`. Deterministic: metrics
/// keys and trace events appear in declaration order
/// ([`crate::metrics::keys::catalog`] / [`crate::trace::names::catalog`]).
pub fn dump_metrics_markdown() -> String {
    let mut md = String::new();
    md.push_str("# CkIO observability catalog\n\n");
    md.push_str(
        "Generated from the in-tree registries (`rust/src/metrics/mod.rs` and\n\
         `rust/src/trace/mod.rs`) by `ckio lint --dump-metrics`. Regenerate after\n\
         any metrics-key or trace-name change — the maintenance rule in\n\
         ROADMAP.md requires this file to move in the same commit.\n",
    );
    md.push_str("\n## Metrics keys\n\n");
    md.push_str(
        "Kinds: **counter** — monotonic sum over the run; **duration** —\n\
         accumulated virtual nanoseconds; **gauge** — last-written value\n\
         (high-water marks via max-merge); **histogram** — log-bucketed\n\
         distribution, quantiles surfaced as `p50`/`p99`/`p99.9` in the\n\
         `latency` section of `ckio bench-json`.\n\n",
    );
    md.push_str("| Key | Kind | Emitted by | Meaning |\n|-----|------|------------|---------|\n");
    for (key, kind, module, desc) in crate::metrics::keys::catalog() {
        md.push_str(&format!("| `{key}` | {kind} | `{module}` | {desc} |\n"));
    }
    md.push_str("\n## Trace events\n\n");
    md.push_str(
        "One row per `trace::names` constant. The category is the prefix\n\
         before the `/` (also the Chrome trace `cat` field); categories can\n\
         be enabled selectively via `TraceConfig::categories`. Turn the\n\
         flight recorder on with `ServiceConfig::trace` or `ckio trace\n\
         <fig-id>`; see `rust/src/trace/mod.rs` for the event model.\n\n",
    );
    md.push_str("| Event | Category | Emitted by | Marks |\n|-------|----------|------------|-------|\n");
    for (name, module, desc) in crate::trace::names::catalog() {
        let cat = name.split('/').next().unwrap_or(name);
        md.push_str(&format!("| `{name}` | {cat} | `{module}` | {desc} |\n"));
    }
    md
}

/// Shared entry point for `ckio lint` and the `ckio-lint` binary.
/// Args: an optional tree root (default `rust/src`, falling back to
/// `src` when invoked from inside `rust/`), `--dump-protocol`, and
/// `--dump-metrics`. Exit codes: 0 clean, 1 findings, 2
/// usage/protocol/IO error.
pub fn cli(args: &[String]) -> i32 {
    let mut dump = false;
    let mut dump_metrics = false;
    let mut root: Option<String> = None;
    for a in args {
        match a.as_str() {
            "--dump-protocol" => dump = true,
            "--dump-metrics" => dump_metrics = true,
            other if !other.starts_with('-') && root.is_none() => root = Some(other.to_string()),
            other => {
                eprintln!("ckio-lint: unknown argument {other:?}");
                eprintln!("usage: ckio-lint [--dump-protocol] [--dump-metrics] [tree-root]");
                return 2;
            }
        }
    }
    let table = protocol::builtin_table();
    if let Err(errs) = protocol::verify(&table) {
        eprintln!("{}", protocol::format_errors(&errs));
        return 2;
    }
    if dump {
        print!("{}", dump_protocol_markdown(&table));
        return 0;
    }
    if dump_metrics {
        print!("{}", dump_metrics_markdown());
        return 0;
    }
    let root = root.unwrap_or_else(|| {
        if Path::new("rust/src").is_dir() {
            "rust/src".into()
        } else {
            "src".into()
        }
    });
    match scan_tree(Path::new(&root), &table) {
        Ok((findings, scanned)) if findings.is_empty() => {
            println!("ckio-lint: {scanned} files clean under {root}");
            0
        }
        Ok((findings, scanned)) => {
            for f in &findings {
                eprintln!("{f}");
            }
            eprintln!("ckio-lint: {} findings in {scanned} files under {root}", findings.len());
            1
        }
        Err(e) => {
            eprintln!("ckio-lint: cannot scan {root}: {e}");
            2
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amt::protocol::{EpSpec, ProtocolSpec};

    struct FooMsg;

    fn one(path: &str, text: &str) -> Vec<(String, String)> {
        vec![(path.to_string(), text.to_string())]
    }

    fn spec(module: &'static str, handles: Vec<EpSpec>) -> ProtocolTable {
        let mut t = ProtocolTable::default();
        t.push(ProtocolSpec { chare: "Fixture", module, handles, sends: vec![] });
        t
    }

    fn of(findings: &[Finding], check: Check) -> Vec<&Finding> {
        findings.iter().filter(|f| f.check == check).collect()
    }

    #[test]
    fn lexer_strips_strings_and_comments() {
        let src = "let a = \"EP_IN_STRING\"; // EP_IN_COMMENT\nlet b = 'x';";
        let lines = clean_source(src);
        assert!(!lines[0].code.contains("EP_IN_STRING"));
        assert_eq!(lines[0].strings, vec!["EP_IN_STRING".to_string()]);
        assert!(lines[0].comment.contains("EP_IN_COMMENT"));
        assert!(!lines[1].code.contains('x'));
    }

    #[test]
    fn lexer_carries_raw_strings_across_lines() {
        let src = "let s = r#\"first \"quoted\"\nsecond EP_RAW\"#;\nlet t = EP_AFTER;";
        let lines = clean_source(src);
        assert!(lines[1].code.trim().is_empty() || !lines[1].code.contains("EP_RAW"));
        assert!(lines[1].strings.iter().any(|s| s.contains("EP_RAW")));
        assert!(lines[2].code.contains("EP_AFTER"));
    }

    #[test]
    fn lexer_carries_plain_strings_across_lines() {
        // A normal string left open at end-of-line (as in `\`-continued
        // literals) must not leak its content — or its braces — into code.
        let src = "let s = \"a { EP_INSIDE\nb } c\";\nlet t = EP_AFTER;";
        let lines = clean_source(src);
        assert!(!lines[0].code.contains('{'));
        assert!(!lines[0].code.contains("EP_INSIDE"));
        assert!(!lines[1].code.contains('}'));
        assert_eq!(lines[1].strings, vec!["a { EP_INSIDE\nb } c"]);
        assert!(lines[2].code.contains("EP_AFTER"));
    }

    #[test]
    fn lexer_keeps_lifetimes_but_drops_char_literals() {
        let lines = clean_source("fn f<'a>(x: &'a str) { let c = '\"'; let d = \"ok\"; }");
        assert!(lines[0].code.contains("'a"));
        assert_eq!(lines[0].strings, vec!["ok".to_string()]);
    }

    #[test]
    fn test_regions_are_masked() {
        let src = "const A: u32 = 1;\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   const B: u32 = 2;\n\
                   }\n\
                   const C: u32 = 3;";
        let lines = clean_source(src);
        let mask = test_mask(&lines);
        assert_eq!(mask, vec![false, true, true, true, true, false]);
    }

    #[test]
    fn dead_ep_detected_and_cleared_by_use() {
        let dead = "pub const EP_DEADX: Ep = 1;\n\
                    fn recv(ep: u32) { match ep { EP_DEADX => {} _ => {} } }";
        let fs = one("app.rs", dead);
        let findings = scan_sources(&fs, &ProtocolTable::default());
        let dead_eps = of(&findings, Check::DeadEp);
        assert_eq!(dead_eps.len(), 1, "{findings:?}");
        assert!(dead_eps[0].message.contains("no non-test send site"));

        let live = "pub const EP_DEADX: Ep = 1;\n\
                    fn go(ctx: &C) { ctx.send(t, EP_DEADX, p); }\n\
                    fn recv(ep: u32) { match ep { EP_DEADX => {} _ => {} } }";
        let findings = scan_sources(&one("app.rs", live), &ProtocolTable::default());
        assert!(of(&findings, Check::DeadEp).is_empty(), "{findings:?}");
    }

    #[test]
    fn stale_comment_ref_detected() {
        let src = "pub const EP_REAL: Ep = 1;\n\
                   // replaced by EP_GONE long ago\n\
                   fn f() { g(EP_REAL); h(EP_REAL); }\n\
                   fn recv(ep: u32) { match ep { EP_REAL => {} _ => {} } }";
        let findings = scan_sources(&one("app.rs", src), &ProtocolTable::default());
        let stale = of(&findings, Check::StaleEpRef);
        assert_eq!(stale.len(), 1, "{findings:?}");
        assert!(stale[0].message.contains("EP_GONE"));
        assert_eq!(stale[0].line, 2);
    }

    #[test]
    fn spec_coverage_both_directions() {
        let src = "pub const EP_ONE: Ep = 1;\n\
                   pub const EP_TWO: Ep = 2;\n\
                   fn s(ctx: &C) { ctx.send(t, EP_ONE, p); ctx.send(t, EP_TWO, p); }\n\
                   fn recv(ep: u32) { match ep { EP_ONE => {} EP_TWO => {} _ => {} } }";
        const EP_ONE: u32 = 1;
        let table = spec("app.rs", vec![crate::ep_spec!(EP_ONE, PayloadKind::Signal)]);
        let findings = scan_sources(&one("app.rs", src), &table);
        let cov = of(&findings, Check::SpecCoverage);
        assert_eq!(cov.len(), 1, "{findings:?}");
        assert!(cov[0].message.contains("EP_TWO"), "{:?}", cov[0]);
    }

    #[test]
    fn payload_mismatch_detected() {
        let src = "pub const EP_ONE: Ep = 1;\n\
                   fn s(ctx: &C) { ctx.send(t, EP_ONE, p); }\n\
                   fn recv(msg: &mut Msg) { match msg.ep {\n\
                   EP_ONE => {\n\
                   let m: BarMsg = msg.take();\n\
                   }\n\
                   _ => {}\n\
                   } }";
        const EP_ONE: u32 = 1;
        let table = spec("app.rs", vec![crate::ep_spec!(EP_ONE, PayloadKind::of::<FooMsg>())]);
        let findings = scan_sources(&one("app.rs", src), &table);
        let pm = of(&findings, Check::PayloadMismatch);
        assert_eq!(pm.len(), 1, "{findings:?}");
        assert!(pm[0].message.contains("BarMsg") && pm[0].message.contains("FooMsg"));
        assert_eq!(pm[0].line, 5);
    }

    #[test]
    fn signal_with_take_detected_and_matching_take_clean() {
        let src = "pub const EP_ONE: Ep = 1;\n\
                   pub const EP_TWO: Ep = 2;\n\
                   fn s(ctx: &C) { ctx.send(t, EP_ONE, p); ctx.send(t, EP_TWO, p); }\n\
                   fn recv(msg: &mut Msg) { match msg.ep {\n\
                   EP_ONE => {\n\
                   let m: FooMsg = msg.take();\n\
                   }\n\
                   EP_TWO => {\n\
                   let m = msg.take::<FooMsg>();\n\
                   }\n\
                   _ => {}\n\
                   } }";
        const EP_ONE: u32 = 1;
        const EP_TWO: u32 = 2;
        let table = spec(
            "app.rs",
            vec![
                crate::ep_spec!(EP_ONE, PayloadKind::of::<FooMsg>()),
                crate::ep_spec!(EP_TWO, PayloadKind::Signal),
            ],
        );
        let findings = scan_sources(&one("app.rs", src), &table);
        let pm = of(&findings, Check::PayloadMismatch);
        assert_eq!(pm.len(), 1, "{findings:?}");
        assert!(pm[0].message.contains("declared as a signal"), "{:?}", pm[0]);
    }

    #[test]
    fn metric_literal_detected_and_exempt_dirs_skipped() {
        let src = "fn f(m: &M) { m.counter(\"ckio.rogue\", 1); }";
        let findings = scan_sources(&one("app.rs", src), &ProtocolTable::default());
        assert_eq!(of(&findings, Check::MetricsLiteral).len(), 1, "{findings:?}");
        let findings = scan_sources(&one("metrics/mod.rs", src), &ProtocolTable::default());
        assert!(of(&findings, Check::MetricsLiteral).is_empty());
    }

    #[test]
    fn trace_literal_detected_and_exempt_dirs_skipped() {
        let src = "fn f(t: &mut T) { t.instant(0, \"ticket/rogue\"); }";
        let findings = scan_sources(&one("ckio/app.rs", src), &ProtocolTable::default());
        let tl = of(&findings, Check::TraceLiteral);
        assert_eq!(tl.len(), 1, "{findings:?}");
        assert!(tl[0].message.contains("ticket/rogue"), "{:?}", tl[0]);
        // The registry itself and the lint fixtures are exempt.
        let findings = scan_sources(&one("trace/mod.rs", src), &ProtocolTable::default());
        assert!(of(&findings, Check::TraceLiteral).is_empty());
        // Prose with a bare category word (no slash) is not a finding,
        // and neither is a prefixed literal on a test-masked line.
        let clean = "fn f() { let _ = \"store budget\"; }\n\
                     #[cfg(test)]\n\
                     mod tests {\n\
                     fn g(t: &mut T) { t.instant(0, \"pfs/read\"); }\n\
                     }";
        let findings = scan_sources(&one("ckio/app.rs", clean), &ProtocolTable::default());
        assert!(of(&findings, Check::TraceLiteral).is_empty(), "{findings:?}");
    }

    #[test]
    fn dump_metrics_markdown_covers_both_registries() {
        let md = dump_metrics_markdown();
        assert!(md.starts_with("# CkIO observability catalog"));
        for (key, _, _, _) in crate::metrics::keys::catalog() {
            assert!(md.contains(&format!("`{key}`")), "missing metrics row for {key}");
        }
        for (name, _, _) in crate::trace::names::catalog() {
            assert!(md.contains(&format!("`{name}`")), "missing trace row for {name}");
        }
    }

    #[test]
    fn stash_without_drain_detected() {
        let src = "struct S {\n\
                   pending_work: HashMap<u32, u64>,\n\
                   parked: Vec<u8>,\n\
                   }\n\
                   impl S { fn d(&mut self) { self.parked.clear(); } }";
        let findings = scan_sources(&one("ckio/stash.rs", src), &ProtocolTable::default());
        let sh = of(&findings, Check::StashHygiene);
        assert_eq!(sh.len(), 1, "{findings:?}");
        assert!(sh[0].message.contains("pending_work"));
    }

    #[test]
    fn pending_fields_must_reach_assert_service_clean() {
        let src = "struct S {\n\
                   pending_work: HashMap<u32, u64>,\n\
                   }\n\
                   impl S { fn d(&mut self) { self.pending_work.clear(); } }\n\
                   pub fn assert_service_clean(s: &S) {\n\
                   assert!(s.ok);\n\
                   }";
        let findings = scan_sources(&one("ckio/stash.rs", src), &ProtocolTable::default());
        let sh = of(&findings, Check::StashHygiene);
        assert_eq!(sh.len(), 1, "{findings:?}");
        assert!(sh[0].message.contains("assert_service_clean"), "{:?}", sh[0]);
    }

    #[test]
    fn builtin_dump_is_deterministic_and_complete() {
        let table = protocol::builtin_table();
        let a = dump_protocol_markdown(&table);
        let b = dump_protocol_markdown(&table);
        assert_eq!(a, b);
        for spec in &table.specs {
            assert!(a.contains(spec.chare), "missing {}", spec.chare);
        }
        assert!(a.contains("| `EP_BUF_DATA` |") || a.contains("`EP_BUF_DATA`"));
    }
}
