//! Span store: the shared resident-data plane (PR 2, sharded in PR 3).
//!
//! Each data-plane shard ([`super::shard::DataShard`]) owns one
//! [`SpanStore`] with the view of *which bytes of which of its files are
//! resident in which buffer-chare array* — live arrays serving open
//! sessions and parked arrays kept after a `reuse_buffers` close alike.
//! (A file's claims always live on exactly one shard, so nothing here
//! needs a cross-shard view.) It replaces the PR 1 ad-hoc parked-buffer
//! FIFO and is what turns K independent sessions into one cooperating
//! data plane:
//!
//! * **Claims.** Every buffer chare's span is registered as a [`Claim`]
//!   when its session starts (and survives a park). A later session over
//!   overlapping bytes is pointed at the claim owner instead of the PFS:
//!   its buffer chares *peer-fetch* the overlapping splinter slots
//!   (`EP_BUF_PEER_FETCH`), which also dedups in-flight prefetch — if the
//!   owner's greedy read has not landed yet, the peer fetch queues and is
//!   served on arrival, so the bytes cross the PFS wire once.
//! * **Partial overlap.** Matching is per splinter slot, so a claim that
//!   only covers a prefix of a new session splits the serve: covered
//!   slots come from the resident array, the remainder goes to the PFS.
//! * **Byte budget + LRU.** Parked arrays are kept under a configurable
//!   byte budget ([`crate::ckio::ServiceConfig::store_budget_bytes`],
//!   split evenly across the active shards); eviction is
//!   least-recently-used.
//!   When no budget is set the store falls back to the PR 1 behavior of
//!   keeping at most [`SpanStore::DEFAULT_MAX_ARRAYS`] parked arrays
//!   (per shard).
//!
//! The store is a pure data structure (no `Ctx`): the owning shard
//! translates its eviction decisions into `EP_BUF_DROP` sends and its
//! match results into per-buffer peer lists, and charges the
//! `ckio.store.*` metrics.

use std::collections::HashMap;

use crate::amt::chare::{ChareRef, CollectionId};
use crate::pfs::layout::FileId;
use crate::util::bytes::ceil_div;

use super::options::ReaderPlacement;

/// Shape key for exact-match parked-array rebind: a new session rebinds a
/// parked array only if every property that shaped the array agrees —
/// including, since PR 5, the *effective placement* it was created
/// under (file policy or session override): a parked array physically
/// sits where its placement put it, so two sessions whose placements
/// differ must never silently inherit each other's layout.
/// (Partial-overlap serving does *not* need this — it goes through
/// claims, which only care about byte ranges.)
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct BufKey {
    pub file: FileId,
    pub offset: u64,
    pub bytes: u64,
    pub readers: u32,
    pub splinter: u64,
    pub window: u32,
    /// The effective [`ReaderPlacement`] of the session that shaped
    /// (or wants to rebind) the array.
    pub placement: ReaderPlacement,
}

/// One buffer chare's registered span: `[lo, hi)` of `file` is (or will
/// shortly be) resident in `owner`, which lives on `owner_pe` (buffer
/// chares are never migrated while holding data, so the PE recorded at
/// registration stays correct for the claim's whole life — including
/// across a park and rebind).
#[derive(Clone, Debug)]
pub struct Claim {
    pub lo: u64,
    pub hi: u64,
    pub owner: ChareRef,
    pub owner_pe: u32,
    /// The resident bytes are newer than the PFS copy (PR 10 write
    /// plane): the claim still serves peer fetches like any other, but
    /// the store must not let it drop without a writeback.
    pub dirty: bool,
}

/// Dominant resident source for one prospective buffer span — one entry
/// of the `PlacementPlan` a data-plane shard answers to the director's
/// `EP_SHARD_PLAN` probe (PR 4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlannedSource {
    /// PE of the claim owner covering the most bytes of the span: where
    /// store-aware placement puts the prospective buffer chare.
    pub pe: u32,
    /// Bytes of the span covered by *any* existing claim — the
    /// expectation the buffer revalidates at register time (an unclaim
    /// racing the plan shows up as actual coverage below this).
    pub covered: u64,
}

/// A parked buffer-chare array available for exact rebind, counted
/// against the byte budget.
#[derive(Clone, Debug)]
struct ParkedEntry {
    key: BufKey,
    buffers: CollectionId,
    nbuf: u32,
    resident_bytes: u64,
    last_use: u64,
}

impl ParkedEntry {
    fn evicted(&self, dirty_bytes: u64) -> Evicted {
        Evicted {
            buffers: self.buffers,
            nbuf: self.nbuf,
            resident_bytes: self.resident_bytes,
            file: self.key.file,
            dirty_bytes,
        }
    }
}

/// An array the store decided to release (budget eviction or file purge);
/// the director must `EP_BUF_DROP` every element.
#[derive(Clone, Debug)]
pub struct Evicted {
    pub buffers: CollectionId,
    pub nbuf: u32,
    pub resident_bytes: u64,
    pub file: FileId,
    /// Dirty claim bytes the array held at release time (PR 10): `> 0`
    /// means the release must force a writeback before the array drops.
    pub dirty_bytes: u64,
}

/// The resident-data plane bookkeeping (owned by the director).
#[derive(Debug, Default)]
pub struct SpanStore {
    claims: HashMap<FileId, Vec<Claim>>,
    parked: Vec<ParkedEntry>,
    /// Byte budget for parked arrays; `None` = PR 1 count-cap behavior.
    budget: Option<u64>,
    lru_clock: u64,
}

impl SpanStore {
    /// Parked arrays kept when no byte budget is configured (the PR 1
    /// default behavior).
    pub const DEFAULT_MAX_ARRAYS: usize = 8;

    pub fn new() -> SpanStore {
        SpanStore::default()
    }

    /// Configure the parked-array byte budget: the per-shard share of
    /// `ServiceConfig::store_budget_bytes`, applied once at boot
    /// (PR 5 — no runtime reconfiguration, no last-writer-wins).
    pub fn set_budget(&mut self, budget: u64) {
        self.budget = Some(budget);
    }

    // ------------------------------------------------------------------
    // claims
    // ------------------------------------------------------------------

    /// Register one buffer chare's span (`owner_pe` = the PE the owner
    /// runs on, recorded for store-aware placement planning; `dirty` =
    /// the span holds unwritten data, PR 10). Zero-length spans (clamped
    /// trailing buffers) are not registered.
    pub fn add_claim(
        &mut self,
        file: FileId,
        lo: u64,
        len: u64,
        owner: ChareRef,
        owner_pe: u32,
        dirty: bool,
    ) {
        if len == 0 {
            return;
        }
        self.claims
            .entry(file)
            .or_default()
            .push(Claim { lo, hi: lo + len, owner, owner_pe, dirty });
    }

    /// Mark one buffer chare's claims durable (its dirty bytes reached
    /// the PFS): the claims keep serving read-after-write peer fetches,
    /// but no longer owe a writeback. Returns the bytes cleaned.
    pub fn mark_clean(&mut self, file: FileId, owner: ChareRef) -> u64 {
        let mut cleaned = 0;
        if let Some(v) = self.claims.get_mut(&file) {
            for c in v.iter_mut().filter(|c| c.owner == owner && c.dirty) {
                c.dirty = false;
                cleaned += c.hi - c.lo;
            }
        }
        cleaned
    }

    /// Total dirty claim bytes across every file (the
    /// `ckio.store.dirty_bytes` gauge numerator and the quiescence
    /// check: a clean service has none).
    pub fn dirty_bytes(&self) -> u64 {
        self.claims
            .values()
            .flat_map(|v| v.iter())
            .filter(|c| c.dirty)
            .map(|c| c.hi - c.lo)
            .sum()
    }

    /// Dirty claim bytes owned by elements of `buffers` — computed
    /// before an eviction drops the claims, so the shard knows whether
    /// the release must detour through a writeback.
    fn dirty_bytes_of(&self, file: FileId, buffers: CollectionId) -> u64 {
        self.claims
            .get(&file)
            .map_or(&[][..], |v| &v[..])
            .iter()
            .filter(|c| c.dirty && c.owner.collection == buffers)
            .map(|c| c.hi - c.lo)
            .sum()
    }

    /// Drop every claim owned by elements of `buffers` (the array is
    /// being released and can no longer serve anyone).
    pub fn drop_claims(&mut self, file: FileId, buffers: CollectionId) {
        if let Some(v) = self.claims.get_mut(&file) {
            v.retain(|c| c.owner.collection != buffers);
            if v.is_empty() {
                self.claims.remove(&file);
            }
        }
    }

    /// Drop the claim of one buffer chare (PR 3: a dropping buffer
    /// unclaims *itself* at its shard, so the unclaim is ordered after
    /// the buffer's own registration — the director never has to race
    /// it). No-op if the owner never claimed.
    pub fn drop_claims_of(&mut self, file: FileId, owner: ChareRef) {
        if let Some(v) = self.claims.get_mut(&file) {
            v.retain(|c| c.owner != owner);
            if v.is_empty() {
                self.claims.remove(&file);
            }
        }
    }

    /// Find the claim fully covering `[lo, lo+len)` of `file`. The
    /// oldest covering claim wins, which keeps the peer-fetch graph
    /// acyclic: edges always point at earlier-registered arrays. A
    /// session can never match itself because the shard matches *before*
    /// registering the new session's own claims.
    pub fn find_cover_claim(&self, file: FileId, lo: u64, len: u64) -> Option<&Claim> {
        let hi = lo + len;
        self.claims.get(&file)?.iter().find(|c| c.lo <= lo && c.hi >= hi)
    }

    /// [`SpanStore::find_cover_claim`], returning just the owner.
    pub fn find_cover(&self, file: FileId, lo: u64, len: u64) -> Option<ChareRef> {
        self.find_cover_claim(file, lo, len).map(|c| c.owner)
    }

    /// Total claims registered for `file` (inspection).
    pub fn claims_for(&self, file: FileId) -> usize {
        self.claims.get(&file).map_or(0, |v| v.len())
    }

    /// Residency summary (PR 4): resident claim bytes of `file` per PE,
    /// sorted by PE. Overlapping claims count each copy (the summary
    /// answers "how much can each PE serve locally", not "how many
    /// distinct bytes exist"). Inspection/diagnostics API, like
    /// [`SpanStore::claims_for`] — the placement path itself uses the
    /// per-span [`SpanStore::plan_spans`], which this must stay
    /// consistent with (both walk the same claims).
    pub fn residency_by_pe(&self, file: FileId) -> Vec<(u32, u64)> {
        let mut per_pe: HashMap<u32, u64> = HashMap::new();
        for c in self.claims.get(&file).map_or(&[][..], |v| &v[..]) {
            *per_pe.entry(c.owner_pe).or_insert(0) += c.hi - c.lo;
        }
        let mut out: Vec<(u32, u64)> = per_pe.into_iter().collect();
        out.sort_unstable();
        out
    }

    /// The `PlacementPlan` for a prospective session partition (PR 4):
    /// for each of the `readers` buffer spans of a session
    /// `[offset, offset+bytes)` splintered at `splinter` (0 = whole-span
    /// slots; clamped per buffer exactly as
    /// [`super::buffer::BufferChare`] clamps it), the dominant resident
    /// source — the PE whose claims cover the most span bytes — plus the
    /// total covered bytes the buffer should re-find at register time.
    /// `None` for spans with no resident coverage (the placement
    /// fallback applies there).
    pub fn plan_spans(
        &self,
        file: FileId,
        offset: u64,
        bytes: u64,
        readers: u32,
        splinter: u64,
    ) -> Vec<Option<PlannedSource>> {
        (0..readers)
            .map(|b| {
                let (blo, blen) =
                    crate::ckio::session::buffer_span_of(offset, bytes, readers, b);
                if blen == 0 {
                    return None;
                }
                let mut per_pe: HashMap<u32, u64> = HashMap::new();
                let mut covered = 0u64;
                for (slo, slen) in slot_extents(blo, blen, splinter.min(blen)) {
                    if slen == 0 {
                        continue;
                    }
                    if let Some(c) = self.find_cover_claim(file, slo, slen) {
                        covered += slen;
                        *per_pe.entry(c.owner_pe).or_insert(0) += slen;
                    }
                }
                per_pe
                    .into_iter()
                    // Deterministic dominant source: most bytes, lowest
                    // PE on ties (HashMap iteration order must not leak
                    // into placement).
                    .max_by_key(|&(pe, b)| (b, std::cmp::Reverse(pe)))
                    .map(|(pe, _)| PlannedSource { pe, covered })
            })
            .collect()
    }

    // ------------------------------------------------------------------
    // parked arrays
    // ------------------------------------------------------------------

    /// Publish a fully parked array. Returns the arrays evicted to stay
    /// within budget (LRU order, freshly parked array last). An array
    /// that *alone* exceeds the byte budget is rejected outright — it is
    /// the sole eviction, and the already-parked (and possibly hot)
    /// arrays are left untouched rather than flushed to make room for
    /// something that can never fit.
    pub fn park(
        &mut self,
        key: BufKey,
        buffers: CollectionId,
        nbuf: u32,
        resident_bytes: u64,
    ) -> Vec<Evicted> {
        if let Some(b) = self.budget {
            if resident_bytes > b {
                let dirty_bytes = self.dirty_bytes_of(key.file, buffers);
                self.drop_claims(key.file, buffers);
                return vec![Evicted {
                    buffers,
                    nbuf,
                    resident_bytes,
                    file: key.file,
                    dirty_bytes,
                }];
            }
        }
        self.lru_clock += 1;
        self.parked.push(ParkedEntry {
            key,
            buffers,
            nbuf,
            resident_bytes,
            last_use: self.lru_clock,
        });
        let mut evicted = Vec::new();
        loop {
            let over = match self.budget {
                Some(b) => self.resident_bytes() > b,
                None => self.parked.len() > Self::DEFAULT_MAX_ARRAYS,
            };
            if !over || self.parked.is_empty() {
                break;
            }
            let lru = self
                .parked
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.last_use)
                .map(|(i, _)| i)
                .unwrap();
            let e = self.parked.remove(lru);
            let dirty_bytes = self.dirty_bytes_of(e.key.file, e.buffers);
            self.drop_claims(e.key.file, e.buffers);
            evicted.push(e.evicted(dirty_bytes));
        }
        evicted
    }

    /// Take an exactly matching parked array for rebind (claims stay: the
    /// array is live again under a new session; it re-enters the LRU
    /// order when it is parked again).
    pub fn take_exact(&mut self, key: &BufKey) -> Option<(CollectionId, u32)> {
        let pos = self.parked.iter().position(|e| e.key == *key)?;
        let e = self.parked.remove(pos);
        Some((e.buffers, e.nbuf))
    }

    /// Refresh a parked array's LRU recency: called by the director when
    /// claim matching points a new session at `buffers` — an array that
    /// keeps serving peer fetches is hot and must not be the eviction
    /// victim. No-op for live (non-parked) arrays.
    pub fn touch(&mut self, buffers: CollectionId) {
        if let Some(e) = self.parked.iter_mut().find(|e| e.buffers == buffers) {
            self.lru_clock += 1;
            e.last_use = self.lru_clock;
        }
    }

    /// Release every parked array of a closed file (they can never be
    /// rebound or peer-fetched again) along with the file's claims.
    pub fn purge_file(&mut self, file: FileId) -> Vec<Evicted> {
        let (gone, kept): (Vec<_>, Vec<_>) =
            std::mem::take(&mut self.parked).into_iter().partition(|e| e.key.file == file);
        self.parked = kept;
        let out = gone
            .into_iter()
            .map(|e| {
                let dirty_bytes = self.dirty_bytes_of(file, e.buffers);
                e.evicted(dirty_bytes)
            })
            .collect();
        self.claims.remove(&file);
        out
    }

    /// Bytes resident across parked arrays (the budget numerator and the
    /// `ckio.store.resident_bytes` gauge).
    pub fn resident_bytes(&self) -> u64 {
        self.parked.iter().map(|e| e.resident_bytes).sum()
    }

    /// Parked array count (inspection / tests).
    pub fn parked_count(&self) -> usize {
        self.parked.len()
    }
}

/// The splinter-slot extents of a buffer span `[offset, offset+len)`:
/// exactly the slots [`crate::ckio::buffer::BufferChare`] reads, so the
/// director's claim matching and the buffer's storage agree bit-for-bit.
/// `splinter == 0` means one slot covering the whole span.
pub fn slot_extents(offset: u64, len: u64, splinter: u64) -> Vec<(u64, u64)> {
    if splinter == 0 || len == 0 {
        return vec![(offset, len)];
    }
    let n = ceil_div(len, splinter);
    (0..n)
        .map(|i| {
            let lo = offset + i * splinter;
            let hi = (lo + splinter).min(offset + len);
            (lo, hi - lo)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(file: u32, offset: u64, bytes: u64) -> BufKey {
        BufKey {
            file: FileId(file),
            offset,
            bytes,
            readers: 2,
            splinter: 0,
            window: 2,
            placement: ReaderPlacement::default(),
        }
    }

    fn owner(cid: u32, i: u32) -> ChareRef {
        ChareRef::new(CollectionId(cid), i)
    }

    /// Test claims place every owner on PE 0 unless the test is about
    /// the per-PE accounting.
    const PE: u32 = 0;

    #[test]
    fn cover_matching_prefers_oldest_covering_claim() {
        let mut s = SpanStore::new();
        s.add_claim(FileId(0), 0, 100, owner(1, 0), PE, false);
        s.add_claim(FileId(0), 50, 100, owner(2, 0), PE, false);
        // Fully inside the first claim: oldest wins.
        assert_eq!(s.find_cover(FileId(0), 10, 20), Some(owner(1, 0)));
        // Only the second claim covers [120, 140).
        assert_eq!(s.find_cover(FileId(0), 120, 20), Some(owner(2, 0)));
        // Straddling both claims but covered by neither alone: no match
        // (slot-level matching keeps serving simple and single-source).
        assert_eq!(s.find_cover(FileId(0), 40, 80), None);
        // Different file: no match.
        assert_eq!(s.find_cover(FileId(1), 10, 20), None);
    }

    #[test]
    fn zero_length_claims_are_not_registered() {
        let mut s = SpanStore::new();
        s.add_claim(FileId(0), 10, 0, owner(1, 3), PE, false);
        assert_eq!(s.claims_for(FileId(0)), 0);
    }

    #[test]
    fn drop_claims_only_touches_the_named_array() {
        let mut s = SpanStore::new();
        s.add_claim(FileId(0), 0, 10, owner(1, 0), PE, false);
        s.add_claim(FileId(0), 10, 10, owner(2, 0), PE, false);
        s.drop_claims(FileId(0), CollectionId(1));
        assert_eq!(s.claims_for(FileId(0)), 1);
        assert_eq!(s.find_cover(FileId(0), 12, 2), Some(owner(2, 0)));
    }

    #[test]
    fn drop_claims_of_only_touches_the_named_element() {
        let mut s = SpanStore::new();
        s.add_claim(FileId(0), 0, 10, owner(1, 0), PE, false);
        s.add_claim(FileId(0), 10, 10, owner(1, 1), PE, false);
        s.drop_claims_of(FileId(0), owner(1, 0));
        assert_eq!(s.claims_for(FileId(0)), 1);
        assert_eq!(s.find_cover(FileId(0), 12, 2), Some(owner(1, 1)));
        // Unknown owner / already-dropped claim: no-op.
        s.drop_claims_of(FileId(0), owner(1, 0));
        s.drop_claims_of(FileId(9), owner(1, 1));
        assert_eq!(s.claims_for(FileId(0)), 1);
        s.drop_claims_of(FileId(0), owner(1, 1));
        assert_eq!(s.claims_for(FileId(0)), 0);
    }

    #[test]
    fn count_cap_without_budget_matches_pr1_default() {
        let mut s = SpanStore::new();
        let mut evicted = Vec::new();
        for i in 0..(SpanStore::DEFAULT_MAX_ARRAYS as u32 + 2) {
            evicted.extend(s.park(key(0, i as u64 * 100, 100), CollectionId(10 + i), 2, 100));
        }
        assert_eq!(s.parked_count(), SpanStore::DEFAULT_MAX_ARRAYS);
        assert_eq!(evicted.len(), 2);
        // FIFO == LRU when nothing is ever re-used.
        assert_eq!(evicted[0].buffers, CollectionId(10));
        assert_eq!(evicted[1].buffers, CollectionId(11));
    }

    #[test]
    fn byte_budget_evicts_lru_first() {
        let mut s = SpanStore::new();
        s.set_budget(250);
        assert!(s.park(key(0, 0, 100), CollectionId(1), 2, 100).is_empty());
        assert!(s.park(key(0, 100, 100), CollectionId(2), 2, 100).is_empty());
        // Rebind entry 1: bumps its recency out of LRU position...
        assert_eq!(s.take_exact(&key(0, 0, 100)), Some((CollectionId(1), 2)));
        assert!(s.park(key(0, 0, 100), CollectionId(1), 2, 100).is_empty());
        assert_eq!(s.resident_bytes(), 200);
        // ...so the third park evicts entry 2, the least recently used.
        let ev = s.park(key(0, 200, 100), CollectionId(3), 2, 100);
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].buffers, CollectionId(2));
        assert_eq!(s.resident_bytes(), 200);
    }

    #[test]
    fn touch_refreshes_parked_recency() {
        let mut s = SpanStore::new();
        s.set_budget(250);
        assert!(s.park(key(0, 0, 100), CollectionId(1), 2, 100).is_empty());
        assert!(s.park(key(0, 100, 100), CollectionId(2), 2, 100).is_empty());
        // Array 1 serves a peer match: it is hot now.
        s.touch(CollectionId(1));
        s.touch(CollectionId(99)); // unknown collection: no-op
        // The next park evicts the cold array 2, not the hot array 1.
        let ev = s.park(key(0, 200, 100), CollectionId(3), 2, 100);
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].buffers, CollectionId(2));
    }

    #[test]
    fn oversized_single_array_is_evicted_immediately() {
        let mut s = SpanStore::new();
        s.set_budget(50);
        let ev = s.park(key(0, 0, 100), CollectionId(1), 2, 100);
        assert_eq!(ev.len(), 1);
        assert_eq!(s.parked_count(), 0);
        assert_eq!(s.resident_bytes(), 0);
    }

    #[test]
    fn oversized_newcomer_does_not_flush_resident_arrays() {
        let mut s = SpanStore::new();
        s.set_budget(300);
        // Three warm arrays, comfortably within budget.
        assert!(s.park(key(0, 0, 100), CollectionId(1), 1, 100).is_empty());
        assert!(s.park(key(0, 100, 100), CollectionId(2), 1, 100).is_empty());
        assert!(s.park(key(0, 200, 100), CollectionId(3), 1, 100).is_empty());
        s.add_claim(FileId(0), 400, 100, owner(4, 0), PE, false);
        // An array that can never fit is rejected alone — the resident
        // arrays survive, and the reject drops the newcomer's claims.
        let ev = s.park(key(0, 400, 500), CollectionId(4), 1, 500);
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].buffers, CollectionId(4));
        assert_eq!(s.parked_count(), 3);
        assert_eq!(s.resident_bytes(), 300);
        assert_eq!(s.find_cover(FileId(0), 420, 10), None);
    }

    #[test]
    fn eviction_and_purge_drop_the_arrays_claims() {
        let mut s = SpanStore::new();
        s.set_budget(100);
        s.add_claim(FileId(0), 0, 100, owner(1, 0), PE, false);
        s.add_claim(FileId(0), 100, 100, owner(2, 0), PE, false);
        assert!(s.park(key(0, 0, 100), CollectionId(1), 1, 100).is_empty());
        // Parking array 2 evicts array 1 (LRU) and its claims with it.
        let ev = s.park(key(0, 100, 100), CollectionId(2), 1, 100);
        assert_eq!(ev.len(), 1);
        assert_eq!(s.find_cover(FileId(0), 10, 10), None);
        assert_eq!(s.find_cover(FileId(0), 110, 10), Some(owner(2, 0)));
        // Purging the file releases the survivor and every claim.
        let purged = s.purge_file(FileId(0));
        assert_eq!(purged.len(), 1);
        assert_eq!(s.claims_for(FileId(0)), 0);
        assert_eq!(s.resident_bytes(), 0);
    }

    #[test]
    fn mark_clean_clears_only_the_named_owners_dirty_bytes() {
        let mut s = SpanStore::new();
        s.add_claim(FileId(0), 0, 100, owner(1, 0), PE, true);
        s.add_claim(FileId(0), 100, 50, owner(1, 1), PE, true);
        s.add_claim(FileId(0), 150, 50, owner(2, 0), PE, false);
        assert_eq!(s.dirty_bytes(), 150);
        assert_eq!(s.mark_clean(FileId(0), owner(1, 0)), 100);
        assert_eq!(s.dirty_bytes(), 50);
        // The cleaned claim still serves cover matching.
        assert_eq!(s.find_cover(FileId(0), 10, 20), Some(owner(1, 0)));
        // Re-cleaning (or cleaning a never-dirty owner) is a no-op.
        assert_eq!(s.mark_clean(FileId(0), owner(1, 0)), 0);
        assert_eq!(s.mark_clean(FileId(0), owner(2, 0)), 0);
        assert_eq!(s.mark_clean(FileId(9), owner(1, 1)), 0);
        assert_eq!(s.dirty_bytes(), 50);
    }

    #[test]
    fn eviction_reports_dirty_bytes_of_the_released_array() {
        let mut s = SpanStore::new();
        s.set_budget(100);
        s.add_claim(FileId(0), 0, 60, owner(1, 0), PE, true);
        s.add_claim(FileId(0), 60, 40, owner(1, 1), PE, false);
        s.add_claim(FileId(0), 100, 100, owner(2, 0), PE, false);
        assert!(s.park(key(0, 0, 100), CollectionId(1), 2, 100).is_empty());
        // Parking the clean array 2 evicts the dirty array 1 (LRU): the
        // eviction carries the dirty byte count so the shard can force
        // the writeback before the drop.
        let ev = s.park(key(0, 100, 100), CollectionId(2), 1, 100);
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].buffers, CollectionId(1));
        assert_eq!(ev[0].dirty_bytes, 60);
        assert_eq!(s.dirty_bytes(), 0, "evicted claims leave the dirty total");
        // Purging a file with a dirty parked array reports it too.
        s.add_claim(FileId(0), 100, 100, owner(2, 0), PE, true);
        let purged = s.purge_file(FileId(0));
        assert_eq!(purged.len(), 1);
        assert_eq!(purged[0].dirty_bytes, 100);
        assert_eq!(s.dirty_bytes(), 0);
    }

    #[test]
    fn take_exact_requires_full_shape_agreement() {
        let mut s = SpanStore::new();
        s.park(key(0, 0, 100), CollectionId(1), 2, 100);
        let mut other = key(0, 0, 100);
        other.readers = 4;
        assert_eq!(s.take_exact(&other), None);
        // The effective placement is part of the shape (PR 5): an array
        // parked under one placement never rebinds under another.
        let mut placed = key(0, 0, 100);
        placed.placement = ReaderPlacement::Explicit(vec![5, 5]);
        assert_eq!(s.take_exact(&placed), None);
        assert_eq!(s.take_exact(&key(0, 0, 100)), Some((CollectionId(1), 2)));
        assert_eq!(s.take_exact(&key(0, 0, 100)), None, "taken arrays leave the store");
    }

    #[test]
    fn residency_by_pe_sums_claim_extents() {
        let mut s = SpanStore::new();
        s.add_claim(FileId(0), 0, 100, owner(1, 0), 3, false);
        s.add_claim(FileId(0), 100, 50, owner(1, 1), 5, false);
        s.add_claim(FileId(0), 150, 50, owner(1, 2), 3, false);
        assert_eq!(s.residency_by_pe(FileId(0)), vec![(3, 150), (5, 50)]);
        assert!(s.residency_by_pe(FileId(1)).is_empty());
    }

    #[test]
    fn plan_spans_names_the_dominant_source_per_span() {
        let mut s = SpanStore::new();
        // Claims: [0, 100) held on PE 1, [100, 200) held on PE 2.
        s.add_claim(FileId(0), 0, 100, owner(1, 0), 1, false);
        s.add_claim(FileId(0), 100, 100, owner(1, 1), 2, false);
        // Prospective session [50, 150), 2 readers, splinter 25: span 0
        // ([50, 100)) is all PE 1, span 1 ([100, 150)) all PE 2.
        let plan = s.plan_spans(FileId(0), 50, 100, 2, 25);
        assert_eq!(plan, vec![
            Some(PlannedSource { pe: 1, covered: 50 }),
            Some(PlannedSource { pe: 2, covered: 50 }),
        ]);
        // The same range as ONE whole-span slot straddles both claims:
        // neither covers it alone, so there is no source.
        assert_eq!(s.plan_spans(FileId(0), 50, 100, 1, 0), vec![None]);
        // Splintered, that span is covered half-and-half: the dominant
        // source breaks the byte tie toward the lower PE, and `covered`
        // still counts every covered slot (the revalidation total).
        assert_eq!(
            s.plan_spans(FileId(0), 50, 100, 1, 25),
            vec![Some(PlannedSource { pe: 1, covered: 100 })]
        );
        // No claims at all: every span is fallback-placed.
        assert!(s.plan_spans(FileId(9), 0, 10, 4, 0).iter().all(|p| p.is_none()));
    }

    #[test]
    fn slot_extents_match_buffer_layout() {
        assert_eq!(slot_extents(1000, 100, 0), vec![(1000, 100)]);
        assert_eq!(
            slot_extents(1000, 100, 30),
            vec![(1000, 30), (1030, 30), (1060, 30), (1090, 10)]
        );
        assert_eq!(slot_extents(5, 0, 30), vec![(5, 0)]);
    }
}
