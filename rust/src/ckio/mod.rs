//! CkIO: the paper's parallel-input library.
//!
//! A two-phase, split-phase input scheme for over-decomposed task-based
//! systems (paper §III). The decomposition of *file readers* is separated
//! from the decomposition of *consumers*: a per-session array of **buffer
//! chares** greedily prefetches the session's byte range from the file
//! system, and client reads are served out of those buffers over the
//! (much faster) interconnect.
//!
//! Components, mirroring the paper's architecture (§III-C, Fig. 5):
//!
//! * [`director`] — singleton coordinating opens, session lifecycle and
//!   global sequencing,
//! * [`manager`] — a chare group (one per PE): the local API entry point;
//!   keeps the session table and assigns zero-copy tags,
//! * [`assembler`] — the ReadAssembler group: gathers the pieces of each
//!   client read from the responsible buffer chares and triggers the
//!   client's continuation,
//! * [`buffer`] — the buffer-chare array: interacts with the file system,
//!   one disjoint span each, reading asynchronously (helper threads in
//!   real mode; split-phase model reads in virtual mode),
//! * [`api`] — the user-facing `open / startReadSession / read /
//!   closeReadSession / close` calls (asynchronous-callback-centric,
//!   §III-D),
//! * [`options`] — reader count/placement/splintering knobs (§III-C.4,
//!   §VI.A–C),
//! * [`session`] — session and read-descriptor types.

pub mod api;
pub mod assembler;
pub mod buffer;
pub mod director;
pub mod manager;
pub mod options;
pub mod session;

pub use api::CkIo;
pub use options::{Options, ReaderPlacement};
pub use session::{FileHandle, ReadResult, Session, SessionId};
