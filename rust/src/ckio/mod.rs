//! CkIO: the paper's parallel-input library.
//!
//! A two-phase, split-phase input scheme for over-decomposed task-based
//! systems (paper §III). The decomposition of *file readers* is separated
//! from the decomposition of *consumers*: a per-session array of **buffer
//! chares** greedily prefetches the session's byte range from the file
//! system, and client reads are served out of those buffers over the
//! (much faster) interconnect.
//!
//! Components, mirroring the paper's architecture (§III-C, Fig. 5):
//!
//! * [`director`] — singleton coordinating opens, session lifecycle and
//!   teardown sequencing (since PR 3 a *thin* coordinator: the data
//!   plane lives on the shards),
//! * [`shard`] — the data-plane shard array (PR 3): each element owns
//!   the span store and admission governor for the `FileId`s that hash
//!   to it, so hot-path coordination scales with the shard count
//!   instead of serializing on the director,
//! * [`manager`] — a chare group (one per PE): the local API entry point;
//!   keeps the session table and assigns zero-copy tags,
//! * [`assembler`] — the ReadAssembler group: gathers the pieces of each
//!   client read from the responsible buffer chares and triggers the
//!   client's continuation,
//! * [`buffer`] — the buffer-chare array: interacts with the file system,
//!   one disjoint span each, reading asynchronously (helper threads in
//!   real mode; split-phase model reads in virtual mode) — and, since
//!   PR 2, serving *peer* buffer chares from its resident data,
//! * [`store`] — the span store (PR 2): the shared resident-data plane —
//!   which bytes of which file live in which array, byte-budgeted LRU
//!   over parked arrays, claim matching for partial-overlap serving and
//!   same-file prefetch dedup,
//! * [`governor`] — the admission governor (PR 2): the per-shard cap on
//!   PFS reads in flight, sequencing sessions' prefetch so they stop
//!   oversubscribing the OSTs; since PR 3 the cap can also be *derived*
//!   adaptively from observed service times (AIMD),
//! * [`write`] — the collective output plane (PR 10): write sessions
//!   (`startWriteSession / write / flush / closeWriteSession`), per-PE
//!   [`write::WriteAssembler`] routing, stripe-aligned write-behind
//!   [`write::WriteBuffer`] chares, and read-after-write residency via
//!   *dirty* store claims (a following read session over freshly
//!   written bytes is served from residency with zero PFS reads),
//! * [`api`] — the user-facing `open / startReadSession / read /
//!   closeReadSession / close` calls (asynchronous-callback-centric,
//!   §III-D),
//! * [`options`] — configuration in three explicit scopes (PR 5):
//!   [`ServiceConfig`] (store budget, shard count, admission —
//!   consumed once by `CkIo::boot_with`), [`FileOptions`] (reader
//!   count, placement — consumed by `open`), and [`SessionOptions`]
//!   ([`QosClass`], splintering, window, reuse, placement override —
//!   consumed by `start_read_session`),
//! * [`session`] — session, tag and read-descriptor types.
//!
//! # Per-session QoS classes (PR 5)
//!
//! Every session declares *who it is*: a [`QosClass`]
//! (`Interactive` / `Bulk` / `Scavenger`, integer-weighted 8 : 2 : 1)
//! carried by its [`SessionOptions`]. The class is negotiated with the
//! file's data-plane shard **before any buffer exists** — it rides the
//! PR 4 plan-then-create probe (`EP_SHARD_PLAN`) when placement is
//! store-aware, and a lightweight `EP_SHARD_ADMIT` register on the same
//! path otherwise — and every admission ticket the session's buffer
//! chares request carries it. Under a saturated admission cap the
//! governor dequeues deferred demand by **weighted deficit round-robin**
//! across the per-class queues (strict priority available via
//! [`AdmissionPolicy::StrictPriority`]), so Interactive sessions drain
//! first while Scavenger work is never starved. Admitted tickets are
//! counted per class on `ckio.governor.class_granted.*`, and the
//! `svc_qos` experiment shows Interactive p50 session makespan beating
//! the classless baseline under contention while Bulk still completes.
//!
//! # The resident-data plane (PR 2, sharded by `FileId` in PR 3)
//!
//! The paper's core claim — separating consumers from readers lets the
//! I/O layer be tuned globally — is realized here beyond a single
//! session. Every buffer chare's byte-span is tracked as a *claim* in a
//! [`store::SpanStore`], across live sessions and parked (reused) arrays
//! alike. Since PR 3 that store (and the admission governor) is
//! partitioned over the [`shard::DataShard`] array by `FileId` hash —
//! a file's whole data-plane state lives on exactly one shard, so
//! same-file cooperation never crosses shards while distinct files
//! scale out:
//!
//! * **Same-file prefetch dedup.** A starting buffer chare registers its
//!   span with its file's shard; when an existing array already claims
//!   some of its splinter slots, the shard's reply points those slots at
//!   the claim owners and the chare *peer-fetches* them
//!   (`EP_BUF_PEER_FETCH`) instead of issuing PFS reads. If the owner's
//!   greedy read is still in flight, the peer fetch queues and is served
//!   on arrival — K concurrent sessions over one file pull its bytes
//!   across the PFS wire approximately once (the `svc_shared` experiment
//!   measures this).
//! * **Partial overlap.** Matching is per splinter slot, so a parked
//!   array covering only part of a new session splits the serve:
//!   resident slots come from the store, the rest from the PFS. A
//!   dropped peer answers with a *miss* and the requester falls back to
//!   its own PFS read — correctness never depends on the cache.
//! * **Byte-budgeted LRU.** Parked arrays are kept under
//!   [`ServiceConfig::store_budget_bytes`] — split evenly across the active
//!   shards — with LRU eviction (default: the PR 1 count cap of 8
//!   arrays per shard).
//! * **Admission control.** With [`ServiceConfig::max_inflight_reads`]
//!   (or the PR 3 [`ServiceConfig::adaptive_admission`] feedback mode,
//!   which derives the cap from observed service times by AIMD), buffer
//!   chares route PFS issuance through their shard's
//!   [`governor::Governor`]: reads in flight are capped per shard
//!   across all sessions (a service booted without either knob is
//!   ungoverned), and queued demand drains weighted-fair across
//!   [`QosClass`]es by [`governor::AdmissionPolicy`].
//!
//! * **Store-aware reader placement (PR 4).** Session start is
//!   *plan-then-create*: before materializing a
//!   [`ReaderPlacement::StoreAware`] session's buffer array, the
//!   director probes the owning shard (`EP_SHARD_PLAN`) for a
//!   `PlacementPlan` — per prospective buffer span, the PE whose claims
//!   cover the most bytes — and creates each buffer chare *on the PE of
//!   its dominant peer source*, turning the peer fetches above into
//!   same-PE copies (the Fig. 12 locality win applied at creation time
//!   instead of by migration). Buffers with no resident coverage use
//!   the configured fallback placement; registration revalidates the
//!   plan snapshot, so claims retracted between plan and create degrade
//!   to ordinary PFS reads (`ckio.place.degraded`), never to an error.
//!   The `svc_locality` experiment measures the effect: K successive
//!   overlapping sessions under `StoreAware` collapse
//!   `ckio.place.cross_pe_fetch` toward zero vs `SpreadNodes`.
//!
//! Store traffic is observable via `ckio.store.hit_bytes` /
//! `miss_bytes` / `evicted_bytes`, the `ckio.store.resident_bytes`
//! gauge (summed across shards), `ckio.governor.throttled`, the
//! `ckio.governor.cap` gauge, the per-shard message-count imbalance
//! pair `ckio.shard.msgs_max` / `ckio.shard.msgs_mean`, and the
//! placement-locality set `ckio.place.planned` / `same_pe_fetch` /
//! `cross_pe_fetch` / `degraded` (all in `ckio bench-json`). Since PR 7
//! latency *distributions* (session makespan, per-class admission wait,
//! PFS service, assembly, peer fetch) are recorded in mergeable
//! histograms, and [`ServiceConfig::trace`] turns on the flight
//! recorder ([`crate::trace`]) — structured spans over the same
//! lifecycle, exportable as a Perfetto-loadable timeline via
//! `ckio trace <fig>`. See `docs/OBSERVABILITY.md` for the catalog.
//!
//! # Concurrency semantics (PR 1)
//!
//! Any number of read sessions — over the same file or distinct files —
//! may be open, reading, and closing concurrently:
//!
//! * **Tag namespacing.** Every client read travels under a
//!   [`session::Tag`] = `(SessionId, PE-salted counter)`. The session id
//!   is part of the assemblers' table key, so concurrent sessions can
//!   never collide on a tag, and a late piece is always attributable to
//!   its (possibly closed) session.
//! * **Refcounted opens.** Concurrent `open`s of one file share a single
//!   MDS transaction and manager broadcast; later opens are answered from
//!   the director's file table. The *first* opener's [`FileOptions`]
//!   govern the file while it stays open — a re-open with *equal*
//!   options is idempotent (the delivered `FileHandle` carries the
//!   options in effect), and a re-open with *different* options fails
//!   with [`OpenError::OptionsConflict`] (PR 5), never a silent ignore.
//!   Each `close` decrements; only the last tears the file down
//!   everywhere.
//! * **Teardown protocol.** `closeReadSession` *drains*: buffer chares
//!   answer every queued fetch exactly once (resident extents with data,
//!   the rest with modeled NACK chunks) before acking; a fetch that was
//!   in flight when the drop landed is flush-served the same way;
//!   managers NACK reads that arrive after the session entry dropped;
//!   assemblers are told the session closed so duplicate late pieces are
//!   tolerated; queued *peer* fetches are answered with data or a miss
//!   (the peer re-reads from the PFS). Net effect: every outstanding
//!   `read` callback fires exactly once, no assembly outlives its
//!   session, and no buffer chare waits forever on a dead peer. Closing
//!   an already-closed session acks immediately (idempotent).
//! * **Reuse policy.** With [`SessionOptions::reuse_buffers`], closing *parks*
//!   the session's buffer array (resident data kept) in the span store
//!   keyed by `(file, range, reader shape)`; a later identical session
//!   rebinds the array and is served with no file-system traffic, and
//!   *overlapping* sessions of any shape peer-fetch from it. Parked
//!   arrays are released when evicted (budget/LRU) or when their file is
//!   finally closed.

pub mod api;
pub mod assembler;
pub mod buffer;
pub mod director;
pub mod governor;
pub mod manager;
pub mod options;
pub mod session;
pub mod shard;
pub mod store;
pub mod write;

pub use api::CkIo;
pub use governor::{AdmissionPolicy, QosClass};
pub use options::{
    ConfigError, ConsumerPlacement, FileOptions, OpenError, ReaderPlacement, RetryPolicy,
    ServiceConfig, SessionOptions, TraceConfig, WriteOptions,
};
pub use session::{FileHandle, ReadResult, Session, SessionId, SessionOutcome, Tag};
pub use shard::DataShard;
pub use store::SpanStore;
pub use write::{WriteAssembler, WriteBuffer, WriteResult};
