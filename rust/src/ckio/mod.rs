//! CkIO: the paper's parallel-input library.
//!
//! A two-phase, split-phase input scheme for over-decomposed task-based
//! systems (paper §III). The decomposition of *file readers* is separated
//! from the decomposition of *consumers*: a per-session array of **buffer
//! chares** greedily prefetches the session's byte range from the file
//! system, and client reads are served out of those buffers over the
//! (much faster) interconnect.
//!
//! Components, mirroring the paper's architecture (§III-C, Fig. 5):
//!
//! * [`director`] — singleton coordinating opens, session lifecycle and
//!   global sequencing,
//! * [`manager`] — a chare group (one per PE): the local API entry point;
//!   keeps the session table and assigns zero-copy tags,
//! * [`assembler`] — the ReadAssembler group: gathers the pieces of each
//!   client read from the responsible buffer chares and triggers the
//!   client's continuation,
//! * [`buffer`] — the buffer-chare array: interacts with the file system,
//!   one disjoint span each, reading asynchronously (helper threads in
//!   real mode; split-phase model reads in virtual mode),
//! * [`api`] — the user-facing `open / startReadSession / read /
//!   closeReadSession / close` calls (asynchronous-callback-centric,
//!   §III-D),
//! * [`options`] — reader count/placement/splintering/reuse knobs
//!   (§III-C.4, §VI.A–C),
//! * [`session`] — session, tag and read-descriptor types.
//!
//! # Concurrency semantics (PR 1)
//!
//! Any number of read sessions — over the same file or distinct files —
//! may be open, reading, and closing concurrently:
//!
//! * **Tag namespacing.** Every client read travels under a
//!   [`session::Tag`] = `(SessionId, PE-salted counter)`. The session id
//!   is part of the assemblers' table key, so concurrent sessions can
//!   never collide on a tag, and a late piece is always attributable to
//!   its (possibly closed) session.
//! * **Refcounted opens.** Concurrent `open`s of one file share a single
//!   MDS transaction and manager broadcast; later opens are answered from
//!   the director's file table. The *first* opener's [`Options`] govern
//!   the file while it stays open (later opens' options are ignored; the
//!   delivered `FileHandle` carries the options in effect). Each `close`
//!   decrements; only the last tears the file down everywhere.
//! * **Teardown protocol.** `closeReadSession` *drains*: buffer chares
//!   answer every queued fetch exactly once (resident extents with data,
//!   the rest with modeled NACK chunks) before acking; a fetch that was
//!   in flight when the drop landed is flush-served the same way;
//!   managers NACK reads that arrive after the session entry dropped;
//!   assemblers are told the session closed so duplicate late pieces are
//!   tolerated. Net effect: every outstanding `read` callback fires
//!   exactly once, and no `assemblies`/`pending` entry outlives its
//!   session. Closing an already-closed session acks immediately
//!   (idempotent).
//! * **Reuse policy.** With [`Options::reuse_buffers`], closing *parks*
//!   the session's buffer array (resident data kept) in a small FIFO
//!   cache keyed by `(file, range, reader shape)`; a later identical
//!   session rebinds the array and is served with no file-system
//!   traffic. Parked arrays are released when evicted (FIFO, small cap)
//!   or when their file is finally closed.

pub mod api;
pub mod assembler;
pub mod buffer;
pub mod director;
pub mod manager;
pub mod options;
pub mod session;

pub use api::CkIo;
pub use options::{Options, ReaderPlacement};
pub use session::{FileHandle, ReadResult, Session, SessionId, Tag};
