//! Admission governor: PFS read-admission control (PR 2, sharded and
//! made adaptive in PR 3, class-weighted in PR 5).
//!
//! Since PR 3 each data-plane shard ([`super::shard::DataShard`]) owns
//! one [`Governor`] covering the files that hash to it. When the
//! service is booted with
//! [`crate::ckio::ServiceConfig::max_inflight_reads`] set (or with
//! [`crate::ckio::ServiceConfig::adaptive_admission`]), buffer chares
//! stop issuing PFS reads directly: they request *tickets* from their
//! file's shard (`EP_SHARD_IO_REQ`), issue exactly the granted count,
//! and return each ticket on read completion (`EP_SHARD_IO_DONE`,
//! carrying the observed service time). The governor caps the number of
//! PFS reads in flight across all sessions of its shard's files, so K
//! concurrent sessions can no longer oversubscribe the OSTs — excess
//! demand queues here instead of interleaving at the disks (the Fig. 1
//! collapse). Same-file sessions always share one shard, hence one cap;
//! files on different shards admit independently (aggregate worst case
//! `cap × active shards`).
//!
//! Scope (PR 5): admission control is **service configuration** — one
//! [`crate::ckio::ServiceConfig`] passed to `CkIo::boot_with` configures
//! every shard once, before any message flows. The PR 2–4 per-file knob
//! ("first opener's cap governs, last writer wins per shard") is gone;
//! a service is either governed or it is not.
//!
//! # QoS classes (PR 5)
//!
//! Every session carries a [`QosClass`]
//! ([`crate::ckio::SessionOptions::class`]):
//!
//! * [`QosClass::Interactive`] — latency-sensitive foreground work
//!   (weight [`QosClass::Interactive`]`.weight()` = 8),
//! * [`QosClass::Bulk`] — ordinary throughput work, the default
//!   (weight 2),
//! * [`QosClass::Scavenger`] — background/best-effort work (weight 1).
//!
//! Queued demand lives in one FIFO per class and is released by
//! **weighted deficit round-robin** (WDRR): the rotation visits each
//! backlogged class in turn, refilling its deficit with the class
//! weight and granting up to that many tickets before moving on. Under
//! a saturated cap the grant rates converge to the weight ratios
//! (8 : 2 : 1), and the scheme is starvation-free by construction —
//! every backlogged class is visited once per rotation and a weight is
//! never zero, so every queued ticket is eventually granted.
//!
//! The [`AdmissionPolicy`] picks the intra-/inter-class order:
//!
//! * [`AdmissionPolicy::Fifo`] — WDRR across classes, arrival order
//!   within a class (with a single active class this is exactly the
//!   PR 2 FIFO),
//! * [`AdmissionPolicy::SmallestFirst`] — WDRR across classes, sessions
//!   with fewer total bytes first within a class (the classic
//!   shortest-job-first trade),
//! * [`AdmissionPolicy::StrictPriority`] — strict `Interactive` >
//!   `Bulk` > `Scavenger`, FIFO within a class. **Not** starvation-free:
//!   a saturating Interactive load parks Scavenger forever; that is the
//!   explicit opt-in trade this policy exists for.
//!
//! # Feedback control (PR 3)
//!
//! With `adaptive_admission` and no static cap, the cap is *derived*
//! from the service times buffers observe on their completed reads
//! (issue → completion, which tracks the PFS model's OST busy time plus
//! queueing). Classic AIMD over windows of [`Governor::ADAPT_WINDOW`]
//! completions:
//!
//! * while the window's p50 stays within [`Governor::INFLATE_TOLERANCE`]
//!   of the best p50 seen, the OSTs are keeping up — **additive
//!   increase** (`cap += 1`),
//! * when the p50 inflates past it, admitted reads are queueing at the
//!   disks — **multiplicative decrease** (`cap /= 2`, floor 1). The
//!   remembered best is relaxed slightly on each decrease so a
//!   permanently slower PFS (or a stale floor) cannot pin the cap at 1.
//!
//! AIMD adapts the *cap*; grants are always dequeued by class weight,
//! whatever the cap currently is.
//!
//! Like the span store, the governor is a pure data structure: the shard
//! translates grants into `EP_BUF_GRANT` sends, charges
//! `ckio.governor.throttled` for every deferred read, publishes the
//! adapted cap on the `ckio.governor.cap` gauge, and counts admitted
//! tickets per class on `ckio.governor.class_granted.*`.

use std::collections::VecDeque;

use crate::amt::chare::ChareRef;
use crate::amt::time::Time;
use crate::metrics::keys;

/// Number of QoS classes (array dimension for per-class state).
pub const NUM_CLASSES: usize = 3;

/// Per-session quality-of-service class (PR 5): who a session is and how
/// urgent its I/O is. Carried by
/// [`crate::ckio::SessionOptions::class`], announced to the owning
/// data-plane shard before any buffer exists (the `EP_SHARD_PLAN`
/// probe, or the lightweight `EP_SHARD_ADMIT` register for
/// non-store-aware placements), and attached to every admission ticket
/// the session's buffer chares request.
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq, Hash)]
pub enum QosClass {
    /// Latency-sensitive foreground work: drains first under load.
    Interactive,
    /// Ordinary throughput work — the default.
    #[default]
    Bulk,
    /// Background/best-effort work: never starved (under the weighted
    /// policies), but always the last to drain.
    Scavenger,
}

impl QosClass {
    /// All classes, in strict-priority (and array-index) order.
    pub const ALL: [QosClass; NUM_CLASSES] =
        [QosClass::Interactive, QosClass::Bulk, QosClass::Scavenger];

    /// WDRR weight: tickets granted per rotation visit while backlogged.
    /// Integer, and never zero — the starvation-freedom invariant.
    pub fn weight(self) -> u32 {
        match self {
            QosClass::Interactive => 8,
            QosClass::Bulk => 2,
            QosClass::Scavenger => 1,
        }
    }

    /// Dense index for per-class state arrays.
    pub fn index(self) -> usize {
        match self {
            QosClass::Interactive => 0,
            QosClass::Bulk => 1,
            QosClass::Scavenger => 2,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            QosClass::Interactive => "interactive",
            QosClass::Bulk => "bulk",
            QosClass::Scavenger => "scavenger",
        }
    }

    /// The `ckio.governor.class_granted.*` metric key for this class.
    pub fn granted_key(self) -> &'static str {
        match self {
            QosClass::Interactive => keys::GOV_GRANTED_INTERACTIVE,
            QosClass::Bulk => keys::GOV_GRANTED_BULK,
            QosClass::Scavenger => keys::GOV_GRANTED_SCAVENGER,
        }
    }

    /// The `ckio.latency.admission_wait.*` histogram key for this class.
    pub fn wait_key(self) -> &'static str {
        match self {
            QosClass::Interactive => keys::LATENCY_ADMISSION_WAIT_INTERACTIVE,
            QosClass::Bulk => keys::LATENCY_ADMISSION_WAIT_BULK,
            QosClass::Scavenger => keys::LATENCY_ADMISSION_WAIT_SCAVENGER,
        }
    }
}

/// Why the adaptive cap last changed — the flight-recorder annotation
/// for `governor/cap` trace events.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum AdaptCause {
    /// The window's p50 stayed flat: additive-increase probe.
    GrowthProbe,
    /// The window's p50 inflated past the tolerated baseline:
    /// multiplicative decrease.
    P50Inflation,
}

impl AdaptCause {
    pub fn label(self) -> &'static str {
        match self {
            AdaptCause::GrowthProbe => "growth_probe",
            AdaptCause::P50Inflation => "p50_inflation",
        }
    }
}

/// Order in which queued prefetch demand is admitted to the PFS.
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Weighted-fair across classes (WDRR), arrival order within a
    /// class. Starvation-free.
    #[default]
    Fifo,
    /// Weighted-fair across classes (WDRR), sessions with the fewest
    /// total bytes first within a class. Starvation-free across
    /// classes (intra-class, a stream of small sessions can still
    /// outrun a large one — the usual SJF trade).
    SmallestFirst,
    /// Strict `Interactive` > `Bulk` > `Scavenger`, FIFO within a
    /// class. Lower classes can starve under saturating higher-class
    /// load — the explicit opt-in trade.
    StrictPriority,
}

/// A buffer chare's queued ticket demand.
#[derive(Clone, Debug)]
struct Pending {
    owner: ChareRef,
    want: u32,
    /// Total bytes of the owning session (the SmallestFirst sort key).
    sess_bytes: u64,
    seq: u64,
    /// Virtual time the demand was deferred (admission-wait origin).
    enqueued_at: Time,
}

/// One admitted-from-the-queue grant the shard must deliver.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct Grant {
    pub owner: ChareRef,
    pub n: u32,
    /// The class the tickets were granted under (per-class metrics).
    pub class: QosClass,
    /// How long the head of this demand queued before admission
    /// (`ckio.latency.admission_wait.*` sample, `ticket/wait` span).
    pub waited_ns: u64,
}

/// Per-shard PFS read-admission state (owned by a data-plane shard).
#[derive(Debug)]
pub struct Governor {
    /// In-flight cap; `None` = ungoverned (buffers never ask).
    cap: Option<u32>,
    policy: AdmissionPolicy,
    /// Whether the cap is AIMD-derived rather than configured.
    adaptive: bool,
    inflight: u32,
    /// Deferred demand, one queue per [`QosClass`] (index =
    /// [`QosClass::index`]).
    queues: [VecDeque<Pending>; NUM_CLASSES],
    /// WDRR deficit per class: tickets the class may still take before
    /// the rotation moves on.
    deficit: [u32; NUM_CLASSES],
    /// WDRR rotation pointer (class index served next).
    rr: usize,
    seq: u64,
    /// Reads deferred because the cap was reached (monotonic).
    pub throttled: u64,
    /// Tickets admitted per class, immediate and dequeued (monotonic;
    /// the `ckio.governor.class_granted.*` numerators).
    granted: [u64; NUM_CLASSES],
    /// Service times (ns) of the current adaptation window.
    window: Vec<u64>,
    /// Best (lowest) window p50 observed so far; the AIMD baseline.
    best_p50: f64,
    /// Why [`Governor::adapt`] last moved the cap (trace annotation).
    last_adapt_cause: Option<AdaptCause>,
}

impl Default for Governor {
    fn default() -> Governor {
        Governor {
            cap: None,
            policy: AdmissionPolicy::default(),
            adaptive: false,
            inflight: 0,
            queues: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
            deficit: [0; NUM_CLASSES],
            rr: 0,
            seq: 0,
            throttled: 0,
            granted: [0; NUM_CLASSES],
            window: Vec::new(),
            best_p50: f64::MAX,
            last_adapt_cause: None,
        }
    }
}

impl Governor {
    /// Starting cap when the governor derives it adaptively.
    pub const ADAPTIVE_INITIAL_CAP: u32 = 2;
    /// Adaptive caps never grow past this (one per plausible OST queue
    /// slot; far above the modeled saturation point).
    pub const ADAPTIVE_MAX_CAP: u32 = 256;
    /// Completions per adaptation decision.
    pub const ADAPT_WINDOW: usize = 8;
    /// p50 inflation (vs the best observed) tolerated before the cap is
    /// cut: 1.25 = "service got a quarter slower, the OSTs are queueing".
    pub const INFLATE_TOLERANCE: f64 = 1.25;

    pub fn new() -> Governor {
        Governor::default()
    }

    /// Configure from the service's [`crate::ckio::ServiceConfig`]
    /// (PR 5: applied exactly once per shard, at boot, before any
    /// message flows — there is no runtime reconfiguration left). A
    /// static cap wins over adaptive mode; asking for neither leaves
    /// the governor off. A zero static cap is rejected at
    /// `ServiceConfig::validate` — demand could never drain — so it is
    /// a hard error to reach this with one, not a silent clamp.
    pub fn configure(&mut self, cap: Option<u32>, policy: AdmissionPolicy, adaptive: bool) {
        if let Some(c) = cap {
            assert!(c >= 1, "zero admission cap must be rejected at ServiceConfig validation");
            self.cap = Some(c);
            self.policy = policy;
            self.adaptive = false;
        } else if adaptive {
            if !self.adaptive {
                self.cap = Some(Self::ADAPTIVE_INITIAL_CAP);
                self.adaptive = true;
                self.window.clear();
                self.best_p50 = f64::MAX;
                self.last_adapt_cause = None;
            }
            self.policy = policy;
        }
    }

    /// Whether admission control is active at all.
    pub fn governed(&self) -> bool {
        self.cap.is_some()
    }

    /// The current cap (static or adapted); `None` = ungoverned.
    pub fn cap(&self) -> Option<u32> {
        self.cap
    }

    /// Whether the cap is AIMD-derived.
    pub fn is_adaptive(&self) -> bool {
        self.adaptive
    }

    /// Why the adaptive cap last changed; `None` before the first
    /// adaptation (or under a static cap).
    pub fn last_adapt_cause(&self) -> Option<AdaptCause> {
        self.last_adapt_cause
    }

    /// Reads currently admitted and not yet completed.
    pub fn inflight(&self) -> u32 {
        self.inflight
    }

    /// Buffer chares with queued (deferred) demand, across all classes.
    pub fn queued(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Queued demand of one class (tests / inspection).
    pub fn queued_in(&self, class: QosClass) -> usize {
        self.queues[class.index()].len()
    }

    /// Deferred tickets still owed to one owner, across all classes —
    /// the shard's I/O-wait window close condition: the owner's PE
    /// stops being input-blocked when this reaches 0.
    pub fn queued_for(&self, owner: ChareRef) -> u32 {
        self.queues.iter().flatten().filter(|p| p.owner == owner).map(|p| p.want).sum()
    }

    /// Tickets admitted under `class` so far (immediate + dequeued).
    pub fn granted_in(&self, class: QosClass) -> u64 {
        self.granted[class.index()]
    }

    /// Request `want` read tickets for `owner` (a buffer chare of a
    /// `class` session totalling `sess_bytes`). Returns the count
    /// granted now; the remainder queues in the class's FIFO and is
    /// granted by later [`Governor::complete`] calls according to the
    /// weighted policy. Without a cap the full request is granted
    /// trivially. `now` is the virtual time of the request — the origin
    /// of the admission-wait clock for whatever queues.
    pub fn request(
        &mut self,
        owner: ChareRef,
        want: u32,
        sess_bytes: u64,
        class: QosClass,
        now: Time,
    ) -> u32 {
        let Some(cap) = self.cap else { return want };
        let grant = want.min(cap.saturating_sub(self.inflight));
        self.inflight += grant;
        self.granted[class.index()] += grant as u64;
        let deferred = want - grant;
        if deferred > 0 {
            self.throttled += deferred as u64;
            self.seq += 1;
            let p = Pending { owner, want: deferred, sess_bytes, seq: self.seq, enqueued_at: now };
            let q = &mut self.queues[class.index()];
            match self.policy {
                AdmissionPolicy::SmallestFirst => {
                    let at = q
                        .iter()
                        .position(|e| (e.sess_bytes, e.seq) > (p.sess_bytes, p.seq))
                        .unwrap_or(q.len());
                    q.insert(at, p);
                }
                _ => q.push_back(p),
            }
        }
        grant
    }

    /// Return `n` tickets (reads completed, or granted to an
    /// already-dropped buffer), reporting the observed service time of
    /// the completed read (`service_ns == 0` for returns that completed
    /// no read — those carry no signal and never adapt the cap). Returns
    /// the grants this frees up — dequeued by class weight — which the
    /// shard must deliver (each stamped with how long its demand
    /// queued, relative to `now`). The caller can watch
    /// [`Governor::cap`] across calls to observe adaptation.
    pub fn complete(&mut self, n: u32, service_ns: u64, now: Time) -> Vec<Grant> {
        if self.cap.is_none() {
            return Vec::new();
        }
        self.inflight = self.inflight.saturating_sub(n);
        if self.adaptive && service_ns > 0 {
            self.window.push(service_ns);
            if self.window.len() >= Self::ADAPT_WINDOW {
                self.adapt();
            }
        }
        self.drain(now)
    }

    /// Deadline for an admitted read, from the AIMD loop's observed
    /// service baseline: `mult ×` the best window p50 seen so far, or
    /// `default_ns` before any observation (and always at least
    /// `default_ns / 8` so a very fast baseline cannot produce a
    /// deadline that fires on healthy reads). The shard stamps this on
    /// every grant it delivers (PR 8).
    pub fn deadline_ns(&self, mult: u32, default_ns: u64) -> u64 {
        if self.best_p50 == f64::MAX {
            return default_ns;
        }
        let d = (self.best_p50 * mult as f64) as u64;
        d.max(default_ns / 8)
    }

    /// Reclaim every ticket and queue entry owned by a torn-down buffer
    /// chare (PR 8 satellite: the owner-death path). `held` is the count
    /// of tickets the owner held against in-flight reads whose
    /// completions will never return them — without this, a buffer
    /// dropped mid-flight would inflate `inflight` forever (and under
    /// AIMD the cap would starve against phantom occupancy). Queued
    /// demand from the owner is removed outright. Returns the number of
    /// queue entries removed plus the grants the freed tickets unblock
    /// (which the shard must still deliver to live owners).
    pub fn reclaim(&mut self, owner: ChareRef, held: u32, now: Time) -> (u32, Vec<Grant>) {
        if self.cap.is_none() {
            return (0, Vec::new());
        }
        let mut removed = 0u32;
        for q in &mut self.queues {
            let before = q.len();
            q.retain(|p| p.owner != owner);
            removed += (before - q.len()) as u32;
        }
        self.inflight = self.inflight.saturating_sub(held);
        // Freed capacity admits queued demand from surviving owners;
        // reclaimed reads carry no service signal (the window never
        // sees them), so the AIMD baseline stays clean.
        let grants = self.drain(now);
        (removed, grants)
    }

    /// The class the next grant comes from, honoring the policy. `None`
    /// when every queue is empty. For the weighted policies this
    /// advances the WDRR rotation, refilling deficits as it passes
    /// empty or exhausted classes.
    fn pick_class(&mut self) -> Option<usize> {
        if self.queues.iter().all(|q| q.is_empty()) {
            return None;
        }
        if self.policy == AdmissionPolicy::StrictPriority {
            return (0..NUM_CLASSES).find(|&c| !self.queues[c].is_empty());
        }
        // WDRR: at least one queue is non-empty, so the rotation finds a
        // backlogged class within NUM_CLASSES steps.
        loop {
            let c = self.rr;
            if self.queues[c].is_empty() {
                self.deficit[c] = 0;
                self.rr = (c + 1) % NUM_CLASSES;
                continue;
            }
            if self.deficit[c] == 0 {
                self.deficit[c] = QosClass::ALL[c].weight();
            }
            return Some(c);
        }
    }

    /// Dequeue grants while the cap has room, by class weight.
    fn drain(&mut self, now: Time) -> Vec<Grant> {
        let mut grants = Vec::new();
        loop {
            let cap = self.cap.unwrap();
            if self.inflight >= cap {
                break;
            }
            let Some(c) = self.pick_class() else { break };
            let budget = if self.policy == AdmissionPolicy::StrictPriority {
                u32::MAX
            } else {
                self.deficit[c]
            };
            let front = self.queues[c].front_mut().expect("picked class has demand");
            let g = front.want.min(cap - self.inflight).min(budget);
            debug_assert!(g >= 1, "pick_class guarantees credit and room");
            self.inflight += g;
            self.granted[c] += g as u64;
            front.want -= g;
            let owner = front.owner;
            let waited_ns = now.saturating_sub(front.enqueued_at);
            if front.want == 0 {
                self.queues[c].pop_front();
            }
            if self.policy != AdmissionPolicy::StrictPriority {
                self.deficit[c] -= g;
                if self.deficit[c] == 0 || self.queues[c].is_empty() {
                    // Quantum spent (or nothing left to spend it on):
                    // the rotation moves to the next class.
                    self.deficit[c] = 0;
                    self.rr = (c + 1) % NUM_CLASSES;
                }
            }
            grants.push(Grant { owner, n: g, class: QosClass::ALL[c], waited_ns });
        }
        grants
    }

    /// One AIMD decision over the filled window.
    fn adapt(&mut self) {
        self.window.sort_unstable();
        let p50 = self.window[self.window.len() / 2] as f64;
        self.window.clear();
        let cap = self.cap.unwrap_or(Self::ADAPTIVE_INITIAL_CAP);
        if p50 <= self.best_p50 * Self::INFLATE_TOLERANCE {
            self.cap = Some((cap + 1).min(Self::ADAPTIVE_MAX_CAP));
            self.best_p50 = self.best_p50.min(p50);
            self.last_adapt_cause = Some(AdaptCause::GrowthProbe);
        } else {
            self.cap = Some((cap / 2).max(1));
            // Relax the remembered floor so a PFS that is now genuinely
            // slower (not just momentarily congested) can grow again.
            self.best_p50 *= Self::INFLATE_TOLERANCE;
            self.last_adapt_cause = Some(AdaptCause::P50Inflation);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amt::chare::CollectionId;

    fn buf(i: u32) -> ChareRef {
        ChareRef::new(CollectionId(7), i)
    }

    fn grant(i: u32, n: u32, class: QosClass) -> Grant {
        Grant { owner: buf(i), n, class, waited_ns: 0 }
    }

    const BULK: QosClass = QosClass::Bulk;

    #[test]
    fn ungoverned_grants_everything() {
        let mut g = Governor::new();
        assert!(!g.governed());
        assert_eq!(g.request(buf(0), 5, 100, BULK, 0), 5);
        assert_eq!(g.inflight(), 0, "no accounting without a cap");
        assert!(g.complete(5, 0, 0).is_empty());
    }

    #[test]
    fn cap_defers_and_completion_drains_fifo() {
        let mut g = Governor::new();
        g.configure(Some(2), AdmissionPolicy::Fifo, false);
        assert_eq!(g.request(buf(0), 2, 100, BULK, 0), 2);
        assert_eq!(g.request(buf(1), 2, 100, BULK, 0), 0); // full: all deferred
        assert_eq!(g.throttled, 2);
        assert_eq!(g.inflight(), 2);
        // One completion frees one ticket for the queue head.
        assert_eq!(g.complete(1, 0, 0), vec![grant(1, 1, BULK)]);
        assert_eq!(g.inflight(), 2);
        // The head still wants 1 more; next completion serves it.
        assert_eq!(g.complete(1, 0, 0), vec![grant(1, 1, BULK)]);
        assert!(g.complete(2, 0, 0).is_empty());
        assert_eq!(g.inflight(), 0);
        assert_eq!(g.queued(), 0);
    }

    #[test]
    fn partial_grant_queues_the_remainder() {
        let mut g = Governor::new();
        g.configure(Some(3), AdmissionPolicy::Fifo, false);
        assert_eq!(g.request(buf(0), 5, 100, BULK, 0), 3);
        assert_eq!(g.throttled, 2);
        assert_eq!(g.complete(3, 0, 0), vec![grant(0, 2, BULK)]);
    }

    /// `queued_for` sums one owner's deferred tickets across classes —
    /// the shard's window-close condition (PR 9): 0 means the owner's
    /// PE is no longer input-blocked.
    #[test]
    fn queued_for_tracks_one_owner_across_classes() {
        let mut g = Governor::new();
        g.configure(Some(1), AdmissionPolicy::Fifo, false);
        assert_eq!(g.request(buf(0), 1, 100, BULK, 0), 1);
        assert_eq!(g.request(buf(1), 3, 100, BULK, 0), 0);
        assert_eq!(g.request(buf(1), 2, 100, QosClass::Interactive, 0), 0);
        assert_eq!(g.queued_for(buf(1)), 5);
        assert_eq!(g.queued_for(buf(0)), 0, "fully granted demand never queues");
        // Draining grants shrinks the owed count until it reaches 0.
        let freed = g.complete(1, 0, 0);
        assert_eq!(freed.iter().map(|f| f.n).sum::<u32>(), 1);
        assert_eq!(g.queued_for(buf(1)), 4);
        while g.queued_for(buf(1)) > 0 {
            assert!(!g.complete(1, 0, 0).is_empty());
        }
        assert_eq!(g.queued(), 0);
    }

    #[test]
    fn smallest_first_reorders_by_session_bytes_within_a_class() {
        let mut g = Governor::new();
        g.configure(Some(1), AdmissionPolicy::SmallestFirst, false);
        assert_eq!(g.request(buf(0), 1, 1000, BULK, 0), 1);
        assert_eq!(g.request(buf(1), 1, 500, BULK, 0), 0); // big-ish
        assert_eq!(g.request(buf(2), 1, 10, BULK, 0), 0); // small: jumps the queue
        assert_eq!(g.request(buf(3), 1, 10, BULK, 0), 0); // ties keep arrival order
        assert_eq!(g.complete(1, 0, 0), vec![grant(2, 1, BULK)]);
        assert_eq!(g.complete(1, 0, 0), vec![grant(3, 1, BULK)]);
        assert_eq!(g.complete(1, 0, 0), vec![grant(1, 1, BULK)]);
    }

    /// A zero static cap is a configuration error, rejected at
    /// `ServiceConfig::validate` — reaching the governor with one is a
    /// hard bug, not a silent clamp (the PR 5 satellite fix).
    #[test]
    #[should_panic(expected = "zero admission cap")]
    fn zero_cap_is_rejected_not_clamped() {
        let mut g = Governor::new();
        g.configure(Some(0), AdmissionPolicy::Fifo, false);
    }

    /// Under a saturated cap, grant rates converge to the class weight
    /// ratios: with every class continuously backlogged, one full WDRR
    /// rotation grants weight(c) tickets to each class.
    #[test]
    fn wdrr_grant_ratios_match_class_weights_under_saturation() {
        let mut g = Governor::new();
        g.configure(Some(1), AdmissionPolicy::Fifo, false);
        // Saturate: one admitted read, then deep per-class backlogs of
        // single-ticket demand (distinct owners, like distinct buffers).
        assert_eq!(g.request(buf(999), 1, 1, BULK, 0), 1);
        let rounds = 11u32; // exactly one WDRR rotation per weight sum
        let per_class = rounds * 10;
        for i in 0..per_class {
            assert_eq!(g.request(buf(i), 1, 100, QosClass::Interactive, 0), 0);
            assert_eq!(g.request(buf(1000 + i), 1, 100, QosClass::Bulk, 0), 0);
            assert_eq!(g.request(buf(2000 + i), 1, 100, QosClass::Scavenger, 0), 0);
        }
        // Drive exactly rounds * (8 + 2 + 1) single-ticket completions:
        // every class stays backlogged throughout.
        let mut counts = [0u64; NUM_CLASSES];
        for _ in 0..rounds * 11 {
            let gs = g.complete(1, 0, 0);
            assert_eq!(gs.len(), 1, "cap 1 admits exactly one per completion");
            counts[gs[0].class.index()] += gs[0].n as u64;
        }
        assert_eq!(
            counts,
            [8 * rounds as u64, 2 * rounds as u64, rounds as u64],
            "saturated WDRR must grant in 8:2:1 weight ratio"
        );
    }

    /// Starvation-freedom: a single queued Scavenger ticket is granted
    /// within one rotation even under a continuously replenished
    /// Interactive backlog.
    #[test]
    fn scavenger_is_not_starved_by_interactive_load() {
        let mut g = Governor::new();
        g.configure(Some(1), AdmissionPolicy::Fifo, false);
        assert_eq!(g.request(buf(0), 1, 1, QosClass::Interactive, 0), 1);
        assert_eq!(g.request(buf(42), 1, 100, QosClass::Scavenger, 0), 0);
        let mut scavenger_served = false;
        for i in 0..64u32 {
            // Interactive demand never dries up.
            g.request(buf(100 + i), 1, 100, QosClass::Interactive, 0);
            for gr in g.complete(1, 0, 0) {
                if gr.class == QosClass::Scavenger {
                    scavenger_served = true;
                }
            }
            if scavenger_served {
                break;
            }
        }
        assert!(scavenger_served, "WDRR must eventually grant the scavenger ticket");
        assert_eq!(g.queued_in(QosClass::Scavenger), 0);
    }

    /// StrictPriority drains Interactive completely before Bulk before
    /// Scavenger (and is deliberately not starvation-free).
    #[test]
    fn strict_priority_drains_classes_in_order() {
        let mut g = Governor::new();
        g.configure(Some(1), AdmissionPolicy::StrictPriority, false);
        assert_eq!(g.request(buf(0), 1, 1, BULK, 0), 1);
        assert_eq!(g.request(buf(1), 2, 100, QosClass::Scavenger, 0), 0);
        assert_eq!(g.request(buf(2), 2, 100, QosClass::Bulk, 0), 0);
        assert_eq!(g.request(buf(3), 2, 100, QosClass::Interactive, 0), 0);
        let mut order = Vec::new();
        for _ in 0..6 {
            for gr in g.complete(1, 0, 0) {
                order.push(gr.class);
            }
        }
        assert_eq!(order, vec![
            QosClass::Interactive,
            QosClass::Interactive,
            QosClass::Bulk,
            QosClass::Bulk,
            QosClass::Scavenger,
            QosClass::Scavenger,
        ]);
        assert_eq!(g.queued(), 0);
    }

    /// Per-class grant accounting covers both immediate and dequeued
    /// grants (the `ckio.governor.class_granted.*` numerators).
    #[test]
    fn per_class_grant_counters_track_admissions() {
        let mut g = Governor::new();
        g.configure(Some(2), AdmissionPolicy::Fifo, false);
        assert_eq!(g.request(buf(0), 2, 100, QosClass::Interactive, 0), 2); // immediate
        assert_eq!(g.request(buf(1), 3, 100, QosClass::Bulk, 0), 0); // all deferred
        assert_eq!(g.granted_in(QosClass::Interactive), 2);
        assert_eq!(g.granted_in(QosClass::Bulk), 0);
        g.complete(2, 0, 0); // frees 2: bulk dequeues 2 of its 3
        assert_eq!(g.granted_in(QosClass::Bulk), 2);
        g.complete(2, 0, 0);
        assert_eq!(g.granted_in(QosClass::Bulk), 3);
        assert_eq!(g.granted_in(QosClass::Scavenger), 0);
        assert_eq!(g.queued(), 0);
    }

    #[test]
    fn static_cap_wins_over_adaptive_and_adaptive_keeps_learning() {
        let mut g = Governor::new();
        g.configure(None, AdmissionPolicy::Fifo, true);
        assert!(g.is_adaptive());
        assert_eq!(g.cap(), Some(Governor::ADAPTIVE_INITIAL_CAP));
        // Grow the cap one window, then re-configure adaptively: the
        // learned cap survives (configuration must not reset the loop).
        for _ in 0..Governor::ADAPT_WINDOW {
            g.complete(0, 1000, 0);
        }
        let learned = g.cap().unwrap();
        assert_eq!(learned, Governor::ADAPTIVE_INITIAL_CAP + 1);
        g.configure(None, AdmissionPolicy::Fifo, true);
        assert_eq!(g.cap(), Some(learned));
        // A static cap overrides adaptation entirely.
        g.configure(Some(4), AdmissionPolicy::Fifo, true);
        assert!(!g.is_adaptive());
        assert_eq!(g.cap(), Some(4));
        // Re-entering adaptive after the static interlude is a fresh
        // epoch: initial cap, no inherited window or best-p50 baseline —
        // a much slower service must not be judged against the old one.
        for _ in 0..Governor::ADAPT_WINDOW - 1 {
            g.complete(0, 1_000, 0); // partial window under the static cap: ignored
        }
        g.configure(None, AdmissionPolicy::Fifo, true);
        assert!(g.is_adaptive());
        assert_eq!(g.cap(), Some(Governor::ADAPTIVE_INITIAL_CAP));
        for _ in 0..Governor::ADAPT_WINDOW {
            g.complete(0, 50_000_000, 0); // 50ms service, flat within the new epoch
        }
        assert_eq!(
            g.cap(),
            Some(Governor::ADAPTIVE_INITIAL_CAP + 1),
            "a clean epoch grows on its own flat baseline instead of halving \
             against the previous epoch's"
        );
    }

    #[test]
    fn aimd_grows_while_flat_and_halves_on_inflation() {
        let mut g = Governor::new();
        g.configure(None, AdmissionPolicy::Fifo, true);
        // Three flat windows: additive increase each time.
        for _ in 0..3 * Governor::ADAPT_WINDOW {
            g.complete(0, 1_000_000, 0);
        }
        assert_eq!(g.cap(), Some(Governor::ADAPTIVE_INITIAL_CAP + 3));
        // An inflated window (4x the baseline p50): multiplicative cut.
        for _ in 0..Governor::ADAPT_WINDOW {
            g.complete(0, 4_000_000, 0);
        }
        assert_eq!(g.cap(), Some((Governor::ADAPTIVE_INITIAL_CAP + 3) / 2));
        // Zero service times (ticket returns without a read) carry no
        // signal: the window must not fill from them.
        for _ in 0..10 * Governor::ADAPT_WINDOW {
            g.complete(0, 0, 0);
        }
        assert_eq!(g.cap(), Some((Governor::ADAPTIVE_INITIAL_CAP + 3) / 2));
    }

    /// Dequeued grants carry the head's queueing time (now − enqueue),
    /// and the AIMD loop reports why it last moved the cap — the two
    /// facts the flight recorder annotates tickets and cap changes with.
    #[test]
    fn grants_report_wait_and_adaptation_reports_cause() {
        let mut g = Governor::new();
        g.configure(Some(1), AdmissionPolicy::Fifo, false);
        assert_eq!(g.request(buf(0), 1, 100, BULK, 500), 1);
        assert_eq!(g.request(buf(1), 1, 100, BULK, 1_000), 0); // queues at t=1000
        assert_eq!(
            g.complete(1, 0, 4_500),
            vec![Grant { owner: buf(1), n: 1, class: BULK, waited_ns: 3_500 }]
        );
        // Static caps never adapt, so no cause is ever recorded.
        assert_eq!(g.last_adapt_cause(), None);

        let mut a = Governor::new();
        a.configure(None, AdmissionPolicy::Fifo, true);
        assert_eq!(a.last_adapt_cause(), None);
        for _ in 0..Governor::ADAPT_WINDOW {
            a.complete(0, 1_000_000, 0); // flat window: additive increase
        }
        assert_eq!(a.last_adapt_cause(), Some(AdaptCause::GrowthProbe));
        for _ in 0..Governor::ADAPT_WINDOW {
            a.complete(0, 4_000_000, 0); // inflated window: cut
        }
        assert_eq!(a.last_adapt_cause(), Some(AdaptCause::P50Inflation));
        assert_eq!(AdaptCause::GrowthProbe.label(), "growth_probe");
        assert_eq!(AdaptCause::P50Inflation.label(), "p50_inflation");
    }

    /// PR 8 satellite regression: a buffer torn down mid-flight must
    /// have its tickets reclaimed — before the owner-death path existed,
    /// the leaked `inflight` occupancy throttled every later session
    /// (and under AIMD the cap starved against phantom reads forever).
    #[test]
    fn reclaim_returns_held_tickets_and_removes_queued_demand() {
        let mut g = Governor::new();
        g.configure(Some(2), AdmissionPolicy::Fifo, false);
        assert_eq!(g.request(buf(0), 2, 100, BULK, 0), 2); // holds both tickets
        assert_eq!(g.request(buf(1), 1, 100, BULK, 0), 0); // queues
        assert_eq!(g.request(buf(0), 3, 100, BULK, 0), 0); // dead owner's queued demand
        assert_eq!(g.queued(), 2);

        // buf(0) dies holding 2 in-flight tickets and 3 queued wants.
        let (removed, grants) = g.reclaim(buf(0), 2, 1_000);
        assert_eq!(removed, 1, "one queue entry belonged to the dead owner");
        // Freed capacity immediately admits the survivor's demand.
        assert_eq!(grants.len(), 1);
        assert_eq!(grants[0].owner, buf(1));
        assert_eq!(grants[0].n, 1);
        assert_eq!(g.inflight(), 1, "only the survivor's read remains");
        assert_eq!(g.queued(), 0);
        // The survivor completes: everything drains to zero.
        assert!(g.complete(1, 0, 2_000).is_empty());
        assert_eq!(g.inflight(), 0);
    }

    /// Reclaimed reads never feed the AIMD window: the cap must not
    /// adapt on phantom service times.
    #[test]
    fn reclaim_does_not_pollute_the_aimd_window() {
        let mut g = Governor::new();
        g.configure(None, AdmissionPolicy::Fifo, true);
        let cap0 = g.cap().unwrap();
        assert_eq!(g.request(buf(0), cap0, 100, BULK, 0), cap0);
        for _ in 0..10 * Governor::ADAPT_WINDOW {
            g.reclaim(buf(0), 0, 0);
        }
        assert_eq!(g.cap(), Some(cap0), "reclaims carry no service signal");
        let (_, _) = g.reclaim(buf(0), cap0, 0);
        assert_eq!(g.inflight(), 0);
    }

    /// The deadline tracks the observed service baseline: default before
    /// any window, `mult × best_p50` after, floored against collapse.
    #[test]
    fn deadline_follows_observed_service_times() {
        let mut g = Governor::new();
        g.configure(None, AdmissionPolicy::Fifo, true);
        assert_eq!(g.deadline_ns(8, 200_000_000), 200_000_000, "no observation yet");
        for _ in 0..Governor::ADAPT_WINDOW {
            g.complete(0, 2_000_000, 0); // 2ms p50 window
        }
        assert_eq!(g.deadline_ns(8, 8_000_000), 16_000_000);
        // A sub-microsecond baseline still yields a usable deadline.
        let mut fast = Governor::new();
        fast.configure(None, AdmissionPolicy::Fifo, true);
        for _ in 0..Governor::ADAPT_WINDOW {
            fast.complete(0, 10, 0);
        }
        assert_eq!(fast.deadline_ns(8, 8_000_000), 1_000_000, "default/8 floor holds");
    }

    #[test]
    fn adaptive_cap_never_drops_below_one() {
        let mut g = Governor::new();
        g.configure(None, AdmissionPolicy::Fifo, true);
        // Establish a fast baseline, then inflate forever.
        for _ in 0..Governor::ADAPT_WINDOW {
            g.complete(0, 1_000, 0);
        }
        for _ in 0..20 * Governor::ADAPT_WINDOW {
            g.complete(0, 1_000_000_000, 0);
        }
        assert_eq!(g.cap(), Some(1), "floor must hold so demand drains");
        // The relaxed baseline eventually accepts the new normal and the
        // cap can grow again.
        let mut grew = false;
        for _ in 0..64 * Governor::ADAPT_WINDOW {
            g.complete(0, 1_000_000_000, 0);
            if g.cap().unwrap() > 1 {
                grew = true;
                break;
            }
        }
        assert!(grew, "a permanently slower PFS must not pin the cap at 1");
    }
}
