//! Admission governor: global PFS read-admission control (PR 2).
//!
//! The director owns one [`Governor`] — the only component with the
//! global view of every session's prefetch pressure. When a file is
//! opened with [`crate::ckio::Options::max_inflight_reads`] set, its
//! sessions' buffer chares stop issuing PFS reads directly: they request
//! *tickets* from the governor (`EP_DIR_IO_REQ`), issue exactly the
//! granted count, and return each ticket on read completion
//! (`EP_DIR_IO_DONE`). The governor caps the aggregate number of PFS
//! reads in flight across all sessions *of governed files*, so K
//! concurrent sessions can no longer oversubscribe the OSTs — excess
//! demand queues here, in one place, instead of interleaving at the
//! disks (the Fig. 1 collapse).
//!
//! Scope: admission control is opt-in per file at *first* open. Sessions
//! of files opened without `max_inflight_reads` bypass the governor and
//! issue reads directly (the PR 1 path) — a deployment that wants a true
//! cluster-wide cap sets the cap on every file it opens. Like shared
//! POSIX descriptor flags, a refcounted re-open of an already-open file
//! does not reconfigure the governor; the first opener's options hold
//! until the file is fully closed.
//!
//! Queued demand is released according to an [`AdmissionPolicy`]:
//!
//! * [`AdmissionPolicy::Fifo`] — arrival order (fair, no starvation),
//! * [`AdmissionPolicy::SmallestFirst`] — sessions with fewer total
//!   bytes drain first (minimizes mean session latency, the classic
//!   shortest-job-first trade).
//!
//! Like the span store, the governor is a pure data structure: the
//! director translates grants into `EP_BUF_GRANT` sends and charges
//! `ckio.governor.throttled` for every deferred read.

use std::collections::VecDeque;

use crate::amt::chare::ChareRef;

/// Order in which queued prefetch demand is admitted to the PFS.
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Grant in arrival order.
    #[default]
    Fifo,
    /// Grant sessions with the fewest total bytes first.
    SmallestFirst,
}

/// A buffer chare's queued ticket demand.
#[derive(Clone, Debug)]
struct Pending {
    owner: ChareRef,
    want: u32,
    /// Total bytes of the owning session (the SmallestFirst sort key).
    sess_bytes: u64,
    seq: u64,
}

/// Global PFS read-admission state (owned by the director).
#[derive(Debug, Default)]
pub struct Governor {
    /// Aggregate in-flight cap; `None` = ungoverned (buffers never ask).
    cap: Option<u32>,
    policy: AdmissionPolicy,
    inflight: u32,
    queue: VecDeque<Pending>,
    seq: u64,
    /// Reads deferred because the cap was reached (monotonic).
    pub throttled: u64,
}

impl Governor {
    pub fn new() -> Governor {
        Governor::default()
    }

    /// (Re)configure from a file's opening `Options` (global knob, last
    /// writer wins — a cap of 0 is clamped to 1 so demand always
    /// drains). Opens that do not ask for admission control
    /// (`cap: None`) leave the governor untouched.
    pub fn configure(&mut self, cap: Option<u32>, policy: AdmissionPolicy) {
        if let Some(c) = cap {
            self.cap = Some(c.max(1));
            self.policy = policy;
        }
    }

    /// Whether admission control is active at all.
    pub fn governed(&self) -> bool {
        self.cap.is_some()
    }

    /// Reads currently admitted and not yet completed.
    pub fn inflight(&self) -> u32 {
        self.inflight
    }

    /// Buffer chares with queued (deferred) demand.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Request `want` read tickets for `owner` (a buffer chare of a
    /// session totalling `sess_bytes`). Returns the count granted now;
    /// the remainder queues and is granted by later [`Governor::complete`]
    /// calls. Without a cap the full request is granted trivially.
    pub fn request(&mut self, owner: ChareRef, want: u32, sess_bytes: u64) -> u32 {
        let Some(cap) = self.cap else { return want };
        let grant = want.min(cap.saturating_sub(self.inflight));
        self.inflight += grant;
        let deferred = want - grant;
        if deferred > 0 {
            self.throttled += deferred as u64;
            self.seq += 1;
            let p = Pending { owner, want: deferred, sess_bytes, seq: self.seq };
            match self.policy {
                AdmissionPolicy::Fifo => self.queue.push_back(p),
                AdmissionPolicy::SmallestFirst => {
                    let at = self
                        .queue
                        .iter()
                        .position(|q| (q.sess_bytes, q.seq) > (p.sess_bytes, p.seq))
                        .unwrap_or(self.queue.len());
                    self.queue.insert(at, p);
                }
            }
        }
        grant
    }

    /// Return `n` tickets (reads completed, or granted to an
    /// already-dropped buffer). Returns the grants this frees up:
    /// `(buffer, count)` pairs the director must deliver.
    pub fn complete(&mut self, n: u32) -> Vec<(ChareRef, u32)> {
        let Some(cap) = self.cap else { return Vec::new() };
        self.inflight = self.inflight.saturating_sub(n);
        let mut grants = Vec::new();
        while self.inflight < cap {
            let Some(front) = self.queue.front_mut() else { break };
            let g = front.want.min(cap - self.inflight);
            self.inflight += g;
            front.want -= g;
            let owner = front.owner;
            if front.want == 0 {
                self.queue.pop_front();
            }
            grants.push((owner, g));
        }
        grants
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amt::chare::CollectionId;

    fn buf(i: u32) -> ChareRef {
        ChareRef::new(CollectionId(7), i)
    }

    #[test]
    fn ungoverned_grants_everything() {
        let mut g = Governor::new();
        assert!(!g.governed());
        assert_eq!(g.request(buf(0), 5, 100), 5);
        assert_eq!(g.inflight(), 0, "no accounting without a cap");
        assert!(g.complete(5).is_empty());
    }

    #[test]
    fn cap_defers_and_completion_drains_fifo() {
        let mut g = Governor::new();
        g.configure(Some(2), AdmissionPolicy::Fifo);
        assert_eq!(g.request(buf(0), 2, 100), 2);
        assert_eq!(g.request(buf(1), 2, 100), 0); // full: all deferred
        assert_eq!(g.throttled, 2);
        assert_eq!(g.inflight(), 2);
        // One completion frees one ticket for the queue head.
        assert_eq!(g.complete(1), vec![(buf(1), 1)]);
        assert_eq!(g.inflight(), 2);
        // The head still wants 1 more; next completion serves it.
        assert_eq!(g.complete(1), vec![(buf(1), 1)]);
        assert!(g.complete(2).is_empty());
        assert_eq!(g.inflight(), 0);
        assert_eq!(g.queued(), 0);
    }

    #[test]
    fn partial_grant_queues_the_remainder() {
        let mut g = Governor::new();
        g.configure(Some(3), AdmissionPolicy::Fifo);
        assert_eq!(g.request(buf(0), 5, 100), 3);
        assert_eq!(g.throttled, 2);
        assert_eq!(g.complete(3), vec![(buf(0), 2)]);
    }

    #[test]
    fn smallest_first_reorders_by_session_bytes() {
        let mut g = Governor::new();
        g.configure(Some(1), AdmissionPolicy::SmallestFirst);
        assert_eq!(g.request(buf(0), 1, 1000), 1);
        assert_eq!(g.request(buf(1), 1, 500), 0); // big-ish
        assert_eq!(g.request(buf(2), 1, 10), 0); // small: jumps the queue
        assert_eq!(g.request(buf(3), 1, 10), 0); // ties keep arrival order
        assert_eq!(g.complete(1), vec![(buf(2), 1)]);
        assert_eq!(g.complete(1), vec![(buf(3), 1)]);
        assert_eq!(g.complete(1), vec![(buf(1), 1)]);
    }

    #[test]
    fn zero_cap_is_clamped_so_demand_drains() {
        let mut g = Governor::new();
        g.configure(Some(0), AdmissionPolicy::Fifo);
        assert_eq!(g.request(buf(0), 1, 10), 1);
    }
}
