//! Admission governor: PFS read-admission control (PR 2, sharded and
//! made adaptive in PR 3).
//!
//! Since PR 3 each data-plane shard ([`super::shard::DataShard`]) owns
//! one [`Governor`] covering the files that hash to it. When a file is
//! opened with [`crate::ckio::Options::max_inflight_reads`] set (or with
//! [`crate::ckio::Options::adaptive_admission`]), its sessions' buffer
//! chares stop issuing PFS reads directly: they request *tickets* from
//! their file's shard (`EP_SHARD_IO_REQ`), issue exactly the granted
//! count, and return each ticket on read completion
//! (`EP_SHARD_IO_DONE`, carrying the observed service time). The
//! governor caps the number of PFS reads in flight across all sessions
//! *of its shard's governed files*, so K concurrent sessions can no
//! longer oversubscribe the OSTs — excess demand queues here instead of
//! interleaving at the disks (the Fig. 1 collapse). Same-file sessions
//! always share one shard, hence one cap; files on different shards
//! admit independently (aggregate worst case `cap × active shards`).
//!
//! Scope: admission control is opt-in per file at *first* open. Sessions
//! of files opened without a cap (and without `adaptive_admission`)
//! bypass the governor and issue reads directly (the PR 1 path). Like
//! shared POSIX descriptor flags, a refcounted re-open of an already-open
//! file does not reconfigure the governor; the first opener's options
//! hold until the file is fully closed.
//!
//! Queued demand is released according to an [`AdmissionPolicy`]:
//!
//! * [`AdmissionPolicy::Fifo`] — arrival order (fair, no starvation),
//! * [`AdmissionPolicy::SmallestFirst`] — sessions with fewer total
//!   bytes drain first (minimizes mean session latency, the classic
//!   shortest-job-first trade).
//!
//! # Feedback control (PR 3)
//!
//! With `adaptive_admission` and no static cap, the cap is *derived*
//! from the service times buffers observe on their completed reads
//! (issue → completion, which tracks the PFS model's OST busy time plus
//! queueing). Classic AIMD over windows of [`Governor::ADAPT_WINDOW`]
//! completions:
//!
//! * while the window's p50 stays within [`Governor::INFLATE_TOLERANCE`]
//!   of the best p50 seen, the OSTs are keeping up — **additive
//!   increase** (`cap += 1`),
//! * when the p50 inflates past it, admitted reads are queueing at the
//!   disks — **multiplicative decrease** (`cap /= 2`, floor 1). The
//!   remembered best is relaxed slightly on each decrease so a
//!   permanently slower PFS (or a stale floor) cannot pin the cap at 1.
//!
//! Like the span store, the governor is a pure data structure: the shard
//! translates grants into `EP_BUF_GRANT` sends, charges
//! `ckio.governor.throttled` for every deferred read, and publishes the
//! adapted cap on the `ckio.governor.cap` gauge.

use std::collections::VecDeque;

use crate::amt::chare::ChareRef;

/// Order in which queued prefetch demand is admitted to the PFS.
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Grant in arrival order.
    #[default]
    Fifo,
    /// Grant sessions with the fewest total bytes first.
    SmallestFirst,
}

/// A buffer chare's queued ticket demand.
#[derive(Clone, Debug)]
struct Pending {
    owner: ChareRef,
    want: u32,
    /// Total bytes of the owning session (the SmallestFirst sort key).
    sess_bytes: u64,
    seq: u64,
}

/// Per-shard PFS read-admission state (owned by a data-plane shard).
#[derive(Debug)]
pub struct Governor {
    /// In-flight cap; `None` = ungoverned (buffers never ask).
    cap: Option<u32>,
    policy: AdmissionPolicy,
    /// Whether the cap is AIMD-derived rather than configured.
    adaptive: bool,
    inflight: u32,
    queue: VecDeque<Pending>,
    seq: u64,
    /// Reads deferred because the cap was reached (monotonic).
    pub throttled: u64,
    /// Service times (ns) of the current adaptation window.
    window: Vec<u64>,
    /// Best (lowest) window p50 observed so far; the AIMD baseline.
    best_p50: f64,
}

impl Default for Governor {
    fn default() -> Governor {
        Governor {
            cap: None,
            policy: AdmissionPolicy::default(),
            adaptive: false,
            inflight: 0,
            queue: VecDeque::new(),
            seq: 0,
            throttled: 0,
            window: Vec::new(),
            best_p50: f64::MAX,
        }
    }
}

impl Governor {
    /// Starting cap when the governor derives it adaptively.
    pub const ADAPTIVE_INITIAL_CAP: u32 = 2;
    /// Adaptive caps never grow past this (one per plausible OST queue
    /// slot; far above the modeled saturation point).
    pub const ADAPTIVE_MAX_CAP: u32 = 256;
    /// Completions per adaptation decision.
    pub const ADAPT_WINDOW: usize = 8;
    /// p50 inflation (vs the best observed) tolerated before the cap is
    /// cut: 1.25 = "service got a quarter slower, the OSTs are queueing".
    pub const INFLATE_TOLERANCE: f64 = 1.25;

    pub fn new() -> Governor {
        Governor::default()
    }

    /// (Re)configure from a file's opening `Options` (per-shard knob,
    /// last writer wins — a static cap of 0 is clamped to 1 so demand
    /// always drains). A static cap wins over adaptive mode; opens that
    /// ask for neither leave the governor untouched. Re-asking for
    /// adaptive mode while it is already running keeps the learned cap
    /// (re-opens must not reset the feedback loop), but *entering*
    /// adaptive mode — fresh or after a static interlude — starts a
    /// clean epoch: a stale sample window or a previous epoch's best-p50
    /// baseline must not drive the first decision of the new one.
    pub fn configure(&mut self, cap: Option<u32>, policy: AdmissionPolicy, adaptive: bool) {
        if let Some(c) = cap {
            self.cap = Some(c.max(1));
            self.policy = policy;
            self.adaptive = false;
        } else if adaptive {
            if !self.adaptive {
                self.cap = Some(Self::ADAPTIVE_INITIAL_CAP);
                self.adaptive = true;
                self.window.clear();
                self.best_p50 = f64::MAX;
            }
            self.policy = policy;
        }
    }

    /// Whether admission control is active at all.
    pub fn governed(&self) -> bool {
        self.cap.is_some()
    }

    /// The current cap (static or adapted); `None` = ungoverned.
    pub fn cap(&self) -> Option<u32> {
        self.cap
    }

    /// Whether the cap is AIMD-derived.
    pub fn is_adaptive(&self) -> bool {
        self.adaptive
    }

    /// Reads currently admitted and not yet completed.
    pub fn inflight(&self) -> u32 {
        self.inflight
    }

    /// Buffer chares with queued (deferred) demand.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Request `want` read tickets for `owner` (a buffer chare of a
    /// session totalling `sess_bytes`). Returns the count granted now;
    /// the remainder queues and is granted by later [`Governor::complete`]
    /// calls. Without a cap the full request is granted trivially.
    pub fn request(&mut self, owner: ChareRef, want: u32, sess_bytes: u64) -> u32 {
        let Some(cap) = self.cap else { return want };
        let grant = want.min(cap.saturating_sub(self.inflight));
        self.inflight += grant;
        let deferred = want - grant;
        if deferred > 0 {
            self.throttled += deferred as u64;
            self.seq += 1;
            let p = Pending { owner, want: deferred, sess_bytes, seq: self.seq };
            match self.policy {
                AdmissionPolicy::Fifo => self.queue.push_back(p),
                AdmissionPolicy::SmallestFirst => {
                    let at = self
                        .queue
                        .iter()
                        .position(|q| (q.sess_bytes, q.seq) > (p.sess_bytes, p.seq))
                        .unwrap_or(self.queue.len());
                    self.queue.insert(at, p);
                }
            }
        }
        grant
    }

    /// Return `n` tickets (reads completed, or granted to an
    /// already-dropped buffer), reporting the observed service time of
    /// the completed read (`service_ns == 0` for returns that completed
    /// no read — those carry no signal and never adapt the cap). Returns
    /// the grants this frees up: `(buffer, count)` pairs the shard must
    /// deliver. The caller can watch [`Governor::cap`] across calls to
    /// observe adaptation.
    pub fn complete(&mut self, n: u32, service_ns: u64) -> Vec<(ChareRef, u32)> {
        if self.cap.is_none() {
            return Vec::new();
        }
        self.inflight = self.inflight.saturating_sub(n);
        if self.adaptive && service_ns > 0 {
            self.window.push(service_ns);
            if self.window.len() >= Self::ADAPT_WINDOW {
                self.adapt();
            }
        }
        let cap = self.cap.unwrap();
        let mut grants = Vec::new();
        while self.inflight < cap {
            let Some(front) = self.queue.front_mut() else { break };
            let g = front.want.min(cap - self.inflight);
            self.inflight += g;
            front.want -= g;
            let owner = front.owner;
            if front.want == 0 {
                self.queue.pop_front();
            }
            grants.push((owner, g));
        }
        grants
    }

    /// One AIMD decision over the filled window.
    fn adapt(&mut self) {
        self.window.sort_unstable();
        let p50 = self.window[self.window.len() / 2] as f64;
        self.window.clear();
        let cap = self.cap.unwrap_or(Self::ADAPTIVE_INITIAL_CAP);
        if p50 <= self.best_p50 * Self::INFLATE_TOLERANCE {
            self.cap = Some((cap + 1).min(Self::ADAPTIVE_MAX_CAP));
            self.best_p50 = self.best_p50.min(p50);
        } else {
            self.cap = Some((cap / 2).max(1));
            // Relax the remembered floor so a PFS that is now genuinely
            // slower (not just momentarily congested) can grow again.
            self.best_p50 *= Self::INFLATE_TOLERANCE;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amt::chare::CollectionId;

    fn buf(i: u32) -> ChareRef {
        ChareRef::new(CollectionId(7), i)
    }

    #[test]
    fn ungoverned_grants_everything() {
        let mut g = Governor::new();
        assert!(!g.governed());
        assert_eq!(g.request(buf(0), 5, 100), 5);
        assert_eq!(g.inflight(), 0, "no accounting without a cap");
        assert!(g.complete(5, 0).is_empty());
    }

    #[test]
    fn cap_defers_and_completion_drains_fifo() {
        let mut g = Governor::new();
        g.configure(Some(2), AdmissionPolicy::Fifo, false);
        assert_eq!(g.request(buf(0), 2, 100), 2);
        assert_eq!(g.request(buf(1), 2, 100), 0); // full: all deferred
        assert_eq!(g.throttled, 2);
        assert_eq!(g.inflight(), 2);
        // One completion frees one ticket for the queue head.
        assert_eq!(g.complete(1, 0), vec![(buf(1), 1)]);
        assert_eq!(g.inflight(), 2);
        // The head still wants 1 more; next completion serves it.
        assert_eq!(g.complete(1, 0), vec![(buf(1), 1)]);
        assert!(g.complete(2, 0).is_empty());
        assert_eq!(g.inflight(), 0);
        assert_eq!(g.queued(), 0);
    }

    #[test]
    fn partial_grant_queues_the_remainder() {
        let mut g = Governor::new();
        g.configure(Some(3), AdmissionPolicy::Fifo, false);
        assert_eq!(g.request(buf(0), 5, 100), 3);
        assert_eq!(g.throttled, 2);
        assert_eq!(g.complete(3, 0), vec![(buf(0), 2)]);
    }

    #[test]
    fn smallest_first_reorders_by_session_bytes() {
        let mut g = Governor::new();
        g.configure(Some(1), AdmissionPolicy::SmallestFirst, false);
        assert_eq!(g.request(buf(0), 1, 1000), 1);
        assert_eq!(g.request(buf(1), 1, 500), 0); // big-ish
        assert_eq!(g.request(buf(2), 1, 10), 0); // small: jumps the queue
        assert_eq!(g.request(buf(3), 1, 10), 0); // ties keep arrival order
        assert_eq!(g.complete(1, 0), vec![(buf(2), 1)]);
        assert_eq!(g.complete(1, 0), vec![(buf(3), 1)]);
        assert_eq!(g.complete(1, 0), vec![(buf(1), 1)]);
    }

    #[test]
    fn zero_cap_is_clamped_so_demand_drains() {
        let mut g = Governor::new();
        g.configure(Some(0), AdmissionPolicy::Fifo, false);
        assert_eq!(g.request(buf(0), 1, 10), 1);
    }

    #[test]
    fn static_cap_wins_over_adaptive_and_adaptive_keeps_learning() {
        let mut g = Governor::new();
        g.configure(None, AdmissionPolicy::Fifo, true);
        assert!(g.is_adaptive());
        assert_eq!(g.cap(), Some(Governor::ADAPTIVE_INITIAL_CAP));
        // Grow the cap one window, then re-open adaptively: learned cap
        // survives (re-opens must not reset the loop).
        for _ in 0..Governor::ADAPT_WINDOW {
            g.complete(0, 1000);
        }
        let learned = g.cap().unwrap();
        assert_eq!(learned, Governor::ADAPTIVE_INITIAL_CAP + 1);
        g.configure(None, AdmissionPolicy::Fifo, true);
        assert_eq!(g.cap(), Some(learned));
        // A static cap overrides adaptation entirely.
        g.configure(Some(4), AdmissionPolicy::Fifo, true);
        assert!(!g.is_adaptive());
        assert_eq!(g.cap(), Some(4));
        // Re-entering adaptive after the static interlude is a fresh
        // epoch: initial cap, no inherited window or best-p50 baseline —
        // a much slower service must not be judged against the old one.
        for _ in 0..Governor::ADAPT_WINDOW - 1 {
            g.complete(0, 1_000); // partial window under the static cap: ignored
        }
        g.configure(None, AdmissionPolicy::Fifo, true);
        assert!(g.is_adaptive());
        assert_eq!(g.cap(), Some(Governor::ADAPTIVE_INITIAL_CAP));
        for _ in 0..Governor::ADAPT_WINDOW {
            g.complete(0, 50_000_000); // 50ms service, flat within the new epoch
        }
        assert_eq!(
            g.cap(),
            Some(Governor::ADAPTIVE_INITIAL_CAP + 1),
            "a clean epoch grows on its own flat baseline instead of halving \
             against the previous epoch's"
        );
    }

    #[test]
    fn aimd_grows_while_flat_and_halves_on_inflation() {
        let mut g = Governor::new();
        g.configure(None, AdmissionPolicy::Fifo, true);
        // Three flat windows: additive increase each time.
        for _ in 0..3 * Governor::ADAPT_WINDOW {
            g.complete(0, 1_000_000);
        }
        assert_eq!(g.cap(), Some(Governor::ADAPTIVE_INITIAL_CAP + 3));
        // An inflated window (4x the baseline p50): multiplicative cut.
        for _ in 0..Governor::ADAPT_WINDOW {
            g.complete(0, 4_000_000);
        }
        assert_eq!(g.cap(), Some((Governor::ADAPTIVE_INITIAL_CAP + 3) / 2));
        // Zero service times (ticket returns without a read) carry no
        // signal: the window must not fill from them.
        for _ in 0..10 * Governor::ADAPT_WINDOW {
            g.complete(0, 0);
        }
        assert_eq!(g.cap(), Some((Governor::ADAPTIVE_INITIAL_CAP + 3) / 2));
    }

    #[test]
    fn adaptive_cap_never_drops_below_one() {
        let mut g = Governor::new();
        g.configure(None, AdmissionPolicy::Fifo, true);
        // Establish a fast baseline, then inflate forever.
        for _ in 0..Governor::ADAPT_WINDOW {
            g.complete(0, 1_000);
        }
        for _ in 0..20 * Governor::ADAPT_WINDOW {
            g.complete(0, 1_000_000_000);
        }
        assert_eq!(g.cap(), Some(1), "floor must hold so demand drains");
        // The relaxed baseline eventually accepts the new normal and the
        // cap can grow again.
        let mut grew = false;
        for _ in 0..64 * Governor::ADAPT_WINDOW {
            g.complete(0, 1_000_000_000);
            if g.cap().unwrap() > 1 {
                grew = true;
                break;
            }
        }
        assert!(grew, "a permanently slower PFS must not pin the cap at 1");
    }
}
