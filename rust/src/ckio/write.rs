//! Collective output plane (PR 10): write sessions, stripe-aligned
//! write-behind, and read-after-write residency.
//!
//! The mirror of the read plane. `CkIo::start_write_session` hands
//! producers a scatter handle (the same [`Session`] value the read path
//! uses); producers emit pieces with `CkIo::write`, which their PE's
//! [`WriteAssembler`] routes to the session's [`WriteBuffer`] chares by
//! span overlap — the exact partition [`buffer_span_of`] serves to
//! readers, so write routing and read routing can never drift. Each
//! buffer coalesces pieces into **stripe-aligned extents**
//! ([`crate::pfs::layout::stripe_extents`]): with
//! [`WriteOptions::write_behind`] an extent is queued for the PFS the
//! moment its last covering piece lands, so the aggregated write stream
//! is a handful of stripe-sized RPCs instead of one RPC per producer
//! piece (the naive baseline `run_svc_rw` compares against).
//!
//! **Read-after-write residency**: on `EP_WB_INIT` each buffer claims
//! its span at the file's data-plane shard (`EP_SHARD_REGISTER` with
//! `dirty: true`). The claim makes the write buffer a *peer source* —
//! a following read session's buffers resolve their slots against it
//! and fetch with `EP_BUF_PEER_FETCH` instead of touching the PFS
//! (the headline `svc_rw` measurement: zero PFS read bytes). Closing a
//! write session *parks* the array in the shard's span store, so the
//! residency outlives the session until evicted or the file closes.
//! In this reproduction the producer payload is the deterministic
//! verification pattern ([`crate::pfs::pattern`], PR 10 satellite), so
//! a buffer regenerates bytes on demand when serving a peer rather
//! than holding a copy resident — residency accounting still charges
//! the full span.
//!
//! **Drain barriers**: `EP_WB_FLUSH` and `EP_WB_CLOSE` queue every
//! covered-but-unwritten byte (clipped to stripe extents) and answer
//! the director only when no queued op, in-flight write, or armed
//! backoff timer remains — every dirty extent is then durably written
//! or degraded into the session's [`super::session::SessionOutcome`].
//! With [`WriteOptions::park_dirty`] (lazy mode) the close skips the
//! drain: the span parks *dirty*, and a later LRU eviction of the
//! parked span forces a writeback (`EP_WB_WRITEBACK` from the shard)
//! before the data may drop.
//!
//! PFS writes are admitted through the same per-shard governor as
//! reads (`EP_SHARD_IO_REQ` / `EP_BUF_GRANT` / `EP_SHARD_IO_DONE`),
//! under the session's [`QosClass`] — a saturated AIMD cap arbitrates
//! readers against writers by class weight. Failed writes (PR 8 fault
//! plane) back off and retry up to the service retry policy's budget,
//! then degrade: the bytes are accounted on `ckio.write.degraded_bytes`
//! and the span still settles, so a flush barrier can never hang on a
//! faulty OST.
//!
//! EP-number sharing: a parked `WriteBuffer` lives in the same span
//! store as read arrays, so the shard and director address it with the
//! read-plane EPs `EP_BUF_DROP` (4), `EP_BUF_PEER_FETCH` (7),
//! `EP_BUF_GRANT` (9), and `EP_BUF_PEERS` (10). The write-plane's own
//! EPs are chosen around those numbers.

use std::collections::{HashMap, VecDeque};

use crate::amt::callback::Callback;
use crate::amt::chare::{Chare, ChareRef, CollectionId};
use crate::amt::engine::Ctx;
use crate::amt::msg::{Ep, Msg, Payload};
use crate::amt::protocol::{PayloadKind, ProtocolSpec};
use crate::amt::time::{Time, MICROS};
use crate::impl_chare_any;
use crate::metrics::keys;
use crate::net::Transfer;
use crate::pfs::backend::{IoResult, WriteRequest};
use crate::pfs::layout::{stripe_extents, FileId};
use crate::pfs::pattern;
use crate::util::bytes::Chunk;
use crate::{ep_spec, send_spec};

use super::buffer::{
    BufStartedMsg, GrantMsg, IoDoneMsg, IoReqMsg, PeerDataMsg, PeerFetchMsg, PeersMsg, ReclaimMsg,
    RetryTimerMsg, EP_BUF_DROP, EP_BUF_GRANT, EP_BUF_PEER_DATA, EP_BUF_PEER_FETCH, EP_BUF_PEERS,
};
use super::governor::QosClass;
use super::options::{RetryPolicy, WriteOptions};
use super::session::{buffer_span_of, Session, SessionId};
use super::shard::{
    MarkCleanMsg, RegisterMsg, UnclaimMsg, WbDoneMsg, EP_SHARD_IO_DONE, EP_SHARD_IO_RECLAIM,
    EP_SHARD_IO_REQ, EP_SHARD_MARK_CLEAN, EP_SHARD_REGISTER, EP_SHARD_UNCLAIM, EP_SHARD_WB_DONE,
};

// ---------------------------------------------------------------------
// WriteAssembler (per-PE group)
// ---------------------------------------------------------------------

/// Director broadcast: a write session started ([`WriteSessionMsg`]).
pub const EP_WA_SESSION: Ep = 1;
/// A producer on this PE scatters a piece ([`PutMsg`]).
pub const EP_WA_PUT: Ep = 2;
/// A write buffer accepted one routed piece ([`WPieceAckMsg`]).
pub const EP_WA_PIECE_ACK: Ep = 3;
/// Director broadcast: the write session closed (payload: [`SessionId`]).
pub const EP_WA_SESSION_DROP: Ep = 4;

// ---------------------------------------------------------------------
// WriteBuffer (per-session chare array)
// ---------------------------------------------------------------------

/// Kick a freshly created write buffer: claim the span (dirty), ack the
/// director.
pub const EP_WB_INIT: Ep = 1;
/// A routed producer piece ([`WPieceMsg`]).
pub const EP_WB_PIECE: Ep = 2;
/// Flush barrier: queue every covered-but-unwritten byte, ack the
/// director when drained.
pub const EP_WB_FLUSH: Ep = 3;
// 4 = EP_BUF_DROP (read-plane shared: release after clean eviction /
// file close).
/// Close barrier: drain like a flush (unless `park_dirty`), then park.
pub const EP_WB_CLOSE: Ep = 5;
/// Split-phase PFS write completion (engine callback).
pub const EP_WB_WRITE_DONE: Ep = 6;
// 7 = EP_BUF_PEER_FETCH (read-plane shared: read-after-write serving).
// 9 = EP_BUF_GRANT, 10 = EP_BUF_PEERS (read-plane shared).
/// Self-timer: a failed write's backoff expired — re-enter admission.
pub const EP_WB_RETRY: Ep = 11;
/// Shard: this parked span's *dirty* claims were evicted — write every
/// dirty byte back before the data may drop, then ack
/// `EP_SHARD_WB_DONE`.
pub const EP_WB_WRITEBACK: Ep = 12;

/// Director → write assemblers: a write session is live; route puts for
/// it. The [`Session`] is the same `Copy` scatter handle producers got.
#[derive(Debug)]
pub struct WriteSessionMsg {
    pub session: Session,
}

/// Producer → its PE's write assembler: scatter `[offset, offset+len)`.
#[derive(Debug)]
pub struct PutMsg {
    pub session: SessionId,
    pub offset: u64,
    pub len: u64,
    /// Fires with a [`WriteResult`] once every routed piece is accepted.
    pub after: Callback,
}

/// Assembler → write buffer: one span-clipped piece of a put.
#[derive(Debug)]
pub struct WPieceMsg {
    /// The originating assembler's put id (acked back verbatim).
    pub put: u64,
    pub offset: u64,
    pub len: u64,
    /// The assembler awaiting the ack.
    pub reply: ChareRef,
}

/// Write buffer → assembler: the piece was accepted into the buffer.
#[derive(Debug)]
pub struct WPieceAckMsg {
    pub put: u64,
    pub bytes: u64,
}

/// The completion value of one `CkIo::write` put: every piece of
/// `[offset, offset+len)` was accepted by its write buffer. Acceptance
/// is *buffering*, not durability — durability is the flush barrier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WriteResult {
    pub session: SessionId,
    pub offset: u64,
    pub len: u64,
}

/// Write buffer → director: this chare's share of a flush barrier is
/// durable (or degraded). `written`/`degraded` are deltas since the
/// previous flush report, so the director's per-flush sums stay
/// meaningful across repeated flushes.
#[derive(Debug)]
pub struct FlushDoneMsg {
    pub session: SessionId,
    pub written: u64,
    pub degraded: u64,
}

/// Write buffer → director: close ack, carrying this chare's
/// contribution to the session's outcome (the write-plane analogue of
/// [`super::buffer::BufDroppedMsg`]).
#[derive(Debug)]
pub struct WbDroppedMsg {
    pub session: SessionId,
    /// Bytes kept resident by the parked span (covered bytes).
    pub resident: u64,
    /// Bytes durably written over the session's lifetime.
    pub written: u64,
    /// Bytes abandoned after the write retry budget.
    pub degraded: u64,
    /// Bytes still dirty at close (non-zero only under `park_dirty`).
    pub dirty: u64,
    /// PFS write re-issues beyond each extent's first attempt.
    pub retries: u64,
}

/// One write assembler put awaiting its routed pieces' acks.
struct PendingPut {
    session: SessionId,
    offset: u64,
    len: u64,
    outstanding: u32,
    after: Callback,
}

/// Per-PE scatter router (the write-side mirror of
/// [`super::assembler::ReadAssembler`]): holds the [`Session`] of every
/// live write session and clips producer puts onto the owning buffers'
/// spans. Exists so producers never need to know the buffer partition —
/// and so put completion (all pieces accepted) is a single callback.
pub struct WriteAssembler {
    /// Patched at boot (`patch_director`), like every service group.
    pub director: ChareRef,
    sessions: HashMap<SessionId, Session>,
    /// Puts whose routed pieces are not all acked yet; drained by
    /// `EP_WA_PIECE_ACK` (leak-checked via [`WriteAssembler::pending_puts`]).
    pending_puts: HashMap<u64, PendingPut>,
    next_put: u64,
}

impl Default for WriteAssembler {
    fn default() -> WriteAssembler {
        WriteAssembler {
            // Placeholder — replaced by `patch_director` before any
            // message is in flight (boot wiring, as for managers).
            director: ChareRef::new(CollectionId(0), 0),
            sessions: HashMap::new(),
            pending_puts: HashMap::new(),
            next_put: 0,
        }
    }
}

impl WriteAssembler {
    /// Puts still awaiting piece acks (leak checks: must be 0 at
    /// quiescence).
    pub fn pending_puts(&self) -> usize {
        self.pending_puts.len()
    }

    /// Write sessions this PE currently routes for (leak checks).
    pub fn live_sessions(&self) -> usize {
        self.sessions.len()
    }
}

/// The write assembler's declared message protocol (see
/// [`crate::amt::protocol`]). Any change to its EPs, payload types, or
/// send sites must update this spec in the same commit.
pub fn assembler_protocol_spec() -> ProtocolSpec {
    use super::director::{EP_DIR_ANNOUNCE_ACK, EP_DIR_DROP_ACK_MGR};
    ProtocolSpec {
        chare: "WriteAssembler",
        module: "ckio/write.rs",
        handles: vec![
            ep_spec!(EP_WA_SESSION, PayloadKind::of::<WriteSessionMsg>()),
            ep_spec!(EP_WA_PUT, PayloadKind::of::<PutMsg>()),
            ep_spec!(EP_WA_PIECE_ACK, PayloadKind::of::<WPieceAckMsg>()),
            ep_spec!(EP_WA_SESSION_DROP, PayloadKind::of::<SessionId>()),
        ],
        sends: vec![
            send_spec!("WriteBuffer", EP_WB_PIECE, PayloadKind::of::<WPieceMsg>()),
            send_spec!("Director", EP_DIR_ANNOUNCE_ACK, PayloadKind::of::<SessionId>()),
            send_spec!("Director", EP_DIR_DROP_ACK_MGR, PayloadKind::of::<SessionId>()),
        ],
    }
}

impl Chare for WriteAssembler {
    fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
        match msg.ep {
            EP_WA_SESSION => {
                let m: WriteSessionMsg = msg.take();
                self.sessions.insert(m.session.id, m.session);
                ctx.advance(MICROS / 2);
                ctx.send(self.director, super::director::EP_DIR_ANNOUNCE_ACK, m.session.id);
            }
            EP_WA_PUT => {
                let m: PutMsg = msg.take();
                let s = *self
                    .sessions
                    .get(&m.session)
                    .expect("write put for a session this PE was never announced");
                assert!(
                    m.offset >= s.offset && m.offset + m.len <= s.offset + s.bytes,
                    "write [{}, {}) outside session [{}, {})",
                    m.offset,
                    m.offset + m.len,
                    s.offset,
                    s.offset + s.bytes
                );
                ctx.metrics().count(keys::WRITE_PUTS, 1);
                ctx.metrics().count(keys::WRITE_BYTES, m.len);
                if m.len == 0 {
                    ctx.fire(
                        m.after,
                        Payload::new(WriteResult { session: m.session, offset: m.offset, len: 0 }),
                    );
                    return;
                }
                let put = self.next_put;
                self.next_put += 1;
                let me = ctx.me();
                let mut outstanding = 0;
                for b in s.buffers_for(m.offset, m.len) {
                    let (blo, blen) = buffer_span_of(s.offset, s.bytes, s.num_buffers, b);
                    let lo = m.offset.max(blo);
                    let hi = (m.offset + m.len).min(blo + blen);
                    if hi <= lo {
                        continue;
                    }
                    outstanding += 1;
                    ctx.send(ChareRef::new(s.buffers, b), EP_WB_PIECE, WPieceMsg {
                        put,
                        offset: lo,
                        len: hi - lo,
                        reply: me,
                    });
                }
                debug_assert!(outstanding > 0, "a non-empty put routes to at least one buffer");
                self.pending_puts.insert(put, PendingPut {
                    session: m.session,
                    offset: m.offset,
                    len: m.len,
                    outstanding,
                    after: m.after,
                });
                ctx.advance(MICROS / 2);
            }
            EP_WA_PIECE_ACK => {
                let m: WPieceAckMsg = msg.take();
                let p = self.pending_puts.get_mut(&m.put).expect("piece ack for an unknown put");
                p.outstanding -= 1;
                if p.outstanding == 0 {
                    let p = self.pending_puts.remove(&m.put).unwrap();
                    ctx.fire(
                        p.after,
                        Payload::new(WriteResult {
                            session: p.session,
                            offset: p.offset,
                            len: p.len,
                        }),
                    );
                }
            }
            EP_WA_SESSION_DROP => {
                let sid: SessionId = msg.take();
                self.sessions.remove(&sid);
                ctx.advance(MICROS / 2);
                ctx.send(self.director, super::director::EP_DIR_DROP_ACK_MGR, sid);
            }
            other => panic!("WriteAssembler: unknown ep {other}"),
        }
    }

    impl_chare_any!();
}

// ---------------------------------------------------------------------
// interval arithmetic (half-open [lo, hi) byte ranges)
// ---------------------------------------------------------------------

/// Merge `[lo, hi)` into a sorted, disjoint interval list.
fn merge_into(v: &mut Vec<(u64, u64)>, lo: u64, hi: u64) {
    if hi <= lo {
        return;
    }
    let (mut lo, mut hi) = (lo, hi);
    let mut out = Vec::with_capacity(v.len() + 1);
    for &(a, b) in v.iter() {
        if b < lo || a > hi {
            out.push((a, b));
        } else {
            lo = lo.min(a);
            hi = hi.max(b);
        }
    }
    out.push((lo, hi));
    out.sort_unstable();
    *v = out;
}

/// Total bytes covered by a disjoint interval list.
fn intervals_bytes(v: &[(u64, u64)]) -> u64 {
    v.iter().map(|&(a, b)| b - a).sum()
}

/// Whether `[lo, hi)` is fully inside the interval list.
fn contains_range(v: &[(u64, u64)], lo: u64, hi: u64) -> bool {
    hi <= lo || v.iter().any(|&(a, b)| a <= lo && hi <= b)
}

/// The parts of `[lo, hi)` *not* covered by the (sorted, disjoint)
/// interval list.
fn subtract_range(v: &[(u64, u64)], lo: u64, hi: u64) -> Vec<(u64, u64)> {
    let mut out = Vec::new();
    let mut cur = lo;
    for &(a, b) in v {
        if b <= cur || a >= hi {
            continue;
        }
        if a > cur {
            out.push((cur, a));
        }
        cur = cur.max(b);
        if cur >= hi {
            break;
        }
    }
    if cur < hi {
        out.push((cur, hi));
    }
    out
}

// ---------------------------------------------------------------------
// WriteBuffer
// ---------------------------------------------------------------------

/// Lifecycle of a write buffer chare.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
enum WPhase {
    /// Accepting pieces; session live.
    Filling,
    /// Session closed; span parked in the shard's store, serving peer
    /// fetches (read-after-write) until evicted or purged.
    Parked,
    /// Released: data gone, late peer fetches answered with a miss.
    Dead,
}

/// One queued or retrying PFS write op — always clipped to a single
/// stripe extent.
#[derive(Copy, Clone, Debug)]
struct WriteOp {
    lo: u64,
    len: u64,
    /// Completed (failed) attempts so far.
    attempts: u32,
}

/// An in-flight PFS write attempt.
struct LiveWrite {
    op: WriteOp,
    issued: Time,
}

/// One write-plane buffer chare: owns a disjoint span of the write
/// session, coalesces producer pieces into stripe-aligned extents, and
/// drives governed, retried PFS writes over them. See the module docs
/// for the full lifecycle.
pub struct WriteBuffer {
    session: SessionId,
    file: FileId,
    /// Span owned by this chare, file coordinates.
    my_lo: u64,
    my_len: u64,
    wopts: WriteOptions,
    /// Max PFS writes in flight (the session's window option, reused).
    window: u32,
    /// Stripe-aligned extents of the span, fixed at creation
    /// ([`stripe_extents`]): the write-op granularity.
    extents: Vec<(u64, u64)>,
    /// Producer-covered bytes (merged, absolute file coordinates).
    covered: Vec<(u64, u64)>,
    /// Bytes ever handed to the op queue — the no-double-write guard.
    issued: Vec<(u64, u64)>,
    /// Bytes durably written *or* degraded: the drain barrier's target
    /// is `settled == issued == covered`.
    settled: Vec<(u64, u64)>,
    /// Ops awaiting admission (governed) or a window slot.
    ops: VecDeque<WriteOp>,
    /// In-flight write attempts keyed by wire `user` id. A completion
    /// settles iff its key is still here (teardown bulk-reclaims).
    live: HashMap<u64, LiveWrite>,
    next_user: u64,
    /// Ops waiting out a failure backoff, keyed by timer id.
    backoffs: HashMap<u32, WriteOp>,
    next_backoff: u32,
    /// Armed backoff timers (drain: a barrier never completes under one).
    retry_timers: u32,
    /// Route writes through the shard's admission governor.
    governed: bool,
    sess_bytes: u64,
    class: QosClass,
    /// Tickets requested and not yet granted.
    asked: u32,
    /// Service retry policy; `None` = one attempt, fail-to-degraded.
    retry: Option<RetryPolicy>,
    /// Peer fetches for bytes whose pieces have not arrived yet
    /// (drained on coverage, or with a miss at release).
    peer_pending: Vec<PeerFetchMsg>,
    /// Session-outcome counters, reported on the close ack.
    n_written: u64,
    n_degraded: u64,
    n_retries: u64,
    /// Deltas since the last flush report (per-flush sums).
    flush_written: u64,
    flush_degraded: u64,
    /// `n_written` at writeback start: the `EP_SHARD_WB_DONE` delta.
    wb_baseline: u64,
    flush_waiting: bool,
    close_waiting: bool,
    wb_waiting: bool,
    phase: WPhase,
    director: ChareRef,
    shard: ChareRef,
}

impl WriteBuffer {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        session: SessionId,
        file: FileId,
        my_lo: u64,
        my_len: u64,
        wopts: WriteOptions,
        window: u32,
        director: ChareRef,
        shard: ChareRef,
    ) -> WriteBuffer {
        let extents =
            if my_len == 0 { Vec::new() } else { stripe_extents(my_lo, my_len, wopts.stripe_bytes) };
        WriteBuffer {
            session,
            file,
            my_lo,
            my_len,
            wopts,
            window: window.max(1),
            extents,
            covered: Vec::new(),
            issued: Vec::new(),
            settled: Vec::new(),
            ops: VecDeque::new(),
            live: HashMap::new(),
            next_user: 0,
            backoffs: HashMap::new(),
            next_backoff: 0,
            retry_timers: 0,
            governed: false,
            sess_bytes: 0,
            class: QosClass::default(),
            asked: 0,
            retry: None,
            peer_pending: Vec::new(),
            n_written: 0,
            n_degraded: 0,
            n_retries: 0,
            flush_written: 0,
            flush_degraded: 0,
            wb_baseline: 0,
            flush_waiting: false,
            close_waiting: false,
            wb_waiting: false,
            phase: WPhase::Filling,
            director,
            shard,
        }
    }

    /// Route PFS writes through the shard's admission governor, as
    /// `class` (the write session's QoS class rides every ticket).
    pub fn governed(mut self, sess_bytes: u64, class: QosClass) -> WriteBuffer {
        self.governed = true;
        self.sess_bytes = sess_bytes;
        self.class = class;
        self
    }

    /// Arm write retries (PR 8 fault plane): failed writes back off and
    /// re-enter admission up to the policy budget, then degrade.
    pub fn with_retry(mut self, policy: RetryPolicy) -> WriteBuffer {
        self.retry = Some(policy);
        self
    }

    /// Producer-covered bytes (tests / inspection).
    pub fn covered_bytes(&self) -> u64 {
        intervals_bytes(&self.covered)
    }

    /// Covered bytes not yet durably written or degraded.
    pub fn dirty_bytes(&self) -> u64 {
        intervals_bytes(&self.covered) - intervals_bytes(&self.settled)
    }

    /// Queued + in-flight + backing-off write work (leak checks: must
    /// be 0 at quiescence).
    pub fn pending_ops(&self) -> usize {
        self.ops.len() + self.live.len() + self.backoffs.len()
    }

    /// Queued peer fetches (leak checks).
    pub fn pending_len(&self) -> usize {
        self.peer_pending.len()
    }

    /// Whether the close parked this chare's span.
    pub fn is_parked(&self) -> bool {
        self.phase == WPhase::Parked
    }

    /// Whether the chare was released.
    pub fn is_dead(&self) -> bool {
        self.phase == WPhase::Dead
    }

    /// Exponential backoff before a failed write re-enters admission —
    /// the read plane's curve ([`super::buffer`]), keyed by timer id so
    /// a burst of same-extent failures never re-converges into a
    /// synchronized retry storm. No RNG: replays stay exact.
    fn backoff_ns(&self, key: u32, attempt: u32) -> u64 {
        let r = self.retry.as_ref().expect("backoff without a retry policy");
        let exp = r.base_backoff_ns.checked_shl(attempt.saturating_sub(1)).unwrap_or(u64::MAX);
        let spread = (r.base_backoff_ns / 2).max(1);
        let jitter = (u64::from(key).wrapping_mul(2_654_435_761) + u64::from(attempt)) % spread;
        exp.min(r.max_backoff_ns) + jitter
    }

    /// Queue stripe-clipped write ops for every covered-but-unissued
    /// byte of `[lo, hi)`. The `issued` list guards double-writes, so
    /// the call is idempotent — flush, close, and writeback can overlap
    /// freely.
    fn enqueue_range(&mut self, lo: u64, hi: u64) {
        let mut fresh: Vec<(u64, u64)> = Vec::new();
        for &(clo, chi) in &self.covered {
            let (a, b) = (clo.max(lo), chi.min(hi));
            if b <= a {
                continue;
            }
            fresh.extend(subtract_range(&self.issued, a, b));
        }
        for (a, b) in fresh {
            // Clip to stripe extents: each op is one (partial) stripe,
            // never straddling an extent boundary.
            for &(elo, elen) in &self.extents {
                let s = a.max(elo);
                let e = b.min(elo + elen);
                if e > s {
                    self.ops.push_back(WriteOp { lo: s, len: e - s, attempts: 0 });
                }
            }
            merge_into(&mut self.issued, a, b);
        }
    }

    /// Write-behind trigger: queue any stripe extent the piece
    /// `[lo, hi)` just completed (fully covered, nothing issued yet).
    fn enqueue_completed_extents(&mut self, lo: u64, hi: u64) {
        let candidates: Vec<(u64, u64)> = self
            .extents
            .iter()
            .copied()
            .filter(|&(elo, elen)| elo < hi && elo + elen > lo)
            .filter(|&(elo, elen)| contains_range(&self.covered, elo, elo + elen))
            .collect();
        for (elo, elen) in candidates {
            self.enqueue_range(elo, elo + elen);
        }
    }

    /// Issue the next queued write op.
    fn issue_next(&mut self, ctx: &mut Ctx<'_>) {
        let Some(op) = self.ops.pop_front() else { return };
        let user = self.next_user;
        self.next_user += 1;
        self.live.insert(user, LiveWrite { op, issued: ctx.now() });
        let me = ctx.me();
        ctx.submit_write(
            WriteRequest { file: self.file, offset: op.lo, len: op.len, user },
            Callback::to_chare(me, EP_WB_WRITE_DONE),
        );
    }

    /// Governed issuance: ask the shard's governor for tickets covering
    /// the queued ops, up to the window.
    fn maybe_request(&mut self, ctx: &mut Ctx<'_>) {
        let queued = self.ops.len() as u32;
        let room = self.window.saturating_sub(self.live.len() as u32 + self.asked);
        let want = queued.saturating_sub(self.asked).min(room);
        if want > 0 {
            self.asked += want;
            let me = ctx.me();
            ctx.send(self.shard, EP_SHARD_IO_REQ, IoReqMsg {
                buffer: me,
                want,
                sess_bytes: self.sess_bytes,
                class: self.class,
                pe: ctx.pe().0,
            });
        }
    }

    /// Kick issuance: governed chares ask the governor, ungoverned ones
    /// write directly up to the window.
    fn pump(&mut self, ctx: &mut Ctx<'_>) {
        if self.governed {
            self.maybe_request(ctx);
        } else {
            while (self.live.len() as u32) < self.window && !self.ops.is_empty() {
                self.issue_next(ctx);
            }
        }
    }

    /// No queued op, in-flight write, or armed backoff remains.
    fn drained(&self) -> bool {
        self.ops.is_empty() && self.live.is_empty() && self.retry_timers == 0
    }

    /// Satisfy whichever drain barriers are met, each exactly once.
    fn maybe_drained(&mut self, ctx: &mut Ctx<'_>) {
        if !self.drained() {
            return;
        }
        if self.flush_waiting {
            self.flush_waiting = false;
            let (written, degraded) = (self.flush_written, self.flush_degraded);
            self.flush_written = 0;
            self.flush_degraded = 0;
            ctx.send(self.director, super::director::EP_DIR_FLUSH_DONE, FlushDoneMsg {
                session: self.session,
                written,
                degraded,
            });
        }
        if self.close_waiting {
            self.close_waiting = false;
            let resident = intervals_bytes(&self.covered);
            let dirty = self.dirty_bytes();
            if dirty == 0 && self.my_len > 0 {
                // Fully durable: downgrade the shard claim so a later
                // eviction releases the span without a writeback.
                let me = ctx.me();
                ctx.send(self.shard, EP_SHARD_MARK_CLEAN, MarkCleanMsg {
                    file: self.file,
                    owner: me,
                });
            }
            self.phase = WPhase::Parked;
            ctx.send(self.director, super::director::EP_DIR_WB_DROPPED, WbDroppedMsg {
                session: self.session,
                resident,
                written: self.n_written,
                degraded: self.n_degraded,
                dirty,
                retries: self.n_retries,
            });
        }
        if self.wb_waiting {
            self.wb_waiting = false;
            let bytes = self.n_written - self.wb_baseline;
            ctx.send(self.shard, EP_SHARD_WB_DONE, WbDoneMsg { bytes });
            self.release(ctx);
        }
    }

    /// Answer a read buffer's peer fetch from covered data. The payload
    /// is regenerated from the verification pattern (module docs): in
    /// this reproduction the producers wrote exactly those bytes.
    fn serve_peer(&self, ctx: &mut Ctx<'_>, f: &PeerFetchMsg) {
        let chunk = Chunk::materialized(f.offset, pattern::make(self.file, f.offset, f.len));
        let wire = chunk.len;
        ctx.metrics().count(keys::STORE_PEER_SERVED, 1);
        ctx.advance(MICROS / 2);
        ctx.send_sized(
            f.reply,
            EP_BUF_PEER_DATA,
            Payload::new(PeerDataMsg { slot: f.slot, len: f.len, chunk: Some(chunk) }),
            wire,
            Transfer::ZeroCopy,
        );
    }

    /// Answer a peer fetch this chare can never serve.
    fn peer_miss(&self, ctx: &mut Ctx<'_>, f: &PeerFetchMsg) {
        ctx.metrics().count(keys::STORE_PEER_MISS, 1);
        ctx.send(f.reply, EP_BUF_PEER_DATA, PeerDataMsg { slot: f.slot, len: f.len, chunk: None });
    }

    /// Serve queued peer fetches whose bytes arrived.
    fn serve_ready_peers(&mut self, ctx: &mut Ctx<'_>) {
        let mut still = Vec::new();
        for f in std::mem::take(&mut self.peer_pending) {
            if contains_range(&self.covered, f.offset, f.offset + f.len) {
                self.serve_peer(ctx, &f);
            } else {
                still.push(f);
            }
        }
        self.peer_pending = still;
    }

    /// Final release: miss-drain queued peer fetches, drop all state.
    fn release(&mut self, ctx: &mut Ctx<'_>) {
        for f in std::mem::take(&mut self.peer_pending) {
            self.peer_miss(ctx, &f);
        }
        self.covered.clear();
        self.issued.clear();
        self.settled.clear();
        self.ops.clear();
        self.backoffs.clear();
        self.phase = WPhase::Dead;
    }

    /// A PFS write attempt completed: settle its ticket and route the
    /// outcome — success settles the range, failures back off and
    /// re-enter admission, exhausted budgets degrade (the range settles
    /// without durability, accounted on `ckio.write.degraded_bytes`).
    fn write_done(&mut self, ctx: &mut Ctx<'_>, r: IoResult) {
        let Some(lw) = self.live.remove(&r.user) else {
            // Bulk-reclaimed at teardown: the ticket already went back.
            return;
        };
        if self.governed {
            // A failed attempt must not feed the AIMD window.
            let service_ns =
                if r.outcome.is_ok() { ctx.now().saturating_sub(lw.issued) } else { 0 };
            ctx.send(self.shard, EP_SHARD_IO_DONE, IoDoneMsg { n: 1, service_ns });
        }
        if self.phase == WPhase::Dead {
            return; // late completion after release
        }
        let op = lw.op;
        if r.outcome.is_ok() {
            merge_into(&mut self.settled, op.lo, op.lo + op.len);
            self.n_written += op.len;
            self.flush_written += op.len;
            ctx.metrics().count(keys::WRITE_EXTENTS, 1);
        } else {
            let attempts = op.attempts + 1;
            let budget = self.retry.map_or(1, |p| p.max_attempts);
            if attempts >= budget {
                // Degrade: the range settles so no barrier can hang on
                // a faulty OST; the bytes ride the outcome as degraded.
                merge_into(&mut self.settled, op.lo, op.lo + op.len);
                self.n_degraded += op.len;
                self.flush_degraded += op.len;
                ctx.metrics().count(keys::WRITE_DEGRADED, op.len);
            } else {
                self.n_retries += 1;
                ctx.metrics().count(keys::RETRY_ATTEMPTS, 1);
                let key = self.next_backoff;
                self.next_backoff += 1;
                self.backoffs.insert(key, WriteOp { lo: op.lo, len: op.len, attempts });
                self.retry_timers += 1;
                let delay = self.backoff_ns(key, attempts);
                let me = ctx.me();
                ctx.send_after(delay, me, EP_WB_RETRY, RetryTimerMsg {
                    slot: key,
                    attempt: attempts,
                });
            }
        }
        self.pump(ctx);
        self.maybe_drained(ctx);
    }
}

/// The write buffer's declared message protocol (see
/// [`crate::amt::protocol`]). Any change to its EPs, payload types, or
/// send sites must update this spec in the same commit.
pub fn buffer_protocol_spec() -> ProtocolSpec {
    use super::director::{EP_DIR_BUF_STARTED, EP_DIR_FLUSH_DONE, EP_DIR_WB_DROPPED};
    ProtocolSpec {
        chare: "WriteBuffer",
        module: "ckio/write.rs",
        handles: vec![
            ep_spec!(EP_WB_INIT, PayloadKind::Signal),
            ep_spec!(EP_WB_PIECE, PayloadKind::of::<WPieceMsg>()),
            ep_spec!(EP_WB_FLUSH, PayloadKind::Signal),
            ep_spec!(EP_BUF_DROP, PayloadKind::Signal),
            ep_spec!(EP_WB_CLOSE, PayloadKind::Signal),
            ep_spec!(EP_WB_WRITE_DONE, PayloadKind::of::<IoResult>()),
            ep_spec!(EP_BUF_PEER_FETCH, PayloadKind::of::<PeerFetchMsg>()),
            ep_spec!(EP_BUF_GRANT, PayloadKind::of::<GrantMsg>()),
            ep_spec!(EP_BUF_PEERS, PayloadKind::of::<PeersMsg>()),
            ep_spec!(EP_WB_RETRY, PayloadKind::of::<RetryTimerMsg>()),
            ep_spec!(EP_WB_WRITEBACK, PayloadKind::Signal),
        ],
        sends: vec![
            send_spec!("DataShard", EP_SHARD_REGISTER, PayloadKind::of::<RegisterMsg>()),
            send_spec!("DataShard", EP_SHARD_UNCLAIM, PayloadKind::of::<UnclaimMsg>()),
            send_spec!("DataShard", EP_SHARD_IO_REQ, PayloadKind::of::<IoReqMsg>()),
            send_spec!("DataShard", EP_SHARD_IO_DONE, PayloadKind::of::<IoDoneMsg>()),
            send_spec!("DataShard", EP_SHARD_IO_RECLAIM, PayloadKind::of::<ReclaimMsg>()),
            send_spec!("DataShard", EP_SHARD_MARK_CLEAN, PayloadKind::of::<MarkCleanMsg>()),
            send_spec!("DataShard", EP_SHARD_WB_DONE, PayloadKind::of::<WbDoneMsg>()),
            send_spec!("WriteBuffer", EP_WB_RETRY, PayloadKind::of::<RetryTimerMsg>()),
            send_spec!("WriteAssembler", EP_WA_PIECE_ACK, PayloadKind::of::<WPieceAckMsg>()),
            send_spec!("BufferChare", EP_BUF_PEER_DATA, PayloadKind::of::<PeerDataMsg>()),
            send_spec!("Director", EP_DIR_BUF_STARTED, PayloadKind::of::<BufStartedMsg>()),
            send_spec!("Director", EP_DIR_FLUSH_DONE, PayloadKind::of::<FlushDoneMsg>()),
            send_spec!("Director", EP_DIR_WB_DROPPED, PayloadKind::of::<WbDroppedMsg>()),
        ],
    }
}

impl Chare for WriteBuffer {
    fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
        match msg.ep {
            EP_WB_INIT => {
                // Claim the span *dirty* at the file's shard: from this
                // moment the chare is a peer source for read sessions
                // (read-after-write), and the store knows these bytes
                // must not drop without a writeback. The PeersMsg reply
                // is ignored — a write buffer consumes no peers.
                let me = ctx.me();
                if self.my_len > 0 {
                    ctx.send(self.shard, EP_SHARD_REGISTER, RegisterMsg {
                        file: self.file,
                        offset: self.my_lo,
                        len: self.my_len,
                        splinter: 0,
                        buffer: me,
                        pe: ctx.pe().0,
                        dirty: true,
                    });
                }
                ctx.advance(MICROS);
                ctx.send(self.director, super::director::EP_DIR_BUF_STARTED, BufStartedMsg {
                    session: self.session,
                });
            }
            EP_BUF_PEERS => {
                // The shard's answer to our registration: write buffers
                // produce data, they never consume peer slots.
                let _m: PeersMsg = msg.take();
            }
            EP_WB_PIECE => {
                let m: WPieceMsg = msg.take();
                debug_assert!(
                    m.offset >= self.my_lo && m.offset + m.len <= self.my_lo + self.my_len,
                    "piece [{}, {}) outside buffer span [{}, {})",
                    m.offset,
                    m.offset + m.len,
                    self.my_lo,
                    self.my_lo + self.my_len
                );
                if self.phase == WPhase::Filling {
                    merge_into(&mut self.covered, m.offset, m.offset + m.len);
                    if self.wopts.write_behind {
                        self.enqueue_completed_extents(m.offset, m.offset + m.len);
                    }
                    // A barrier already in progress extends over newly
                    // covered bytes (a put racing a flush/close joins
                    // the drain instead of leaking dirty).
                    if self.flush_waiting || (self.close_waiting && !self.wopts.park_dirty) {
                        self.enqueue_range(self.my_lo, self.my_lo + self.my_len);
                    }
                    self.pump(ctx);
                    self.serve_ready_peers(ctx);
                }
                // else: a piece racing past the session's close — ack it
                // (put completion stays exactly-once) but drop the data;
                // the session outcome was already delivered.
                ctx.advance(MICROS / 2);
                ctx.send(m.reply, EP_WA_PIECE_ACK, WPieceAckMsg { put: m.put, bytes: m.len });
            }
            EP_WB_WRITE_DONE => {
                let r: IoResult = msg.take();
                self.write_done(ctx, r);
            }
            EP_BUF_GRANT => {
                let g: GrantMsg = msg.take();
                // Writes arm no deadline timers (failures are discovered
                // at completion): the grant's deadline_ns is unused.
                self.asked = self.asked.saturating_sub(g.n);
                if self.phase == WPhase::Dead {
                    ctx.send(self.shard, EP_SHARD_IO_DONE, IoDoneMsg { n: g.n, service_ns: 0 });
                    return;
                }
                let mut issued = 0;
                for _ in 0..g.n {
                    if self.ops.is_empty() {
                        break;
                    }
                    self.issue_next(ctx);
                    issued += 1;
                }
                if issued < g.n {
                    ctx.send(self.shard, EP_SHARD_IO_DONE, IoDoneMsg {
                        n: g.n - issued,
                        service_ns: 0,
                    });
                }
            }
            EP_WB_RETRY => {
                let m: RetryTimerMsg = msg.take();
                self.retry_timers = self.retry_timers.saturating_sub(1);
                if let Some(op) = self.backoffs.remove(&m.slot) {
                    if self.phase != WPhase::Dead {
                        self.ops.push_back(op);
                        self.pump(ctx);
                    }
                }
                self.maybe_drained(ctx);
            }
            EP_WB_FLUSH => {
                // Drain barrier: every covered byte becomes a queued op
                // (idempotent against already-issued ranges), and the
                // director is acked only once nothing is outstanding.
                self.enqueue_range(self.my_lo, self.my_lo + self.my_len);
                self.flush_waiting = true;
                self.pump(ctx);
                ctx.advance(MICROS / 2);
                self.maybe_drained(ctx);
            }
            EP_WB_CLOSE => {
                // Close barrier: like a flush, then park. Lazy mode
                // (`park_dirty`) skips the drain — the span parks dirty
                // and eviction forces the writeback later.
                self.close_waiting = true;
                if !self.wopts.park_dirty {
                    self.enqueue_range(self.my_lo, self.my_lo + self.my_len);
                }
                self.pump(ctx);
                ctx.advance(MICROS / 2);
                self.maybe_drained(ctx);
            }
            EP_WB_WRITEBACK => {
                // The store evicted this parked span's dirty claims: the
                // data must reach the PFS before it may drop. The shard
                // holds an outstanding-writeback count until our
                // EP_SHARD_WB_DONE.
                if self.phase == WPhase::Dead {
                    ctx.send(self.shard, EP_SHARD_WB_DONE, WbDoneMsg { bytes: 0 });
                    return;
                }
                self.wb_waiting = true;
                self.wb_baseline = self.n_written;
                self.enqueue_range(self.my_lo, self.my_lo + self.my_len);
                self.pump(ctx);
                ctx.advance(MICROS / 2);
                self.maybe_drained(ctx);
            }
            EP_BUF_PEER_FETCH => {
                let f: PeerFetchMsg = msg.take();
                let in_span =
                    f.offset >= self.my_lo && f.offset + f.len <= self.my_lo + self.my_len;
                if self.phase == WPhase::Dead || !in_span || f.len == 0 {
                    self.peer_miss(ctx, &f);
                } else if contains_range(&self.covered, f.offset, f.offset + f.len) {
                    self.serve_peer(ctx, &f);
                } else {
                    // The covering piece is still in flight from its
                    // producer: serve on arrival — the wait *is* the
                    // read-after-write dedup.
                    self.peer_pending.push(f);
                }
            }
            EP_BUF_DROP => {
                // Clean eviction, purge, or a park whose file closed
                // underneath it. Dirty spans never take this path — the
                // store routes those through EP_WB_WRITEBACK.
                let was_live = self.phase != WPhase::Dead;
                if was_live && self.governed && (!self.live.is_empty() || self.asked > 0) {
                    let me = ctx.me();
                    ctx.send(self.shard, EP_SHARD_IO_RECLAIM, ReclaimMsg {
                        owner: me,
                        held: self.live.len() as u32,
                    });
                    self.asked = 0;
                }
                self.live.clear();
                if was_live && self.my_len > 0 {
                    // Idempotent after a shard-driven eviction (which
                    // already dropped the claims); FIFO-ordered after
                    // our own registration.
                    let me = ctx.me();
                    ctx.send(self.shard, EP_SHARD_UNCLAIM, UnclaimMsg {
                        file: self.file,
                        owner: me,
                    });
                }
                ctx.advance(MICROS / 2);
                self.release(ctx);
            }
            other => panic!("WriteBuffer: unknown ep {other}"),
        }
    }

    fn pack_size(&self) -> u64 {
        // Write buffers track intervals, not payload bytes (module
        // docs): descriptor-only size.
        256
    }

    impl_chare_any!();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(stripe: u64, write_behind: bool) -> WriteBuffer {
        WriteBuffer::new(
            SessionId(0),
            FileId(0),
            1000,
            100,
            WriteOptions { stripe_bytes: stripe, write_behind, park_dirty: false },
            2,
            ChareRef::new(CollectionId(0), 0),
            ChareRef::new(CollectionId(2), 0),
        )
    }

    #[test]
    fn merge_into_coalesces_and_sorts() {
        let mut v = Vec::new();
        merge_into(&mut v, 10, 20);
        merge_into(&mut v, 30, 40);
        assert_eq!(v, vec![(10, 20), (30, 40)]);
        merge_into(&mut v, 20, 30); // bridges both
        assert_eq!(v, vec![(10, 40)]);
        merge_into(&mut v, 5, 5); // empty: no-op
        assert_eq!(v, vec![(10, 40)]);
        assert_eq!(intervals_bytes(&v), 30);
    }

    #[test]
    fn contains_and_subtract_agree() {
        let v = vec![(10, 20), (30, 40)];
        assert!(contains_range(&v, 12, 18));
        assert!(!contains_range(&v, 15, 35));
        assert!(contains_range(&v, 15, 15), "empty range is always covered");
        assert_eq!(subtract_range(&v, 0, 50), vec![(0, 10), (20, 30), (40, 50)]);
        assert_eq!(subtract_range(&v, 12, 18), Vec::<(u64, u64)>::new());
        assert_eq!(subtract_range(&v, 15, 35), vec![(20, 30)]);
    }

    #[test]
    fn extents_are_stripe_aligned_relative_to_file_offset() {
        let b = mk(64, true);
        // Span [1000, 1100) against 64-byte stripes: boundaries at
        // 1024 and 1088 (absolute stripe grid).
        assert_eq!(b.extents, vec![(1000, 24), (1024, 64), (1088, 12)]);
        let whole = mk(1 << 20, true);
        assert_eq!(whole.extents, vec![(1000, 100)], "one stripe covers the span");
    }

    #[test]
    fn enqueue_range_clips_to_extents_and_never_double_issues() {
        let mut b = mk(64, false);
        merge_into(&mut b.covered, 1000, 1100);
        b.enqueue_range(1000, 1100);
        let got: Vec<(u64, u64)> = b.ops.iter().map(|o| (o.lo, o.len)).collect();
        assert_eq!(got, vec![(1000, 24), (1024, 64), (1088, 12)]);
        assert_eq!(intervals_bytes(&b.issued), 100);
        // Idempotent: a second barrier queues nothing new.
        b.enqueue_range(1000, 1100);
        assert_eq!(b.ops.len(), 3);
    }

    #[test]
    fn write_behind_waits_for_a_complete_stripe() {
        let mut b = mk(64, true);
        merge_into(&mut b.covered, 1024, 1060);
        b.enqueue_completed_extents(1024, 1060);
        assert!(b.ops.is_empty(), "half a stripe is not writable yet");
        merge_into(&mut b.covered, 1060, 1088);
        b.enqueue_completed_extents(1060, 1088);
        let got: Vec<(u64, u64)> = b.ops.iter().map(|o| (o.lo, o.len)).collect();
        assert_eq!(got, vec![(1024, 64)], "the completed stripe queues whole");
        assert_eq!(b.dirty_bytes(), 92, "queued but not yet settled stays dirty");
    }

    #[test]
    fn partial_coverage_flush_settles_only_covered_bytes() {
        let mut b = mk(1 << 20, false);
        merge_into(&mut b.covered, 1000, 1030);
        merge_into(&mut b.covered, 1050, 1100);
        b.enqueue_range(1000, 1100); // what EP_WB_FLUSH does
        let got: Vec<(u64, u64)> = b.ops.iter().map(|o| (o.lo, o.len)).collect();
        assert_eq!(got, vec![(1000, 30), (1050, 50)], "the gap is never written");
        assert_eq!(b.covered_bytes(), 80);
        // Settle both ops as the completion path would.
        for (lo, len) in got {
            merge_into(&mut b.settled, lo, lo + len);
        }
        assert_eq!(b.dirty_bytes(), 0);
    }

    #[test]
    fn drained_accounts_queue_inflight_and_backoffs() {
        let mut b = mk(1 << 20, false);
        assert!(b.drained());
        b.ops.push_back(WriteOp { lo: 1000, len: 10, attempts: 0 });
        assert!(!b.drained());
        b.ops.clear();
        b.retry_timers = 1;
        assert!(!b.drained());
        b.retry_timers = 0;
        assert!(b.drained());
    }

    #[test]
    fn backoff_grows_exponentially_caps_and_is_deterministic() {
        let b = mk(1 << 20, true).with_retry(RetryPolicy::default());
        let p = RetryPolicy::default();
        let spread = p.base_backoff_ns / 2;
        for attempt in 1..=6u32 {
            let got = b.backoff_ns(7, attempt);
            let exp = (p.base_backoff_ns << (attempt - 1)).min(p.max_backoff_ns);
            let jitter = (7u64.wrapping_mul(2_654_435_761) + u64::from(attempt)) % spread;
            assert_eq!(got, exp + jitter, "attempt {attempt}");
            assert_eq!(got, b.backoff_ns(7, attempt), "no RNG: replays must agree");
        }
    }

    #[test]
    fn fresh_buffer_is_filling_and_empty() {
        let b = mk(64, true);
        assert!(!b.is_parked());
        assert!(!b.is_dead());
        assert_eq!(b.covered_bytes(), 0);
        assert_eq!(b.dirty_bytes(), 0);
        assert_eq!(b.pending_ops(), 0);
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn zero_length_span_has_no_extents() {
        let b = WriteBuffer::new(
            SessionId(0),
            FileId(0),
            1000,
            0,
            WriteOptions::default(),
            2,
            ChareRef::new(CollectionId(0), 0),
            ChareRef::new(CollectionId(2), 0),
        );
        assert!(b.extents.is_empty());
        assert!(b.drained());
    }
}
