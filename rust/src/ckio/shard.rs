//! Data-plane shards (PR 3): the director's span store and admission
//! governor, sharded by `FileId`.
//!
//! PR 2 gave CkIO a shared resident-data plane, but parked *all* of it
//! on the director singleton: every claim registration, peer-fetch
//! lookup, LRU touch, and admission ticket funneled through one mailbox
//! on one PE — the exact serialization bottleneck the over-decomposition
//! model exists to avoid. PR 3 splits that state across a chare array of
//! [`DataShard`]s, one per PE (of which the first
//! [`crate::ckio::ServiceConfig::data_plane_shards`] are *active*), each owning
//! the [`SpanStore`] claims/parked arrays and the [`Governor`] for the
//! files that hash to it ([`shard_of`]).
//!
//! Routing invariant: **a file's entire data-plane state lives on
//! exactly one shard**. Same-file cooperation (prefetch dedup, admission
//! sequencing, parked-array rebind) therefore never crosses shards,
//! while sessions over distinct files talk to distinct shards and scale
//! with the shard count instead of queueing on one coordinator. `FileId`s
//! are dense indices assigned sequentially by the PFS, so the hash is a
//! plain modulo: perfectly balanced for the common sequential id
//! pattern, and trivially stable across close/re-open (since PR 5 the
//! active-shard count is fixed at boot —
//! [`crate::ckio::ServiceConfig::data_plane_shards`] — so routing can
//! never change for the life of the service).
//!
//! Message flow (all *hot-path* traffic is buffer↔shard; the director
//! keeps only session/file lifecycle):
//!
//! * `EP_SHARD_REGISTER` — a freshly initialized buffer chare announces
//!   its span. The shard resolves the buffer's splinter slots against
//!   existing claims (*before* registering the newcomer's own claim, so
//!   a buffer can never match itself and peer edges always point at
//!   earlier-registered arrays — the acyclicity argument of PR 2,
//!   enforced by the shard's atomic task), refreshes the LRU standing of
//!   matched parked arrays, registers the claim, and answers
//!   `EP_BUF_PEERS`.
//! * `EP_SHARD_UNCLAIM` — a dropping buffer retracts its claim. Sent by
//!   the buffer itself so it is FIFO-ordered after that buffer's own
//!   registration; a racing claim match at worst points a new session at
//!   a dying buffer, which answers with a peer *miss* and the requester
//!   falls back to the PFS (correctness never depends on the cache).
//! * `EP_SHARD_IO_REQ` / `EP_SHARD_IO_DONE` — the admission-governor
//!   ticket protocol (PR 2 ran it on the director; PR 3 re-homed it).
//!   Completions carry the observed service time, which feeds the AIMD
//!   feedback loop when the cap is adaptive; grants go straight back to
//!   the requesting buffer (`EP_BUF_GRANT`).
//! * `EP_SHARD_TAKE` / `EP_SHARD_PARK` / `EP_SHARD_PURGE` — the parked
//!   array lifecycle, driven by the director (rebind probe at session
//!   start, publish after a parking close fully acks, purge at final
//!   file close). Evictions are translated into `EP_BUF_DROP` sends
//!   here, shard-locally.
//! * `EP_SHARD_PLAN` — the plan-then-create probe (PR 4): before
//!   creating a `StoreAware` session's buffer array, the director asks
//!   this shard where the prospective spans' bytes already live. The
//!   store answers a `PlacementPlan` (per-span dominant source PE +
//!   covered bytes from [`SpanStore::plan_spans`]), the director places
//!   the buffers onto those PEs, and registration revalidates the
//!   snapshot — an unclaim racing the plan degrades to the fallback
//!   behavior (PFS reads), never to an assert. Since PR 5 the probe
//!   also carries the session's [`QosClass`]: the admission class is
//!   negotiated on the same round trip, before any buffer exists.
//! * `EP_SHARD_ADMIT` — the lightweight admission-register message
//!   (PR 5): session starts that run **no** plan probe (non-store-aware
//!   placements, including rebinds) announce their QoS class to the
//!   owning shard on the same path the plan would have taken. Exactly
//!   one of {plan probe, admit} fires per session start, so the
//!   per-class registration counters
//!   ([`DataShard::class_registrations`]) count sessions.
//!
//! Configuration (PR 5): the shard's store budget, admission cap,
//! policy, and adaptive mode come from the service-wide
//! [`crate::ckio::ServiceConfig`], applied **once at boot** via
//! [`DataShard::boot_configure`] — synchronously, before any message is
//! in flight (like the director-ref patching). The PR 2–4 runtime
//! shard-configuration message, its "last writer wins per shard"
//! semantics, and the director's idle-barrier re-sharding no longer
//! exist.
//!
//! Observability: the shard maintains the `ckio.store.resident_bytes`
//! gauge as an *add-delta* (each shard contributes the change in its own
//! residency, so the gauge is the sum over shards — with one shard this
//! is exactly the PR 2 value; with many, PR 2's `set()` would have
//! silently reported only the last-writing shard). `ckio.store.*` and
//! `ckio.governor.throttled` counters land in the engine-global sink and
//! sum across shards by construction. Each shard also counts the
//! data-plane messages it processed ([`DataShard::msgs_processed`]);
//! the harness turns those into the `ckio.shard.msgs_max`/`_mean`
//! imbalance pair.

use std::collections::{HashMap, HashSet};

use crate::amt::chare::{Chare, ChareRef, CollectionId};
use crate::amt::engine::Ctx;
use crate::amt::msg::{Ep, Msg};
use crate::amt::protocol::{PayloadKind, ProtocolSpec};
use crate::amt::time::MICROS;
use crate::amt::topology::Pe;
use crate::impl_chare_any;
use crate::metrics::keys;
use crate::pfs::layout::FileId;
use crate::trace::{names as trace_names, Lane as TraceLane, TraceCategory};
use crate::{ep_spec, send_spec};

use super::buffer::{
    GrantMsg, IoDoneMsg, IoReqMsg, PeerSlot, PeersMsg, ReclaimMsg, EP_BUF_DROP, EP_BUF_GRANT,
    EP_BUF_PEERS,
};
use super::director::{PlanReplyMsg, TakeReplyMsg, EP_DIR_PLAN_REPLY, EP_DIR_TAKE_REPLY};
use super::governor::{Governor, QosClass, NUM_CLASSES};
use super::options::{RetryPolicy, ServiceConfig};
use super::store::{slot_extents, BufKey, Evicted, SpanStore};
use super::write::EP_WB_WRITEBACK;

/// Buffer chare: register a span claim and resolve peer sources.
pub const EP_SHARD_REGISTER: Ep = 1;
/// Buffer chare: retract a claim (the buffer dropped its data).
pub const EP_SHARD_UNCLAIM: Ep = 2;
/// Director: probe for an exactly matching parked array (reuse rebind).
pub const EP_SHARD_TAKE: Ep = 3;
/// Director: publish a fully parked array into the store.
pub const EP_SHARD_PARK: Ep = 4;
/// Director: a file finally closed — release its claims/parked arrays.
pub const EP_SHARD_PURGE: Ep = 5;
/// Buffer chare: an owner died/dropped — reclaim its held tickets and
/// queued demand (PR 8). Without this, a buffer torn down while holding
/// tickets (or with requests still queued in the governor) leaks cap
/// forever: the governor's inflight count never decrements and queued
/// entries for the dead owner occupy WDRR slots.
pub const EP_SHARD_IO_RECLAIM: Ep = 6;
/// Buffer chare: request PFS read tickets from the admission governor.
pub const EP_SHARD_IO_REQ: Ep = 7;
/// Buffer chare: return PFS read tickets (with observed service time).
pub const EP_SHARD_IO_DONE: Ep = 8;
/// Director: plan a prospective session's reader placement against the
/// span store (PR 4's plan-then-create round trip; carries the QoS
/// class since PR 5).
pub const EP_SHARD_PLAN: Ep = 9;
/// Director: register a starting session's QoS class (PR 5) — the
/// lightweight stand-in for the plan probe on non-store-aware starts
/// and rebinds. Payload: the bare [`QosClass`] (routing already picked
/// this shard; fire-and-forget).
pub const EP_SHARD_ADMIT: Ep = 10;
/// Write buffer: its dirty span reached the PFS durably (PR 10) — flip
/// the claim clean. The claim itself stays: it keeps serving
/// read-after-write peer fetches.
pub const EP_SHARD_MARK_CLEAN: Ep = 11;
/// Write buffer: a forced writeback (dirty eviction/purge) finished —
/// one outstanding writeback drains from the shard's pending count.
pub const EP_SHARD_WB_DONE: Ep = 12;

/// The shard a file's data-plane state lives on. `FileId`s are dense
/// sequential indices, so plain modulo is balanced *and* stable — the
/// routing invariant every test of claim locality relies on.
pub fn shard_of(file: FileId, active_shards: u32) -> u32 {
    file.0 % active_shards.max(1)
}

/// Buffer → shard: register `[offset, offset+len)` of `file` (held by
/// `buffer`, splintered at `splinter`) and resolve its slots against
/// existing claims.
#[derive(Debug)]
pub struct RegisterMsg {
    pub file: FileId,
    pub offset: u64,
    pub len: u64,
    /// The buffer's *clamped* splinter size (0 = whole-span slot), so
    /// shard-side slot extents agree bit-for-bit with the buffer's.
    pub splinter: u64,
    pub buffer: ChareRef,
    /// The PE the buffer runs on — recorded with its claim so placement
    /// plans and locality metrics know where the bytes live.
    pub pe: u32,
    /// The span holds unwritten data (PR 10 write plane): read-side
    /// buffers always register clean; write buffers register dirty and
    /// flip clean via [`EP_SHARD_MARK_CLEAN`] once durable.
    pub dirty: bool,
}

/// Write buffer → shard: `owner`'s dirty span of `file` is durable now.
#[derive(Debug)]
pub struct MarkCleanMsg {
    pub file: FileId,
    pub owner: ChareRef,
}

/// Write buffer → shard: a forced writeback finished, `bytes` written.
#[derive(Debug)]
pub struct WbDoneMsg {
    pub bytes: u64,
}

/// Buffer → shard: this buffer dropped its data; retract its claim.
#[derive(Debug)]
pub struct UnclaimMsg {
    pub file: FileId,
    pub owner: ChareRef,
}

/// Director → shard: is an identically shaped parked array available?
#[derive(Debug)]
pub struct TakeMsg {
    pub key: BufKey,
    /// Correlates the reply with the director's stashed session start.
    pub token: u64,
}

/// Director → shard: plan a prospective session's reader placement
/// (PR 4). Carries the exact partition the director would create —
/// [`super::session::buffer_span_of`] over `readers` spans, splintered
/// at `splinter` (unclamped; the store clamps per buffer exactly as
/// [`super::buffer::BufferChare::new`] does) — so the plan's slot
/// extents agree bit-for-bit with what the buffers will register.
#[derive(Debug)]
pub struct PlanMsg {
    pub file: FileId,
    pub offset: u64,
    pub bytes: u64,
    pub readers: u32,
    pub splinter: u64,
    /// The starting session's QoS class (PR 5): negotiated on this
    /// probe, before any buffer exists.
    pub class: QosClass,
    /// Correlates the reply with the director's stashed session start.
    pub token: u64,
}

/// Director → shard: publish a fully parked array for reuse.
#[derive(Debug)]
pub struct ParkMsg {
    pub key: BufKey,
    pub buffers: CollectionId,
    pub nbuf: u32,
    pub resident_bytes: u64,
}

/// One data-plane shard.
pub struct DataShard {
    index: u32,
    /// Patched right after boot (pre-run, like the managers' director).
    pub director: ChareRef,
    store: SpanStore,
    governor: Governor,
    /// Data-plane messages processed — claims, tickets, parked-array
    /// lifecycle (the imbalance metric's numerator).
    msgs: u64,
    /// Sessions registered per QoS class (PR 5): bumped by the plan
    /// probe or the admit message, exactly once per session start on
    /// this shard (monotonic).
    class_registered: [u64; NUM_CLASSES],
    /// Last residency this shard contributed to the global gauge.
    resident_reported: f64,
    /// Last dirty-byte total this shard contributed to the global
    /// `ckio.store.dirty_bytes` gauge (add-delta, like residency).
    dirty_reported: f64,
    /// Forced writebacks signalled to evicted dirty write buffers and
    /// not yet acknowledged via [`EP_SHARD_WB_DONE`] (PR 10). Drained
    /// in this file; leak-checked in `assert_service_clean` — a nonzero
    /// count at quiescence means a dirty array was released and its
    /// writeback never finished.
    pending_writebacks: u64,
    /// Last cap published on the `ckio.governor.cap` gauge.
    cap_reported: Option<u32>,
    /// The service-wide retry policy (PR 8), stashed at boot. `Some`
    /// turns grants into *deadlined* grants: each one carries the
    /// deadline the requesting buffer should arm its timeout at, derived
    /// from the governor's observed service-time window.
    retry: Option<RetryPolicy>,
    /// Buffers with an open I/O-wait overlap window (PR 9): owner → the
    /// PE whose scheduler hint was raised when the governor first queued
    /// a ticket for that owner. Closed (and the hint lowered) when the
    /// owner's queued demand drains to zero — by grant delivery or by
    /// reclaim — so every `Ctx::io_wait_begin` is balanced by exactly
    /// one `Ctx::io_wait_end`. Drained on reclaim; leak-checked via
    /// [`DataShard::io_waiting`] in `assert_service_clean`.
    waiting: HashMap<ChareRef, u32>,
}

impl DataShard {
    pub fn new(index: u32, director: ChareRef) -> DataShard {
        DataShard {
            index,
            director,
            store: SpanStore::new(),
            governor: Governor::new(),
            msgs: 0,
            class_registered: [0; NUM_CLASSES],
            resident_reported: 0.0,
            dirty_reported: 0.0,
            pending_writebacks: 0,
            cap_reported: None,
            retry: None,
            waiting: HashMap::new(),
        }
    }

    /// Apply the service-wide configuration (PR 5). Called exactly once
    /// per shard by `CkIo::boot_with`, synchronously, before any message
    /// is in flight — so there is no configuration race and no runtime
    /// reconfiguration path at all. Returns the configured cap's gauge
    /// contribution (the caller sums it into `ckio.governor.cap`, since
    /// no `Ctx` exists at boot).
    pub fn boot_configure(&mut self, cfg: &ServiceConfig, budget_share: Option<u64>) -> f64 {
        if let Some(b) = budget_share {
            self.store.set_budget(b);
        }
        self.governor.configure(cfg.max_inflight_reads, cfg.admission, cfg.adaptive_admission);
        self.retry = cfg.retry;
        self.cap_reported = self.governor.cap();
        self.cap_reported.unwrap_or(0) as f64
    }

    /// The deadline to stamp on a grant: the governor's observed
    /// service-time window scaled by the policy's multiplier (0 when the
    /// service runs without a retry policy — the buffer arms no timer).
    fn grant_deadline(&self) -> u64 {
        match &self.retry {
            Some(r) => self.governor.deadline_ns(r.deadline_mult, r.default_deadline_ns),
            None => 0,
        }
    }

    /// Contribute this shard's residency *change* to the global gauge
    /// (sum-over-shards semantics; see the module docs).
    fn update_resident_gauge(&mut self, ctx: &mut Ctx<'_>) {
        let now = self.store.resident_bytes() as f64;
        if now != self.resident_reported {
            ctx.metrics().add(keys::STORE_RESIDENT, now - self.resident_reported);
            self.resident_reported = now;
        }
    }

    /// Publish this shard's cap *change* on the `ckio.governor.cap`
    /// gauge. Like the resident-bytes gauge, the value is an add-delta —
    /// the gauge reads as the **sum of per-shard caps**, i.e. the
    /// cluster-wide admission ceiling over the active shards (and
    /// exactly the cap itself when one shard is active), never a
    /// last-writing shard's private view. Boot configuration publishes
    /// through `CkIo::boot_with` (no `Ctx` exists then); after boot the
    /// only thing that can move a cap is the AIMD feedback loop
    /// ([`Governor::complete`]), so every change seen here counts as an
    /// adaptation.
    fn publish_cap(&mut self, ctx: &mut Ctx<'_>) {
        let cap = self.governor.cap();
        if cap != self.cap_reported {
            let old = self.cap_reported.unwrap_or(0);
            let new = cap.unwrap_or(0);
            ctx.metrics().add(keys::GOV_CAP, new as f64 - old as f64);
            if self.governor.is_adaptive() {
                ctx.metrics().count(keys::GOV_ADAPTATIONS, 1);
            }
            if ctx.trace().on(TraceCategory::Governor) {
                // Annotate the cap move with *why* AIMD moved it.
                let note =
                    self.governor.last_adapt_cause().map(|c| c.label()).unwrap_or("configured");
                let now = ctx.now();
                ctx.trace().instant(
                    now,
                    TraceCategory::Governor,
                    trace_names::GOVERNOR_CAP,
                    TraceLane::Shard(self.index),
                    u64::from(new),
                    u64::from(old),
                    note,
                );
            }
            self.cap_reported = cap;
        }
    }

    /// Contribute this shard's dirty-byte *change* to the global gauge
    /// (add-delta, same sum-over-shards semantics as residency).
    fn update_dirty_gauge(&mut self, ctx: &mut Ctx<'_>) {
        let now = self.store.dirty_bytes() as f64;
        if now != self.dirty_reported {
            ctx.metrics().add(keys::STORE_DIRTY, now - self.dirty_reported);
            self.dirty_reported = now;
        }
    }

    /// Release every element of an evicted/purged buffer-chare array. A
    /// clean array is dropped outright (`EP_BUF_DROP`); an array that
    /// still held dirty claims (PR 10: a lazily closed write session's
    /// parked data) must not lose those bytes — its elements are told to
    /// write back first (`EP_WB_WRITEBACK`), each acknowledging with
    /// [`EP_SHARD_WB_DONE`] before freeing itself.
    fn release_evicted(&mut self, ctx: &mut Ctx<'_>, evicted: Vec<Evicted>) {
        for e in evicted {
            if e.dirty_bytes > 0 {
                for b in 0..e.nbuf {
                    ctx.signal(ChareRef::new(e.buffers, b), EP_WB_WRITEBACK);
                }
                self.pending_writebacks += u64::from(e.nbuf);
                ctx.metrics().count(keys::STORE_DIRTY_WRITEBACKS, 1);
                if ctx.trace().on(TraceCategory::Store) {
                    let now = ctx.now();
                    ctx.trace().instant(
                        now,
                        TraceCategory::Store,
                        trace_names::STORE_WRITEBACK,
                        TraceLane::Shard(self.index),
                        e.dirty_bytes,
                        u64::from(e.nbuf),
                        "",
                    );
                }
            } else {
                for b in 0..e.nbuf {
                    ctx.signal(ChareRef::new(e.buffers, b), EP_BUF_DROP);
                }
            }
            ctx.metrics().count(keys::BUFFER_CACHE_EVICTIONS, 1);
            ctx.metrics().count(keys::STORE_EVICTED, e.resident_bytes);
        }
    }

    // ------------------------------------------------------------------
    // test / driver inspection
    // ------------------------------------------------------------------

    /// This shard's index in the array.
    pub fn index(&self) -> u32 {
        self.index
    }

    /// The span store slice owned by this shard.
    pub fn span_store(&self) -> &SpanStore {
        &self.store
    }

    /// The admission governor slice owned by this shard.
    pub fn admission(&self) -> &Governor {
        &self.governor
    }

    /// Data-plane messages this shard has processed.
    pub fn msgs_processed(&self) -> u64 {
        self.msgs
    }

    /// Sessions registered under `class` on this shard (PR 5): the
    /// class rode either the plan probe or the admit message, so this
    /// counts session starts per class.
    pub fn class_registrations(&self, class: QosClass) -> u64 {
        self.class_registered[class.index()]
    }

    /// Record a starting session's class (plan probe or admit message).
    fn register_class(&mut self, class: QosClass) {
        self.class_registered[class.index()] += 1;
    }

    /// Owners with an I/O-wait overlap window currently open on this
    /// shard (PR 9). Leak check: must be 0 at quiescence — a non-empty
    /// map means a PE's scheduler hint was raised and never lowered.
    pub fn io_waiting(&self) -> usize {
        self.waiting.len()
    }

    /// Forced writebacks still outstanding on this shard (PR 10). Leak
    /// check: must be 0 at quiescence — a nonzero count means a dirty
    /// array was evicted and its data never reached the PFS.
    pub fn pending_writebacks(&self) -> u64 {
        self.pending_writebacks
    }

    /// Close `owner`'s overlap window if its queued governor demand has
    /// fully drained (a partial grant leaves the window open: the owner
    /// is still waiting for the rest).
    fn maybe_close_wait(&mut self, ctx: &mut Ctx<'_>, owner: ChareRef) {
        if self.waiting.contains_key(&owner) && self.governor.queued_for(owner) == 0 {
            let pe = self.waiting.remove(&owner).expect("checked above");
            ctx.io_wait_end(Pe(pe));
        }
    }
}

/// The shard's declared message protocol (see [`crate::amt::protocol`]).
/// Any change to its EPs, payload types, or send sites must update this
/// spec in the same commit.
pub fn protocol_spec() -> ProtocolSpec {
    ProtocolSpec {
        chare: "DataShard",
        module: "ckio/shard.rs",
        handles: vec![
            ep_spec!(EP_SHARD_REGISTER, PayloadKind::of::<RegisterMsg>()),
            ep_spec!(EP_SHARD_UNCLAIM, PayloadKind::of::<UnclaimMsg>()),
            ep_spec!(EP_SHARD_TAKE, PayloadKind::of::<TakeMsg>()),
            ep_spec!(EP_SHARD_PARK, PayloadKind::of::<ParkMsg>()),
            ep_spec!(EP_SHARD_PURGE, PayloadKind::of::<FileId>()),
            ep_spec!(EP_SHARD_IO_RECLAIM, PayloadKind::of::<ReclaimMsg>()),
            ep_spec!(EP_SHARD_IO_REQ, PayloadKind::of::<IoReqMsg>()),
            ep_spec!(EP_SHARD_IO_DONE, PayloadKind::of::<IoDoneMsg>()),
            ep_spec!(EP_SHARD_PLAN, PayloadKind::of::<PlanMsg>()),
            ep_spec!(EP_SHARD_ADMIT, PayloadKind::of::<QosClass>()),
            ep_spec!(EP_SHARD_MARK_CLEAN, PayloadKind::of::<MarkCleanMsg>()),
            ep_spec!(EP_SHARD_WB_DONE, PayloadKind::of::<WbDoneMsg>()),
        ],
        sends: vec![
            send_spec!("BufferChare", EP_BUF_PEERS, PayloadKind::of::<PeersMsg>()),
            send_spec!("BufferChare", EP_BUF_GRANT, PayloadKind::of::<GrantMsg>()),
            send_spec!("BufferChare", EP_BUF_DROP, PayloadKind::Signal),
            send_spec!("WriteBuffer", EP_BUF_PEERS, PayloadKind::of::<PeersMsg>()),
            send_spec!("WriteBuffer", EP_BUF_GRANT, PayloadKind::of::<GrantMsg>()),
            send_spec!("WriteBuffer", EP_WB_WRITEBACK, PayloadKind::Signal),
            send_spec!("Director", EP_DIR_TAKE_REPLY, PayloadKind::of::<TakeReplyMsg>()),
            send_spec!("Director", EP_DIR_PLAN_REPLY, PayloadKind::of::<PlanReplyMsg>()),
        ],
    }
}

impl Chare for DataShard {
    fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
        // Every message is data-plane traffic now (PR 5 moved shard
        // configuration to boot time), so all of it counts toward the
        // msgs_max/mean imbalance pair.
        self.msgs += 1;
        match msg.ep {
            EP_SHARD_REGISTER => {
                let m: RegisterMsg = msg.take();
                // Resolve before registering: the newcomer can never
                // match itself, and matches always point at
                // earlier-registered arrays (acyclic peer graph).
                let peers: Vec<PeerSlot> = slot_extents(m.offset, m.len, m.splinter)
                    .into_iter()
                    .enumerate()
                    .filter(|&(_, (_, slen))| slen > 0)
                    .filter_map(|(i, (slo, slen))| {
                        self.store.find_cover_claim(m.file, slo, slen).map(|c| PeerSlot {
                            slot: i as u32,
                            owner: c.owner,
                            owner_pe: c.owner_pe,
                        })
                    })
                    .collect();
                // Serving peers keeps a parked array hot: refresh its LRU
                // standing (once per distinct array, not per slot).
                let owners: HashSet<CollectionId> =
                    peers.iter().map(|p| p.owner.collection).collect();
                for owner in owners {
                    self.store.touch(owner);
                }
                self.store.add_claim(m.file, m.offset, m.len, m.buffer, m.pe, m.dirty);
                if m.dirty {
                    self.update_dirty_gauge(ctx);
                }
                ctx.advance(MICROS);
                ctx.send(m.buffer, EP_BUF_PEERS, PeersMsg { peers });
            }
            EP_SHARD_MARK_CLEAN => {
                let m: MarkCleanMsg = msg.take();
                self.store.mark_clean(m.file, m.owner);
                self.update_dirty_gauge(ctx);
                ctx.advance(MICROS / 2);
            }
            EP_SHARD_WB_DONE => {
                let m: WbDoneMsg = msg.take();
                assert!(
                    self.pending_writebacks > 0,
                    "DataShard: writeback ack without an outstanding writeback"
                );
                self.pending_writebacks -= 1;
                ctx.metrics().count(keys::STORE_DIRTY_WRITEBACK_BYTES, m.bytes);
                ctx.advance(MICROS / 2);
            }
            EP_SHARD_PLAN => {
                let m: PlanMsg = msg.take();
                // The probe doubles as the admission-class negotiation
                // (PR 5): the shard learns who is coming before any
                // buffer of the session exists.
                self.register_class(m.class);
                // One probe answers "who holds these bytes" for the whole
                // prospective partition: the store aggregates covering
                // claims per span and names each span's dominant source
                // PE. The reply is a *snapshot* — the director creates
                // the buffers from it, and registration revalidates.
                let slots =
                    self.store.plan_spans(m.file, m.offset, m.bytes, m.readers, m.splinter);
                if ctx.trace().on(TraceCategory::Place) {
                    let now = ctx.now();
                    ctx.trace().instant(
                        now,
                        TraceCategory::Place,
                        trace_names::PLACE_PLAN,
                        TraceLane::Shard(self.index),
                        m.bytes,
                        u64::from(m.readers),
                        m.class.label(),
                    );
                }
                ctx.advance(MICROS);
                ctx.send(
                    self.director,
                    EP_DIR_PLAN_REPLY,
                    PlanReplyMsg { token: m.token, slots },
                );
            }
            EP_SHARD_ADMIT => {
                let class: QosClass = msg.take();
                self.register_class(class);
                ctx.advance(MICROS / 2);
            }
            EP_SHARD_UNCLAIM => {
                let m: UnclaimMsg = msg.take();
                self.store.drop_claims_of(m.file, m.owner);
                self.update_dirty_gauge(ctx);
                ctx.advance(MICROS / 2);
            }
            EP_SHARD_TAKE => {
                let m: TakeMsg = msg.take();
                let found = self.store.take_exact(&m.key);
                if found.is_some() {
                    // The rebound session is served entirely from
                    // resident data: a full-range store hit.
                    ctx.metrics().count(keys::STORE_HIT, m.key.bytes);
                    self.update_resident_gauge(ctx);
                }
                if ctx.trace().on(TraceCategory::Store) {
                    let now = ctx.now();
                    ctx.trace().instant(
                        now,
                        TraceCategory::Store,
                        trace_names::STORE_TAKE,
                        TraceLane::Shard(self.index),
                        u64::from(found.is_some()),
                        m.key.bytes,
                        if found.is_some() { "hit" } else { "miss" },
                    );
                }
                ctx.advance(MICROS);
                ctx.send(self.director, EP_DIR_TAKE_REPLY, TakeReplyMsg { token: m.token, found });
            }
            EP_SHARD_PARK => {
                let m: ParkMsg = msg.take();
                let evicted = self.store.park(m.key, m.buffers, m.nbuf, m.resident_bytes);
                self.release_evicted(ctx, evicted);
                self.update_resident_gauge(ctx);
                self.update_dirty_gauge(ctx);
                if ctx.trace().on(TraceCategory::Store) {
                    let now = ctx.now();
                    ctx.trace().instant(
                        now,
                        TraceCategory::Store,
                        trace_names::STORE_PARK,
                        TraceLane::Shard(self.index),
                        m.resident_bytes,
                        u64::from(m.nbuf),
                        "",
                    );
                }
                ctx.advance(MICROS);
            }
            EP_SHARD_PURGE => {
                let file: FileId = msg.take();
                let purged = self.store.purge_file(file);
                self.release_evicted(ctx, purged);
                self.update_resident_gauge(ctx);
                self.update_dirty_gauge(ctx);
                if ctx.trace().on(TraceCategory::Store) {
                    let now = ctx.now();
                    ctx.trace().instant(
                        now,
                        TraceCategory::Store,
                        trace_names::STORE_PURGE,
                        TraceLane::Shard(self.index),
                        u64::from(file.0),
                        0,
                        "",
                    );
                }
                ctx.advance(MICROS);
            }
            EP_SHARD_IO_REQ => {
                let m: IoReqMsg = msg.take();
                let now = ctx.now();
                let granted = self.governor.request(m.buffer, m.want, m.sess_bytes, m.class, now);
                if granted < m.want {
                    // I/O-aware overlap hint (PR 9, after TASIO,
                    // arXiv 2011.13823): the requesting buffer's PE now
                    // has an admission wait open — raise the scheduler
                    // hint so background-chare work run there is charged
                    // to the overlap counters until the demand drains.
                    if self.waiting.insert(m.buffer, m.pe).is_none() {
                        ctx.io_wait_begin(Pe(m.pe));
                    }
                    ctx.metrics().count(keys::GOV_THROTTLED, (m.want - granted) as u64);
                    if ctx.trace().on(TraceCategory::Ticket) {
                        ctx.trace().instant(
                            now,
                            TraceCategory::Ticket,
                            trace_names::TICKET_ENQUEUE,
                            TraceLane::Shard(self.index),
                            u64::from(m.want - granted),
                            m.sess_bytes,
                            m.class.label(),
                        );
                    }
                }
                if granted > 0 {
                    ctx.metrics().count(m.class.granted_key(), granted as u64);
                    // Immediately admitted tickets waited zero ns; record
                    // them so the per-class wait quantiles cover *all*
                    // admissions, not just the deferred ones.
                    ctx.metrics().record(m.class.wait_key(), 0);
                    if ctx.trace().on(TraceCategory::Ticket) {
                        ctx.trace().complete(
                            now,
                            0,
                            TraceCategory::Ticket,
                            trace_names::TICKET_WAIT,
                            TraceLane::Shard(self.index),
                            0,
                            u64::from(granted),
                            0,
                            m.class.label(),
                        );
                    }
                    let deadline_ns = self.grant_deadline();
                    ctx.send(m.buffer, EP_BUF_GRANT, GrantMsg { n: granted, deadline_ns });
                }
                ctx.advance(MICROS);
            }
            EP_SHARD_IO_RECLAIM => {
                let m: ReclaimMsg = msg.take();
                let now = ctx.now();
                let (removed, grants) = self.governor.reclaim(m.owner, m.held, now);
                // The reclaimed owner is gone: its overlap window (if
                // any) closes now, grantless.
                if let Some(pe) = self.waiting.remove(&m.owner) {
                    ctx.io_wait_end(Pe(pe));
                }
                ctx.metrics().count(keys::GOV_RECLAIMED, u64::from(m.held) + u64::from(removed));
                // Reclaimed capacity goes straight back to waiting
                // sessions: deliver whatever the drain freed.
                let deadline_ns = self.grant_deadline();
                for g in grants {
                    ctx.metrics().count(g.class.granted_key(), g.n as u64);
                    ctx.metrics().record(g.class.wait_key(), g.waited_ns);
                    ctx.send(g.owner, EP_BUF_GRANT, GrantMsg { n: g.n, deadline_ns });
                    self.maybe_close_wait(ctx, g.owner);
                }
                self.publish_cap(ctx);
                ctx.advance(MICROS / 2);
            }
            EP_SHARD_IO_DONE => {
                let m: IoDoneMsg = msg.take();
                let now = ctx.now();
                if ctx.trace().on(TraceCategory::Ticket) {
                    ctx.trace().instant(
                        now,
                        TraceCategory::Ticket,
                        trace_names::TICKET_DONE,
                        TraceLane::Shard(self.index),
                        u64::from(m.n),
                        m.service_ns,
                        "",
                    );
                }
                for g in self.governor.complete(m.n, m.service_ns, now) {
                    ctx.metrics().count(g.class.granted_key(), g.n as u64);
                    ctx.metrics().record(g.class.wait_key(), g.waited_ns);
                    if ctx.trace().on(TraceCategory::Ticket) {
                        // The whole wait is one backdated complete-event:
                        // begin/end pairing would break on partial grants.
                        ctx.trace().complete(
                            now.saturating_sub(g.waited_ns),
                            g.waited_ns,
                            TraceCategory::Ticket,
                            trace_names::TICKET_WAIT,
                            TraceLane::Shard(self.index),
                            0,
                            u64::from(g.n),
                            0,
                            g.class.label(),
                        );
                    }
                    let deadline_ns = self.grant_deadline();
                    ctx.send(g.owner, EP_BUF_GRANT, GrantMsg { n: g.n, deadline_ns });
                    self.maybe_close_wait(ctx, g.owner);
                }
                self.publish_cap(ctx);
                ctx.advance(MICROS);
            }
            other => panic!("DataShard: unknown ep {other}"),
        }
    }

    impl_chare_any!();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_of_is_stable_and_balanced_for_dense_ids() {
        // Dense sequential FileIds spread perfectly over the modulus.
        for active in [1u32, 2, 4, 8] {
            let mut counts = vec![0u32; active as usize];
            for f in 0..64u32 {
                counts[shard_of(FileId(f), active) as usize] += 1;
            }
            assert!(counts.iter().all(|&c| c == 64 / active));
        }
        // Stability: the same file always lands on the same shard.
        assert_eq!(shard_of(FileId(5), 4), shard_of(FileId(5), 4));
        // Degenerate modulus is clamped, never a divide-by-zero.
        assert_eq!(shard_of(FileId(7), 0), 0);
    }

    #[test]
    fn same_file_never_crosses_shards() {
        // The routing invariant: every piece of a file's data-plane
        // state uses the same shard_of value, whatever the caller.
        for f in 0..32u32 {
            let s = shard_of(FileId(f), 8);
            assert!(s < 8);
            assert_eq!(s, shard_of(FileId(f), 8));
        }
    }
}
