//! Director chare (paper §III-C.1) — since PR 3, a *thin lifecycle
//! coordinator*.
//!
//! The singleton drives file opens through the MDS, creates the
//! per-session buffer-chare array, announces sessions to the manager
//! group, fires the user's `opened`/`ready`/`closed` callbacks once every
//! participant has acknowledged, and sequences session/file teardown.
//! That — and only that — is what still runs here.
//!
//! # Coordinator vs. data-plane shards (PR 3)
//!
//! PR 2 also parked the span store and the admission governor on this
//! singleton, which made every hot-path event — claim registration,
//! peer-fetch resolution, LRU touch, admission ticket — serialize
//! through one mailbox on one PE. PR 3 moves all of that into the
//! [`super::shard::DataShard`] chare array: each shard owns the store
//! and governor state for the `FileId`s that hash to it
//! ([`super::shard::shard_of`]; the active shard count comes from
//! [`super::ServiceConfig::data_plane_shards`], fixed at boot). The
//! director's remaining involvement with the data plane is strictly
//! lifecycle-shaped, one message per event, always to the single shard
//! owning the file:
//!
//! * **session start** — buffers register/resolve *themselves* with
//!   their shard (`EP_SHARD_REGISTER` → `EP_BUF_PEERS`); the director
//!   only passes them the shard's address. For a `reuse_buffers` start
//!   it first probes the shard for an exactly matching parked array
//!   (`EP_SHARD_TAKE` → [`EP_DIR_TAKE_REPLY`]) and then either rebinds
//!   the returned array or creates a fresh one. Since PR 4 a fresh
//!   start under [`super::options::ReaderPlacement::StoreAware`] is
//!   **two-phase — plan, then create**: the director probes the owning
//!   shard (`EP_SHARD_PLAN` → [`EP_DIR_PLAN_REPLY`]) for a
//!   `PlacementPlan` (per-span dominant peer-source PE + resident-byte
//!   counts out of the span store) and only then materializes the
//!   placement, mapping each buffer chare onto the PE of its dominant
//!   source (`Placement::Explicit` built from the plan; fallback PEs
//!   where nothing is resident). The plan is a snapshot racing ordinary
//!   data-plane churn: registration revalidates it at the shard, a
//!   vanished claim degrades that buffer to plain PFS reads (counted on
//!   `ckio.place.degraded`), and a plan reply arriving after the file's
//!   final close resumes exactly as a late take reply does — plans are
//!   never cached, so a close/re-open cycle cannot see a stale one,
//! * **session close** — a parking close publishes the fully parked
//!   array to the shard (`EP_SHARD_PARK`) once every ack is in; a
//!   dropping close just drops the array (each buffer retracts its own
//!   claim at the shard),
//! * **file close** — the owning shard purges the file's claims and
//!   parked arrays (`EP_SHARD_PURGE`).
//!
//! The director-side governor ticket protocol of PR 2 no longer exists
//! here at all: buffers talk straight to their shard
//! (`EP_SHARD_IO_REQ`/`EP_SHARD_IO_DONE`). Net effect: same-file
//! cooperation never crosses shards, and session churn over distinct
//! files scales with the shard count instead of queueing on one chare.
//!
//! Concurrency (PR 1): the director is genuinely multi-session —
//!
//! * **opens are refcounted**: concurrent or repeated opens of the same
//!   file share one MDS transaction / manager broadcast (later opens are
//!   answered from the file table); each `close` decrements, and only the
//!   last one tears the file down everywhere,
//! * any number of sessions — same file or distinct files — may be open,
//!   reading, and closing at once; all coordination state is keyed by
//!   `SessionId`,
//! * **teardown drains**: buffers answer every queued fetch (data or
//!   modeled NACK) before acking, managers NACK reads that arrive after
//!   the drop, assemblers are told so late pieces are tolerated — no
//!   read callback is ever stranded or fired twice,
//! * **buffer reuse** (`SessionOptions::reuse_buffers`): closing parks the
//!   session's buffer array in its shard's span store keyed by
//!   `(file, range, shape)`; a later identical session rebinds it and is
//!   served from resident data with no file-system traffic.

use std::collections::{HashMap, HashSet, VecDeque};

use crate::amt::callback::Callback;
use crate::amt::chare::{Chare, ChareRef, CollectionId};
use crate::amt::engine::Ctx;
use crate::amt::msg::{Ep, Msg, Payload};
use crate::amt::protocol::{PayloadKind, ProtocolSpec};
use crate::amt::time::MICROS;
use crate::amt::topology::Placement;
use crate::amt::time::Time;
use crate::impl_chare_any;
use crate::metrics::keys;
use crate::pfs::layout::FileId;
use crate::trace::{names as trace_names, Lane as TraceLane, TraceCategory};
use crate::{ep_spec, send_spec};

use super::assembler::EP_A_SESSION_DROP;
use super::buffer::{
    BufDroppedMsg, BufStartedMsg, BufferChare, RebindMsg, EP_BUF_DROP, EP_BUF_INIT, EP_BUF_PARK,
    EP_BUF_REBIND,
};
use super::manager::{
    FileOpenedMsg, SessionAnnounceMsg, EP_M_FILE_CLOSE, EP_M_FILE_OPENED, EP_M_SESSION_ANNOUNCE,
    EP_M_SESSION_DROP,
};
use super::options::{
    ConsumerPlacement, FileOptions, OpenError, ReaderPlacement, RetryPolicy, SessionOptions,
    WriteOptions,
};
use super::session::{
    buffer_span_of, ConsumerAdviceMsg, FileHandle, FlowReportMsg, Session, SessionId,
    SessionOutcome, EP_CONSUMER_ADVICE,
};
use super::shard::{
    shard_of, ParkMsg, PlanMsg, TakeMsg, EP_SHARD_ADMIT, EP_SHARD_PARK, EP_SHARD_PLAN,
    EP_SHARD_PURGE, EP_SHARD_TAKE,
};
use super::store::{BufKey, PlannedSource};
use super::write::{
    FlushDoneMsg, WbDroppedMsg, WriteBuffer, WriteSessionMsg, EP_WA_SESSION, EP_WA_SESSION_DROP,
    EP_WB_CLOSE, EP_WB_FLUSH, EP_WB_INIT,
};

/// User: open a file.
pub const EP_DIR_OPEN: Ep = 1;
/// MDS open transaction completed.
pub const EP_DIR_MDS_DONE: Ep = 2;
/// Manager ack: file table updated.
pub const EP_DIR_OPEN_ACK: Ep = 3;
/// User: start a read session.
pub const EP_DIR_START_SESSION: Ep = 4;
/// Buffer chare: greedy reads initiated (or parked array rebound).
pub const EP_DIR_BUF_STARTED: Ep = 5;
/// Manager ack: session table updated.
pub const EP_DIR_ANNOUNCE_ACK: Ep = 6;
/// User: close a read session.
pub const EP_DIR_CLOSE_SESSION: Ep = 7;
/// Buffer chare ack: state dropped/parked.
pub const EP_DIR_DROP_ACK: Ep = 8;
/// Manager ack: session entry dropped.
pub const EP_DIR_DROP_ACK_MGR: Ep = 9;
/// User: close a file.
pub const EP_DIR_CLOSE_FILE: Ep = 10;
/// Manager ack: file entry dropped.
pub const EP_DIR_CLOSE_ACK: Ep = 11;
/// Shard: answer to a parked-array rebind probe (`EP_SHARD_TAKE`).
pub const EP_DIR_TAKE_REPLY: Ep = 12;
/// Shard: answer to a placement-plan probe (`EP_SHARD_PLAN`).
pub const EP_DIR_PLAN_REPLY: Ep = 13;
/// Assembler: a consumer-flow delta for a FlowAware session (PR 9).
/// The director accumulates the per-(consumer, source-PE) matrix and,
/// when a consumer's dominant source PE is not where it runs, advises
/// it to migrate there (`EP_CONSUMER_ADVICE`, within the session's
/// budget and hysteresis).
pub const EP_DIR_FLOW_REPORT: Ep = 14;
/// User: start a write session (PR 10).
pub const EP_DIR_START_WRITE: Ep = 15;
/// User: flush a write session — a drain barrier over every dirty
/// extent; the callback fires once all of them are durable or degraded.
pub const EP_DIR_FLUSH: Ep = 16;
/// User: close a write session (drain unless lazy, then park).
pub const EP_DIR_CLOSE_WRITE: Ep = 17;
/// Write buffer: its share of a flush barrier drained.
pub const EP_DIR_FLUSH_DONE: Ep = 18;
/// Write buffer close ack: span parked, outcome counters attached.
pub const EP_DIR_WB_DROPPED: Ep = 19;

#[derive(Debug)]
pub struct OpenMsg {
    pub file: FileId,
    pub size: u64,
    pub opts: FileOptions,
    pub opened: Callback,
}

#[derive(Debug)]
pub struct StartSessionMsg {
    pub file: FileId,
    pub offset: u64,
    pub bytes: u64,
    /// Per-session intent (PR 5): QoS class, splintering, window,
    /// reuse, optional placement override.
    pub opts: SessionOptions,
    pub ready: Callback,
}

#[derive(Debug)]
pub struct CloseSessionMsg {
    pub session: SessionId,
    pub after: Callback,
}

/// User → director: start a write session over `[offset, offset+bytes)`
/// of `file` (PR 10). `ready` fires with the [`Session`] scatter handle
/// once every write buffer claimed its span and every PE's assembler
/// routes for the session.
#[derive(Debug)]
pub struct StartWriteMsg {
    pub file: FileId,
    pub offset: u64,
    pub bytes: u64,
    /// Session scope (QoS class, window, reader-count resolution rides
    /// the file options exactly as for reads).
    pub opts: SessionOptions,
    /// Write scope: stripe width, write-behind, lazy parking.
    pub wopts: WriteOptions,
    pub ready: Callback,
}

/// User → director: flush barrier over a write session.
#[derive(Debug)]
pub struct FlushMsg {
    pub session: SessionId,
    pub after: Callback,
}

/// User → director: close a write session.
#[derive(Debug)]
pub struct CloseWriteMsg {
    pub session: SessionId,
    pub after: Callback,
}

#[derive(Debug)]
pub struct CloseFileMsg {
    pub file: FileId,
    pub after: Callback,
}

/// Shard → director: the result of an `EP_SHARD_TAKE` rebind probe.
#[derive(Debug)]
pub struct TakeReplyMsg {
    pub token: u64,
    /// The exactly matching parked array, if one was available.
    pub found: Option<(CollectionId, u32)>,
}

/// Shard → director: the `PlacementPlan` answering an `EP_SHARD_PLAN`
/// probe (PR 4) — one entry per prospective buffer, `Some` where the
/// span store found resident coverage (dominant source PE + covered
/// bytes), `None` where the fallback placement applies.
#[derive(Debug)]
pub struct PlanReplyMsg {
    pub token: u64,
    pub slots: Vec<Option<PlannedSource>>,
}

/// An open in flight through the MDS; later opens of the same file pile
/// their callbacks onto `waiters`.
struct OpenState {
    size: u64,
    opts: FileOptions,
    waiters: Vec<Callback>,
    acks: u32,
}

/// An open file: refcounted so concurrent sessions can share it.
struct FileEntry {
    size: u64,
    opts: FileOptions,
    open_count: u32,
}

struct SessionState {
    session: Session,
    ready: Callback,
    buf_started: u32,
    mgr_acks: u32,
    fired: bool,
    /// `Some` iff the session opted into buffer reuse: the span-store key
    /// its array is parked under on close.
    reuse_key: Option<BufKey>,
    /// Virtual time the session was inserted — the origin of the
    /// `ckio.latency.session_makespan` sample and `session/active` span.
    started_at: Time,
}

/// A teardown in progress (session or file); extra close calls for the
/// same id pile onto `afters`.
struct CloseState {
    afters: Vec<Callback>,
    acks: u32,
    need: u32,
    /// For a parking (reuse) session close: the array to publish into
    /// the owning shard's span store once every ack is in. Publishing
    /// only *after* the close completes guarantees a cached array is
    /// fully parked — no later eviction or purge can race this close's
    /// own acks.
    park: Option<(BufKey, CollectionId, u32)>,
    /// Resident bytes reported by the parking buffers' acks (the span
    /// store's budget accounting for the published array).
    parked_bytes: u64,
    /// Aggregated session outcome (PR 8): each buffer's teardown ack
    /// contributes its served/degraded/retry counters; the sum rides
    /// the close callback. Manager acks contribute zeros.
    outcome: SessionOutcome,
}

/// Write-session scope the director keeps beyond the shared
/// [`SessionState`] (PR 10): the close path needs the write options (a
/// lazy close skips the drain) and the sentinel park key.
struct WriteState {
    wopts: WriteOptions,
    /// The span-store key the array parks under at close. Write parks
    /// use a *sentinel* key — `placement: ReaderPlacement::Explicit(vec![])`,
    /// unreachable from any read session since placement validation
    /// requires covering at least one reader — so a read-side rebind
    /// probe can never take a write array (whose chares do not speak
    /// `EP_BUF_REBIND`). Read-after-write is served via peer *claims*,
    /// not rebinds.
    key: BufKey,
}

/// A flush barrier in progress over one write session; overlapping
/// flush calls pile onto `afters` and complete together.
struct FlushState {
    afters: Vec<Callback>,
    acks: u32,
    need: u32,
    /// Bytes the buffers wrote / degraded settling *this* barrier
    /// (per-flush deltas, summed across the array).
    written: u64,
    degraded: u64,
    /// Barrier origin: the `session/flush` trace span's start edge.
    started_at: Time,
}

/// A `reuse_buffers` session start awaiting its shard's rebind probe.
/// Carries everything needed to resume: the start logically happened
/// when the probe was issued (the file was open in the table then), so
/// the resume must not depend on the file still being open — a final
/// close racing the probe is tolerated exactly as PR 2's synchronous
/// path tolerated start-then-close. (The session's own options travel
/// inside `msg.opts`; only the file scope needs stashing.)
struct PendingTake {
    msg: StartSessionMsg,
    key: BufKey,
    fopts: FileOptions,
}

/// The consumer-flow matrix of one FlowAware session (PR 9): who each
/// consumer's pieces actually came from, accumulated from assembler
/// flow-report deltas, plus the advisor's hysteresis and budget state.
struct FlowState {
    /// consumer → (source buffer PE → total bytes delivered from it).
    matrix: HashMap<ChareRef, HashMap<u32, u64>>,
    /// consumer → PEs it has run on or been advised toward. Advice never
    /// targets a PE already in this set, so a consumer can never be
    /// ping-ponged between two sources however the flow shifts.
    advised: HashMap<ChareRef, HashSet<u32>>,
    /// Migrations this session may still advise (hard per-session cap).
    budget_left: u32,
}

/// A `StoreAware` session start awaiting its shard's placement plan
/// (PR 4). Same resumption contract as [`PendingTake`]: the options
/// travel with the probe, so the resume never depends on the file table
/// — and a plan is *never* cached or keyed by file, so a close/re-open
/// cycle can never resurrect a stale one.
struct PendingPlan {
    msg: StartSessionMsg,
    key: BufKey,
    fopts: FileOptions,
}

/// The Director singleton.
pub struct Director {
    managers: CollectionId,
    assemblers: CollectionId,
    /// The per-PE write-scatter router group (PR 10).
    wassemblers: CollectionId,
    /// The data-plane shard array (structurally one chare per PE).
    shards: CollectionId,
    /// Elements in `shards`.
    nshards: u32,
    /// How many shards the `FileId` hash routes over. Fixed at boot
    /// from `ServiceConfig::data_plane_shards` (PR 5) — FileId→shard
    /// routing can never change for the life of the service, so the
    /// PR 3/4 idle-barrier reconfiguration no longer exists.
    active_shards: u32,
    /// Whether the service was booted with admission control
    /// (`ServiceConfig::governed()`): every session's buffers then run
    /// the shard ticket protocol.
    governed: bool,
    /// Service-wide retry policy (PR 8): every fresh buffer array is
    /// armed with it at creation. `None` = no deadlines, no retries.
    retry: Option<RetryPolicy>,
    npes: u32,
    /// Opens awaiting MDS completion, FIFO (the MDS completes in order).
    mds_queue: VecDeque<FileId>,
    opens: HashMap<FileId, OpenState>,
    files: HashMap<FileId, FileEntry>,
    /// startReadSession calls that raced ahead of their file's open.
    early_sessions: HashMap<FileId, Vec<StartSessionMsg>>,
    /// startWriteSession calls that raced ahead of their file's open
    /// (PR 10) — replayed alongside `early_sessions` on the open ack.
    early_writes: HashMap<FileId, Vec<StartWriteMsg>>,
    /// Opens rejected by option validation, remembered so a session
    /// start *pipelined* behind a rejected open (the split-phase
    /// open-then-start pattern the early_sessions queue exists for)
    /// degrades to the same structured error on its callback instead of
    /// tripping the never-opened assert. Entries are configuration
    /// errors keyed by dense `FileId`s, so the map is naturally
    /// bounded; a later *valid* open of the file clears its entry.
    rejected_opens: HashMap<FileId, OpenError>,
    sessions: HashMap<SessionId, SessionState>,
    /// Write-session scope, keyed alongside `sessions` (PR 10); removed
    /// when the close begins (the CloseState carries the park from
    /// there).
    writes: HashMap<SessionId, WriteState>,
    /// Flush barriers in progress (PR 10).
    flushes: HashMap<SessionId, FlushState>,
    closes: HashMap<SessionId, CloseState>,
    file_closes: HashMap<FileId, CloseState>,
    /// Reuse session starts whose rebind probe is at the shard.
    pending_takes: HashMap<u64, PendingTake>,
    next_take: u64,
    /// StoreAware session starts whose placement plan is at the shard.
    pending_plans: HashMap<u64, PendingPlan>,
    next_plan: u64,
    /// Consumer-flow matrices of live FlowAware sessions (PR 9), keyed
    /// by session; armed at session start, torn down when the close
    /// fully acks. Late flow reports after teardown are tolerated.
    flows: HashMap<SessionId, FlowState>,
    next_session: u32,
}

impl Director {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        managers: CollectionId,
        assemblers: CollectionId,
        wassemblers: CollectionId,
        shards: CollectionId,
        nshards: u32,
        active_shards: u32,
        governed: bool,
        retry: Option<RetryPolicy>,
        npes: u32,
    ) -> Director {
        Director {
            managers,
            assemblers,
            wassemblers,
            shards,
            nshards,
            active_shards: active_shards.clamp(1, nshards.max(1)),
            governed,
            retry,
            npes,
            mds_queue: VecDeque::new(),
            opens: HashMap::new(),
            files: HashMap::new(),
            early_sessions: HashMap::new(),
            early_writes: HashMap::new(),
            rejected_opens: HashMap::new(),
            sessions: HashMap::new(),
            writes: HashMap::new(),
            flushes: HashMap::new(),
            closes: HashMap::new(),
            file_closes: HashMap::new(),
            pending_takes: HashMap::new(),
            next_take: 0,
            pending_plans: HashMap::new(),
            next_plan: 0,
            flows: HashMap::new(),
            next_session: 0,
        }
    }

    /// Arm a starting session's consumer-flow matrix when it opted into
    /// [`ConsumerPlacement::FlowAware`]; returns the flow threshold to
    /// stamp on the [`Session`] (0 for `Static`: assemblers then keep no
    /// accounts at all).
    fn arm_flow(&mut self, sid: SessionId, opts: &SessionOptions) -> u32 {
        match opts.consumer_placement {
            ConsumerPlacement::Static => 0,
            ConsumerPlacement::FlowAware { migration_budget, .. } => {
                self.flows.insert(sid, FlowState {
                    matrix: HashMap::new(),
                    advised: HashMap::new(),
                    budget_left: migration_budget,
                });
                opts.consumer_placement.piece_threshold()
            }
        }
    }

    /// The shard owning `file`'s data-plane state.
    fn shard_ref(&self, file: FileId) -> ChareRef {
        ChareRef::new(self.shards, shard_of(file, self.active_shards))
    }

    /// The placement a session actually starts under: its override when
    /// set (validated at session start), the file's policy otherwise.
    fn effective_placement<'a>(
        fopts: &'a FileOptions,
        sopts: &'a SessionOptions,
    ) -> &'a ReaderPlacement {
        sopts.placement_override.as_ref().unwrap_or(&fopts.placement)
    }

    fn maybe_ready(&mut self, ctx: &mut Ctx<'_>, sid: SessionId) {
        // Tolerate late start-acks for sessions already torn down (a
        // close can race the tail of session startup).
        let Some(st) = self.sessions.get_mut(&sid) else { return };
        if !st.fired && st.buf_started == st.session.num_buffers && st.mgr_acks == self.npes {
            st.fired = true;
            let nbuf = st.session.num_buffers;
            ctx.fire(st.ready.clone(), Payload::new(st.session));
            if ctx.trace().on(TraceCategory::Session) {
                let now = ctx.now();
                let pe = ctx.pe().0;
                ctx.trace().instant(
                    now,
                    TraceCategory::Session,
                    trace_names::SESSION_READY,
                    TraceLane::Pe(pe),
                    u64::from(sid.0),
                    u64::from(nbuf),
                    "",
                );
            }
        }
    }

    fn ack_close(&mut self, ctx: &mut Ctx<'_>, sid: SessionId, resident: u64, d: SessionOutcome) {
        // Acks may also come from cache-evicted parked buffers whose
        // original close completed long ago: ignore those.
        let Some(st) = self.closes.get_mut(&sid) else { return };
        st.acks += 1;
        st.parked_bytes += resident;
        st.outcome.served_bytes += d.served_bytes;
        st.outcome.degraded_bytes += d.degraded_bytes;
        st.outcome.retries += d.retries;
        st.outcome.hedges += d.hedges;
        st.outcome.gave_up_spans += d.gave_up_spans;
        st.outcome.written_bytes += d.written_bytes;
        st.outcome.dirty_bytes += d.dirty_bytes;
        if st.acks == st.need {
            let st = self.closes.remove(&sid).unwrap();
            // The consumer-flow matrix dies with the session (PR 9);
            // flow reports still in flight find no entry and are
            // tolerated (never revive advice for a dead session).
            self.flows.remove(&sid);
            if let Some(ss) = self.sessions.remove(&sid) {
                // The session is fully gone: every buffer and manager
                // acked. This close edge is the makespan's far end.
                let now = ctx.now();
                let makespan = now.saturating_sub(ss.started_at);
                ctx.metrics().record(keys::LATENCY_SESSION_MAKESPAN, makespan);
                if ctx.trace().on(TraceCategory::Session) {
                    let pe = ctx.pe().0;
                    ctx.trace().end(
                        now,
                        TraceCategory::Session,
                        trace_names::SESSION_ACTIVE,
                        TraceLane::Pe(pe),
                        u64::from(sid.0),
                        makespan,
                        0,
                    );
                    ctx.trace().instant(
                        now,
                        TraceCategory::Session,
                        trace_names::SESSION_CLOSE,
                        TraceLane::Pe(pe),
                        u64::from(sid.0),
                        makespan,
                        "",
                    );
                }
            }
            // Publish the fully parked array for reuse — unless its file
            // was closed in the meantime (nothing can rebind it then;
            // the shard's purge already dropped its claims).
            if let Some((key, buffers, nbuf)) = st.park {
                if self.files.contains_key(&key.file) {
                    let shard = self.shard_ref(key.file);
                    ctx.send(shard, EP_SHARD_PARK, ParkMsg {
                        key,
                        buffers,
                        nbuf,
                        resident_bytes: st.parked_bytes,
                    });
                } else {
                    self.drop_array(ctx, buffers, nbuf);
                }
            }
            // Every close callback receives the aggregated outcome
            // (PR 8): who got served, who degraded, and what the retry
            // plane spent getting there.
            let outcome = SessionOutcome { session: sid, ..st.outcome };
            for after in st.afters {
                ctx.fire(after, Payload::new(outcome));
            }
        }
    }

    /// Release every element of a buffer-chare array (teardown, or a
    /// park whose file closed underneath it).
    fn drop_array(&self, ctx: &mut Ctx<'_>, buffers: CollectionId, n: u32) {
        for b in 0..n {
            ctx.signal(ChareRef::new(buffers, b), EP_BUF_DROP);
        }
    }

    /// Announce a freshly inserted session to every manager.
    fn announce(&mut self, ctx: &mut Ctx<'_>, session: Session) {
        for pe in 0..self.npes {
            ctx.send_group(
                self.managers,
                crate::amt::topology::Pe(pe),
                EP_M_SESSION_ANNOUNCE,
                SessionAnnounceMsg { session },
            );
        }
    }

    /// The session-shape key used for parked-array rebind matching: the
    /// reader count comes from the file scope, splinter/window/effective
    /// placement from the session scope (PR 5) — two sessions with
    /// different staging intent never rebind each other's arrays. The
    /// placement is part of the key because a parked array physically
    /// sits where its placement put it: without it, a session with a
    /// `placement_override` could rebind an array at the file-policy
    /// PEs (or vice versa) and silently end up placed wrong.
    fn buf_key(&self, ctx: &Ctx<'_>, fopts: &FileOptions, m: &StartSessionMsg) -> BufKey {
        let topo = ctx.topo();
        BufKey {
            file: m.file,
            offset: m.offset,
            bytes: m.bytes,
            readers: fopts.resolve_readers(m.bytes, &topo),
            splinter: m.opts.splinter_bytes.unwrap_or(0),
            window: m.opts.read_window,
            placement: Self::effective_placement(fopts, &m.opts).clone(),
        }
    }

    /// Start a session over a rebound parked array (the shard's take
    /// probe found an exact shape match; claims stayed registered). The
    /// rebind carries the new session's QoS class — the array may serve
    /// a different tenant now — and the class is registered with the
    /// owning shard (the rebind path runs no plan probe).
    fn start_rebind(
        &mut self,
        ctx: &mut Ctx<'_>,
        m: StartSessionMsg,
        key: BufKey,
        buffers: CollectionId,
        nbuf: u32,
    ) {
        debug_assert_eq!(nbuf, key.readers);
        let sid = SessionId(self.next_session);
        self.next_session += 1;
        let class = m.opts.class;
        let shard = self.shard_ref(m.file);
        ctx.send(shard, EP_SHARD_ADMIT, class);
        let flow = self.arm_flow(sid, &m.opts);
        let session = Session::new(sid, m.file, m.offset, m.bytes, buffers, nbuf).with_flow(flow);
        let started_at = ctx.now();
        self.sessions.insert(sid, SessionState {
            session,
            ready: m.ready,
            buf_started: 0,
            mgr_acks: 0,
            fired: false,
            reuse_key: Some(key),
            started_at,
        });
        if ctx.trace().on(TraceCategory::Session) {
            let pe = ctx.pe().0;
            ctx.trace().begin(
                started_at,
                TraceCategory::Session,
                trace_names::SESSION_ACTIVE,
                TraceLane::Pe(pe),
                u64::from(sid.0),
                m.bytes,
                u64::from(nbuf),
            );
            ctx.trace().instant(
                started_at,
                TraceCategory::Session,
                trace_names::SESSION_CREATE,
                TraceLane::Pe(pe),
                u64::from(sid.0),
                u64::from(nbuf),
                "rebind",
            );
        }
        for b in 0..nbuf {
            ctx.send(ChareRef::new(buffers, b), EP_BUF_REBIND, RebindMsg { session: sid, class });
        }
        self.announce(ctx, session);
        ctx.metrics().count(keys::BUFFER_REUSE, 1);
        ctx.advance(MICROS);
    }

    /// Admit a fresh (non-rebind) session start. A `StoreAware`
    /// placement first runs the plan-then-create round trip: the owning
    /// shard is probed (`EP_SHARD_PLAN`) for where the prospective
    /// spans' bytes already live — the probe carries the session's QoS
    /// class (PR 5), so the admission class is negotiated on the same
    /// round trip — and creation resumes at [`EP_DIR_PLAN_REPLY`].
    /// Every other placement registers its class with a lightweight
    /// `EP_SHARD_ADMIT` on the same path and creates immediately (the
    /// PR 3 register-after-create order, now the no-plan special case).
    ///
    /// Known cost: a `reuse_buffers` + `StoreAware` start whose rebind
    /// probe misses pays two serialized round trips to the same shard
    /// (take, then plan). Folding the plan into the take *miss* reply
    /// would save one and is left as a follow-up rather than widening
    /// the take protocol twice.
    fn begin_fresh(
        &mut self,
        ctx: &mut Ctx<'_>,
        m: StartSessionMsg,
        key: BufKey,
        fopts: FileOptions,
    ) {
        let shard = self.shard_ref(m.file);
        if Self::effective_placement(&fopts, &m.opts).is_store_aware() {
            let token = self.next_plan;
            self.next_plan += 1;
            ctx.send(shard, EP_SHARD_PLAN, PlanMsg {
                file: m.file,
                offset: m.offset,
                bytes: m.bytes,
                readers: key.readers,
                splinter: key.splinter,
                class: m.opts.class,
                token,
            });
            self.pending_plans.insert(token, PendingPlan { msg: m, key, fopts });
            if ctx.trace().on(TraceCategory::Session) {
                let now = ctx.now();
                let pe = ctx.pe().0;
                ctx.trace().instant(
                    now,
                    TraceCategory::Session,
                    trace_names::SESSION_PLAN,
                    TraceLane::Pe(pe),
                    token,
                    0,
                    "",
                );
            }
            ctx.advance(MICROS);
            return;
        }
        ctx.send(shard, EP_SHARD_ADMIT, m.opts.class);
        self.start_fresh(ctx, m, key, fopts, None);
    }

    /// Start a session over a freshly created buffer-chare array. The
    /// buffers register their claims and resolve peer sources with their
    /// file's shard themselves (`EP_SHARD_REGISTER`) — the director only
    /// hands them the shard's address. `fopts` are the file's opening
    /// options, resolved by the caller when the start was admitted (the
    /// file may legitimately have fully closed since, if a rebind or
    /// plan probe was in flight — the session proceeds regardless, as it
    /// would have under PR 2's synchronous start); the session's own
    /// intent travels in `m.opts`.
    ///
    /// `plan` is the shard's `PlacementPlan` for a `StoreAware` start:
    /// each planned buffer is mapped onto the PE of its dominant peer
    /// source (`Placement::Explicit` built from the plan), unplanned
    /// buffers keep the fallback placement's PE, and every planned
    /// buffer carries its expected coverage so registration can
    /// revalidate the snapshot.
    fn start_fresh(
        &mut self,
        ctx: &mut Ctx<'_>,
        m: StartSessionMsg,
        key: BufKey,
        fopts: FileOptions,
        plan: Option<Vec<Option<PlannedSource>>>,
    ) {
        let sid = SessionId(self.next_session);
        self.next_session += 1;
        let nreaders = key.readers;
        let splinter = m.opts.splinter_bytes;
        let window = m.opts.read_window;
        let class = m.opts.class;
        let file = m.file;
        let (offset, bytes) = (m.offset, m.bytes);
        let me = ctx.me();
        let assemblers = self.assemblers;
        let shard = self.shard_ref(file);
        // File placements are validated at open (EP_DIR_OPEN), session
        // overrides at session start, and the resolved reader count only
        // ever clamps *down* from the validated worst case — so
        // materializing the placement here cannot fail.
        let base = Self::effective_placement(&fopts, &m.opts)
            .to_placement(nreaders)
            .expect("placement validated at open / session start");
        let placement = match &plan {
            Some(slots) => {
                debug_assert_eq!(slots.len(), nreaders as usize, "plan arity mismatch");
                let mut pes = base.place(&ctx.topo(), nreaders as usize);
                let planned = slots.iter().flatten().count() as u64;
                for (b, src) in slots.iter().enumerate() {
                    if let Some(src) = src {
                        pes[b] = crate::amt::topology::Pe(src.pe);
                    }
                }
                if planned > 0 {
                    ctx.metrics().count(crate::metrics::keys::PLACE_PLANNED, planned);
                }
                Placement::Explicit(pes)
            }
            None => base,
        };
        // The same span partition Session::buffer_span serves to
        // assemblers — one definition, so chare spans, claims, and
        // routing can never drift.
        let spans: Vec<(u64, u64)> =
            (0..nreaders).map(|b| buffer_span_of(offset, bytes, nreaders, b)).collect();
        let governed = self.governed;
        let retry = self.retry;
        let buffers = ctx.create_array_now(nreaders, &placement, |i| {
            let (o, l) = spans[i as usize];
            let mut b = BufferChare::new(sid, file, o, l, splinter, window, me, shard, assemblers);
            if governed {
                b = b.governed(bytes, class);
            }
            if let Some(r) = retry {
                b = b.with_retry(r);
            }
            if let Some(slots) = &plan {
                if let Some(src) = slots[i as usize] {
                    b = b.planned(src.covered);
                }
            }
            b
        });
        // The buffers are a dynamically created collection: declare their
        // protocol so debug builds validate sends addressed to them too.
        ctx.register_protocol(buffers, super::buffer::protocol_spec());
        let flow = self.arm_flow(sid, &m.opts);
        let session = Session::new(sid, file, offset, bytes, buffers, nreaders).with_flow(flow);
        let started_at = ctx.now();
        self.sessions.insert(sid, SessionState {
            session,
            ready: m.ready,
            buf_started: 0,
            mgr_acks: 0,
            fired: false,
            reuse_key: m.opts.reuse_buffers.then_some(key),
            started_at,
        });
        if ctx.trace().on(TraceCategory::Session) {
            let pe = ctx.pe().0;
            ctx.trace().begin(
                started_at,
                TraceCategory::Session,
                trace_names::SESSION_ACTIVE,
                TraceLane::Pe(pe),
                u64::from(sid.0),
                bytes,
                u64::from(nreaders),
            );
            ctx.trace().instant(
                started_at,
                TraceCategory::Session,
                trace_names::SESSION_CREATE,
                TraceLane::Pe(pe),
                u64::from(sid.0),
                u64::from(nreaders),
                if plan.is_some() { "planned" } else { "fresh" },
            );
        }
        // Kick the greedy reads (via shard registration) and announce.
        for b in 0..nreaders {
            ctx.signal(ChareRef::new(buffers, b), EP_BUF_INIT);
        }
        self.announce(ctx, session);
        ctx.advance(2 * MICROS);
    }

    /// Start a write session over a freshly created [`WriteBuffer`]
    /// array (PR 10). The mirror of [`Director::start_fresh`], minus the
    /// read-only machinery: no rebind/plan probe (write arrays park
    /// under a sentinel key no read session can take — a `StoreAware`
    /// placement simply materializes its fallback), no consumer-flow
    /// matrix, no splinters (the coalescing grid is the stripe). The
    /// buffers claim their spans *dirty* at the shard, which is what
    /// makes a following read session resolve against them
    /// (read-after-write residency).
    fn start_write(&mut self, ctx: &mut Ctx<'_>, m: StartWriteMsg, fopts: FileOptions) {
        let sid = SessionId(self.next_session);
        self.next_session += 1;
        let nwriters = fopts.resolve_readers(m.bytes, &ctx.topo());
        let window = m.opts.read_window;
        let class = m.opts.class;
        let wopts = m.wopts;
        let file = m.file;
        let (offset, bytes) = (m.offset, m.bytes);
        let me = ctx.me();
        let shard = self.shard_ref(file);
        ctx.send(shard, EP_SHARD_ADMIT, class);
        let placement = Self::effective_placement(&fopts, &m.opts)
            .to_placement(nwriters)
            .expect("placement validated at open / session start");
        // Same span partition as the read side: put routing, claims,
        // and a later read session's slots all agree bit for bit.
        let spans: Vec<(u64, u64)> =
            (0..nwriters).map(|b| buffer_span_of(offset, bytes, nwriters, b)).collect();
        let governed = self.governed;
        let retry = self.retry;
        let buffers = ctx.create_array_now(nwriters, &placement, |i| {
            let (o, l) = spans[i as usize];
            let mut b = WriteBuffer::new(sid, file, o, l, wopts, window, me, shard);
            if governed {
                b = b.governed(bytes, class);
            }
            if let Some(r) = retry {
                b = b.with_retry(r);
            }
            b
        });
        ctx.register_protocol(buffers, super::write::buffer_protocol_spec());
        let session = Session::new(sid, file, offset, bytes, buffers, nwriters);
        let started_at = ctx.now();
        self.sessions.insert(sid, SessionState {
            session,
            ready: m.ready,
            buf_started: 0,
            mgr_acks: 0,
            fired: false,
            reuse_key: None,
            started_at,
        });
        self.writes.insert(sid, WriteState {
            wopts,
            key: BufKey {
                file,
                offset,
                bytes,
                readers: nwriters,
                splinter: wopts.stripe_bytes,
                window: 0,
                placement: ReaderPlacement::Explicit(Vec::new()),
            },
        });
        ctx.metrics().count(keys::WRITE_SESSIONS, 1);
        if ctx.trace().on(TraceCategory::Session) {
            let pe = ctx.pe().0;
            ctx.trace().begin(
                started_at,
                TraceCategory::Session,
                trace_names::SESSION_ACTIVE,
                TraceLane::Pe(pe),
                u64::from(sid.0),
                bytes,
                u64::from(nwriters),
            );
            ctx.trace().instant(
                started_at,
                TraceCategory::Session,
                trace_names::SESSION_CREATE,
                TraceLane::Pe(pe),
                u64::from(sid.0),
                u64::from(nwriters),
                "write",
            );
        }
        for b in 0..nwriters {
            ctx.signal(ChareRef::new(buffers, b), EP_WB_INIT);
        }
        // The write assemblers are the session's managers: each PE's
        // router learns the scatter handle and acks like a manager does
        // (maybe_ready counts them on the same mgr_acks tally).
        for pe in 0..self.npes {
            ctx.send_group(
                self.wassemblers,
                crate::amt::topology::Pe(pe),
                EP_WA_SESSION,
                WriteSessionMsg { session },
            );
        }
        ctx.advance(2 * MICROS);
    }

    // ------------------------------------------------------------------
    // test / driver inspection
    // ------------------------------------------------------------------

    /// Sessions currently live (leak checks: must be 0 after all closes).
    pub fn active_sessions(&self) -> usize {
        self.sessions.len()
    }

    /// Session teardowns still collecting acks.
    pub fn pending_closes(&self) -> usize {
        self.closes.len()
    }

    /// Rebind probes still at their shard.
    pub fn pending_takes(&self) -> usize {
        self.pending_takes.len()
    }

    /// Placement plans still at their shard.
    pub fn pending_plans(&self) -> usize {
        self.pending_plans.len()
    }

    /// Files currently open (refcounted).
    pub fn open_files(&self) -> usize {
        self.files.len()
    }

    /// Write sessions currently live (leak checks: must be 0 after all
    /// write closes).
    pub fn active_writes(&self) -> usize {
        self.writes.len()
    }

    /// Flush barriers still collecting buffer acks (leak checks).
    pub fn pending_flushes(&self) -> usize {
        self.flushes.len()
    }

    /// Sessions with a live consumer-flow matrix (leak checks: must be
    /// 0 after all closes — the matrix dies with the session).
    pub fn flow_sessions(&self) -> usize {
        self.flows.len()
    }

    /// Shards the `FileId` hash currently routes over.
    pub fn active_shards(&self) -> u32 {
        self.active_shards
    }

    /// The shard index owning `file`'s data-plane state (routing
    /// stability tests).
    pub fn shard_of_file(&self, file: FileId) -> u32 {
        shard_of(file, self.active_shards)
    }
}

/// The director's declared message protocol (see [`crate::amt::protocol`]).
/// Any change to its EPs, payload types, or send sites must update this
/// spec in the same commit.
pub fn protocol_spec() -> ProtocolSpec {
    use super::governor::QosClass;
    ProtocolSpec {
        chare: "Director",
        module: "ckio/director.rs",
        handles: vec![
            ep_spec!(EP_DIR_OPEN, PayloadKind::of::<OpenMsg>()),
            ep_spec!(EP_DIR_MDS_DONE, PayloadKind::Signal),
            ep_spec!(EP_DIR_OPEN_ACK, PayloadKind::of::<FileId>()),
            ep_spec!(EP_DIR_START_SESSION, PayloadKind::of::<StartSessionMsg>()),
            ep_spec!(EP_DIR_BUF_STARTED, PayloadKind::of::<BufStartedMsg>()),
            ep_spec!(EP_DIR_ANNOUNCE_ACK, PayloadKind::of::<SessionId>()),
            ep_spec!(EP_DIR_CLOSE_SESSION, PayloadKind::of::<CloseSessionMsg>()),
            ep_spec!(EP_DIR_DROP_ACK, PayloadKind::of::<BufDroppedMsg>()),
            ep_spec!(EP_DIR_DROP_ACK_MGR, PayloadKind::of::<SessionId>()),
            ep_spec!(EP_DIR_CLOSE_FILE, PayloadKind::of::<CloseFileMsg>()),
            ep_spec!(EP_DIR_CLOSE_ACK, PayloadKind::of::<FileId>()),
            ep_spec!(EP_DIR_TAKE_REPLY, PayloadKind::of::<TakeReplyMsg>()),
            ep_spec!(EP_DIR_PLAN_REPLY, PayloadKind::of::<PlanReplyMsg>()),
            ep_spec!(EP_DIR_FLOW_REPORT, PayloadKind::of::<FlowReportMsg>()),
            ep_spec!(EP_DIR_START_WRITE, PayloadKind::of::<StartWriteMsg>()),
            ep_spec!(EP_DIR_FLUSH, PayloadKind::of::<FlushMsg>()),
            ep_spec!(EP_DIR_CLOSE_WRITE, PayloadKind::of::<CloseWriteMsg>()),
            ep_spec!(EP_DIR_FLUSH_DONE, PayloadKind::of::<FlushDoneMsg>()),
            ep_spec!(EP_DIR_WB_DROPPED, PayloadKind::of::<WbDroppedMsg>()),
        ],
        sends: vec![
            send_spec!("Director", EP_DIR_START_SESSION, PayloadKind::of::<StartSessionMsg>()),
            send_spec!("Director", EP_DIR_START_WRITE, PayloadKind::of::<StartWriteMsg>()),
            send_spec!("Manager", EP_M_FILE_OPENED, PayloadKind::of::<FileOpenedMsg>()),
            send_spec!("Manager", EP_M_SESSION_ANNOUNCE, PayloadKind::of::<SessionAnnounceMsg>()),
            send_spec!("Manager", EP_M_SESSION_DROP, PayloadKind::of::<SessionId>()),
            send_spec!("Manager", EP_M_FILE_CLOSE, PayloadKind::of::<FileId>()),
            send_spec!("ReadAssembler", EP_A_SESSION_DROP, PayloadKind::of::<SessionId>()),
            send_spec!("BufferChare", EP_BUF_INIT, PayloadKind::Signal),
            send_spec!("BufferChare", EP_BUF_DROP, PayloadKind::Signal),
            send_spec!("BufferChare", EP_BUF_PARK, PayloadKind::Signal),
            send_spec!("BufferChare", EP_BUF_REBIND, PayloadKind::of::<RebindMsg>()),
            send_spec!("DataShard", EP_SHARD_TAKE, PayloadKind::of::<TakeMsg>()),
            send_spec!("DataShard", EP_SHARD_PARK, PayloadKind::of::<ParkMsg>()),
            send_spec!("DataShard", EP_SHARD_PURGE, PayloadKind::of::<FileId>()),
            send_spec!("DataShard", EP_SHARD_PLAN, PayloadKind::of::<PlanMsg>()),
            send_spec!("DataShard", EP_SHARD_ADMIT, PayloadKind::of::<QosClass>()),
            send_spec!("WriteAssembler", EP_WA_SESSION, PayloadKind::of::<WriteSessionMsg>()),
            send_spec!("WriteAssembler", EP_WA_SESSION_DROP, PayloadKind::of::<SessionId>()),
            send_spec!("WriteBuffer", EP_WB_INIT, PayloadKind::Signal),
            send_spec!("WriteBuffer", EP_WB_FLUSH, PayloadKind::Signal),
            send_spec!("WriteBuffer", EP_WB_CLOSE, PayloadKind::Signal),
            send_spec!("WriteBuffer", EP_BUF_DROP, PayloadKind::Signal),
        ],
    }
}

impl Chare for Director {
    fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
        match msg.ep {
            EP_DIR_OPEN => {
                let m: OpenMsg = msg.take();
                if ctx.trace().on(TraceCategory::Session) {
                    let now = ctx.now();
                    let pe = ctx.pe().0;
                    ctx.trace().instant(
                        now,
                        TraceCategory::Session,
                        trace_names::SESSION_OPEN,
                        TraceLane::Pe(pe),
                        u64::from(m.file.0),
                        m.size,
                        "",
                    );
                }
                // Refcounted re-open: the file is already open everywhere,
                // answer immediately from the file table — unless the
                // re-open asks for *different* FileOptions, which is a
                // structured conflict (PR 5), never a silent ignore.
                if let Some(entry) = self.files.get_mut(&m.file) {
                    if entry.opts != m.opts {
                        ctx.metrics().count(keys::OPENS_REJECTED, 1);
                        ctx.fire(m.opened, Payload::new(OpenError::OptionsConflict));
                        return;
                    }
                    entry.open_count += 1;
                    ctx.metrics().count(keys::REOPENS, 1);
                    let handle =
                        FileHandle { file: m.file, size: entry.size, opts: entry.opts.clone() };
                    ctx.fire(m.opened, Payload::new(handle));
                    return;
                }
                // An open of the same file is already in flight: share its
                // MDS transaction and manager broadcast (same conflict
                // rule as above).
                if let Some(st) = self.opens.get_mut(&m.file) {
                    if st.opts != m.opts {
                        ctx.metrics().count(keys::OPENS_REJECTED, 1);
                        ctx.fire(m.opened, Payload::new(OpenError::OptionsConflict));
                        return;
                    }
                    st.waiters.push(m.opened);
                    ctx.metrics().count(keys::REOPENS, 1);
                    return;
                }
                // First open: validate the options *before* they can
                // govern the file. A placement that cannot cover the
                // largest reader count any session could resolve to is
                // rejected here with a structured error on the open
                // callback — instead of panicking at some later session
                // start (the pre-PR 4 behavior of a short explicit
                // list). Service-wide knobs no longer ride the open at
                // all (PR 5): the data plane was configured at boot.
                if let Err(e) = m.opts.validate(m.size, &ctx.topo()) {
                    ctx.metrics().count(keys::OPENS_REJECTED, 1);
                    self.rejected_opens.insert(m.file, e.clone());
                    ctx.fire(m.opened, Payload::new(e));
                    return;
                }
                // A valid open supersedes any earlier rejection of this
                // file (session starts must again wait for it, not
                // bounce off the stale error).
                self.rejected_opens.remove(&m.file);
                self.opens.insert(m.file, OpenState {
                    size: m.size,
                    opts: m.opts,
                    waiters: vec![m.opened],
                    acks: 0,
                });
                self.mds_queue.push_back(m.file);
                let me = ctx.me();
                ctx.advance(MICROS);
                ctx.open_file(Callback::to_chare(me, EP_DIR_MDS_DONE));
            }
            EP_DIR_MDS_DONE => {
                // MDS transactions complete FIFO; match to the oldest open.
                let file = self.mds_queue.pop_front().expect("MDS done without open");
                let opts = self.opens[&file].opts.clone();
                // Tell every manager about the file.
                for pe in 0..self.npes {
                    ctx.send_group(self.managers, crate::amt::topology::Pe(pe), EP_M_FILE_OPENED,
                        FileOpenedMsg { file, opts: opts.clone() });
                }
                ctx.advance(MICROS);
            }
            EP_DIR_OPEN_ACK => {
                let file: FileId = msg.take();
                let st = self.opens.get_mut(&file).expect("ack for unknown open");
                st.acks += 1;
                if st.acks == self.npes {
                    let st = self.opens.remove(&file).unwrap();
                    self.files.insert(file, FileEntry {
                        size: st.size,
                        opts: st.opts.clone(),
                        open_count: st.waiters.len() as u32,
                    });
                    for opened in st.waiters {
                        ctx.fire(opened, Payload::new(FileHandle {
                            file,
                            size: st.size,
                            opts: st.opts.clone(),
                        }));
                    }
                    // Replay session starts that raced ahead of the open.
                    let me = ctx.me();
                    for m in self.early_sessions.remove(&file).unwrap_or_default() {
                        ctx.send(me, EP_DIR_START_SESSION, m);
                    }
                    for m in self.early_writes.remove(&file).unwrap_or_default() {
                        ctx.send(me, EP_DIR_START_WRITE, m);
                    }
                }
            }
            EP_DIR_START_SESSION => {
                let m: StartSessionMsg = msg.take();
                // Robustness: a session start racing ahead of the file's
                // open completion is held and replayed (split-phase APIs
                // make this easy to hit from driver code). A start
                // pipelined behind a *rejected* open gets the same
                // structured error the open callback got — never a
                // panic for a recoverable configuration mistake.
                let Some(entry) = self.files.get(&m.file) else {
                    if self.opens.contains_key(&m.file) {
                        self.early_sessions.entry(m.file).or_default().push(m);
                        return;
                    }
                    if let Some(e) = self.rejected_opens.get(&m.file) {
                        ctx.metrics().count(keys::SESSIONS_REJECTED, 1);
                        ctx.fire(m.ready, Payload::new(e.clone()));
                        return;
                    }
                    panic!("startReadSession for a file that was never opened");
                };
                let (size, fopts) = (entry.size, entry.opts.clone());
                assert!(m.offset + m.bytes <= size, "session beyond EOF");
                // A placement override is session scope: validate it
                // here, against this session's resolved reader count,
                // and fail the ready callback with the same structured
                // error an impossible open gets (PR 5).
                let key = self.buf_key(ctx, &fopts, &m);
                if let Some(p) = &m.opts.placement_override {
                    if let Err(e) = p.validate(key.readers) {
                        ctx.metrics().count(keys::SESSIONS_REJECTED, 1);
                        ctx.fire(m.ready, Payload::new(e));
                        return;
                    }
                }
                ctx.metrics().count(keys::SESSIONS, 1);

                // Reuse path: probe the file's shard for an identically
                // shaped parked array (it owns the parked inventory);
                // the start resumes at EP_DIR_TAKE_REPLY. The options
                // travel with the probe so the resume never depends on
                // the file table (a final close may race the reply).
                // The key carries the effective placement, so an
                // override only ever rebinds an array parked under the
                // same override — never one sitting at the file-policy
                // PEs (and vice versa).
                if m.opts.reuse_buffers {
                    let token = self.next_take;
                    self.next_take += 1;
                    let shard = self.shard_ref(m.file);
                    ctx.send(shard, EP_SHARD_TAKE, TakeMsg { key: key.clone(), token });
                    self.pending_takes.insert(token, PendingTake { msg: m, key, fopts });
                    ctx.advance(MICROS);
                    return;
                }

                // Fresh path: create the per-session buffer chare array
                // (dynamic creation, as CkIO does on session start),
                // planning the placement first when it is store-aware.
                self.begin_fresh(ctx, m, key, fopts);
            }
            EP_DIR_TAKE_REPLY => {
                let r: TakeReplyMsg = msg.take();
                let pt = self.pending_takes.remove(&r.token).expect("reply for unknown take");
                match r.found {
                    Some((buffers, nbuf)) => {
                        self.start_rebind(ctx, pt.msg, pt.key, buffers, nbuf)
                    }
                    None => self.begin_fresh(ctx, pt.msg, pt.key, pt.fopts),
                }
            }
            EP_DIR_PLAN_REPLY => {
                let r: PlanReplyMsg = msg.take();
                let pp = self.pending_plans.remove(&r.token).expect("reply for unknown plan");
                self.start_fresh(ctx, pp.msg, pp.key, pp.fopts, Some(r.slots));
            }
            EP_DIR_BUF_STARTED => {
                let m: BufStartedMsg = msg.take();
                if let Some(st) = self.sessions.get_mut(&m.session) {
                    st.buf_started += 1;
                }
                self.maybe_ready(ctx, m.session);
            }
            EP_DIR_ANNOUNCE_ACK => {
                let sid: SessionId = msg.take();
                if let Some(st) = self.sessions.get_mut(&sid) {
                    st.mgr_acks += 1;
                }
                self.maybe_ready(ctx, sid);
            }
            EP_DIR_CLOSE_SESSION => {
                let m: CloseSessionMsg = msg.take();
                // A close already in flight for this session: attach.
                if let Some(cs) = self.closes.get_mut(&m.session) {
                    cs.afters.push(m.after);
                    ctx.metrics().count(keys::DOUBLE_CLOSE, 1);
                    return;
                }
                let Some(st) = self.sessions.get(&m.session) else {
                    // Already fully closed (idempotent close): ack now,
                    // with an all-zero outcome — the first close carried
                    // the real one.
                    ctx.metrics().count(keys::DOUBLE_CLOSE, 1);
                    ctx.fire(
                        m.after,
                        Payload::new(SessionOutcome { session: m.session, ..Default::default() }),
                    );
                    return;
                };
                let nbuf = st.session.num_buffers;
                let buffers = st.session.buffers;
                let park = match st.reuse_key.clone() {
                    Some(key) => {
                        // Park: drain pending fetches but keep resident
                        // data (and span-store claims) for reuse. The
                        // array is published into the shard's store only
                        // once this close fully acks (ack_close).
                        for b in 0..nbuf {
                            ctx.signal(ChareRef::new(buffers, b), EP_BUF_PARK);
                        }
                        Some((key, buffers, nbuf))
                    }
                    None => {
                        // Dropping: each buffer retracts its own claim at
                        // the shard as part of its drop (FIFO-ordered
                        // after its registration), so a dying array stops
                        // serving as a peer source without the director
                        // racing the shard.
                        self.drop_array(ctx, buffers, nbuf);
                        None
                    }
                };
                for pe in 0..self.npes {
                    ctx.send_group(
                        self.managers,
                        crate::amt::topology::Pe(pe),
                        EP_M_SESSION_DROP,
                        m.session,
                    );
                    // Fire-and-forget: assemblers only need to know the
                    // session is gone so late pieces are tolerated.
                    ctx.send_group(
                        self.assemblers,
                        crate::amt::topology::Pe(pe),
                        EP_A_SESSION_DROP,
                        m.session,
                    );
                }
                self.closes.insert(m.session, CloseState {
                    afters: vec![m.after],
                    acks: 0,
                    need: nbuf + self.npes,
                    park,
                    parked_bytes: 0,
                    outcome: SessionOutcome::default(),
                });
                if ctx.trace().on(TraceCategory::Session) {
                    let now = ctx.now();
                    let pe = ctx.pe().0;
                    ctx.trace().instant(
                        now,
                        TraceCategory::Session,
                        trace_names::SESSION_DRAIN,
                        TraceLane::Pe(pe),
                        u64::from(m.session.0),
                        u64::from(nbuf),
                        "",
                    );
                }
                ctx.advance(MICROS);
            }
            EP_DIR_DROP_ACK => {
                let m: BufDroppedMsg = msg.take();
                let delta = SessionOutcome {
                    session: m.session,
                    served_bytes: m.served_bytes,
                    degraded_bytes: m.degraded_bytes,
                    retries: m.retries,
                    hedges: m.hedges,
                    gave_up_spans: m.gave_up,
                    ..Default::default()
                };
                self.ack_close(ctx, m.session, m.resident, delta);
            }
            EP_DIR_DROP_ACK_MGR => {
                let sid: SessionId = msg.take();
                self.ack_close(ctx, sid, 0, SessionOutcome::default());
            }
            EP_DIR_CLOSE_FILE => {
                let m: CloseFileMsg = msg.take();
                let entry = self.files.get_mut(&m.file).expect("closing unopened file");
                entry.open_count -= 1;
                if entry.open_count > 0 {
                    // Other owners (concurrent sessions) still hold the
                    // file open: this close is complete immediately.
                    ctx.fire(m.after, Payload::empty());
                    return;
                }
                self.files.remove(&m.file);
                // Parked buffer arrays of a closed file can never be
                // rebound or peer-fetched again: the owning shard
                // releases them (with their claims).
                let shard = self.shard_ref(m.file);
                ctx.send(shard, EP_SHARD_PURGE, m.file);
                for pe in 0..self.npes {
                    ctx.send_group(
                        self.managers,
                        crate::amt::topology::Pe(pe),
                        EP_M_FILE_CLOSE,
                        m.file,
                    );
                }
                self.file_closes.insert(m.file, CloseState {
                    afters: vec![m.after],
                    acks: 0,
                    need: self.npes,
                    park: None,
                    parked_bytes: 0,
                    outcome: SessionOutcome::default(),
                });
                ctx.advance(MICROS);
            }
            EP_DIR_FLOW_REPORT => {
                let m: FlowReportMsg = msg.take();
                // A report racing the session's teardown finds no matrix:
                // tolerated, exactly like a late take/plan reply.
                let Some(fs) = self.flows.get_mut(&m.session) else { return };
                ctx.metrics().count(keys::CONSUMER_FLOW_REPORTS, 1);
                // Hysteresis seed: wherever the consumer *currently*
                // runs is never an advisable destination — this is what
                // makes ping-pong impossible (a move back would target a
                // PE already in the set).
                fs.advised.entry(m.consumer).or_default().insert(m.consumer_pe);
                let row = fs.matrix.entry(m.consumer).or_default();
                for (pe, bytes) in m.by_pe {
                    *row.entry(pe).or_default() += bytes;
                }
                let here = row.get(&m.consumer_pe).copied().unwrap_or(0);
                // Dominant source PE: most bytes, ties broken toward the
                // lowest PE so the decision is deterministic whatever
                // the map's iteration order.
                let Some((&dom, &dom_bytes)) =
                    row.iter().max_by_key(|&(&pe, &b)| (b, std::cmp::Reverse(pe)))
                else {
                    return;
                };
                // Advice rule: the dominant source must be elsewhere AND
                // clearly dominant (≥ 2× the consumer's local bytes) —
                // migration is not free, so a marginal edge never moves
                // anyone.
                let wants_move =
                    dom != m.consumer_pe && dom_bytes >= here.saturating_mul(2).max(1);
                if wants_move {
                    let blocked = fs.budget_left == 0
                        || fs.advised.get(&m.consumer).is_some_and(|s| s.contains(&dom));
                    if blocked {
                        ctx.metrics().count(keys::CONSUMER_ADVICE_SUPPRESSED, 1);
                    } else {
                        fs.budget_left -= 1;
                        fs.advised.entry(m.consumer).or_default().insert(dom);
                        ctx.metrics().count(keys::CONSUMER_MIGRATIONS_ADVISED, 1);
                        if ctx.trace().on(TraceCategory::Place) {
                            let now = ctx.now();
                            let pe = ctx.pe().0;
                            ctx.trace().instant(
                                now,
                                TraceCategory::Place,
                                trace_names::PLACE_CONSUMER_ADVICE,
                                TraceLane::Pe(pe),
                                u64::from(dom),
                                dom_bytes,
                                "",
                            );
                        }
                        // Location-managed delivery: the advice follows
                        // the consumer even if it is already migrating.
                        ctx.fire(
                            Callback::to_chare(m.consumer, EP_CONSUMER_ADVICE),
                            Payload::new(ConsumerAdviceMsg { session: m.session, to_pe: dom }),
                        );
                    }
                }
                ctx.advance(MICROS / 2);
            }
            EP_DIR_START_WRITE => {
                let m: StartWriteMsg = msg.take();
                // Same early/rejected robustness as read session starts:
                // a write pipelined behind its open is held and replayed;
                // one behind a rejected open degrades to the structured
                // error.
                let Some(entry) = self.files.get(&m.file) else {
                    if self.opens.contains_key(&m.file) {
                        self.early_writes.entry(m.file).or_default().push(m);
                        return;
                    }
                    if let Some(e) = self.rejected_opens.get(&m.file) {
                        ctx.metrics().count(keys::SESSIONS_REJECTED, 1);
                        ctx.fire(m.ready, Payload::new(e.clone()));
                        return;
                    }
                    panic!("startWriteSession for a file that was never opened");
                };
                let (size, fopts) = (entry.size, entry.opts.clone());
                assert!(m.offset + m.bytes <= size, "write session beyond EOF");
                if let Err(e) = m.wopts.validate() {
                    ctx.metrics().count(keys::SESSIONS_REJECTED, 1);
                    ctx.fire(m.ready, Payload::new(e));
                    return;
                }
                self.start_write(ctx, m, fopts);
            }
            EP_DIR_FLUSH => {
                let m: FlushMsg = msg.take();
                // Flushing a fully closed session is a completed barrier
                // by definition (idempotent, like a double close).
                if !self.sessions.contains_key(&m.session) {
                    ctx.fire(m.after, Payload::empty());
                    return;
                }
                assert!(
                    self.writes.contains_key(&m.session),
                    "flush of a read session (flush is write-plane only)"
                );
                // A barrier already in flight: attach — the buffers
                // re-queue any bytes covered since, so one drain answers
                // both calls.
                if let Some(fs) = self.flushes.get_mut(&m.session) {
                    fs.afters.push(m.after);
                    return;
                }
                let st = &self.sessions[&m.session];
                let nbuf = st.session.num_buffers;
                let buffers = st.session.buffers;
                for b in 0..nbuf {
                    ctx.signal(ChareRef::new(buffers, b), EP_WB_FLUSH);
                }
                self.flushes.insert(m.session, FlushState {
                    afters: vec![m.after],
                    acks: 0,
                    need: nbuf,
                    written: 0,
                    degraded: 0,
                    started_at: ctx.now(),
                });
                ctx.advance(MICROS);
            }
            EP_DIR_FLUSH_DONE => {
                let m: FlushDoneMsg = msg.take();
                let Some(fs) = self.flushes.get_mut(&m.session) else { return };
                fs.acks += 1;
                fs.written += m.written;
                fs.degraded += m.degraded;
                if fs.acks == fs.need {
                    let fs = self.flushes.remove(&m.session).unwrap();
                    ctx.metrics().count(keys::WRITE_FLUSHES, 1);
                    if ctx.trace().on(TraceCategory::Session) {
                        let now = ctx.now();
                        let pe = ctx.pe().0;
                        ctx.trace().complete(
                            fs.started_at,
                            now.saturating_sub(fs.started_at),
                            TraceCategory::Session,
                            trace_names::SESSION_FLUSH,
                            TraceLane::Pe(pe),
                            u64::from(m.session.0),
                            fs.written,
                            fs.degraded,
                            "",
                        );
                    }
                    for after in fs.afters {
                        ctx.fire(after, Payload::empty());
                    }
                }
            }
            EP_DIR_CLOSE_WRITE => {
                let m: CloseWriteMsg = msg.take();
                if let Some(cs) = self.closes.get_mut(&m.session) {
                    cs.afters.push(m.after);
                    ctx.metrics().count(keys::DOUBLE_CLOSE, 1);
                    return;
                }
                let Some(st) = self.sessions.get(&m.session) else {
                    ctx.metrics().count(keys::DOUBLE_CLOSE, 1);
                    ctx.fire(
                        m.after,
                        Payload::new(SessionOutcome { session: m.session, ..Default::default() }),
                    );
                    return;
                };
                let ws = self.writes.remove(&m.session).expect("closeWrite of a read session");
                let nbuf = st.session.num_buffers;
                let buffers = st.session.buffers;
                // A write close *always* parks: the resident (possibly
                // still dirty) spans are the read-after-write cache. The
                // drain-or-not decision lives in the buffers' close
                // handler (`park_dirty` skips it).
                for b in 0..nbuf {
                    ctx.signal(ChareRef::new(buffers, b), EP_WB_CLOSE);
                }
                for pe in 0..self.npes {
                    ctx.send_group(
                        self.wassemblers,
                        crate::amt::topology::Pe(pe),
                        EP_WA_SESSION_DROP,
                        m.session,
                    );
                }
                self.closes.insert(m.session, CloseState {
                    afters: vec![m.after],
                    acks: 0,
                    need: nbuf + self.npes,
                    park: Some((ws.key, buffers, nbuf)),
                    parked_bytes: 0,
                    outcome: SessionOutcome::default(),
                });
                if ctx.trace().on(TraceCategory::Session) {
                    let now = ctx.now();
                    let pe = ctx.pe().0;
                    ctx.trace().instant(
                        now,
                        TraceCategory::Session,
                        trace_names::SESSION_DRAIN,
                        TraceLane::Pe(pe),
                        u64::from(m.session.0),
                        u64::from(nbuf),
                        "write",
                    );
                }
                ctx.advance(MICROS);
            }
            EP_DIR_WB_DROPPED => {
                let m: WbDroppedMsg = msg.take();
                let delta = SessionOutcome {
                    session: m.session,
                    written_bytes: m.written,
                    degraded_bytes: m.degraded,
                    dirty_bytes: m.dirty,
                    retries: m.retries,
                    ..Default::default()
                };
                self.ack_close(ctx, m.session, m.resident, delta);
            }
            EP_DIR_CLOSE_ACK => {
                let file: FileId = msg.take();
                let st = self.file_closes.get_mut(&file).expect("ack for unknown close");
                st.acks += 1;
                if st.acks == st.need {
                    let st = self.file_closes.remove(&file).unwrap();
                    for after in st.afters {
                        ctx.fire(after, Payload::empty());
                    }
                }
            }
            other => panic!("Director: unknown ep {other}"),
        }
    }

    impl_chare_any!();
}
