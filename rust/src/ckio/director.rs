//! Director chare (paper §III-C.1).
//!
//! The singleton coordinator: drives file opens through the MDS, creates
//! the per-session buffer-chare array, announces sessions to the manager
//! group, fires the user's `opened`/`ready`/`closed` callbacks once every
//! participant has acknowledged, and sequences session teardown. Global
//! coordination (e.g. sequencing sessions of distinct files) also lives
//! here.
//!
//! Concurrency (PR 1): the director is genuinely multi-session —
//!
//! * **opens are refcounted**: concurrent or repeated opens of the same
//!   file share one MDS transaction / manager broadcast (later opens are
//!   answered from the file table); each `close` decrements, and only the
//!   last one tears the file down everywhere,
//! * any number of sessions — same file or distinct files — may be open,
//!   reading, and closing at once; all coordination state is keyed by
//!   `SessionId`,
//! * **teardown drains**: buffers answer every queued fetch (data or
//!   modeled NACK) before acking, managers NACK reads that arrive after
//!   the drop, assemblers are told so late pieces are tolerated — no
//!   read callback is ever stranded or fired twice,
//! * **buffer reuse** (`Options::reuse_buffers`): closing parks the
//!   session's buffer array in a small FIFO cache keyed by
//!   `(file, range, shape)`; a later identical session rebinds it and is
//!   served from resident data with no file-system traffic.

use std::collections::{HashMap, VecDeque};

use crate::amt::callback::Callback;
use crate::amt::chare::{Chare, ChareRef, CollectionId};
use crate::amt::engine::Ctx;
use crate::amt::msg::{Ep, Msg, Payload};
use crate::amt::time::MICROS;
use crate::impl_chare_any;
use crate::pfs::layout::FileId;

use super::assembler::EP_A_SESSION_DROP;
use super::buffer::{
    BufDroppedMsg, BufStartedMsg, BufferChare, EP_BUF_DROP, EP_BUF_INIT, EP_BUF_PARK, EP_BUF_REBIND,
};
use super::manager::{
    FileOpenedMsg, SessionAnnounceMsg, EP_M_FILE_CLOSE, EP_M_FILE_OPENED, EP_M_SESSION_ANNOUNCE,
    EP_M_SESSION_DROP,
};
use super::options::Options;
use super::session::{FileHandle, Session, SessionId};

/// User: open a file.
pub const EP_DIR_OPEN: Ep = 1;
/// MDS open transaction completed.
pub const EP_DIR_MDS_DONE: Ep = 2;
/// Manager ack: file table updated.
pub const EP_DIR_OPEN_ACK: Ep = 3;
/// User: start a read session.
pub const EP_DIR_START_SESSION: Ep = 4;
/// Buffer chare: greedy reads initiated (or parked array rebound).
pub const EP_DIR_BUF_STARTED: Ep = 5;
/// Manager ack: session table updated.
pub const EP_DIR_ANNOUNCE_ACK: Ep = 6;
/// User: close a read session.
pub const EP_DIR_CLOSE_SESSION: Ep = 7;
/// Buffer chare ack: state dropped/parked.
pub const EP_DIR_DROP_ACK: Ep = 8;
/// Manager ack: session entry dropped.
pub const EP_DIR_DROP_ACK_MGR: Ep = 9;
/// User: close a file.
pub const EP_DIR_CLOSE_FILE: Ep = 10;
/// Manager ack: file entry dropped.
pub const EP_DIR_CLOSE_ACK: Ep = 11;

/// Parked buffer arrays kept for reuse before the oldest is evicted
/// (real eviction policy is an open item — see ROADMAP).
const MAX_CACHED_ARRAYS: usize = 8;

#[derive(Debug)]
pub struct OpenMsg {
    pub file: FileId,
    pub size: u64,
    pub opts: Options,
    pub opened: Callback,
}

#[derive(Debug)]
pub struct StartSessionMsg {
    pub file: FileId,
    pub offset: u64,
    pub bytes: u64,
    pub ready: Callback,
}

#[derive(Debug)]
pub struct CloseSessionMsg {
    pub session: SessionId,
    pub after: Callback,
}

#[derive(Debug)]
pub struct CloseFileMsg {
    pub file: FileId,
    pub after: Callback,
}

/// An open in flight through the MDS; later opens of the same file pile
/// their callbacks onto `waiters`.
struct OpenState {
    size: u64,
    opts: Options,
    waiters: Vec<Callback>,
    acks: u32,
}

/// An open file: refcounted so concurrent sessions can share it.
struct FileEntry {
    size: u64,
    opts: Options,
    open_count: u32,
}

/// Shape key for the parked-buffer reuse cache: a new session matches a
/// parked array only if every property that shaped the array agrees.
#[derive(Clone, PartialEq, Eq, Debug)]
struct BufKey {
    file: FileId,
    offset: u64,
    bytes: u64,
    readers: u32,
    splinter: u64,
    window: u32,
}

struct SessionState {
    session: Session,
    ready: Callback,
    buf_started: u32,
    mgr_acks: u32,
    fired: bool,
    /// `Some` iff the session opted into buffer reuse: the cache key its
    /// array is parked under on close.
    reuse_key: Option<BufKey>,
}

/// A teardown in progress (session or file); extra close calls for the
/// same id pile onto `afters`.
struct CloseState {
    afters: Vec<Callback>,
    acks: u32,
    need: u32,
    /// For a parking (reuse) session close: the array to publish into
    /// the cache once every ack is in. Publishing only *after* the close
    /// completes guarantees a cached array is fully parked — no later
    /// eviction or purge can race this close's own acks.
    park: Option<(BufKey, CollectionId, u32)>,
}

/// The Director singleton.
pub struct Director {
    managers: CollectionId,
    assemblers: CollectionId,
    npes: u32,
    /// Opens awaiting MDS completion, FIFO (the MDS completes in order).
    mds_queue: VecDeque<FileId>,
    opens: HashMap<FileId, OpenState>,
    files: HashMap<FileId, FileEntry>,
    /// startReadSession calls that raced ahead of their file's open.
    early_sessions: HashMap<FileId, Vec<StartSessionMsg>>,
    sessions: HashMap<SessionId, SessionState>,
    closes: HashMap<SessionId, CloseState>,
    file_closes: HashMap<FileId, CloseState>,
    /// Parked buffer arrays, FIFO by park time.
    buffer_cache: Vec<(BufKey, CollectionId, u32)>,
    next_session: u32,
}

impl Director {
    pub fn new(managers: CollectionId, assemblers: CollectionId, npes: u32) -> Director {
        Director {
            managers,
            assemblers,
            npes,
            mds_queue: VecDeque::new(),
            opens: HashMap::new(),
            files: HashMap::new(),
            early_sessions: HashMap::new(),
            sessions: HashMap::new(),
            closes: HashMap::new(),
            file_closes: HashMap::new(),
            buffer_cache: Vec::new(),
            next_session: 0,
        }
    }

    fn maybe_ready(&mut self, ctx: &mut Ctx<'_>, sid: SessionId) {
        // Tolerate late start-acks for sessions already torn down (a
        // close can race the tail of session startup).
        let Some(st) = self.sessions.get_mut(&sid) else { return };
        if !st.fired && st.buf_started == st.session.num_buffers && st.mgr_acks == self.npes {
            st.fired = true;
            ctx.fire(st.ready.clone(), Payload::new(st.session));
        }
    }

    fn ack_close(&mut self, ctx: &mut Ctx<'_>, sid: SessionId) {
        // Acks may also come from cache-evicted parked buffers whose
        // original close completed long ago: ignore those.
        let Some(st) = self.closes.get_mut(&sid) else { return };
        st.acks += 1;
        if st.acks == st.need {
            let st = self.closes.remove(&sid).unwrap();
            self.sessions.remove(&sid);
            // Publish the fully parked array for reuse — unless its file
            // was closed in the meantime (nothing can rebind it then).
            if let Some((key, buffers, nbuf)) = st.park {
                if self.files.contains_key(&key.file) {
                    self.buffer_cache.push((key, buffers, nbuf));
                    if self.buffer_cache.len() > MAX_CACHED_ARRAYS {
                        let (_, old, oldn) = self.buffer_cache.remove(0);
                        self.drop_array(ctx, old, oldn);
                        ctx.metrics().count("ckio.buffer_cache_evictions", 1);
                    }
                } else {
                    self.drop_array(ctx, buffers, nbuf);
                }
            }
            for after in st.afters {
                ctx.fire(after, Payload::empty());
            }
        }
    }

    /// Release every element of a buffer-chare array (teardown, cache
    /// eviction, or file-close purge).
    fn drop_array(&self, ctx: &mut Ctx<'_>, buffers: CollectionId, n: u32) {
        for b in 0..n {
            ctx.signal(ChareRef::new(buffers, b), EP_BUF_DROP);
        }
    }

    /// Announce a freshly inserted session to every manager.
    fn announce(&mut self, ctx: &mut Ctx<'_>, session: Session) {
        for pe in 0..self.npes {
            ctx.send_group(
                self.managers,
                crate::amt::topology::Pe(pe),
                EP_M_SESSION_ANNOUNCE,
                SessionAnnounceMsg { session },
            );
        }
    }

    // ------------------------------------------------------------------
    // test / driver inspection
    // ------------------------------------------------------------------

    /// Sessions currently live (leak checks: must be 0 after all closes).
    pub fn active_sessions(&self) -> usize {
        self.sessions.len()
    }

    /// Session teardowns still collecting acks.
    pub fn pending_closes(&self) -> usize {
        self.closes.len()
    }

    /// Files currently open (refcounted).
    pub fn open_files(&self) -> usize {
        self.files.len()
    }

    /// Parked buffer arrays available for reuse.
    pub fn cached_buffer_arrays(&self) -> usize {
        self.buffer_cache.len()
    }
}

impl Chare for Director {
    fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
        match msg.ep {
            EP_DIR_OPEN => {
                let m: OpenMsg = msg.take();
                // Refcounted re-open: the file is already open everywhere,
                // answer immediately from the file table.
                if let Some(entry) = self.files.get_mut(&m.file) {
                    entry.open_count += 1;
                    ctx.metrics().count("ckio.reopens", 1);
                    let handle =
                        FileHandle { file: m.file, size: entry.size, opts: entry.opts.clone() };
                    ctx.fire(m.opened, Payload::new(handle));
                    return;
                }
                // An open of the same file is already in flight: share its
                // MDS transaction and manager broadcast.
                if let Some(st) = self.opens.get_mut(&m.file) {
                    st.waiters.push(m.opened);
                    ctx.metrics().count("ckio.reopens", 1);
                    return;
                }
                self.opens.insert(m.file, OpenState {
                    size: m.size,
                    opts: m.opts,
                    waiters: vec![m.opened],
                    acks: 0,
                });
                self.mds_queue.push_back(m.file);
                let me = ctx.me();
                ctx.advance(MICROS);
                ctx.open_file(Callback::to_chare(me, EP_DIR_MDS_DONE));
            }
            EP_DIR_MDS_DONE => {
                // MDS transactions complete FIFO; match to the oldest open.
                let file = self.mds_queue.pop_front().expect("MDS done without open");
                let opts = self.opens[&file].opts.clone();
                // Tell every manager about the file.
                for pe in 0..self.npes {
                    ctx.send_group(self.managers, crate::amt::topology::Pe(pe), EP_M_FILE_OPENED,
                        FileOpenedMsg { file, opts: opts.clone() });
                }
                ctx.advance(MICROS);
            }
            EP_DIR_OPEN_ACK => {
                let file: FileId = msg.take();
                let st = self.opens.get_mut(&file).expect("ack for unknown open");
                st.acks += 1;
                if st.acks == self.npes {
                    let st = self.opens.remove(&file).unwrap();
                    self.files.insert(file, FileEntry {
                        size: st.size,
                        opts: st.opts.clone(),
                        open_count: st.waiters.len() as u32,
                    });
                    for opened in st.waiters {
                        ctx.fire(opened, Payload::new(FileHandle {
                            file,
                            size: st.size,
                            opts: st.opts.clone(),
                        }));
                    }
                    // Replay session starts that raced ahead of the open.
                    let me = ctx.me();
                    for m in self.early_sessions.remove(&file).unwrap_or_default() {
                        ctx.send(me, EP_DIR_START_SESSION, m);
                    }
                }
            }
            EP_DIR_START_SESSION => {
                let m: StartSessionMsg = msg.take();
                // Robustness: a session start racing ahead of the file's
                // open completion is held and replayed (split-phase APIs
                // make this easy to hit from driver code).
                let Some(entry) = self.files.get(&m.file) else {
                    assert!(
                        self.opens.contains_key(&m.file),
                        "startReadSession for a file that was never opened"
                    );
                    self.early_sessions.entry(m.file).or_default().push(m);
                    return;
                };
                let (size, opts) = (entry.size, entry.opts.clone());
                assert!(m.offset + m.bytes <= size, "session beyond EOF");
                let sid = SessionId(self.next_session);
                self.next_session += 1;
                let topo = ctx.topo();
                let nreaders = opts.resolve_readers(m.bytes, &topo);
                let splinter = opts.splinter_bytes;
                let window = opts.read_window;
                let file = m.file;
                let (offset, bytes) = (m.offset, m.bytes);
                let key = BufKey {
                    file,
                    offset,
                    bytes,
                    readers: nreaders,
                    splinter: splinter.unwrap_or(0),
                    window,
                };
                ctx.metrics().count("ckio.sessions", 1);

                // Reuse path: an identically shaped parked array serves
                // the new session from resident data — no greedy re-read.
                if opts.reuse_buffers {
                    if let Some(pos) = self.buffer_cache.iter().position(|(k, _, _)| *k == key) {
                        let (_, buffers, nbuf) = self.buffer_cache.remove(pos);
                        debug_assert_eq!(nbuf, nreaders);
                        let session = Session::new(sid, file, offset, bytes, buffers, nreaders);
                        self.sessions.insert(sid, SessionState {
                            session,
                            ready: m.ready,
                            buf_started: 0,
                            mgr_acks: 0,
                            fired: false,
                            reuse_key: Some(key),
                        });
                        for b in 0..nreaders {
                            ctx.send(ChareRef::new(buffers, b), EP_BUF_REBIND, sid);
                        }
                        self.announce(ctx, session);
                        ctx.metrics().count("ckio.buffer_reuse", 1);
                        ctx.advance(MICROS);
                        return;
                    }
                }

                // Fresh path: create the per-session buffer chare array
                // (dynamic creation, as CkIO does on session start).
                let me = ctx.me();
                let assemblers = self.assemblers;
                let placement = opts.placement.to_placement(nreaders);
                let mut spans: Vec<(u64, u64)> = Vec::with_capacity(nreaders as usize);
                {
                    // span math identical to Session::buffer_span
                    let span = crate::util::bytes::ceil_div(bytes, nreaders as u64);
                    for b in 0..nreaders as u64 {
                        let lo = (offset + b * span).min(offset + bytes);
                        let hi = (lo + span).min(offset + bytes);
                        spans.push((lo, hi - lo));
                    }
                }
                let buffers = ctx.create_array_now(nreaders, &placement, |i| {
                    let (o, l) = spans[i as usize];
                    BufferChare::new(sid, file, o, l, splinter, window, me, assemblers)
                });
                let session = Session::new(sid, file, offset, bytes, buffers, nreaders);
                self.sessions.insert(sid, SessionState {
                    session,
                    ready: m.ready,
                    buf_started: 0,
                    mgr_acks: 0,
                    fired: false,
                    reuse_key: opts.reuse_buffers.then_some(key),
                });
                // Kick the greedy reads and announce to managers.
                for b in 0..nreaders {
                    ctx.signal(ChareRef::new(buffers, b), EP_BUF_INIT);
                }
                self.announce(ctx, session);
                ctx.advance(2 * MICROS);
            }
            EP_DIR_BUF_STARTED => {
                let m: BufStartedMsg = msg.take();
                if let Some(st) = self.sessions.get_mut(&m.session) {
                    st.buf_started += 1;
                }
                self.maybe_ready(ctx, m.session);
            }
            EP_DIR_ANNOUNCE_ACK => {
                let sid: SessionId = msg.take();
                if let Some(st) = self.sessions.get_mut(&sid) {
                    st.mgr_acks += 1;
                }
                self.maybe_ready(ctx, sid);
            }
            EP_DIR_CLOSE_SESSION => {
                let m: CloseSessionMsg = msg.take();
                // A close already in flight for this session: attach.
                if let Some(cs) = self.closes.get_mut(&m.session) {
                    cs.afters.push(m.after);
                    ctx.metrics().count("ckio.double_close", 1);
                    return;
                }
                let Some(st) = self.sessions.get(&m.session) else {
                    // Already fully closed (idempotent close): ack now.
                    ctx.metrics().count("ckio.double_close", 1);
                    ctx.fire(m.after, Payload::empty());
                    return;
                };
                let nbuf = st.session.num_buffers;
                let buffers = st.session.buffers;
                let park = match st.reuse_key.clone() {
                    Some(key) => {
                        // Park: drain pending fetches but keep resident
                        // data for a future identically shaped session.
                        // The array is published into the reuse cache
                        // only once this close fully acks (ack_close).
                        for b in 0..nbuf {
                            ctx.signal(ChareRef::new(buffers, b), EP_BUF_PARK);
                        }
                        Some((key, buffers, nbuf))
                    }
                    None => {
                        self.drop_array(ctx, buffers, nbuf);
                        None
                    }
                };
                for pe in 0..self.npes {
                    ctx.send_group(self.managers, crate::amt::topology::Pe(pe), EP_M_SESSION_DROP, m.session);
                    // Fire-and-forget: assemblers only need to know the
                    // session is gone so late pieces are tolerated.
                    ctx.send_group(self.assemblers, crate::amt::topology::Pe(pe), EP_A_SESSION_DROP, m.session);
                }
                self.closes.insert(m.session, CloseState {
                    afters: vec![m.after],
                    acks: 0,
                    need: nbuf + self.npes,
                    park,
                });
                ctx.advance(MICROS);
            }
            EP_DIR_DROP_ACK => {
                let m: BufDroppedMsg = msg.take();
                self.ack_close(ctx, m.session);
            }
            EP_DIR_DROP_ACK_MGR => {
                let sid: SessionId = msg.take();
                self.ack_close(ctx, sid);
            }
            EP_DIR_CLOSE_FILE => {
                let m: CloseFileMsg = msg.take();
                let entry = self.files.get_mut(&m.file).expect("closing unopened file");
                entry.open_count -= 1;
                if entry.open_count > 0 {
                    // Other owners (concurrent sessions) still hold the
                    // file open: this close is complete immediately.
                    ctx.fire(m.after, Payload::empty());
                    return;
                }
                self.files.remove(&m.file);
                // Parked buffer arrays of a closed file can never be
                // rebound again: release them.
                let mut kept = Vec::new();
                for (k, cid, n) in std::mem::take(&mut self.buffer_cache) {
                    if k.file == m.file {
                        self.drop_array(ctx, cid, n);
                    } else {
                        kept.push((k, cid, n));
                    }
                }
                self.buffer_cache = kept;
                for pe in 0..self.npes {
                    ctx.send_group(self.managers, crate::amt::topology::Pe(pe), EP_M_FILE_CLOSE, m.file);
                }
                self.file_closes.insert(m.file, CloseState {
                    afters: vec![m.after],
                    acks: 0,
                    need: self.npes,
                    park: None,
                });
                ctx.advance(MICROS);
            }
            EP_DIR_CLOSE_ACK => {
                let file: FileId = msg.take();
                let st = self.file_closes.get_mut(&file).expect("ack for unknown close");
                st.acks += 1;
                if st.acks == st.need {
                    let st = self.file_closes.remove(&file).unwrap();
                    for after in st.afters {
                        ctx.fire(after, Payload::empty());
                    }
                }
            }
            other => panic!("Director: unknown ep {other}"),
        }
    }

    impl_chare_any!();
}
