//! Director chare (paper §III-C.1).
//!
//! The singleton coordinator: drives file opens through the MDS, creates
//! the per-session buffer-chare array, announces sessions to the manager
//! group, fires the user's `opened`/`ready`/`closed` callbacks once every
//! participant has acknowledged, and sequences session teardown. Global
//! coordination (e.g. sequencing sessions of distinct files) would also
//! live here.

use std::collections::{HashMap, VecDeque};

use crate::amt::callback::Callback;
use crate::amt::chare::{Chare, ChareRef, CollectionId};
use crate::amt::engine::Ctx;
use crate::amt::msg::{Ep, Msg, Payload};
use crate::amt::time::MICROS;
use crate::impl_chare_any;
use crate::pfs::layout::FileId;

use super::buffer::{BufDroppedMsg, BufStartedMsg, BufferChare, EP_BUF_DROP, EP_BUF_INIT};
use super::manager::{FileOpenedMsg, SessionAnnounceMsg, EP_M_FILE_CLOSE, EP_M_FILE_OPENED, EP_M_SESSION_ANNOUNCE, EP_M_SESSION_DROP};
use super::options::Options;
use super::session::{FileHandle, Session, SessionId};

/// User: open a file.
pub const EP_DIR_OPEN: Ep = 1;
/// MDS open transaction completed.
pub const EP_DIR_MDS_DONE: Ep = 2;
/// Manager ack: file table updated.
pub const EP_DIR_OPEN_ACK: Ep = 3;
/// User: start a read session.
pub const EP_DIR_START_SESSION: Ep = 4;
/// Buffer chare: greedy reads initiated.
pub const EP_DIR_BUF_STARTED: Ep = 5;
/// Manager ack: session table updated.
pub const EP_DIR_ANNOUNCE_ACK: Ep = 6;
/// User: close a read session.
pub const EP_DIR_CLOSE_SESSION: Ep = 7;
/// Buffer chare ack: state dropped.
pub const EP_DIR_DROP_ACK: Ep = 8;
/// Manager ack: session entry dropped.
pub const EP_DIR_DROP_ACK_MGR: Ep = 9;
/// User: close a file.
pub const EP_DIR_CLOSE_FILE: Ep = 10;
/// Manager ack: file entry dropped.
pub const EP_DIR_CLOSE_ACK: Ep = 11;

#[derive(Debug)]
pub struct OpenMsg {
    pub file: FileId,
    pub size: u64,
    pub opts: Options,
    pub opened: Callback,
}

#[derive(Debug)]
pub struct StartSessionMsg {
    pub file: FileId,
    pub offset: u64,
    pub bytes: u64,
    pub ready: Callback,
}

#[derive(Debug)]
pub struct CloseSessionMsg {
    pub session: SessionId,
    pub after: Callback,
}

#[derive(Debug)]
pub struct CloseFileMsg {
    pub file: FileId,
    pub after: Callback,
}

struct OpenState {
    size: u64,
    opts: Options,
    opened: Callback,
    acks: u32,
}

struct SessionState {
    session: Session,
    ready: Callback,
    buf_started: u32,
    mgr_acks: u32,
    fired: bool,
}

struct CloseState {
    after: Callback,
    acks: u32,
    need: u32,
}

/// The Director singleton.
pub struct Director {
    managers: CollectionId,
    assemblers: CollectionId,
    npes: u32,
    /// Opens awaiting MDS completion, FIFO (the MDS completes in order).
    mds_queue: VecDeque<FileId>,
    opens: HashMap<FileId, OpenState>,
    files: HashMap<FileId, (u64, Options)>,
    /// startReadSession calls that raced ahead of their file's open.
    early_sessions: HashMap<FileId, Vec<StartSessionMsg>>,
    sessions: HashMap<SessionId, SessionState>,
    closes: HashMap<SessionId, CloseState>,
    file_closes: HashMap<FileId, CloseState>,
    next_session: u32,
}

impl Director {
    pub fn new(managers: CollectionId, assemblers: CollectionId, npes: u32) -> Director {
        Director {
            managers,
            assemblers,
            npes,
            mds_queue: VecDeque::new(),
            opens: HashMap::new(),
            files: HashMap::new(),
            early_sessions: HashMap::new(),
            sessions: HashMap::new(),
            closes: HashMap::new(),
            file_closes: HashMap::new(),
            next_session: 0,
        }
    }

    fn maybe_ready(&mut self, ctx: &mut Ctx<'_>, sid: SessionId) {
        let st = self.sessions.get_mut(&sid).expect("unknown session");
        if !st.fired && st.buf_started == st.session.num_buffers && st.mgr_acks == self.npes {
            st.fired = true;
            ctx.fire(st.ready.clone(), Payload::new(st.session));
        }
    }
}

impl Chare for Director {
    fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
        match msg.ep {
            EP_DIR_OPEN => {
                let m: OpenMsg = msg.take();
                self.opens.insert(m.file, OpenState {
                    size: m.size,
                    opts: m.opts,
                    opened: m.opened,
                    acks: 0,
                });
                self.mds_queue.push_back(m.file);
                let me = ctx.me();
                ctx.advance(MICROS);
                ctx.open_file(Callback::to_chare(me, EP_DIR_MDS_DONE));
            }
            EP_DIR_MDS_DONE => {
                // MDS transactions complete FIFO; match to the oldest open.
                let file = self.mds_queue.pop_front().expect("MDS done without open");
                let opts = self.opens[&file].opts.clone();
                // Tell every manager about the file.
                for pe in 0..self.npes {
                    ctx.send_group(self.managers, crate::amt::topology::Pe(pe), EP_M_FILE_OPENED,
                        FileOpenedMsg { file, opts: opts.clone() });
                }
                ctx.advance(MICROS);
            }
            EP_DIR_OPEN_ACK => {
                let file: FileId = msg.take();
                let st = self.opens.get_mut(&file).expect("ack for unknown open");
                st.acks += 1;
                if st.acks == self.npes {
                    let st = self.opens.remove(&file).unwrap();
                    self.files.insert(file, (st.size, st.opts.clone()));
                    ctx.fire(st.opened, Payload::new(FileHandle {
                        file,
                        size: st.size,
                        opts: st.opts,
                    }));
                    // Replay session starts that raced ahead of the open.
                    let me = ctx.me();
                    for m in self.early_sessions.remove(&file).unwrap_or_default() {
                        ctx.send(me, EP_DIR_START_SESSION, m);
                    }
                }
            }
            EP_DIR_START_SESSION => {
                let m: StartSessionMsg = msg.take();
                // Robustness: a session start racing ahead of the file's
                // open completion is held and replayed (split-phase APIs
                // make this easy to hit from driver code).
                let Some(entry) = self.files.get(&m.file) else {
                    assert!(
                        self.opens.contains_key(&m.file),
                        "startReadSession for a file that was never opened"
                    );
                    self.early_sessions.entry(m.file).or_default().push(m);
                    return;
                };
                let (size, opts) = entry.clone();
                assert!(m.offset + m.bytes <= size, "session beyond EOF");
                let sid = SessionId(self.next_session);
                self.next_session += 1;
                let topo = ctx.topo();
                let nreaders = opts.resolve_readers(m.bytes, &topo);
                // Create the per-session buffer chare array (dynamic
                // creation, as CkIO does on session start).
                let me = ctx.me();
                let assemblers = self.assemblers;
                let placement = opts.placement.to_placement(nreaders);
                // Session math first (needs the collection id).
                let splinter = opts.splinter_bytes;
                let window = opts.read_window;
                let file = m.file;
                let (offset, bytes) = (m.offset, m.bytes);
                // Two-phase: compute spans via a prototype Session once we
                // know the collection id from create_array_now.
                let mut spans: Vec<(u64, u64)> = Vec::with_capacity(nreaders as usize);
                {
                    // span math identical to Session::buffer_span
                    let span = crate::util::bytes::ceil_div(bytes, nreaders as u64);
                    for b in 0..nreaders as u64 {
                        let lo = (offset + b * span).min(offset + bytes);
                        let hi = (lo + span).min(offset + bytes);
                        spans.push((lo, hi - lo));
                    }
                }
                let buffers = ctx.create_array_now(nreaders, &placement, |i| {
                    let (o, l) = spans[i as usize];
                    BufferChare::new(sid, file, o, l, splinter, window, me, assemblers)
                });
                let session = Session::new(sid, file, offset, bytes, buffers, nreaders);
                self.sessions.insert(sid, SessionState {
                    session,
                    ready: m.ready,
                    buf_started: 0,
                    mgr_acks: 0,
                    fired: false,
                });
                // Kick the greedy reads and announce to managers.
                for b in 0..nreaders {
                    ctx.signal(ChareRef::new(buffers, b), EP_BUF_INIT);
                }
                for pe in 0..self.npes {
                    ctx.send_group(self.managers, crate::amt::topology::Pe(pe), EP_M_SESSION_ANNOUNCE,
                        SessionAnnounceMsg { session });
                }
                ctx.advance(2 * MICROS);
                ctx.metrics().count("ckio.sessions", 1);
            }
            EP_DIR_BUF_STARTED => {
                let m: BufStartedMsg = msg.take();
                if let Some(st) = self.sessions.get_mut(&m.session) {
                    st.buf_started += 1;
                }
                self.maybe_ready(ctx, m.session);
            }
            EP_DIR_ANNOUNCE_ACK => {
                let sid: SessionId = msg.take();
                if let Some(st) = self.sessions.get_mut(&sid) {
                    st.mgr_acks += 1;
                }
                self.maybe_ready(ctx, sid);
            }
            EP_DIR_CLOSE_SESSION => {
                let m: CloseSessionMsg = msg.take();
                let st = self.sessions.get(&m.session).expect("closing unknown session");
                let nbuf = st.session.num_buffers;
                let buffers = st.session.buffers;
                for b in 0..nbuf {
                    ctx.signal(ChareRef::new(buffers, b), EP_BUF_DROP);
                }
                for pe in 0..self.npes {
                    ctx.send_group(self.managers, crate::amt::topology::Pe(pe), EP_M_SESSION_DROP, m.session);
                }
                self.closes.insert(m.session, CloseState {
                    after: m.after,
                    acks: 0,
                    need: nbuf + self.npes,
                });
                ctx.advance(MICROS);
            }
            EP_DIR_DROP_ACK => {
                let m: BufDroppedMsg = msg.take();
                self.ack_close(ctx, m.session);
            }
            EP_DIR_DROP_ACK_MGR => {
                let sid: SessionId = msg.take();
                self.ack_close(ctx, sid);
            }
            EP_DIR_CLOSE_FILE => {
                let m: CloseFileMsg = msg.take();
                assert!(self.files.remove(&m.file).is_some(), "closing unopened file");
                for pe in 0..self.npes {
                    ctx.send_group(self.managers, crate::amt::topology::Pe(pe), EP_M_FILE_CLOSE, m.file);
                }
                self.file_closes.insert(m.file, CloseState { after: m.after, acks: 0, need: self.npes });
                ctx.advance(MICROS);
            }
            EP_DIR_CLOSE_ACK => {
                let file: FileId = msg.take();
                let st = self.file_closes.get_mut(&file).expect("ack for unknown close");
                st.acks += 1;
                if st.acks == st.need {
                    let st = self.file_closes.remove(&file).unwrap();
                    ctx.fire(st.after, Payload::empty());
                }
            }
            other => panic!("Director: unknown ep {other}"),
        }
    }

    impl_chare_any!();
}

impl Director {
    fn ack_close(&mut self, ctx: &mut Ctx<'_>, sid: SessionId) {
        let st = self.closes.get_mut(&sid).expect("drop ack for unknown close");
        st.acks += 1;
        if st.acks == st.need {
            let st = self.closes.remove(&sid).unwrap();
            self.sessions.remove(&sid);
            ctx.fire(st.after, Payload::empty());
        }
    }
}
