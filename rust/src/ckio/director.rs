//! Director chare (paper §III-C.1).
//!
//! The singleton coordinator: drives file opens through the MDS, creates
//! the per-session buffer-chare array, announces sessions to the manager
//! group, fires the user's `opened`/`ready`/`closed` callbacks once every
//! participant has acknowledged, and sequences session teardown. Global
//! coordination lives here — concretely, the director owns the two
//! PR 2 subsystems that need the cluster-wide view:
//!
//! * the **span store** ([`super::store`]): which bytes of which file are
//!   resident in which buffer-chare array (live or parked). At session
//!   start the director matches the new session's splinter slots against
//!   the store's claims and points the new buffers at *peer* sources
//!   instead of the PFS — same-file concurrent sessions dedup their
//!   prefetch, and parked arrays serve partial overlaps. Parked arrays
//!   are kept under a byte budget with LRU eviction
//!   ([`super::Options::store_budget_bytes`]).
//! * the **admission governor** ([`super::governor`]): the global cap on
//!   PFS reads in flight ([`super::Options::max_inflight_reads`]). Buffer
//!   chares of governed files request tickets here and the governor
//!   sequences or throttles session prefetch across *all* sessions.
//!
//! Concurrency (PR 1): the director is genuinely multi-session —
//!
//! * **opens are refcounted**: concurrent or repeated opens of the same
//!   file share one MDS transaction / manager broadcast (later opens are
//!   answered from the file table); each `close` decrements, and only the
//!   last one tears the file down everywhere,
//! * any number of sessions — same file or distinct files — may be open,
//!   reading, and closing at once; all coordination state is keyed by
//!   `SessionId`,
//! * **teardown drains**: buffers answer every queued fetch (data or
//!   modeled NACK) before acking, managers NACK reads that arrive after
//!   the drop, assemblers are told so late pieces are tolerated — no
//!   read callback is ever stranded or fired twice,
//! * **buffer reuse** (`Options::reuse_buffers`): closing parks the
//!   session's buffer array in the span store keyed by
//!   `(file, range, shape)`; a later identical session rebinds it and is
//!   served from resident data with no file-system traffic.

use std::collections::{HashMap, VecDeque};

use crate::amt::callback::Callback;
use crate::amt::chare::{Chare, ChareRef, CollectionId};
use crate::amt::engine::Ctx;
use crate::amt::msg::{Ep, Msg, Payload};
use crate::amt::time::MICROS;
use crate::impl_chare_any;
use crate::metrics::keys;
use crate::pfs::layout::FileId;

use super::assembler::EP_A_SESSION_DROP;
use super::buffer::{
    BufDroppedMsg, BufStartedMsg, BufferChare, GrantMsg, IoDoneMsg, IoReqMsg, EP_BUF_DROP,
    EP_BUF_GRANT, EP_BUF_INIT, EP_BUF_PARK, EP_BUF_REBIND,
};
use super::governor::Governor;
use super::manager::{
    FileOpenedMsg, SessionAnnounceMsg, EP_M_FILE_CLOSE, EP_M_FILE_OPENED, EP_M_SESSION_ANNOUNCE,
    EP_M_SESSION_DROP,
};
use super::options::Options;
use super::session::{buffer_span_of, FileHandle, Session, SessionId};
use super::store::{slot_extents, BufKey, Evicted, SpanStore};

/// User: open a file.
pub const EP_DIR_OPEN: Ep = 1;
/// MDS open transaction completed.
pub const EP_DIR_MDS_DONE: Ep = 2;
/// Manager ack: file table updated.
pub const EP_DIR_OPEN_ACK: Ep = 3;
/// User: start a read session.
pub const EP_DIR_START_SESSION: Ep = 4;
/// Buffer chare: greedy reads initiated (or parked array rebound).
pub const EP_DIR_BUF_STARTED: Ep = 5;
/// Manager ack: session table updated.
pub const EP_DIR_ANNOUNCE_ACK: Ep = 6;
/// User: close a read session.
pub const EP_DIR_CLOSE_SESSION: Ep = 7;
/// Buffer chare ack: state dropped/parked.
pub const EP_DIR_DROP_ACK: Ep = 8;
/// Manager ack: session entry dropped.
pub const EP_DIR_DROP_ACK_MGR: Ep = 9;
/// User: close a file.
pub const EP_DIR_CLOSE_FILE: Ep = 10;
/// Manager ack: file entry dropped.
pub const EP_DIR_CLOSE_ACK: Ep = 11;
/// Buffer chare: request PFS read tickets from the admission governor.
pub const EP_DIR_IO_REQ: Ep = 12;
/// Buffer chare: return PFS read tickets to the admission governor.
pub const EP_DIR_IO_DONE: Ep = 13;

#[derive(Debug)]
pub struct OpenMsg {
    pub file: FileId,
    pub size: u64,
    pub opts: Options,
    pub opened: Callback,
}

#[derive(Debug)]
pub struct StartSessionMsg {
    pub file: FileId,
    pub offset: u64,
    pub bytes: u64,
    pub ready: Callback,
}

#[derive(Debug)]
pub struct CloseSessionMsg {
    pub session: SessionId,
    pub after: Callback,
}

#[derive(Debug)]
pub struct CloseFileMsg {
    pub file: FileId,
    pub after: Callback,
}

/// An open in flight through the MDS; later opens of the same file pile
/// their callbacks onto `waiters`.
struct OpenState {
    size: u64,
    opts: Options,
    waiters: Vec<Callback>,
    acks: u32,
}

/// An open file: refcounted so concurrent sessions can share it.
struct FileEntry {
    size: u64,
    opts: Options,
    open_count: u32,
}

struct SessionState {
    session: Session,
    ready: Callback,
    buf_started: u32,
    mgr_acks: u32,
    fired: bool,
    /// `Some` iff the session opted into buffer reuse: the span-store key
    /// its array is parked under on close.
    reuse_key: Option<BufKey>,
}

/// A teardown in progress (session or file); extra close calls for the
/// same id pile onto `afters`.
struct CloseState {
    afters: Vec<Callback>,
    acks: u32,
    need: u32,
    /// For a parking (reuse) session close: the array to publish into
    /// the span store once every ack is in. Publishing only *after* the
    /// close completes guarantees a cached array is fully parked — no
    /// later eviction or purge can race this close's own acks.
    park: Option<(BufKey, CollectionId, u32)>,
    /// Resident bytes reported by the parking buffers' acks (the span
    /// store's budget accounting for the published array).
    parked_bytes: u64,
}

/// The Director singleton.
pub struct Director {
    managers: CollectionId,
    assemblers: CollectionId,
    npes: u32,
    /// Opens awaiting MDS completion, FIFO (the MDS completes in order).
    mds_queue: VecDeque<FileId>,
    opens: HashMap<FileId, OpenState>,
    files: HashMap<FileId, FileEntry>,
    /// startReadSession calls that raced ahead of their file's open.
    early_sessions: HashMap<FileId, Vec<StartSessionMsg>>,
    sessions: HashMap<SessionId, SessionState>,
    closes: HashMap<SessionId, CloseState>,
    file_closes: HashMap<FileId, CloseState>,
    /// The resident-data plane: claims + parked arrays (PR 2).
    store: SpanStore,
    /// Global PFS read-admission control (PR 2).
    governor: Governor,
    next_session: u32,
}

impl Director {
    pub fn new(managers: CollectionId, assemblers: CollectionId, npes: u32) -> Director {
        Director {
            managers,
            assemblers,
            npes,
            mds_queue: VecDeque::new(),
            opens: HashMap::new(),
            files: HashMap::new(),
            early_sessions: HashMap::new(),
            sessions: HashMap::new(),
            closes: HashMap::new(),
            file_closes: HashMap::new(),
            store: SpanStore::new(),
            governor: Governor::new(),
            next_session: 0,
        }
    }

    fn maybe_ready(&mut self, ctx: &mut Ctx<'_>, sid: SessionId) {
        // Tolerate late start-acks for sessions already torn down (a
        // close can race the tail of session startup).
        let Some(st) = self.sessions.get_mut(&sid) else { return };
        if !st.fired && st.buf_started == st.session.num_buffers && st.mgr_acks == self.npes {
            st.fired = true;
            ctx.fire(st.ready.clone(), Payload::new(st.session));
        }
    }

    fn ack_close(&mut self, ctx: &mut Ctx<'_>, sid: SessionId, resident: u64) {
        // Acks may also come from cache-evicted parked buffers whose
        // original close completed long ago: ignore those.
        let Some(st) = self.closes.get_mut(&sid) else { return };
        st.acks += 1;
        st.parked_bytes += resident;
        if st.acks == st.need {
            let st = self.closes.remove(&sid).unwrap();
            self.sessions.remove(&sid);
            // Publish the fully parked array for reuse — unless its file
            // was closed in the meantime (nothing can rebind it then).
            if let Some((key, buffers, nbuf)) = st.park {
                if self.files.contains_key(&key.file) {
                    let evicted = self.store.park(key, buffers, nbuf, st.parked_bytes);
                    self.release_evicted(ctx, evicted);
                } else {
                    self.store.drop_claims(key.file, buffers);
                    self.drop_array(ctx, buffers, nbuf);
                }
                ctx.metrics().set(keys::STORE_RESIDENT, self.store.resident_bytes() as f64);
            }
            for after in st.afters {
                ctx.fire(after, Payload::empty());
            }
        }
    }

    /// Release every element of a buffer-chare array (teardown, cache
    /// eviction, or file-close purge).
    fn drop_array(&self, ctx: &mut Ctx<'_>, buffers: CollectionId, n: u32) {
        for b in 0..n {
            ctx.signal(ChareRef::new(buffers, b), EP_BUF_DROP);
        }
    }

    /// Release arrays the span store evicted (budget) or purged (file
    /// close), charging the eviction metrics.
    fn release_evicted(&mut self, ctx: &mut Ctx<'_>, evicted: Vec<Evicted>) {
        for e in evicted {
            self.drop_array(ctx, e.buffers, e.nbuf);
            ctx.metrics().count("ckio.buffer_cache_evictions", 1);
            ctx.metrics().count(keys::STORE_EVICTED, e.resident_bytes);
        }
    }

    /// Announce a freshly inserted session to every manager.
    fn announce(&mut self, ctx: &mut Ctx<'_>, session: Session) {
        for pe in 0..self.npes {
            ctx.send_group(
                self.managers,
                crate::amt::topology::Pe(pe),
                EP_M_SESSION_ANNOUNCE,
                SessionAnnounceMsg { session },
            );
        }
    }

    // ------------------------------------------------------------------
    // test / driver inspection
    // ------------------------------------------------------------------

    /// Sessions currently live (leak checks: must be 0 after all closes).
    pub fn active_sessions(&self) -> usize {
        self.sessions.len()
    }

    /// Session teardowns still collecting acks.
    pub fn pending_closes(&self) -> usize {
        self.closes.len()
    }

    /// Files currently open (refcounted).
    pub fn open_files(&self) -> usize {
        self.files.len()
    }

    /// Parked buffer arrays available for reuse.
    pub fn cached_buffer_arrays(&self) -> usize {
        self.store.parked_count()
    }

    /// The resident-data plane (inspection).
    pub fn span_store(&self) -> &SpanStore {
        &self.store
    }

    /// The admission governor (inspection).
    pub fn admission(&self) -> &Governor {
        &self.governor
    }
}

impl Chare for Director {
    fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
        match msg.ep {
            EP_DIR_OPEN => {
                let m: OpenMsg = msg.take();
                // Refcounted re-open: the file is already open everywhere,
                // answer immediately from the file table.
                if let Some(entry) = self.files.get_mut(&m.file) {
                    entry.open_count += 1;
                    ctx.metrics().count("ckio.reopens", 1);
                    let handle =
                        FileHandle { file: m.file, size: entry.size, opts: entry.opts.clone() };
                    ctx.fire(m.opened, Payload::new(handle));
                    return;
                }
                // An open of the same file is already in flight: share its
                // MDS transaction and manager broadcast.
                if let Some(st) = self.opens.get_mut(&m.file) {
                    st.waiters.push(m.opened);
                    ctx.metrics().count("ckio.reopens", 1);
                    return;
                }
                // First open: the file's Options configure the global
                // store budget and governor (last writer wins).
                if let Some(budget) = m.opts.store_budget_bytes {
                    self.store.set_budget(budget);
                }
                self.governor.configure(m.opts.max_inflight_reads, m.opts.admission);
                self.opens.insert(m.file, OpenState {
                    size: m.size,
                    opts: m.opts,
                    waiters: vec![m.opened],
                    acks: 0,
                });
                self.mds_queue.push_back(m.file);
                let me = ctx.me();
                ctx.advance(MICROS);
                ctx.open_file(Callback::to_chare(me, EP_DIR_MDS_DONE));
            }
            EP_DIR_MDS_DONE => {
                // MDS transactions complete FIFO; match to the oldest open.
                let file = self.mds_queue.pop_front().expect("MDS done without open");
                let opts = self.opens[&file].opts.clone();
                // Tell every manager about the file.
                for pe in 0..self.npes {
                    ctx.send_group(self.managers, crate::amt::topology::Pe(pe), EP_M_FILE_OPENED,
                        FileOpenedMsg { file, opts: opts.clone() });
                }
                ctx.advance(MICROS);
            }
            EP_DIR_OPEN_ACK => {
                let file: FileId = msg.take();
                let st = self.opens.get_mut(&file).expect("ack for unknown open");
                st.acks += 1;
                if st.acks == self.npes {
                    let st = self.opens.remove(&file).unwrap();
                    self.files.insert(file, FileEntry {
                        size: st.size,
                        opts: st.opts.clone(),
                        open_count: st.waiters.len() as u32,
                    });
                    for opened in st.waiters {
                        ctx.fire(opened, Payload::new(FileHandle {
                            file,
                            size: st.size,
                            opts: st.opts.clone(),
                        }));
                    }
                    // Replay session starts that raced ahead of the open.
                    let me = ctx.me();
                    for m in self.early_sessions.remove(&file).unwrap_or_default() {
                        ctx.send(me, EP_DIR_START_SESSION, m);
                    }
                }
            }
            EP_DIR_START_SESSION => {
                let m: StartSessionMsg = msg.take();
                // Robustness: a session start racing ahead of the file's
                // open completion is held and replayed (split-phase APIs
                // make this easy to hit from driver code).
                let Some(entry) = self.files.get(&m.file) else {
                    assert!(
                        self.opens.contains_key(&m.file),
                        "startReadSession for a file that was never opened"
                    );
                    self.early_sessions.entry(m.file).or_default().push(m);
                    return;
                };
                let (size, opts) = (entry.size, entry.opts.clone());
                assert!(m.offset + m.bytes <= size, "session beyond EOF");
                let sid = SessionId(self.next_session);
                self.next_session += 1;
                let topo = ctx.topo();
                let nreaders = opts.resolve_readers(m.bytes, &topo);
                let splinter = opts.splinter_bytes;
                let window = opts.read_window;
                let file = m.file;
                let (offset, bytes) = (m.offset, m.bytes);
                let key = BufKey {
                    file,
                    offset,
                    bytes,
                    readers: nreaders,
                    splinter: splinter.unwrap_or(0),
                    window,
                };
                ctx.metrics().count("ckio.sessions", 1);

                // Reuse path: an identically shaped parked array serves
                // the new session from resident data — no greedy re-read.
                if opts.reuse_buffers {
                    if let Some((buffers, nbuf)) = self.store.take_exact(&key) {
                        debug_assert_eq!(nbuf, nreaders);
                        ctx.metrics().count(keys::STORE_HIT, bytes);
                        ctx.metrics().set(keys::STORE_RESIDENT, self.store.resident_bytes() as f64);
                        let session = Session::new(sid, file, offset, bytes, buffers, nreaders);
                        self.sessions.insert(sid, SessionState {
                            session,
                            ready: m.ready,
                            buf_started: 0,
                            mgr_acks: 0,
                            fired: false,
                            reuse_key: Some(key),
                        });
                        for b in 0..nreaders {
                            ctx.send(ChareRef::new(buffers, b), EP_BUF_REBIND, sid);
                        }
                        self.announce(ctx, session);
                        ctx.metrics().count("ckio.buffer_reuse", 1);
                        ctx.advance(MICROS);
                        return;
                    }
                }

                // Fresh path: create the per-session buffer chare array
                // (dynamic creation, as CkIO does on session start).
                let me = ctx.me();
                let assemblers = self.assemblers;
                let placement = opts.placement.to_placement(nreaders);
                // The same span partition Session::buffer_span serves to
                // assemblers — one definition, so chare spans, claims,
                // and routing can never drift.
                let spans: Vec<(u64, u64)> =
                    (0..nreaders).map(|b| buffer_span_of(offset, bytes, nreaders, b)).collect();
                // Span-store matching: point each splinter slot that an
                // existing array (live or parked) fully covers at that
                // peer instead of the PFS — prefetch dedup for same-file
                // concurrent sessions, partial-overlap serving from
                // parked arrays. The new session's own claims are not
                // registered yet, so it can never match itself.
                let splinter_v = splinter.unwrap_or(0);
                let peer_lists: Vec<Vec<(u32, ChareRef)>> = spans
                    .iter()
                    .map(|&(o, l)| {
                        slot_extents(o, l, splinter_v)
                            .into_iter()
                            .enumerate()
                            .filter(|&(_, (_, slen))| slen > 0)
                            .filter_map(|(i, (slo, slen))| {
                                self.store
                                    .find_cover(file, slo, slen)
                                    .map(|owner| (i as u32, owner))
                            })
                            .collect()
                    })
                    .collect();
                // Serving peers keeps a parked array hot: refresh its
                // LRU standing (once per distinct array, not per slot)
                // so the budget evicts cold arrays first.
                let owners: std::collections::HashSet<CollectionId> =
                    peer_lists.iter().flatten().map(|&(_, o)| o.collection).collect();
                for owner in owners {
                    self.store.touch(owner);
                }
                let governed = opts.max_inflight_reads.is_some();
                let buffers = ctx.create_array_now(nreaders, &placement, |i| {
                    let (o, l) = spans[i as usize];
                    let mut b = BufferChare::new(sid, file, o, l, splinter, window, me, assemblers)
                        .with_peers(peer_lists[i as usize].clone());
                    if governed {
                        b = b.governed(bytes);
                    }
                    b
                });
                // Register the new array's spans so later sessions (and
                // the parked-array bookkeeping) can find them.
                for (b, &(o, l)) in spans.iter().enumerate() {
                    self.store.add_claim(file, o, l, ChareRef::new(buffers, b as u32));
                }
                let session = Session::new(sid, file, offset, bytes, buffers, nreaders);
                self.sessions.insert(sid, SessionState {
                    session,
                    ready: m.ready,
                    buf_started: 0,
                    mgr_acks: 0,
                    fired: false,
                    reuse_key: opts.reuse_buffers.then_some(key),
                });
                // Kick the greedy reads and announce to managers.
                for b in 0..nreaders {
                    ctx.signal(ChareRef::new(buffers, b), EP_BUF_INIT);
                }
                self.announce(ctx, session);
                ctx.advance(2 * MICROS);
            }
            EP_DIR_BUF_STARTED => {
                let m: BufStartedMsg = msg.take();
                if let Some(st) = self.sessions.get_mut(&m.session) {
                    st.buf_started += 1;
                }
                self.maybe_ready(ctx, m.session);
            }
            EP_DIR_ANNOUNCE_ACK => {
                let sid: SessionId = msg.take();
                if let Some(st) = self.sessions.get_mut(&sid) {
                    st.mgr_acks += 1;
                }
                self.maybe_ready(ctx, sid);
            }
            EP_DIR_CLOSE_SESSION => {
                let m: CloseSessionMsg = msg.take();
                // A close already in flight for this session: attach.
                if let Some(cs) = self.closes.get_mut(&m.session) {
                    cs.afters.push(m.after);
                    ctx.metrics().count("ckio.double_close", 1);
                    return;
                }
                let Some(st) = self.sessions.get(&m.session) else {
                    // Already fully closed (idempotent close): ack now.
                    ctx.metrics().count("ckio.double_close", 1);
                    ctx.fire(m.after, Payload::empty());
                    return;
                };
                let nbuf = st.session.num_buffers;
                let buffers = st.session.buffers;
                let file = st.session.file;
                let park = match st.reuse_key.clone() {
                    Some(key) => {
                        // Park: drain pending fetches but keep resident
                        // data (and span-store claims) for reuse. The
                        // array is published into the store only once
                        // this close fully acks (ack_close).
                        for b in 0..nbuf {
                            ctx.signal(ChareRef::new(buffers, b), EP_BUF_PARK);
                        }
                        Some((key, buffers, nbuf))
                    }
                    None => {
                        // Dropping: the array can no longer serve peers —
                        // unregister its claims before the drop lands so
                        // no new session is pointed at a dying source.
                        self.store.drop_claims(file, buffers);
                        self.drop_array(ctx, buffers, nbuf);
                        None
                    }
                };
                for pe in 0..self.npes {
                    ctx.send_group(self.managers, crate::amt::topology::Pe(pe), EP_M_SESSION_DROP, m.session);
                    // Fire-and-forget: assemblers only need to know the
                    // session is gone so late pieces are tolerated.
                    ctx.send_group(self.assemblers, crate::amt::topology::Pe(pe), EP_A_SESSION_DROP, m.session);
                }
                self.closes.insert(m.session, CloseState {
                    afters: vec![m.after],
                    acks: 0,
                    need: nbuf + self.npes,
                    park,
                    parked_bytes: 0,
                });
                ctx.advance(MICROS);
            }
            EP_DIR_DROP_ACK => {
                let m: BufDroppedMsg = msg.take();
                self.ack_close(ctx, m.session, m.resident);
            }
            EP_DIR_DROP_ACK_MGR => {
                let sid: SessionId = msg.take();
                self.ack_close(ctx, sid, 0);
            }
            EP_DIR_IO_REQ => {
                let m: IoReqMsg = msg.take();
                let granted = self.governor.request(m.buffer, m.want, m.sess_bytes);
                if granted < m.want {
                    ctx.metrics().count(keys::GOV_THROTTLED, (m.want - granted) as u64);
                }
                if granted > 0 {
                    ctx.send(m.buffer, EP_BUF_GRANT, GrantMsg { n: granted });
                }
            }
            EP_DIR_IO_DONE => {
                let m: IoDoneMsg = msg.take();
                for (buffer, n) in self.governor.complete(m.n) {
                    ctx.send(buffer, EP_BUF_GRANT, GrantMsg { n });
                }
            }
            EP_DIR_CLOSE_FILE => {
                let m: CloseFileMsg = msg.take();
                let entry = self.files.get_mut(&m.file).expect("closing unopened file");
                entry.open_count -= 1;
                if entry.open_count > 0 {
                    // Other owners (concurrent sessions) still hold the
                    // file open: this close is complete immediately.
                    ctx.fire(m.after, Payload::empty());
                    return;
                }
                self.files.remove(&m.file);
                // Parked buffer arrays of a closed file can never be
                // rebound or peer-fetched again: release them (with
                // their claims).
                let purged = self.store.purge_file(m.file);
                self.release_evicted(ctx, purged);
                ctx.metrics().set(keys::STORE_RESIDENT, self.store.resident_bytes() as f64);
                for pe in 0..self.npes {
                    ctx.send_group(self.managers, crate::amt::topology::Pe(pe), EP_M_FILE_CLOSE, m.file);
                }
                self.file_closes.insert(m.file, CloseState {
                    afters: vec![m.after],
                    acks: 0,
                    need: self.npes,
                    park: None,
                    parked_bytes: 0,
                });
                ctx.advance(MICROS);
            }
            EP_DIR_CLOSE_ACK => {
                let file: FileId = msg.take();
                let st = self.file_closes.get_mut(&file).expect("ack for unknown close");
                st.acks += 1;
                if st.acks == st.need {
                    let st = self.file_closes.remove(&file).unwrap();
                    for after in st.afters {
                        ctx.fire(after, Payload::empty());
                    }
                }
            }
            other => panic!("Director: unknown ep {other}"),
        }
    }

    impl_chare_any!();
}
