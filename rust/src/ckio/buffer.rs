//! Buffer chares: the designated file-reading agents (paper §III-C.4).
//!
//! Each buffer chare owns a disjoint span of the session and reads it
//! *greedily* as soon as the session starts — before any client asks —
//! via split-phase reads (helper pthreads in the paper; the engine's I/O
//! backends here). Client fetches that arrive before the data is resident
//! are queued and served on I/O completion; fetches for resident data are
//! answered immediately with a zero-copy send to the requesting PE's
//! ReadAssembler.
//!
//! Splintered I/O (paper §VI.C) is supported: with
//! `SessionOptions::splinter_bytes` set, the span is read in sub-chunks and a
//! fetch is served as soon as the splinters covering it have arrived.
//!
//! Resident-data plane (PR 2, sharded in PR 3): a buffer chare is a
//! *source* as well as a reader. On `EP_BUF_INIT` the chare registers
//! its span with its file's data-plane shard
//! ([`super::shard::DataShard`], `EP_SHARD_REGISTER`); the shard
//! resolves the chare's splinter slots against existing claims and
//! answers `EP_BUF_PEERS` with the slots an earlier array (live or
//! parked, same file) already covers. Those slots are obtained with
//! `EP_BUF_PEER_FETCH` from the owning buffers and never touch the file
//! system; greedy PFS reads for the rest start only once the peer list
//! is in (so a racing resolve can never lose a dedup opportunity).
//! Symmetrically, this chare answers peer fetches for its own resident
//! slots — a fetch for a slot whose greedy read is still in flight
//! queues and is served on arrival, which is what dedups concurrent
//! same-file prefetch. A peer that was dropped meanwhile answers with a
//! *miss* and the requester falls back to its own PFS read, so
//! correctness never depends on the cache. When the service was booted
//! with `ServiceConfig::max_inflight_reads` (or `adaptive_admission`),
//! PFS reads are additionally *governed*: the chare requests tickets
//! from its shard's admission governor (`EP_SHARD_IO_REQ`), issues
//! exactly what is granted, and reports each read's observed service
//! time with the returned ticket (`EP_SHARD_IO_DONE`) — the signal the
//! adaptive cap's AIMD loop feeds on. Every ticket request carries the
//! session's [`crate::ckio::QosClass`] (PR 5), so under a saturated cap
//! the governor dequeues this chare's demand at its class's weight.
//!
//! Store-aware placement (PR 4): when the session started under
//! [`crate::ckio::ReaderPlacement::StoreAware`], this chare was *placed*
//! by a `PlacementPlan` — the director probed the shard before creating
//! the array and put the chare on the PE of its dominant peer source, so
//! the peer fetches above are same-PE copies. The plan is only a
//! snapshot: registration **confirms-or-corrects** it. The shard's
//! `EP_BUF_PEERS` reply is authoritative — if it covers fewer bytes than
//! the plan promised (a claim owner unclaimed in between), the chare
//! counts `ckio.place.degraded` and the uncovered slots are already in
//! its PFS queue; nothing asserts and no fetch is ever sent to a peer
//! the plan imagined but registration did not confirm. Each peer chunk
//! that lands is charged to `ckio.place.same_pe_fetch` or
//! `ckio.place.cross_pe_fetch` by comparing the source's PE with ours.
//!
//! Lifecycle (PR 1): a buffer chare is `Active` while its session runs.
//! Teardown *drains* — every queued fetch is answered before the director
//! is acked (resident extents with real data, the rest with modeled NACK
//! chunks), so a `closeReadSession` racing outstanding reads can never
//! strand an assembly. A fetch that arrives *after* the drop (it was in
//! flight when the drop landed) is flush-served the same way. With
//! `SessionOptions::reuse_buffers`, teardown *parks* instead: resident data is
//! kept and a later identical session rebinds the array without touching
//! the file system again.

use std::collections::{HashMap, HashSet, VecDeque};

use crate::amt::callback::Callback;
use crate::amt::chare::{Chare, ChareRef, CollectionId};
use crate::amt::engine::Ctx;
use crate::amt::msg::{Ep, Msg, Payload};
use crate::amt::protocol::{PayloadKind, ProtocolSpec};
use crate::amt::time::{Time, MICROS};
use crate::amt::topology::Pe;
use crate::impl_chare_any;
use crate::metrics::keys;
use crate::{ep_spec, send_spec};
use crate::net::Transfer;
use crate::pfs::backend::{IoResult, ReadRequest};
use crate::pfs::layout::FileId;
use crate::trace::{names as trace_names, Lane as TraceLane, TraceCategory};
use crate::util::bytes::{ceil_div, Chunk};

use super::governor::QosClass;
use super::options::RetryPolicy;
use super::session::{SessionId, Tag};
use super::shard::{
    RegisterMsg, UnclaimMsg, EP_SHARD_IO_DONE, EP_SHARD_IO_RECLAIM, EP_SHARD_IO_REQ,
    EP_SHARD_REGISTER, EP_SHARD_UNCLAIM,
};

/// Kick a freshly created buffer chare: issue its greedy reads.
pub const EP_BUF_INIT: Ep = 1;
/// Split-phase read completion (engine callback).
pub const EP_BUF_DATA: Ep = 2;
/// A ReadAssembler requests a sub-extent.
pub const EP_BUF_FETCH: Ep = 3;
/// Session teardown: drain pending fetches, release memory, ack.
pub const EP_BUF_DROP: Ep = 4;
/// Session teardown with reuse: drain, keep resident data, ack.
pub const EP_BUF_PARK: Ep = 5;
/// Revive a parked buffer under a new session (payload: [`RebindMsg`] —
/// the new session id and its QoS class).
pub const EP_BUF_REBIND: Ep = 6;
/// A peer buffer chare requests one of its slots from our resident data.
pub const EP_BUF_PEER_FETCH: Ep = 7;
/// A peer's reply: the slot's chunk, or `None` (miss — read it yourself).
pub const EP_BUF_PEER_DATA: Ep = 8;
/// Admission governor grant: issue this many PFS reads now.
pub const EP_BUF_GRANT: Ep = 9;
/// The shard's answer to `EP_SHARD_REGISTER`: which of this chare's
/// splinter slots are served by peer buffers instead of the PFS.
pub const EP_BUF_PEERS: Ep = 10;
/// Self-timer (PR 8): a governed read attempt's deadline expired. With
/// hedging enabled the attempt stays live and a duplicate races it;
/// otherwise the attempt is abandoned — its ticket returns to the
/// governor and the slot re-enters admission after a backoff.
pub const EP_BUF_TIMEOUT: Ep = 11;
/// Self-timer (PR 8): a failed/abandoned attempt's backoff expired —
/// re-queue the slot and re-enter admission.
pub const EP_BUF_RETRY: Ep = 12;

/// Fetch request from an assembler.
#[derive(Debug)]
pub struct FetchMsg {
    pub tag: Tag,
    /// File-coordinate extent (already clipped to this buffer's span).
    pub offset: u64,
    pub len: u64,
    /// PE whose assembler should receive the piece.
    pub reply_pe: Pe,
}

/// Piece sent to an assembler (zero-copy payload).
#[derive(Debug)]
pub struct PieceMsg {
    pub tag: Tag,
    pub chunk: Chunk,
    /// PE of the buffer chare that served this piece (PR 9): the
    /// assembler compares it against its own PE for the
    /// `ckio.place.piece_same_pe`/`piece_cross_pe` split and charges it
    /// to the consumer's flow account under FlowAware sessions.
    pub src_pe: u32,
}

/// Buffer → buffer: serve `[offset, offset+len)` (the requester's slot
/// `slot`) from your resident data.
#[derive(Debug)]
pub struct PeerFetchMsg {
    pub offset: u64,
    pub len: u64,
    /// The *requester's* splinter slot this extent fills.
    pub slot: u32,
    pub reply: ChareRef,
}

/// Buffer → buffer: the answer to a [`PeerFetchMsg`]. `chunk: None` is a
/// miss (the source was dropped): fall back to a PFS read.
#[derive(Debug)]
pub struct PeerDataMsg {
    pub slot: u32,
    pub len: u64,
    pub chunk: Option<Chunk>,
}

/// Buffer → shard: request PFS read tickets from the governor. The
/// ticket carries the session's QoS class (PR 5): under a saturated cap
/// the governor dequeues deferred demand by class weight.
#[derive(Debug)]
pub struct IoReqMsg {
    pub buffer: ChareRef,
    pub want: u32,
    /// Total bytes of the owning session (admission priority key).
    pub sess_bytes: u64,
    /// QoS class of the owning session.
    pub class: QosClass,
    /// PE the requesting buffer runs on (PR 9): if the governor queues
    /// this request, the shard raises the I/O-wait overlap hint on that
    /// PE so background work run there during the wait is measured.
    pub pe: u32,
}

/// Director → buffer: revive a parked chare under a new session. The
/// class travels with the rebind (PR 5): the new session may be a
/// different tenant than the one that parked the array, and later
/// tickets must be charged to the *current* session's class.
#[derive(Debug)]
pub struct RebindMsg {
    pub session: SessionId,
    pub class: QosClass,
}

/// Buffer → shard: return `n` tickets (reads completed, or a grant
/// arrived after this buffer was dropped).
#[derive(Debug)]
pub struct IoDoneMsg {
    pub n: u32,
    /// Observed issue→completion time of the read this ticket covered
    /// (0 when the ticket completed no read — a return without signal).
    /// Feeds the adaptive governor's AIMD window.
    pub service_ns: u64,
}

/// Grant from the governor (via the shard). Since PR 8 the grant is
/// *deadlined*: `deadline_ns` is how long the governor expects each of
/// these reads to take (its observed service-time window scaled by the
/// retry policy's multiplier), and the buffer arms a timeout at that
/// horizon for every read it issues on the grant. 0 = no retry policy,
/// no timer (the pre-PR 8 behavior, bit for bit).
#[derive(Debug)]
pub struct GrantMsg {
    pub n: u32,
    pub deadline_ns: u64,
}

/// Buffer → shard (PR 8): this (dropping) buffer's admission state is
/// dead — return the `held` tickets backing its still-in-flight reads
/// and purge its queued demand from the governor. Without this, a
/// buffer torn down mid-read leaks cap: the governor's inflight count
/// would wait forever for completions this chare will now ignore.
#[derive(Debug)]
pub struct ReclaimMsg {
    pub owner: ChareRef,
    pub held: u32,
}

/// Self-timer payload (PR 8): both the read deadline (`EP_BUF_TIMEOUT`)
/// and the backoff expiry (`EP_BUF_RETRY`) name the exact attempt they
/// guard, so a timer that fires after its attempt completed (or was
/// superseded) is a no-op — timers are best-effort by design.
#[derive(Debug)]
pub struct RetryTimerMsg {
    pub slot: u32,
    pub attempt: u32,
}

/// One resolved peer assignment: splinter slot `slot` of the requesting
/// buffer is served by `owner`, which runs on `owner_pe` — the PE is
/// what the locality metrics (`ckio.place.same_pe_fetch` /
/// `cross_pe_fetch`) and store-aware placement planning key on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PeerSlot {
    pub slot: u32,
    pub owner: ChareRef,
    pub owner_pe: u32,
}

/// Shard → buffer: the resolved peer list — one [`PeerSlot`] for every
/// splinter slot an existing claim fully covers.
#[derive(Debug)]
pub struct PeersMsg {
    pub peers: Vec<PeerSlot>,
}

/// Notification to the director that this buffer initiated its reads
/// (or, on rebind, that it is serving again).
#[derive(Debug)]
pub struct BufStartedMsg {
    pub session: SessionId,
}

/// Ack to the director after dropping/parking session state. Since
/// PR 8 the ack carries this chare's contribution to the session's
/// [`super::session::SessionOutcome`] — the director sums the counters
/// across the array and delivers the aggregate through the close
/// callback.
#[derive(Debug)]
pub struct BufDroppedMsg {
    pub session: SessionId,
    /// Bytes this chare keeps resident (its span length when parking,
    /// 0 when dropping) — the span store's budget accounting.
    pub resident: u64,
    /// Bytes of client fetches answered with data-bearing pieces.
    pub served_bytes: u64,
    /// Bytes of client fetches answered degraded (NACK or gave-up).
    pub degraded_bytes: u64,
    /// PFS read re-issues beyond each slot's first attempt.
    pub retries: u64,
    /// Hedged duplicate reads issued past their deadline.
    pub hedges: u64,
    /// Slots abandoned after the retry budget was exhausted.
    pub gave_up: u64,
}

/// Lifecycle state of a buffer chare.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
enum BufState {
    /// Serving a live session.
    Active,
    /// Session closed with `reuse_buffers`: data retained for rebind and
    /// peer fetches.
    Parked,
    /// Session closed: data released; late fetches are flush-served
    /// with modeled NACK chunks, late I/O completions discarded, late
    /// peer fetches answered with a miss.
    Dropped,
}

/// One buffer chare.
pub struct BufferChare {
    session: SessionId,
    file: FileId,
    /// Span owned by this chare, file coordinates.
    my_offset: u64,
    my_len: u64,
    /// Splinter size (0 = read the whole span in one request).
    splinter: u64,
    /// Max splinters in flight.
    window: u32,
    /// Per-splinter data; index = splinter slot.
    chunks: Vec<Option<Chunk>>,
    /// Slots to read from the PFS, in issue order (slots assigned to
    /// peers are absent; a peer miss re-queues its slot here).
    pfs_queue: VecDeque<u32>,
    /// Slots served by peer buffer chares.
    peer_slots: Vec<PeerSlot>,
    /// PFS reads issued and not yet completed.
    pfs_inflight: u32,
    completed: u32,
    pending: Vec<FetchMsg>,
    /// Peer fetches for slots whose data has not arrived yet.
    peer_pending: Vec<PeerFetchMsg>,
    /// Governed issuance (the service booted with admission control).
    governed: bool,
    /// Total session bytes (governor admission priority key).
    sess_bytes: u64,
    /// QoS class of the owning session: attached to every ticket
    /// request, updated on rebind (the array may serve a new tenant).
    class: QosClass,
    /// Tickets requested from the governor and not yet granted.
    asked: u32,
    /// Issue times of in-flight governed PFS reads, keyed by slot — the
    /// observed service time reported with each returned ticket.
    issued_at: HashMap<u32, Time>,
    /// Retry policy (PR 8): `Some` arms deadlines and the whole retry
    /// machine below; `None` keeps the pre-PR 8 behavior bit for bit.
    retry: Option<RetryPolicy>,
    /// In-flight read *attempts* keyed by their wire `user` id
    /// (`slot | attempt << 32`) → issue time. The ticket-accounting
    /// invariant: an attempt's completion returns its ticket iff its
    /// key is still here; a timeout-abandon removes the key and returns
    /// the ticket itself. A ticket can therefore never return twice and
    /// never leak, whatever order completions and timers land in.
    live: HashMap<u64, Time>,
    /// Highest attempt number issued per slot (1 = first read).
    attempt: HashMap<u32, u32>,
    /// Slots abandoned after the retry budget: resident as modeled
    /// chunks, and every byte served from them counts as degraded.
    degraded_slots: HashSet<u32>,
    /// Deadline from the most recent grant (0 = arm no timer).
    current_deadline: u64,
    /// Session-outcome counters (PR 8), reported on the teardown ack.
    n_served_bytes: u64,
    n_degraded_bytes: u64,
    n_retries: u64,
    n_hedges: u64,
    n_gave_up: u64,
    /// Send times of outstanding peer fetches, keyed by slot — the
    /// `ckio.latency.peer_fetch` histogram's request→data interval.
    peer_sent_at: HashMap<u32, Time>,
    /// Whether the shard has answered our registration (PFS issuance
    /// holds until then, so a racing resolve never loses a dedup).
    peers_resolved: bool,
    /// Store-aware placement plan (PR 4): the peer-covered bytes the
    /// director's `EP_SHARD_PLAN` probe promised this chare. Registration
    /// *revalidates* the plan — if the shard's actual peer list covers
    /// fewer bytes (a claim owner unclaimed between plan and register),
    /// the shortfall is counted on `ckio.place.degraded` and the
    /// uncovered slots degrade to ordinary PFS reads.
    planned_covered: Option<u64>,
    director: ChareRef,
    /// The data-plane shard owning this chare's file.
    shard: ChareRef,
    assemblers: CollectionId,
    state: BufState,
}

impl BufferChare {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        session: SessionId,
        file: FileId,
        my_offset: u64,
        my_len: u64,
        splinter: Option<u64>,
        window: u32,
        director: ChareRef,
        shard: ChareRef,
        assemblers: CollectionId,
    ) -> BufferChare {
        let splinter = splinter.unwrap_or(0).min(my_len);
        let nslots = if splinter == 0 || my_len == 0 {
            1
        } else {
            ceil_div(my_len, splinter) as usize
        };
        let pfs_queue = if my_len == 0 { VecDeque::new() } else { (0..nslots as u32).collect() };
        BufferChare {
            session,
            file,
            my_offset,
            my_len,
            splinter,
            window: window.max(1),
            chunks: vec![None; nslots],
            pfs_queue,
            peer_slots: Vec::new(),
            pfs_inflight: 0,
            completed: 0,
            pending: Vec::new(),
            peer_pending: Vec::new(),
            governed: false,
            sess_bytes: 0,
            class: QosClass::default(),
            asked: 0,
            issued_at: HashMap::new(),
            retry: None,
            live: HashMap::new(),
            attempt: HashMap::new(),
            degraded_slots: HashSet::new(),
            current_deadline: 0,
            n_served_bytes: 0,
            n_degraded_bytes: 0,
            n_retries: 0,
            n_hedges: 0,
            n_gave_up: 0,
            peer_sent_at: HashMap::new(),
            peers_resolved: false,
            planned_covered: None,
            director,
            shard,
            assemblers,
            state: BufState::Active,
        }
    }

    /// Assign slots to peer sources, as the shard's `EP_BUF_PEERS` reply
    /// does at runtime: those slots are peer-fetched instead of read
    /// from the PFS. Test-only: it bypasses shard registration entirely
    /// (no claim exists for a chare built this way), so live chares must
    /// always get their peers from the shard after registering.
    #[cfg(test)]
    fn with_peers(mut self, peers: Vec<PeerSlot>) -> BufferChare {
        self.apply_peers(&peers);
        self.peer_slots = peers;
        self.peers_resolved = true;
        self
    }

    /// Remove peer-assigned slots from the PFS queue.
    fn apply_peers(&mut self, peers: &[PeerSlot]) {
        for p in peers {
            self.pfs_queue.retain(|&s| s != p.slot);
        }
    }

    /// Route PFS reads through the shard's admission governor, as
    /// `class` (the owning session's QoS class rides every ticket).
    pub fn governed(mut self, sess_bytes: u64, class: QosClass) -> BufferChare {
        self.governed = true;
        self.sess_bytes = sess_bytes;
        self.class = class;
        self
    }

    /// Arm the retry machine (PR 8): reads issued by this chare carry
    /// deadlines, time out, back off, retry, and eventually degrade
    /// gracefully. Requires governed issuance (validated at boot).
    pub fn with_retry(mut self, policy: RetryPolicy) -> BufferChare {
        self.retry = Some(policy);
        self
    }

    /// Record the store-aware plan's expectation for this chare: the
    /// placement plan saw `covered` bytes of its span already claimed by
    /// peers. Registration confirms-or-corrects this (see
    /// [`BufferChare::planned_covered`]).
    pub fn planned(mut self, covered: u64) -> BufferChare {
        self.planned_covered = Some(covered);
        self
    }

    /// The file-coordinate extent of splinter slot `i`.
    fn slot_extent(&self, i: u32) -> (u64, u64) {
        if self.splinter == 0 {
            return (self.my_offset, self.my_len);
        }
        let lo = self.my_offset + i as u64 * self.splinter;
        let hi = (lo + self.splinter).min(self.my_offset + self.my_len);
        (lo, hi - lo)
    }

    /// Slots overlapping `[offset, offset+len)`.
    fn slots_for(&self, offset: u64, len: u64) -> std::ops::RangeInclusive<u32> {
        debug_assert!(offset >= self.my_offset && offset + len <= self.my_offset + self.my_len);
        if self.splinter == 0 {
            return 0..=0;
        }
        let lo = ((offset - self.my_offset) / self.splinter) as u32;
        let hi = ((offset + len - 1 - self.my_offset) / self.splinter) as u32;
        lo..=hi
    }

    fn have(&self, offset: u64, len: u64) -> bool {
        self.slots_for(offset, len).all(|s| self.chunks[s as usize].is_some())
    }

    /// The in-flight target: splinterless spans are one read.
    fn window_cap(&self) -> u32 {
        if self.splinter == 0 {
            1
        } else {
            self.window
        }
    }

    /// The wire `user` id of one read attempt: slot in the low half,
    /// attempt number in the high half. Attempt 0 is the retry-less
    /// encoding (`user == slot`), kept so runs without a policy stay
    /// bit-for-bit identical to PR 7.
    fn attempt_key(slot: u32, attempt: u32) -> u64 {
        u64::from(slot) | (u64::from(attempt) << 32)
    }

    /// Exponential backoff before re-entering admission: doubling from
    /// the policy base, capped, plus a deterministic per-slot jitter so
    /// a burst of same-deadline failures does not re-converge into a
    /// synchronized retry storm. No RNG: replays stay exact.
    fn backoff_ns(&self, slot: u32, attempt: u32) -> u64 {
        let r = self.retry.as_ref().expect("backoff without a retry policy");
        let exp = r.base_backoff_ns.checked_shl(attempt.saturating_sub(1)).unwrap_or(u64::MAX);
        let spread = (r.base_backoff_ns / 2).max(1);
        let jitter = (u64::from(slot).wrapping_mul(2_654_435_761) + u64::from(attempt)) % spread;
        exp.min(r.max_backoff_ns) + jitter
    }

    /// Byte overlap of `[offset, offset+len)` with gave-up slots — the
    /// degraded share of a served fetch.
    fn degraded_overlap(&self, offset: u64, len: u64) -> u64 {
        if self.degraded_slots.is_empty() {
            return 0;
        }
        let mut d = 0;
        for s in self.slots_for(offset, len) {
            if self.degraded_slots.contains(&s) {
                let (slo, slen) = self.slot_extent(s);
                d += (offset + len).min(slo + slen) - offset.max(slo);
            }
        }
        d
    }

    /// Retry budget exhausted: degrade the slot gracefully. A modeled
    /// chunk takes the data's place so every queued and future fetch
    /// still completes exactly once — just without verified bytes.
    fn give_up(&mut self, ctx: &mut Ctx<'_>, slot: u32) {
        if self.chunks[slot as usize].is_some() {
            return; // a racing attempt delivered after all
        }
        let (offset, len) = self.slot_extent(slot);
        self.degraded_slots.insert(slot);
        self.n_gave_up += 1;
        ctx.metrics().count(keys::RETRY_GAVE_UP, 1);
        if ctx.trace().on(TraceCategory::Pfs) {
            let now = ctx.now();
            ctx.trace().instant(
                now,
                TraceCategory::Pfs,
                trace_names::PFS_RETRY,
                TraceLane::Pe(ctx.pe().0),
                u64::from(slot),
                len,
                "gave_up",
            );
        }
        self.slot_arrived(ctx, slot as usize, Chunk::modeled(offset, len));
    }

    /// Completion handling when a retry policy is armed (PR 8): decode
    /// the attempt, settle its ticket exactly once, then route the
    /// outcome — data lands, failures back off and re-enter admission,
    /// exhausted budgets degrade gracefully.
    fn read_done_with_retry(&mut self, ctx: &mut Ctx<'_>, r: IoResult) {
        let slot = r.user as u32;
        let Some(issued) = self.live.remove(&r.user) else {
            // The attempt was abandoned (timeout) or bulk-reclaimed
            // (teardown): its ticket already went back. Drop the data —
            // a replacement attempt owns the slot now.
            ctx.metrics().count(keys::RETRY_LATE, 1);
            return;
        };
        self.pfs_inflight = self.pfs_inflight.saturating_sub(1);
        let service_ns = ctx.now().saturating_sub(issued);
        ctx.send(self.shard, EP_SHARD_IO_DONE, IoDoneMsg { n: 1, service_ns });
        if self.state == BufState::Dropped {
            return; // unreachable once teardown clears `live`; belt and braces
        }
        if r.outcome.is_ok() {
            if self.chunks[slot as usize].is_none() {
                self.slot_arrived(ctx, slot as usize, r.chunk);
            }
            // else: hedge loser — the winner already filled the slot.
            self.pump(ctx);
            return;
        }
        // Failed read (transient, persistent, or short): the modeled
        // service time was still paid — an error is only discovered at
        // completion, as on a real client. Decide whether to retry.
        if self.chunks[slot as usize].is_some() || self.pfs_queue.contains(&slot) {
            self.pump(ctx);
            return; // a hedge won, or a re-issue is already queued
        }
        let attempt = (r.user >> 32) as u32;
        let newest = self.attempt.get(&slot).copied().unwrap_or(attempt);
        if attempt < newest {
            self.pump(ctx);
            return; // a newer attempt is in flight: it decides
        }
        let policy = self.retry.expect("retry completion without a policy");
        if attempt >= policy.max_attempts {
            self.give_up(ctx, slot);
        } else {
            let me = ctx.me();
            ctx.send_after(
                self.backoff_ns(slot, attempt),
                me,
                EP_BUF_RETRY,
                RetryTimerMsg { slot, attempt },
            );
        }
        self.pump(ctx);
    }

    /// Hand this session's outcome counters to a teardown ack (and zero
    /// them: a parked chare's next session starts a fresh report).
    fn take_outcome(&mut self) -> (u64, u64, u64, u64, u64) {
        let out = (
            self.n_served_bytes,
            self.n_degraded_bytes,
            self.n_retries,
            self.n_hedges,
            self.n_gave_up,
        );
        self.n_served_bytes = 0;
        self.n_degraded_bytes = 0;
        self.n_retries = 0;
        self.n_hedges = 0;
        self.n_gave_up = 0;
        out
    }

    /// Issue the next queued PFS slot read, if any.
    fn issue_next(&mut self, ctx: &mut Ctx<'_>) {
        let Some(slot) = self.pfs_queue.pop_front() else { return };
        let (offset, len) = self.slot_extent(slot);
        self.pfs_inflight += 1;
        let user = if self.retry.is_some() {
            // A sibling attempt still live for this slot means this
            // issue is the hedge; otherwise attempts beyond the first
            // are retries. (Hedges were counted when enqueued.)
            let is_hedge = self.live.keys().any(|&u| u as u32 == slot);
            let attempt = self.attempt.entry(slot).and_modify(|a| *a += 1).or_insert(1);
            let attempt = *attempt;
            let user = Self::attempt_key(slot, attempt);
            self.live.insert(user, ctx.now());
            if attempt > 1 && !is_hedge {
                self.n_retries += 1;
                ctx.metrics().count(keys::RETRY_ATTEMPTS, 1);
                if ctx.trace().on(TraceCategory::Pfs) {
                    let now = ctx.now();
                    ctx.trace().instant(
                        now,
                        TraceCategory::Pfs,
                        trace_names::PFS_RETRY,
                        TraceLane::Pe(ctx.pe().0),
                        u64::from(slot),
                        u64::from(attempt),
                        "reissue",
                    );
                }
            }
            // Arm the deadline the grant promised for this read.
            if self.current_deadline > 0 {
                let me = ctx.me();
                ctx.send_after(
                    self.current_deadline,
                    me,
                    EP_BUF_TIMEOUT,
                    RetryTimerMsg { slot, attempt },
                );
            }
            user
        } else {
            u64::from(slot)
        };
        if self.governed && self.retry.is_none() {
            // Remember the issue time: the ticket return reports the
            // observed service time to the adaptive governor. (With a
            // retry policy the `live` map plays this role per attempt.)
            self.issued_at.insert(slot, ctx.now());
        }
        ctx.metrics().count(keys::STORE_MISS, len);
        let me = ctx.me();
        ctx.submit_read(
            ReadRequest { file: self.file, offset, len, user },
            Callback::to_chare(me, EP_BUF_DATA),
        );
    }

    /// Governed issuance: ask the shard's governor for tickets covering
    /// the queued slots, up to the window.
    fn maybe_request(&mut self, ctx: &mut Ctx<'_>) {
        if !self.governed {
            return;
        }
        let queued = self.pfs_queue.len() as u32;
        let room = self.window_cap().saturating_sub(self.pfs_inflight + self.asked);
        let want = queued.saturating_sub(self.asked).min(room);
        if want > 0 {
            self.asked += want;
            let me = ctx.me();
            ctx.send(
                self.shard,
                EP_SHARD_IO_REQ,
                IoReqMsg {
                    buffer: me,
                    want,
                    sess_bytes: self.sess_bytes,
                    class: self.class,
                    pe: ctx.pe().0,
                },
            );
        }
    }

    /// Kick issuance: governed chares ask the governor, ungoverned ones
    /// read directly. Holds entirely until the shard has resolved our
    /// peer list — issuing earlier could duplicate a read a peer already
    /// has in flight.
    fn pump(&mut self, ctx: &mut Ctx<'_>) {
        if !self.peers_resolved {
            return;
        }
        if self.governed {
            self.maybe_request(ctx);
        } else {
            while self.pfs_inflight < self.window_cap() && !self.pfs_queue.is_empty() {
                self.issue_next(ctx);
            }
        }
    }

    /// Answer a fetch from resident data: zero-copy send to the
    /// requesting PE's assembler.
    fn serve(&mut self, ctx: &mut Ctx<'_>, f: &FetchMsg) {
        let chunk = self.extract(f.offset, f.len);
        let to = ChareRef::new(self.assemblers, f.reply_pe.0);
        let wire = chunk.len;
        ctx.metrics().count(keys::PIECES_SERVED, 1);
        // Outcome accounting (PR 8): bytes overlapping gave-up slots
        // ride a modeled chunk — degraded service, not a clean serve.
        let degraded = self.degraded_overlap(f.offset, f.len);
        self.n_served_bytes += f.len - degraded;
        if degraded > 0 {
            self.n_degraded_bytes += degraded;
            ctx.metrics().count(keys::SESSION_DEGRADED, degraded);
        }
        // Zero-copy: the runtime RDMA-gets the resident buffer; the chare
        // itself only touches descriptors.
        ctx.advance(MICROS / 2);
        ctx.send_sized(
            to,
            super::assembler::EP_A_PIECE,
            Payload::new(PieceMsg { tag: f.tag, chunk, src_pe: ctx.pe().0 }),
            wire,
            Transfer::ZeroCopy,
        );
    }

    /// Answer a fetch that can no longer be served with data (teardown):
    /// a modeled NACK chunk so the assembly still completes exactly once.
    fn serve_nack(&mut self, ctx: &mut Ctx<'_>, f: &FetchMsg) {
        ctx.metrics().count(keys::PIECES_NACKED, 1);
        // NACKed bytes are degraded service (PR 8): the assembly
        // completes, but without verified data.
        self.n_degraded_bytes += f.len;
        ctx.metrics().count(keys::SESSION_DEGRADED, f.len);
        let to = ChareRef::new(self.assemblers, f.reply_pe.0);
        ctx.send(
            to,
            super::assembler::EP_A_PIECE,
            PieceMsg { tag: f.tag, chunk: Chunk::modeled(f.offset, f.len), src_pe: ctx.pe().0 },
        );
    }

    /// Answer a peer fetch from resident data (zero-copy, like a piece).
    fn serve_peer(&self, ctx: &mut Ctx<'_>, f: &PeerFetchMsg) {
        let chunk = self.extract(f.offset, f.len);
        let wire = chunk.len;
        ctx.metrics().count(keys::STORE_PEER_SERVED, 1);
        ctx.advance(MICROS / 2);
        ctx.send_sized(
            f.reply,
            EP_BUF_PEER_DATA,
            Payload::new(PeerDataMsg { slot: f.slot, len: f.len, chunk: Some(chunk) }),
            wire,
            Transfer::ZeroCopy,
        );
    }

    /// Answer a peer fetch this chare can never serve (dropped / out of
    /// span): the requester falls back to its own PFS read.
    fn peer_miss(&self, ctx: &mut Ctx<'_>, f: &PeerFetchMsg) {
        ctx.metrics().count(keys::STORE_PEER_MISS, 1);
        ctx.send(f.reply, EP_BUF_PEER_DATA, PeerDataMsg { slot: f.slot, len: f.len, chunk: None });
    }

    /// Serve every queued assembler/peer fetch that became satisfiable.
    fn serve_ready(&mut self, ctx: &mut Ctx<'_>) {
        let mut still = Vec::new();
        for f in std::mem::take(&mut self.pending) {
            if self.have(f.offset, f.len) {
                self.serve(ctx, &f);
            } else {
                still.push(f);
            }
        }
        self.pending = still;
        let mut still = Vec::new();
        for f in std::mem::take(&mut self.peer_pending) {
            if self.have(f.offset, f.len) {
                self.serve_peer(ctx, &f);
            } else {
                still.push(f);
            }
        }
        self.peer_pending = still;
    }

    /// A slot's data arrived (PFS completion or peer chunk): store it and
    /// serve whatever became satisfiable.
    fn slot_arrived(&mut self, ctx: &mut Ctx<'_>, slot: usize, chunk: Chunk) {
        debug_assert!(self.chunks[slot].is_none(), "duplicate splinter completion");
        self.chunks[slot] = Some(chunk);
        self.completed += 1;
        if self.completed as usize == self.chunks.len() {
            let t = ctx.now() as f64;
            ctx.metrics().set_max(keys::LAST_IO_NS, t);
        }
        self.serve_ready(ctx);
    }

    /// Teardown drain of *client* fetches: answer every queued assembler
    /// fetch exactly once — resident extents with data, the rest as
    /// NACKs. Shared by both teardown flavors (drop and park).
    fn drain_client_fetches(&mut self, ctx: &mut Ctx<'_>) {
        for f in std::mem::take(&mut self.pending) {
            if self.have(f.offset, f.len) {
                self.serve(ctx, &f);
            } else {
                self.serve_nack(ctx, &f);
            }
        }
    }

    /// Full teardown drain (drop only): client fetches as above, and
    /// queued peer fetches get data or a miss (their owner re-reads from
    /// the PFS). Parking skips the peer half — a parked chare keeps its
    /// data and serves peers on arrival.
    fn drain_pending(&mut self, ctx: &mut Ctx<'_>) {
        self.drain_client_fetches(ctx);
        for f in std::mem::take(&mut self.peer_pending) {
            if self.have(f.offset, f.len) {
                self.serve_peer(ctx, &f);
            } else {
                self.peer_miss(ctx, &f);
            }
        }
    }

    /// Build the chunk for `[offset, offset+len)` from resident splinters.
    fn extract(&self, offset: u64, len: u64) -> Chunk {
        let slots = self.slots_for(offset, len);
        let (lo, hi) = (*slots.start(), *slots.end());
        if lo == hi {
            return self.chunks[lo as usize].as_ref().unwrap().slice(offset, len);
        }
        // Multi-splinter extract: concatenate the relevant pieces.
        let mut bytes: Option<Vec<u8>> = None;
        let mut modeled_only = false;
        for s in slots {
            let c = self.chunks[s as usize].as_ref().unwrap();
            let (slo, slen) = self.slot_extent(s);
            let take_lo = offset.max(slo);
            let take_hi = (offset + len).min(slo + slen);
            let piece = c.slice(take_lo, take_hi - take_lo);
            match piece.bytes {
                Some(b) => bytes.get_or_insert_with(Vec::new).extend_from_slice(&b),
                None => modeled_only = true,
            }
        }
        if modeled_only || bytes.is_none() {
            Chunk::modeled(offset, len)
        } else {
            Chunk::materialized(offset, bytes.unwrap().into())
        }
    }

    /// Queued fetch count (leak checks in tests).
    pub fn pending_len(&self) -> usize {
        self.pending.len() + self.peer_pending.len()
    }

    /// Whether teardown released this chare's data.
    pub fn is_dropped(&self) -> bool {
        self.state == BufState::Dropped
    }

    /// Bytes currently resident (parked-cache inspection).
    pub fn resident_bytes(&self) -> u64 {
        self.chunks.iter().flatten().map(|c| c.len).sum()
    }

    /// Slots assigned to peer sources (tests).
    pub fn peer_slot_count(&self) -> usize {
        self.peer_slots.len()
    }
}

/// The buffer chare's declared message protocol (see
/// [`crate::amt::protocol`]). Any change to its EPs, payload types, or
/// send sites must update this spec in the same commit.
pub fn protocol_spec() -> ProtocolSpec {
    use super::assembler::EP_A_PIECE;
    use super::director::{EP_DIR_BUF_STARTED, EP_DIR_DROP_ACK};
    ProtocolSpec {
        chare: "BufferChare",
        module: "ckio/buffer.rs",
        handles: vec![
            ep_spec!(EP_BUF_INIT, PayloadKind::Signal),
            ep_spec!(EP_BUF_DATA, PayloadKind::of::<IoResult>()),
            ep_spec!(EP_BUF_FETCH, PayloadKind::of::<FetchMsg>()),
            ep_spec!(EP_BUF_DROP, PayloadKind::Signal),
            ep_spec!(EP_BUF_PARK, PayloadKind::Signal),
            ep_spec!(EP_BUF_REBIND, PayloadKind::of::<RebindMsg>()),
            ep_spec!(EP_BUF_PEER_FETCH, PayloadKind::of::<PeerFetchMsg>()),
            ep_spec!(EP_BUF_PEER_DATA, PayloadKind::of::<PeerDataMsg>()),
            ep_spec!(EP_BUF_GRANT, PayloadKind::of::<GrantMsg>()),
            ep_spec!(EP_BUF_PEERS, PayloadKind::of::<PeersMsg>()),
            ep_spec!(EP_BUF_TIMEOUT, PayloadKind::of::<RetryTimerMsg>()),
            ep_spec!(EP_BUF_RETRY, PayloadKind::of::<RetryTimerMsg>()),
        ],
        sends: vec![
            send_spec!("DataShard", EP_SHARD_REGISTER, PayloadKind::of::<RegisterMsg>()),
            send_spec!("DataShard", EP_SHARD_UNCLAIM, PayloadKind::of::<UnclaimMsg>()),
            send_spec!("DataShard", EP_SHARD_IO_REQ, PayloadKind::of::<IoReqMsg>()),
            send_spec!("DataShard", EP_SHARD_IO_DONE, PayloadKind::of::<IoDoneMsg>()),
            send_spec!("DataShard", EP_SHARD_IO_RECLAIM, PayloadKind::of::<ReclaimMsg>()),
            send_spec!("BufferChare", EP_BUF_TIMEOUT, PayloadKind::of::<RetryTimerMsg>()),
            send_spec!("BufferChare", EP_BUF_RETRY, PayloadKind::of::<RetryTimerMsg>()),
            send_spec!("ReadAssembler", EP_A_PIECE, PayloadKind::of::<PieceMsg>()),
            send_spec!("BufferChare", EP_BUF_PEER_FETCH, PayloadKind::of::<PeerFetchMsg>()),
            send_spec!("BufferChare", EP_BUF_PEER_DATA, PayloadKind::of::<PeerDataMsg>()),
            send_spec!("Director", EP_DIR_BUF_STARTED, PayloadKind::of::<BufStartedMsg>()),
            send_spec!("Director", EP_DIR_DROP_ACK, PayloadKind::of::<BufDroppedMsg>()),
        ],
    }
}

impl Chare for BufferChare {
    fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
        match msg.ep {
            EP_BUF_INIT => {
                // Register this chare's span with its file's data-plane
                // shard: the shard resolves which splinter slots an
                // existing array already covers (same-file prefetch
                // dedup, partial-overlap serving) and claims the span
                // for later sessions. PFS issuance waits for the
                // EP_BUF_PEERS reply so a racing resolve never loses a
                // dedup opportunity.
                let me = ctx.me();
                if self.my_len == 0 {
                    // Nothing to read or claim.
                    self.peers_resolved = true;
                } else {
                    ctx.send(self.shard, EP_SHARD_REGISTER, RegisterMsg {
                        file: self.file,
                        offset: self.my_offset,
                        len: self.my_len,
                        splinter: self.splinter,
                        buffer: me,
                        pe: ctx.pe().0,
                        dirty: false,
                    });
                }
                ctx.advance(MICROS);
                ctx.send(self.director, super::director::EP_DIR_BUF_STARTED, BufStartedMsg {
                    session: self.session,
                });
            }
            EP_BUF_PEERS => {
                let m: PeersMsg = msg.take();
                if self.state == BufState::Dropped {
                    return; // resolved after teardown: nothing to start
                }
                // Peer-assigned slots: fetch from the owning buffer chare
                // (its greedy read is resident or in flight) — these
                // bytes never touch the PFS again.
                self.peers_resolved = true;
                self.apply_peers(&m.peers);
                // Revalidate the store-aware plan (PR 4): the plan was a
                // snapshot, and a claim owner may have unclaimed between
                // EP_SHARD_PLAN and this registration. The uncovered
                // slots are already back in the PFS queue — the
                // degradation is graceful by construction — but the
                // shortfall is worth a counter: it measures how often
                // planned locality evaporated under churn.
                if let Some(expected) = self.planned_covered {
                    let actual: u64 =
                        m.peers.iter().map(|p| self.slot_extent(p.slot).1).sum();
                    if actual < expected {
                        ctx.metrics().count(keys::PLACE_DEGRADED, 1);
                    }
                }
                let me = ctx.me();
                for p in &m.peers {
                    let (offset, len) = self.slot_extent(p.slot);
                    self.peer_sent_at.insert(p.slot, ctx.now());
                    ctx.send(
                        p.owner,
                        EP_BUF_PEER_FETCH,
                        PeerFetchMsg { offset, len, slot: p.slot, reply: me },
                    );
                }
                self.peer_slots = m.peers;
                // Greedy PFS reads for the unclaimed slots: start now,
                // before any client asks (through the governor when
                // admission-controlled).
                self.pump(ctx);
            }
            EP_BUF_DATA => {
                let r: IoResult = msg.take();
                if self.retry.is_some() {
                    self.read_done_with_retry(ctx, r);
                    return;
                }
                // Governor bookkeeping happens even for late completions
                // of dropped chares — tickets must return (with the
                // observed service time: the AIMD signal). A *dropped*
                // chare's in-flight tickets were already bulk-reclaimed
                // at teardown (EP_SHARD_IO_RECLAIM), so only completions
                // still tracked in `issued_at` return one here.
                self.pfs_inflight = self.pfs_inflight.saturating_sub(1);
                if self.governed {
                    match self.issued_at.remove(&(r.user as u32)) {
                        Some(t) => {
                            let service_ns = ctx.now().saturating_sub(t);
                            ctx.send(self.shard, EP_SHARD_IO_DONE, IoDoneMsg {
                                n: 1,
                                service_ns,
                            });
                        }
                        None if self.state == BufState::Dropped => {} // reclaimed at drop
                        None => {
                            ctx.send(self.shard, EP_SHARD_IO_DONE, IoDoneMsg {
                                n: 1,
                                service_ns: 0,
                            });
                        }
                    }
                }
                if self.state == BufState::Dropped {
                    return; // late completion after teardown
                }
                let slot = r.user as u32;
                let chunk = if r.outcome.is_ok() {
                    r.chunk
                } else {
                    // A fault with no retry policy degrades immediately:
                    // a modeled chunk takes the extent's place so every
                    // fetch still completes exactly once.
                    let (o, l) = self.slot_extent(slot);
                    self.degraded_slots.insert(slot);
                    self.n_gave_up += 1;
                    ctx.metrics().count(keys::RETRY_GAVE_UP, 1);
                    Chunk::modeled(o, l)
                };
                // Active or Parked: keep filling (a parked buffer keeps
                // warming its cache for the next rebind or peer fetch).
                self.slot_arrived(ctx, slot as usize, chunk);
                self.pump(ctx);
            }
            EP_BUF_PEER_DATA => {
                let m: PeerDataMsg = msg.take();
                let sent = self.peer_sent_at.remove(&m.slot);
                match m.chunk {
                    Some(chunk) => {
                        if self.state == BufState::Dropped {
                            return; // late peer data after teardown
                        }
                        ctx.metrics().count(keys::STORE_HIT, m.len);
                        // Locality accounting (PR 4): did these bytes
                        // cross a PE boundary? Store-aware placement
                        // exists to drive the cross-PE share toward zero.
                        let my_pe = ctx.pe().0;
                        let same = self
                            .peer_slots
                            .iter()
                            .find(|p| p.slot == m.slot)
                            .is_some_and(|p| p.owner_pe == my_pe);
                        let key =
                            if same { keys::PLACE_SAME_PE } else { keys::PLACE_CROSS_PE };
                        ctx.metrics().count(key, m.len);
                        if let Some(t) = sent {
                            let waited = ctx.now().saturating_sub(t);
                            ctx.metrics().record(keys::LATENCY_PEER_FETCH, waited);
                            if ctx.trace().on(TraceCategory::Store) {
                                ctx.trace().complete(
                                    t,
                                    waited,
                                    TraceCategory::Store,
                                    trace_names::STORE_PEER_FETCH,
                                    TraceLane::Pe(my_pe),
                                    0,
                                    u64::from(m.slot),
                                    m.len,
                                    if same { "same_pe" } else { "cross_pe" },
                                );
                            }
                        }
                        self.slot_arrived(ctx, m.slot as usize, chunk);
                    }
                    None => {
                        // Peer dropped before serving: this slot is ours
                        // to read after all.
                        if self.state == BufState::Dropped {
                            return;
                        }
                        self.pfs_queue.push_back(m.slot);
                        self.pump(ctx);
                    }
                }
            }
            EP_BUF_GRANT => {
                let g: GrantMsg = msg.take();
                self.asked = self.asked.saturating_sub(g.n);
                // The grant's deadline governs the reads it admits (and
                // stays current for any direct re-issues): the governor's
                // live view of how long a healthy read should take.
                self.current_deadline = g.deadline_ns;
                if self.state == BufState::Dropped {
                    // Too late to read: return the tickets untouched.
                    ctx.send(self.shard, EP_SHARD_IO_DONE, IoDoneMsg { n: g.n, service_ns: 0 });
                    return;
                }
                let mut issued = 0;
                for _ in 0..g.n {
                    if self.pfs_queue.is_empty() {
                        break;
                    }
                    self.issue_next(ctx);
                    issued += 1;
                }
                if issued < g.n {
                    // Excess tickets (peer data landed meanwhile): return.
                    ctx.send(self.shard, EP_SHARD_IO_DONE, IoDoneMsg {
                        n: g.n - issued,
                        service_ns: 0,
                    });
                }
            }
            EP_BUF_FETCH => {
                let f: FetchMsg = msg.take();
                debug_assert!(
                    f.offset >= self.my_offset && f.offset + f.len <= self.my_offset + self.my_len,
                    "fetch [{}, {}) outside buffer span [{}, {})",
                    f.offset,
                    f.offset + f.len,
                    self.my_offset,
                    self.my_offset + self.my_len
                );
                ctx.metrics().count(keys::FETCHES, 1);
                if self.state == BufState::Dropped {
                    // The fetch was in flight when the drop landed:
                    // flush-serve so its assembly still completes.
                    ctx.metrics().count(keys::FETCH_AFTER_DROP, 1);
                    if self.have(f.offset, f.len) {
                        self.serve(ctx, &f);
                    } else {
                        self.serve_nack(ctx, &f);
                    }
                } else if self.have(f.offset, f.len) {
                    self.serve(ctx, &f);
                } else {
                    self.pending.push(f);
                }
            }
            EP_BUF_PEER_FETCH => {
                let f: PeerFetchMsg = msg.take();
                let in_span =
                    f.offset >= self.my_offset && f.offset + f.len <= self.my_offset + self.my_len;
                if self.state == BufState::Dropped || !in_span || f.len == 0 {
                    // Dropped (or a stale claim): the requester falls
                    // back to its own PFS read.
                    self.peer_miss(ctx, &f);
                } else if self.have(f.offset, f.len) {
                    self.serve_peer(ctx, &f);
                } else {
                    // The covering greedy read is queued or in flight:
                    // serve on arrival — this wait *is* the dedup.
                    self.peer_pending.push(f);
                }
            }
            EP_BUF_TIMEOUT => {
                let m: RetryTimerMsg = msg.take();
                if self.state == BufState::Dropped {
                    return;
                }
                let Some(policy) = self.retry else { return };
                let user = Self::attempt_key(m.slot, m.attempt);
                if !self.live.contains_key(&user) {
                    return; // the attempt completed or was abandoned already
                }
                ctx.metrics().count(keys::RETRY_TIMEOUTS, 1);
                if policy.hedge {
                    // Hedged read: keep the overdue attempt live (its
                    // data may still win) and race a duplicate against
                    // it, charged against the same admission cap.
                    let newest = self.attempt.get(&m.slot).copied().unwrap_or(1);
                    if newest >= policy.max_attempts
                        || self.chunks[m.slot as usize].is_some()
                        || self.pfs_queue.contains(&m.slot)
                    {
                        return;
                    }
                    self.n_hedges += 1;
                    ctx.metrics().count(keys::RETRY_HEDGES, 1);
                    if ctx.trace().on(TraceCategory::Pfs) {
                        let now = ctx.now();
                        ctx.trace().instant(
                            now,
                            TraceCategory::Pfs,
                            trace_names::PFS_HEDGE,
                            TraceLane::Pe(ctx.pe().0),
                            u64::from(m.slot),
                            u64::from(m.attempt),
                            "hedge",
                        );
                    }
                    self.pfs_queue.push_back(m.slot);
                    self.pump(ctx);
                } else {
                    // Abandon: the ticket returns *now* (service 0 — an
                    // abandoned read must not feed the AIMD window), the
                    // slot re-enters admission after a backoff, and the
                    // eventual late completion finds its key gone.
                    self.live.remove(&user);
                    self.pfs_inflight = self.pfs_inflight.saturating_sub(1);
                    ctx.send(self.shard, EP_SHARD_IO_DONE, IoDoneMsg { n: 1, service_ns: 0 });
                    if m.attempt >= policy.max_attempts {
                        self.give_up(ctx, m.slot);
                    } else {
                        let me = ctx.me();
                        ctx.send_after(
                            self.backoff_ns(m.slot, m.attempt),
                            me,
                            EP_BUF_RETRY,
                            RetryTimerMsg { slot: m.slot, attempt: m.attempt },
                        );
                    }
                    self.pump(ctx);
                }
            }
            EP_BUF_RETRY => {
                let m: RetryTimerMsg = msg.take();
                if self.state == BufState::Dropped {
                    return;
                }
                if self.chunks[m.slot as usize].is_some() || self.pfs_queue.contains(&m.slot) {
                    return; // data landed (or a re-issue queued) meanwhile
                }
                self.pfs_queue.push_back(m.slot);
                self.pump(ctx);
            }
            EP_BUF_DROP => {
                self.drain_pending(ctx);
                self.chunks.iter_mut().for_each(|c| *c = None);
                self.peer_sent_at.clear();
                self.degraded_slots.clear();
                self.attempt.clear();
                let was_active = self.state != BufState::Dropped;
                self.state = BufState::Dropped;
                ctx.advance(MICROS / 2);
                // Retract our span claim at the shard. Sent by *this*
                // chare so it is FIFO-ordered after our own registration
                // (same source → same destination); idempotent on the
                // store side, and redundant after a shard-driven
                // eviction/purge (which already dropped the claims).
                if was_active && self.my_len > 0 {
                    let me = ctx.me();
                    ctx.send(self.shard, EP_SHARD_UNCLAIM, UnclaimMsg {
                        file: self.file,
                        owner: me,
                    });
                }
                // Owner-death reclaim (PR 8): tickets backing reads this
                // chare will now ignore, plus any demand still queued in
                // the governor, go back in one message — the AIMD cap
                // can never leak to a torn-down owner. Late completions
                // find their keys cleared and return nothing.
                if was_active && self.governed {
                    let held = if self.retry.is_some() {
                        self.live.len()
                    } else {
                        self.issued_at.len()
                    } as u32;
                    let me = ctx.me();
                    ctx.send(self.shard, EP_SHARD_IO_RECLAIM, ReclaimMsg { owner: me, held });
                    self.live.clear();
                    self.issued_at.clear();
                    self.asked = 0;
                }
                let (served_bytes, degraded_bytes, retries, hedges, gave_up) =
                    self.take_outcome();
                ctx.send(self.director, super::director::EP_DIR_DROP_ACK, BufDroppedMsg {
                    session: self.session,
                    resident: 0,
                    served_bytes,
                    degraded_bytes,
                    retries,
                    hedges,
                    gave_up,
                });
            }
            EP_BUF_PARK => {
                // Assembler fetches are drained; peer fetches stay — the
                // parked chare keeps warming and serves them on arrival.
                self.drain_client_fetches(ctx);
                self.state = BufState::Parked;
                ctx.advance(MICROS / 2);
                let (served_bytes, degraded_bytes, retries, hedges, gave_up) =
                    self.take_outcome();
                ctx.send(self.director, super::director::EP_DIR_DROP_ACK, BufDroppedMsg {
                    session: self.session,
                    // The span store accounts the *eventual* residency:
                    // in-flight greedy reads keep landing while parked.
                    resident: self.my_len,
                    served_bytes,
                    degraded_bytes,
                    retries,
                    hedges,
                    gave_up,
                });
            }
            EP_BUF_REBIND => {
                let m: RebindMsg = msg.take();
                debug_assert!(
                    self.state == BufState::Parked,
                    "rebind of a non-parked buffer ({:?})",
                    self.state
                );
                self.session = m.session;
                // The rebinding session may be a different tenant: its
                // class charges any tickets this chare still requests.
                self.class = m.class;
                self.state = BufState::Active;
                ctx.metrics().count(keys::BUFFERS_REBOUND, 1);
                ctx.advance(MICROS / 2);
                // Resident data makes this chare immediately serviceable;
                // any still-outstanding prefetch completions keep landing.
                ctx.send(self.director, super::director::EP_DIR_BUF_STARTED, BufStartedMsg {
                    session: m.session,
                });
            }
            other => panic!("BufferChare: unknown ep {other}"),
        }
        let _ = keys::CKIO_BYTES; // (metrics charged by the assembler side)
    }

    fn pack_size(&self) -> u64 {
        // Buffer chares are not migrated while holding data in this
        // implementation; descriptor-only size.
        256
    }

    impl_chare_any!();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(splinter: Option<u64>) -> BufferChare {
        BufferChare::new(
            SessionId(0),
            FileId(0),
            1000,
            100,
            splinter,
            2,
            ChareRef::new(CollectionId(0), 0),
            ChareRef::new(CollectionId(2), 0),
            CollectionId(1),
        )
    }

    #[test]
    fn slot_extents_whole_span() {
        let b = mk(None);
        assert_eq!(b.chunks.len(), 1);
        assert_eq!(b.slot_extent(0), (1000, 100));
        assert_eq!(b.slots_for(1000, 100), 0..=0);
    }

    #[test]
    fn slot_extents_splintered() {
        let b = mk(Some(30));
        assert_eq!(b.chunks.len(), 4); // 30+30+30+10
        assert_eq!(b.slot_extent(0), (1000, 30));
        assert_eq!(b.slot_extent(3), (1090, 10));
        assert_eq!(b.slots_for(1000, 30), 0..=0);
        assert_eq!(b.slots_for(1029, 2), 0..=1);
        assert_eq!(b.slots_for(1000, 100), 0..=3);
    }

    #[test]
    fn slot_extents_agree_with_store_helper() {
        let b = mk(Some(30));
        let from_store = super::super::store::slot_extents(1000, 100, 30);
        for (i, &(o, l)) in from_store.iter().enumerate() {
            assert_eq!(b.slot_extent(i as u32), (o, l));
        }
    }

    #[test]
    fn have_tracks_partial_arrival() {
        let mut b = mk(Some(30));
        assert!(!b.have(1000, 10));
        b.chunks[0] = Some(Chunk::modeled(1000, 30));
        assert!(b.have(1000, 30));
        assert!(!b.have(1020, 20)); // needs slot 1
        b.chunks[1] = Some(Chunk::modeled(1030, 30));
        assert!(b.have(1020, 20));
    }

    #[test]
    fn extract_concatenates_materialized_splinters() {
        use crate::pfs::pattern;
        let mut b = mk(Some(30));
        for s in 0..4u32 {
            let (o, l) = b.slot_extent(s);
            b.chunks[s as usize] = Some(Chunk::materialized(o, pattern::make(FileId(0), o, l)));
        }
        let c = b.extract(1025, 40); // spans slots 0..=2
        assert_eq!(c.offset, 1025);
        assert_eq!(c.len, 40);
        let bytes = c.bytes.unwrap();
        assert_eq!(pattern::verify(FileId(0), 1025, &bytes), None);
    }

    #[test]
    fn extract_modeled_stays_modeled() {
        let mut b = mk(Some(30));
        for s in 0..4u32 {
            let (o, l) = b.slot_extent(s);
            b.chunks[s as usize] = Some(Chunk::modeled(o, l));
        }
        let c = b.extract(1025, 40);
        assert!(c.bytes.is_none());
        assert_eq!(c.len, 40);
    }

    #[test]
    fn fresh_buffer_is_active_and_empty() {
        let b = mk(Some(30));
        assert!(!b.is_dropped());
        assert_eq!(b.pending_len(), 0);
        assert_eq!(b.resident_bytes(), 0);
        assert_eq!(b.pfs_queue.len(), 4, "every slot starts PFS-bound");
        assert!(
            !b.peers_resolved,
            "a fresh chare must hold PFS issuance until the shard answers"
        );
    }

    #[test]
    fn peer_assignment_removes_slots_from_the_pfs_queue() {
        let src = ChareRef::new(CollectionId(9), 0);
        let b = mk(Some(30)).with_peers(vec![
            PeerSlot { slot: 0, owner: src, owner_pe: 0 },
            PeerSlot { slot: 2, owner: src, owner_pe: 0 },
        ]);
        assert_eq!(b.peer_slot_count(), 2);
        assert_eq!(b.pfs_queue, VecDeque::from(vec![1, 3]));
        assert!(b.peers_resolved);
    }

    #[test]
    fn planned_builder_records_the_expectation() {
        let b = mk(Some(30)).planned(60);
        assert_eq!(b.planned_covered, Some(60));
        assert!(!b.peers_resolved, "a plan does not replace registration");
    }

    #[test]
    fn backoff_grows_exponentially_caps_and_is_deterministic() {
        let b = mk(Some(30)).with_retry(RetryPolicy::default());
        let p = RetryPolicy::default();
        let spread = p.base_backoff_ns / 2;
        for attempt in 1..=6u32 {
            let got = b.backoff_ns(7, attempt);
            let exp = (p.base_backoff_ns << (attempt - 1)).min(p.max_backoff_ns);
            let jitter = (7u64.wrapping_mul(2_654_435_761) + u64::from(attempt)) % spread;
            assert_eq!(got, exp + jitter, "attempt {attempt}");
            assert_eq!(got, b.backoff_ns(7, attempt), "no RNG: replays must agree");
        }
    }

    #[test]
    fn degraded_overlap_counts_only_gave_up_slots() {
        let mut b = mk(Some(30));
        assert_eq!(b.degraded_overlap(1000, 100), 0);
        b.degraded_slots.insert(1); // slot 1 = [1030, 1060)
        assert_eq!(b.degraded_overlap(1000, 100), 30);
        assert_eq!(b.degraded_overlap(1040, 10), 10);
        assert_eq!(b.degraded_overlap(1000, 30), 0);
        assert_eq!(b.degraded_overlap(1025, 10), 5);
    }

    #[test]
    fn attempt_keys_never_collide_across_slots_or_attempts() {
        assert_eq!(BufferChare::attempt_key(3, 1), 3 | (1 << 32));
        assert_ne!(BufferChare::attempt_key(3, 1), BufferChare::attempt_key(3, 2));
        assert_ne!(BufferChare::attempt_key(3, 1), BufferChare::attempt_key(4, 1));
        // The retry-less encoding (attempt 0) is the bare slot.
        assert_eq!(BufferChare::attempt_key(5, 0), 5);
    }

    #[test]
    fn take_outcome_hands_off_and_resets() {
        let mut b = mk(None);
        b.n_served_bytes = 10;
        b.n_degraded_bytes = 5;
        b.n_retries = 2;
        b.n_hedges = 1;
        b.n_gave_up = 3;
        assert_eq!(b.take_outcome(), (10, 5, 2, 1, 3));
        assert_eq!(b.take_outcome(), (0, 0, 0, 0, 0), "a fresh session starts clean");
    }

    #[test]
    fn zero_length_span_has_no_pfs_work() {
        let b = BufferChare::new(
            SessionId(0),
            FileId(0),
            1000,
            0,
            Some(30),
            2,
            ChareRef::new(CollectionId(0), 0),
            ChareRef::new(CollectionId(2), 0),
            CollectionId(1),
        );
        assert!(b.pfs_queue.is_empty());
    }
}
