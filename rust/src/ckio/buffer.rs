//! Buffer chares: the designated file-reading agents (paper §III-C.4).
//!
//! Each buffer chare owns a disjoint span of the session and reads it
//! *greedily* as soon as the session starts — before any client asks —
//! via split-phase reads (helper pthreads in the paper; the engine's I/O
//! backends here). Client fetches that arrive before the data is resident
//! are queued and served on I/O completion; fetches for resident data are
//! answered immediately with a zero-copy send to the requesting PE's
//! ReadAssembler.
//!
//! Splintered I/O (paper §VI.C) is supported: with
//! `Options::splinter_bytes` set, the span is read in sub-chunks and a
//! fetch is served as soon as the splinters covering it have arrived.
//!
//! Lifecycle (PR 1): a buffer chare is `Active` while its session runs.
//! Teardown *drains* — every queued fetch is answered before the director
//! is acked (resident extents with real data, the rest with modeled NACK
//! chunks), so a `closeReadSession` racing outstanding reads can never
//! strand an assembly. A fetch that arrives *after* the drop (it was in
//! flight when the drop landed) is flush-served the same way. With
//! `Options::reuse_buffers`, teardown *parks* instead: resident data is
//! kept and a later identical session rebinds the array without touching
//! the file system again.

use crate::amt::callback::Callback;
use crate::amt::chare::{Chare, ChareRef, CollectionId};
use crate::amt::engine::Ctx;
use crate::amt::msg::{Ep, Msg};
use crate::amt::time::MICROS;
use crate::amt::topology::Pe;
use crate::impl_chare_any;
use crate::metrics::keys;
use crate::net::Transfer;
use crate::pfs::backend::{IoResult, ReadRequest};
use crate::pfs::layout::FileId;
use crate::util::bytes::{ceil_div, Chunk};

use super::session::{SessionId, Tag};

/// Kick a freshly created buffer chare: issue its greedy reads.
pub const EP_BUF_INIT: Ep = 1;
/// Split-phase read completion (engine callback).
pub const EP_BUF_DATA: Ep = 2;
/// A ReadAssembler requests a sub-extent.
pub const EP_BUF_FETCH: Ep = 3;
/// Session teardown: drain pending fetches, release memory, ack.
pub const EP_BUF_DROP: Ep = 4;
/// Session teardown with reuse: drain, keep resident data, ack.
pub const EP_BUF_PARK: Ep = 5;
/// Revive a parked buffer under a new session id (payload: `SessionId`).
pub const EP_BUF_REBIND: Ep = 6;

/// Fetch request from an assembler.
#[derive(Debug)]
pub struct FetchMsg {
    pub tag: Tag,
    /// File-coordinate extent (already clipped to this buffer's span).
    pub offset: u64,
    pub len: u64,
    /// PE whose assembler should receive the piece.
    pub reply_pe: Pe,
}

/// Piece sent to an assembler (zero-copy payload).
#[derive(Debug)]
pub struct PieceMsg {
    pub tag: Tag,
    pub chunk: Chunk,
}

/// Notification to the director that this buffer initiated its reads
/// (or, on rebind, that it is serving again).
#[derive(Debug)]
pub struct BufStartedMsg {
    pub session: SessionId,
}

/// Ack to the director after dropping/parking session state.
#[derive(Debug)]
pub struct BufDroppedMsg {
    pub session: SessionId,
}

/// Lifecycle state of a buffer chare.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
enum BufState {
    /// Serving a live session.
    Active,
    /// Session closed with `reuse_buffers`: data retained for rebind.
    Parked,
    /// Session closed: data released; late fetches are flush-served
    /// with modeled NACK chunks, late I/O completions discarded.
    Dropped,
}

/// One buffer chare.
pub struct BufferChare {
    session: SessionId,
    file: FileId,
    /// Span owned by this chare, file coordinates.
    my_offset: u64,
    my_len: u64,
    /// Splinter size (0 = read the whole span in one request).
    splinter: u64,
    /// Max splinters in flight.
    window: u32,
    /// Per-splinter data; index = splinter slot.
    chunks: Vec<Option<Chunk>>,
    next_issue: u32,
    completed: u32,
    pending: Vec<FetchMsg>,
    director: ChareRef,
    assemblers: CollectionId,
    state: BufState,
}

impl BufferChare {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        session: SessionId,
        file: FileId,
        my_offset: u64,
        my_len: u64,
        splinter: Option<u64>,
        window: u32,
        director: ChareRef,
        assemblers: CollectionId,
    ) -> BufferChare {
        let splinter = splinter.unwrap_or(0).min(my_len);
        let nslots = if splinter == 0 || my_len == 0 {
            1
        } else {
            ceil_div(my_len, splinter) as usize
        };
        BufferChare {
            session,
            file,
            my_offset,
            my_len,
            splinter,
            window: window.max(1),
            chunks: vec![None; nslots],
            next_issue: 0,
            completed: 0,
            pending: Vec::new(),
            director,
            assemblers,
            state: BufState::Active,
        }
    }

    /// The file-coordinate extent of splinter slot `i`.
    fn slot_extent(&self, i: u32) -> (u64, u64) {
        if self.splinter == 0 {
            return (self.my_offset, self.my_len);
        }
        let lo = self.my_offset + i as u64 * self.splinter;
        let hi = (lo + self.splinter).min(self.my_offset + self.my_len);
        (lo, hi - lo)
    }

    /// Slots overlapping `[offset, offset+len)`.
    fn slots_for(&self, offset: u64, len: u64) -> std::ops::RangeInclusive<u32> {
        debug_assert!(offset >= self.my_offset && offset + len <= self.my_offset + self.my_len);
        if self.splinter == 0 {
            return 0..=0;
        }
        let lo = ((offset - self.my_offset) / self.splinter) as u32;
        let hi = ((offset + len - 1 - self.my_offset) / self.splinter) as u32;
        lo..=hi
    }

    fn have(&self, offset: u64, len: u64) -> bool {
        self.slots_for(offset, len).all(|s| self.chunks[s as usize].is_some())
    }

    /// Issue the next splinter read, if any remain.
    fn issue_next(&mut self, ctx: &mut Ctx<'_>) {
        if self.my_len == 0 || self.next_issue as usize >= self.chunks.len() {
            return;
        }
        let slot = self.next_issue;
        self.next_issue += 1;
        let (offset, len) = self.slot_extent(slot);
        let me = ctx.me();
        ctx.submit_read(
            ReadRequest { file: self.file, offset, len, user: slot as u64 },
            Callback::to_chare(me, EP_BUF_DATA),
        );
    }

    /// Answer a fetch from resident data: zero-copy send to the
    /// requesting PE's assembler.
    fn serve(&self, ctx: &mut Ctx<'_>, f: &FetchMsg) {
        let chunk = self.extract(f.offset, f.len);
        let to = ChareRef::new(self.assemblers, f.reply_pe.0);
        let wire = chunk.len;
        ctx.metrics().count("ckio.pieces_served", 1);
        // Zero-copy: the runtime RDMA-gets the resident buffer; the chare
        // itself only touches descriptors.
        ctx.advance(MICROS / 2);
        ctx.send_sized(
            to,
            super::assembler::EP_A_PIECE,
            crate::amt::msg::Payload::new(PieceMsg { tag: f.tag, chunk }),
            wire,
            Transfer::ZeroCopy,
        );
    }

    /// Answer a fetch that can no longer be served with data (teardown):
    /// a modeled NACK chunk so the assembly still completes exactly once.
    fn serve_nack(&self, ctx: &mut Ctx<'_>, f: &FetchMsg) {
        ctx.metrics().count("ckio.pieces_nacked", 1);
        let to = ChareRef::new(self.assemblers, f.reply_pe.0);
        ctx.send(
            to,
            super::assembler::EP_A_PIECE,
            PieceMsg { tag: f.tag, chunk: Chunk::modeled(f.offset, f.len) },
        );
    }

    /// Teardown drain: answer every queued fetch exactly once — resident
    /// extents with data, the rest as NACKs — before acking the director.
    fn drain_pending(&mut self, ctx: &mut Ctx<'_>) {
        for f in std::mem::take(&mut self.pending) {
            if self.have(f.offset, f.len) {
                self.serve(ctx, &f);
            } else {
                self.serve_nack(ctx, &f);
            }
        }
    }

    /// Build the chunk for `[offset, offset+len)` from resident splinters.
    fn extract(&self, offset: u64, len: u64) -> Chunk {
        let slots = self.slots_for(offset, len);
        let (lo, hi) = (*slots.start(), *slots.end());
        if lo == hi {
            return self.chunks[lo as usize].as_ref().unwrap().slice(offset, len);
        }
        // Multi-splinter extract: concatenate the relevant pieces.
        let mut bytes: Option<Vec<u8>> = None;
        let mut modeled_only = false;
        for s in slots {
            let c = self.chunks[s as usize].as_ref().unwrap();
            let (slo, slen) = self.slot_extent(s);
            let take_lo = offset.max(slo);
            let take_hi = (offset + len).min(slo + slen);
            let piece = c.slice(take_lo, take_hi - take_lo);
            match piece.bytes {
                Some(b) => bytes.get_or_insert_with(Vec::new).extend_from_slice(&b),
                None => modeled_only = true,
            }
        }
        if modeled_only || bytes.is_none() {
            Chunk::modeled(offset, len)
        } else {
            Chunk::materialized(offset, bytes.unwrap().into())
        }
    }

    /// Queued fetch count (leak checks in tests).
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Whether teardown released this chare's data.
    pub fn is_dropped(&self) -> bool {
        self.state == BufState::Dropped
    }

    /// Bytes currently resident (parked-cache inspection).
    pub fn resident_bytes(&self) -> u64 {
        self.chunks.iter().flatten().map(|c| c.len).sum()
    }
}

impl Chare for BufferChare {
    fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
        match msg.ep {
            EP_BUF_INIT => {
                // Greedy read: start immediately, before any client asks.
                let n = if self.splinter == 0 { 1 } else { self.window };
                for _ in 0..n {
                    self.issue_next(ctx);
                }
                ctx.advance(MICROS);
                ctx.send(self.director, super::director::EP_DIR_BUF_STARTED, BufStartedMsg {
                    session: self.session,
                });
            }
            EP_BUF_DATA => {
                let r: IoResult = msg.take();
                if self.state == BufState::Dropped {
                    return; // late completion after teardown
                }
                // Active or Parked: keep filling (a parked buffer keeps
                // warming its cache for the next rebind).
                let slot = r.user as usize;
                debug_assert!(self.chunks[slot].is_none(), "duplicate splinter completion");
                self.chunks[slot] = Some(r.chunk);
                self.completed += 1;
                self.issue_next(ctx);
                if self.completed as usize == self.chunks.len() {
                    let t = ctx.now() as f64;
                    ctx.metrics().set_max("ckio.last_io_ns", t);
                }
                // Serve whatever became satisfiable.
                let mut still = Vec::new();
                for f in std::mem::take(&mut self.pending) {
                    if self.have(f.offset, f.len) {
                        self.serve(ctx, &f);
                    } else {
                        still.push(f);
                    }
                }
                self.pending = still;
            }
            EP_BUF_FETCH => {
                let f: FetchMsg = msg.take();
                debug_assert!(
                    f.offset >= self.my_offset && f.offset + f.len <= self.my_offset + self.my_len,
                    "fetch [{}, {}) outside buffer span [{}, {})",
                    f.offset,
                    f.offset + f.len,
                    self.my_offset,
                    self.my_offset + self.my_len
                );
                ctx.metrics().count("ckio.fetches", 1);
                if self.state == BufState::Dropped {
                    // The fetch was in flight when the drop landed:
                    // flush-serve so its assembly still completes.
                    ctx.metrics().count("ckio.fetch_after_drop", 1);
                    if self.have(f.offset, f.len) {
                        self.serve(ctx, &f);
                    } else {
                        self.serve_nack(ctx, &f);
                    }
                } else if self.have(f.offset, f.len) {
                    self.serve(ctx, &f);
                } else {
                    self.pending.push(f);
                }
            }
            EP_BUF_DROP => {
                self.drain_pending(ctx);
                self.chunks.iter_mut().for_each(|c| *c = None);
                self.state = BufState::Dropped;
                ctx.advance(MICROS / 2);
                ctx.send(self.director, super::director::EP_DIR_DROP_ACK, BufDroppedMsg {
                    session: self.session,
                });
            }
            EP_BUF_PARK => {
                self.drain_pending(ctx);
                self.state = BufState::Parked;
                ctx.advance(MICROS / 2);
                ctx.send(self.director, super::director::EP_DIR_DROP_ACK, BufDroppedMsg {
                    session: self.session,
                });
            }
            EP_BUF_REBIND => {
                let sid: SessionId = msg.take();
                debug_assert!(
                    self.state == BufState::Parked,
                    "rebind of a non-parked buffer ({:?})",
                    self.state
                );
                self.session = sid;
                self.state = BufState::Active;
                ctx.metrics().count("ckio.buffers_rebound", 1);
                ctx.advance(MICROS / 2);
                // Resident data makes this chare immediately serviceable;
                // any still-outstanding prefetch completions keep landing.
                ctx.send(self.director, super::director::EP_DIR_BUF_STARTED, BufStartedMsg {
                    session: sid,
                });
            }
            other => panic!("BufferChare: unknown ep {other}"),
        }
        let _ = keys::CKIO_BYTES; // (metrics charged by the assembler side)
    }

    fn pack_size(&self) -> u64 {
        // Buffer chares are not migrated while holding data in this
        // implementation; descriptor-only size.
        256
    }

    impl_chare_any!();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(splinter: Option<u64>) -> BufferChare {
        BufferChare::new(
            SessionId(0),
            FileId(0),
            1000,
            100,
            splinter,
            2,
            ChareRef::new(CollectionId(0), 0),
            CollectionId(1),
        )
    }

    #[test]
    fn slot_extents_whole_span() {
        let b = mk(None);
        assert_eq!(b.chunks.len(), 1);
        assert_eq!(b.slot_extent(0), (1000, 100));
        assert_eq!(b.slots_for(1000, 100), 0..=0);
    }

    #[test]
    fn slot_extents_splintered() {
        let b = mk(Some(30));
        assert_eq!(b.chunks.len(), 4); // 30+30+30+10
        assert_eq!(b.slot_extent(0), (1000, 30));
        assert_eq!(b.slot_extent(3), (1090, 10));
        assert_eq!(b.slots_for(1000, 30), 0..=0);
        assert_eq!(b.slots_for(1029, 2), 0..=1);
        assert_eq!(b.slots_for(1000, 100), 0..=3);
    }

    #[test]
    fn have_tracks_partial_arrival() {
        let mut b = mk(Some(30));
        assert!(!b.have(1000, 10));
        b.chunks[0] = Some(Chunk::modeled(1000, 30));
        assert!(b.have(1000, 30));
        assert!(!b.have(1020, 20)); // needs slot 1
        b.chunks[1] = Some(Chunk::modeled(1030, 30));
        assert!(b.have(1020, 20));
    }

    #[test]
    fn extract_concatenates_materialized_splinters() {
        use crate::pfs::pattern;
        let mut b = mk(Some(30));
        for s in 0..4u32 {
            let (o, l) = b.slot_extent(s);
            b.chunks[s as usize] = Some(Chunk::materialized(o, pattern::make(FileId(0), o, l)));
        }
        let c = b.extract(1025, 40); // spans slots 0..=2
        assert_eq!(c.offset, 1025);
        assert_eq!(c.len, 40);
        let bytes = c.bytes.unwrap();
        assert_eq!(pattern::verify(FileId(0), 1025, &bytes), None);
    }

    #[test]
    fn extract_modeled_stays_modeled() {
        let mut b = mk(Some(30));
        for s in 0..4u32 {
            let (o, l) = b.slot_extent(s);
            b.chunks[s as usize] = Some(Chunk::modeled(o, l));
        }
        let c = b.extract(1025, 40);
        assert!(c.bytes.is_none());
        assert_eq!(c.len, 40);
    }

    #[test]
    fn fresh_buffer_is_active_and_empty(){
        let b = mk(Some(30));
        assert!(!b.is_dropped());
        assert_eq!(b.pending_len(), 0);
        assert_eq!(b.resident_bytes(), 0);
    }
}
