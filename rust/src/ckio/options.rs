//! CkIO configuration (`Ck::IO::Options` in the paper).

use crate::amt::topology::{Placement, Topology};
use crate::util::bytes::ceil_div;

pub use super::governor::AdmissionPolicy;

/// Where buffer chares are placed (paper §VI.B, extended in PR 4 with
/// store-aware planning).
#[derive(Clone, Debug, Default)]
pub enum ReaderPlacement {
    /// Spread across nodes first (maximize NIC / FS-path parallelism) —
    /// the default, and what the paper's experiments use.
    #[default]
    SpreadNodes,
    /// Pack onto consecutive PEs.
    PackPes,
    /// Explicit PE list (length must cover the reader count; when the
    /// resolved count is *smaller* — e.g. a tiny file clamps the reader
    /// count below the list length — the list is truncated).
    Explicit(Vec<u32>),
    /// Store-aware placement (PR 4, the paper's Fig. 12 locality idea at
    /// session start): the director first asks the file's data-plane
    /// shard *where the session's bytes already live* (`EP_SHARD_PLAN`)
    /// and places each buffer chare on the PE of its dominant peer
    /// source, so peer fetches become same-PE copies. Buffers whose span
    /// has no resident coverage fall back to `fallback` (which must be
    /// one of the concrete variants above — nesting `StoreAware` is a
    /// configuration error caught at `open`).
    StoreAware { fallback: Box<ReaderPlacement> },
}

/// Structured configuration error, delivered through the `open` callback
/// (instead of a FileHandle) when a file's opening [`Options`] can never
/// work. Callers discriminate with `payload.peek::<OpenError>()`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum OpenError {
    /// An explicit placement list is shorter than the largest reader
    /// count any session of this file could resolve to.
    PlacementTooShort { need: u32, got: u32 },
    /// `StoreAware` must fall back to a concrete placement, not to
    /// another `StoreAware`.
    RecursiveFallback,
}

impl std::fmt::Display for OpenError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            OpenError::PlacementTooShort { need, got } => {
                write!(f, "explicit reader placement needs >= {need} PEs, got {got}")
            }
            OpenError::RecursiveFallback => {
                write!(f, "StoreAware fallback must be a concrete placement")
            }
        }
    }
}

impl ReaderPlacement {
    /// Whether session start must run the plan-then-create round trip
    /// (`EP_SHARD_PLAN`) before materializing a placement.
    pub fn is_store_aware(&self) -> bool {
        matches!(self, ReaderPlacement::StoreAware { .. })
    }

    /// Validate this policy for a file whose sessions can resolve at
    /// most `need` readers ([`Options::validate`] computes `need` from
    /// the file size, the worst case over every admissible session).
    pub fn validate(&self, need: u32) -> Result<(), OpenError> {
        match self {
            ReaderPlacement::SpreadNodes | ReaderPlacement::PackPes => Ok(()),
            ReaderPlacement::Explicit(pes) => {
                if (pes.len() as u32) < need {
                    Err(OpenError::PlacementTooShort { need, got: pes.len() as u32 })
                } else {
                    Ok(())
                }
            }
            ReaderPlacement::StoreAware { fallback } => match fallback.as_ref() {
                ReaderPlacement::StoreAware { .. } => Err(OpenError::RecursiveFallback),
                concrete => concrete.validate(need),
            },
        }
    }

    /// Materialize a [`Placement`] for `n` *resolved* readers.
    ///
    /// `n` comes out of [`Options::resolve_readers`], which may clamp the
    /// requested count down (never more readers than bytes) — so an
    /// explicit list only needs to be *at least* `n` long; extra entries
    /// are ignored. A list shorter than `n` is a configuration error,
    /// reported as a structured [`OpenError`] (the director runs
    /// [`Options::validate`] at `open`, so a session start over an
    /// admitted file can never see `Err` here).
    ///
    /// For [`ReaderPlacement::StoreAware`] this returns the *fallback*
    /// placement — the no-residency answer; the director overrides
    /// per-buffer PEs with the shard's `PlacementPlan` when one exists.
    pub fn to_placement(&self, n: u32) -> Result<Placement, OpenError> {
        match self {
            ReaderPlacement::SpreadNodes => Ok(Placement::RoundRobinNodes),
            ReaderPlacement::PackPes => Ok(Placement::RoundRobinPes),
            ReaderPlacement::Explicit(pes) => {
                if (pes.len() as u32) < n {
                    return Err(OpenError::PlacementTooShort { need: n, got: pes.len() as u32 });
                }
                Ok(Placement::Explicit(
                    pes.iter().take(n as usize).map(|&p| crate::amt::topology::Pe(p)).collect(),
                ))
            }
            ReaderPlacement::StoreAware { fallback } => fallback.to_placement(n),
        }
    }
}

/// Options passed to `Ck::IO::open` (paper §III-D).
#[derive(Clone, Debug)]
pub struct Options {
    /// Number of buffer chares per session (`Options::numReaders`).
    /// `None` selects automatically from file size and cluster shape
    /// (paper §VI.A).
    pub num_readers: Option<u32>,
    /// Buffer chare placement policy.
    pub placement: ReaderPlacement,
    /// Splintered I/O (paper §VI.C): buffer chares read their span in
    /// sub-chunks of this size, so early reads can be served before the
    /// whole span arrives. `None` = one read per span (base design).
    pub splinter_bytes: Option<u64>,
    /// Splinters kept in flight per buffer chare when splintering.
    pub read_window: u32,
    /// Buffer-chare reuse across sessions (PR 1): when set, closing a
    /// session *parks* its buffer-chare array (keeping resident data)
    /// instead of dropping it, and a later `startReadSession` over the
    /// same `(file, range, shape)` revives it — repeated sessions on the
    /// same file skip the greedy re-read entirely.
    pub reuse_buffers: bool,
    /// Byte budget of the director's span store for *parked* arrays
    /// (PR 2). `None` keeps the PR 1 default of at most
    /// [`super::store::SpanStore::DEFAULT_MAX_ARRAYS`] parked arrays;
    /// `Some(bytes)` switches to byte-budgeted LRU eviction. The store is
    /// global: the opening `Options` of each file (re)configure it, last
    /// writer wins.
    pub store_budget_bytes: Option<u64>,
    /// Admission governor (PR 2): cap on the number of PFS reads in
    /// flight across all sessions of governed files. `None` = this
    /// file's sessions are ungoverned (buffer chares issue reads
    /// directly, the PR 1 behavior) — unless [`Options::adaptive_admission`]
    /// turns on the derived cap. The cap value itself is a global knob
    /// configured at *first* open of a file (last writer wins;
    /// refcounted re-opens do not reconfigure).
    ///
    /// Since PR 3 the cap is enforced **per data-plane shard**: sessions
    /// of files that hash to the same shard share one cap (so same-file
    /// sessions are sequenced exactly as before), while files on
    /// different shards admit independently — the aggregate worst case
    /// is `cap × active shards`. For the PR 2 cluster-wide semantics,
    /// set [`Options::data_plane_shards`] to `Some(1)`.
    pub max_inflight_reads: Option<u32>,
    /// Order in which the governor admits queued prefetch demand.
    pub admission: AdmissionPolicy,
    /// Governor feedback control (PR 3): when `max_inflight_reads` is
    /// `None`, govern this file's sessions anyway and *derive* the
    /// per-shard cap from observed read service times (AIMD: the cap
    /// grows by one while the p50 service time of a completion window
    /// stays flat, and halves when it inflates — i.e. when the OSTs
    /// start queueing). Ignored when a static cap is set. The
    /// `ckio.governor.cap` gauge tracks the adapted value.
    pub adaptive_admission: bool,
    /// Number of data-plane shards the director's `FileId` hash routes
    /// over (PR 3). `None` = one shard per PE (the full array booted by
    /// [`super::CkIo::boot`]); `Some(n)` clamps the hash to the first
    /// `n` shards. Structural knob: applied only when the data plane is
    /// fully quiescent (no open files, opens, sessions, teardowns,
    /// rebind probes, or placement plans in flight), so FileId→shard
    /// routing is stable for the whole life of every piece of data-plane
    /// state. `Some(1)` funnels everything through one shard —
    /// bit-for-bit the PR 2 single-plane semantics (global store budget,
    /// global cap).
    pub data_plane_shards: Option<u32>,
}

impl Default for Options {
    fn default() -> Self {
        Options {
            num_readers: None,
            placement: ReaderPlacement::default(),
            splinter_bytes: None,
            read_window: 2,
            reuse_buffers: false,
            store_budget_bytes: None,
            max_inflight_reads: None,
            admission: AdmissionPolicy::default(),
            adaptive_admission: false,
            data_plane_shards: None,
        }
    }
}

impl Options {
    pub fn with_readers(n: u32) -> Options {
        Options { num_readers: Some(n), ..Default::default() }
    }

    /// Resolve the reader count for a session of `bytes` on `topo`
    /// (§VI.A's automatic policy when `num_readers` is `None`).
    pub fn resolve_readers(&self, bytes: u64, topo: &Topology) -> u32 {
        let n = self.num_readers.unwrap_or_else(|| auto_readers(bytes, topo));
        // Never more readers than bytes.
        n.clamp(1, bytes.max(1).min(u32::MAX as u64) as u32)
    }

    /// Validate these options for a file of `file_size` bytes: the check
    /// the director runs at `open`, before the options can govern the
    /// file. `resolve_readers` is monotonic in the session byte count,
    /// so the largest reader count any session `[off, off+b)` with
    /// `b <= file_size` can resolve to is `resolve_readers(file_size)` —
    /// an explicit placement list admitted here can never come up short
    /// at a later session start (it is only ever truncated).
    pub fn validate(&self, file_size: u64, topo: &Topology) -> Result<(), OpenError> {
        let need = self.resolve_readers(file_size.max(1), topo);
        self.placement.validate(need)
    }
}

/// Automatic reader-count policy (paper §VI.A, future work — implemented
/// here as a tunable heuristic and evaluated in `ablation_autoreaders`):
///
/// * target span per reader ≈ 8 MiB (a few RPCs per stream: enough to
///   amortize per-stream overheads while maximizing concurrent OST
///   streams — the sweep in `ablation_autoreaders` sits there),
/// * at least 2 readers per node (a single stream can't fill a NIC),
/// * at most one reader per PE (past that, streams interleave at the
///   OSTs and per-RPC overheads dominate — the Fig. 1 collapse).
pub fn auto_readers(bytes: u64, topo: &Topology) -> u32 {
    const TARGET_SPAN: u64 = 8 << 20;
    let by_span = ceil_div(bytes, TARGET_SPAN);
    let lo = (2 * topo.nodes) as u64;
    let hi = topo.npes() as u64;
    by_span.clamp(lo.min(hi), hi).max(1) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_policy_scales_with_file_and_nodes() {
        let t16 = Topology::new(16, 32);
        // Tiny file: floor of 2 readers/node.
        assert_eq!(auto_readers(1 << 20, &t16), 32);
        // Huge file: ceiling of one reader per PE.
        assert_eq!(auto_readers(64 << 30, &t16), 512);
        // Mid-size: span-driven (1 GiB / 8 MiB = 128 readers).
        assert_eq!(auto_readers(1 << 30, &t16), 128);
    }

    #[test]
    fn resolve_respects_explicit_count() {
        let topo = Topology::new(2, 4);
        let o = Options::with_readers(6);
        assert_eq!(o.resolve_readers(1 << 30, &topo), 6);
    }

    #[test]
    fn resolve_clamps_to_bytes() {
        let topo = Topology::new(2, 4);
        let o = Options::with_readers(64);
        assert_eq!(o.resolve_readers(10, &topo), 10);
    }

    #[test]
    fn placement_mapping() {
        let p = ReaderPlacement::SpreadNodes.to_placement(8).unwrap();
        assert!(matches!(p, Placement::RoundRobinNodes));
        let p = ReaderPlacement::Explicit(vec![0, 3]).to_placement(2).unwrap();
        assert!(matches!(p, Placement::Explicit(_)));
    }

    /// Regression (PR 4): a too-short explicit list is a structured
    /// error, not a panic — the director surfaces it through the open
    /// callback.
    #[test]
    fn explicit_placement_wrong_length_is_an_error() {
        assert_eq!(
            ReaderPlacement::Explicit(vec![0]).to_placement(2).unwrap_err(),
            OpenError::PlacementTooShort { need: 2, got: 1 }
        );
    }

    /// Regression (PR 1): a tiny file clamps the resolved reader count
    /// below the explicit PE-list length; placement must truncate the
    /// list to the clamped count instead of erroring.
    #[test]
    fn explicit_placement_truncates_to_clamped_readers() {
        use crate::amt::topology::Pe;
        let topo = Topology::new(2, 4);
        let o = Options {
            num_readers: Some(4),
            placement: ReaderPlacement::Explicit(vec![0, 1, 2, 3]),
            ..Default::default()
        };
        // 2-byte file: never more readers than bytes.
        let n = o.resolve_readers(2, &topo);
        assert_eq!(n, 2);
        match o.placement.to_placement(n).unwrap() {
            Placement::Explicit(pes) => assert_eq!(pes, vec![Pe(0), Pe(1)]),
            other => panic!("unexpected placement {other:?}"),
        }
    }

    #[test]
    fn store_aware_resolves_and_validates_through_its_fallback() {
        let sa = ReaderPlacement::StoreAware { fallback: Box::new(ReaderPlacement::SpreadNodes) };
        assert!(sa.is_store_aware());
        assert!(matches!(sa.to_placement(4), Ok(Placement::RoundRobinNodes)));
        assert_eq!(sa.validate(8), Ok(()));

        let short = ReaderPlacement::StoreAware {
            fallback: Box::new(ReaderPlacement::Explicit(vec![0, 1])),
        };
        assert_eq!(short.validate(4), Err(OpenError::PlacementTooShort { need: 4, got: 2 }));

        let nested = ReaderPlacement::StoreAware {
            fallback: Box::new(ReaderPlacement::StoreAware {
                fallback: Box::new(ReaderPlacement::SpreadNodes),
            }),
        };
        assert_eq!(nested.validate(4), Err(OpenError::RecursiveFallback));
    }

    /// `Options::validate` checks the worst case over every admissible
    /// session: the whole-file reader count.
    #[test]
    fn validate_checks_the_largest_resolvable_reader_count() {
        let topo = Topology::new(2, 4);
        let o = Options {
            num_readers: Some(4),
            placement: ReaderPlacement::Explicit(vec![0, 1]),
            ..Default::default()
        };
        // A large file can resolve all 4 readers: the 2-entry list fails.
        assert_eq!(
            o.validate(1 << 20, &topo),
            Err(OpenError::PlacementTooShort { need: 4, got: 2 })
        );
        // A 2-byte file clamps every session to <= 2 readers: it passes.
        assert_eq!(o.validate(2, &topo), Ok(()));
    }
}
