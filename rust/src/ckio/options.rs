//! CkIO configuration, in three explicit scopes (PR 5).
//!
//! The paper's thesis is that CkIO is "configurable via multiple
//! parameters … tuned depending on characteristics of the application".
//! Until PR 5 every knob lived in one `Options` struct passed to `open`,
//! which conflated three very different scopes — service-wide state was
//! "last writer wins", per-session intent was frozen at open time, and a
//! session had no way to say who it is or how urgent it is. The scopes
//! are now explicit types, each consumed exactly once, at the call that
//! owns that scope:
//!
//! * [`ServiceConfig`] → `CkIo::boot_with` — state shared by every file
//!   and session of the service instance: the span-store byte budget,
//!   the data-plane shard count, and the admission cap/policy. Applied
//!   once, at boot, before any message flows; there is no runtime
//!   reconfiguration (and therefore no "last writer wins" or idle-
//!   barrier re-sharding left anywhere).
//! * [`FileOptions`] → `CkIo::open` — per-file policy: reader count and
//!   buffer-chare placement. Validated at open with structured
//!   [`OpenError`]s; re-opening an already-open file with *different*
//!   options is a structured conflict error, not a silent ignore.
//! * [`SessionOptions`] → `CkIo::start_read_session` — per-session
//!   intent: the [`QosClass`] (who this session is / how urgent),
//!   splintering, the read window, buffer reuse, and an optional
//!   placement override. `SessionOptions::default()` reproduces the
//!   pre-redesign behavior exactly.
//!
//! # Migration from the old `Options`
//!
//! | old `Options` field     | new home                                  |
//! |-------------------------|-------------------------------------------|
//! | `num_readers`           | [`FileOptions::num_readers`]              |
//! | `placement`             | [`FileOptions::placement`] (per-session: [`SessionOptions::placement_override`]) |
//! | `splinter_bytes`        | [`SessionOptions::splinter_bytes`]        |
//! | `read_window`           | [`SessionOptions::read_window`]           |
//! | `reuse_buffers`         | [`SessionOptions::reuse_buffers`]         |
//! | `store_budget_bytes`    | [`ServiceConfig::store_budget_bytes`]     |
//! | `max_inflight_reads`    | [`ServiceConfig::max_inflight_reads`]     |
//! | `admission`             | [`ServiceConfig::admission`]              |
//! | `adaptive_admission`    | [`ServiceConfig::adaptive_admission`]     |
//! | `data_plane_shards`     | [`ServiceConfig::data_plane_shards`]      |
//! | *(new, PR 5)*           | [`SessionOptions::class`]                 |
//! | *(new, PR 7)*           | [`ServiceConfig::trace`]                  |

use crate::amt::topology::{Placement, Topology};
use crate::util::bytes::ceil_div;

pub use super::governor::{AdmissionPolicy, QosClass};
pub use crate::trace::TraceConfig;

/// Where buffer chares are placed (paper §VI.B, extended in PR 4 with
/// store-aware planning).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum ReaderPlacement {
    /// Spread across nodes first (maximize NIC / FS-path parallelism) —
    /// the default, and what the paper's experiments use.
    #[default]
    SpreadNodes,
    /// Pack onto consecutive PEs.
    PackPes,
    /// Explicit PE list (length must cover the reader count; when the
    /// resolved count is *smaller* — e.g. a tiny file clamps the reader
    /// count below the list length — the list is truncated).
    Explicit(Vec<u32>),
    /// Store-aware placement (PR 4, the paper's Fig. 12 locality idea at
    /// session start): the director first asks the file's data-plane
    /// shard *where the session's bytes already live* (`EP_SHARD_PLAN`)
    /// and places each buffer chare on the PE of its dominant peer
    /// source, so peer fetches become same-PE copies. Buffers whose span
    /// has no resident coverage fall back to `fallback` (which must be
    /// one of the concrete variants above — nesting `StoreAware` is a
    /// configuration error caught at `open`).
    StoreAware { fallback: Box<ReaderPlacement> },
}

/// Consumer-side locality policy (PR 9, the dual of
/// [`ReaderPlacement::StoreAware`]): instead of moving *readers* to the
/// data, move data *consumers* to the buffer chares that feed them —
/// the half of the paper's Fig. 12 story only an over-decomposed,
/// migratable programming model can do at all.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ConsumerPlacement {
    /// Consumers stay where the application put them (the default, and
    /// the pre-PR 9 behavior bit for bit: no flow accounts are kept and
    /// no advice is ever sent).
    #[default]
    Static,
    /// Flow-matrix-driven migration advice: assemblers charge every
    /// piece delivery to a per-(consumer, source-PE) flow account and
    /// report to the director every `piece_threshold` pieces; when a
    /// consumer's dominant source PE differs from where it runs (by at
    /// least 2× the bytes it receives locally), the director advises
    /// the consumer to migrate there (`EP_CONSUMER_ADVICE`, delivered
    /// through the AMT location manager so it follows prior moves).
    /// Hysteresis: a consumer is never advised toward a PE it already
    /// ran on or was already sent to, so it can never ping-pong; and at
    /// most `migration_budget` migrations are advised per session.
    FlowAware {
        /// Pieces delivered per consumer between flow reports (>= 1;
        /// also stamped on the session as its flow-account granularity).
        piece_threshold: u32,
        /// Hard cap on migrations advised for this session, across all
        /// of its consumers.
        migration_budget: u32,
    },
}

impl ConsumerPlacement {
    /// The assembler-side flow-report granularity: 0 = keep no flow
    /// accounts at all (`Static`).
    pub fn piece_threshold(&self) -> u32 {
        match self {
            ConsumerPlacement::Static => 0,
            ConsumerPlacement::FlowAware { piece_threshold, .. } => (*piece_threshold).max(1),
        }
    }
}

/// Structured configuration error, delivered through the `open` callback
/// (instead of a FileHandle) when a file's opening [`FileOptions`] can
/// never work — or through the `start_read_session` callback when a
/// [`SessionOptions::placement_override`] cannot cover the session's
/// readers. Callers discriminate with `payload.peek::<OpenError>()`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum OpenError {
    /// An explicit placement list is shorter than the largest reader
    /// count any session of this file could resolve to.
    PlacementTooShort { need: u32, got: u32 },
    /// `StoreAware` must fall back to a concrete placement, not to
    /// another `StoreAware`.
    RecursiveFallback,
    /// A re-open of an already-open (or opening) file asked for
    /// *different* [`FileOptions`]. The first opener's options govern
    /// the file while it stays open — but a divergent re-open is a
    /// conflict surfaced to the caller, never silently ignored (the
    /// pre-PR 5 footgun).
    OptionsConflict,
    /// [`WriteOptions::stripe_bytes`] of 0 — there is no coalescing
    /// grid, so no extent could ever form (PR 10).
    ZeroStripe,
}

impl std::fmt::Display for OpenError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            OpenError::PlacementTooShort { need, got } => {
                write!(f, "explicit reader placement needs >= {need} PEs, got {got}")
            }
            OpenError::RecursiveFallback => {
                write!(f, "StoreAware fallback must be a concrete placement")
            }
            OpenError::OptionsConflict => {
                write!(f, "file is already open with different FileOptions")
            }
            OpenError::ZeroStripe => {
                write!(f, "WriteOptions::stripe_bytes must be >= 1")
            }
        }
    }
}

/// Structured error for an invalid [`ServiceConfig`], returned by
/// `CkIo::boot_with` before any service state is created.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ConfigError {
    /// `max_inflight_reads: Some(0)` — demand could never drain. The
    /// pre-PR 5 governor silently clamped this to 1; it is now rejected
    /// where the configuration is made.
    ZeroCap,
    /// `data_plane_shards: Some(0)` — there is no shard to route to.
    ZeroShards,
    /// A [`RetryPolicy`] with `max_attempts: 0` — no read could ever be
    /// issued, so no session could ever complete.
    ZeroAttempts,
    /// A [`RetryPolicy`] on an ungoverned service: deadlines and retry
    /// tickets ride the shard admission path, which only exists when a
    /// static or adaptive cap is configured.
    RetryWithoutAdmission,
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::ZeroCap => {
                write!(f, "max_inflight_reads must be >= 1 (a zero cap can never drain)")
            }
            ConfigError::ZeroShards => write!(f, "data_plane_shards must be >= 1"),
            ConfigError::ZeroAttempts => {
                write!(f, "retry.max_attempts must be >= 1 (the first attempt counts)")
            }
            ConfigError::RetryWithoutAdmission => write!(
                f,
                "retry requires admission control (set max_inflight_reads or adaptive_admission)"
            ),
        }
    }
}

/// Reliability policy for admitted PFS reads (PR 8). When set on
/// [`ServiceConfig::retry`], every governed read carries a deadline; a
/// read that misses it (or completes with a transient error / short
/// read) releases its admission ticket, backs off exponentially with
/// deterministic jitter, and re-enters admission — up to `max_attempts`
/// total attempts, after which the span degrades gracefully (served as
/// a NACK, counted in `ckio.session.degraded_bytes`, reported through
/// the session's [`super::session::SessionOutcome`]). All fields are
/// plain integers so the policy is `Eq` and participates in config
/// comparison like every other scope.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total attempts per splinter, counting the first (>= 1).
    pub max_attempts: u32,
    /// Backoff before attempt n+1 is `base_backoff_ns << (n-1)`, clamped
    /// to `max_backoff_ns`, plus deterministic jitter in
    /// `[0, base_backoff_ns / 2)`.
    pub base_backoff_ns: u64,
    pub max_backoff_ns: u64,
    /// Deadline = `deadline_mult ×` the governor's best observed p50
    /// read service time (its AIMD window); before any observation the
    /// deadline is `default_deadline_ns`.
    pub deadline_mult: u32,
    pub default_deadline_ns: u64,
    /// Hedge instead of abandoning on the *first* timeout: keep the slow
    /// read running and race a duplicate through admission (charged
    /// against the same cap); first completion wins, the loser's ticket
    /// is returned on arrival.
    pub hedge: bool,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 4,
            base_backoff_ns: 500_000,      // 0.5 ms
            max_backoff_ns: 8_000_000,     // 8 ms
            deadline_mult: 8,
            default_deadline_ns: 200_000_000, // 200 ms before any observation
            hedge: false,
        }
    }
}

impl RetryPolicy {
    pub fn with_hedging(mut self) -> Self {
        self.hedge = true;
        self
    }
}

impl ReaderPlacement {
    /// Whether session start must run the plan-then-create round trip
    /// (`EP_SHARD_PLAN`) before materializing a placement.
    pub fn is_store_aware(&self) -> bool {
        matches!(self, ReaderPlacement::StoreAware { .. })
    }

    /// Validate this policy for a file whose sessions can resolve at
    /// most `need` readers ([`FileOptions::validate`] computes `need`
    /// from the file size, the worst case over every admissible
    /// session; a [`SessionOptions::placement_override`] is validated
    /// against the one session's resolved count).
    pub fn validate(&self, need: u32) -> Result<(), OpenError> {
        match self {
            ReaderPlacement::SpreadNodes | ReaderPlacement::PackPes => Ok(()),
            ReaderPlacement::Explicit(pes) => {
                if (pes.len() as u32) < need {
                    Err(OpenError::PlacementTooShort { need, got: pes.len() as u32 })
                } else {
                    Ok(())
                }
            }
            ReaderPlacement::StoreAware { fallback } => match fallback.as_ref() {
                ReaderPlacement::StoreAware { .. } => Err(OpenError::RecursiveFallback),
                concrete => concrete.validate(need),
            },
        }
    }

    /// Materialize a [`Placement`] for `n` *resolved* readers.
    ///
    /// `n` comes out of [`FileOptions::resolve_readers`], which may
    /// clamp the requested count down (never more readers than bytes) —
    /// so an explicit list only needs to be *at least* `n` long; extra
    /// entries are ignored. A list shorter than `n` is a configuration
    /// error, reported as a structured [`OpenError`] (the director runs
    /// [`FileOptions::validate`] at `open` and validates overrides at
    /// session start, so an admitted start can never see `Err` here).
    ///
    /// For [`ReaderPlacement::StoreAware`] this returns the *fallback*
    /// placement — the no-residency answer; the director overrides
    /// per-buffer PEs with the shard's `PlacementPlan` when one exists.
    pub fn to_placement(&self, n: u32) -> Result<Placement, OpenError> {
        match self {
            ReaderPlacement::SpreadNodes => Ok(Placement::RoundRobinNodes),
            ReaderPlacement::PackPes => Ok(Placement::RoundRobinPes),
            ReaderPlacement::Explicit(pes) => {
                if (pes.len() as u32) < n {
                    return Err(OpenError::PlacementTooShort { need: n, got: pes.len() as u32 });
                }
                Ok(Placement::Explicit(
                    pes.iter().take(n as usize).map(|&p| crate::amt::topology::Pe(p)).collect(),
                ))
            }
            ReaderPlacement::StoreAware { fallback } => fallback.to_placement(n),
        }
    }
}

/// Service-wide configuration, passed **once** to `CkIo::boot_with`
/// (`CkIo::boot` uses the default). This is the state every file and
/// session of the instance shares; configuring it at boot — instead of
/// smuggling it through whichever file happened to `open` first — kills
/// the "last writer wins" / "first opener governs" footguns the old
/// `Options` documented, and lets the shard count be genuinely
/// structural (no idle-barrier re-sharding).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ServiceConfig {
    /// Byte budget of the span store for *parked* arrays (PR 2), split
    /// evenly across the active shards. `None` keeps the default of at
    /// most [`super::store::SpanStore::DEFAULT_MAX_ARRAYS`] parked
    /// arrays per shard; `Some(bytes)` switches to byte-budgeted LRU
    /// eviction.
    pub store_budget_bytes: Option<u64>,
    /// Number of data-plane shards the `FileId` hash routes over
    /// (PR 3). `None` = one shard per PE (the full array booted by
    /// `CkIo::boot_with`); `Some(n)` clamps the hash to the first `n`
    /// shards (and is itself clamped to the PE count). `Some(1)`
    /// funnels everything through one shard — bit-for-bit the PR 2
    /// single-plane semantics (global store budget, global cap).
    /// `Some(0)` is rejected by [`ServiceConfig::validate`].
    pub data_plane_shards: Option<u32>,
    /// Admission governor: static cap on PFS reads in flight **per
    /// shard**, across all sessions (PR 2). `None` = ungoverned (buffer
    /// chares issue reads directly) unless
    /// [`ServiceConfig::adaptive_admission`] derives a cap. `Some(0)`
    /// is rejected by [`ServiceConfig::validate`] — the pre-PR 5
    /// governor silently clamped it to 1.
    pub max_inflight_reads: Option<u32>,
    /// Order in which the governor admits queued prefetch demand —
    /// weighted-fair across [`QosClass`]es (or strict priority); see
    /// [`AdmissionPolicy`].
    pub admission: AdmissionPolicy,
    /// Governor feedback control (PR 3): when `max_inflight_reads` is
    /// `None`, govern anyway and *derive* the per-shard cap from
    /// observed read service times (AIMD). Ignored when a static cap is
    /// set. The `ckio.governor.cap` gauge tracks the adapted value.
    pub adaptive_admission: bool,
    /// Flight recorder (PR 7): structured event tracing into a bounded,
    /// virtual-clock-stamped per-PE ring, exportable as a Chrome
    /// trace-event timeline (`ckio trace <fig>`). Off by default; when
    /// disabled the hot path is a single branch and no event is ever
    /// allocated. See [`TraceConfig`].
    pub trace: TraceConfig,
    /// Reliability policy (PR 8): deadlines, retry with backoff, and
    /// optional hedging for admitted PFS reads. `None` (the default)
    /// keeps the pre-PR 8 behavior bit-for-bit: no timers are armed and
    /// a faulted read degrades immediately instead of retrying.
    /// Requires admission control ([`ServiceConfig::governed`]).
    pub retry: Option<RetryPolicy>,
}

impl ServiceConfig {
    /// Whether admission control (static or adaptive) is on: every
    /// session's PFS issuance then runs the shard ticket protocol.
    pub fn governed(&self) -> bool {
        self.max_inflight_reads.is_some() || self.adaptive_admission
    }

    /// Validate the configuration before it can boot a service. Run by
    /// `CkIo::boot_with`; rejecting here (instead of clamping deep in
    /// the governor) is what makes a nonsense knob a visible error at
    /// the call that set it.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.max_inflight_reads == Some(0) {
            return Err(ConfigError::ZeroCap);
        }
        if self.data_plane_shards == Some(0) {
            return Err(ConfigError::ZeroShards);
        }
        if let Some(r) = &self.retry {
            if r.max_attempts == 0 {
                return Err(ConfigError::ZeroAttempts);
            }
            if !self.governed() {
                return Err(ConfigError::RetryWithoutAdmission);
            }
        }
        Ok(())
    }

    /// The active shard count on a cluster of `npes` PEs.
    pub fn resolve_shards(&self, npes: u32) -> u32 {
        self.data_plane_shards.unwrap_or(npes).clamp(1, npes.max(1))
    }

    /// The per-shard share of the store budget over `active` shards.
    pub fn budget_share(&self, active: u32) -> Option<u64> {
        self.store_budget_bytes.map(|b| ceil_div(b, active.max(1) as u64))
    }
}

/// Per-file policy, passed to `CkIo::open` (paper §III-D). What remains
/// of the old `Options` once service state and session intent moved to
/// their own scopes: how a file's sessions decompose into readers, and
/// where those readers go.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FileOptions {
    /// Number of buffer chares per session (`Options::numReaders`).
    /// `None` selects automatically from file size and cluster shape
    /// (paper §VI.A).
    pub num_readers: Option<u32>,
    /// Buffer chare placement policy (a session may override it via
    /// [`SessionOptions::placement_override`]).
    pub placement: ReaderPlacement,
}

impl FileOptions {
    pub fn with_readers(n: u32) -> FileOptions {
        FileOptions { num_readers: Some(n), ..Default::default() }
    }

    /// Resolve the reader count for a session of `bytes` on `topo`
    /// (§VI.A's automatic policy when `num_readers` is `None`).
    pub fn resolve_readers(&self, bytes: u64, topo: &Topology) -> u32 {
        let n = self.num_readers.unwrap_or_else(|| auto_readers(bytes, topo));
        // Never more readers than bytes.
        n.clamp(1, bytes.max(1).min(u32::MAX as u64) as u32)
    }

    /// Validate these options for a file of `file_size` bytes: the check
    /// the director runs at `open`, before the options can govern the
    /// file. `resolve_readers` is monotonic in the session byte count,
    /// so the largest reader count any session `[off, off+b)` with
    /// `b <= file_size` can resolve to is `resolve_readers(file_size)` —
    /// an explicit placement list admitted here can never come up short
    /// at a later session start (it is only ever truncated).
    pub fn validate(&self, file_size: u64, topo: &Topology) -> Result<(), OpenError> {
        let need = self.resolve_readers(file_size.max(1), topo);
        self.placement.validate(need)
    }
}

/// Per-session intent, passed to `CkIo::start_read_session` (PR 5).
/// This is what the old API could not express at all: *who* a session
/// is ([`QosClass`]) and how it wants its bytes staged. The `Default`
/// reproduces the pre-redesign behavior byte-for-byte (Bulk class, no
/// splintering, window 2, no reuse, the file's placement).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SessionOptions {
    /// QoS class: rides the session-start probe to the owning data-plane
    /// shard (so the admission class is negotiated before any buffer
    /// exists) and every admission ticket the session's buffers request.
    /// Under a saturated cap the governor dequeues by class weight.
    pub class: QosClass,
    /// Splintered I/O (paper §VI.C): buffer chares read their span in
    /// sub-chunks of this size, so early reads can be served before the
    /// whole span arrives. `None` = one read per span (base design).
    pub splinter_bytes: Option<u64>,
    /// Splinters kept in flight per buffer chare when splintering.
    pub read_window: u32,
    /// Buffer-chare reuse across sessions (PR 1): when set, closing this
    /// session *parks* its buffer-chare array (keeping resident data)
    /// instead of dropping it, and a later `startReadSession` over the
    /// same `(file, range, shape)` revives it — repeated sessions on the
    /// same file skip the greedy re-read entirely.
    pub reuse_buffers: bool,
    /// Override the file's [`FileOptions::placement`] for this session
    /// only (e.g. one Interactive session packing its readers next to
    /// its consumers while the file default spreads). Validated at
    /// session start against the session's resolved reader count; an
    /// impossible override fails the `ready` callback with a structured
    /// [`OpenError`]. The effective placement is part of the
    /// parked-array rebind key: with
    /// [`SessionOptions::reuse_buffers`] also set, an override only
    /// rebinds an array parked under the *same* override — a parked
    /// array sits wherever its creating session put it, so rebinding
    /// across placements would silently mis-place the session. A miss
    /// creates the array fresh (still peer-fetching resident claims).
    pub placement_override: Option<ReaderPlacement>,
    /// Consumer-side locality (PR 9): when [`ConsumerPlacement::FlowAware`],
    /// assemblers keep per-(consumer, source-PE) flow accounts for this
    /// session and the director advises consumers to migrate toward their
    /// dominant source PE (within the option's budget and hysteresis).
    pub consumer_placement: ConsumerPlacement,
}

impl SessionOptions {
    fn with_class(class: QosClass) -> SessionOptions {
        SessionOptions { class, ..Default::default() }
    }

    /// Latency-sensitive foreground session (weight 8).
    pub fn interactive() -> SessionOptions {
        Self::with_class(QosClass::Interactive)
    }

    /// Ordinary throughput session (weight 2) — same as `default()`.
    pub fn bulk() -> SessionOptions {
        Self::with_class(QosClass::Bulk)
    }

    /// Background/best-effort session (weight 1, never starved under
    /// the weighted policies).
    pub fn scavenger() -> SessionOptions {
        Self::with_class(QosClass::Scavenger)
    }
}

impl Default for SessionOptions {
    fn default() -> Self {
        SessionOptions {
            class: QosClass::default(),
            splinter_bytes: None,
            read_window: 2,
            reuse_buffers: false,
            placement_override: None,
            consumer_placement: ConsumerPlacement::Static,
        }
    }
}

/// Per-write-session intent, passed to `CkIo::start_write_session`
/// (PR 10) alongside the shared [`SessionOptions`]. The output plane's
/// own knobs: the coalescing grid and the durability mode of close.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WriteOptions {
    /// Stripe size of the coalescing grid: a write buffer accumulates
    /// producer pieces and flushes stripe-aligned extents of this size
    /// (clamped at its span edges), so one PFS write RPC carries one
    /// full stripe instead of one splinter. Should match the file's PFS
    /// stripe size; must be >= 1.
    pub stripe_bytes: u64,
    /// Write-behind: flush an extent as soon as producer pieces fully
    /// cover it, overlapping PFS writes with ongoing production. When
    /// off, dirty extents accumulate until an explicit `flush` or close.
    pub write_behind: bool,
    /// Lazy durability (PR 10's dirty-residency mode): close parks the
    /// write buffers with their claims still *dirty* instead of
    /// draining them — read-after-write is served from residency at
    /// once, the [`super::session::SessionOutcome`] reports the parked
    /// bytes as `dirty_bytes`, and the PFS write happens only when the
    /// store evicts or purges the array (a forced writeback). Off by
    /// default: close is a full drain barrier.
    pub park_dirty: bool,
}

impl Default for WriteOptions {
    fn default() -> Self {
        WriteOptions { stripe_bytes: 1 << 20, write_behind: true, park_dirty: false }
    }
}

impl WriteOptions {
    /// The lazy-durability preset: no write-behind, park dirty at close.
    /// Data reaches the PFS only under store pressure (or purge) — the
    /// mode that makes dirty evictions and forced writebacks reachable.
    pub fn lazy() -> WriteOptions {
        WriteOptions { write_behind: false, park_dirty: true, ..Default::default() }
    }

    /// Validate before a write session can start (the director runs
    /// this on `start_write_session`, failing the ready callback with a
    /// structured [`OpenError`] instead of panicking mid-plane).
    pub fn validate(&self) -> Result<(), OpenError> {
        if self.stripe_bytes == 0 {
            return Err(OpenError::ZeroStripe);
        }
        Ok(())
    }
}

/// Automatic reader-count policy (paper §VI.A, future work — implemented
/// here as a tunable heuristic and evaluated in `ablation_autoreaders`):
///
/// * target span per reader ≈ 8 MiB (a few RPCs per stream: enough to
///   amortize per-stream overheads while maximizing concurrent OST
///   streams — the sweep in `ablation_autoreaders` sits there),
/// * at least 2 readers per node (a single stream can't fill a NIC),
/// * at most one reader per PE (past that, streams interleave at the
///   OSTs and per-RPC overheads dominate — the Fig. 1 collapse).
pub fn auto_readers(bytes: u64, topo: &Topology) -> u32 {
    const TARGET_SPAN: u64 = 8 << 20;
    let by_span = ceil_div(bytes, TARGET_SPAN);
    let lo = (2 * topo.nodes) as u64;
    let hi = topo.npes() as u64;
    by_span.clamp(lo.min(hi), hi).max(1) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_policy_scales_with_file_and_nodes() {
        let t16 = Topology::new(16, 32);
        // Tiny file: floor of 2 readers/node.
        assert_eq!(auto_readers(1 << 20, &t16), 32);
        // Huge file: ceiling of one reader per PE.
        assert_eq!(auto_readers(64 << 30, &t16), 512);
        // Mid-size: span-driven (1 GiB / 8 MiB = 128 readers).
        assert_eq!(auto_readers(1 << 30, &t16), 128);
    }

    #[test]
    fn resolve_respects_explicit_count() {
        let topo = Topology::new(2, 4);
        let o = FileOptions::with_readers(6);
        assert_eq!(o.resolve_readers(1 << 30, &topo), 6);
    }

    #[test]
    fn resolve_clamps_to_bytes() {
        let topo = Topology::new(2, 4);
        let o = FileOptions::with_readers(64);
        assert_eq!(o.resolve_readers(10, &topo), 10);
    }

    #[test]
    fn placement_mapping() {
        let p = ReaderPlacement::SpreadNodes.to_placement(8).unwrap();
        assert!(matches!(p, Placement::RoundRobinNodes));
        let p = ReaderPlacement::Explicit(vec![0, 3]).to_placement(2).unwrap();
        assert!(matches!(p, Placement::Explicit(_)));
    }

    /// Regression (PR 4): a too-short explicit list is a structured
    /// error, not a panic — the director surfaces it through the open
    /// callback.
    #[test]
    fn explicit_placement_wrong_length_is_an_error() {
        assert_eq!(
            ReaderPlacement::Explicit(vec![0]).to_placement(2).unwrap_err(),
            OpenError::PlacementTooShort { need: 2, got: 1 }
        );
    }

    /// Regression (PR 1): a tiny file clamps the resolved reader count
    /// below the explicit PE-list length; placement must truncate the
    /// list to the clamped count instead of erroring.
    #[test]
    fn explicit_placement_truncates_to_clamped_readers() {
        use crate::amt::topology::Pe;
        let topo = Topology::new(2, 4);
        let o = FileOptions {
            num_readers: Some(4),
            placement: ReaderPlacement::Explicit(vec![0, 1, 2, 3]),
        };
        // 2-byte file: never more readers than bytes.
        let n = o.resolve_readers(2, &topo);
        assert_eq!(n, 2);
        match o.placement.to_placement(n).unwrap() {
            Placement::Explicit(pes) => assert_eq!(pes, vec![Pe(0), Pe(1)]),
            other => panic!("unexpected placement {other:?}"),
        }
    }

    #[test]
    fn store_aware_resolves_and_validates_through_its_fallback() {
        let sa = ReaderPlacement::StoreAware { fallback: Box::new(ReaderPlacement::SpreadNodes) };
        assert!(sa.is_store_aware());
        assert!(matches!(sa.to_placement(4), Ok(Placement::RoundRobinNodes)));
        assert_eq!(sa.validate(8), Ok(()));

        let short = ReaderPlacement::StoreAware {
            fallback: Box::new(ReaderPlacement::Explicit(vec![0, 1])),
        };
        assert_eq!(short.validate(4), Err(OpenError::PlacementTooShort { need: 4, got: 2 }));

        let nested = ReaderPlacement::StoreAware {
            fallback: Box::new(ReaderPlacement::StoreAware {
                fallback: Box::new(ReaderPlacement::SpreadNodes),
            }),
        };
        assert_eq!(nested.validate(4), Err(OpenError::RecursiveFallback));
    }

    /// `FileOptions::validate` checks the worst case over every
    /// admissible session: the whole-file reader count.
    #[test]
    fn validate_checks_the_largest_resolvable_reader_count() {
        let topo = Topology::new(2, 4);
        let o = FileOptions {
            num_readers: Some(4),
            placement: ReaderPlacement::Explicit(vec![0, 1]),
        };
        // A large file can resolve all 4 readers: the 2-entry list fails.
        assert_eq!(
            o.validate(1 << 20, &topo),
            Err(OpenError::PlacementTooShort { need: 4, got: 2 })
        );
        // A 2-byte file clamps every session to <= 2 readers: it passes.
        assert_eq!(o.validate(2, &topo), Ok(()));
    }

    /// The PR 5 satellite: a zero static cap is rejected where the
    /// configuration is made, with a structured error — not silently
    /// clamped to 1 deep inside the governor.
    #[test]
    fn service_config_rejects_zero_cap_and_zero_shards() {
        let ok = ServiceConfig::default();
        assert_eq!(ok.validate(), Ok(()));
        assert!(!ok.governed());

        let zero_cap = ServiceConfig { max_inflight_reads: Some(0), ..Default::default() };
        assert_eq!(zero_cap.validate(), Err(ConfigError::ZeroCap));

        let zero_shards = ServiceConfig { data_plane_shards: Some(0), ..Default::default() };
        assert_eq!(zero_shards.validate(), Err(ConfigError::ZeroShards));

        let governed = ServiceConfig { max_inflight_reads: Some(1), ..Default::default() };
        assert_eq!(governed.validate(), Ok(()));
        assert!(governed.governed());
        let adaptive = ServiceConfig { adaptive_admission: true, ..Default::default() };
        assert!(adaptive.governed());
    }

    /// PR 8: retry policies are validated where the configuration is
    /// made — zero attempts and retry-without-admission are structured
    /// errors, not latent hangs.
    #[test]
    fn service_config_validates_retry_policy() {
        let ok = ServiceConfig {
            max_inflight_reads: Some(4),
            retry: Some(RetryPolicy::default()),
            ..Default::default()
        };
        assert_eq!(ok.validate(), Ok(()));

        let zero = ServiceConfig {
            max_inflight_reads: Some(4),
            retry: Some(RetryPolicy { max_attempts: 0, ..Default::default() }),
            ..Default::default()
        };
        assert_eq!(zero.validate(), Err(ConfigError::ZeroAttempts));

        let ungoverned =
            ServiceConfig { retry: Some(RetryPolicy::default()), ..Default::default() };
        assert_eq!(ungoverned.validate(), Err(ConfigError::RetryWithoutAdmission));

        let adaptive = ServiceConfig {
            adaptive_admission: true,
            retry: Some(RetryPolicy::default().with_hedging()),
            ..Default::default()
        };
        assert_eq!(adaptive.validate(), Ok(()));
        assert!(adaptive.retry.unwrap().hedge);
    }

    #[test]
    fn service_config_resolves_shards_and_budget_shares() {
        let cfg = ServiceConfig::default();
        assert_eq!(cfg.resolve_shards(8), 8, "default is one shard per PE");
        let pinned = ServiceConfig { data_plane_shards: Some(1), ..Default::default() };
        assert_eq!(pinned.resolve_shards(8), 1);
        let over = ServiceConfig { data_plane_shards: Some(64), ..Default::default() };
        assert_eq!(over.resolve_shards(8), 8, "shard count clamps to the PE count");
        let budget =
            ServiceConfig { store_budget_bytes: Some(100), ..Default::default() };
        assert_eq!(budget.budget_share(4), Some(25));
        assert_eq!(budget.budget_share(3), Some(34), "shares round up");
        assert_eq!(cfg.budget_share(4), None);
    }

    /// The tentpole's compatibility contract: `SessionOptions::default()`
    /// is exactly the pre-redesign behavior — Bulk class, no
    /// splintering, window 2, no reuse, the file's own placement.
    #[test]
    fn session_options_default_matches_pre_redesign_behavior() {
        let d = SessionOptions::default();
        assert_eq!(d.class, QosClass::Bulk);
        assert_eq!(d.splinter_bytes, None);
        assert_eq!(d.read_window, 2);
        assert!(!d.reuse_buffers);
        assert_eq!(d.placement_override, None);
        assert_eq!(d.consumer_placement, ConsumerPlacement::Static);
        assert_eq!(d.consumer_placement.piece_threshold(), 0);
        assert_eq!(
            ConsumerPlacement::FlowAware { piece_threshold: 0, migration_budget: 1 }
                .piece_threshold(),
            1
        );
        assert_eq!(d, SessionOptions::bulk());
        assert_eq!(SessionOptions::interactive().class, QosClass::Interactive);
        assert_eq!(SessionOptions::scavenger().class, QosClass::Scavenger);
    }

    /// PR 10: write options validate their coalescing grid, and the
    /// lazy preset is the (no write-behind, park-dirty) corner.
    #[test]
    fn write_options_validate_and_preset() {
        let d = WriteOptions::default();
        assert_eq!(d.stripe_bytes, 1 << 20);
        assert!(d.write_behind);
        assert!(!d.park_dirty);
        assert_eq!(d.validate(), Ok(()));

        let lazy = WriteOptions::lazy();
        assert!(!lazy.write_behind);
        assert!(lazy.park_dirty);
        assert_eq!(lazy.stripe_bytes, d.stripe_bytes);

        let zero = WriteOptions { stripe_bytes: 0, ..Default::default() };
        assert_eq!(zero.validate(), Err(OpenError::ZeroStripe));
    }
}
