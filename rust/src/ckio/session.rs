//! Session and read-descriptor types.

use crate::amt::chare::CollectionId;
use crate::pfs::layout::FileId;
use crate::util::bytes::{ceil_div, Chunk};

use super::options::FileOptions;

/// Identifies a read session.
#[derive(Copy, Clone, Default, Eq, PartialEq, Ord, PartialOrd, Hash, Debug)]
pub struct SessionId(pub u32);

/// Zero-copy transfer tag: the wire identity of one client read.
///
/// Tags are *namespaced by session* so concurrent sessions can never
/// collide in the assemblers' tables, and a late piece can be attributed
/// to its (possibly already closed) session. Within a session, `local`
/// is a PE-salted counter (the assigning manager's PE in the high bits),
/// so managers on different PEs never collide either.
#[derive(Copy, Clone, Eq, PartialEq, Ord, PartialOrd, Hash, Debug)]
pub struct Tag {
    pub session: SessionId,
    pub local: u64,
}

/// Bounded record of torn-down sessions. Managers and assemblers keep
/// one per PE to recognize reads/pieces that race a session's teardown;
/// in a long-running service the naive "insert every closed id forever"
/// set would grow without bound. Session ids are assigned monotonically
/// by the director, so when the set exceeds its cap it is compacted to
/// the most recent half and everything below the resulting watermark is
/// treated as closed — sound, because a *live* session is always found
/// in the session table first (the closed-set is only consulted on a
/// table miss), and new ids are always above any compaction watermark.
#[derive(Debug, Default)]
pub struct ClosedSessions {
    ids: std::collections::HashSet<SessionId>,
    watermark: u32,
}

impl ClosedSessions {
    const CAP: usize = 4096;

    pub fn insert(&mut self, sid: SessionId) {
        self.ids.insert(sid);
        if self.ids.len() > Self::CAP {
            let max = self.ids.iter().map(|s| s.0).max().unwrap_or(0);
            let watermark = max.saturating_sub((Self::CAP / 2) as u32);
            self.ids.retain(|s| s.0 >= watermark);
            self.watermark = self.watermark.max(watermark);
        }
    }

    pub fn contains(&self, sid: &SessionId) -> bool {
        sid.0 < self.watermark || self.ids.contains(sid)
    }
}

/// Returned by `Ck::IO::open`'s callback.
#[derive(Clone, Debug)]
pub struct FileHandle {
    pub file: FileId,
    pub size: u64,
    /// The [`FileOptions`] in effect for this file (the first opener's).
    pub opts: FileOptions,
}

/// Returned by `Ck::IO::startReadSession`'s callback; everything a client
/// (or assembler) needs to route reads. Cheap to copy into messages.
#[derive(Copy, Clone, Debug)]
pub struct Session {
    pub id: SessionId,
    pub file: FileId,
    /// First byte of the session within the file.
    pub offset: u64,
    /// Session length in bytes.
    pub bytes: u64,
    /// The buffer-chare array serving this session.
    pub buffers: CollectionId,
    pub num_buffers: u32,
    /// Bytes per buffer chare (last one may be shorter).
    pub span: u64,
    /// Consumer-flow accounting granularity (PR 9): pieces delivered per
    /// consumer between assembler flow reports to the director. 0 (the
    /// default) means the session runs [`ConsumerPlacement::Static`] and
    /// assemblers keep no flow accounts at all.
    ///
    /// [`ConsumerPlacement::Static`]: super::options::ConsumerPlacement::Static
    pub flow_threshold: u32,
}

impl Session {
    pub fn new(
        id: SessionId,
        file: FileId,
        offset: u64,
        bytes: u64,
        buffers: CollectionId,
        num_buffers: u32,
    ) -> Session {
        assert!(bytes > 0 && num_buffers > 0);
        let span = ceil_div(bytes, num_buffers as u64);
        Session { id, file, offset, bytes, buffers, num_buffers, span, flow_threshold: 0 }
    }

    /// Stamp the consumer-flow granularity (director, at session start,
    /// from [`ConsumerPlacement::piece_threshold`]).
    ///
    /// [`ConsumerPlacement::piece_threshold`]:
    ///     super::options::ConsumerPlacement::piece_threshold
    pub fn with_flow(mut self, piece_threshold: u32) -> Session {
        self.flow_threshold = piece_threshold;
        self
    }

    /// End byte (exclusive) of the session.
    pub fn end(&self) -> u64 {
        self.offset + self.bytes
    }

    /// File-coordinate span `[offset, len)` owned by buffer `b`.
    /// Trailing buffers of a session whose byte count is not divisible by
    /// the buffer count may own zero bytes; their span is clamped to the
    /// session end so spans always partition `[offset, end)` exactly.
    /// Exactly these spans are registered as span-store claims at the
    /// file's data-plane shard (PR 2, sharded in PR 3 — each buffer
    /// registers its own), so assembler routing and peer-fetch sourcing
    /// agree.
    pub fn buffer_span(&self, b: u32) -> (u64, u64) {
        assert!(b < self.num_buffers);
        buffer_span_of(self.offset, self.bytes, self.num_buffers, b)
    }

    /// Which buffer owns the byte at file offset `o`.
    pub fn buffer_of(&self, o: u64) -> u32 {
        assert!(o >= self.offset && o < self.end(), "offset {o} outside session");
        ((o - self.offset) / self.span) as u32
    }

    /// The (inclusive) range of buffers overlapping `[offset, offset+len)`.
    pub fn buffers_for(&self, offset: u64, len: u64) -> std::ops::RangeInclusive<u32> {
        assert!(len > 0);
        assert!(
            offset >= self.offset && offset + len <= self.end(),
            "read [{offset}, {}) outside session [{}, {})",
            offset + len,
            self.offset,
            self.end()
        );
        self.buffer_of(offset)..=self.buffer_of(offset + len - 1)
    }
}

/// File-coordinate span of buffer `b` for a session of `bytes` at
/// `offset` split across `num_buffers` buffer chares — the single
/// definition of the span partition. [`Session::buffer_span`] (assembler
/// routing) and the director's chare creation + span-store claim
/// registration all call this, so the three can never drift.
pub fn buffer_span_of(offset: u64, bytes: u64, num_buffers: u32, b: u32) -> (u64, u64) {
    let span = ceil_div(bytes, num_buffers as u64);
    let end = offset + bytes;
    let lo = (offset + b as u64 * span).min(end);
    let hi = (lo + span).min(end);
    (lo, hi - lo)
}

/// Delivered to the client's `closeReadSession` callback (PR 8): the
/// session's structured service report. PR 1–7 completed a close with an
/// empty signal, which made a session served entirely from NACK-degraded
/// assemblies indistinguishable from a clean one. Under fault injection
/// that distinction is the whole point: the outcome says how many bytes
/// were served with real data, how many degraded to modeled chunks
/// (NACKs and gave-up retry spans), and how hard the reliability plane
/// had to work (retries, hedges, give-ups) to get there.
///
/// Aggregated by the director from the per-buffer counters riding each
/// teardown ack ([`super::buffer::BufDroppedMsg`]); idempotent re-closes
/// deliver an all-zero outcome (the first close carried the real one).
#[derive(Copy, Clone, Debug, Default)]
pub struct SessionOutcome {
    pub session: SessionId,
    /// Bytes of client reads answered with data-bearing pieces.
    pub served_bytes: u64,
    /// Bytes of client reads answered with modeled (NACK / gave-up)
    /// pieces — the assembly completed, but carried no verified data.
    pub degraded_bytes: u64,
    /// PFS read re-issues (attempts beyond each extent's first).
    pub retries: u64,
    /// Hedged duplicate reads issued past their deadline.
    pub hedges: u64,
    /// Splinter slots abandoned after the retry budget was exhausted.
    pub gave_up_spans: u64,
    /// Bytes durably written to the PFS by this session (PR 10, write
    /// sessions only — always 0 for read sessions).
    pub written_bytes: u64,
    /// Bytes accepted but *not yet* durable when close completed (PR 10):
    /// nonzero only for `park_dirty` write sessions, whose data stays
    /// dirty-resident until a forced writeback. Every other close is a
    /// drain barrier, so this is 0.
    pub dirty_bytes: u64,
}

impl SessionOutcome {
    /// Fully served, nothing degraded, no give-ups (retries/hedges may
    /// have happened along the way — they are effort, not failure), and
    /// nothing left dirty (PR 10: a clean write session drained fully).
    pub fn is_clean(&self) -> bool {
        self.degraded_bytes == 0 && self.gave_up_spans == 0 && self.dirty_bytes == 0
    }
}

/// Well-known consumer EP for director migration advice (PR 9): a
/// session opting into [`ConsumerPlacement::FlowAware`] agrees that its
/// consumer chares handle this EP (payload [`ConsumerAdviceMsg`]) —
/// normally by calling `Ctx::migrate_me` toward the advised PE. Numbered
/// in the harness client range so it can never collide with the CkIO
/// service EPs consumers already receive callbacks on.
///
/// [`ConsumerPlacement::FlowAware`]: super::options::ConsumerPlacement::FlowAware
pub const EP_CONSUMER_ADVICE: crate::amt::msg::Ep = 39;

/// Assembler → director consumer-flow delta (PR 9, FlowAware sessions
/// only): bytes delivered to one consumer, charged per *source buffer
/// PE*, since the last report. Deltas, not totals — the director owns
/// the accumulated matrix, so assembler state stays bounded and dies
/// with the session drop.
#[derive(Clone, Debug)]
pub struct FlowReportMsg {
    pub session: SessionId,
    /// The consumer chare these bytes were assembled for.
    pub consumer: crate::amt::chare::ChareRef,
    /// PE the consumer's reads were assembled on (= the PE it ran on:
    /// managers route reads to their own PE's assembler).
    pub consumer_pe: u32,
    /// (source buffer PE, bytes delivered from it) since the last report.
    pub by_pe: Vec<(u32, u64)>,
}

/// Director → consumer migration advice (PR 9): the flow matrix says
/// `to_pe` is this consumer's dominant piece source. Advice, not an
/// order — a consumer that cannot migrate (or already moved) may ignore
/// it; hysteresis on the director guarantees it is never re-advised to
/// a PE it already ran on.
#[derive(Copy, Clone, Debug)]
pub struct ConsumerAdviceMsg {
    pub session: SessionId,
    /// Dominant source PE to move toward.
    pub to_pe: u32,
}

/// Delivered to the client's `after_read` callback.
#[derive(Debug)]
pub struct ReadResult {
    pub session: SessionId,
    pub offset: u64,
    pub len: u64,
    /// The assembled data (materialized in verified runs).
    pub chunk: Chunk,
    /// The zero-copy tag that carried this read (diagnostics).
    pub tag: Tag,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sess() -> Session {
        // 100 bytes at offset 1000, 4 buffers → span 25.
        Session::new(SessionId(0), FileId(0), 1000, 100, CollectionId(5), 4)
    }

    #[test]
    fn spans_partition_session() {
        let s = sess();
        let mut pos = 1000;
        for b in 0..4 {
            let (o, l) = s.buffer_span(b);
            assert_eq!(o, pos);
            pos = o + l;
        }
        assert_eq!(pos, 1100);
    }

    #[test]
    fn uneven_last_span() {
        let s = Session::new(SessionId(0), FileId(0), 0, 10, CollectionId(0), 4);
        assert_eq!(s.span, 3);
        assert_eq!(s.buffer_span(0), (0, 3));
        assert_eq!(s.buffer_span(3), (9, 1));
    }

    #[test]
    fn buffer_of_boundaries() {
        let s = sess();
        assert_eq!(s.buffer_of(1000), 0);
        assert_eq!(s.buffer_of(1024), 0);
        assert_eq!(s.buffer_of(1025), 1);
        assert_eq!(s.buffer_of(1099), 3);
    }

    #[test]
    fn buffers_for_spanning_read() {
        let s = sess();
        assert_eq!(s.buffers_for(1000, 25), 0..=0);
        assert_eq!(s.buffers_for(1020, 10), 0..=1);
        assert_eq!(s.buffers_for(1000, 100), 0..=3);
    }

    #[test]
    #[should_panic(expected = "outside session")]
    fn read_outside_session_panics() {
        sess().buffers_for(900, 10);
    }

    #[test]
    fn flow_threshold_defaults_off_and_stamps() {
        let s = sess();
        assert_eq!(s.flow_threshold, 0, "Session::new must default to Static (no accounts)");
        let s = s.with_flow(8);
        assert_eq!(s.flow_threshold, 8);
        // Copy semantics: the stamped session travels whole.
        let t = s;
        assert_eq!(t.flow_threshold, 8);
    }

    #[test]
    fn closed_sessions_stay_bounded_and_sound() {
        let mut c = ClosedSessions::default();
        for i in 0..20_000u32 {
            c.insert(SessionId(i));
        }
        // Bounded: compaction kept the set at or below its cap.
        assert!(c.ids.len() <= 4096, "set grew to {}", c.ids.len());
        // Sound: every id ever closed still reads as closed (recent ones
        // from the set, ancient ones from the watermark).
        assert!(c.contains(&SessionId(0)));
        assert!(c.contains(&SessionId(10_000)));
        assert!(c.contains(&SessionId(19_999)));
        // Ids never closed and above the watermark are not closed.
        assert!(!c.contains(&SessionId(25_000)));
    }
}
