//! Manager group (paper §III-C.2).
//!
//! One manager per PE — the local API entry point (clients reach their
//! PE's manager without crossing the wire, like a Charm++ group pointer
//! access). Managers keep the session table, assign the zero-copy tags
//! used for buffer→assembler transfers, and forward each read to the
//! local ReadAssembler. Reads that race ahead of the session announcement
//! are held until it arrives.
//!
//! Concurrency (PR 1): tags are namespaced per session ([`Tag`]), so any
//! number of sessions can be in flight on a PE without colliding in the
//! assembler's tables. The manager also remembers *closed* sessions:
//! a read that races a `closeReadSession` (arriving after the session
//! entry was dropped) is answered immediately with a modeled NACK chunk
//! instead of being stranded in the early-read queue forever.
//!
//! Managers are deliberately unaware of the PR 2 resident-data plane
//! *and* of its PR 3 sharding: where a buffer chare got its bytes (PFS
//! read, peer fetch, or a parked array) and which data-plane shard
//! coordinated that is invisible to the read path — a read routes to the
//! session's buffer chares exactly as before, which is what lets the
//! span store, the admission governor, and now the shard map evolve
//! without touching the client ABI.

use std::collections::HashMap;

use crate::amt::callback::Callback;
use crate::amt::chare::{Chare, ChareRef, CollectionId};
use crate::amt::engine::Ctx;
use crate::amt::msg::{Ep, Msg, Payload};
use crate::amt::protocol::{PayloadKind, ProtocolSpec};
use crate::impl_chare_any;
use crate::metrics::keys;
use crate::pfs::layout::FileId;
use crate::util::bytes::Chunk;
use crate::{ep_spec, send_spec};

use super::assembler::{AssembleReq, EP_A_REQ};
use super::options::FileOptions;
use super::session::{ClosedSessions, ReadResult, Session, SessionId, Tag};

/// Client read (local API call).
pub const EP_M_READ: Ep = 1;
/// Director: a file is now open everywhere.
pub const EP_M_FILE_OPENED: Ep = 2;
/// Director: a session has started.
pub const EP_M_SESSION_ANNOUNCE: Ep = 3;
/// Director: tear down a session.
pub const EP_M_SESSION_DROP: Ep = 4;
/// Director: close a file.
pub const EP_M_FILE_CLOSE: Ep = 5;

/// A client read request.
#[derive(Debug)]
pub struct ReadMsg {
    pub session: SessionId,
    pub offset: u64,
    pub len: u64,
    pub after: Callback,
}

#[derive(Debug)]
pub struct FileOpenedMsg {
    pub file: FileId,
    pub opts: FileOptions,
}

#[derive(Debug)]
pub struct SessionAnnounceMsg {
    pub session: Session,
}

/// One manager (group element).
pub struct Manager {
    pub director: ChareRef,
    pub assemblers: CollectionId,
    files: HashMap<FileId, FileOptions>,
    sessions: HashMap<SessionId, Session>,
    /// Reads received before the session announcement.
    early: HashMap<SessionId, Vec<ReadMsg>>,
    /// Sessions this PE has seen torn down (read-after-close detection;
    /// bounded — see [`ClosedSessions`]).
    closed: ClosedSessions,
    /// Per-session tag counters (session-namespaced zero-copy tags).
    next_tag: HashMap<SessionId, u64>,
    my_pe_salt: u64,
}

impl Manager {
    pub fn new(director: ChareRef, assemblers: CollectionId, pe: u32) -> Manager {
        Manager {
            director,
            assemblers,
            files: HashMap::new(),
            sessions: HashMap::new(),
            early: HashMap::new(),
            closed: ClosedSessions::default(),
            next_tag: HashMap::new(),
            my_pe_salt: (pe as u64) << 40,
        }
    }

    /// Assign a cluster-unique zero-copy tag within `sid`'s namespace
    /// (PE-salted counter, so managers on distinct PEs never collide).
    fn make_tag(&mut self, sid: SessionId) -> Tag {
        let seq = self.next_tag.entry(sid).or_insert(0);
        *seq += 1;
        Tag { session: sid, local: self.my_pe_salt | *seq }
    }

    fn forward(&mut self, ctx: &mut Ctx<'_>, session: Session, r: ReadMsg) {
        let tag = self.make_tag(session.id);
        let pe = ctx.pe();
        ctx.advance(300);
        ctx.send(
            ChareRef::new(self.assemblers, pe.0),
            EP_A_REQ,
            AssembleReq { tag, session, offset: r.offset, len: r.len, after: r.after },
        );
    }

    /// Answer a read whose session is already gone: the data plane can no
    /// longer serve it, so complete the callback exactly once with a
    /// modeled (payload-free) chunk rather than stranding the client.
    fn nack(&mut self, ctx: &mut Ctx<'_>, r: ReadMsg) {
        ctx.metrics().count(keys::READS_AFTER_CLOSE, 1);
        let tag = Tag { session: r.session, local: self.my_pe_salt };
        ctx.fire(
            r.after,
            Payload::new(ReadResult {
                session: r.session,
                offset: r.offset,
                len: r.len,
                chunk: Chunk::modeled(r.offset, r.len),
                tag,
            }),
        );
    }

    /// Test/driver inspection.
    pub fn knows_session(&self, id: SessionId) -> bool {
        self.sessions.contains_key(&id)
    }

    pub fn knows_file(&self, id: FileId) -> bool {
        self.files.contains_key(&id)
    }

    /// Live session-table size (leak checks in tests).
    pub fn session_count(&self) -> usize {
        self.sessions.len()
    }

    /// Held early reads across all sessions (leak checks in tests).
    pub fn early_count(&self) -> usize {
        self.early.values().map(|v| v.len()).sum()
    }
}

/// The manager's declared message protocol (see [`crate::amt::protocol`]).
/// Any change to its EPs, payload types, or send sites must update this
/// spec in the same commit.
pub fn protocol_spec() -> ProtocolSpec {
    use super::director::{
        EP_DIR_ANNOUNCE_ACK, EP_DIR_CLOSE_ACK, EP_DIR_DROP_ACK_MGR, EP_DIR_OPEN_ACK,
    };
    ProtocolSpec {
        chare: "Manager",
        module: "ckio/manager.rs",
        handles: vec![
            ep_spec!(EP_M_READ, PayloadKind::of::<ReadMsg>()),
            ep_spec!(EP_M_FILE_OPENED, PayloadKind::of::<FileOpenedMsg>()),
            ep_spec!(EP_M_SESSION_ANNOUNCE, PayloadKind::of::<SessionAnnounceMsg>()),
            ep_spec!(EP_M_SESSION_DROP, PayloadKind::of::<SessionId>()),
            ep_spec!(EP_M_FILE_CLOSE, PayloadKind::of::<FileId>()),
        ],
        sends: vec![
            send_spec!("ReadAssembler", EP_A_REQ, PayloadKind::of::<AssembleReq>()),
            send_spec!("Director", EP_DIR_OPEN_ACK, PayloadKind::of::<FileId>()),
            send_spec!("Director", EP_DIR_ANNOUNCE_ACK, PayloadKind::of::<SessionId>()),
            send_spec!("Director", EP_DIR_DROP_ACK_MGR, PayloadKind::of::<SessionId>()),
            send_spec!("Director", EP_DIR_CLOSE_ACK, PayloadKind::of::<FileId>()),
        ],
    }
}

impl Chare for Manager {
    fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
        match msg.ep {
            EP_M_READ => {
                let r: ReadMsg = msg.take();
                match self.sessions.get(&r.session) {
                    Some(s) => {
                        let s = *s;
                        self.forward(ctx, s, r);
                    }
                    None if self.closed.contains(&r.session) => self.nack(ctx, r),
                    // Read raced ahead of the announcement: hold it.
                    None => self.early.entry(r.session).or_default().push(r),
                }
            }
            EP_M_FILE_OPENED => {
                let m: FileOpenedMsg = msg.take();
                self.files.insert(m.file, m.opts);
                ctx.advance(200);
                ctx.send(self.director, super::director::EP_DIR_OPEN_ACK, m.file);
            }
            EP_M_SESSION_ANNOUNCE => {
                let m: SessionAnnounceMsg = msg.take();
                let s = m.session;
                self.sessions.insert(s.id, s);
                // Flush reads that arrived early.
                for r in self.early.remove(&s.id).unwrap_or_default() {
                    self.forward(ctx, s, r);
                }
                ctx.advance(200);
                ctx.send(self.director, super::director::EP_DIR_ANNOUNCE_ACK, s.id);
            }
            EP_M_SESSION_DROP => {
                let sid: SessionId = msg.take();
                self.sessions.remove(&sid);
                self.next_tag.remove(&sid);
                self.closed.insert(sid);
                // Announcements always precede drops (the director
                // sequences them), so held early reads for this session
                // can never be served any more — complete them as NACKs.
                for r in self.early.remove(&sid).unwrap_or_default() {
                    self.nack(ctx, r);
                }
                ctx.advance(200);
                ctx.send(self.director, super::director::EP_DIR_DROP_ACK_MGR, sid);
            }
            EP_M_FILE_CLOSE => {
                let file: FileId = msg.take();
                self.files.remove(&file);
                ctx.advance(200);
                ctx.send(self.director, super::director::EP_DIR_CLOSE_ACK, file);
            }
            other => panic!("Manager: unknown ep {other}"),
        }
    }

    impl_chare_any!();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tags_are_session_and_pe_unique() {
        let d = ChareRef::new(CollectionId(0), 0);
        let mut m0 = Manager::new(d, CollectionId(1), 0);
        let mut m1 = Manager::new(d, CollectionId(1), 1);
        let (s0, s1) = (SessionId(0), SessionId(1));
        let t0a = m0.make_tag(s0);
        let t0b = m0.make_tag(s0);
        let t1a = m1.make_tag(s0);
        assert_ne!(t0a, t0b);
        assert_ne!(t0a, t1a);
        assert_ne!(t0b, t1a);
        // A different session restarts the local counter, but the tag as
        // a whole still never collides: the namespace is the session.
        let tx = m0.make_tag(s1);
        assert_eq!(tx.local, t0a.local);
        assert_ne!(tx, t0a);
    }
}
