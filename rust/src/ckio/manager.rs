//! Manager group (paper §III-C.2).
//!
//! One manager per PE — the local API entry point (clients reach their
//! PE's manager without crossing the wire, like a Charm++ group pointer
//! access). Managers keep the session table, assign the zero-copy tags
//! used for buffer→assembler transfers, and forward each read to the
//! local ReadAssembler. Reads that race ahead of the session announcement
//! are held until it arrives.

use std::collections::HashMap;

use crate::amt::callback::Callback;
use crate::amt::chare::{Chare, ChareRef, CollectionId};
use crate::amt::engine::Ctx;
use crate::amt::msg::{Ep, Msg};
use crate::impl_chare_any;
use crate::pfs::layout::FileId;

use super::assembler::{AssembleReq, EP_A_REQ};
use super::options::Options;
use super::session::{Session, SessionId};

/// Client read (local API call).
pub const EP_M_READ: Ep = 1;
/// Director: a file is now open everywhere.
pub const EP_M_FILE_OPENED: Ep = 2;
/// Director: a session has started.
pub const EP_M_SESSION_ANNOUNCE: Ep = 3;
/// Director: tear down a session.
pub const EP_M_SESSION_DROP: Ep = 4;
/// Director: close a file.
pub const EP_M_FILE_CLOSE: Ep = 5;

/// A client read request.
#[derive(Debug)]
pub struct ReadMsg {
    pub session: SessionId,
    pub offset: u64,
    pub len: u64,
    pub after: Callback,
}

#[derive(Debug)]
pub struct FileOpenedMsg {
    pub file: FileId,
    pub opts: Options,
}

#[derive(Debug)]
pub struct SessionAnnounceMsg {
    pub session: Session,
}

/// One manager (group element).
pub struct Manager {
    pub director: ChareRef,
    pub assemblers: CollectionId,
    files: HashMap<FileId, Options>,
    sessions: HashMap<SessionId, Session>,
    /// Reads received before the session announcement.
    early: HashMap<SessionId, Vec<ReadMsg>>,
    next_tag: u64,
    my_pe_salt: u64,
}

impl Manager {
    pub fn new(director: ChareRef, assemblers: CollectionId, pe: u32) -> Manager {
        Manager {
            director,
            assemblers,
            files: HashMap::new(),
            sessions: HashMap::new(),
            early: HashMap::new(),
            next_tag: 0,
            my_pe_salt: (pe as u64) << 40,
        }
    }

    /// Assign a cluster-unique zero-copy tag (PE-salted counter).
    fn make_tag(&mut self) -> u64 {
        self.next_tag += 1;
        self.my_pe_salt | self.next_tag
    }

    fn forward(&mut self, ctx: &mut Ctx<'_>, session: Session, r: ReadMsg) {
        let tag = self.make_tag();
        let pe = ctx.pe();
        ctx.advance(300);
        ctx.send(
            ChareRef::new(self.assemblers, pe.0),
            EP_A_REQ,
            AssembleReq { tag, session, offset: r.offset, len: r.len, after: r.after },
        );
    }

    /// Test/driver inspection.
    pub fn knows_session(&self, id: SessionId) -> bool {
        self.sessions.contains_key(&id)
    }

    pub fn knows_file(&self, id: FileId) -> bool {
        self.files.contains_key(&id)
    }
}

impl Chare for Manager {
    fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
        match msg.ep {
            EP_M_READ => {
                let r: ReadMsg = msg.take();
                match self.sessions.get(&r.session) {
                    Some(s) => {
                        let s = *s;
                        self.forward(ctx, s, r);
                    }
                    // Read raced ahead of the announcement: hold it.
                    None => self.early.entry(r.session).or_default().push(r),
                }
            }
            EP_M_FILE_OPENED => {
                let m: FileOpenedMsg = msg.take();
                self.files.insert(m.file, m.opts);
                ctx.advance(200);
                ctx.send(self.director, super::director::EP_DIR_OPEN_ACK, m.file);
            }
            EP_M_SESSION_ANNOUNCE => {
                let m: SessionAnnounceMsg = msg.take();
                let s = m.session;
                self.sessions.insert(s.id, s);
                // Flush reads that arrived early.
                for r in self.early.remove(&s.id).unwrap_or_default() {
                    self.forward(ctx, s, r);
                }
                ctx.advance(200);
                ctx.send(self.director, super::director::EP_DIR_ANNOUNCE_ACK, s.id);
            }
            EP_M_SESSION_DROP => {
                let sid: SessionId = msg.take();
                self.sessions.remove(&sid);
                self.early.remove(&sid);
                ctx.advance(200);
                ctx.send(self.director, super::director::EP_DIR_DROP_ACK_MGR, sid);
            }
            EP_M_FILE_CLOSE => {
                let file: FileId = msg.take();
                self.files.remove(&file);
                ctx.advance(200);
                ctx.send(self.director, super::director::EP_DIR_CLOSE_ACK, file);
            }
            other => panic!("Manager: unknown ep {other}"),
        }
    }

    impl_chare_any!();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tags_are_pe_unique() {
        let d = ChareRef::new(CollectionId(0), 0);
        let mut m0 = Manager::new(d, CollectionId(1), 0);
        let mut m1 = Manager::new(d, CollectionId(1), 1);
        let t0a = m0.make_tag();
        let t0b = m0.make_tag();
        let t1a = m1.make_tag();
        assert_ne!(t0a, t0b);
        assert_ne!(t0a, t1a);
        assert_ne!(t0b, t1a);
    }
}
