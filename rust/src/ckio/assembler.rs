//! ReadAssembler group (paper §III-C.3).
//!
//! One assembler per PE. Every client read on that PE is routed here (by
//! the local manager); the assembler determines which buffer chares hold
//! the requested extent (usually 1–2 consecutive ones given typical
//! over-decomposition), issues fetches, assembles the arriving pieces,
//! and fires the client's `after_read` continuation — which, being a
//! location-managed callback, follows the client across migrations.
//!
//! Concurrency (PR 1): assemblies are keyed by session-namespaced
//! [`Tag`]s, so concurrent sessions cannot collide. The director notifies
//! assemblers when a session is torn down; a piece arriving for an
//! unknown tag of a *closed* session (the drop drained it concurrently)
//! is counted and discarded, while an unknown tag of a live session still
//! panics — that would be a real protocol bug.

use std::collections::{HashMap, HashSet};

use crate::amt::callback::Callback;
use crate::amt::chare::{Chare, ChareRef, CollectionId};
use crate::amt::engine::Ctx;
use crate::amt::msg::{Ep, Msg, Payload};
use crate::amt::protocol::{PayloadKind, ProtocolSpec};
use crate::amt::time::Time;
use crate::impl_chare_any;
use crate::metrics::keys;
use crate::trace::{names as trace_names, Lane as TraceLane, TraceCategory};
use crate::util::bytes::Chunk;
use crate::{ep_spec, send_spec};

use super::buffer::{FetchMsg, PieceMsg, EP_BUF_FETCH};
use super::director::EP_DIR_FLOW_REPORT;
use super::session::{ClosedSessions, FlowReportMsg, ReadResult, Session, SessionId, Tag};

/// A read request forwarded from the local manager.
pub const EP_A_REQ: Ep = 1;
/// A piece arriving from a buffer chare.
pub const EP_A_PIECE: Ep = 2;
/// Director: a session is being torn down (tolerate its late pieces).
pub const EP_A_SESSION_DROP: Ep = 3;

/// Manager → assembler: perform this read.
#[derive(Debug)]
pub struct AssembleReq {
    pub tag: Tag,
    pub session: Session,
    pub offset: u64,
    pub len: u64,
    pub after: Callback,
}

#[derive(Debug)]
struct Assembly {
    session: SessionId,
    offset: u64,
    len: u64,
    remaining: u32,
    pieces: Vec<Chunk>,
    after: Callback,
    started_at: Time,
    /// The consumer chare this read is delivered to (PR 9): the
    /// `after` callback's target, when it is a chare callback. Flow
    /// accounts are charged per consumer, so advice can name who to
    /// move; future/broadcast callbacks have no migratable consumer.
    consumer: Option<ChareRef>,
    /// The owning session's [`Session::flow_threshold`], copied at
    /// request time (0 = Static session, keep no accounts).
    flow_threshold: u32,
}

/// Per-(consumer, source-PE) delivery account since the last flow
/// report (PR 9): deltas, flushed to the director every
/// `flow_threshold` pieces.
#[derive(Debug, Default)]
struct ConsumerFlow {
    /// source buffer PE → bytes delivered from it.
    by_pe: HashMap<u32, u64>,
    /// Pieces delivered since the last report.
    pieces: u32,
}

/// Per-PE read assembler.
pub struct ReadAssembler {
    assemblies: HashMap<Tag, Assembly>,
    /// Sessions known to be torn down (late-piece tolerance; bounded —
    /// see [`ClosedSessions`]).
    closed: ClosedSessions,
    /// Sessions whose first assembled byte this PE has already traced
    /// (populated only while tracing — the `session/first_byte` marker).
    first_served: HashSet<SessionId>,
    /// Consumer-flow accounts for FlowAware sessions (PR 9), keyed by
    /// session so a drop removes exactly that session's accounts.
    /// Bounded: each account resets when its delta is reported, and the
    /// whole entry dies with `EP_A_SESSION_DROP`. Leak-checked via
    /// [`ReadAssembler::flow_accounts`] in `assert_service_clean`.
    flows: HashMap<SessionId, HashMap<ChareRef, ConsumerFlow>>,
    /// Patched right after boot (pre-run, like the managers' director).
    pub director: ChareRef,
    /// Total reads assembled (inspection).
    pub completed: u64,
}

impl Default for ReadAssembler {
    fn default() -> ReadAssembler {
        ReadAssembler {
            assemblies: HashMap::new(),
            closed: ClosedSessions::default(),
            first_served: HashSet::new(),
            flows: HashMap::new(),
            // Placeholder — replaced by `patch_director` before any
            // message is in flight (boot wiring, as for managers/shards).
            director: ChareRef::new(CollectionId(0), 0),
            completed: 0,
        }
    }
}

impl ReadAssembler {
    fn finish(&mut self, ctx: &mut Ctx<'_>, tag: Tag) {
        let a = self.assemblies.remove(&tag).expect("finishing unknown assembly");
        let chunk = merge(a.pieces, a.offset, a.len);
        self.completed += 1;
        ctx.metrics().count(keys::CKIO_READS, 1);
        ctx.metrics().count(keys::CKIO_BYTES, a.len);
        let latency = ctx.now().saturating_sub(a.started_at);
        ctx.metrics().charge(keys::ASSEMBLY_LATENCY, latency);
        ctx.metrics().record(keys::LATENCY_ASSEMBLY, latency);
        if ctx.trace().on(TraceCategory::Session) {
            let pe = ctx.pe().0;
            ctx.trace().complete(
                a.started_at,
                latency,
                TraceCategory::Session,
                trace_names::SESSION_ASSEMBLY,
                TraceLane::Pe(pe),
                u64::from(a.session.0),
                a.len,
                0,
                "",
            );
            if self.first_served.insert(a.session) {
                // First byte delivered to a client of this session on
                // this PE: the paper's time-to-first-data marker.
                let now = ctx.now();
                ctx.trace().instant(
                    now,
                    TraceCategory::Session,
                    trace_names::SESSION_FIRST_BYTE,
                    TraceLane::Pe(pe),
                    u64::from(a.session.0),
                    latency,
                    "",
                );
            }
        }
        // One memcpy into the client's buffer (~80 GB/s), plus bookkeeping.
        ctx.advance(300 + (a.len as f64 * 0.0125) as Time);
        ctx.fire(
            a.after,
            Payload::new(ReadResult {
                session: a.session,
                offset: a.offset,
                len: a.len,
                chunk,
                tag,
            }),
        );
    }

    /// In-flight assembly count (leak checks in tests: must be 0 after
    /// all sessions close).
    pub fn outstanding(&self) -> usize {
        self.assemblies.len()
    }

    /// Sessions with a live first-byte trace mark on this PE. A dropped
    /// session must not linger here (the PR 9 regression guard for the
    /// `EP_A_SESSION_DROP` cleanup of `first_served`).
    pub fn first_served_count(&self) -> usize {
        self.first_served.len()
    }

    /// Sessions with live consumer-flow accounts (leak checks: must be
    /// 0 after all sessions close — accounts die with the drop).
    pub fn flow_accounts(&self) -> usize {
        self.flows.len()
    }
}

/// The assembler's declared message protocol (see [`crate::amt::protocol`]).
/// Any change to its EPs, payload types, or send sites must update this
/// spec in the same commit.
pub fn protocol_spec() -> ProtocolSpec {
    ProtocolSpec {
        chare: "ReadAssembler",
        module: "ckio/assembler.rs",
        handles: vec![
            ep_spec!(EP_A_REQ, PayloadKind::of::<AssembleReq>()),
            ep_spec!(EP_A_PIECE, PayloadKind::of::<PieceMsg>()),
            ep_spec!(EP_A_SESSION_DROP, PayloadKind::of::<SessionId>()),
        ],
        sends: vec![
            send_spec!("BufferChare", EP_BUF_FETCH, PayloadKind::of::<FetchMsg>()),
            send_spec!("Director", EP_DIR_FLOW_REPORT, PayloadKind::of::<FlowReportMsg>()),
        ],
    }
}

/// Merge fetched pieces (sorted by offset) into one contiguous chunk.
fn merge(mut pieces: Vec<Chunk>, offset: u64, len: u64) -> Chunk {
    pieces.sort_by_key(|c| c.offset);
    debug_assert_eq!(pieces.first().map(|c| c.offset), Some(offset));
    debug_assert_eq!(pieces.iter().map(|c| c.len).sum::<u64>(), len);
    if pieces.len() == 1 {
        return pieces.pop().unwrap();
    }
    if pieces.iter().all(|c| c.bytes.is_some()) {
        let mut out = Vec::with_capacity(len as usize);
        for p in &pieces {
            out.extend_from_slice(p.bytes.as_ref().unwrap());
        }
        Chunk::materialized(offset, out.into())
    } else {
        Chunk::modeled(offset, len)
    }
}

impl Chare for ReadAssembler {
    fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
        match msg.ep {
            EP_A_REQ => {
                let req: AssembleReq = msg.take();
                let buffers = req.session.buffers_for(req.offset, req.len);
                let nbuf = *buffers.end() - *buffers.start() + 1;
                let me_pe = ctx.pe();
                for b in buffers {
                    let (blo, blen) = req.session.buffer_span(b);
                    let lo = req.offset.max(blo);
                    let hi = (req.offset + req.len).min(blo + blen);
                    debug_assert!(lo < hi);
                    ctx.send(
                        ChareRef::new(req.session.buffers, b),
                        EP_BUF_FETCH,
                        FetchMsg { tag: req.tag, offset: lo, len: hi - lo, reply_pe: me_pe },
                    );
                }
                ctx.advance(400);
                let consumer = match &req.after {
                    Callback::Chare { to, .. } => Some(*to),
                    _ => None,
                };
                self.assemblies.insert(req.tag, Assembly {
                    session: req.session.id,
                    offset: req.offset,
                    len: req.len,
                    remaining: nbuf,
                    pieces: Vec::with_capacity(nbuf as usize),
                    after: req.after,
                    started_at: ctx.now(),
                    consumer,
                    flow_threshold: req.session.flow_threshold,
                });
            }
            EP_A_PIECE => {
                let piece: PieceMsg = msg.take();
                let Some(a) = self.assemblies.get_mut(&piece.tag) else {
                    if self.closed.contains(&piece.tag.session) {
                        // Teardown race: this read already completed via
                        // the drain path and a duplicate/late piece
                        // arrived afterwards. Tolerated, never delivered.
                        ctx.metrics().count(keys::PIECES_AFTER_CLOSE, 1);
                        return;
                    }
                    panic!("piece for unknown assembly (tag reuse or drop race): {:?}", piece.tag);
                };
                // Piece-leg locality (PR 9): the buffer→assembler hop,
                // the delivery counterpart of the buffer↔buffer
                // `ckio.place.same_pe_fetch`/`cross_pe_fetch` pair.
                // Always on — observable without FlowAware.
                if piece.src_pe == ctx.pe().0 {
                    ctx.metrics().count(keys::PLACE_PIECE_SAME_PE, piece.chunk.len);
                } else {
                    ctx.metrics().count(keys::PLACE_PIECE_CROSS_PE, piece.chunk.len);
                }
                // Flow accounts (FlowAware sessions only): charge the
                // delivery to this read's consumer, per source PE, and
                // flush the delta to the director every
                // `flow_threshold` pieces.
                if a.flow_threshold > 0 {
                    if let Some(consumer) = a.consumer {
                        let f = self
                            .flows
                            .entry(a.session)
                            .or_default()
                            .entry(consumer)
                            .or_default();
                        *f.by_pe.entry(piece.src_pe).or_default() += piece.chunk.len;
                        f.pieces += 1;
                        if f.pieces >= a.flow_threshold {
                            // Sorted for determinism: HashMap iteration
                            // order must never leak into message bytes.
                            let mut by_pe: Vec<(u32, u64)> = f.by_pe.drain().collect();
                            by_pe.sort_unstable();
                            f.pieces = 0;
                            ctx.send(self.director, EP_DIR_FLOW_REPORT, FlowReportMsg {
                                session: a.session,
                                consumer,
                                consumer_pe: ctx.pe().0,
                                by_pe,
                            });
                        }
                    }
                }
                a.pieces.push(piece.chunk);
                a.remaining -= 1;
                if a.remaining == 0 {
                    self.finish(ctx, piece.tag);
                }
            }
            EP_A_SESSION_DROP => {
                let sid: SessionId = msg.take();
                self.closed.insert(sid);
                self.first_served.remove(&sid);
                // Flow accounts die with the session (PR 9): unreported
                // residuals are deliberately discarded — advice for a
                // closing session is useless, and the director's matrix
                // is torn down when the close fully acks anyway.
                self.flows.remove(&sid);
                // Note: assemblies of `sid` still in flight are NOT
                // purged — the teardown drain guarantees each of their
                // pending fetches is answered (resident data or a modeled
                // NACK), so every one completes exactly once.
            }
            other => panic!("ReadAssembler: unknown ep {other}"),
        }
    }

    impl_chare_any!();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pfs::layout::FileId;
    use crate::pfs::pattern;

    #[test]
    fn merge_single_piece_passthrough() {
        let c = Chunk::modeled(100, 50);
        let m = merge(vec![c], 100, 50);
        assert_eq!(m.offset, 100);
        assert_eq!(m.len, 50);
    }

    #[test]
    fn merge_sorts_and_concatenates() {
        let p1 = Chunk::materialized(100, pattern::make(FileId(0), 100, 30));
        let p0 = Chunk::materialized(70, pattern::make(FileId(0), 70, 30));
        let m = merge(vec![p1, p0], 70, 60);
        assert_eq!(m.offset, 70);
        assert_eq!(m.len, 60);
        assert_eq!(pattern::verify(FileId(0), 70, m.bytes.as_ref().unwrap()), None);
    }

    #[test]
    fn merge_modeled_mix_degrades_to_modeled() {
        let p0 = Chunk::modeled(0, 10);
        let p1 = Chunk::materialized(10, pattern::make(FileId(0), 10, 10));
        let m = merge(vec![p0, p1], 0, 20);
        assert!(m.bytes.is_none());
        assert_eq!(m.len, 20);
    }
}
