//! ReadAssembler group (paper §III-C.3).
//!
//! One assembler per PE. Every client read on that PE is routed here (by
//! the local manager); the assembler determines which buffer chares hold
//! the requested extent (usually 1–2 consecutive ones given typical
//! over-decomposition), issues fetches, assembles the arriving pieces,
//! and fires the client's `after_read` continuation — which, being a
//! location-managed callback, follows the client across migrations.
//!
//! Concurrency (PR 1): assemblies are keyed by session-namespaced
//! [`Tag`]s, so concurrent sessions cannot collide. The director notifies
//! assemblers when a session is torn down; a piece arriving for an
//! unknown tag of a *closed* session (the drop drained it concurrently)
//! is counted and discarded, while an unknown tag of a live session still
//! panics — that would be a real protocol bug.

use std::collections::{HashMap, HashSet};

use crate::amt::callback::Callback;
use crate::amt::chare::{Chare, ChareRef};
use crate::amt::engine::Ctx;
use crate::amt::msg::{Ep, Msg, Payload};
use crate::amt::protocol::{PayloadKind, ProtocolSpec};
use crate::amt::time::Time;
use crate::impl_chare_any;
use crate::metrics::keys;
use crate::trace::{names as trace_names, Lane as TraceLane, TraceCategory};
use crate::util::bytes::Chunk;
use crate::{ep_spec, send_spec};

use super::buffer::{FetchMsg, PieceMsg, EP_BUF_FETCH};
use super::session::{ClosedSessions, ReadResult, Session, SessionId, Tag};

/// A read request forwarded from the local manager.
pub const EP_A_REQ: Ep = 1;
/// A piece arriving from a buffer chare.
pub const EP_A_PIECE: Ep = 2;
/// Director: a session is being torn down (tolerate its late pieces).
pub const EP_A_SESSION_DROP: Ep = 3;

/// Manager → assembler: perform this read.
#[derive(Debug)]
pub struct AssembleReq {
    pub tag: Tag,
    pub session: Session,
    pub offset: u64,
    pub len: u64,
    pub after: Callback,
}

#[derive(Debug)]
struct Assembly {
    session: SessionId,
    offset: u64,
    len: u64,
    remaining: u32,
    pieces: Vec<Chunk>,
    after: Callback,
    started_at: Time,
}

/// Per-PE read assembler.
#[derive(Default)]
pub struct ReadAssembler {
    assemblies: HashMap<Tag, Assembly>,
    /// Sessions known to be torn down (late-piece tolerance; bounded —
    /// see [`ClosedSessions`]).
    closed: ClosedSessions,
    /// Sessions whose first assembled byte this PE has already traced
    /// (populated only while tracing — the `session/first_byte` marker).
    first_served: HashSet<SessionId>,
    /// Total reads assembled (inspection).
    pub completed: u64,
}

impl ReadAssembler {
    fn finish(&mut self, ctx: &mut Ctx<'_>, tag: Tag) {
        let a = self.assemblies.remove(&tag).expect("finishing unknown assembly");
        let chunk = merge(a.pieces, a.offset, a.len);
        self.completed += 1;
        ctx.metrics().count(keys::CKIO_READS, 1);
        ctx.metrics().count(keys::CKIO_BYTES, a.len);
        let latency = ctx.now().saturating_sub(a.started_at);
        ctx.metrics().charge(keys::ASSEMBLY_LATENCY, latency);
        ctx.metrics().record(keys::LATENCY_ASSEMBLY, latency);
        if ctx.trace().on(TraceCategory::Session) {
            let pe = ctx.pe().0;
            ctx.trace().complete(
                a.started_at,
                latency,
                TraceCategory::Session,
                trace_names::SESSION_ASSEMBLY,
                TraceLane::Pe(pe),
                u64::from(a.session.0),
                a.len,
                0,
                "",
            );
            if self.first_served.insert(a.session) {
                // First byte delivered to a client of this session on
                // this PE: the paper's time-to-first-data marker.
                let now = ctx.now();
                ctx.trace().instant(
                    now,
                    TraceCategory::Session,
                    trace_names::SESSION_FIRST_BYTE,
                    TraceLane::Pe(pe),
                    u64::from(a.session.0),
                    latency,
                    "",
                );
            }
        }
        // One memcpy into the client's buffer (~80 GB/s), plus bookkeeping.
        ctx.advance(300 + (a.len as f64 * 0.0125) as Time);
        ctx.fire(
            a.after,
            Payload::new(ReadResult {
                session: a.session,
                offset: a.offset,
                len: a.len,
                chunk,
                tag,
            }),
        );
    }

    /// In-flight assembly count (leak checks in tests: must be 0 after
    /// all sessions close).
    pub fn outstanding(&self) -> usize {
        self.assemblies.len()
    }
}

/// The assembler's declared message protocol (see [`crate::amt::protocol`]).
/// Any change to its EPs, payload types, or send sites must update this
/// spec in the same commit.
pub fn protocol_spec() -> ProtocolSpec {
    ProtocolSpec {
        chare: "ReadAssembler",
        module: "ckio/assembler.rs",
        handles: vec![
            ep_spec!(EP_A_REQ, PayloadKind::of::<AssembleReq>()),
            ep_spec!(EP_A_PIECE, PayloadKind::of::<PieceMsg>()),
            ep_spec!(EP_A_SESSION_DROP, PayloadKind::of::<SessionId>()),
        ],
        sends: vec![send_spec!("BufferChare", EP_BUF_FETCH, PayloadKind::of::<FetchMsg>())],
    }
}

/// Merge fetched pieces (sorted by offset) into one contiguous chunk.
fn merge(mut pieces: Vec<Chunk>, offset: u64, len: u64) -> Chunk {
    pieces.sort_by_key(|c| c.offset);
    debug_assert_eq!(pieces.first().map(|c| c.offset), Some(offset));
    debug_assert_eq!(pieces.iter().map(|c| c.len).sum::<u64>(), len);
    if pieces.len() == 1 {
        return pieces.pop().unwrap();
    }
    if pieces.iter().all(|c| c.bytes.is_some()) {
        let mut out = Vec::with_capacity(len as usize);
        for p in &pieces {
            out.extend_from_slice(p.bytes.as_ref().unwrap());
        }
        Chunk::materialized(offset, out.into())
    } else {
        Chunk::modeled(offset, len)
    }
}

impl Chare for ReadAssembler {
    fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
        match msg.ep {
            EP_A_REQ => {
                let req: AssembleReq = msg.take();
                let buffers = req.session.buffers_for(req.offset, req.len);
                let nbuf = *buffers.end() - *buffers.start() + 1;
                let me_pe = ctx.pe();
                for b in buffers {
                    let (blo, blen) = req.session.buffer_span(b);
                    let lo = req.offset.max(blo);
                    let hi = (req.offset + req.len).min(blo + blen);
                    debug_assert!(lo < hi);
                    ctx.send(
                        ChareRef::new(req.session.buffers, b),
                        EP_BUF_FETCH,
                        FetchMsg { tag: req.tag, offset: lo, len: hi - lo, reply_pe: me_pe },
                    );
                }
                ctx.advance(400);
                self.assemblies.insert(req.tag, Assembly {
                    session: req.session.id,
                    offset: req.offset,
                    len: req.len,
                    remaining: nbuf,
                    pieces: Vec::with_capacity(nbuf as usize),
                    after: req.after,
                    started_at: ctx.now(),
                });
            }
            EP_A_PIECE => {
                let piece: PieceMsg = msg.take();
                let Some(a) = self.assemblies.get_mut(&piece.tag) else {
                    if self.closed.contains(&piece.tag.session) {
                        // Teardown race: this read already completed via
                        // the drain path and a duplicate/late piece
                        // arrived afterwards. Tolerated, never delivered.
                        ctx.metrics().count(keys::PIECES_AFTER_CLOSE, 1);
                        return;
                    }
                    panic!("piece for unknown assembly (tag reuse or drop race): {:?}", piece.tag);
                };
                a.pieces.push(piece.chunk);
                a.remaining -= 1;
                if a.remaining == 0 {
                    self.finish(ctx, piece.tag);
                }
            }
            EP_A_SESSION_DROP => {
                let sid: SessionId = msg.take();
                self.closed.insert(sid);
                self.first_served.remove(&sid);
                // Note: assemblies of `sid` still in flight are NOT
                // purged — the teardown drain guarantees each of their
                // pending fetches is answered (resident data or a modeled
                // NACK), so every one completes exactly once.
            }
            other => panic!("ReadAssembler: unknown ep {other}"),
        }
    }

    impl_chare_any!();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pfs::layout::FileId;
    use crate::pfs::pattern;

    #[test]
    fn merge_single_piece_passthrough() {
        let c = Chunk::modeled(100, 50);
        let m = merge(vec![c], 100, 50);
        assert_eq!(m.offset, 100);
        assert_eq!(m.len, 50);
    }

    #[test]
    fn merge_sorts_and_concatenates() {
        let p1 = Chunk::materialized(100, pattern::make(FileId(0), 100, 30));
        let p0 = Chunk::materialized(70, pattern::make(FileId(0), 70, 30));
        let m = merge(vec![p1, p0], 70, 60);
        assert_eq!(m.offset, 70);
        assert_eq!(m.len, 60);
        assert_eq!(pattern::verify(FileId(0), 70, m.bytes.as_ref().unwrap()), None);
    }

    #[test]
    fn merge_modeled_mix_degrades_to_modeled() {
        let p0 = Chunk::modeled(0, 10);
        let p1 = Chunk::materialized(10, pattern::make(FileId(0), 10, 10));
        let m = merge(vec![p0, p1], 0, 20);
        assert!(m.bytes.is_none());
        assert_eq!(m.len, 20);
    }
}
