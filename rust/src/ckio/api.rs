//! The user-facing CkIO API (paper §III-D).
//!
//! All calls are split-phase: they return immediately and deliver their
//! result through a [`Callback`]. Mapping to the paper:
//!
//! | paper                        | here                          |
//! |------------------------------|-------------------------------|
//! | `Ck::IO::open`               | [`CkIo::open`]                |
//! | `Ck::IO::startReadSession`   | [`CkIo::start_read_session`]  |
//! | `Ck::IO::read`               | [`CkIo::read`]                |
//! | `Ck::IO::closeReadSession`   | [`CkIo::close_read_session`]  |
//! | `Ck::IO::close`              | [`CkIo::close`]               |
//!
//! Client-side calls take the chare's `Ctx`; the driver-side `*_driver`
//! variants inject from outside the chare world (experiment setup).

use crate::amt::callback::Callback;
use crate::amt::chare::{Chare, ChareRef, CollectionId};
use crate::amt::engine::{Ctx, Engine};
use crate::amt::topology::{Pe, Placement};
use crate::pfs::layout::FileId;

use super::assembler::ReadAssembler;
use super::director::{
    CloseFileMsg, CloseSessionMsg, Director, OpenMsg, StartSessionMsg, EP_DIR_CLOSE_FILE,
    EP_DIR_CLOSE_SESSION, EP_DIR_OPEN, EP_DIR_START_SESSION,
};
use super::manager::{Manager, ReadMsg, EP_M_READ};
use super::options::Options;
use super::session::{Session, SessionId};
use super::shard::DataShard;

/// Handle bundle for the CkIO service instance; cheap to copy into every
/// client chare.
#[derive(Copy, Clone, Debug)]
pub struct CkIo {
    pub director: ChareRef,
    pub managers: CollectionId,
    pub assemblers: CollectionId,
    /// The data-plane shard array (PR 3): span-store + governor state,
    /// partitioned by `FileId` hash.
    pub shards: CollectionId,
    /// Elements in `shards` (one per PE; how many the hash actually
    /// routes over is `Options::data_plane_shards`, inspected via
    /// [`Director::active_shards`]).
    pub nshards: u32,
}

/// Patch the freshly created director's `ChareRef` into every element of
/// a booted collection (managers, data-plane shards). Boot wiring only:
/// the collections are created with a placeholder ref because the
/// director does not exist yet, and this helper is the single place that
/// replaces it — asserting the engine has **no event in flight**, so no
/// message can ever observe the placeholder.
fn patch_director<T: Chare>(
    engine: &mut Engine,
    cid: CollectionId,
    n: u32,
    director: ChareRef,
    field: impl Fn(&mut T) -> &mut ChareRef,
) {
    assert_eq!(
        engine.core.pending_events(),
        0,
        "director patching must complete before any message is in flight"
    );
    for i in 0..n {
        *field(engine.chare_mut::<T>(ChareRef::new(cid, i))) = director;
    }
}

impl CkIo {
    /// Install the CkIO service into an engine: the ReadAssembler group,
    /// the Manager group, the data-plane shard array (one element per
    /// PE), and the Director singleton (on PE 0).
    pub fn boot(engine: &mut Engine) -> CkIo {
        let assemblers = engine.create_group(|_| ReadAssembler::default());
        // The director's ChareRef isn't known until created; managers and
        // shards are patched right after through `patch_director`, which
        // asserts the placeholder is unobservable.
        let placeholder = ChareRef::new(assemblers, 0);
        let managers = engine.create_group(|pe| Manager::new(placeholder, assemblers, pe.0));
        let npes = engine.core.topo.npes();
        let nshards = npes;
        let shards = engine
            .create_array(nshards, &Placement::RoundRobinPes, |i| DataShard::new(i, placeholder));
        let director = engine
            .create_singleton(Pe(0), Director::new(managers, assemblers, shards, nshards, npes));
        patch_director::<Manager>(engine, managers, npes, director, |m| &mut m.director);
        patch_director::<DataShard>(engine, shards, nshards, director, |s| &mut s.director);
        CkIo { director, managers, assemblers, shards, nshards }
    }

    // ------------------------------------------------------------------
    // data-plane inspection (tests / drivers) — the PR 2 director
    // accessors, now summed over the shard array
    // ------------------------------------------------------------------

    /// Borrow one data-plane shard.
    pub fn shard<'e>(&self, engine: &'e Engine, i: u32) -> &'e DataShard {
        engine.chare(ChareRef::new(self.shards, i))
    }

    /// Parked buffer arrays available for reuse, across all shards.
    pub fn cached_buffer_arrays(&self, engine: &Engine) -> usize {
        (0..self.nshards).map(|s| self.shard(engine, s).span_store().parked_count()).sum()
    }

    /// Bytes resident in parked arrays, across all shards (the value the
    /// `ckio.store.resident_bytes` gauge sums to).
    pub fn store_resident_bytes(&self, engine: &Engine) -> u64 {
        (0..self.nshards).map(|s| self.shard(engine, s).span_store().resident_bytes()).sum()
    }

    /// Admitted-and-uncompleted governor tickets, across all shards
    /// (leak checks: must be 0 at quiescence).
    pub fn governor_inflight(&self, engine: &Engine) -> u32 {
        (0..self.nshards).map(|s| self.shard(engine, s).admission().inflight()).sum()
    }

    /// Buffer chares with queued (deferred) governor demand, across all
    /// shards (leak checks: must be 0 at quiescence).
    pub fn governor_queued(&self, engine: &Engine) -> usize {
        (0..self.nshards).map(|s| self.shard(engine, s).admission().queued()).sum()
    }

    /// Data-plane messages processed per shard (the imbalance pair
    /// `ckio.shard.msgs_max` / `ckio.shard.msgs_mean` is computed from
    /// this).
    pub fn shard_msgs(&self, engine: &Engine) -> Vec<u64> {
        (0..self.nshards).map(|s| self.shard(engine, s).msgs_processed()).collect()
    }

    // ------------------------------------------------------------------
    // client-side (inside chare handlers)
    // ------------------------------------------------------------------

    /// Open `file`; `opened` receives a [`super::session::FileHandle`].
    ///
    /// Opens are refcounted per file: concurrent or repeated opens share
    /// one metadata transaction, and **the first opener's `opts` govern
    /// the file** (like flags on a shared POSIX descriptor) — a later
    /// open's `opts` are not applied while the file is already open. The
    /// handle delivered to `opened` carries the options actually in
    /// effect.
    ///
    /// Invalid options fail the open (PR 4): if the placement can never
    /// cover the largest reader count a session of this file could
    /// resolve to (or a `StoreAware` fallback is itself `StoreAware`),
    /// `opened` fires with a structured
    /// [`super::options::OpenError`] instead of a `FileHandle` —
    /// discriminate with `payload.peek::<OpenError>()`. No file state is
    /// created anywhere on a rejected open.
    pub fn open(
        &self,
        ctx: &mut Ctx<'_>,
        file: FileId,
        size: u64,
        opts: Options,
        opened: Callback,
    ) {
        ctx.send(self.director, EP_DIR_OPEN, OpenMsg { file, size, opts, opened });
    }

    /// Start a read session over `[offset, offset+bytes)` of `file`;
    /// `ready` receives a [`Session`]. Buffer chares begin their greedy
    /// reads immediately — computation continues meanwhile.
    pub fn start_read_session(
        &self,
        ctx: &mut Ctx<'_>,
        file: FileId,
        offset: u64,
        bytes: u64,
        ready: Callback,
    ) {
        ctx.send(self.director, EP_DIR_START_SESSION, StartSessionMsg {
            file,
            offset,
            bytes,
            ready,
        });
    }

    /// Read `[offset, offset+len)` within a session; `after` receives a
    /// [`super::session::ReadResult`]. Never blocks: the continuation is
    /// enqueued when the data is ready. The call goes through the
    /// *local* manager (same-PE group access).
    pub fn read(
        &self,
        ctx: &mut Ctx<'_>,
        session: &Session,
        offset: u64,
        len: u64,
        after: Callback,
    ) {
        let pe = ctx.pe();
        ctx.send_group(self.managers, pe, EP_M_READ, ReadMsg {
            session: session.id,
            offset,
            len,
            after,
        });
    }

    /// Tear down a session (buffer memory, manager tables).
    pub fn close_read_session(&self, ctx: &mut Ctx<'_>, session: SessionId, after: Callback) {
        ctx.send(self.director, EP_DIR_CLOSE_SESSION, CloseSessionMsg { session, after });
    }

    /// Close a file on all PEs.
    pub fn close(&self, ctx: &mut Ctx<'_>, file: FileId, after: Callback) {
        ctx.send(self.director, EP_DIR_CLOSE_FILE, CloseFileMsg { file, after });
    }

    // ------------------------------------------------------------------
    // driver-side (experiment setup, outside any chare)
    // ------------------------------------------------------------------

    /// Driver-side open.
    pub fn open_driver(
        &self,
        engine: &mut Engine,
        file: FileId,
        size: u64,
        opts: Options,
        opened: Callback,
    ) {
        engine.inject(self.director, EP_DIR_OPEN, OpenMsg { file, size, opts, opened });
    }

    /// Driver-side session start.
    pub fn start_session_driver(
        &self,
        engine: &mut Engine,
        file: FileId,
        offset: u64,
        bytes: u64,
        ready: Callback,
    ) {
        engine.inject(self.director, EP_DIR_START_SESSION, StartSessionMsg {
            file,
            offset,
            bytes,
            ready,
        });
    }

    /// Driver-side session close.
    pub fn close_session_driver(&self, engine: &mut Engine, session: SessionId, after: Callback) {
        engine.inject(self.director, EP_DIR_CLOSE_SESSION, CloseSessionMsg { session, after });
    }

    /// Driver-side file close (drops one refcount, like [`CkIo::close`];
    /// pairs with [`CkIo::open_driver`] for drivers that hold a file open
    /// across several sessions).
    pub fn close_file_driver(&self, engine: &mut Engine, file: FileId, after: Callback) {
        engine.inject(self.director, EP_DIR_CLOSE_FILE, CloseFileMsg { file, after });
    }
}
