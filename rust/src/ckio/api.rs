//! The user-facing CkIO API (paper §III-D), with scoped configuration
//! (PR 5).
//!
//! All calls are split-phase: they return immediately and deliver their
//! result through a [`Callback`]. Mapping to the paper:
//!
//! | paper                        | here                          |
//! |------------------------------|-------------------------------|
//! | `Ck::IO::open`               | [`CkIo::open`]                |
//! | `Ck::IO::startReadSession`   | [`CkIo::start_read_session`]  |
//! | `Ck::IO::read`               | [`CkIo::read`]                |
//! | `Ck::IO::closeReadSession`   | [`CkIo::close_read_session`]  |
//! | `Ck::IO::close`              | [`CkIo::close`]               |
//!
//! Configuration is scoped (PR 5) — each call consumes exactly the
//! scope it owns:
//!
//! | scope   | type                                  | consumed by                  |
//! |---------|---------------------------------------|------------------------------|
//! | service | [`super::options::ServiceConfig`]     | [`CkIo::boot_with`] (once)   |
//! | file    | [`super::options::FileOptions`]       | [`CkIo::open`]               |
//! | session | [`super::options::SessionOptions`]    | [`CkIo::start_read_session`] |
//!
//! Client-side calls take the chare's `Ctx`; the driver-side `*_driver`
//! variants inject from outside the chare world (experiment setup).
//! Every public call has a driver twin — [`CkIo::open_driver`],
//! [`CkIo::start_session_driver`], [`CkIo::read_driver`],
//! [`CkIo::close_session_driver`], [`CkIo::close_file_driver`] — so
//! harnesses never need to hand-craft internal messages.

use crate::amt::callback::Callback;
use crate::amt::chare::{Chare, ChareRef, CollectionId};
use crate::amt::engine::{Ctx, Engine};
use crate::amt::topology::{Pe, Placement};
use crate::metrics::keys;
use crate::pfs::layout::FileId;

use super::assembler::ReadAssembler;
use super::director::{
    CloseFileMsg, CloseSessionMsg, CloseWriteMsg, Director, FlushMsg, OpenMsg, StartSessionMsg,
    StartWriteMsg, EP_DIR_CLOSE_FILE, EP_DIR_CLOSE_SESSION, EP_DIR_CLOSE_WRITE, EP_DIR_FLUSH,
    EP_DIR_OPEN, EP_DIR_START_SESSION, EP_DIR_START_WRITE,
};
use super::manager::{Manager, ReadMsg, EP_M_READ};
use super::options::{ConfigError, FileOptions, ServiceConfig, SessionOptions, WriteOptions};
use super::session::{Session, SessionId};
use super::shard::DataShard;
use super::write::{PutMsg, WriteAssembler, EP_WA_PUT};

/// Handle bundle for the CkIO service instance; cheap to copy into every
/// client chare.
#[derive(Copy, Clone, Debug)]
pub struct CkIo {
    pub director: ChareRef,
    pub managers: CollectionId,
    pub assemblers: CollectionId,
    /// The per-PE write-scatter router group (PR 10): producers' `write`
    /// calls enter the output plane through their local element.
    pub wassemblers: CollectionId,
    /// The data-plane shard array (PR 3): span-store + governor state,
    /// partitioned by `FileId` hash.
    pub shards: CollectionId,
    /// Elements in `shards` (one per PE; how many the hash actually
    /// routes over is fixed at boot by
    /// `ServiceConfig::data_plane_shards`, inspected via
    /// [`Director::active_shards`]).
    pub nshards: u32,
}

/// Patch the freshly created director's `ChareRef` into every element of
/// a booted collection (managers, data-plane shards). Boot wiring only:
/// the collections are created with a placeholder ref because the
/// director does not exist yet, and this helper is the single place that
/// replaces it — asserting the engine has **no event in flight**, so no
/// message can ever observe the placeholder.
fn patch_director<T: Chare>(
    engine: &mut Engine,
    cid: CollectionId,
    n: u32,
    director: ChareRef,
    field: impl Fn(&mut T) -> &mut ChareRef,
) {
    assert_eq!(
        engine.core.pending_events(),
        0,
        "director patching must complete before any message is in flight"
    );
    for i in 0..n {
        *field(engine.chare_mut::<T>(ChareRef::new(cid, i))) = director;
    }
}

impl CkIo {
    /// [`CkIo::boot_with`] under the default [`ServiceConfig`] (no store
    /// budget, one shard per PE, no admission control) — always valid.
    pub fn boot(engine: &mut Engine) -> CkIo {
        Self::boot_with(engine, ServiceConfig::default())
            .expect("the default ServiceConfig always validates")
    }

    /// Install the CkIO service into an engine: the ReadAssembler group,
    /// the Manager group, the data-plane shard array (one element per
    /// PE), and the Director singleton (on PE 0) — configured by `cfg`,
    /// the **service scope** (PR 5): store budget, shard count, and
    /// admission cap/policy are set here, once, synchronously, before
    /// any message is in flight. There is no runtime reconfiguration:
    /// the "last writer wins" / "first opener governs" semantics of the
    /// old per-file knobs are gone by construction.
    ///
    /// An invalid configuration (zero cap, zero shards) is rejected
    /// with a structured [`ConfigError`] before any service state is
    /// created.
    pub fn boot_with(engine: &mut Engine, cfg: ServiceConfig) -> Result<CkIo, ConfigError> {
        cfg.validate()?;
        // Flight recorder (PR 7): install the sink before any service
        // state exists, so even boot-time sends are recorded. Leaving
        // the field alone when tracing is off preserves a sink armed via
        // `trace::station` (the CLI path) — the config and the station
        // compose, last writer wins.
        if cfg.trace.enabled {
            engine.core.trace = crate::trace::TraceSink::new(&cfg.trace);
        }
        let assemblers = engine.create_group(|_| ReadAssembler::default());
        let wassemblers = engine.create_group(|_| WriteAssembler::default());
        // The director's ChareRef isn't known until created; managers and
        // shards are patched right after through `patch_director`, which
        // asserts the placeholder is unobservable.
        let placeholder = ChareRef::new(assemblers, 0);
        let managers = engine.create_group(|pe| Manager::new(placeholder, assemblers, pe.0));
        let npes = engine.core.topo.npes();
        let nshards = npes;
        let active = cfg.resolve_shards(npes);
        let shards = engine
            .create_array(nshards, &Placement::RoundRobinPes, |i| DataShard::new(i, placeholder));
        let director = engine.create_singleton(
            Pe(0),
            Director::new(
                managers,
                assemblers,
                wassemblers,
                shards,
                nshards,
                active,
                cfg.governed(),
                cfg.retry,
                npes,
            ),
        );
        patch_director::<Manager>(engine, managers, npes, director, |m| &mut m.director);
        patch_director::<DataShard>(engine, shards, nshards, director, |s| &mut s.director);
        patch_director::<ReadAssembler>(engine, assemblers, npes, director, |a| &mut a.director);
        patch_director::<WriteAssembler>(engine, wassemblers, npes, director, |a| &mut a.director);
        // Prove the declared EP graph sound before any message can flow,
        // and arm the engine's per-send validation (debug builds) for
        // every service collection. Buffer arrays are registered by the
        // director when it creates them, per session.
        if let Err(errs) = crate::amt::protocol::verify(&crate::amt::protocol::builtin_table()) {
            panic!("{}", crate::amt::protocol::format_errors(&errs));
        }
        engine.register_protocol(director.collection, super::director::protocol_spec());
        engine.register_protocol(managers, super::manager::protocol_spec());
        engine.register_protocol(assemblers, super::assembler::protocol_spec());
        engine.register_protocol(wassemblers, super::write::assembler_protocol_spec());
        engine.register_protocol(shards, super::shard::protocol_spec());
        // Configure the *active* shards (inactive ones never see
        // traffic): store-budget share and governor, applied directly to
        // the chare structs — boot runs before any message, exactly like
        // the director patching above. The configured caps are summed
        // onto the `ckio.governor.cap` gauge here because no `Ctx`
        // exists at boot; after this, only the AIMD loop can move a cap.
        let share = cfg.budget_share(active);
        let mut cap_gauge = 0.0;
        for s in 0..active {
            let shard = engine.chare_mut::<DataShard>(ChareRef::new(shards, s));
            cap_gauge += shard.boot_configure(&cfg, share);
        }
        if cap_gauge > 0.0 {
            engine.core.metrics.add(keys::GOV_CAP, cap_gauge);
        }
        Ok(CkIo { director, managers, assemblers, wassemblers, shards, nshards })
    }

    // ------------------------------------------------------------------
    // data-plane inspection (tests / drivers) — the PR 2 director
    // accessors, now summed over the shard array
    // ------------------------------------------------------------------

    /// Borrow one data-plane shard.
    pub fn shard<'e>(&self, engine: &'e Engine, i: u32) -> &'e DataShard {
        engine.chare(ChareRef::new(self.shards, i))
    }

    /// Parked buffer arrays available for reuse, across all shards.
    pub fn cached_buffer_arrays(&self, engine: &Engine) -> usize {
        (0..self.nshards).map(|s| self.shard(engine, s).span_store().parked_count()).sum()
    }

    /// Bytes resident in parked arrays, across all shards (the value the
    /// `ckio.store.resident_bytes` gauge sums to).
    pub fn store_resident_bytes(&self, engine: &Engine) -> u64 {
        (0..self.nshards).map(|s| self.shard(engine, s).span_store().resident_bytes()).sum()
    }

    /// Admitted-and-uncompleted governor tickets, across all shards
    /// (leak checks: must be 0 at quiescence).
    pub fn governor_inflight(&self, engine: &Engine) -> u32 {
        (0..self.nshards).map(|s| self.shard(engine, s).admission().inflight()).sum()
    }

    /// Buffer chares with queued (deferred) governor demand, across all
    /// shards (leak checks: must be 0 at quiescence).
    pub fn governor_queued(&self, engine: &Engine) -> usize {
        (0..self.nshards).map(|s| self.shard(engine, s).admission().queued()).sum()
    }

    /// Data-plane messages processed per shard (the imbalance pair
    /// `ckio.shard.msgs_max` / `ckio.shard.msgs_mean` is computed from
    /// this).
    pub fn shard_msgs(&self, engine: &Engine) -> Vec<u64> {
        (0..self.nshards).map(|s| self.shard(engine, s).msgs_processed()).collect()
    }

    // ------------------------------------------------------------------
    // client-side (inside chare handlers)
    // ------------------------------------------------------------------

    /// Open `file`; `opened` receives a [`super::session::FileHandle`].
    ///
    /// Opens are refcounted per file: concurrent or repeated opens share
    /// one metadata transaction, and the file is governed by the
    /// [`FileOptions`] it was first opened with. A re-open with *equal*
    /// options is idempotent (the handle carries the options in
    /// effect); a re-open with **different** options fails with
    /// [`super::options::OpenError::OptionsConflict`] on `opened` —
    /// never the pre-PR 5 silent ignore.
    ///
    /// Invalid options fail the open (PR 4): if the placement can never
    /// cover the largest reader count a session of this file could
    /// resolve to (or a `StoreAware` fallback is itself `StoreAware`),
    /// `opened` fires with a structured
    /// [`super::options::OpenError`] instead of a `FileHandle` —
    /// discriminate with `payload.peek::<OpenError>()`. No file state is
    /// created anywhere on a rejected open.
    pub fn open(
        &self,
        ctx: &mut Ctx<'_>,
        file: FileId,
        size: u64,
        opts: FileOptions,
        opened: Callback,
    ) {
        ctx.send(self.director, EP_DIR_OPEN, OpenMsg { file, size, opts, opened });
    }

    /// Start a read session over `[offset, offset+bytes)` of `file`,
    /// carrying this session's intent in `opts` (PR 5): the
    /// [`super::options::QosClass`] (announced to the owning data-plane
    /// shard before any buffer exists, and attached to every admission
    /// ticket), splintering, the read window, buffer reuse, and an
    /// optional placement override. `ready` receives a [`Session`].
    /// Buffer chares begin their greedy reads immediately — computation
    /// continues meanwhile. `SessionOptions::default()` reproduces the
    /// pre-PR 5 behavior exactly. An impossible `placement_override`
    /// fails `ready` with a structured
    /// [`super::options::OpenError`].
    pub fn start_read_session(
        &self,
        ctx: &mut Ctx<'_>,
        file: FileId,
        offset: u64,
        bytes: u64,
        opts: SessionOptions,
        ready: Callback,
    ) {
        ctx.send(self.director, EP_DIR_START_SESSION, StartSessionMsg {
            file,
            offset,
            bytes,
            opts,
            ready,
        });
    }

    /// Read `[offset, offset+len)` within a session; `after` receives a
    /// [`super::session::ReadResult`]. Never blocks: the continuation is
    /// enqueued when the data is ready. The call goes through the
    /// *local* manager (same-PE group access).
    pub fn read(
        &self,
        ctx: &mut Ctx<'_>,
        session: &Session,
        offset: u64,
        len: u64,
        after: Callback,
    ) {
        let pe = ctx.pe();
        ctx.send_group(self.managers, pe, EP_M_READ, ReadMsg {
            session: session.id,
            offset,
            len,
            after,
        });
    }

    /// Tear down a session (buffer memory, manager tables).
    pub fn close_read_session(&self, ctx: &mut Ctx<'_>, session: SessionId, after: Callback) {
        ctx.send(self.director, EP_DIR_CLOSE_SESSION, CloseSessionMsg { session, after });
    }

    /// Close a file on all PEs.
    pub fn close(&self, ctx: &mut Ctx<'_>, file: FileId, after: Callback) {
        ctx.send(self.director, EP_DIR_CLOSE_FILE, CloseFileMsg { file, after });
    }

    // ------------------------------------------------------------------
    // write plane (PR 10)
    // ------------------------------------------------------------------

    /// Start a write session over `[offset, offset+bytes)` of `file`
    /// (PR 10). `ready` receives the same [`Session`] scatter handle
    /// reads use; producers then [`CkIo::write`] pieces into it. The
    /// writer count resolves from the file's [`FileOptions`] exactly as
    /// the reader count does; `opts` carries the QoS class (PFS writes
    /// are admitted through the same per-shard governor as reads) and
    /// the write window; `wopts` the stripe grid, write-behind, and
    /// lazy-parking policy. A zero `stripe_bytes` fails `ready` with a
    /// structured [`super::options::OpenError`].
    pub fn start_write_session(
        &self,
        ctx: &mut Ctx<'_>,
        file: FileId,
        offset: u64,
        bytes: u64,
        opts: SessionOptions,
        wopts: WriteOptions,
        ready: Callback,
    ) {
        ctx.send(self.director, EP_DIR_START_WRITE, StartWriteMsg {
            file,
            offset,
            bytes,
            opts,
            wopts,
            ready,
        });
    }

    /// Scatter `[offset, offset+len)` into a write session; `after`
    /// receives a [`super::write::WriteResult`] once every routed piece
    /// was accepted by its buffer (acceptance is buffering — durability
    /// is [`CkIo::flush_write_session`] / close). The call goes through
    /// the *local* write assembler (same-PE group access); in this
    /// reproduction the payload is the deterministic verification
    /// pattern, so the call carries geometry, not bytes.
    pub fn write(
        &self,
        ctx: &mut Ctx<'_>,
        session: &Session,
        offset: u64,
        len: u64,
        after: Callback,
    ) {
        let pe = ctx.pe();
        ctx.send_group(self.wassemblers, pe, EP_WA_PUT, PutMsg {
            session: session.id,
            offset,
            len,
            after,
        });
    }

    /// Flush barrier: `after` fires once every byte producers have
    /// scattered so far is durably on the PFS or degraded into the
    /// session outcome — no dirty extent, queued write, or write ticket
    /// survives the barrier.
    pub fn flush_write_session(&self, ctx: &mut Ctx<'_>, session: SessionId, after: Callback) {
        ctx.send(self.director, EP_DIR_FLUSH, FlushMsg { session, after });
    }

    /// Close a write session: drain like a flush (unless the session
    /// opted into [`WriteOptions::park_dirty`]), then *park* the buffers
    /// — their residency is what serves a following read session with
    /// zero PFS reads. `after` receives the aggregated
    /// [`super::session::SessionOutcome`] (written / degraded / dirty
    /// byte accounting), exactly once per close call.
    pub fn close_write_session(&self, ctx: &mut Ctx<'_>, session: SessionId, after: Callback) {
        ctx.send(self.director, EP_DIR_CLOSE_WRITE, CloseWriteMsg { session, after });
    }

    // ------------------------------------------------------------------
    // driver-side (experiment setup, outside any chare)
    // ------------------------------------------------------------------

    /// Driver-side open.
    pub fn open_driver(
        &self,
        engine: &mut Engine,
        file: FileId,
        size: u64,
        opts: FileOptions,
        opened: Callback,
    ) {
        engine.inject(self.director, EP_DIR_OPEN, OpenMsg { file, size, opts, opened });
    }

    /// Driver-side session start.
    pub fn start_session_driver(
        &self,
        engine: &mut Engine,
        file: FileId,
        offset: u64,
        bytes: u64,
        opts: SessionOptions,
        ready: Callback,
    ) {
        engine.inject(self.director, EP_DIR_START_SESSION, StartSessionMsg {
            file,
            offset,
            bytes,
            opts,
            ready,
        });
    }

    /// Driver-side read (PR 5 satellite): route a client read through
    /// `pe`'s manager — exactly the path [`CkIo::read`] takes from a
    /// chare on that PE — instead of hand-injecting `EP_M_READ`
    /// messages. `after` receives the [`super::session::ReadResult`].
    pub fn read_driver(
        &self,
        engine: &mut Engine,
        pe: u32,
        session: &Session,
        offset: u64,
        len: u64,
        after: Callback,
    ) {
        engine.inject(ChareRef::new(self.managers, pe), EP_M_READ, ReadMsg {
            session: session.id,
            offset,
            len,
            after,
        });
    }

    /// Driver-side session close.
    pub fn close_session_driver(&self, engine: &mut Engine, session: SessionId, after: Callback) {
        engine.inject(self.director, EP_DIR_CLOSE_SESSION, CloseSessionMsg { session, after });
    }

    /// Driver-side file close (drops one refcount, like [`CkIo::close`];
    /// pairs with [`CkIo::open_driver`] for drivers that hold a file open
    /// across several sessions).
    pub fn close_file_driver(&self, engine: &mut Engine, file: FileId, after: Callback) {
        engine.inject(self.director, EP_DIR_CLOSE_FILE, CloseFileMsg { file, after });
    }

    /// Driver-side write-session start (PR 10).
    #[allow(clippy::too_many_arguments)]
    pub fn start_write_driver(
        &self,
        engine: &mut Engine,
        file: FileId,
        offset: u64,
        bytes: u64,
        opts: SessionOptions,
        wopts: WriteOptions,
        ready: Callback,
    ) {
        engine.inject(self.director, EP_DIR_START_WRITE, StartWriteMsg {
            file,
            offset,
            bytes,
            opts,
            wopts,
            ready,
        });
    }

    /// Driver-side write: scatter a producer put through `pe`'s write
    /// assembler — exactly the path [`CkIo::write`] takes from a chare
    /// on that PE.
    pub fn write_driver(
        &self,
        engine: &mut Engine,
        pe: u32,
        session: &Session,
        offset: u64,
        len: u64,
        after: Callback,
    ) {
        engine.inject(ChareRef::new(self.wassemblers, pe), EP_WA_PUT, PutMsg {
            session: session.id,
            offset,
            len,
            after,
        });
    }

    /// Driver-side flush barrier.
    pub fn flush_write_driver(&self, engine: &mut Engine, session: SessionId, after: Callback) {
        engine.inject(self.director, EP_DIR_FLUSH, FlushMsg { session, after });
    }

    /// Driver-side write-session close.
    pub fn close_write_driver(&self, engine: &mut Engine, session: SessionId, after: Callback) {
        engine.inject(self.director, EP_DIR_CLOSE_WRITE, CloseWriteMsg { session, after });
    }
}
