//! MPI-IO-style two-phase collective input (ROMIO; Thakur et al. '99) —
//! the comparator in the paper's Fig. 7.
//!
//! One rank per PE. A subset of ranks act as *aggregators* (`cb_nodes`,
//! default one per node, as ROMIO). The collective read proceeds in
//! bulk-synchronous phases with no computation overlap:
//!
//! 1. every rank posts its `(offset, len)` need to the aggregators whose
//!    *file domain* (contiguous partition of the accessed range) overlaps,
//! 2. each aggregator reads its whole domain from the PFS in large
//!    contiguous requests (data sieving),
//! 3. aggregators scatter the pieces to the requesting ranks,
//! 4. each rank completes when all its pieces arrived; the collective
//!    completes when all ranks did.
//!
//! Structurally this is CkIO's aggregation *without* the session
//! abstraction, prefetch overlap, tunable reader count or migratability —
//! which is exactly the comparison the paper draws.
//!
//! Since PR 10 the module also carries the **write-side baseline**:
//! [`NaiveWriter`], the output mirror of the naive per-task read — every
//! producer writes each of its pieces straight to the PFS with its own
//! RPC, no aggregation. `run_svc_rw` runs it against the `ckio::write`
//! plane's stripe-coalesced stream to report the PFS write-op reduction.

use crate::amt::callback::Callback;
use crate::amt::chare::{Chare, ChareRef, CollectionId};
use crate::amt::engine::Ctx;
use crate::amt::msg::{Ep, Msg, Payload};
use crate::amt::protocol::{PayloadKind, ProtocolSpec};
use crate::impl_chare_any;
use crate::net::Transfer;
use crate::pfs::backend::{IoResult, ReadRequest, WriteRequest};
use crate::pfs::layout::FileId;
use crate::util::bytes::Chunk;
use crate::{ep_spec, send_spec};

/// Driver: begin the collective read (sent to every rank).
pub const EP_C_GO: Ep = 1;
/// Rank → aggregator: my need within your domain.
pub const EP_C_NEED: Ep = 2;
/// Aggregator I/O completion.
pub const EP_C_DATA: Ep = 3;
/// Aggregator → rank: a piece of your request.
pub const EP_C_PIECE: Ep = 4;

#[derive(Debug)]
pub struct NeedMsg {
    pub rank: u32,
    pub offset: u64,
    pub len: u64,
}

#[derive(Debug)]
pub struct PieceMsg {
    pub chunk: Chunk,
}

/// Static description of the collective (same on every rank, as an MPI
/// communicator's collective-buffering settings would be).
#[derive(Clone, Debug)]
pub struct CollectiveConfig {
    pub file: FileId,
    /// Full accessed range (offset, len) across all ranks.
    pub range: (u64, u64),
    /// Rank index of each aggregator.
    pub aggregators: Vec<u32>,
    /// Total ranks.
    pub nranks: u32,
}

impl CollectiveConfig {
    /// File domain (offset, len) of aggregator `a` (contiguous equal
    /// partition of the accessed range, ROMIO-style).
    pub fn domain(&self, a: usize) -> (u64, u64) {
        let (lo, total) = self.range;
        let n = self.aggregators.len() as u64;
        let per = crate::util::bytes::ceil_div(total, n);
        let start = lo + a as u64 * per;
        let end = (start + per).min(lo + total);
        (start, end.saturating_sub(start))
    }

    /// Aggregator indices overlapping `[offset, offset+len)`.
    pub fn aggs_for(&self, offset: u64, len: u64) -> Vec<usize> {
        (0..self.aggregators.len())
            .filter(|&a| {
                let (o, l) = self.domain(a);
                l > 0 && o < offset + len && offset < o + l
            })
            .collect()
    }

    /// Ranks whose slice overlaps aggregator `a`'s domain, assuming the
    /// canonical equal split of the range across ranks.
    pub fn expected_needs(&self, a: usize, slices: &[(u64, u64)]) -> u32 {
        let (o, l) = self.domain(a);
        slices
            .iter()
            .filter(|&&(so, sl)| sl > 0 && l > 0 && so < o + l && o < so + sl)
            .count() as u32
    }
}

/// One MPI rank (and possibly aggregator).
pub struct MpiRank {
    pub cfg: CollectiveConfig,
    pub rank: u32,
    /// This rank's slice of the range.
    pub offset: u64,
    pub len: u64,
    /// Aggregator state (Some iff this rank aggregates): expected needs.
    agg: Option<AggState>,
    /// Pieces still missing for my own slice.
    missing: u64,
    pub done: Callback,
    pub ranks: CollectionId,
}

struct AggState {
    expect: u32,
    needs: Vec<NeedMsg>,
    data: Option<Chunk>,
    io_pending: bool,
}

impl MpiRank {
    pub fn new(
        cfg: CollectiveConfig,
        rank: u32,
        slices: &[(u64, u64)],
        ranks: CollectionId,
        done: Callback,
    ) -> MpiRank {
        let (offset, len) = slices[rank as usize];
        let agg_idx = cfg.aggregators.iter().position(|&a| a == rank);
        let agg = agg_idx.map(|a| AggState {
            expect: cfg.expected_needs(a, slices),
            needs: Vec::new(),
            data: None,
            io_pending: false,
        });
        MpiRank { cfg, rank, offset, len, agg, missing: len, done, ranks }
    }

    fn my_agg_index(&self) -> usize {
        self.cfg.aggregators.iter().position(|&a| a == self.rank).expect("not an aggregator")
    }

    /// Phase 2: aggregator has all needs → read the domain.
    fn maybe_read_domain(&mut self, ctx: &mut Ctx<'_>) {
        let a = self.my_agg_index();
        let (o, l) = self.cfg.domain(a);
        let st = self.agg.as_mut().unwrap();
        if st.io_pending || st.data.is_some() || (st.needs.len() as u32) < st.expect || l == 0 {
            return;
        }
        st.io_pending = true;
        let me = ctx.me();
        ctx.submit_read(
            ReadRequest { file: self.cfg.file, offset: o, len: l, user: 0 },
            Callback::to_chare(me, EP_C_DATA),
        );
    }

    /// Phase 3: scatter pieces to requesters.
    fn scatter(&mut self, ctx: &mut Ctx<'_>) {
        let st = self.agg.as_mut().unwrap();
        let Some(data) = st.data.clone() else { return };
        let needs = std::mem::take(&mut st.needs);
        for n in needs {
            let lo = n.offset.max(data.offset);
            let hi = (n.offset + n.len).min(data.end());
            debug_assert!(lo < hi);
            let piece = data.slice(lo, hi - lo);
            let wire = piece.len;
            ctx.send_sized(
                ChareRef::new(self.ranks, n.rank),
                EP_C_PIECE,
                Payload::new(PieceMsg { chunk: piece }),
                wire,
                Transfer::Eager,
            );
        }
    }
}

/// The rank's declared message protocol (see [`crate::amt::protocol`]).
/// Any change to its EPs, payload types, or send sites must update this
/// spec in the same commit.
pub fn protocol_spec() -> ProtocolSpec {
    ProtocolSpec {
        chare: "MpiRank",
        module: "baselines/collective.rs",
        handles: vec![
            ep_spec!(EP_C_GO, PayloadKind::Signal),
            ep_spec!(EP_C_NEED, PayloadKind::of::<NeedMsg>()),
            ep_spec!(EP_C_DATA, PayloadKind::of::<IoResult>()),
            ep_spec!(EP_C_PIECE, PayloadKind::of::<PieceMsg>()),
        ],
        sends: vec![
            send_spec!("MpiRank", EP_C_NEED, PayloadKind::of::<NeedMsg>()),
            send_spec!("MpiRank", EP_C_PIECE, PayloadKind::of::<PieceMsg>()),
        ],
    }
}

impl Chare for MpiRank {
    fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
        match msg.ep {
            EP_C_GO => {
                // Phase 1: post needs to overlapping aggregators.
                if self.len > 0 {
                    for a in self.cfg.aggs_for(self.offset, self.len) {
                        let (o, l) = self.cfg.domain(a);
                        let lo = self.offset.max(o);
                        let hi = (self.offset + self.len).min(o + l);
                        let agg_rank = self.cfg.aggregators[a];
                        ctx.send(
                            ChareRef::new(self.ranks, agg_rank),
                            EP_C_NEED,
                            NeedMsg { rank: self.rank, offset: lo, len: hi - lo },
                        );
                    }
                } else {
                    ctx.fire(self.done.clone(), Payload::new(0u64));
                }
                ctx.advance(500);
            }
            EP_C_NEED => {
                let n: NeedMsg = msg.take();
                let st = self.agg.as_mut().expect("need sent to non-aggregator");
                st.needs.push(n);
                ctx.advance(300);
                self.maybe_read_domain(ctx);
            }
            EP_C_DATA => {
                let r: IoResult = msg.take();
                let st = self.agg.as_mut().unwrap();
                st.io_pending = false;
                st.data = Some(r.chunk);
                self.scatter(ctx);
            }
            EP_C_PIECE => {
                let p: PieceMsg = msg.take();
                self.missing -= p.chunk.len;
                // Unpack into the user buffer (one memcpy).
                ctx.advance(200 + (p.chunk.len as f64 * 0.0125) as u64);
                if self.missing == 0 {
                    ctx.fire(self.done.clone(), Payload::new(self.len));
                }
            }
            other => panic!("MpiRank: unknown ep {other}"),
        }
    }
    impl_chare_any!();
}

/// Driver: begin the naive collective write (sent to every writer).
pub const EP_W_GO: Ep = 5;
/// Naive writer I/O completion (one per piece).
pub const EP_W_DATA: Ep = 6;

/// The naive every-producer-writes baseline (PR 10): each producer
/// issues one PFS write RPC **per piece** of its slice — the output
/// analogue of the Fig. 1 per-task reads, and what two-phase collective
/// output papers aggregate away. No coalescing, no stripe alignment,
/// no admission: the PFS sees one small RPC per producer piece.
pub struct NaiveWriter {
    pub file: FileId,
    /// This producer's slice of the output range.
    pub offset: u64,
    pub len: u64,
    /// Producer piece granularity: every piece is its own write RPC.
    pub piece_bytes: u64,
    outstanding: u32,
    pub done: Callback,
}

impl NaiveWriter {
    pub fn new(file: FileId, offset: u64, len: u64, piece_bytes: u64, done: Callback) -> Self {
        assert!(piece_bytes > 0, "piece granularity must be positive");
        NaiveWriter { file, offset, len, piece_bytes, outstanding: 0, done }
    }
}

/// [`NaiveWriter`]'s declared message protocol (see
/// [`crate::amt::protocol`]). Its only inbound traffic besides the go
/// signal is the engine's write-completion callback (no direct sends).
pub fn naive_writer_protocol_spec() -> ProtocolSpec {
    ProtocolSpec {
        chare: "NaiveWriter",
        module: "baselines/collective.rs",
        handles: vec![
            ep_spec!(EP_W_GO, PayloadKind::Signal),
            ep_spec!(EP_W_DATA, PayloadKind::of::<IoResult>()),
        ],
        sends: vec![],
    }
}

impl Chare for NaiveWriter {
    fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
        match msg.ep {
            EP_W_GO => {
                if self.len == 0 {
                    ctx.fire(self.done.clone(), Payload::new(0u64));
                    return;
                }
                let me = ctx.me();
                let end = self.offset + self.len;
                let mut o = self.offset;
                while o < end {
                    let l = self.piece_bytes.min(end - o);
                    ctx.submit_write(
                        WriteRequest { file: self.file, offset: o, len: l, user: 0 },
                        Callback::to_chare(me, EP_W_DATA),
                    );
                    self.outstanding += 1;
                    o += l;
                }
            }
            EP_W_DATA => {
                let r: IoResult = msg.take();
                debug_assert!(r.outcome.is_ok(), "naive baseline runs against a clean PFS");
                self.outstanding -= 1;
                if self.outstanding == 0 {
                    ctx.fire(self.done.clone(), Payload::new(self.len));
                }
            }
            other => panic!("NaiveWriter: unknown ep {other}"),
        }
    }
    impl_chare_any!();
}

/// Build the canonical equal split of `(lo, total)` across `n` ranks.
pub fn equal_slices(lo: u64, total: u64, n: u32) -> Vec<(u64, u64)> {
    let per = crate::util::bytes::ceil_div(total, n as u64);
    (0..n as u64)
        .map(|i| {
            let s = lo + i * per;
            let e = (s + per).min(lo + total);
            (s, e.saturating_sub(s))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amt::engine::{Engine, EngineConfig};
    use crate::amt::topology::Placement;
    use crate::pfs::PfsConfig;

    fn run_collective(nodes: u32, pes: u32, size: u64, aggs_per_node: u32) -> (u64, Engine) {
        let mut eng = Engine::new(EngineConfig::sim(nodes, pes)).with_sim_pfs(PfsConfig {
            noise_sigma: 0.0,
            ..PfsConfig::default()
        });
        let file = eng.core.sim_pfs_mut().create_file(size);
        let nranks = nodes * pes;
        let slices = equal_slices(0, size, nranks);
        let aggregators: Vec<u32> = (0..nodes)
            .flat_map(|n| (0..aggs_per_node).map(move |i| n * pes + i))
            .collect();
        let cfg = CollectiveConfig { file, range: (0, size), aggregators, nranks };
        let fut = eng.future(nranks);
        // Two-pass creation: the collection id is needed inside.
        let slices2 = slices.clone();
        let cfg2 = cfg.clone();
        let cid_holder = std::cell::Cell::new(CollectionId(u32::MAX));
        let cid = eng.create_array(nranks, &Placement::RoundRobinPes, |r| {
            MpiRank::new(cfg2.clone(), r, &slices2, cid_holder.get(), Callback::Future(fut))
        });
        // Fix the collection id (elements were built before cid existed).
        for r in 0..nranks {
            eng.chare_mut::<MpiRank>(ChareRef::new(cid, r)).ranks = cid;
        }
        for r in 0..nranks {
            eng.inject_signal(ChareRef::new(cid, r), EP_C_GO);
        }
        let end = eng.run();
        assert!(eng.future_done(fut), "collective did not complete");
        let total: u64 = eng.take_future(fut).into_iter().map(|(_, mut p)| p.take::<u64>()).sum();
        assert_eq!(total, size);
        (end, eng)
    }

    #[test]
    fn collective_completes_exactly() {
        let (end, eng) = run_collective(2, 4, 16 << 20, 1);
        assert!(end > 0);
        // Aggregators read the whole range once.
        assert_eq!(eng.core.metrics.counter("pfs.bytes_read"), 16 << 20);
    }

    #[test]
    fn domains_partition_range() {
        let cfg = CollectiveConfig {
            file: FileId(0),
            range: (100, 1000),
            aggregators: vec![0, 2, 5],
            nranks: 8,
        };
        let mut pos = 100;
        for a in 0..3 {
            let (o, l) = cfg.domain(a);
            assert_eq!(o, pos);
            pos = o + l;
        }
        assert_eq!(pos, 1100);
    }

    #[test]
    fn aggs_for_overlap() {
        let cfg = CollectiveConfig {
            file: FileId(0),
            range: (0, 900),
            aggregators: vec![0, 1, 2],
            nranks: 3,
        };
        assert_eq!(cfg.aggs_for(0, 300), vec![0]);
        assert_eq!(cfg.aggs_for(250, 100), vec![0, 1]);
        assert_eq!(cfg.aggs_for(0, 900), vec![0, 1, 2]);
    }

    #[test]
    fn naive_writers_pay_one_rpc_per_piece() {
        let mut eng = Engine::new(EngineConfig::sim(2, 4)).with_sim_pfs(PfsConfig {
            noise_sigma: 0.0,
            ..PfsConfig::default()
        });
        let size: u64 = 4 << 20;
        let piece: u64 = 64 << 10;
        let file = eng.core.sim_pfs_mut().create_file(size);
        let n = 8u32;
        let per = size / n as u64;
        let fut = eng.future(n);
        let cid = eng.create_array(n, &Placement::RoundRobinPes, |i| {
            NaiveWriter::new(file, i as u64 * per, per, piece, Callback::Future(fut))
        });
        eng.register_protocol(cid, naive_writer_protocol_spec());
        for i in 0..n {
            eng.inject_signal(ChareRef::new(cid, i), EP_W_GO);
        }
        eng.run();
        assert!(eng.future_done(fut), "naive write did not complete");
        let total: u64 =
            eng.take_future(fut).into_iter().map(|(_, mut p)| p.take::<u64>()).sum();
        assert_eq!(total, size);
        // The defining property of the baseline: one RPC per piece.
        assert_eq!(eng.core.metrics.counter("pfs.write_rpcs"), size / piece);
        assert_eq!(eng.core.metrics.counter("pfs.bytes_written"), size);
    }

    #[test]
    fn more_aggregators_change_io_shape() {
        let (t1, _) = run_collective(4, 4, 64 << 20, 1);
        let (t4, _) = run_collective(4, 4, 64 << 20, 4);
        // Not asserting which wins (depends on calibration) — both must
        // complete and differ (the knob is live).
        assert_ne!(t1, t4);
    }
}
