//! Naive over-decomposed input: every client chare opens the file and
//! reads its slice with its own file-system call (paper Figs. 1, 4).
//!
//! Two blocking disciplines are modeled:
//!
//! * `block_pe: false` — the read is split-phase (the chare waits on a
//!   callback, the PE keeps scheduling). This is the *best case* for
//!   naive input and what Figs. 1/4 measure (pure input throughput).
//! * `block_pe: true` — the chare *blocks its PE* for the duration of the
//!   read, as a synchronous `read()` from task code does in practice.
//!   This is what makes naive input poisonous to overlap (Fig. 8: naive
//!   runtime more than doubles when background work is added).

use crate::amt::callback::Callback;
use crate::amt::chare::Chare;
use crate::amt::engine::Ctx;
use crate::amt::msg::{Ep, Msg, Payload};
use crate::amt::protocol::{PayloadKind, ProtocolSpec};
use crate::ep_spec;
use crate::impl_chare_any;
use crate::pfs::backend::{IoResult, ReadRequest};
use crate::pfs::layout::FileId;
use crate::pfs::pattern;

/// Start: open the file (own MDS transaction), then read.
pub const EP_N_GO: Ep = 1;
/// MDS open done.
pub const EP_N_OPENED: Ep = 2;
/// Read completion.
pub const EP_N_DATA: Ep = 3;

/// One naive client.
pub struct NaiveClient {
    pub file: FileId,
    pub offset: u64,
    pub len: u64,
    /// Model a blocking read: the PE is held for the read's duration.
    pub block_pe: bool,
    /// Verify the delivered bytes against the file pattern.
    pub verify: bool,
    pub done: Callback,
    io_issued_at: u64,
}

impl NaiveClient {
    pub fn new(file: FileId, offset: u64, len: u64, done: Callback) -> NaiveClient {
        NaiveClient { file, offset, len, block_pe: false, verify: false, done, io_issued_at: 0 }
    }
}

/// The client's declared message protocol (see [`crate::amt::protocol`]).
/// All of its inbound traffic arrives via callbacks (no direct sends).
pub fn protocol_spec() -> ProtocolSpec {
    ProtocolSpec {
        chare: "NaiveClient",
        module: "baselines/naive.rs",
        handles: vec![
            ep_spec!(EP_N_GO, PayloadKind::Signal),
            ep_spec!(EP_N_OPENED, PayloadKind::Signal),
            ep_spec!(EP_N_DATA, PayloadKind::of::<IoResult>()),
        ],
        sends: vec![],
    }
}

impl Chare for NaiveClient {
    fn receive(&mut self, ctx: &mut Ctx<'_>, mut msg: Msg) {
        match msg.ep {
            EP_N_GO => {
                // Every client performs its own open — with thousands of
                // over-decomposed clients the MDS serialization alone is
                // measurable (part of the Fig. 1 collapse).
                let me = ctx.me();
                ctx.open_file(Callback::to_chare(me, EP_N_OPENED));
            }
            EP_N_OPENED => {
                let me = ctx.me();
                self.io_issued_at = ctx.now();
                ctx.submit_read(
                    ReadRequest { file: self.file, offset: self.offset, len: self.len, user: 0 },
                    Callback::to_chare(me, EP_N_DATA),
                );
            }
            EP_N_DATA => {
                let r: IoResult = msg.take();
                debug_assert_eq!(r.len, self.len);
                if self.verify {
                    let bytes = r.chunk.bytes.as_ref().expect("materialized run");
                    assert_eq!(pattern::verify(self.file, r.offset, bytes), None);
                }
                if self.block_pe {
                    // A synchronous read would have pinned the PE from
                    // issue to completion; charge that hold so queued
                    // tasks (e.g. background work) are delayed behind it.
                    let held = ctx.now().saturating_sub(self.io_issued_at);
                    ctx.charge("naive.pe_blocked", held);
                }
                ctx.fire(self.done.clone(), Payload::new(self.len));
            }
            other => panic!("NaiveClient: unknown ep {other}"),
        }
    }
    impl_chare_any!();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amt::chare::ChareRef;
    use crate::amt::engine::{Engine, EngineConfig};
    use crate::amt::topology::Placement;
    use crate::pfs::PfsConfig;

    #[test]
    fn naive_clients_read_everything() {
        let mut eng = Engine::new(EngineConfig::sim(2, 4)).with_sim_pfs(PfsConfig {
            materialize: true,
            noise_sigma: 0.0,
            ..PfsConfig::default()
        });
        let size: u64 = 8 << 20;
        let file = eng.core.sim_pfs_mut().create_file(size);
        let n = 16u32;
        let per = size / n as u64;
        let fut = eng.future(n);
        let cid = eng.create_array(n, &Placement::RoundRobinPes, |i| {
            let mut c = NaiveClient::new(file, i as u64 * per, per, Callback::Future(fut));
            c.verify = true;
            c
        });
        for i in 0..n {
            eng.inject_signal(ChareRef::new(cid, i), EP_N_GO);
        }
        let end = eng.run();
        assert!(eng.future_done(fut));
        assert!(end > 0);
        assert_eq!(eng.core.metrics.counter("pfs.bytes_read"), size);
    }

    #[test]
    fn blocking_discipline_charges_pe() {
        let mut eng = Engine::new(EngineConfig::sim(1, 1)).with_sim_pfs(PfsConfig {
            noise_sigma: 0.0,
            ..PfsConfig::default()
        });
        let file = eng.core.sim_pfs_mut().create_file(4 << 20);
        let fut = eng.future(1);
        let cid = eng.create_array(1, &Placement::RoundRobinPes, |_| {
            let mut c = NaiveClient::new(file, 0, 4 << 20, Callback::Future(fut));
            c.block_pe = true;
            c
        });
        eng.inject_signal(ChareRef::new(cid, 0), EP_N_GO);
        eng.run();
        let blocked = eng.core.metrics.duration("naive.pe_blocked");
        assert!(blocked > 0, "PE hold time should be charged");
        // The PE was busy at least as long as the read took.
        assert!(eng.pe_state(crate::amt::topology::Pe(0)).busy_ns >= blocked);
    }
}
