//! Baseline input schemes the paper compares CkIO against.
//!
//! * [`naive`] — every client chare makes its own file-system call
//!   (the paper's "naive parallel input", Figs. 1, 4, 8),
//! * [`collective`] — an MPI-IO-style bulk-synchronous two-phase
//!   collective read with ROMIO-like aggregators (Fig. 7's comparator).

pub mod collective;
pub mod naive;
