//! CkIO launcher: run any paper experiment (or all of them), inspect the
//! cluster/PFS configuration, or exercise the runtime end-to-end.
//!
//! ```text
//! ckio fig <1|2|4|7|8|9|12|13|sec5|splinter|autoreaders|svc_concurrent|svc_shared|svc_churn|svc_locality|svc_qos|svc_chaos|svc_overlap|svc_rw|all>
//!      [--reps N] [--out bench_out] [--tp 65536] [--trace]
//! ckio read   --file-size 4GiB --clients 512 [--scheme naive|ckio] [--readers N]
//! ckio changa --nodes 4 --tp 4096 --scheme ckio [--nbodies 2097152]
//! ckio perf   [--iters 5] [--file-size 4GiB] [--clients 8192] [--readers 512]
//! ckio trace <fig-id> [--out trace.json] [--reps 1]   # flight-recorded run -> Perfetto timeline
//! ckio bench-json [--pr 8|9|10] [--out BENCH_pr8.json] [--reps 3]   # svc perf + observability anchors
//! ckio artifacts [--dir artifacts]           # list + smoke-run lowered artifacts
//! ckio lint [--dump-protocol] [--dump-metrics] [tree-root]   # protocol verifier + source lint
//! ```

use ckio::amt::time;
use ckio::apps::changa::driver::{run_changa_input, Scheme};
use ckio::ckio::{FileOptions, SessionOptions};
use ckio::harness::bench::Table;
use ckio::harness::experiments as exp;
use ckio::metrics::keys;
use ckio::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "fig" => cmd_fig(&args),
        "read" => cmd_read(&args),
        "changa" => cmd_changa(&args),
        "artifacts" => cmd_artifacts(&args),
        "perf" => cmd_perf(&args),
        "trace" => cmd_trace(&args),
        "bench-json" => cmd_bench_json(&args),
        "lint" => {
            // Re-read raw argv: the lint CLI takes flag-style args
            // (`--dump-protocol`) that `Args` would swallow.
            let rest: Vec<String> = std::env::args().skip(2).collect();
            std::process::exit(ckio::lint::cli(&rest));
        }
        _ => {
            eprintln!(
                "usage: ckio fig <id|all> [--reps N] [--out DIR] [--trace] | read | changa | \
                 perf [--iters N] | trace <fig-id> [--out trace.json] | artifacts | \
                 bench-json [--pr 8|9|10] [--out BENCH_pr8.json] | \
                 lint [--dump-protocol] [--dump-metrics] [tree-root]\n\
                 see `rust/src/main.rs` header for full flags"
            );
        }
    }
}

/// Run one named figure; shared with the bench harness.
pub fn run_figure(id: &str, reps: u32, n_tp: u32) -> Option<(String, Table)> {
    let t = match id {
        "1" => exp::fig1_naive_clients(reps),
        "2" => exp::fig2_disk_vs_net(reps),
        "4" => exp::fig4_ckio_vs_naive(reps),
        "7" => exp::fig7_mpiio_vs_ckio(reps),
        "8" => exp::fig8_overlap_runtime(reps),
        "9" => exp::fig9_overlap_fraction(reps),
        "12" => exp::fig12_migration(reps),
        "13" => exp::fig13_changa(reps, n_tp),
        "sec5" => exp::sec5_breakdown(reps),
        "splinter" => exp::ablation_splinter(reps),
        "autoreaders" => exp::ablation_autoreaders(reps),
        "svc_concurrent" => exp::svc_concurrent(reps),
        "svc_shared" => exp::svc_shared(reps),
        "svc_churn" => exp::svc_churn(reps),
        "svc_locality" => exp::svc_locality(reps),
        "svc_qos" => exp::svc_qos(reps),
        "svc_chaos" => exp::svc_chaos(reps),
        "svc_overlap" => exp::svc_overlap(reps),
        "svc_rw" => exp::svc_rw(reps),
        _ => return None,
    };
    let slug = match id {
        "sec5" => "sec5_breakdown".to_string(),
        "splinter" => "ablation_splinter".to_string(),
        "autoreaders" => "ablation_autoreaders".to_string(),
        "svc_concurrent" => "svc_concurrent".to_string(),
        "svc_shared" => "svc_shared".to_string(),
        "svc_churn" => "svc_churn".to_string(),
        "svc_locality" => "svc_locality".to_string(),
        "svc_qos" => "svc_qos".to_string(),
        "svc_chaos" => "svc_chaos".to_string(),
        "svc_overlap" => "svc_overlap".to_string(),
        "svc_rw" => "svc_rw".to_string(),
        n => format!("fig{n}"),
    };
    Some((slug, t))
}

fn cmd_fig(args: &Args) {
    let id = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let reps = args.get_or("reps", 3u32);
    let out = args.get("out").unwrap_or("bench_out").to_string();
    let n_tp = args.get_or("tp", 1u32 << 16);
    let traced = args.flag("trace");
    let ids: Vec<&str> = if id == "all" {
        vec![
            "1", "2", "4", "7", "8", "9", "12", "13", "sec5", "splinter", "autoreaders",
            "svc_concurrent", "svc_shared", "svc_churn", "svc_locality", "svc_qos", "svc_chaos",
            "svc_overlap", "svc_rw",
        ]
    } else {
        vec![id]
    };
    for id in ids {
        let started = std::time::Instant::now();
        if traced {
            ckio::trace::arm(ckio::trace::TraceConfig::on());
        }
        let Some((slug, table)) = run_figure(id, reps, n_tp) else {
            eprintln!("unknown figure {id:?}");
            std::process::exit(2);
        };
        table.print();
        match table.write_csv(&out, &slug) {
            Ok(p) => {
                println!("[csv] {} ({:.1}s wall)\n", p.display(), started.elapsed().as_secs_f64())
            }
            Err(e) => eprintln!("csv write failed: {e}"),
        }
        if traced {
            // One timeline per figure, next to its CSV.
            let sinks = ckio::trace::collect();
            ckio::trace::disarm();
            write_trace(&sinks, std::path::Path::new(&out).join(format!("{slug}_trace.json")));
        }
    }
}

/// Export deposited sinks as Chrome trace-event JSON and print the
/// per-category summary (shared by `fig --trace` and `trace`).
fn write_trace(sinks: &[ckio::trace::TraceSink], path: std::path::PathBuf) {
    if let Some(dir) = path.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    let json = ckio::trace::export_chrome(sinks);
    std::fs::write(&path, &json).unwrap_or_else(|e| panic!("writing {}: {e}", path.display()));
    let events: u64 = ckio::trace::category_counts(sinks).values().sum();
    let dropped: u64 = sinks.iter().map(|s| s.dropped()).sum();
    println!(
        "[trace] {}: {} engine run(s), {events} events, {dropped} dropped",
        path.display(),
        sinks.len()
    );
    for (cat, n) in ckio::trace::category_counts(sinks) {
        println!("  {cat:10} {n}");
    }
}

/// Run one figure with the flight recorder armed and export its
/// timeline as Chrome trace-event JSON — load the file in Perfetto
/// (ui.perfetto.dev) or chrome://tracing. Lanes: one per PE (sessions,
/// reads, tasks) plus one per data-plane shard (store, governor,
/// placement).
fn cmd_trace(args: &Args) {
    let Some(id) = args.positional.get(1).map(|s| s.as_str()) else {
        eprintln!("usage: ckio trace <fig-id> [--out trace.json] [--reps 1] [--tp 65536]");
        std::process::exit(2);
    };
    let reps = args.get_or("reps", 1u32);
    let n_tp = args.get_or("tp", 1u32 << 16);
    let out = args.get("out").unwrap_or("trace.json").to_string();
    ckio::trace::arm(ckio::trace::TraceConfig::on());
    let Some((_slug, table)) = run_figure(id, reps, n_tp) else {
        eprintln!("unknown figure {id:?}");
        std::process::exit(2);
    };
    table.print();
    let sinks = ckio::trace::collect();
    ckio::trace::disarm();
    write_trace(&sinks, std::path::PathBuf::from(out));
}

fn cmd_read(args: &Args) {
    let size = args.get_bytes_or("file-size", 4 << 30);
    let clients = args.get_or("clients", 512u32);
    let nodes = args.get_or("nodes", exp::PAPER_NODES);
    let pes = args.get_or("pes-per-node", exp::PAPER_PES);
    let scheme = args.get("scheme").unwrap_or("ckio").to_string();
    let seed = args.get_or("seed", 1u64);
    let (t, eng) = match scheme.as_str() {
        "naive" => exp::run_naive_read(nodes, pes, size, clients, args.flag("block-pe"), seed),
        "ckio" => {
            let fopts = match args.get("readers") {
                Some(r) => FileOptions::with_readers(r.parse().expect("--readers")),
                None => FileOptions::default(),
            };
            exp::run_ckio_read(nodes, pes, size, clients, fopts, SessionOptions::default(), seed)
        }
        other => {
            eprintln!("unknown scheme {other:?} (naive|ckio)");
            std::process::exit(2);
        }
    };
    println!(
        "{scheme}: {} read by {clients} clients on {nodes}x{pes} PEs in {} ({:.2} GiB/s)",
        ckio::util::human_bytes(size),
        time::human(t),
        size as f64 / (1u64 << 30) as f64 / time::to_secs(t),
    );
    if args.flag("metrics") {
        print!("{}", eng.core.metrics.report());
    }
}

fn cmd_changa(args: &Args) {
    let nodes = args.get_or("nodes", 4u32);
    let pes = args.get_or("pes-per-node", 32u32);
    let n_tp = args.get_or("tp", 4096u32);
    let nbodies = args.get_or("nbodies", 2u64 << 20);
    let scheme = match args.get("scheme").unwrap_or("ckio") {
        "unopt" => Scheme::Unopt,
        "handopt" => Scheme::HandOpt,
        "ckio" => Scheme::CkIo,
        other => {
            eprintln!("unknown scheme {other:?}");
            std::process::exit(2);
        }
    };
    let run = run_changa_input(nodes, pes, n_tp, nbodies, scheme, args.get_or("seed", 1u64));
    println!(
        "changa[{}]: {} particles, {} TreePieces, {}x{} PEs -> input {}",
        scheme.label(),
        nbodies,
        n_tp,
        nodes,
        pes,
        time::human(run.input_time),
    );
    if args.flag("metrics") {
        print!("{}", run.engine.core.metrics.report());
    }
}

/// In-process perf driver: repeat the heavy CkIO stress scenario and
/// report engine throughput (events/s), excluding process startup.
fn cmd_perf(args: &Args) {
    let iters = args.get_or("iters", 5u32);
    let size = args.get_bytes_or("file-size", 4 << 30);
    let clients = args.get_or("clients", 8192u32);
    let readers = args.get_or("readers", 512u32);
    // Warmup.
    exp::run_ckio_read(
        16,
        32,
        size,
        clients,
        FileOptions::with_readers(readers),
        SessionOptions::default(),
        1,
    );
    let mut total_tasks = 0u64;
    let mut total_msgs = 0u64;
    let t0 = std::time::Instant::now();
    for i in 0..iters {
        let (_, eng) = exp::run_ckio_read(
            16,
            32,
            size,
            clients,
            FileOptions::with_readers(readers),
            SessionOptions::default(),
            i as u64,
        );
        total_tasks += eng.core.metrics.counter(keys::TASKS);
        total_msgs += eng.core.metrics.counter(keys::MSGS);
    }
    let wall = t0.elapsed().as_secs_f64();
    // Every task + message involves at least one heap event; PFS adds
    // its own. Report the conservative proxy (tasks + msgs).
    let events = total_tasks + total_msgs;
    println!(
        "perf: {iters} runs x ({clients} clients, {readers} readers, {}) in {wall:.3}s",
        ckio::util::human_bytes(size)
    );
    println!(
        "  tasks={total_tasks} msgs={total_msgs}  ->  {:.2} M(task+msg)/s, {:.1} ms/run",
        events as f64 / wall / 1e6,
        wall * 1e3 / iters as f64
    );
}

/// Emit a PR's machine-readable perf anchor as JSON. `--pr 8` (default)
/// is the service-wide anchor: svc_concurrent aggregate GiB/s,
/// svc_shared PFS-dedup ratios, the svc_churn shard sweep, the
/// adaptive-governor feedback run, the svc_locality placement pair, the
/// svc_qos classed-vs-classless pair, the svc_chaos fault-rate
/// reliability sweep, and the span-store / admission-governor / shard /
/// placement / qos / retry observability keys. `--pr 9` is the
/// consumer-locality + admission-wait-overlap anchor (`BENCH_pr9.json`):
/// static vs flow-aware consumer placement with the flow-matrix
/// counters, and the governed with/without-background pair with the
/// `ckio.overlap.*` counters. `--pr 10` is the collective-output-plane
/// anchor (`BENCH_pr10.json`): naive vs aggregated PFS write ops, the
/// zero-PFS-read read-after-write residency claim, lazy-close forced
/// writebacks, and the write-fault flush/close accounting.
fn cmd_bench_json(args: &Args) {
    let pr = args.get_or("pr", 8u32);
    let (json, default_out) = match pr {
        8 => (exp::bench_pr8_json(args.get_or("reps", 3u32)), "BENCH_pr8.json"),
        9 => (exp::bench_pr9_json(args.get_or("reps", 1u32)), "BENCH_pr9.json"),
        10 => (exp::bench_pr10_json(args.get_or("reps", 1u32)), "BENCH_pr10.json"),
        other => {
            eprintln!("unknown --pr {other} (8|9|10)");
            std::process::exit(2);
        }
    };
    let out = args.get("out").unwrap_or(default_out).to_string();
    std::fs::write(&out, &json).unwrap_or_else(|e| panic!("writing {out}: {e}"));
    println!("[json] {out}");
    println!("{json}");
}

fn cmd_artifacts(args: &Args) {
    let dir = args.get("dir").unwrap_or("artifacts").to_string();
    let mut rt = match ckio::runtime::ArtifactRuntime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("PJRT client failed: {e:#}");
            std::process::exit(1);
        }
    };
    match rt.load_dir(&dir) {
        Ok(names) => {
            println!("platform: {}", rt.platform());
            for n in &names {
                println!("  artifact {n}");
            }
            // Smoke-run the smallest gravity artifact. Real jax-lowered
            // modules exceed the built-in interpreter's elementwise
            // subset — report that instead of panicking mid-listing.
            if rt.has("gravity_n256") {
                let n = 256usize;
                let pos: Vec<f32> = (0..n * 3).map(|i| (i as f32 * 0.37).sin()).collect();
                let res = rt.execute(
                    "gravity_n256",
                    &[
                        ckio::runtime::TensorF32::new(vec![n as i64, 3], pos),
                        ckio::runtime::TensorF32::new(vec![n as i64, 3], vec![0.0; n * 3]),
                        ckio::runtime::TensorF32::new(vec![n as i64], vec![1.0; n]),
                        ckio::runtime::TensorF32::scalar(1e-3),
                    ],
                );
                match res {
                    Ok(outs) if outs.len() >= 4 => {
                        println!("gravity_n256 smoke: |acc| sum = {:.4}", outs[3].data[0]);
                    }
                    Ok(outs) => println!("gravity_n256 smoke: unexpected arity {}", outs.len()),
                    Err(e) => println!("gravity_n256 smoke skipped: {e}"),
                }
            }
        }
        Err(e) => {
            eprintln!("artifact load failed: {e:#} (run `make artifacts`)");
            std::process::exit(1);
        }
    }
}
