//! `CkCallback`-style continuations.
//!
//! A callback names *where a result should go*, not how to get there:
//! a chare entry point, a group member on a PE, a broadcast, or a
//! driver-level future. Split-phase APIs (all of CkIO) take callbacks so
//! no PE ever blocks waiting for completion — when the data is ready the
//! continuation is enqueued as an ordinary task.

use super::chare::{ChareRef, CollectionId};
use super::msg::{Ep, Payload};
use super::topology::Pe;

/// Driver-level completion slot, fulfilled during `Engine::run`.
#[derive(Copy, Clone, Eq, PartialEq, Hash, Debug)]
pub struct FutureId(pub u32);

/// A continuation for a split-phase operation.
#[derive(Clone, Debug)]
pub enum Callback {
    /// Invoke `ep` on one chare (array element / singleton). Delivery is
    /// location-managed: it follows the chare across migrations.
    Chare { to: ChareRef, ep: Ep },
    /// Invoke `ep` on the group member of `collection` residing on `pe`.
    Group { collection: CollectionId, pe: Pe, ep: Ep },
    /// Invoke `ep` on every element of an array collection.
    Broadcast { collection: CollectionId, ep: Ep },
    /// Fulfill a driver-level future (ends/records an experiment phase).
    Future(FutureId),
    /// Drop the result.
    Ignore,
}

impl Callback {
    pub fn to_chare(to: ChareRef, ep: Ep) -> Callback {
        Callback::Chare { to, ep }
    }

    pub fn to_group(collection: CollectionId, pe: Pe, ep: Ep) -> Callback {
        Callback::Group { collection, pe, ep }
    }

    /// True if sending to this callback does nothing.
    pub fn is_ignore(&self) -> bool {
        matches!(self, Callback::Ignore)
    }
}

/// A payload paired with the callback it should be delivered to —
/// the unit the I/O subsystem hands back on completion.
#[derive(Debug)]
pub struct Completion {
    pub callback: Callback,
    pub payload: Payload,
}
