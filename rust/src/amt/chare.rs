//! Chares: migratable message-driven objects, arrays, and groups.

use std::any::Any;

use super::engine::Ctx;
use super::msg::Msg;

/// Identifies a chare collection (array, group, or singleton).
#[derive(Copy, Clone, Eq, PartialEq, Ord, PartialOrd, Hash, Debug)]
pub struct CollectionId(pub u32);

/// A reference to one chare: collection + index.
///
/// For groups the index is the PE number; for singletons it is 0.
#[derive(Copy, Clone, Eq, PartialEq, Ord, PartialOrd, Hash, Debug)]
pub struct ChareRef {
    pub collection: CollectionId,
    pub index: u32,
}

impl ChareRef {
    pub fn new(collection: CollectionId, index: u32) -> ChareRef {
        ChareRef { collection, index }
    }
}

/// Kind of a collection — governs addressing and migratability.
#[derive(Copy, Clone, Eq, PartialEq, Debug)]
pub enum CollectionKind {
    /// Indexed, migratable, location-managed (Charm++ chare array).
    Array,
    /// Exactly one element per PE, never migrates (Charm++ group).
    Group,
    /// One element, fixed placement.
    Singleton,
}

/// A message-driven object.
///
/// A chare owns its data; the runtime delivers at most one message at a
/// time (tasks are atomic / non-preemptible). Handlers must never block:
/// long operations are split-phase via [`super::callback::Callback`]s.
pub trait Chare: Any {
    /// Handle one asynchronous method invocation.
    fn receive(&mut self, ctx: &mut Ctx<'_>, msg: Msg);

    /// Modeled serialization size for migration cost (PUP size).
    fn pack_size(&self) -> u64 {
        1024
    }

    /// Hook invoked on the destination PE right after a migration.
    fn on_migrated(&mut self, _ctx: &mut Ctx<'_>) {}

    /// Whether this chare is background/best-effort work (e.g. the
    /// overlap harness's `BgWorker`). The engine charges tasks of
    /// background chares that execute while their PE has an open
    /// I/O-wait window to the TASIO-style overlap counters
    /// (`ckio.overlap.bg_iters` / `ckio.overlap.bg_time`) — the
    /// "iterations fit inside input time" measurement of Figs. 8–9.
    fn is_background(&self) -> bool {
        false
    }

    /// Downcasts for driver-side inspection in tests/experiments.
    fn as_any(&self) -> &dyn Any;
    fn as_any_mut(&mut self) -> &mut dyn Any;
}

/// Implements the `as_any` boilerplate for a chare type.
#[macro_export]
macro_rules! impl_chare_any {
    () => {
        fn as_any(&self) -> &dyn std::any::Any {
            self
        }
        fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
            self
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn refs_compare_and_hash() {
        let a = ChareRef::new(CollectionId(1), 4);
        let b = ChareRef::new(CollectionId(1), 4);
        let c = ChareRef::new(CollectionId(2), 4);
        assert_eq!(a, b);
        assert_ne!(a, c);
        let mut set = std::collections::HashSet::new();
        set.insert(a);
        assert!(set.contains(&b));
        assert!(!set.contains(&c));
    }
}
