//! Time base for the runtime: nanoseconds since engine start.
//!
//! The same `Time` type is used by the virtual (discrete-event) clock and
//! the wall clock, so model code is identical in both modes.

/// Nanoseconds since engine start (virtual or wall).
pub type Time = u64;

/// One nanosecond.
pub const NANOS: Time = 1;
/// One microsecond.
pub const MICROS: Time = 1_000;
/// One millisecond.
pub const MILLIS: Time = 1_000_000;
/// One second.
pub const SECS: Time = 1_000_000_000;

/// Convert to (fractional) seconds.
pub fn to_secs(t: Time) -> f64 {
    t as f64 / SECS as f64
}

/// Convert fractional seconds to `Time` (saturating at 0 for negatives).
pub fn from_secs(s: f64) -> Time {
    if s <= 0.0 {
        0
    } else {
        (s * SECS as f64).round() as Time
    }
}

/// Convert fractional microseconds to `Time`.
pub fn from_micros(us: f64) -> Time {
    from_secs(us * 1e-6)
}

/// Human-readable duration (`"3.25 ms"`).
pub fn human(t: Time) -> String {
    let t = t as f64;
    if t < 1e3 {
        format!("{t:.0} ns")
    } else if t < 1e6 {
        format!("{:.2} us", t / 1e3)
    } else if t < 1e9 {
        format!("{:.2} ms", t / 1e6)
    } else {
        format!("{:.3} s", t / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions_round_trip() {
        assert_eq!(from_secs(1.5), 1_500_000_000);
        assert!((to_secs(250 * MILLIS) - 0.25).abs() < 1e-12);
        assert_eq!(from_micros(250.0), 250 * MICROS);
        assert_eq!(from_secs(-1.0), 0);
    }

    #[test]
    fn human_formats() {
        assert_eq!(human(500), "500 ns");
        assert_eq!(human(1500), "1.50 us");
        assert_eq!(human(3_250_000), "3.25 ms");
        assert_eq!(human(2 * SECS), "2.000 s");
    }
}
