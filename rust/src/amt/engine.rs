//! The runtime engine: an event-driven executor for chares.
//!
//! Two clock modes share all scheduling/routing/chare logic:
//!
//! * **Virtual** — a deterministic discrete-event simulation. Message
//!   latencies come from the [`crate::net`] model, file reads from the
//!   [`crate::pfs::model`] queueing model, and handler compute from
//!   explicit [`Ctx::advance`] charges. This simulates a 16-node × 512-PE
//!   cluster faithfully (contention and all) on one core, which is how
//!   every paper-scale figure is produced.
//! * **Wall** — events run as fast as possible in real time; file reads
//!   are real `pread`s on helper threads ([`crate::pfs::backend`]); chare
//!   handlers may invoke real PJRT executables. Used by the end-to-end
//!   example and integration tests.
//!
//! Scheduling follows Charm++: each PE executes one non-preemptible task
//! at a time from a FIFO queue; nothing ever blocks a PE — all waiting is
//! expressed through [`Callback`] continuations.

use std::any::{Any, TypeId};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::time::Instant;

use crate::metrics::{keys, Metrics};
use crate::net::{NetConfig, Network, Transfer};
use crate::pfs::backend::{LocalDisk, ReadRequest, WriteRequest};
use crate::pfs::model::{PfsConfig, PfsEvent, SimPfs};
use crate::trace::{names as trace_names, Lane as TraceLane, TraceCategory, TraceSink};
use crate::util::rng::Pcg32;

use super::callback::{Callback, FutureId};
use super::chare::{Chare, ChareRef, CollectionId, CollectionKind};
use super::location::{LocationManager, Route};
use super::msg::{Envelope, Ep, Msg, Payload, CONTROL_MSG_BYTES};
use super::protocol::{PayloadKind, ProtocolSpec};
use super::scheduler::{CostModel, PeState};
use super::time::Time;
use super::topology::{NodeId, Pe, Placement, Topology};

/// Which clock drives the engine.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum ClockMode {
    Virtual,
    Wall,
}

/// Engine construction parameters.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub topo: Topology,
    pub clock: ClockMode,
    pub cost: CostModel,
    pub net: NetConfig,
    pub seed: u64,
}

impl EngineConfig {
    /// A virtual-clock cluster of `nodes` × `pes_per_node`.
    pub fn sim(nodes: u32, pes_per_node: u32) -> EngineConfig {
        EngineConfig {
            topo: Topology::new(nodes, pes_per_node),
            clock: ClockMode::Virtual,
            cost: CostModel::default(),
            net: NetConfig::default(),
            seed: 1,
        }
    }

    /// A wall-clock "cluster" multiplexed on this process.
    pub fn real(nodes: u32, pes_per_node: u32) -> EngineConfig {
        EngineConfig { clock: ClockMode::Wall, ..EngineConfig::sim(nodes, pes_per_node) }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// The I/O backend attached to the engine.
pub enum Io {
    /// Simulated Lustre-like PFS (virtual clock).
    Sim(SimPfs),
    /// Real local files + reader thread pool (wall clock).
    Real(LocalDisk),
    /// No I/O in this run.
    None,
}

enum Event {
    /// A message has arrived (over the modeled wire) at `at_pe`.
    /// (Measured: boxing the envelope to shrink heap elements LOSES —
    /// the extra malloc/free outweighs the smaller sift moves.)
    Deliver { at_pe: Pe, env: Envelope },
    /// Pop and execute the next task on `pe`.
    RunNext { pe: Pe },
    /// Simulated-PFS internal event.
    Pfs(PfsEvent),
    /// A migrating chare arrives at its destination.
    MigrateArrive { chare: ChareRef },
}

struct Scheduled {
    at: Time,
    seq: u64,
    ev: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

struct CollectionInfo {
    kind: CollectionKind,
    size: u32,
}

/// Everything handlers may touch through [`Ctx`] (the chare map itself is
/// split out so a running chare can't alias itself).
pub struct Core {
    pub topo: Topology,
    pub cost: CostModel,
    clock: ClockMode,
    now: Time,
    epoch: Instant,
    heap: BinaryHeap<Reverse<Scheduled>>,
    seq: u64,
    pes: Vec<PeState>,
    pub net: Network,
    pub loc: LocationManager,
    pub metrics: Metrics,
    /// Flight recorder (disabled and storage-free by default; installed
    /// by `CkIo::boot_with` when `ServiceConfig::trace` enables it, or
    /// by the armed `crate::trace` station for CLI-traced runs).
    pub trace: TraceSink,
    pub rng: Pcg32,
    pub io: Io,
    futures: Vec<FutureState>,
    collections: Vec<CollectionInfo>,
    /// Dense chare storage: `collection_base[cid] + index` is the slot in
    /// the engine's chare vector (no hashing on the per-task hot path).
    collection_base: Vec<usize>,
    chare_slots: usize,
    pfs_scratch: Vec<crate::pfs::model::Scheduled>,
    /// Hot counters kept as plain fields (flushed into `metrics` when a
    /// run quiesces); the BTreeMap would otherwise be ~4% of runtime.
    n_tasks: u64,
    n_msgs: u64,
    flushed_tasks: u64,
    flushed_msgs: u64,
    /// I/O-wait overlap accounting (TASIO, arXiv 2011.13823): closed
    /// admission-wait windows and the background-chare work that fit
    /// inside them. Per-window state lives in [`PeState`]; these are the
    /// run-wide totals behind the `ckio.overlap.*` keys (flushed with
    /// the other hot counters).
    n_overlap_windows: u64,
    n_overlap_bg_iters: u64,
    overlap_bg_ns: Time,
    overlap_window_ns: Time,
    flushed_overlap_windows: u64,
    flushed_overlap_bg_iters: u64,
    flushed_overlap_bg_ns: Time,
    flushed_overlap_window_ns: Time,
    /// Declared protocols by collection id (see [`Core::register_protocol`]).
    /// Debug builds validate every send to a registered collection;
    /// collections without a spec (test chares, drivers) are exempt.
    protocols: HashMap<u32, ProtocolSpec>,
    /// The chare whose completed task is currently flushing its sends,
    /// named in protocol-violation panics; `None` means driver-injected.
    debug_sender: Option<ChareRef>,
}

struct FutureState {
    expected: u32,
    arrived: Vec<(Time, Payload)>,
}

impl Core {
    /// Current time (ns since engine start).
    pub fn now(&self) -> Time {
        self.now
    }

    fn wall_now(&self) -> Time {
        self.epoch.elapsed().as_nanos() as Time
    }

    fn push(&mut self, at: Time, ev: Event) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(Scheduled { at, seq, ev }));
    }

    /// Resolve the first-hop PE for an envelope's destination.
    fn first_hop(&self, from: Pe, to: ChareRef) -> Pe {
        if self.loc.is_array(to.collection) {
            self.loc.lookup_from(from, to)
        } else {
            match self.collections[to.collection.0 as usize].kind {
                CollectionKind::Group => Pe(to.index),
                CollectionKind::Singleton | CollectionKind::Array => self.loc.pe_of(to),
            }
        }
    }

    /// Declare `cid`'s message protocol. From then on (in debug builds)
    /// every send addressed to the collection is validated against the
    /// spec at enqueue time — see [`Core::validate_send`].
    pub fn register_protocol(&mut self, cid: CollectionId, spec: ProtocolSpec) {
        self.protocols.insert(cid.0, spec);
    }

    /// Name the currently-flushing sender for violation messages.
    fn sender_name(&self) -> String {
        match self.debug_sender {
            Some(s) => match self.protocols.get(&s.collection.0) {
                Some(spec) => format!("{}[{}]", spec.chare, s.index),
                None => format!("{s:?}"),
            },
            None => "driver".to_string(),
        }
    }

    /// Debug-build check of one enqueued send against the registered
    /// protocol of its destination (compiled out of release builds).
    /// Turns the receiver-side downcast panic into a structured error
    /// naming the sending chare, the EP constant, and both type names.
    fn validate_send(&self, env: &Envelope) {
        if !cfg!(debug_assertions) {
            return;
        }
        if env.msg.ep == EP_ON_MIGRATED {
            return; // engine-internal arrival hook, never declared
        }
        let Some(spec) = self.protocols.get(&env.to.collection.0) else {
            return;
        };
        let Some(h) = spec.handler(env.msg.ep) else {
            panic!(
                "protocol violation: {} sends undeclared ep {} to {}[{}]",
                self.sender_name(),
                env.msg.ep,
                spec.chare,
                env.to.index
            );
        };
        let sent_id = match env.msg.payload.value_type_id() {
            // Pure signals pass everywhere: broadcasts and completion
            // callbacks legitimately deliver no payload, and an empty
            // payload where one was expected still panics in `Msg::take`
            // with full EP/target context.
            None => return,
            Some(id) if id == TypeId::of::<()>() => return, // signal-equivalent
            Some(id) => id,
        };
        let ok = match h.payload {
            PayloadKind::Any => true,
            PayloadKind::Signal => false,
            PayloadKind::Type { id, .. } => id == sent_id,
        };
        if !ok {
            panic!(
                "protocol violation: {} -> {}[{}].{}: sent {}, handler decodes {}",
                self.sender_name(),
                spec.chare,
                env.to.index,
                h.name,
                env.msg.payload.type_name(),
                h.payload.name()
            );
        }
    }

    /// Schedule a send departing at `t` from `from`.
    fn schedule_send(&mut self, t: Time, env: Envelope, class: Transfer) {
        self.validate_send(&env);
        self.n_msgs += 1;
        let dest = self.first_hop(env.from_pe, env.to);
        if self.trace.on(TraceCategory::Sched) {
            self.trace.instant(
                t,
                TraceCategory::Sched,
                trace_names::SCHED_SEND,
                TraceLane::Pe(env.from_pe.0),
                u64::from(env.msg.ep),
                env.wire_bytes,
                "",
            );
        }
        let delay = match self.clock {
            ClockMode::Virtual => {
                let (topo, from) = (self.topo, env.from_pe);
                self.net.delay(&topo, &mut self.metrics, t, from, dest, env.wire_bytes, class)
            }
            ClockMode::Wall => 0,
        };
        self.push(t + delay, Event::Deliver { at_pe: dest, env });
    }

    /// Fire a callback with a payload at time `t` (zero-delay local task
    /// enqueue for chare targets; immediate resolution for futures).
    fn fire_at(&mut self, t: Time, callback: Callback, payload: Payload, from_pe: Pe) {
        match callback {
            Callback::Chare { to, ep } => {
                let env = Envelope {
                    to,
                    msg: Msg::from_payload(ep, payload),
                    wire_bytes: CONTROL_MSG_BYTES,
                    from_pe,
                };
                self.schedule_send(t, env, Transfer::Eager);
            }
            Callback::Group { collection, pe, ep } => {
                let to = ChareRef::new(collection, pe.0);
                let env = Envelope {
                    to,
                    msg: Msg::from_payload(ep, payload),
                    wire_bytes: CONTROL_MSG_BYTES,
                    from_pe,
                };
                self.schedule_send(t, env, Transfer::Eager);
            }
            Callback::Broadcast { collection, ep } => {
                let size = self.collections[collection.0 as usize].size;
                for i in 0..size {
                    let to = ChareRef::new(collection, i);
                    let env = Envelope {
                        to,
                        msg: Msg::signal(ep),
                        wire_bytes: CONTROL_MSG_BYTES,
                        from_pe,
                    };
                    self.schedule_send(t, env, Transfer::Eager);
                }
                // Broadcast payloads are not cloneable in general; the
                // broadcast itself is the signal. Deliver the payload to
                // nobody (drop).
                drop(payload);
            }
            Callback::Future(id) => {
                let f = self.futures.get_mut(id.0 as usize).expect("unknown future");
                f.arrived.push((t, payload));
            }
            Callback::Ignore => {}
        }
    }

    /// Enqueue a ready task; returns true if the caller should run the
    /// PE's scheduler immediately (the PE is idle and the task is due
    /// now) — this skips a heap round-trip for the common case.
    fn enqueue_task(&mut self, pe: Pe, env: Envelope) -> bool {
        let at = self.now;
        let st = &mut self.pes[pe.0 as usize];
        st.enqueue(env);
        if !st.run_scheduled {
            st.run_scheduled = true;
            let when = st.busy_until.max(at);
            if when == at {
                return true;
            }
            self.push(when, Event::RunNext { pe });
        }
        false
    }

    /// Submit a read to the attached I/O backend; `cb` receives an
    /// [`crate::pfs::IoResult`] payload when the read completes.
    pub fn submit_read(&mut self, pe: Pe, req: ReadRequest, cb: Callback) {
        let now = self.now;
        let node = self.topo.node_of(pe).0;
        match &mut self.io {
            Io::Sim(pfs) => {
                let mut out = std::mem::take(&mut self.pfs_scratch);
                pfs.submit(now, pe, node, req, cb, &mut self.metrics, &mut self.trace, &mut out);
                for s in out.drain(..) {
                    self.push(s.at, Event::Pfs(s.ev));
                }
                self.pfs_scratch = out;
            }
            Io::Real(disk) => disk.submit(pe, req, cb),
            Io::None => panic!("submit_read with no I/O backend attached"),
        }
    }

    /// Submit a write to the attached I/O backend (PR 10); `cb`
    /// receives an [`crate::pfs::IoResult`] payload (no data chunk) when
    /// the write commits. Only the modeled backend writes — the
    /// real-disk pool is a read-only verification harness.
    pub fn submit_write(&mut self, pe: Pe, req: WriteRequest, cb: Callback) {
        let now = self.now;
        let node = self.topo.node_of(pe).0;
        match &mut self.io {
            Io::Sim(pfs) => {
                let mut out = std::mem::take(&mut self.pfs_scratch);
                pfs.submit_write(
                    now,
                    pe,
                    node,
                    req,
                    cb,
                    &mut self.metrics,
                    &mut self.trace,
                    &mut out,
                );
                for s in out.drain(..) {
                    self.push(s.at, Event::Pfs(s.ev));
                }
                self.pfs_scratch = out;
            }
            Io::Real(_) => panic!("submit_write on the read-only real-disk backend"),
            Io::None => panic!("submit_write with no I/O backend attached"),
        }
    }

    /// Open the file system's metadata path (MDS); fires `cb` when done.
    /// On the real backend opens are immediate (the pool opens lazily).
    pub fn open_file(&mut self, pe: Pe, cb: Callback) {
        let t = match &mut self.io {
            Io::Sim(pfs) => pfs.open(self.now),
            _ => self.now,
        };
        self.fire_at(t, cb, Payload::empty(), pe);
    }

    /// Access the simulated PFS (panics on real/none backends).
    pub fn sim_pfs_mut(&mut self) -> &mut SimPfs {
        match &mut self.io {
            Io::Sim(pfs) => pfs,
            _ => panic!("no simulated PFS attached"),
        }
    }

    pub fn sim_pfs(&self) -> &SimPfs {
        match &self.io {
            Io::Sim(pfs) => pfs,
            _ => panic!("no simulated PFS attached"),
        }
    }

    /// Access the real-disk backend (panics on sim/none backends).
    pub fn local_disk_mut(&mut self) -> &mut LocalDisk {
        match &mut self.io {
            Io::Real(d) => d,
            _ => panic!("no real disk attached"),
        }
    }

    /// Number of elements in a collection.
    pub fn collection_size(&self, cid: CollectionId) -> u32 {
        self.collections[cid.0 as usize].size
    }

    /// Events currently scheduled (deliveries, task runs, PFS events).
    /// Boot-time wiring uses this to assert nothing is in flight yet —
    /// i.e. that a pre-run patch of chare state cannot be observed by
    /// any message.
    pub fn pending_events(&self) -> usize {
        self.heap.len()
    }

    /// Dense slot of a chare (collection base + index).
    #[inline]
    fn slot(&self, cref: ChareRef) -> usize {
        self.collection_base[cref.collection.0 as usize] + cref.index as usize
    }

    /// Allocate a collection id + dense slot range.
    fn alloc_collection(&mut self, kind: CollectionKind, size: u32) -> CollectionId {
        let cid = CollectionId(self.collections.len() as u32);
        self.collections.push(CollectionInfo { kind, size });
        self.collection_base.push(self.chare_slots);
        self.chare_slots += size as usize;
        cid
    }

    /// Raise the I/O-wait overlap hint on `pe` (TASIO): the admission
    /// governor queued a ticket for a chare on that PE, so the PE is
    /// logically blocked on input. Waits refcount — the window opens at
    /// the first queued wait and stays open until every wait drains.
    /// While open, [`Engine::run_task`] charges background-chare tasks
    /// on the PE to the overlap counters.
    pub fn io_wait_begin(&mut self, pe: Pe, now: Time) {
        let st = &mut self.pes[pe.0 as usize];
        if st.io_wait_open == 0 {
            st.io_wait_since = now;
            st.io_wait_bg_iters = 0;
            st.io_wait_bg_ns = 0;
        }
        st.io_wait_open += 1;
    }

    /// Drop one I/O wait on `pe`. Closing the last wait folds the window
    /// into the run-wide overlap totals and (when tracing) emits a
    /// `sched/overlap` instant carrying the background iterations that
    /// fit inside it.
    pub fn io_wait_end(&mut self, pe: Pe, now: Time) {
        let st = &mut self.pes[pe.0 as usize];
        debug_assert!(st.io_wait_open > 0, "io_wait_end without a matching begin");
        st.io_wait_open = st.io_wait_open.saturating_sub(1);
        if st.io_wait_open > 0 {
            return;
        }
        let span = now.saturating_sub(st.io_wait_since);
        let (iters, bg_ns) = (st.io_wait_bg_iters, st.io_wait_bg_ns);
        self.n_overlap_windows += 1;
        self.n_overlap_bg_iters += iters;
        self.overlap_bg_ns += bg_ns;
        self.overlap_window_ns += span;
        if self.trace.on(TraceCategory::Sched) {
            self.trace.instant(
                now,
                TraceCategory::Sched,
                trace_names::SCHED_OVERLAP,
                TraceLane::Pe(pe.0),
                iters,
                span,
                "",
            );
        }
    }

    /// Run-wide overlap totals: (closed windows, background iterations
    /// fit inside them, background ns inside them, total window ns).
    pub fn overlap_totals(&self) -> (u64, u64, Time, Time) {
        (self.n_overlap_windows, self.n_overlap_bg_iters, self.overlap_bg_ns, self.overlap_window_ns)
    }

    /// Flush hot counters into the metrics sink (idempotent deltas).
    fn flush_hot_counters(&mut self) {
        self.metrics.count(keys::TASKS, self.n_tasks - self.flushed_tasks);
        self.metrics.count(keys::MSGS, self.n_msgs - self.flushed_msgs);
        self.flushed_tasks = self.n_tasks;
        self.flushed_msgs = self.n_msgs;
        if self.n_overlap_windows > self.flushed_overlap_windows {
            self.metrics
                .count(keys::OVERLAP_WINDOWS, self.n_overlap_windows - self.flushed_overlap_windows);
            self.flushed_overlap_windows = self.n_overlap_windows;
        }
        if self.n_overlap_bg_iters > self.flushed_overlap_bg_iters {
            self.metrics.count(
                keys::OVERLAP_BG_ITERS,
                self.n_overlap_bg_iters - self.flushed_overlap_bg_iters,
            );
            self.flushed_overlap_bg_iters = self.n_overlap_bg_iters;
        }
        if self.overlap_bg_ns > self.flushed_overlap_bg_ns {
            self.metrics.charge(keys::OVERLAP_BG_TIME, self.overlap_bg_ns - self.flushed_overlap_bg_ns);
            self.flushed_overlap_bg_ns = self.overlap_bg_ns;
        }
        if self.overlap_window_ns > self.flushed_overlap_window_ns {
            self.metrics.charge(
                keys::OVERLAP_WINDOW_TIME,
                self.overlap_window_ns - self.flushed_overlap_window_ns,
            );
            self.flushed_overlap_window_ns = self.overlap_window_ns;
        }
        if self.trace.is_enabled() {
            // Ring truncation is never silent: surface the drop count.
            let d = self.trace.take_unflushed_dropped();
            if d > 0 {
                self.metrics.count(keys::TRACE_DROPPED, d);
            }
        }
        self.metrics.set("net.bytes_total", self.net.total_bytes as f64);
        let busy = self.net.total_busy;
        self.metrics.set("net.busy_secs", busy as f64 / 1e9);
    }

    /// Whether the engine runs in wall-clock mode.
    pub fn is_wall(&self) -> bool {
        self.clock == ClockMode::Wall
    }
}

/// Handler-side view of the engine: everything a chare may do while
/// processing a message. Sends and migration are *deferred* to the task's
/// completion time, matching the semantics of a non-preemptible task that
/// computes first and communicates at its end.
pub struct Ctx<'a> {
    pub core: &'a mut Core,
    me: ChareRef,
    pe: Pe,
    advanced: Time,
    sends: Vec<(Envelope, Transfer)>,
    delayed: Vec<(Time, Envelope, Transfer)>,
    fires: Vec<(Callback, Payload)>,
    migrate_to: Option<Pe>,
    wall_start: Option<Instant>,
    creations: Vec<(ChareRef, Box<dyn Chare>)>,
}

impl<'a> Ctx<'a> {
    /// Logical time at which this task started.
    pub fn now(&self) -> Time {
        self.core.now()
    }

    /// This chare's reference.
    pub fn me(&self) -> ChareRef {
        self.me
    }

    /// The PE this chare currently runs on.
    pub fn pe(&self) -> Pe {
        self.pe
    }

    /// The node of the current PE.
    pub fn node(&self) -> NodeId {
        self.core.topo.node_of(self.pe)
    }

    pub fn topo(&self) -> Topology {
        self.core.topo
    }

    /// Charge `d` ns of compute to this task (virtual clock).
    pub fn advance(&mut self, d: Time) {
        self.advanced += d;
    }

    /// Charge compute and account it under a metric key (e.g. the
    /// background-work accounting of Figs. 8–9).
    pub fn charge(&mut self, key: &'static str, d: Time) {
        self.advance(d);
        self.core.metrics.charge(key, d);
    }

    /// Send a control message (small payload).
    pub fn send<T: Any + Send>(&mut self, to: ChareRef, ep: Ep, value: T) {
        self.send_sized(to, ep, Payload::new(value), CONTROL_MSG_BYTES, Transfer::Eager);
    }

    /// Send a pure signal (no payload).
    pub fn signal(&mut self, to: ChareRef, ep: Ep) {
        self.send_sized(to, ep, Payload::empty(), CONTROL_MSG_BYTES, Transfer::Eager);
    }

    /// Send a control message departing `delay` ns after this task
    /// completes (a virtual-clock timer: deadlines, retry backoff).
    /// Delivery is best-effort by design — the receiver must tolerate the
    /// timer firing after the state it guards has moved on.
    pub fn send_after<T: Any + Send>(&mut self, delay: Time, to: ChareRef, ep: Ep, value: T) {
        self.delayed.push((
            delay,
            Envelope {
                to,
                msg: Msg::new(ep, value),
                wire_bytes: CONTROL_MSG_BYTES,
                from_pe: self.pe,
            },
            Transfer::Eager,
        ));
    }

    /// Send with an explicit modeled wire size and transfer class —
    /// the data plane (CkIO chunk delivery) uses this.
    pub fn send_sized(
        &mut self,
        to: ChareRef,
        ep: Ep,
        payload: Payload,
        wire_bytes: u64,
        class: Transfer,
    ) {
        self.sends.push((
            Envelope { to, msg: Msg::from_payload(ep, payload), wire_bytes, from_pe: self.pe },
            class,
        ));
    }

    /// Send to the member of group `cid` on `pe`.
    pub fn send_group<T: Any + Send>(&mut self, cid: CollectionId, pe: Pe, ep: Ep, value: T) {
        self.send(ChareRef::new(cid, pe.0), ep, value);
    }

    /// Broadcast a signal to every element of a collection.
    pub fn broadcast(&mut self, cid: CollectionId, ep: Ep) {
        for i in 0..self.core.collection_size(cid) {
            self.signal(ChareRef::new(cid, i), ep);
        }
    }

    /// Fire a completion callback (deferred to task end).
    pub fn fire(&mut self, cb: Callback, payload: Payload) {
        self.fires.push((cb, payload));
    }

    /// Submit a split-phase read; `cb` gets an `IoResult` payload.
    pub fn submit_read(&mut self, req: ReadRequest, cb: Callback) {
        self.core.submit_read(self.pe, req, cb);
    }

    /// Submit a split-phase write (PR 10); `cb` gets an `IoResult`
    /// payload (outcome only, no data) when the write commits.
    pub fn submit_write(&mut self, req: WriteRequest, cb: Callback) {
        self.core.submit_write(self.pe, req, cb);
    }

    /// Split-phase file open (MDS transaction).
    pub fn open_file(&mut self, cb: Callback) {
        self.core.open_file(self.pe, cb);
    }

    /// Raise the I/O-wait overlap hint for `pe` (see
    /// [`Core::io_wait_begin`]): the data plane calls this when the
    /// governor queues a ticket for a chare on that PE, so background
    /// work drained there during the wait is charged to the
    /// `ckio.overlap.*` counters.
    pub fn io_wait_begin(&mut self, pe: Pe) {
        let now = self.core.now();
        self.core.io_wait_begin(pe, now);
    }

    /// Drop one I/O wait on `pe` (see [`Core::io_wait_end`]).
    pub fn io_wait_end(&mut self, pe: Pe) {
        let now = self.core.now();
        self.core.io_wait_end(pe, now);
    }

    /// Request migration of this chare to `pe` after this task completes.
    pub fn migrate_me(&mut self, pe: Pe) {
        assert!(
            self.core.loc.is_array(self.me.collection),
            "only array elements are migratable"
        );
        self.migrate_to = Some(pe);
    }

    /// Create a new chare array from within a handler (dynamic creation,
    /// as a Charm++ `ckNew` inside an entry method). The collection id is
    /// valid immediately for sends departing at this task's end; the
    /// elements are inserted when the task completes.
    pub fn create_array_now<T: Chare>(
        &mut self,
        n: u32,
        placement: &Placement,
        mut f: impl FnMut(u32) -> T,
    ) -> CollectionId {
        let cid = self.core.alloc_collection(CollectionKind::Array, n);
        let pes = placement.place(&self.core.topo, n as usize);
        self.core.loc.register_array(cid, &pes);
        for i in 0..n {
            self.creations.push((ChareRef::new(cid, i), Box::new(f(i))));
        }
        cid
    }

    /// Declare a dynamically created collection's message protocol; see
    /// [`Core::register_protocol`].
    pub fn register_protocol(&mut self, cid: CollectionId, spec: ProtocolSpec) {
        self.core.register_protocol(cid, spec);
    }

    /// Deterministic per-run RNG.
    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.core.rng
    }

    pub fn metrics(&mut self) -> &mut Metrics {
        &mut self.core.metrics
    }

    /// The flight recorder (a no-op sink unless tracing was enabled).
    pub fn trace(&mut self) -> &mut TraceSink {
        &mut self.core.trace
    }

    /// True in wall-clock (real I/O / real compute) runs.
    pub fn is_wall(&self) -> bool {
        self.core.is_wall()
    }
}

/// The engine: chare storage + [`Core`] + the event loop.
pub struct Engine {
    /// Dense chare storage; index = `Core::slot`.
    chares: Vec<Option<Box<dyn Chare>>>,
    in_transit: HashMap<ChareRef, Box<dyn Chare>>,
    pub core: Core,
}

impl Engine {
    pub fn new(cfg: EngineConfig) -> Engine {
        let npes = cfg.topo.npes();
        Engine {
            chares: Vec::new(),
            in_transit: HashMap::new(),
            core: Core {
                topo: cfg.topo,
                cost: cfg.cost,
                clock: cfg.clock,
                now: 0,
                epoch: Instant::now(),
                heap: BinaryHeap::new(),
                seq: 0,
                pes: (0..npes).map(|_| PeState::default()).collect(),
                net: Network::new(cfg.net, &cfg.topo),
                loc: LocationManager::new(npes),
                metrics: Metrics::new(),
                // The CLI's armed trace station traces every engine built
                // on this thread; otherwise the sink is a storage-free
                // no-op until `CkIo::boot_with` installs one.
                trace: match crate::trace::armed() {
                    Some(tc) => TraceSink::new(&tc),
                    None => TraceSink::disabled(),
                },
                rng: Pcg32::seeded(cfg.seed),
                io: Io::None,
                futures: Vec::new(),
                collections: Vec::new(),
                collection_base: Vec::new(),
                chare_slots: 0,
                pfs_scratch: Vec::new(),
                n_tasks: 0,
                n_msgs: 0,
                flushed_tasks: 0,
                flushed_msgs: 0,
                n_overlap_windows: 0,
                n_overlap_bg_iters: 0,
                overlap_bg_ns: 0,
                overlap_window_ns: 0,
                flushed_overlap_windows: 0,
                flushed_overlap_bg_iters: 0,
                flushed_overlap_bg_ns: 0,
                flushed_overlap_window_ns: 0,
                protocols: HashMap::new(),
                debug_sender: None,
            },
        }
    }

    /// Attach the simulated PFS.
    pub fn with_sim_pfs(mut self, cfg: PfsConfig) -> Engine {
        let seed = self.core.rng.next_u64();
        self.core.io = Io::Sim(SimPfs::new(cfg, self.core.topo.nodes, seed));
        self
    }

    /// Attach a real-disk backend with `threads` reader threads.
    pub fn with_local_disk(mut self, threads: usize) -> Engine {
        self.core.io = Io::Real(LocalDisk::new(threads));
        self
    }

    fn alloc_collection(&mut self, kind: CollectionKind, size: u32) -> CollectionId {
        let cid = self.core.alloc_collection(kind, size);
        self.chares.resize_with(self.core.chare_slots, || None);
        cid
    }

    fn put(&mut self, cref: ChareRef, boxed: Box<dyn Chare>) {
        let slot = self.core.slot(cref);
        if slot >= self.chares.len() {
            self.chares.resize_with(self.core.chare_slots, || None);
        }
        debug_assert!(self.chares[slot].is_none(), "slot occupied: {cref:?}");
        self.chares[slot] = Some(boxed);
    }

    /// Create a migratable chare array of `n` elements.
    pub fn create_array<T: Chare>(
        &mut self,
        n: u32,
        placement: &Placement,
        mut f: impl FnMut(u32) -> T,
    ) -> CollectionId {
        let cid = self.alloc_collection(CollectionKind::Array, n);
        let pes = placement.place(&self.core.topo, n as usize);
        self.core.loc.register_array(cid, &pes);
        for i in 0..n {
            self.put(ChareRef::new(cid, i), Box::new(f(i)));
        }
        cid
    }

    /// Create a group: one element per PE, indexed by PE number.
    pub fn create_group<T: Chare>(&mut self, mut f: impl FnMut(Pe) -> T) -> CollectionId {
        let npes = self.core.topo.npes();
        let cid = self.alloc_collection(CollectionKind::Group, npes);
        for pe in 0..npes {
            self.put(ChareRef::new(cid, pe), Box::new(f(Pe(pe))));
        }
        cid
    }

    /// Create a singleton chare pinned to `pe`.
    pub fn create_singleton<T: Chare>(&mut self, pe: Pe, chare: T) -> ChareRef {
        let cid = self.alloc_collection(CollectionKind::Singleton, 1);
        let cref = ChareRef::new(cid, 0);
        // Singletons are tracked by the location manager as a 1-element
        // non-migrating array so `first_hop` resolves them uniformly.
        self.core.loc.register_array(cid, &[pe]);
        self.put(cref, Box::new(chare));
        cref
    }

    /// Allocate a future fulfilled after `expected` callback deliveries.
    pub fn future(&mut self, expected: u32) -> FutureId {
        let id = FutureId(self.core.futures.len() as u32);
        self.core.futures.push(FutureState { expected, arrived: Vec::new() });
        id
    }

    /// Whether a future has received all expected deliveries.
    pub fn future_done(&self, id: FutureId) -> bool {
        let f = &self.core.futures[id.0 as usize];
        f.arrived.len() as u32 >= f.expected
    }

    /// Take a future's deliveries (time, payload).
    pub fn take_future(&mut self, id: FutureId) -> Vec<(Time, Payload)> {
        std::mem::take(&mut self.core.futures[id.0 as usize].arrived)
    }

    /// Declare a collection's message protocol (driver-side); see
    /// [`Core::register_protocol`].
    pub fn register_protocol(&mut self, cid: CollectionId, spec: ProtocolSpec) {
        self.core.register_protocol(cid, spec);
    }

    /// Inject a message from "outside" (driver code) at the current time.
    pub fn inject<T: Any + Send>(&mut self, to: ChareRef, ep: Ep, value: T) {
        let env = Envelope {
            to,
            msg: Msg::new(ep, value),
            wire_bytes: CONTROL_MSG_BYTES,
            from_pe: Pe(0),
        };
        let t = self.core.now;
        self.core.schedule_send(t, env, Transfer::Eager);
    }

    /// Inject a payload-free signal.
    pub fn inject_signal(&mut self, to: ChareRef, ep: Ep) {
        let env = Envelope {
            to,
            msg: Msg::signal(ep),
            wire_bytes: CONTROL_MSG_BYTES,
            from_pe: Pe(0),
        };
        let t = self.core.now;
        self.core.schedule_send(t, env, Transfer::Eager);
    }

    /// Borrow a chare for inspection (tests, drivers). Panics if absent.
    pub fn chare<T: Chare>(&self, cref: ChareRef) -> &T {
        let slot = self.core.slot(cref);
        self.chares[slot]
            .as_ref()
            .expect("no such chare")
            .as_any()
            .downcast_ref::<T>()
            .expect("chare type mismatch")
    }

    pub fn chare_mut<T: Chare>(&mut self, cref: ChareRef) -> &mut T {
        let slot = self.core.slot(cref);
        self.chares[slot]
            .as_mut()
            .expect("no such chare")
            .as_any_mut()
            .downcast_mut::<T>()
            .expect("chare type mismatch")
    }

    /// Current PE of an array element (driver-side).
    pub fn pe_of(&self, cref: ChareRef) -> Pe {
        self.core.loc.pe_of(cref)
    }

    /// Per-PE scheduler state (utilization reporting).
    pub fn pe_state(&self, pe: Pe) -> &PeState {
        &self.core.pes[pe.0 as usize]
    }

    /// Run to quiescence: no events pending and no I/O in flight.
    /// Returns the final time (virtual ns, or wall ns elapsed).
    pub fn run(&mut self) -> Time {
        loop {
            // Wall mode: fold in any real I/O completions first.
            if let Io::Real(_) = self.core.io {
                self.drain_real_completions(false);
            }
            let Some(Reverse(sch)) = self.core.heap.pop() else {
                // Nothing scheduled: block on real I/O if some is in flight.
                if let Io::Real(disk) = &self.core.io {
                    if disk.in_flight() > 0 {
                        self.drain_real_completions(true);
                        continue;
                    }
                }
                break;
            };
            match self.core.clock {
                ClockMode::Virtual => {
                    debug_assert!(sch.at >= self.core.now, "time went backwards");
                    self.core.now = sch.at;
                }
                ClockMode::Wall => self.core.now = self.core.wall_now(),
            }
            self.handle(sch.ev);
        }
        self.core.flush_hot_counters();
        self.core.now
    }

    fn drain_real_completions(&mut self, block: bool) {
        // Collect first to appease the borrow checker.
        let mut got = Vec::new();
        if let Io::Real(disk) = &mut self.core.io {
            if block {
                if let Ok(c) = disk.completions.recv() {
                    disk.note_completion();
                    got.push(c);
                }
            }
            while let Ok(c) = disk.completions.try_recv() {
                disk.note_completion();
                got.push(c);
            }
        }
        for c in got {
            let t = self.core.wall_now();
            self.core.now = t;
            self.core
                .fire_at(t, c.callback, Payload::new(c.result), c.pe);
        }
    }

    fn handle(&mut self, ev: Event) {
        match ev {
            Event::Deliver { at_pe, env } => self.deliver(at_pe, env),
            Event::RunNext { pe } => self.run_task(pe),
            Event::Pfs(pev) => {
                let now = self.core.now;
                let mut out = std::mem::take(&mut self.core.pfs_scratch);
                let done = match &mut self.core.io {
                    Io::Sim(pfs) => {
                        pfs.on_event(now, pev, &mut self.core.metrics, &mut self.core.trace, &mut out)
                    }
                    _ => None,
                };
                for s in out.drain(..) {
                    self.core.push(s.at, Event::Pfs(s.ev));
                }
                self.core.pfs_scratch = out;
                if let Some(d) = done {
                    self.core
                        .fire_at(now, d.callback, Payload::new(d.result), d.pe);
                }
            }
            Event::MigrateArrive { chare } => {
                let boxed = self.in_transit.remove(&chare).expect("arriving chare not in transit");
                let slot = self.core.slot(chare);
                debug_assert!(self.chares[slot].is_none());
                self.chares[slot] = Some(boxed);
                let flushed = self.core.loc.finish_migration(chare);
                self.core.metrics.count(keys::MIGRATIONS, 1);
                let dest = self.core.loc.pe_of(chare);
                // Flush messages buffered at home while in flight.
                for env in flushed {
                    let t = self.core.now;
                    self.core.push(t, Event::Deliver { at_pe: dest, env });
                }
                // Run the arrival hook as a task so it's properly charged.
                let npe = dest;
                let on_migrated_env = Envelope {
                    to: chare,
                    msg: Msg::signal(EP_ON_MIGRATED),
                    wire_bytes: 0,
                    from_pe: npe,
                };
                if self.core.enqueue_task(npe, on_migrated_env) {
                    self.run_task(npe);
                }
            }
        }
    }

    fn deliver(&mut self, at_pe: Pe, env: Envelope) {
        if self.core.loc.is_array(env.to.collection) {
            match self.core.loc.route(at_pe, env.to) {
                Route::Deliver => {
                    // Caches only matter for elements that have migrated;
                    // array-map routing covers everything else.
                    if self.core.loc.has_migrated(env.to) {
                        self.core.loc.refresh_cache(env.from_pe, env.to);
                    }
                    if self.core.enqueue_task(at_pe, env) {
                        self.run_task(at_pe);
                    }
                }
                Route::Forward(next) => {
                    self.core.metrics.count(keys::FWD_HOPS, 1);
                    let t = self.core.now;
                    let (topo, bytes) = (self.core.topo, env.wire_bytes);
                    let delay = match self.core.clock {
                        ClockMode::Virtual => self.core.net.delay(
                            &topo,
                            &mut self.core.metrics,
                            t,
                            at_pe,
                            next,
                            bytes,
                            Transfer::Eager,
                        ),
                        ClockMode::Wall => 0,
                    };
                    self.core.push(t + delay, Event::Deliver { at_pe: next, env });
                }
                Route::Buffered => self.core.loc.buffer_at_home(env.to, env),
            }
        } else {
            // Groups: index *is* the PE.
            debug_assert_eq!(at_pe.0, env.to.index, "group message at wrong PE");
            if self.core.enqueue_task(at_pe, env) {
                self.run_task(at_pe);
            }
        }
    }

    fn run_task(&mut self, pe: Pe) {
        let st = &mut self.core.pes[pe.0 as usize];
        let Some(env) = st.queue.pop_front() else {
            st.run_scheduled = false;
            return;
        };
        let to = env.to;
        let wire_bytes = env.wire_bytes;
        let task_ep = env.msg.ep;
        let slot = self.core.slot(to);
        let Some(mut chare) = self.chares[slot].take() else {
            // The chare migrated away after this message was queued here
            // (or is in flight): re-present it to the router, which will
            // forward it (charging the hop) or buffer it at the home PE.
            if self.core.loc.is_array(to.collection) {
                self.deliver(pe, env);
                let st = &mut self.core.pes[pe.0 as usize];
                if st.queue.is_empty() {
                    st.run_scheduled = false;
                } else {
                    let when = st.busy_until.max(self.core.now);
                    self.core.push(when, Event::RunNext { pe });
                }
                return;
            }
            panic!("task for missing chare {to:?} on {pe:?}");
        };

        let mut ctx = Ctx {
            core: &mut self.core,
            me: to,
            pe,
            advanced: 0,
            sends: Vec::new(),
            delayed: Vec::new(),
            fires: Vec::new(),
            migrate_to: None,
            wall_start: None,
            creations: Vec::new(),
        };
        if ctx.core.clock == ClockMode::Wall {
            ctx.wall_start = Some(Instant::now());
        }
        let mut msg = env.msg;
        msg.target = Some(to); // diagnostic context for `Msg::take` panics
        if msg.ep == EP_ON_MIGRATED {
            chare.on_migrated(&mut ctx);
        } else {
            chare.receive(&mut ctx, msg);
        }

        let advanced = match ctx.wall_start {
            Some(s) => s.elapsed().as_nanos() as Time,
            None => ctx.advanced,
        };
        let sends = std::mem::take(&mut ctx.sends);
        let delayed = std::mem::take(&mut ctx.delayed);
        let fires = std::mem::take(&mut ctx.fires);
        let creations = std::mem::take(&mut ctx.creations);
        let migrate_to = ctx.migrate_to;

        let cost = self.core.cost.task_cost(advanced, wire_bytes);
        let start = self.core.now;
        let done_t = start + cost;
        let st = &mut self.core.pes[pe.0 as usize];
        st.busy_until = done_t;
        st.account(cost);
        // TASIO overlap accounting: a background-chare task that ran
        // while this PE had an open I/O-wait window is an iteration
        // that fit inside input time.
        if st.io_wait_open > 0 && chare.is_background() {
            st.io_wait_bg_iters += 1;
            st.io_wait_bg_ns += cost;
        }
        self.core.n_tasks += 1;
        if self.core.trace.on(TraceCategory::Sched) {
            self.core.trace.complete(
                start,
                cost,
                TraceCategory::Sched,
                trace_names::SCHED_TASK,
                TraceLane::Pe(pe.0),
                0,
                u64::from(task_ep),
                u64::from(to.index),
                "",
            );
        }

        // Dynamically created chares exist before any message can reach
        // them (sends depart at `done_t`, delivery events come later).
        for (cref, boxed) in creations {
            self.put(cref, boxed);
        }

        // Communications depart at task completion. The flushing chare is
        // recorded so a protocol-violation panic can name its sender.
        self.core.debug_sender = Some(to);
        for (env, class) in sends {
            self.core.schedule_send(done_t, env, class);
        }
        for (delay, env, class) in delayed {
            self.core.schedule_send(done_t + delay, env, class);
        }
        for (cb, payload) in fires {
            self.core.fire_at(done_t, cb, payload, pe);
        }
        self.core.debug_sender = None;

        // Migration or reinsertion.
        match migrate_to {
            Some(dest) if dest != pe => {
                let bytes = chare.pack_size();
                self.core.loc.begin_migration(to, dest);
                self.in_transit.insert(to, chare);
                let (topo, m) = (self.core.topo, &mut self.core.metrics);
                let delay = match self.core.clock {
                    ClockMode::Virtual => {
                        self.core.net.delay(&topo, m, done_t, pe, dest, bytes, Transfer::Eager)
                    }
                    ClockMode::Wall => 0,
                };
                self.core.push(done_t + delay, Event::MigrateArrive { chare: to });
            }
            _ => {
                self.chares[slot] = Some(chare);
            }
        }

        // Keep the PE's scheduler running.
        let st = &mut self.core.pes[pe.0 as usize];
        if st.queue.is_empty() {
            st.run_scheduled = false;
        } else {
            let when = st.busy_until;
            self.core.push(when, Event::RunNext { pe });
        }
    }
}

impl Drop for Engine {
    /// Hand the sink to the armed trace station (a no-op for untraced
    /// engines and unarmed threads) so CLI-traced experiment drivers
    /// need no signature changes to surface their timelines.
    fn drop(&mut self) {
        if self.core.trace.is_enabled() {
            crate::trace::deposit(std::mem::take(&mut self.core.trace));
        }
    }
}

/// Reserved entry point used internally for the post-migration hook.
pub const EP_ON_MIGRATED: Ep = u32::MAX;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amt::time::MILLIS;
    use crate::impl_chare_any;

    // --- test chares -----------------------------------------------------

    /// Pings back to whoever pings it; counts pings.
    struct Ponger {
        pings: u32,
    }
    const EP_PING: Ep = 1;
    impl Chare for Ponger {
        fn receive(&mut self, ctx: &mut Ctx, mut msg: Msg) {
            assert_eq!(msg.ep, EP_PING);
            self.pings += 1;
            let reply_to: Callback = msg.take();
            ctx.fire(reply_to, Payload::new(self.pings));
        }
        impl_chare_any!();
    }

    /// Accumulates compute time in fixed slices, self-scheduling.
    struct Worker {
        slices_left: u32,
        slice: Time,
    }
    const EP_WORK: Ep = 2;
    impl Chare for Worker {
        fn receive(&mut self, ctx: &mut Ctx, msg: Msg) {
            assert_eq!(msg.ep, EP_WORK);
            if self.slices_left == 0 {
                return;
            }
            self.slices_left -= 1;
            ctx.charge("test.work", self.slice);
            let me = ctx.me();
            ctx.signal(me, EP_WORK); // yield and reschedule
        }
        impl_chare_any!();
    }

    /// Migrates itself to a target PE when poked, then reports its PE.
    struct Roamer {
        report: Callback,
        migrated_hook_ran: bool,
    }
    const EP_GO: Ep = 3;
    const EP_WHERE: Ep = 4;
    impl Chare for Roamer {
        fn receive(&mut self, ctx: &mut Ctx, mut msg: Msg) {
            match msg.ep {
                EP_GO => {
                    let dest: Pe = msg.take();
                    ctx.migrate_me(dest);
                }
                EP_WHERE => {
                    let pe = ctx.pe();
                    ctx.fire(self.report.clone(), Payload::new(pe));
                }
                _ => unreachable!(),
            }
        }
        fn on_migrated(&mut self, _ctx: &mut Ctx) {
            self.migrated_hook_ran = true;
        }
        impl_chare_any!();
    }

    // --- tests -----------------------------------------------------------

    #[test]
    fn ping_pong_round_trip() {
        let mut eng = Engine::new(EngineConfig::sim(2, 2));
        let ponger = eng.create_singleton(Pe(3), Ponger { pings: 0 });
        let fut = eng.future(1);
        eng.inject(ponger, EP_PING, Callback::Future(fut));
        let end = eng.run();
        assert!(end > 0, "virtual time should advance");
        assert!(eng.future_done(fut));
        let mut got = eng.take_future(fut);
        let (t, mut payload) = got.pop().unwrap();
        assert!(t > 0);
        assert_eq!(payload.take::<u32>(), 1);
        assert_eq!(eng.chare::<Ponger>(ponger).pings, 1);
    }

    #[test]
    fn virtual_time_matches_charged_work() {
        let mut eng = Engine::new(EngineConfig::sim(1, 1));
        let cid = eng.create_array(1, &Placement::RoundRobinPes, |_| Worker {
            slices_left: 10,
            slice: MILLIS,
        });
        eng.inject_signal(ChareRef::new(cid, 0), EP_WORK);
        let end = eng.run();
        // 10 slices of 1 ms plus small per-task overheads.
        assert!(end >= 10 * MILLIS, "end={end}");
        assert!(end < 11 * MILLIS, "end={end}");
        assert_eq!(eng.core.metrics.duration("test.work"), 10 * MILLIS);
        assert_eq!(eng.pe_state(Pe(0)).tasks_run, 11); // 10 work + 1 no-op
    }

    #[test]
    fn send_after_delivers_at_the_delayed_time() {
        struct Timer {
            cb: Callback,
            armed: bool,
        }
        const EP_ARM: Ep = 1;
        const EP_FIRE: Ep = 2;
        impl Chare for Timer {
            fn receive(&mut self, ctx: &mut Ctx, msg: Msg) {
                match msg.ep {
                    EP_ARM => {
                        self.armed = true;
                        let me = ctx.me();
                        ctx.send_after(5 * MILLIS, me, EP_FIRE, 7u32);
                    }
                    EP_FIRE => {
                        assert!(self.armed);
                        let now = ctx.now();
                        ctx.fire(self.cb.clone(), Payload::new(now));
                    }
                    _ => unreachable!(),
                }
            }
            impl_chare_any!();
        }
        let mut eng = Engine::new(EngineConfig::sim(1, 1));
        let fut = eng.future(1);
        let t = eng.create_singleton(Pe(0), Timer { cb: Callback::Future(fut), armed: false });
        eng.inject_signal(t, EP_ARM);
        eng.run();
        assert!(eng.future_done(fut));
        let (at, _) = eng.take_future(fut).pop().unwrap();
        assert!(at >= 5 * MILLIS, "timer fired early: {at}");
        assert!(at < 6 * MILLIS, "timer fired far late: {at}");
    }

    #[test]
    fn tasks_on_one_pe_serialize_tasks_on_two_dont() {
        let run = |pes: u32| -> Time {
            let mut eng = Engine::new(EngineConfig::sim(1, pes));
            let cid = eng.create_array(2, &Placement::RoundRobinPes, |_| Worker {
                slices_left: 50,
                slice: MILLIS,
            });
            eng.inject_signal(ChareRef::new(cid, 0), EP_WORK);
            eng.inject_signal(ChareRef::new(cid, 1), EP_WORK);
            eng.run()
        };
        let serial = run(1);
        let parallel = run(2);
        assert!(serial > 95 * MILLIS, "serial={serial}");
        assert!(parallel < 55 * MILLIS, "parallel={parallel}");
    }

    #[test]
    fn migration_preserves_state_and_routes_messages() {
        let mut eng = Engine::new(EngineConfig::sim(2, 1));
        let fut = eng.future(1);
        let cid = eng.create_array(1, &Placement::Explicit(vec![Pe(0)]), |_| Roamer {
            report: Callback::Future(fut),
            migrated_hook_ran: false,
        });
        let roamer = ChareRef::new(cid, 0);
        assert_eq!(eng.pe_of(roamer), Pe(0));
        eng.inject(roamer, EP_GO, Pe(1));
        // Queued behind the migration: must chase the chare to PE 1.
        eng.inject_signal(roamer, EP_WHERE);
        eng.run();
        assert_eq!(eng.pe_of(roamer), Pe(1));
        assert!(eng.chare::<Roamer>(roamer).migrated_hook_ran);
        let mut got = eng.take_future(fut);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].1.take::<Pe>(), Pe(1));
        assert!(eng.core.metrics.counter(keys::MIGRATIONS) >= 1);
    }

    /// PR 9 satellite (AMT): repeated migrations are each counted
    /// exactly once, and a probe injected between hops always finds the
    /// element at its newest PE — stale routes are corrected, never
    /// trusted.
    #[test]
    fn chained_migrations_count_once_each_and_routing_follows() {
        let mut eng = Engine::new(EngineConfig::sim(2, 2));
        let fut = eng.future(3);
        let cid = eng.create_array(1, &Placement::Explicit(vec![Pe(0)]), |_| Roamer {
            report: Callback::Future(fut),
            migrated_hook_ran: false,
        });
        let roamer = ChareRef::new(cid, 0);
        for dest in [1u32, 2, 3] {
            eng.inject(roamer, EP_GO, Pe(dest));
            eng.inject_signal(roamer, EP_WHERE);
            eng.run();
            assert_eq!(eng.pe_of(roamer), Pe(dest));
        }
        assert_eq!(eng.core.metrics.counter(keys::MIGRATIONS), 3, "one count per hop");
        let got = eng.take_future(fut);
        let pes: Vec<Pe> = got.into_iter().map(|(_, mut p)| p.take::<Pe>()).collect();
        assert_eq!(pes, vec![Pe(1), Pe(2), Pe(3)], "each probe chased its hop");
        assert_eq!(eng.core.loc.buffered_count(), 0, "no stranded forwarded envelopes");
    }

    /// PR 9 satellite (AMT): a burst of messages already in flight when
    /// the element migrates is forwarded in full — none lost, none
    /// delivered at the old PE — and the location manager buffers
    /// nothing once the migration completes.
    #[test]
    fn in_flight_burst_is_forwarded_across_migration() {
        let mut eng = Engine::new(EngineConfig::sim(2, 1));
        let fut = eng.future(8);
        let cid = eng.create_array(1, &Placement::Explicit(vec![Pe(0)]), |_| Roamer {
            report: Callback::Future(fut),
            migrated_hook_ran: false,
        });
        let roamer = ChareRef::new(cid, 0);
        eng.inject(roamer, EP_GO, Pe(1));
        for _ in 0..8 {
            eng.inject_signal(roamer, EP_WHERE);
        }
        eng.run();
        assert_eq!(eng.pe_of(roamer), Pe(1));
        let got = eng.take_future(fut);
        assert_eq!(got.len(), 8, "every in-flight probe must be delivered");
        for (_, mut p) in got {
            assert_eq!(p.take::<Pe>(), Pe(1), "probes must not land on the old PE");
        }
        assert_eq!(eng.core.metrics.counter(keys::MIGRATIONS), 1);
        assert_eq!(eng.core.loc.buffered_count(), 0);
    }

    /// PR 9 satellite (AMT): `on_migrated` runs on the new PE before any
    /// forwarded message is delivered — arrival-side state is ready
    /// before traffic resumes.
    #[test]
    fn on_migrated_runs_before_forwarded_messages() {
        struct Arrival {
            probes_after_hook: u32,
            hook_ran: bool,
        }
        const EP_AGO: Ep = 1;
        const EP_APROBE: Ep = 2;
        impl Chare for Arrival {
            fn receive(&mut self, ctx: &mut Ctx, mut msg: Msg) {
                match msg.ep {
                    EP_AGO => {
                        let dest: Pe = msg.take();
                        ctx.migrate_me(dest);
                    }
                    EP_APROBE => {
                        assert!(self.hook_ran, "forwarded message delivered before on_migrated");
                        self.probes_after_hook += 1;
                    }
                    _ => unreachable!(),
                }
            }
            fn on_migrated(&mut self, _ctx: &mut Ctx) {
                self.hook_ran = true;
            }
            impl_chare_any!();
        }
        let mut eng = Engine::new(EngineConfig::sim(2, 1));
        let cid = eng.create_array(1, &Placement::Explicit(vec![Pe(0)]), |_| Arrival {
            probes_after_hook: 0,
            hook_ran: false,
        });
        let a = ChareRef::new(cid, 0);
        eng.inject(a, EP_AGO, Pe(1));
        for _ in 0..3 {
            eng.inject_signal(a, EP_APROBE);
        }
        eng.run();
        let arrived = eng.chare::<Arrival>(a);
        assert!(arrived.hook_ran);
        assert_eq!(arrived.probes_after_hook, 3, "all probes delivered after the hook");
    }

    /// PR 9 satellite (AMT): after a migration settles, fresh sends
    /// route on the updated location, and the element can migrate back —
    /// the old home PE's entry was corrected, not merely bypassed.
    #[test]
    fn post_migration_sends_route_fresh_and_element_can_return() {
        let mut eng = Engine::new(EngineConfig::sim(2, 1));
        let fut = eng.future(2);
        let cid = eng.create_array(1, &Placement::Explicit(vec![Pe(0)]), |_| Roamer {
            report: Callback::Future(fut),
            migrated_hook_ran: false,
        });
        let roamer = ChareRef::new(cid, 0);
        eng.inject(roamer, EP_GO, Pe(1));
        eng.run();
        assert_eq!(eng.pe_of(roamer), Pe(1));
        eng.inject_signal(roamer, EP_WHERE);
        eng.run();
        // Return trip: the corrected route must work in both directions.
        eng.inject(roamer, EP_GO, Pe(0));
        eng.inject_signal(roamer, EP_WHERE);
        eng.run();
        assert_eq!(eng.pe_of(roamer), Pe(0));
        let got = eng.take_future(fut);
        let pes: Vec<Pe> = got.into_iter().map(|(_, mut p)| p.take::<Pe>()).collect();
        assert_eq!(pes, vec![Pe(1), Pe(0)]);
        assert_eq!(eng.core.metrics.counter(keys::MIGRATIONS), 2);
        assert_eq!(eng.core.loc.buffered_count(), 0);
    }

    #[test]
    fn group_members_live_on_their_pes() {
        struct WhereAmI {
            cb: Callback,
        }
        impl Chare for WhereAmI {
            fn receive(&mut self, ctx: &mut Ctx, _msg: Msg) {
                let pe = ctx.pe();
                ctx.fire(self.cb.clone(), Payload::new(pe));
            }
            impl_chare_any!();
        }
        let mut eng = Engine::new(EngineConfig::sim(2, 2));
        let fut = eng.future(4);
        let grp = eng.create_group(|_| WhereAmI { cb: Callback::Future(fut) });
        for pe in 0..4 {
            eng.inject_signal(ChareRef::new(grp, pe), 0);
        }
        eng.run();
        let mut pes: Vec<u32> = eng
            .take_future(fut)
            .into_iter()
            .map(|(_, mut p)| p.take::<Pe>().0)
            .collect();
        pes.sort_unstable();
        assert_eq!(pes, vec![0, 1, 2, 3]);
    }

    #[test]
    fn sim_read_completes_and_verifies() {
        use crate::pfs::{pattern, PfsConfig};
        struct Reader {
            done: Callback,
        }
        const EP_START: Ep = 1;
        const EP_DATA: Ep = 2;
        impl Chare for Reader {
            fn receive(&mut self, ctx: &mut Ctx, mut msg: Msg) {
                match msg.ep {
                    EP_START => {
                        let me = ctx.me();
                        ctx.submit_read(
                            ReadRequest {
                                file: crate::pfs::FileId(0),
                                offset: 4096,
                                len: 64 << 10,
                                user: 42,
                            },
                            Callback::to_chare(me, EP_DATA),
                        );
                    }
                    EP_DATA => {
                        let r: crate::pfs::IoResult = msg.take();
                        assert_eq!(r.user, 42);
                        assert_eq!(r.offset, 4096);
                        let bytes = r.chunk.bytes.as_ref().expect("materialized");
                        assert_eq!(pattern::verify(r.file, r.offset, bytes), None);
                        ctx.fire(self.done.clone(), Payload::empty());
                    }
                    _ => unreachable!(),
                }
            }
            impl_chare_any!();
        }
        let mut eng = Engine::new(EngineConfig::sim(1, 2)).with_sim_pfs(PfsConfig {
            materialize: true,
            ..PfsConfig::default()
        });
        eng.core.sim_pfs_mut().create_file(1 << 20);
        let fut = eng.future(1);
        let r = eng.create_singleton(Pe(1), Reader { done: Callback::Future(fut) });
        eng.inject_signal(r, EP_START);
        let end = eng.run();
        assert!(eng.future_done(fut));
        assert!(end > MILLIS, "a 64 KiB read should take >1ms of modeled time, got {end}");
    }

    #[test]
    fn wall_clock_real_disk_round_trip() {
        use crate::pfs::pattern;
        struct Reader {
            done: Callback,
        }
        const EP_START: Ep = 1;
        const EP_DATA: Ep = 2;
        impl Chare for Reader {
            fn receive(&mut self, ctx: &mut Ctx, mut msg: Msg) {
                match msg.ep {
                    EP_START => {
                        let me = ctx.me();
                        ctx.submit_read(
                            ReadRequest {
                                file: crate::pfs::FileId(0),
                                offset: 0,
                                len: 128 << 10,
                                user: 0,
                            },
                            Callback::to_chare(me, EP_DATA),
                        );
                    }
                    EP_DATA => {
                        let r: crate::pfs::IoResult = msg.take();
                        let bytes = r.chunk.bytes.as_ref().unwrap();
                        assert_eq!(pattern::verify(r.file, 0, bytes), None);
                        ctx.fire(self.done.clone(), Payload::empty());
                    }
                    _ => unreachable!(),
                }
            }
            impl_chare_any!();
        }
        let dir = std::env::temp_dir().join("ckio_engine_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("wall.bin");
        pattern::write_file(&path, crate::pfs::FileId(0), 256 << 10).unwrap();

        let mut eng = Engine::new(EngineConfig::real(1, 1)).with_local_disk(2);
        eng.core.local_disk_mut().register_file(&path);
        let fut = eng.future(1);
        let r = eng.create_singleton(Pe(0), Reader { done: Callback::Future(fut) });
        eng.inject_signal(r, EP_START);
        eng.run();
        assert!(eng.future_done(fut));
    }

    #[test]
    fn broadcast_reaches_every_element() {
        struct Counter {
            cb: Callback,
        }
        impl Chare for Counter {
            fn receive(&mut self, ctx: &mut Ctx, _msg: Msg) {
                ctx.fire(self.cb.clone(), Payload::empty());
            }
            impl_chare_any!();
        }
        let mut eng = Engine::new(EngineConfig::sim(2, 4));
        let fut = eng.future(16);
        let cid = eng.create_array(16, &Placement::RoundRobinPes, |_| Counter {
            cb: Callback::Future(fut),
        });
        // A broadcast callback fired from outside:
        let t = eng.core.now();
        eng.core
            .fire_at(t, Callback::Broadcast { collection: cid, ep: 0 }, Payload::empty(), Pe(0));
        eng.run();
        assert!(eng.future_done(fut));
        assert_eq!(eng.take_future(fut).len(), 16);
    }
}
