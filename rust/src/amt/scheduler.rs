//! Per-PE scheduling state.
//!
//! Each PE runs one non-preemptible task at a time off a FIFO queue, as in
//! Charm++'s user-space scheduler. In virtual-clock mode the `busy_until`
//! horizon serializes tasks in *logical* time; utilization counters feed
//! the overlap experiments (paper Figs. 8–9).

use std::collections::VecDeque;

use super::msg::Envelope;
use super::time::Time;

/// Scheduler state for one PE.
#[derive(Debug, Default)]
pub struct PeState {
    /// Ready tasks, FIFO.
    pub queue: VecDeque<Envelope>,
    /// Logical time until which this PE is executing its current task.
    pub busy_until: Time,
    /// Whether a `RunNext` event is already scheduled for this PE.
    pub run_scheduled: bool,
    /// Total logical ns spent executing tasks (all kinds).
    pub busy_ns: u64,
    /// Total tasks executed.
    pub tasks_run: u64,
    /// Peak queue depth observed (backpressure signal).
    pub max_queue_depth: usize,
    /// I/O-wait overlap hint (TASIO, arXiv 2011.13823): number of
    /// admission waits currently open on this PE. Raised by the data
    /// plane when the governor queues a ticket for a chare on this PE
    /// and lowered when the wait drains; while > 0 the engine charges
    /// background-chare tasks to the overlap counters.
    pub io_wait_open: u32,
    /// When the current overlap window opened (first queued wait).
    pub io_wait_since: Time,
    /// Background-chare tasks run inside the current window.
    pub io_wait_bg_iters: u64,
    /// Logical ns of background-chare execution inside the current
    /// window.
    pub io_wait_bg_ns: Time,
}

impl PeState {
    /// Enqueue a ready task.
    pub fn enqueue(&mut self, env: Envelope) {
        self.queue.push_back(env);
        self.max_queue_depth = self.max_queue_depth.max(self.queue.len());
    }

    /// Account one executed task.
    pub fn account(&mut self, cost: Time) {
        self.busy_ns += cost;
        self.tasks_run += 1;
    }
}

/// Task cost model: what the runtime charges around each handler.
#[derive(Copy, Clone, Debug)]
pub struct CostModel {
    /// Fixed scheduling/dispatch overhead per task (queue pop, message
    /// header handling). Charm++ measures ~1 µs per message send+recv.
    pub dispatch_overhead: Time,
    /// Per-byte cost of touching a delivered payload (cache-line fill);
    /// applied to wire_bytes when a task's payload is consumed.
    pub touch_per_byte_ns: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            dispatch_overhead: 800, // 0.8 µs
            touch_per_byte_ns: 0.0, // charged explicitly by handlers that copy
        }
    }
}

impl CostModel {
    /// Total charged cost for a task that advanced `advanced` ns itself.
    pub fn task_cost(&self, advanced: Time, wire_bytes: u64) -> Time {
        self.dispatch_overhead + advanced + (self.touch_per_byte_ns * wire_bytes as f64) as Time
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amt::chare::{ChareRef, CollectionId};
    use crate::amt::msg::Msg;
    use crate::amt::topology::Pe;

    fn env() -> Envelope {
        Envelope {
            to: ChareRef::new(CollectionId(0), 0),
            msg: Msg::signal(0),
            wire_bytes: 100,
            from_pe: Pe(0),
        }
    }

    #[test]
    fn queue_depth_tracking() {
        let mut pe = PeState::default();
        pe.enqueue(env());
        pe.enqueue(env());
        pe.queue.pop_front();
        pe.enqueue(env());
        assert_eq!(pe.max_queue_depth, 2);
        assert_eq!(pe.queue.len(), 2);
    }

    #[test]
    fn cost_model_sums() {
        let cm = CostModel { dispatch_overhead: 1000, touch_per_byte_ns: 0.5 };
        assert_eq!(cm.task_cost(500, 100), 1000 + 500 + 50);
    }

    #[test]
    fn accounting() {
        let mut pe = PeState::default();
        pe.account(100);
        pe.account(250);
        assert_eq!(pe.busy_ns, 350);
        assert_eq!(pe.tasks_run, 2);
    }
}
