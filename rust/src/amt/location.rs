//! Location management for migratable chare arrays.
//!
//! Charm++ semantics: every array element has a *home* PE that always
//! knows its authoritative location. Senders keep per-PE location caches;
//! a message sent with a stale cache entry is forwarded (cached-PE → home
//! → actual), each hop paying interconnect cost. While an element is in
//! flight between PEs its home buffers messages and flushes them on
//! arrival. CkIO relies on this to let clients migrate between reads
//! (paper §IV-A.3, Figs. 10–12).

use std::collections::HashMap;

use super::chare::{ChareRef, CollectionId};
use super::msg::Envelope;
use super::topology::Pe;

/// Where an array element currently is.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Residence {
    On(Pe),
    /// Packed and in transit; home buffers messages until arrival.
    InFlight { dest: Pe },
}

/// Authoritative location state for one array collection.
#[derive(Debug)]
struct ArrayLoc {
    residence: Vec<Residence>,
    /// Initial placement (the Charm++ array map): every PE can compute
    /// it, so messages to never-migrated elements need no forwarding.
    initial: Vec<Pe>,
    /// Elements that have ever migrated (only these can have stale
    /// caches).
    ever_migrated: std::collections::HashSet<u32>,
    /// Messages buffered at the home PE while the element is in flight.
    buffered: HashMap<u32, Vec<Envelope>>,
}

/// The runtime-wide location manager.
#[derive(Debug, Default)]
pub struct LocationManager {
    /// Indexed by `CollectionId.0` (collection ids are sequential);
    /// `None` for non-array collections (groups).
    arrays: Vec<Option<ArrayLoc>>,
    /// Per-PE location caches: what each PE believes about element homes.
    caches: Vec<HashMap<ChareRef, Pe>>,
    npes: u32,
    /// Total forwarding hops taken by mis-delivered messages (metric).
    pub forward_hops: u64,
}

/// Outcome of presenting a message at a PE.
#[derive(Debug, PartialEq, Eq)]
pub enum Route {
    /// The element lives here: deliver.
    Deliver,
    /// Not here: forward to this PE (hop charged by caller).
    Forward(Pe),
    /// Element is in flight and this is its home: the manager buffered
    /// the message; it will be flushed when migration completes.
    Buffered,
}

impl LocationManager {
    pub fn new(npes: u32) -> LocationManager {
        LocationManager {
            arrays: Vec::new(),
            caches: (0..npes).map(|_| HashMap::new()).collect(),
            npes,
            forward_hops: 0,
        }
    }

    /// Register a migratable array with its initial element placement.
    pub fn register_array(&mut self, cid: CollectionId, placement: &[Pe]) {
        let residence = placement.iter().map(|&p| Residence::On(p)).collect();
        let idx = cid.0 as usize;
        if idx >= self.arrays.len() {
            self.arrays.resize_with(idx + 1, || None);
        }
        self.arrays[idx] = Some(ArrayLoc {
            residence,
            initial: placement.to_vec(),
            ever_migrated: Default::default(),
            buffered: HashMap::new(),
        });
    }

    /// Whether a collection is location-managed (registered as an array).
    #[inline]
    pub fn is_array(&self, cid: CollectionId) -> bool {
        self.arrays.get(cid.0 as usize).is_some_and(|a| a.is_some())
    }

    #[inline]
    fn arr(&self, cid: CollectionId) -> &ArrayLoc {
        self.arrays[cid.0 as usize].as_ref().expect("unregistered array")
    }

    #[inline]
    fn arr_mut(&mut self, cid: CollectionId) -> &mut ArrayLoc {
        self.arrays[cid.0 as usize].as_mut().expect("unregistered array")
    }

    /// The home PE of an element (fixed hash placement, as in Charm++).
    pub fn home(&self, chare: ChareRef) -> Pe {
        Pe(chare.index % self.npes)
    }

    /// Authoritative residence.
    pub fn residence(&self, chare: ChareRef) -> &Residence {
        &self.arr(chare.collection).residence[chare.index as usize]
    }

    /// Where PE `from` should first send a message for `chare`.
    ///
    /// Charm++ semantics: the initial placement comes from the array map,
    /// which every PE can evaluate — so elements that never migrated are
    /// addressed exactly. Only migrated elements fall back to the
    /// sender's cache, then the home PE.
    pub fn lookup_from(&self, from: Pe, chare: ChareRef) -> Pe {
        let arr = self.arr(chare.collection);
        if !arr.ever_migrated.contains(&chare.index) {
            return arr.initial[chare.index as usize];
        }
        if let Some(&pe) = self.caches[from.0 as usize].get(&chare) {
            return pe;
        }
        self.home(chare)
    }

    /// Decide what a PE holding a message for `chare` should do with it.
    /// `Forward` results must be re-presented at the returned PE; a
    /// `Buffered` result means the caller must hand the envelope to
    /// [`LocationManager::buffer_at_home`].
    pub fn route(&mut self, here: Pe, chare: ChareRef) -> Route {
        let home = self.home(chare);
        let arr = self.arr(chare.collection);
        match arr.residence[chare.index as usize] {
            Residence::On(pe) if pe == here => Route::Deliver,
            Residence::On(pe) => {
                self.forward_hops += 1;
                // Anyone who is not the element's host forwards: the home
                // knows the truth; others redirect to home first unless
                // they *are* the home (then straight to the actual PE).
                Route::Forward(if here == home { pe } else { home })
            }
            Residence::InFlight { .. } => {
                if here == home {
                    Route::Buffered
                } else {
                    self.forward_hops += 1;
                    Route::Forward(home)
                }
            }
        }
    }

    /// Buffer a message at the element's home while it is in flight.
    pub fn buffer_at_home(&mut self, chare: ChareRef, env: Envelope) {
        let arr = self.arr_mut(chare.collection);
        debug_assert!(matches!(arr.residence[chare.index as usize], Residence::InFlight { .. }));
        arr.buffered.entry(chare.index).or_default().push(env);
    }

    /// Record that a sender's cache should now point at the true location.
    pub fn refresh_cache(&mut self, pe: Pe, chare: ChareRef) {
        if let Residence::On(actual) = self.residence(chare).clone() {
            self.caches[pe.0 as usize].insert(chare, actual);
        }
    }

    /// Begin migrating an element toward `dest`.
    pub fn begin_migration(&mut self, chare: ChareRef, dest: Pe) {
        let arr = self.arr_mut(chare.collection);
        arr.ever_migrated.insert(chare.index);
        arr.residence[chare.index as usize] = Residence::InFlight { dest };
    }

    /// Complete a migration; returns messages buffered at home to flush.
    pub fn finish_migration(&mut self, chare: ChareRef) -> Vec<Envelope> {
        let arr = self.arr_mut(chare.collection);
        let dest = match arr.residence[chare.index as usize] {
            Residence::InFlight { dest } => dest,
            ref r => panic!("finish_migration on non-inflight element: {r:?}"),
        };
        arr.residence[chare.index as usize] = Residence::On(dest);
        arr.buffered.remove(&chare.index).unwrap_or_default()
    }

    /// Whether an element has ever migrated (cache maintenance filter).
    #[inline]
    pub fn has_migrated(&self, chare: ChareRef) -> bool {
        self.arr(chare.collection).ever_migrated.contains(&chare.index)
    }

    /// Current PE of an element, panicking if in flight.
    pub fn pe_of(&self, chare: ChareRef) -> Pe {
        match self.residence(chare) {
            Residence::On(pe) => *pe,
            Residence::InFlight { .. } => panic!("pe_of: element in flight"),
        }
    }

    /// Messages currently buffered at homes for in-flight elements,
    /// across every registered array (leak checks: must be 0 at
    /// quiescence — a stranded forward means a migration never
    /// completed).
    pub fn buffered_count(&self) -> usize {
        self.arrays
            .iter()
            .flatten()
            .map(|a| a.buffered.values().map(Vec::len).sum::<usize>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amt::msg::Msg;

    const CID: CollectionId = CollectionId(9);

    fn env(to: ChareRef) -> Envelope {
        Envelope { to, msg: Msg::signal(0), wire_bytes: 64, from_pe: Pe(0) }
    }

    fn setup() -> (LocationManager, ChareRef) {
        let mut lm = LocationManager::new(4);
        lm.register_array(CID, &[Pe(0), Pe(1), Pe(2), Pe(3)]);
        (lm, ChareRef::new(CID, 2))
    }

    #[test]
    fn home_is_index_mod_npes() {
        let (lm, c) = setup();
        assert_eq!(lm.home(c), Pe(2));
        assert_eq!(lm.home(ChareRef::new(CID, 5)), Pe(1));
    }

    #[test]
    fn direct_delivery_when_resident() {
        let (mut lm, c) = setup();
        assert_eq!(lm.route(Pe(2), c), Route::Deliver);
        assert_eq!(lm.forward_hops, 0);
    }

    #[test]
    fn stale_cache_forwards_via_home() {
        let (mut lm, c) = setup();
        // Move element 2 from PE2 to PE0.
        lm.begin_migration(c, Pe(0));
        let flushed = lm.finish_migration(c);
        assert!(flushed.is_empty());
        // A message presented at the old PE forwards to home (PE2 IS home
        // here so it goes straight to actual); present at a random PE:
        match lm.route(Pe(3), c) {
            Route::Forward(pe) => assert_eq!(pe, Pe(2)), // to home first
            r => panic!("unexpected {r:?}"),
        }
        // Home knows the truth:
        match lm.route(Pe(2), c) {
            Route::Forward(pe) => assert_eq!(pe, Pe(0)),
            r => panic!("unexpected {r:?}"),
        }
        assert_eq!(lm.route(Pe(0), c), Route::Deliver);
        assert_eq!(lm.forward_hops, 2);
    }

    #[test]
    fn inflight_buffers_at_home_and_flushes() {
        let (mut lm, c) = setup();
        lm.begin_migration(c, Pe(1));
        // at home → buffered (caller hands the envelope over)
        assert_eq!(lm.route(Pe(2), c), Route::Buffered);
        lm.buffer_at_home(c, env(c));
        // elsewhere → forwarded to home
        match lm.route(Pe(0), c) {
            Route::Forward(pe) => assert_eq!(pe, Pe(2)),
            r => panic!("unexpected {r:?}"),
        }
        let flushed = lm.finish_migration(c);
        assert_eq!(flushed.len(), 1);
        assert_eq!(lm.pe_of(c), Pe(1));
    }

    #[test]
    fn cache_refresh_updates_lookup() {
        let (mut lm, c) = setup();
        lm.begin_migration(c, Pe(0));
        lm.finish_migration(c);
        assert_eq!(lm.lookup_from(Pe(3), c), Pe(2)); // home guess
        lm.refresh_cache(Pe(3), c);
        assert_eq!(lm.lookup_from(Pe(3), c), Pe(0)); // cached truth
    }

    #[test]
    #[should_panic]
    fn finish_without_begin_panics() {
        let (mut lm, c) = setup();
        lm.finish_migration(c);
    }
}
