//! Over-decomposed, message-driven task runtime (Charm++-like substrate).
//!
//! The paper's system (CkIO) is a library *on top of* Charm++; since no
//! such runtime exists in Rust we build the substrate from scratch:
//!
//! * [`chare`] — migratable message-driven objects, arrays and groups,
//! * [`engine`] — the event-driven executor with a **virtual** clock
//!   (deterministic discrete-event simulation of an N-node × P-PE cluster,
//!   used for every paper-scale figure) or a **wall** clock (real file
//!   reads on helper threads + real PJRT compute, used by the end-to-end
//!   example),
//! * [`scheduler`] — per-PE run queues: one non-preemptible task at a
//!   time, no PE ever blocks (split-phase I/O only),
//! * [`location`] — home-based location management so messages chase
//!   migrating chares (extra forwarding hops are charged to the network
//!   model, as in Charm++),
//! * [`callback`] — `CkCallback`-style continuations,
//! * [`protocol`] — declared per-chare message protocols, verified
//!   sound at boot and enforced per-send in debug builds,
//! * [`topology`] — node/PE shapes and placement policies.

pub mod callback;
pub mod chare;
pub mod engine;
pub mod location;
pub mod msg;
pub mod protocol;
pub mod scheduler;
pub mod time;
pub mod topology;

pub use callback::Callback;
pub use chare::{Chare, ChareRef, CollectionId};
pub use engine::{Ctx, Engine, EngineConfig};
pub use msg::{Ep, Msg, Payload};
pub use time::{Time, MICROS, MILLIS, NANOS, SECS};
pub use topology::{NodeId, Pe, Placement, Topology};
