//! Declared message protocols: which entry points each chare type
//! handles, what payload type each entry point decodes, and which entry
//! points each chare type sends.
//!
//! The AMT message fabric is untyped — [`Ep`](super::msg::Ep) is a bare
//! `u32` and [`Payload`](super::msg::Payload) erases the value behind
//! `dyn Any` — so a mis-wired endpoint is normally caught only when a
//! test happens to deliver that exact message and the receiver's
//! downcast panics. This module turns the protocol into data:
//!
//! * Each chare-bearing module exports a `protocol_spec()` returning a
//!   [`ProtocolSpec`]: the chare's handled entry points (with payload
//!   types, via [`PayloadKind::of`]) and its declared send sites. Use
//!   the [`ep_spec!`](crate::ep_spec) / [`send_spec!`](crate::send_spec)
//!   macros so the EP constant's *name* travels with its value — both
//!   the boot-time verifier and `ckio-lint` report by name.
//! * [`builtin_table`] collects every in-tree spec into a
//!   [`ProtocolTable`]; [`verify`] proves the table sound: no duplicate
//!   EP value within a chare, every declared send names a chare that
//!   exists, handles that EP, and decodes the same payload type.
//!   `CkIo::boot` runs it on every boot.
//! * In debug builds the engine additionally validates each enqueued
//!   send against the registered specs (see `Core::validate_send`),
//!   turning the receiver-side downcast panic into a structured error
//!   naming the sending chare, the EP constant, and both type names.
//!
//! The `sends` list declares a module's *direct* `ctx.send*` sites.
//! Callback fires (`ctx.fire`) are wired at runtime by whoever built the
//! [`Callback`](super::callback::Callback), so they are covered by the
//! engine's enqueue-time validation rather than by static declaration.
//!
//! Maintenance rule (see ROADMAP.md): any change to a chare's message
//! protocol — a new EP, a changed payload type, a new send site — must
//! update that module's `protocol_spec()` in the same commit. The
//! boot-time verifier and the `ckio-lint` source pass (tier-1 tests and
//! CI) both fail otherwise.

use std::any::TypeId;
use std::collections::HashMap;
use std::fmt;

use super::msg::Ep;

/// What a declared entry point carries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PayloadKind {
    /// No payload (or the unit payload `()`, which the engine treats as
    /// signal-equivalent).
    Signal,
    /// Exactly one concrete payload type.
    Type {
        id: TypeId,
        name: &'static str,
    },
    /// Deliberately polymorphic: more than one concrete type arrives on
    /// this EP (e.g. open-completion callbacks deliver a handle on
    /// success and an error value on failure). The handler is expected
    /// to probe before downcasting; neither the verifier nor the engine
    /// constrains the payload.
    Any,
}

impl PayloadKind {
    /// The kind for one concrete payload type.
    pub fn of<T: 'static>() -> PayloadKind {
        PayloadKind::Type { id: TypeId::of::<T>(), name: std::any::type_name::<T>() }
    }

    /// Full payload type name (or a `(signal)` / `(any)` marker).
    pub fn name(&self) -> &'static str {
        match self {
            PayloadKind::Signal => "(signal)",
            PayloadKind::Type { name, .. } => name,
            PayloadKind::Any => "(any)",
        }
    }

    /// Last path segment of the payload type name — what source code
    /// (and the protocol table in `docs/PROTOCOL.md`) calls the type.
    pub fn short_name(&self) -> &'static str {
        short_type_name(self.name())
    }
}

/// Last `::` segment of a type path; tuples and markers pass through.
pub fn short_type_name(name: &'static str) -> &'static str {
    if name.starts_with('(') || name.ends_with('>') {
        return name;
    }
    name.rsplit("::").next().unwrap_or(name)
}

/// One entry point a chare handles. Build with [`ep_spec!`](crate::ep_spec).
#[derive(Clone, Debug)]
pub struct EpSpec {
    pub ep: Ep,
    /// The `EP_*` constant's name.
    pub name: &'static str,
    pub payload: PayloadKind,
}

/// One entry point a chare sends. Build with [`send_spec!`](crate::send_spec).
#[derive(Clone, Debug)]
pub struct SendSpec {
    /// The *chare name* of the receiver (EP values are only unique
    /// within a chare type, so the target cannot be inferred from the
    /// EP alone).
    pub target: &'static str,
    pub ep: Ep,
    pub name: &'static str,
    pub payload: PayloadKind,
}

/// One chare type's declared protocol.
#[derive(Clone, Debug)]
pub struct ProtocolSpec {
    /// Chare type name (`"Director"`, `"BufferChare"`, …).
    pub chare: &'static str,
    /// Defining source file, relative to `rust/src`
    /// (`"ckio/director.rs"`). `ckio-lint` cross-checks the spec
    /// against this file.
    pub module: &'static str,
    pub handles: Vec<EpSpec>,
    pub sends: Vec<SendSpec>,
}

impl ProtocolSpec {
    /// The handled-EP entry for `ep`, if declared.
    pub fn handler(&self, ep: Ep) -> Option<&EpSpec> {
        self.handles.iter().find(|h| h.ep == ep)
    }
}

/// All declared protocols of one build.
#[derive(Clone, Debug, Default)]
pub struct ProtocolTable {
    pub specs: Vec<ProtocolSpec>,
}

impl ProtocolTable {
    pub fn push(&mut self, spec: ProtocolSpec) {
        self.specs.push(spec);
    }

    pub fn get(&self, chare: &str) -> Option<&ProtocolSpec> {
        self.specs.iter().find(|s| s.chare == chare)
    }
}

/// A soundness violation found by [`verify`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ProtocolError {
    /// Two specs claim the same chare name.
    DuplicateChare { chare: &'static str },
    /// Two handled entry points of one chare share an EP value.
    DuplicateEp { chare: &'static str, ep: Ep, first: &'static str, second: &'static str },
    /// A declared send names a chare no spec declares.
    UnknownTarget { chare: &'static str, ep_name: &'static str, target: &'static str },
    /// A declared send's target does not handle that EP value.
    UnhandledSend { chare: &'static str, ep_name: &'static str, ep: Ep, target: &'static str },
    /// A declared send's payload type differs from the target handler's.
    PayloadMismatch {
        chare: &'static str,
        ep_name: &'static str,
        target: &'static str,
        sent: &'static str,
        handled: &'static str,
    },
}

impl fmt::Display for ProtocolError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ProtocolError::DuplicateChare { chare } => {
                write!(f, "duplicate protocol spec for chare {chare}")
            }
            ProtocolError::DuplicateEp { chare, ep, first, second } => {
                write!(f, "{chare}: {first} and {second} share EP value {ep}")
            }
            ProtocolError::UnknownTarget { chare, ep_name, target } => {
                write!(f, "{chare}: send {ep_name} targets unknown chare {target}")
            }
            ProtocolError::UnhandledSend { chare, ep_name, ep, target } => {
                write!(f, "{chare}: send {ep_name} (ep {ep}) is not handled by {target}")
            }
            ProtocolError::PayloadMismatch { chare, ep_name, target, sent, handled } => {
                write!(
                    f,
                    "{chare}: send {ep_name} carries {sent} but {target} decodes {handled}"
                )
            }
        }
    }
}

/// Render a verification failure as one line per error.
pub fn format_errors(errs: &[ProtocolError]) -> String {
    let lines: Vec<String> = errs.iter().map(|e| format!("  - {e}")).collect();
    format!("protocol table unsound ({} errors):\n{}", errs.len(), lines.join("\n"))
}

/// Is a declared send payload compatible with the target's handler?
fn compatible(sent: &PayloadKind, handled: &PayloadKind) -> bool {
    match (sent, handled) {
        (PayloadKind::Any, _) | (_, PayloadKind::Any) => true,
        (PayloadKind::Signal, PayloadKind::Signal) => true,
        (PayloadKind::Type { id: a, .. }, PayloadKind::Type { id: b, .. }) => a == b,
        _ => false,
    }
}

/// Prove a protocol table sound. Returns every violation, not just the
/// first, so one boot failure reports the whole protocol drift.
pub fn verify(table: &ProtocolTable) -> Result<(), Vec<ProtocolError>> {
    let mut errs = Vec::new();
    let mut by_name: HashMap<&'static str, &ProtocolSpec> = HashMap::new();
    for spec in &table.specs {
        if by_name.insert(spec.chare, spec).is_some() {
            errs.push(ProtocolError::DuplicateChare { chare: spec.chare });
        }
    }
    for spec in &table.specs {
        let mut seen: HashMap<Ep, &'static str> = HashMap::new();
        for h in &spec.handles {
            if let Some(first) = seen.insert(h.ep, h.name) {
                errs.push(ProtocolError::DuplicateEp {
                    chare: spec.chare,
                    ep: h.ep,
                    first,
                    second: h.name,
                });
            }
        }
        for s in &spec.sends {
            let Some(target) = by_name.get(s.target) else {
                errs.push(ProtocolError::UnknownTarget {
                    chare: spec.chare,
                    ep_name: s.name,
                    target: s.target,
                });
                continue;
            };
            let Some(handler) = target.handler(s.ep) else {
                errs.push(ProtocolError::UnhandledSend {
                    chare: spec.chare,
                    ep_name: s.name,
                    ep: s.ep,
                    target: s.target,
                });
                continue;
            };
            if !compatible(&s.payload, &handler.payload) {
                errs.push(ProtocolError::PayloadMismatch {
                    chare: spec.chare,
                    ep_name: s.name,
                    target: s.target,
                    sent: s.payload.short_name(),
                    handled: handler.payload.short_name(),
                });
            }
        }
    }
    if errs.is_empty() {
        Ok(())
    } else {
        Err(errs)
    }
}

/// Every in-tree chare's declared protocol. New chare modules must add
/// their `protocol_spec()` here (and `ckio-lint` will refuse specs whose
/// declared module file disagrees with the code).
pub fn builtin_table() -> ProtocolTable {
    let mut t = ProtocolTable::default();
    for spec in [
        crate::ckio::director::protocol_spec(),
        crate::ckio::manager::protocol_spec(),
        crate::ckio::assembler::protocol_spec(),
        crate::ckio::buffer::protocol_spec(),
        crate::ckio::shard::protocol_spec(),
        crate::ckio::write::assembler_protocol_spec(),
        crate::ckio::write::buffer_protocol_spec(),
        crate::harness::bgwork::protocol_spec(),
        crate::harness::experiments::slice_reader_protocol_spec(),
        crate::harness::experiments::collector_protocol_spec(),
        crate::harness::experiments::mig_client_protocol_spec(),
        crate::harness::experiments::concurrent_client_protocol_spec(),
        crate::harness::experiments::overlap_client_protocol_spec(),
        crate::harness::experiments::rw_client_protocol_spec(),
        crate::baselines::naive::protocol_spec(),
        crate::baselines::collective::protocol_spec(),
        crate::baselines::collective::naive_writer_protocol_spec(),
        crate::apps::changa::treepiece::protocol_spec(),
    ] {
        t.push(spec);
    }
    t
}

/// Build an [`EpSpec`] whose `name` is the spelled-out constant.
///
/// ```ignore
/// ep_spec!(EP_BUF_DATA, PayloadKind::of::<IoResult>())
/// ```
#[macro_export]
macro_rules! ep_spec {
    ($ep:expr, $kind:expr) => {
        $crate::amt::protocol::EpSpec { ep: $ep, name: stringify!($ep), payload: $kind }
    };
}

/// Build a [`SendSpec`] whose `name` is the spelled-out constant.
///
/// ```ignore
/// send_spec!("ReadAssembler", EP_A_PIECE, PayloadKind::of::<PieceMsg>())
/// ```
#[macro_export]
macro_rules! send_spec {
    ($target:expr, $ep:expr, $kind:expr) => {
        $crate::amt::protocol::SendSpec {
            target: $target,
            ep: $ep,
            name: stringify!($ep),
            payload: $kind,
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::{ep_spec, send_spec};

    struct FooMsg;
    struct BarMsg;

    const EP_A: Ep = 1;
    const EP_B: Ep = 2;

    fn receiver() -> ProtocolSpec {
        ProtocolSpec {
            chare: "Receiver",
            module: "tests/receiver.rs",
            handles: vec![
                ep_spec!(EP_A, PayloadKind::of::<FooMsg>()),
                ep_spec!(EP_B, PayloadKind::Signal),
            ],
            sends: vec![],
        }
    }

    fn table_of(specs: Vec<ProtocolSpec>) -> ProtocolTable {
        let mut t = ProtocolTable::default();
        for s in specs {
            t.push(s);
        }
        t
    }

    #[test]
    fn sound_table_verifies() {
        let sender = ProtocolSpec {
            chare: "Sender",
            module: "tests/sender.rs",
            handles: vec![ep_spec!(EP_B, PayloadKind::Signal)],
            sends: vec![
                send_spec!("Receiver", EP_A, PayloadKind::of::<FooMsg>()),
                send_spec!("Receiver", EP_B, PayloadKind::Signal),
            ],
        };
        assert!(verify(&table_of(vec![receiver(), sender])).is_ok());
    }

    #[test]
    fn duplicate_ep_rejected() {
        let mut r = receiver();
        r.handles.push(ep_spec!(EP_A, PayloadKind::Signal));
        let errs = verify(&table_of(vec![r])).unwrap_err();
        assert!(
            matches!(errs[0], ProtocolError::DuplicateEp { ep: 1, .. }),
            "wrong error: {errs:?}"
        );
    }

    #[test]
    fn undeclared_send_rejected() {
        let sender = ProtocolSpec {
            chare: "Sender",
            module: "tests/sender.rs",
            handles: vec![],
            sends: vec![
                send_spec!("Nobody", EP_A, PayloadKind::Signal),
                send_spec!("Receiver", 99, PayloadKind::Signal),
            ],
        };
        let errs = verify(&table_of(vec![receiver(), sender])).unwrap_err();
        assert_eq!(errs.len(), 2, "{errs:?}");
        assert!(matches!(errs[0], ProtocolError::UnknownTarget { target: "Nobody", .. }));
        assert!(matches!(errs[1], ProtocolError::UnhandledSend { ep: 99, .. }));
    }

    #[test]
    fn payload_mismatch_rejected() {
        let sender = ProtocolSpec {
            chare: "Sender",
            module: "tests/sender.rs",
            handles: vec![],
            sends: vec![send_spec!("Receiver", EP_A, PayloadKind::of::<BarMsg>())],
        };
        let errs = verify(&table_of(vec![receiver(), sender])).unwrap_err();
        assert!(
            matches!(errs[0], ProtocolError::PayloadMismatch { handled: "FooMsg", .. }),
            "wrong error: {errs:?}"
        );
        let line = format!("{}", errs[0]);
        assert!(line.contains("BarMsg") && line.contains("FooMsg"), "{line}");
    }

    #[test]
    fn any_is_compatible_with_everything() {
        let sender = ProtocolSpec {
            chare: "Sender",
            module: "tests/sender.rs",
            handles: vec![],
            sends: vec![
                send_spec!("Receiver", EP_A, PayloadKind::Any),
                send_spec!("Receiver", EP_B, PayloadKind::Any),
            ],
        };
        assert!(verify(&table_of(vec![receiver(), sender])).is_ok());
    }

    #[test]
    fn builtin_table_is_sound() {
        let table = builtin_table();
        assert!(table.specs.len() >= 13, "missing specs: {}", table.specs.len());
        if let Err(errs) = verify(&table) {
            panic!("{}", format_errors(&errs));
        }
    }

    #[test]
    fn short_names() {
        assert_eq!(PayloadKind::of::<FooMsg>().short_name(), "FooMsg");
        assert_eq!(PayloadKind::of::<u64>().short_name(), "u64");
        assert_eq!(PayloadKind::of::<(u32, u8)>().short_name(), "(u32, u8)");
        assert_eq!(PayloadKind::Signal.short_name(), "(signal)");
    }
}
