//! Cluster shape (nodes × PEs) and chare placement policies.

/// A processing element (one scheduler instance; Charm++ "PE").
#[derive(Copy, Clone, Eq, PartialEq, Ord, PartialOrd, Hash, Debug)]
pub struct Pe(pub u32);

/// A physical node (shares a NIC and, in the model, intra-node memory bw).
#[derive(Copy, Clone, Eq, PartialEq, Ord, PartialOrd, Hash, Debug)]
pub struct NodeId(pub u32);

/// Cluster shape: `nodes` × `pes_per_node`, PEs numbered node-major.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct Topology {
    pub nodes: u32,
    pub pes_per_node: u32,
}

impl Topology {
    pub fn new(nodes: u32, pes_per_node: u32) -> Topology {
        assert!(nodes > 0 && pes_per_node > 0);
        Topology { nodes, pes_per_node }
    }

    /// Total PE count.
    pub fn npes(&self) -> u32 {
        self.nodes * self.pes_per_node
    }

    /// Node that hosts a PE.
    pub fn node_of(&self, pe: Pe) -> NodeId {
        debug_assert!(pe.0 < self.npes());
        NodeId(pe.0 / self.pes_per_node)
    }

    /// Whether two PEs share a node.
    pub fn same_node(&self, a: Pe, b: Pe) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    /// The PEs hosted by a node.
    pub fn pes_on(&self, node: NodeId) -> impl Iterator<Item = Pe> {
        let lo = node.0 * self.pes_per_node;
        (lo..lo + self.pes_per_node).map(Pe)
    }

    /// All PEs.
    pub fn all_pes(&self) -> impl Iterator<Item = Pe> {
        (0..self.npes()).map(Pe)
    }
}

/// Placement policy for chare-array elements.
///
/// The paper's evaluation depends on placement: buffer chares are spread
/// to maximize file-system parallelism while clients follow the
/// application's decomposition.
#[derive(Clone, Debug)]
pub enum Placement {
    /// Element `i` on PE `i % npes` (Charm++ default round-robin).
    RoundRobinPes,
    /// Element `i` on node `i % nodes`, cycling that node's PEs
    /// (spreads few elements across as many NICs/FS paths as possible).
    RoundRobinNodes,
    /// Contiguous blocks of elements per PE.
    BlockPes,
    /// Explicit per-element placement.
    Explicit(Vec<Pe>),
}

impl Placement {
    /// Compute the PE for each of `n` elements.
    pub fn place(&self, topo: &Topology, n: usize) -> Vec<Pe> {
        let npes = topo.npes() as usize;
        match self {
            Placement::RoundRobinPes => (0..n).map(|i| Pe((i % npes) as u32)).collect(),
            Placement::RoundRobinNodes => (0..n)
                .map(|i| {
                    let node = (i % topo.nodes as usize) as u32;
                    let slot = (i / topo.nodes as usize) % topo.pes_per_node as usize;
                    Pe(node * topo.pes_per_node + slot as u32)
                })
                .collect(),
            Placement::BlockPes => {
                let per = n.div_ceil(npes).max(1);
                (0..n).map(|i| Pe(((i / per) % npes) as u32)).collect()
            }
            Placement::Explicit(pes) => {
                assert_eq!(pes.len(), n, "explicit placement length mismatch");
                pes.clone()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_math() {
        let t = Topology::new(4, 8);
        assert_eq!(t.npes(), 32);
        assert_eq!(t.node_of(Pe(0)), NodeId(0));
        assert_eq!(t.node_of(Pe(7)), NodeId(0));
        assert_eq!(t.node_of(Pe(8)), NodeId(1));
        assert_eq!(t.node_of(Pe(31)), NodeId(3));
        assert!(t.same_node(Pe(8), Pe(15)));
        assert!(!t.same_node(Pe(7), Pe(8)));
        assert_eq!(t.pes_on(NodeId(2)).collect::<Vec<_>>(), (16..24).map(Pe).collect::<Vec<_>>());
    }

    #[test]
    fn round_robin_pes() {
        let t = Topology::new(2, 2);
        let p = Placement::RoundRobinPes.place(&t, 6);
        assert_eq!(p, vec![Pe(0), Pe(1), Pe(2), Pe(3), Pe(0), Pe(1)]);
    }

    #[test]
    fn round_robin_nodes_spreads_across_nics() {
        let t = Topology::new(2, 4);
        let p = Placement::RoundRobinNodes.place(&t, 4);
        // elements alternate node0/node1 before reusing a node
        assert_eq!(t.node_of(p[0]), NodeId(0));
        assert_eq!(t.node_of(p[1]), NodeId(1));
        assert_eq!(t.node_of(p[2]), NodeId(0));
        assert_eq!(t.node_of(p[3]), NodeId(1));
        // and within a node, distinct PEs
        assert_ne!(p[0], p[2]);
    }

    #[test]
    fn block_placement_contiguous() {
        let t = Topology::new(1, 4);
        let p = Placement::BlockPes.place(&t, 8);
        assert_eq!(p, vec![Pe(0), Pe(0), Pe(1), Pe(1), Pe(2), Pe(2), Pe(3), Pe(3)]);
    }

    #[test]
    fn block_placement_fewer_elements_than_pes() {
        let t = Topology::new(1, 8);
        let p = Placement::BlockPes.place(&t, 3);
        assert_eq!(p.len(), 3);
        assert_eq!(p, vec![Pe(0), Pe(1), Pe(2)]);
    }

    #[test]
    #[should_panic]
    fn explicit_length_mismatch_panics() {
        let t = Topology::new(1, 2);
        Placement::Explicit(vec![Pe(0)]).place(&t, 2);
    }
}
