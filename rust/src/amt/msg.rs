//! Messages: asynchronous method invocations between chares.

use std::any::{Any, TypeId};

use super::chare::ChareRef;
use super::topology::Pe;

/// Entry-point id: which method of the target chare a message invokes.
/// Each chare type defines its own `Ep` constants.
pub type Ep = u32;

/// Type-erased message payload.
///
/// Everything runs in one address space, so payloads move as boxed values
/// (the cost of serialization/wire transfer is *modeled* by the network
/// layer using the envelope's `wire_bytes`, matching how Charm++ charges
/// for marshalling without us actually re-encoding). The wrapped value's
/// type name rides along so a mismatched downcast can name what was
/// actually sent, not just what the receiver wanted.
pub struct Payload {
    value: Option<Box<dyn Any + Send>>,
    type_name: &'static str,
}

impl Payload {
    /// Wrap a value.
    pub fn new<T: Any + Send>(v: T) -> Payload {
        Payload { value: Some(Box::new(v)), type_name: std::any::type_name::<T>() }
    }

    /// An empty payload (pure signal).
    pub fn empty() -> Payload {
        Payload { value: None, type_name: "(none)" }
    }

    /// Whether a value is present.
    pub fn is_empty(&self) -> bool {
        self.value.is_none()
    }

    /// The wrapped value's type name (`"(none)"` when empty).
    pub fn type_name(&self) -> &'static str {
        self.type_name
    }

    /// The wrapped value's `TypeId`, if a value is present.
    pub fn value_type_id(&self) -> Option<TypeId> {
        self.value.as_ref().map(|b| (**b).type_id())
    }

    /// Take the value out, panicking on type mismatch — a message sent to
    /// the wrong entry point is a programming error, as in Charm++.
    pub fn take<T: Any>(&mut self) -> T {
        self.try_take().unwrap_or_else(|e| panic!("{e}"))
    }

    /// Take the value out, reporting mismatch / absence as an error that
    /// names both the wanted and the actually-sent type.
    pub fn try_take<T: Any>(&mut self) -> Result<T, String> {
        let sent = self.type_name;
        let boxed = match self.value.take() {
            Some(b) => b,
            None => return Err("payload already taken / empty".to_string()),
        };
        match boxed.downcast::<T>() {
            Ok(v) => Ok(*v),
            Err(_) => Err(format!(
                "payload type mismatch: wanted {}, got {sent}",
                std::any::type_name::<T>()
            )),
        }
    }

    /// Borrow the value without consuming it.
    pub fn peek<T: Any>(&self) -> Option<&T> {
        self.value.as_ref()?.downcast_ref::<T>()
    }
}

impl std::fmt::Debug for Payload {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.value.is_some() {
            write!(f, "Payload({})", self.type_name)
        } else {
            write!(f, "Payload(empty)")
        }
    }
}

/// A message: entry point + payload.
#[derive(Debug)]
pub struct Msg {
    pub ep: Ep,
    pub payload: Payload,
    /// The chare this message was delivered to, stamped by the scheduler
    /// just before `receive`. Diagnostic only: a mismatched `take` in a
    /// receive arm can then name the exact endpoint, not just the types.
    pub target: Option<ChareRef>,
}

impl Msg {
    pub fn new<T: Any + Send>(ep: Ep, v: T) -> Msg {
        Msg { ep, payload: Payload::new(v), target: None }
    }

    pub fn signal(ep: Ep) -> Msg {
        Msg { ep, payload: Payload::empty(), target: None }
    }

    pub fn from_payload(ep: Ep, payload: Payload) -> Msg {
        Msg { ep, payload, target: None }
    }

    /// Shorthand for `self.payload.take()`, with the message's EP and
    /// delivery target appended to any failure so a protocol violation
    /// that slips past the registry is diagnosable from the panic alone.
    pub fn take<T: Any>(&mut self) -> T {
        self.payload.try_take().unwrap_or_else(|e| match self.target {
            Some(to) => panic!("{e} (ep {} -> {to:?})", self.ep),
            None => panic!("{e} (ep {})", self.ep),
        })
    }
}

/// Default modeled size of a control message (headers + small args).
pub const CONTROL_MSG_BYTES: u64 = 256;

/// A routed message: destination + wire-size for the network model.
#[derive(Debug)]
pub struct Envelope {
    pub to: ChareRef,
    pub msg: Msg,
    /// Bytes charged to the interconnect model (payload + headers).
    pub wire_bytes: u64,
    /// Sender PE (for delay computation and location-cache updates).
    pub from_pe: Pe,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_round_trip() {
        let mut p = Payload::new(vec![1u32, 2, 3]);
        assert!(!p.is_empty());
        assert_eq!(p.peek::<Vec<u32>>().unwrap().len(), 3);
        let v: Vec<u32> = p.take();
        assert_eq!(v, vec![1, 2, 3]);
    }

    #[test]
    #[should_panic(expected = "type mismatch")]
    fn payload_type_mismatch_panics() {
        let mut p = Payload::new(1u32);
        let _: String = p.take();
    }

    #[test]
    #[should_panic(expected = "already taken")]
    fn payload_double_take_panics() {
        let mut p = Payload::new(1u32);
        let _: u32 = p.take();
        let _: u32 = p.take();
    }

    #[test]
    fn mismatch_names_both_types() {
        let mut p = Payload::new(1u32);
        let err = p.try_take::<String>().unwrap_err();
        assert!(err.contains("wanted") && err.contains("u32"), "{err}");
    }

    #[test]
    fn msg_take_appends_ep_context() {
        let mut m = Msg::new(9, 1u32);
        m.target = Some(ChareRef::new(super::super::chare::CollectionId(3), 4));
        let got = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _: String = m.take();
        }));
        let err = *got.unwrap_err().downcast::<String>().unwrap();
        assert!(err.contains("type mismatch") && err.contains("ep 9"), "{err}");
    }

    #[test]
    fn signal_is_empty() {
        let m = Msg::signal(7);
        assert_eq!(m.ep, 7);
        assert!(m.payload.is_empty());
        assert_eq!(m.payload.type_name(), "(none)");
    }
}
