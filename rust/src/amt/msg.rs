//! Messages: asynchronous method invocations between chares.

use std::any::Any;

use super::chare::ChareRef;
use super::topology::Pe;

/// Entry-point id: which method of the target chare a message invokes.
/// Each chare type defines its own `Ep` constants.
pub type Ep = u32;

/// Type-erased message payload.
///
/// Everything runs in one address space, so payloads move as boxed values
/// (the cost of serialization/wire transfer is *modeled* by the network
/// layer using the envelope's `wire_bytes`, matching how Charm++ charges
/// for marshalling without us actually re-encoding).
pub struct Payload(Option<Box<dyn Any + Send>>);

impl Payload {
    /// Wrap a value.
    pub fn new<T: Any + Send>(v: T) -> Payload {
        Payload(Some(Box::new(v)))
    }

    /// An empty payload (pure signal).
    pub fn empty() -> Payload {
        Payload(None)
    }

    /// Whether a value is present.
    pub fn is_empty(&self) -> bool {
        self.0.is_none()
    }

    /// Take the value out, panicking on type mismatch — a message sent to
    /// the wrong entry point is a programming error, as in Charm++.
    pub fn take<T: Any>(&mut self) -> T {
        let boxed = self.0.take().expect("payload already taken / empty");
        *boxed.downcast::<T>().unwrap_or_else(|b| {
            panic!(
                "payload type mismatch: wanted {}, got {:?}",
                std::any::type_name::<T>(),
                (*b).type_id()
            )
        })
    }

    /// Borrow the value without consuming it.
    pub fn peek<T: Any>(&self) -> Option<&T> {
        self.0.as_ref()?.downcast_ref::<T>()
    }
}

impl std::fmt::Debug for Payload {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Payload({})", if self.0.is_some() { "some" } else { "empty" })
    }
}

/// A message: entry point + payload.
#[derive(Debug)]
pub struct Msg {
    pub ep: Ep,
    pub payload: Payload,
}

impl Msg {
    pub fn new<T: Any + Send>(ep: Ep, v: T) -> Msg {
        Msg { ep, payload: Payload::new(v) }
    }

    pub fn signal(ep: Ep) -> Msg {
        Msg { ep, payload: Payload::empty() }
    }

    /// Shorthand for `self.payload.take()`.
    pub fn take<T: Any>(&mut self) -> T {
        self.payload.take()
    }
}

/// Default modeled size of a control message (headers + small args).
pub const CONTROL_MSG_BYTES: u64 = 256;

/// A routed message: destination + wire-size for the network model.
#[derive(Debug)]
pub struct Envelope {
    pub to: ChareRef,
    pub msg: Msg,
    /// Bytes charged to the interconnect model (payload + headers).
    pub wire_bytes: u64,
    /// Sender PE (for delay computation and location-cache updates).
    pub from_pe: Pe,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_round_trip() {
        let mut p = Payload::new(vec![1u32, 2, 3]);
        assert!(!p.is_empty());
        assert_eq!(p.peek::<Vec<u32>>().unwrap().len(), 3);
        let v: Vec<u32> = p.take();
        assert_eq!(v, vec![1, 2, 3]);
    }

    #[test]
    #[should_panic(expected = "type mismatch")]
    fn payload_type_mismatch_panics() {
        let mut p = Payload::new(1u32);
        let _: String = p.take();
    }

    #[test]
    #[should_panic(expected = "already taken")]
    fn payload_double_take_panics() {
        let mut p = Payload::new(1u32);
        let _: u32 = p.take();
        let _: u32 = p.take();
    }

    #[test]
    fn signal_is_empty() {
        let m = Msg::signal(7);
        assert_eq!(m.ep, 7);
        assert!(m.payload.is_empty());
    }
}
