//! Flight recorder: structured event tracing for the simulated service.
//!
//! A bounded, virtual-clock-stamped event ring ([`TraceSink`], one per
//! engine `Core`) records span begin/end, complete (begin+duration) and
//! instant events at the existing choke points of the stack — task
//! dispatch and message sends ([`TraceCategory::Sched`]), governor
//! enqueue→grant→done ([`TraceCategory::Ticket`], tagged with the QoS
//! class), PFS RPC issue→complete ([`TraceCategory::Pfs`]), session
//! open→plan→create→first-byte→drain→close
//! ([`TraceCategory::Session`]), span-store traffic
//! ([`TraceCategory::Store`]), placement planning
//! ([`TraceCategory::Place`]) and AIMD cap changes annotated with their
//! cause ([`TraceCategory::Governor`]).
//!
//! Design rules:
//!
//! * **Off by default, zero-allocation when off.** Every recording
//!   method first consults [`TraceSink::on`] — a branch on two plain
//!   fields — and returns immediately for a disabled sink. The default
//!   sink owns no ring storage at all.
//! * **Bounded, never silently truncated.** The ring holds at most
//!   `capacity` events; when full the *oldest* event is dropped and the
//!   `dropped` counter advances. The engine flushes that counter into
//!   `metrics::keys::TRACE_DROPPED` so truncation is always visible.
//! * **Deterministic.** Events are stamped with the engine's virtual
//!   clock, never wall time, and recording never perturbs the
//!   simulation (no `advance`, no RNG draws).
//! * **Name hygiene.** Span/instant names are category-prefixed
//!   (`"session/…"`, `"ticket/…"`, …) and the literals live *only* in
//!   [`names`]; everywhere else refers to the constants. `ckio-lint`'s
//!   trace-literal check enforces this, mirroring the metrics-literal
//!   check.
//!
//! Two ways to enable tracing:
//!
//! * `ServiceConfig::trace` ([`TraceConfig`]) installs a sink at
//!   `CkIo::boot_with` time — per-service opt-in from code.
//! * The thread-local *station* ([`arm`]/[`collect`]) lets the CLI
//!   (`ckio trace <fig-id>`, `ckio fig --trace`) trace unmodified
//!   experiment drivers: while armed, every `Engine::new` on this
//!   thread installs a sink, and every engine drop [`deposit`]s its
//!   sink back for export.
//!
//! [`export_chrome`] renders deposited sinks as Chrome trace-event JSON
//! (loadable in Perfetto / `chrome://tracing`): one process per run per
//! plane (even pids = PE lanes, odd pids = data-plane shard lanes), one
//! thread per PE or shard — a Projections-style timeline of the
//! simulated service.

use std::cell::RefCell;
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::fmt::Write as _;

use crate::amt::time::Time;

/// Event categories; each can be masked independently via
/// [`TraceConfig::categories`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum TraceCategory {
    /// Session lifecycle: open → plan → create → ready → first byte →
    /// drain → close (director, assembler).
    Session,
    /// Admission tickets: enqueue → grant (with waited time) → done
    /// (data-plane shards, tagged with the QoS class).
    Ticket,
    /// PFS read RPCs: issue → complete (simulated PFS model).
    Pfs,
    /// Span-store traffic: take/park/purge and peer fetches.
    Store,
    /// Store-aware placement planning.
    Place,
    /// Admission-governor cap changes, annotated with the AIMD cause.
    Governor,
    /// Engine scheduler: message sends and task dispatch.
    Sched,
}

impl TraceCategory {
    /// Every category, in declaration order.
    pub const ALL: [TraceCategory; 7] = [
        TraceCategory::Session,
        TraceCategory::Ticket,
        TraceCategory::Pfs,
        TraceCategory::Store,
        TraceCategory::Place,
        TraceCategory::Governor,
        TraceCategory::Sched,
    ];

    fn bit(self) -> u8 {
        1 << (self as u8)
    }

    /// Stable lowercase label (also the Chrome `cat` field).
    pub fn label(self) -> &'static str {
        match self {
            TraceCategory::Session => "session",
            TraceCategory::Ticket => "ticket",
            TraceCategory::Pfs => "pfs",
            TraceCategory::Store => "store",
            TraceCategory::Place => "place",
            TraceCategory::Governor => "governor",
            TraceCategory::Sched => "sched",
        }
    }

    /// Inverse of [`TraceCategory::label`] (CLI category filters).
    pub fn parse(s: &str) -> Option<TraceCategory> {
        TraceCategory::ALL.iter().copied().find(|c| c.label() == s)
    }
}

/// A set of [`TraceCategory`], stored as a bitmask.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CategoryMask(u8);

impl CategoryMask {
    pub const fn none() -> CategoryMask {
        CategoryMask(0)
    }

    pub const fn all() -> CategoryMask {
        CategoryMask(0x7f)
    }

    #[must_use]
    pub fn with(self, c: TraceCategory) -> CategoryMask {
        CategoryMask(self.0 | c.bit())
    }

    pub fn contains(self, c: TraceCategory) -> bool {
        self.0 & c.bit() != 0
    }
}

impl Default for CategoryMask {
    fn default() -> CategoryMask {
        CategoryMask::all()
    }
}

/// Default ring capacity (events) when tracing is enabled.
pub const DEFAULT_CAPACITY: usize = 65_536;

/// Flight-recorder configuration (`ServiceConfig::trace`). The default
/// is **disabled**; `TraceConfig::on()` is the enabled-everything
/// convenience the CLI uses.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceConfig {
    /// Master switch; when false the sink is a no-op and owns no
    /// storage.
    pub enabled: bool,
    /// Ring capacity in events; oldest events are dropped (and counted)
    /// beyond this.
    pub capacity: usize,
    /// Which categories to record.
    pub categories: CategoryMask,
}

impl Default for TraceConfig {
    fn default() -> TraceConfig {
        TraceConfig {
            enabled: false,
            capacity: DEFAULT_CAPACITY,
            categories: CategoryMask::all(),
        }
    }
}

impl TraceConfig {
    /// Enabled, default capacity, all categories.
    pub fn on() -> TraceConfig {
        TraceConfig {
            enabled: true,
            ..TraceConfig::default()
        }
    }
}

/// Which timeline lane an event belongs to: a PE (control/compute
/// plane) or a data-plane shard.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lane {
    Pe(u32),
    Shard(u32),
}

/// Event shape: async span begin/end (matched by category + id),
/// self-contained complete spans (begin timestamp + duration), and
/// point-in-time instants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    Begin,
    End,
    Instant,
    Complete { dur: Time },
}

/// One recorded event. `a0`/`a1` are free-form integer arguments
/// (bytes, counts, EPs); `note` is a static annotation such as the
/// AIMD cause.
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    pub ts: Time,
    pub cat: TraceCategory,
    pub kind: EventKind,
    pub name: &'static str,
    pub lane: Lane,
    pub id: u64,
    pub a0: u64,
    pub a1: u64,
    pub note: &'static str,
}

/// The bounded event ring. One per engine `Core`; disabled (and
/// storage-free) unless installed by `CkIo::boot_with` or the armed
/// station.
#[derive(Debug, Default)]
pub struct TraceSink {
    enabled: bool,
    mask: CategoryMask,
    cap: usize,
    ring: VecDeque<TraceEvent>,
    dropped: u64,
    flushed_dropped: u64,
    open_spans: i64,
}

impl TraceSink {
    /// Build a sink from config; a disabled config yields the no-op
    /// sink.
    pub fn new(cfg: &TraceConfig) -> TraceSink {
        if !cfg.enabled {
            return TraceSink::default();
        }
        let cap = cfg.capacity.max(16);
        TraceSink {
            enabled: true,
            mask: cfg.categories,
            cap,
            ring: VecDeque::with_capacity(cap),
            dropped: 0,
            flushed_dropped: 0,
            open_spans: 0,
        }
    }

    /// The no-op sink (what `Core` carries by default).
    pub fn disabled() -> TraceSink {
        TraceSink::default()
    }

    /// Cheap hot-path guard: is this category being recorded?
    #[inline]
    pub fn on(&self, cat: TraceCategory) -> bool {
        self.enabled && self.mask.contains(cat)
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    fn push(&mut self, ev: TraceEvent) {
        if self.ring.len() == self.cap {
            // Drop-oldest, never silently: the counter is flushed into
            // metrics::keys::TRACE_DROPPED by the engine.
            self.ring.pop_front();
            self.dropped += 1;
        }
        self.ring.push_back(ev);
    }

    /// Open an async span; pair with [`TraceSink::end`] using the same
    /// category, name and id.
    pub fn begin(
        &mut self,
        ts: Time,
        cat: TraceCategory,
        name: &'static str,
        lane: Lane,
        id: u64,
        a0: u64,
        a1: u64,
    ) {
        if !self.on(cat) {
            return;
        }
        self.open_spans += 1;
        self.push(TraceEvent {
            ts,
            cat,
            kind: EventKind::Begin,
            name,
            lane,
            id,
            a0,
            a1,
            note: "",
        });
    }

    /// Close an async span opened by [`TraceSink::begin`].
    pub fn end(
        &mut self,
        ts: Time,
        cat: TraceCategory,
        name: &'static str,
        lane: Lane,
        id: u64,
        a0: u64,
        a1: u64,
    ) {
        if !self.on(cat) {
            return;
        }
        self.open_spans -= 1;
        self.push(TraceEvent {
            ts,
            cat,
            kind: EventKind::End,
            name,
            lane,
            id,
            a0,
            a1,
            note: "",
        });
    }

    /// Record a point-in-time event.
    pub fn instant(
        &mut self,
        ts: Time,
        cat: TraceCategory,
        name: &'static str,
        lane: Lane,
        a0: u64,
        a1: u64,
        note: &'static str,
    ) {
        if !self.on(cat) {
            return;
        }
        self.push(TraceEvent {
            ts,
            cat,
            kind: EventKind::Instant,
            name,
            lane,
            id: 0,
            a0,
            a1,
            note,
        });
    }

    /// Record a self-contained span (`ts` may lie in the past — e.g. a
    /// ticket's enqueue time — since the exporter orders by timestamp).
    #[allow(clippy::too_many_arguments)]
    pub fn complete(
        &mut self,
        ts: Time,
        dur: Time,
        cat: TraceCategory,
        name: &'static str,
        lane: Lane,
        id: u64,
        a0: u64,
        a1: u64,
        note: &'static str,
    ) {
        if !self.on(cat) {
            return;
        }
        self.push(TraceEvent {
            ts,
            cat,
            kind: EventKind::Complete { dur },
            name,
            lane,
            id,
            a0,
            a1,
            note,
        });
    }

    /// Events currently resident in the ring (oldest first).
    pub fn events(&self) -> impl Iterator<Item = &TraceEvent> {
        self.ring.iter()
    }

    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// Total events evicted by the drop-oldest policy.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Begin events minus end events — zero at quiescence when every
    /// span was closed (asserted by `assert_service_clean`). Tracked by
    /// counter, independent of ring eviction: a span whose Begin was
    /// evicted still balances.
    pub fn open_spans(&self) -> i64 {
        self.open_spans
    }

    /// Drop-count delta since the last flush (for the engine's
    /// hot-counter flush into metrics).
    pub fn take_unflushed_dropped(&mut self) -> u64 {
        let d = self.dropped - self.flushed_dropped;
        self.flushed_dropped = self.dropped;
        d
    }
}

/// Span/instant name constants — the **only** place trace-name
/// literals may appear (`ckio-lint`'s trace-literal check flags the
/// category-prefixed literals anywhere else outside `trace/`).
pub mod names {
    /// Session active span: start accepted → close acknowledged
    /// (director; id = session id).
    pub const SESSION_ACTIVE: &str = "session/active";
    /// File opened (or re-opened) at the director.
    pub const SESSION_OPEN: &str = "session/open";
    /// Placement plan probe sent to the owning shard.
    pub const SESSION_PLAN: &str = "session/plan";
    /// Buffer array created for a fresh session.
    pub const SESSION_CREATE: &str = "session/create";
    /// Session became ready (all buffers registered and client
    /// notified).
    pub const SESSION_READY: &str = "session/ready";
    /// First assembled read completed on a PE for this session
    /// (assembler).
    pub const SESSION_FIRST_BYTE: &str = "session/first_byte";
    /// One client read assembled: request → last piece (assembler;
    /// complete span).
    pub const SESSION_ASSEMBLY: &str = "session/assembly";
    /// Session close requested; teardown drain begins.
    pub const SESSION_DRAIN: &str = "session/drain";
    /// Session close acknowledged; a1 is the makespan in ns.
    pub const SESSION_CLOSE: &str = "session/close";
    /// Admission ticket deferred by the governor (a0 = tickets still
    /// wanted).
    pub const TICKET_ENQUEUE: &str = "ticket/enqueue";
    /// Admission wait span: enqueue → grant (complete span; dur is the
    /// admission wait, note is the QoS class).
    pub const TICKET_WAIT: &str = "ticket/wait";
    /// Governed PFS read completed and returned its tickets (a0 = n,
    /// a1 = observed service ns).
    pub const TICKET_DONE: &str = "ticket/done";
    /// PFS read RPC span: issue → complete (id = request id).
    pub const PFS_READ: &str = "pfs/read";
    /// Store claim take (a0 = 1 hit / 0 miss).
    pub const STORE_TAKE: &str = "store/take";
    /// Buffer array parked into the store.
    pub const STORE_PARK: &str = "store/park";
    /// File claims purged from the store.
    pub const STORE_PURGE: &str = "store/purge";
    /// Peer fetch span: request sent → data received (complete span on
    /// the requesting buffer's PE).
    pub const STORE_PEER_FETCH: &str = "store/peer_fetch";
    /// Placement plan computed by a shard (a0 = planned slots).
    pub const PLACE_PLAN: &str = "place/plan";
    /// Admission cap change (a0 = new cap, a1 = old cap; note is the
    /// AIMD cause: growth probe vs p50 inflation).
    pub const GOVERNOR_CAP: &str = "governor/cap";
    /// Message scheduled for delivery (a0 = EP, a1 = wire bytes).
    pub const SCHED_SEND: &str = "sched/send";
    /// Task executed on a PE (complete span; a0 = EP).
    pub const SCHED_TASK: &str = "sched/task";
    /// Injected PFS fault surfaced at completion (a0 = request id;
    /// note: transient/persistent/short).
    pub const PFS_FAULT: &str = "pfs/fault";
    /// Retry-plane decision at a buffer (a0 = slot, a1 = attempt;
    /// note: reissue/gave_up).
    pub const PFS_RETRY: &str = "pfs/retry";
    /// Hedged duplicate read enqueued for an overdue attempt (a0 =
    /// slot, a1 = overdue attempt number).
    pub const PFS_HEDGE: &str = "pfs/hedge";
    /// I/O-wait overlap window closed on a PE (PR 9; a0 = background
    /// tasks run inside it, a1 = window span ns).
    pub const SCHED_OVERLAP: &str = "sched/overlap";
    /// Consumer migration advised by the flow matrix (PR 9; a0 =
    /// destination PE, a1 = dominant-source bytes).
    pub const PLACE_CONSUMER_ADVICE: &str = "place/consumer_advice";
    /// Write-session flush barrier (PR 10): flush requested → every
    /// dirty extent durable or degraded (complete span at the director;
    /// a0 = bytes written, a1 = bytes degraded).
    pub const SESSION_FLUSH: &str = "session/flush";
    /// PFS write RPC span: issue → commit (PR 10; id = request id).
    pub const PFS_WRITE: &str = "pfs/write";
    /// Dirty parked span evicted under the store budget: writeback
    /// forced before the bytes may drop (PR 10; a0 = dirty bytes).
    pub const STORE_WRITEBACK: &str = "store/writeback";

    /// The trace catalog: `(event name, emitting module, what it
    /// marks)` for every constant above — rendered into
    /// `docs/OBSERVABILITY.md` by `ckio lint --dump-metrics`. The
    /// category is the prefix before the `/` (also the Chrome `cat`
    /// field); `catalog_covers_every_name` keeps the list complete.
    pub fn catalog() -> Vec<(&'static str, &'static str, &'static str)> {
        vec![
            (SESSION_ACTIVE, "ckio/director.rs", "session active span, start accepted -> close acked"),
            (SESSION_OPEN, "ckio/director.rs", "file opened (or re-opened)"),
            (SESSION_PLAN, "ckio/director.rs", "placement plan probe sent to the owning shard"),
            (SESSION_CREATE, "ckio/director.rs", "buffer array created (note: fresh/planned/rebind)"),
            (SESSION_READY, "ckio/director.rs", "session ready, client notified"),
            (SESSION_FIRST_BYTE, "ckio/assembler.rs", "first assembled read on a PE for this session"),
            (SESSION_ASSEMBLY, "ckio/assembler.rs", "one client read assembled (complete span)"),
            (SESSION_DRAIN, "ckio/director.rs", "session close requested, teardown drain begins"),
            (SESSION_CLOSE, "ckio/director.rs", "session close acknowledged (a0 = makespan ns)"),
            (TICKET_ENQUEUE, "ckio/shard.rs", "admission ticket deferred by the governor"),
            (TICKET_WAIT, "ckio/shard.rs", "admission wait span, enqueue -> grant (note: QoS class)"),
            (TICKET_DONE, "ckio/shard.rs", "governed PFS read returned its tickets"),
            (PFS_READ, "pfs/model.rs", "PFS read RPC span, issue -> complete"),
            (STORE_TAKE, "ckio/shard.rs", "store claim take (note: hit/miss)"),
            (STORE_PARK, "ckio/shard.rs", "buffer array parked into the store"),
            (STORE_PURGE, "ckio/shard.rs", "file claims purged from the store"),
            (STORE_PEER_FETCH, "ckio/buffer.rs", "peer fetch span, request -> data (note: same_pe/cross_pe)"),
            (PLACE_PLAN, "ckio/shard.rs", "placement plan computed by a shard"),
            (GOVERNOR_CAP, "ckio/shard.rs", "admission cap change (note: AIMD cause)"),
            (SCHED_SEND, "amt/engine.rs", "message scheduled for delivery"),
            (SCHED_TASK, "amt/engine.rs", "task executed on a PE (complete span)"),
            (PFS_FAULT, "pfs/model.rs", "injected fault surfaced at completion (note: kind)"),
            (PFS_RETRY, "ckio/buffer.rs", "retry-plane decision (note: reissue/gave_up)"),
            (PFS_HEDGE, "ckio/buffer.rs", "hedged duplicate read enqueued past deadline"),
            (SCHED_OVERLAP, "amt/engine.rs", "I/O-wait overlap window closed on a PE"),
            (PLACE_CONSUMER_ADVICE, "ckio/director.rs", "consumer migration advised by the flow matrix"),
            (SESSION_FLUSH, "ckio/director.rs", "write-session flush barrier (complete span)"),
            (PFS_WRITE, "pfs/model.rs", "PFS write RPC span, issue -> commit"),
            (STORE_WRITEBACK, "ckio/shard.rs", "dirty-span eviction forced a writeback"),
        ]
    }
}

// ---------------------------------------------------------------------------
// The station: thread-local arming + sink collection for the CLI.
// ---------------------------------------------------------------------------

struct Station {
    armed: Option<TraceConfig>,
    sinks: Vec<TraceSink>,
}

thread_local! {
    static STATION: RefCell<Station> = RefCell::new(Station {
        armed: None,
        sinks: Vec::new(),
    });
}

/// Arm the station: subsequent `Engine::new` calls on this thread
/// install a [`TraceSink`] built from `cfg`, and dropped engines
/// deposit their sinks for [`collect`].
pub fn arm(cfg: TraceConfig) {
    STATION.with(|s| {
        let mut s = s.borrow_mut();
        s.armed = Some(cfg);
        s.sinks.clear();
    });
}

/// The armed config, if any (consulted by `Engine::new`).
pub fn armed() -> Option<TraceConfig> {
    STATION.with(|s| s.borrow().armed.clone())
}

/// Disarm and discard any undeposited sinks.
pub fn disarm() {
    STATION.with(|s| {
        let mut s = s.borrow_mut();
        s.armed = None;
        s.sinks.clear();
    });
}

/// Hand a finished engine's sink to the station. No-op when the
/// station is unarmed or the sink is disabled, so ordinary runs never
/// accumulate state.
pub fn deposit(sink: TraceSink) {
    STATION.with(|s| {
        let mut s = s.borrow_mut();
        if s.armed.is_some() && sink.is_enabled() {
            s.sinks.push(sink);
        }
    });
}

/// Drain the deposited sinks (in engine-completion order).
pub fn collect() -> Vec<TraceSink> {
    STATION.with(|s| std::mem::take(&mut s.borrow_mut().sinks))
}

// ---------------------------------------------------------------------------
// Chrome trace-event export (Perfetto / chrome://tracing).
// ---------------------------------------------------------------------------

fn push_event_prefix(out: &mut String, ev: &TraceEvent, pid: u64, tid: u32) {
    let ts_us = ev.ts as f64 / 1000.0;
    let _ = write!(
        out,
        "{{\"name\":\"{}\",\"cat\":\"{}\",\"ts\":{:.3},\"pid\":{},\"tid\":{}",
        ev.name,
        ev.cat.label(),
        ts_us,
        pid,
        tid
    );
}

fn push_args(out: &mut String, ev: &TraceEvent) {
    let _ = write!(out, ",\"args\":{{\"a0\":{},\"a1\":{}", ev.a0, ev.a1);
    if !ev.note.is_empty() {
        let _ = write!(out, ",\"note\":\"{}\"", ev.note);
    }
    out.push_str("}}");
}

/// Render deposited sinks as Chrome trace-event JSON. Each sink (one
/// per traced engine run) gets two processes: pid `2r` for its PE
/// lanes and pid `2r + 1` for its data-plane shard lanes, with one
/// named thread per PE / shard. Async spans use `b`/`e` phases matched
/// by category + id, so overlapping spans on one lane render
/// correctly; a span whose Begin was evicted from the ring shows as an
/// unmatched end, which Perfetto tolerates (and `TRACE_DROPPED`
/// reports).
pub fn export_chrome(sinks: &[TraceSink]) -> String {
    let mut events: Vec<String> = Vec::new();
    for (run, sink) in sinks.iter().enumerate() {
        let pid_pe = (run as u64) * 2;
        let pid_shard = pid_pe + 1;
        // Lane discovery for thread-name metadata.
        let mut pe_lanes: BTreeSet<u32> = BTreeSet::new();
        let mut shard_lanes: BTreeSet<u32> = BTreeSet::new();
        for ev in sink.events() {
            match ev.lane {
                Lane::Pe(p) => {
                    pe_lanes.insert(p);
                }
                Lane::Shard(s) => {
                    shard_lanes.insert(s);
                }
            }
        }
        let mut meta = |pid: u64, tid: u32, kind: &str, name: String| {
            events.push(format!(
                "{{\"ph\":\"M\",\"name\":\"{kind}\",\"pid\":{pid},\"tid\":{tid},\"args\":{{\"name\":\"{name}\"}}}}"
            ));
        };
        if !pe_lanes.is_empty() {
            meta(pid_pe, 0, "process_name", format!("run {run} PEs"));
            for &p in &pe_lanes {
                meta(pid_pe, p, "thread_name", format!("PE {p}"));
            }
        }
        if !shard_lanes.is_empty() {
            meta(pid_shard, 0, "process_name", format!("run {run} shards"));
            for &s in &shard_lanes {
                meta(pid_shard, s, "thread_name", format!("shard {s}"));
            }
        }
        for ev in sink.events() {
            let (pid, tid) = match ev.lane {
                Lane::Pe(p) => (pid_pe, p),
                Lane::Shard(s) => (pid_shard, s),
            };
            let mut e = String::new();
            push_event_prefix(&mut e, ev, pid, tid);
            match ev.kind {
                EventKind::Begin => {
                    let _ = write!(e, ",\"ph\":\"b\",\"id\":\"0x{:x}\"", ev.id);
                }
                EventKind::End => {
                    let _ = write!(e, ",\"ph\":\"e\",\"id\":\"0x{:x}\"", ev.id);
                }
                EventKind::Instant => {
                    e.push_str(",\"ph\":\"i\",\"s\":\"t\"");
                }
                EventKind::Complete { dur } => {
                    let _ = write!(e, ",\"ph\":\"X\",\"dur\":{:.3}", dur as f64 / 1000.0);
                }
            }
            push_args(&mut e, ev);
            events.push(e);
        }
    }
    let mut out = String::from("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[\n");
    out.push_str(&events.join(",\n"));
    out.push_str("\n]}\n");
    out
}

/// Per-category event counts across sinks (CLI summary line).
pub fn category_counts(sinks: &[TraceSink]) -> BTreeMap<&'static str, u64> {
    let mut m: BTreeMap<&'static str, u64> = BTreeMap::new();
    for sink in sinks {
        for ev in sink.events() {
            *m.entry(ev.cat.label()).or_insert(0) += 1;
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(cap: usize) -> TraceConfig {
        TraceConfig {
            enabled: true,
            capacity: cap,
            categories: CategoryMask::all(),
        }
    }

    #[test]
    fn catalog_covers_every_name() {
        // Self-parse the `names` module out of this file and require one
        // catalog row per declared constant — adding an event name without
        // cataloguing it (and regenerating docs/OBSERVABILITY.md) fails here.
        let src = include_str!("mod.rs");
        let module = src
            .split("pub mod names {")
            .nth(1)
            .expect("names module present");
        let mut declared = Vec::new();
        for line in module.lines() {
            if line.trim_start().starts_with("pub fn catalog") {
                break;
            }
            if line.trim_start().starts_with("pub const ") {
                let lit = line.split('"').nth(1).expect("string literal");
                declared.push(lit.to_string());
            }
        }
        assert!(declared.len() >= 20, "expected the full name set, found {declared:?}");
        let cat = names::catalog();
        assert_eq!(cat.len(), declared.len(), "catalog rows != declared constants");
        for name in &declared {
            assert_eq!(
                cat.iter().filter(|(n, _, _)| *n == name.as_str()).count(),
                1,
                "{name} must appear exactly once in names::catalog()"
            );
        }
        let labels: Vec<&str> = TraceCategory::ALL.iter().map(|c| c.label()).collect();
        for (name, module, desc) in &cat {
            let prefix = name.split('/').next().unwrap();
            assert!(labels.contains(&prefix), "{name}: unknown category prefix");
            assert!(!module.is_empty() && !desc.is_empty());
        }
    }

    #[test]
    fn disabled_sink_records_nothing_and_owns_nothing() {
        let mut t = TraceSink::disabled();
        assert!(!t.is_enabled());
        assert!(!t.on(TraceCategory::Session));
        t.instant(5, TraceCategory::Session, names::SESSION_OPEN, Lane::Pe(0), 0, 0, "");
        t.begin(5, TraceCategory::Pfs, names::PFS_READ, Lane::Pe(0), 1, 0, 0);
        assert!(t.is_empty());
        assert_eq!(t.dropped(), 0);
        assert_eq!(t.open_spans(), 0);
    }

    #[test]
    fn ring_drops_oldest_and_counts() {
        let mut t = TraceSink::new(&cfg(16));
        for i in 0..20u64 {
            t.instant(i, TraceCategory::Sched, names::SCHED_SEND, Lane::Pe(0), i, 0, "");
        }
        assert_eq!(t.len(), 16);
        assert_eq!(t.dropped(), 4);
        // Oldest four were evicted: the ring starts at ts = 4.
        assert_eq!(t.events().next().unwrap().ts, 4);
        assert_eq!(t.take_unflushed_dropped(), 4);
        assert_eq!(t.take_unflushed_dropped(), 0);
        t.instant(99, TraceCategory::Sched, names::SCHED_SEND, Lane::Pe(0), 0, 0, "");
        assert_eq!(t.take_unflushed_dropped(), 1);
    }

    #[test]
    fn category_mask_filters() {
        let mut c = cfg(64);
        c.categories = CategoryMask::none().with(TraceCategory::Pfs);
        let mut t = TraceSink::new(&c);
        assert!(t.on(TraceCategory::Pfs));
        assert!(!t.on(TraceCategory::Sched));
        t.instant(1, TraceCategory::Sched, names::SCHED_SEND, Lane::Pe(0), 0, 0, "");
        assert!(t.is_empty());
        t.begin(1, TraceCategory::Pfs, names::PFS_READ, Lane::Pe(0), 7, 0, 0);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn span_pairing_counter_balances() {
        let mut t = TraceSink::new(&cfg(64));
        t.begin(1, TraceCategory::Session, names::SESSION_ACTIVE, Lane::Pe(0), 1, 0, 0);
        t.begin(2, TraceCategory::Pfs, names::PFS_READ, Lane::Pe(1), 2, 0, 0);
        assert_eq!(t.open_spans(), 2);
        t.end(3, TraceCategory::Pfs, names::PFS_READ, Lane::Pe(1), 2, 0, 0);
        t.end(4, TraceCategory::Session, names::SESSION_ACTIVE, Lane::Pe(0), 1, 0, 0);
        assert_eq!(t.open_spans(), 0);
    }

    #[test]
    fn pairing_counter_survives_ring_eviction() {
        // Capacity floor is 16; flood with instants so Begin events are
        // evicted, then close the spans: the counter must still balance.
        let mut t = TraceSink::new(&cfg(16));
        t.begin(0, TraceCategory::Session, names::SESSION_ACTIVE, Lane::Pe(0), 1, 0, 0);
        for i in 0..40u64 {
            t.instant(i, TraceCategory::Sched, names::SCHED_SEND, Lane::Pe(0), 0, 0, "");
        }
        t.end(99, TraceCategory::Session, names::SESSION_ACTIVE, Lane::Pe(0), 1, 0, 0);
        assert_eq!(t.open_spans(), 0);
        assert!(t.dropped() > 0);
    }

    #[test]
    fn station_roundtrip() {
        arm(TraceConfig::on());
        assert!(armed().is_some());
        let mut t = TraceSink::new(&armed().unwrap());
        t.instant(1, TraceCategory::Session, names::SESSION_OPEN, Lane::Pe(0), 0, 0, "");
        deposit(t);
        deposit(TraceSink::disabled()); // filtered out
        let sinks = collect();
        assert_eq!(sinks.len(), 1);
        assert_eq!(sinks[0].len(), 1);
        assert!(collect().is_empty());
        disarm();
        assert!(armed().is_none());
        // Unarmed deposits are discarded.
        deposit(TraceSink::new(&TraceConfig::on()));
        assert!(collect().is_empty());
    }

    // -- minimal JSON validator (objects/arrays/strings/numbers/bools) --

    fn ws(b: &[u8], i: &mut usize) {
        while *i < b.len() && matches!(b[*i], b' ' | b'\n' | b'\r' | b'\t') {
            *i += 1;
        }
    }

    fn value(b: &[u8], i: &mut usize) -> bool {
        ws(b, i);
        if *i >= b.len() {
            return false;
        }
        match b[*i] {
            b'{' => {
                *i += 1;
                ws(b, i);
                if *i < b.len() && b[*i] == b'}' {
                    *i += 1;
                    return true;
                }
                loop {
                    ws(b, i);
                    if *i >= b.len() || b[*i] != b'"' || !string(b, i) {
                        return false;
                    }
                    ws(b, i);
                    if *i >= b.len() || b[*i] != b':' {
                        return false;
                    }
                    *i += 1;
                    if !value(b, i) {
                        return false;
                    }
                    ws(b, i);
                    if *i >= b.len() {
                        return false;
                    }
                    match b[*i] {
                        b',' => *i += 1,
                        b'}' => {
                            *i += 1;
                            return true;
                        }
                        _ => return false,
                    }
                }
            }
            b'[' => {
                *i += 1;
                ws(b, i);
                if *i < b.len() && b[*i] == b']' {
                    *i += 1;
                    return true;
                }
                loop {
                    if !value(b, i) {
                        return false;
                    }
                    ws(b, i);
                    if *i >= b.len() {
                        return false;
                    }
                    match b[*i] {
                        b',' => *i += 1,
                        b']' => {
                            *i += 1;
                            return true;
                        }
                        _ => return false,
                    }
                }
            }
            b'"' => string(b, i),
            b't' => lit(b, i, b"true"),
            b'f' => lit(b, i, b"false"),
            b'n' => lit(b, i, b"null"),
            _ => number(b, i),
        }
    }

    fn string(b: &[u8], i: &mut usize) -> bool {
        // b[*i] == b'"'
        *i += 1;
        while *i < b.len() {
            match b[*i] {
                b'\\' => *i += 2,
                b'"' => {
                    *i += 1;
                    return true;
                }
                _ => *i += 1,
            }
        }
        false
    }

    fn lit(b: &[u8], i: &mut usize, want: &[u8]) -> bool {
        if b.len() - *i >= want.len() && &b[*i..*i + want.len()] == want {
            *i += want.len();
            true
        } else {
            false
        }
    }

    fn number(b: &[u8], i: &mut usize) -> bool {
        let start = *i;
        if *i < b.len() && b[*i] == b'-' {
            *i += 1;
        }
        let mut digits = 0;
        while *i < b.len() && (b[*i].is_ascii_digit() || b[*i] == b'.' || b[*i] == b'e' || b[*i] == b'E' || b[*i] == b'+' || b[*i] == b'-') {
            if b[*i].is_ascii_digit() {
                digits += 1;
            }
            *i += 1;
        }
        digits > 0 && *i > start
    }

    fn json_ok(s: &str) -> bool {
        let b = s.as_bytes();
        let mut i = 0;
        if !value(b, &mut i) {
            return false;
        }
        ws(b, &mut i);
        i == b.len()
    }

    #[test]
    fn json_validator_sanity() {
        assert!(json_ok("{\"a\":[1,2.5,\"x\"],\"b\":{\"c\":true}}"));
        assert!(!json_ok("{\"a\":[1,]}"));
        assert!(!json_ok("{\"a\":1,}"));
        assert!(!json_ok("{\"a\":1} trailing"));
    }

    #[test]
    fn chrome_export_golden() {
        let mut t = TraceSink::new(&cfg(64));
        t.begin(1_000, TraceCategory::Session, names::SESSION_ACTIVE, Lane::Pe(0), 3, 0, 0);
        t.begin(2_000, TraceCategory::Pfs, names::PFS_READ, Lane::Pe(1), 42, 4096, 0);
        t.end(5_000, TraceCategory::Pfs, names::PFS_READ, Lane::Pe(1), 42, 0, 0);
        t.complete(
            2_500,
            1_500,
            TraceCategory::Ticket,
            names::TICKET_WAIT,
            Lane::Shard(0),
            7,
            1,
            0,
            "bulk",
        );
        t.instant(
            6_000,
            TraceCategory::Governor,
            names::GOVERNOR_CAP,
            Lane::Shard(0),
            4,
            2,
            "growth_probe",
        );
        t.end(9_000, TraceCategory::Session, names::SESSION_ACTIVE, Lane::Pe(0), 3, 0, 0);
        let json = export_chrome(&[t]);
        assert!(json_ok(&json), "export must be valid JSON:\n{json}");
        for needle in [
            "\"traceEvents\"",
            names::SESSION_ACTIVE,
            names::PFS_READ,
            names::TICKET_WAIT,
            names::GOVERNOR_CAP,
            "\"ph\":\"b\"",
            "\"ph\":\"e\"",
            "\"ph\":\"X\"",
            "\"ph\":\"i\"",
            "\"note\":\"growth_probe\"",
            "\"note\":\"bulk\"",
            "\"process_name\"",
            "\"thread_name\"",
            // ns → µs: ticket wait of 1500 ns is 1.5 µs.
            "\"dur\":1.500",
        ] {
            assert!(json.contains(needle), "missing {needle} in:\n{json}");
        }
        // PE lanes on even pid 0; shard lanes on odd pid 1.
        assert!(json.contains("\"pid\":0"));
        assert!(json.contains("\"pid\":1"));
    }

    #[test]
    fn chrome_export_second_run_gets_offset_pids() {
        let mut a = TraceSink::new(&cfg(16));
        a.instant(1, TraceCategory::Session, names::SESSION_OPEN, Lane::Pe(0), 0, 0, "");
        let mut b = TraceSink::new(&cfg(16));
        b.instant(1, TraceCategory::Store, names::STORE_PARK, Lane::Shard(2), 0, 0, "");
        let json = export_chrome(&[a, b]);
        assert!(json_ok(&json));
        assert!(json.contains("\"pid\":3")); // run 1 shard plane = 2*1 + 1
        assert!(json.contains("run 1 shards"));
    }

    #[test]
    fn category_labels_roundtrip() {
        for c in TraceCategory::ALL {
            assert_eq!(TraceCategory::parse(c.label()), Some(c));
        }
        assert_eq!(TraceCategory::parse("nope"), None);
    }
}
