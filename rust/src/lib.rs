//! # CkIO — Parallel File Input for Over-Decomposed Task-Based Systems
//!
//! A from-scratch reproduction of *"CkIO: Parallel File Input for
//! Over-Decomposed Task-Based Systems"* (Jacob, Taylor, Kale; 2024) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the paper's coordination contribution: an
//!   over-decomposed, message-driven task runtime ([`amt`]), the CkIO input
//!   library built on it ([`ckio`]), the baselines it is evaluated against
//!   ([`baselines`]), and the parallel-file-system + interconnect substrate
//!   ([`pfs`], [`net`]) the evaluation needs.
//! * **Layer 2/1 (build-time Python)** — the data *consumer*: a mini-ChaNGa
//!   ingest + gravity step written in JAX with Pallas kernels, AOT-lowered
//!   to HLO text and executed from Rust via PJRT ([`runtime`]). Python is
//!   never on the request path.
//!
//! See `DESIGN.md` for the system inventory and the per-figure experiment
//! index, and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod amt;
pub mod apps;
pub mod baselines;
pub mod ckio;
pub mod harness;
pub mod lint;
pub mod metrics;
pub mod net;
pub mod pfs;
pub mod runtime;
pub mod trace;
pub mod util;

pub use amt::{
    engine::{Engine, EngineConfig},
    topology::Topology,
};
