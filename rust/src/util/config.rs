//! Tiny configuration-file parser (TOML subset; no serde in the offline
//! crate set).
//!
//! Supports `[section]` headers, `key = value` pairs, `#` comments,
//! strings (quoted or bare), integers, floats, booleans and byte
//! quantities. All experiment drivers and the launcher read their cluster
//! / PFS / CkIO parameters through this.

use std::collections::BTreeMap;
use std::path::Path;

/// A parsed config: `section.key -> raw string value`.
#[derive(Debug, Default, Clone)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    /// Parse from text.
    pub fn parse(text: &str) -> Result<Config, String> {
        let mut out = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section header", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected `key = value`", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let mut val = v.trim().to_string();
            if val.len() >= 2 && val.starts_with('"') && val.ends_with('"') {
                val = val[1..val.len() - 1].to_string();
            }
            out.values.insert(key, val);
        }
        Ok(out)
    }

    /// Load from a file path.
    pub fn load(path: impl AsRef<Path>) -> Result<Config, String> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| format!("{}: {e}", path.as_ref().display()))?;
        Config::parse(&text)
    }

    /// Raw value lookup (`"pfs.ost_count"`).
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// Typed lookup with default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.values.get(key) {
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("config {key}: cannot parse {v:?}")),
            None => default,
        }
    }

    /// Boolean lookup (`true/false/1/0/yes/no`).
    pub fn get_bool_or(&self, key: &str, default: bool) -> bool {
        match self.values.get(key).map(|s| s.as_str()) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(v) => panic!("config {key}: not a boolean: {v:?}"),
            None => default,
        }
    }

    /// Byte-quantity lookup (`"4GiB"`).
    pub fn get_bytes_or(&self, key: &str, default: u64) -> u64 {
        match self.values.get(key) {
            Some(v) => super::parse_bytes(v).unwrap_or_else(|e| panic!("config {key}: {e}")),
            None => default,
        }
    }

    /// Set a value programmatically (CLI overrides).
    pub fn set(&mut self, key: &str, value: impl Into<String>) {
        self.values.insert(key.to_string(), value.into());
    }

    /// All keys under a section prefix.
    pub fn section_keys(&self, section: &str) -> Vec<&str> {
        let prefix = format!("{section}.");
        self.values
            .keys()
            .filter(|k| k.starts_with(&prefix))
            .map(|k| k.as_str())
            .collect()
    }
}

fn strip_comment(line: &str) -> &str {
    // Respect `#` inside quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# cluster shape
[cluster]
nodes = 16
pes_per_node = 32

[pfs]
ost_count = 16         # Lustre "Ocean"-ish
stripe_size = "4MiB"
rpc_overhead_us = 250.5
name = "ocean #1"

[ckio]
readers_per_node = 32
verify = true
"#;

    #[test]
    fn parse_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get_or("cluster.nodes", 0u32), 16);
        assert_eq!(c.get_or("cluster.pes_per_node", 0u32), 32);
        assert_eq!(c.get_or("pfs.ost_count", 0u32), 16);
        assert_eq!(c.get_bytes_or("pfs.stripe_size", 0), 4 << 20);
        assert!((c.get_or("pfs.rpc_overhead_us", 0.0f64) - 250.5).abs() < 1e-12);
        assert!(c.get_bool_or("ckio.verify", false));
        assert_eq!(c.get("pfs.name"), Some("ocean #1"));
        assert_eq!(c.get("missing.key"), None);
    }

    #[test]
    fn defaults_apply() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.get_or("a.b", 7u32), 7);
        assert!(!c.get_bool_or("a.c", false));
    }

    #[test]
    fn overrides() {
        let mut c = Config::parse(SAMPLE).unwrap();
        c.set("cluster.nodes", "64");
        assert_eq!(c.get_or("cluster.nodes", 0u32), 64);
    }

    #[test]
    fn section_key_listing() {
        let c = Config::parse(SAMPLE).unwrap();
        let keys = c.section_keys("pfs");
        assert_eq!(keys.len(), 4);
    }

    #[test]
    fn bad_lines_error() {
        assert!(Config::parse("[unterminated").is_err());
        assert!(Config::parse("no_equals_here").is_err());
    }
}
