//! Minimal CLI argument parser (no clap in the offline crate set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments, with typed getters and a usage dump.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (first element must NOT be argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminates option parsing.
                    out.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    out.options.insert(body.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1)).expect("argv parse")
    }

    /// Whether `--name` was passed as a bare flag.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.options.get(name).is_some_and(|v| v == "true")
    }

    /// Raw string option.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Typed option with a default.
    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.options.get(name) {
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{name}: cannot parse {v:?}")),
            None => default,
        }
    }

    /// Required typed option.
    pub fn require<T: std::str::FromStr>(&self, name: &str) -> T {
        let v = self
            .options
            .get(name)
            .unwrap_or_else(|| panic!("missing required option --{name}"));
        v.parse()
            .unwrap_or_else(|_| panic!("--{name}: cannot parse {v:?}"))
    }

    /// Byte-quantity option (`--file-size 4GiB`).
    pub fn get_bytes_or(&self, name: &str, default: u64) -> u64 {
        match self.options.get(name) {
            Some(v) => super::parse_bytes(v).unwrap_or_else(|e| panic!("--{name}: {e}")),
            None => default,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn mixed_forms() {
        let a = parse("fig1 --nodes 16 --pes-per-node=32 --verify --file-size 4GiB pos2");
        assert_eq!(a.positional, vec!["fig1", "pos2"]);
        assert_eq!(a.get_or("nodes", 0u32), 16);
        assert_eq!(a.get_or("pes-per-node", 0u32), 32);
        assert!(a.flag("verify"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.get_bytes_or("file-size", 0), 4 << 30);
    }

    #[test]
    fn double_dash_terminates() {
        let a = parse("--x 1 -- --not-an-option");
        assert_eq!(a.get_or("x", 0u32), 1);
        assert_eq!(a.positional, vec!["--not-an-option"]);
    }

    #[test]
    fn flag_at_end() {
        let a = parse("--verbose");
        assert!(a.flag("verbose"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--a --b");
        assert!(a.flag("a") && a.flag("b"));
    }

    #[test]
    #[should_panic]
    fn require_missing_panics() {
        parse("").require::<u32>("nodes");
    }
}
