//! Summary statistics for benchmark reporting.

/// Mean / stddev / min / max / percentiles over a sample set.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; panics on an empty sample set.
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty samples");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
            p99: percentile(&sorted, 0.99),
        }
    }

    /// Relative standard deviation (coefficient of variation).
    pub fn cv(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.stddev / self.mean
        }
    }
}

/// Linear-interpolated percentile over a pre-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean (for speedup aggregation).
pub fn geomean(samples: &[f64]) -> f64 {
    assert!(!samples.is_empty());
    let log_sum: f64 = samples.iter().map(|x| x.max(1e-300).ln()).sum();
    (log_sum / samples.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.stddev - 1.5811388300841898).abs() < 1e-9);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn summary_single_sample() {
        let s = Summary::of(&[7.5]);
        assert_eq!(s.mean, 7.5);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.p99, 7.5);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile(&sorted, 0.5) - 5.0).abs() < 1e-12);
        assert!((percentile(&sorted, 0.95) - 9.5).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn empty_panics() {
        Summary::of(&[]);
    }
}
