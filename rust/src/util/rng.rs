//! PCG32 pseudo-random number generator.
//!
//! Deterministic, seedable, and tiny — used by workload generators, the
//! PFS variability model, and the mini property-testing framework
//! ([`crate::util::prop`]). PCG-XSH-RR 64/32 (O'Neill 2014).

/// A PCG-XSH-RR 64/32 generator.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and a stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Create a generator from a seed with the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    /// Next 32 uniformly random bits.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 uniformly random bits.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` (Lemire-style debiased).
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "gen_range bound must be positive");
        // Rejection sampling over the top of the range to remove modulo bias.
        let zone = u64::MAX - (u64::MAX % bound);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % bound;
            }
        }
    }

    /// Uniform in `[lo, hi)`.
    pub fn gen_in(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo);
        lo + self.gen_range(hi - lo)
    }

    /// Uniform float in `[0, 1)`.
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform float in `[lo, hi)`.
    pub fn gen_f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.gen_f64() * (hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(1e-12);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal multiplicative noise with the given sigma, mean ~1.
    ///
    /// Used to model run-to-run file-system variability (the error bars in
    /// the paper's Figs. 1 and 4).
    pub fn noise(&mut self, sigma: f64) -> f64 {
        (self.gen_normal() * sigma - sigma * sigma / 2.0).exp()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn gen_range_in_bounds() {
        let mut r = Pcg32::seeded(7);
        for _ in 0..10_000 {
            let v = r.gen_range(17);
            assert!(v < 17);
        }
    }

    #[test]
    fn gen_range_hits_all_values() {
        let mut r = Pcg32::seeded(9);
        let mut seen = [false; 8];
        for _ in 0..1_000 {
            seen[r.gen_range(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Pcg32::seeded(3);
        for _ in 0..10_000 {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Pcg32::seeded(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn noise_mean_near_one() {
        let mut r = Pcg32::seeded(5);
        let n = 20_000;
        let m = (0..n).map(|_| r.noise(0.1)).sum::<f64>() / n as f64;
        assert!((m - 1.0).abs() < 0.02, "m={m}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(13);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
