//! Small self-contained utilities.
//!
//! The build environment is fully offline with a limited vendored crate
//! set, so the conveniences a project would normally pull from crates.io
//! (CLI parsing, config files, RNGs, stats, a bench harness, property
//! testing) are implemented here from scratch.

pub mod bytes;
pub mod cli;
pub mod config;
pub mod prop;
pub mod rng;
pub mod stats;

pub use bytes::{human_bytes, parse_bytes, Chunk};
pub use rng::Pcg32;
pub use stats::Summary;
