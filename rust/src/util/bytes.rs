//! Byte-quantity formatting/parsing and the data-plane `Chunk` type.

use std::fmt;
use std::sync::Arc;

/// A contiguous span of file data moving through the system.
///
/// In *verified* runs the payload is materialized (`bytes: Some`) so tests
/// can check end-to-end content integrity against the deterministic
/// pattern in [`crate::pfs::pattern`]. In *modeled* runs at paper scale
/// (multi-GiB files on the virtual cluster) the payload is elided and only
/// the logical extent moves; every queueing/latency computation uses `len`.
#[derive(Clone)]
pub struct Chunk {
    /// Absolute offset of this chunk within the file.
    pub offset: u64,
    /// Logical length in bytes.
    pub len: u64,
    /// Materialized payload (verified mode) or `None` (modeled mode).
    pub bytes: Option<Arc<[u8]>>,
}

impl Chunk {
    /// A modeled (payload-free) chunk.
    pub fn modeled(offset: u64, len: u64) -> Chunk {
        Chunk { offset, len, bytes: None }
    }

    /// A materialized chunk; `bytes.len()` must equal `len`.
    pub fn materialized(offset: u64, bytes: Arc<[u8]>) -> Chunk {
        Chunk { offset, len: bytes.len() as u64, bytes: Some(bytes) }
    }

    /// End offset (exclusive).
    pub fn end(&self) -> u64 {
        self.offset + self.len
    }

    /// Sub-chunk covering `[offset, offset+len)` in *file* coordinates.
    ///
    /// Panics if the requested range is not fully inside this chunk.
    pub fn slice(&self, offset: u64, len: u64) -> Chunk {
        assert!(
            offset >= self.offset && offset + len <= self.end(),
            "slice [{offset}, {}) outside chunk [{}, {})",
            offset + len,
            self.offset,
            self.end()
        );
        let bytes = self.bytes.as_ref().map(|b| {
            let lo = (offset - self.offset) as usize;
            let hi = lo + len as usize;
            Arc::from(&b[lo..hi])
        });
        Chunk { offset, len, bytes }
    }

    /// Whether this chunk intersects `[offset, offset+len)`.
    pub fn overlaps(&self, offset: u64, len: u64) -> bool {
        self.offset < offset + len && offset < self.end()
    }
}

impl fmt::Debug for Chunk {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "Chunk[{}..{}) ({}, {})",
            self.offset,
            self.end(),
            human_bytes(self.len),
            if self.bytes.is_some() { "materialized" } else { "modeled" }
        )
    }
}

/// `1536 → "1.5 KiB"`.
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 7] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB", "EiB"];
    if n < 1024 {
        return format!("{n} B");
    }
    let mut v = n as f64;
    let mut unit = 0;
    while v >= 1024.0 && unit < UNITS.len() - 1 {
        v /= 1024.0;
        unit += 1;
    }
    if (v - v.round()).abs() < 0.05 {
        format!("{:.0} {}", v.round(), UNITS[unit])
    } else {
        format!("{:.1} {}", v, UNITS[unit])
    }
}

/// Parse `"4GiB"`, `"512m"`, `"1048576"` and friends into bytes.
pub fn parse_bytes(s: &str) -> Result<u64, String> {
    let s = s.trim();
    let split = s
        .find(|c: char| !c.is_ascii_digit() && c != '.')
        .unwrap_or(s.len());
    let (num, suffix) = s.split_at(split);
    let num: f64 = num
        .parse()
        .map_err(|_| format!("bad byte quantity: {s:?}"))?;
    let mult: u64 = match suffix.trim().to_ascii_lowercase().as_str() {
        "" | "b" => 1,
        "k" | "kb" | "kib" => 1 << 10,
        "m" | "mb" | "mib" => 1 << 20,
        "g" | "gb" | "gib" => 1 << 30,
        "t" | "tb" | "tib" => 1 << 40,
        other => return Err(format!("unknown byte suffix {other:?} in {s:?}")),
    };
    Ok((num * mult as f64).round() as u64)
}

/// Integer ceiling division.
pub fn ceil_div(a: u64, b: u64) -> u64 {
    assert!(b > 0);
    a.div_ceil(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_round_trip() {
        assert_eq!(human_bytes(0), "0 B");
        assert_eq!(human_bytes(1023), "1023 B");
        assert_eq!(human_bytes(1024), "1 KiB");
        assert_eq!(human_bytes(1536), "1.5 KiB");
        assert_eq!(human_bytes(4 << 30), "4 GiB");
    }

    #[test]
    fn parse_variants() {
        assert_eq!(parse_bytes("1048576").unwrap(), 1 << 20);
        assert_eq!(parse_bytes("4GiB").unwrap(), 4 << 30);
        assert_eq!(parse_bytes("512m").unwrap(), 512 << 20);
        assert_eq!(parse_bytes("1.5k").unwrap(), 1536);
        assert_eq!(parse_bytes(" 2 GB ").unwrap(), 2 << 30);
        assert!(parse_bytes("12xyz").is_err());
        assert!(parse_bytes("").is_err());
    }

    #[test]
    fn chunk_slice_materialized() {
        let data: Arc<[u8]> = (0u8..100).collect::<Vec<_>>().into();
        let c = Chunk::materialized(1000, data);
        let s = c.slice(1010, 5);
        assert_eq!(s.offset, 1010);
        assert_eq!(s.len, 5);
        assert_eq!(&s.bytes.unwrap()[..], &[10, 11, 12, 13, 14]);
    }

    #[test]
    fn chunk_slice_modeled() {
        let c = Chunk::modeled(0, 100);
        let s = c.slice(50, 25);
        assert_eq!(s.len, 25);
        assert!(s.bytes.is_none());
    }

    #[test]
    #[should_panic]
    fn chunk_slice_out_of_range() {
        Chunk::modeled(0, 100).slice(90, 20);
    }

    #[test]
    fn chunk_overlap() {
        let c = Chunk::modeled(100, 50);
        assert!(c.overlaps(100, 1));
        assert!(c.overlaps(149, 10));
        assert!(!c.overlaps(150, 10));
        assert!(!c.overlaps(0, 100));
        assert!(c.overlaps(0, 101));
    }

    #[test]
    fn ceil_div_cases() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }
}
