//! Mini property-based testing framework (no proptest in the offline
//! crate set).
//!
//! `forall` runs a property over many seeded random cases; on failure it
//! re-runs with progressively simpler size hints to report the smallest
//! failing size (a lightweight stand-in for shrinking). Generators are
//! plain closures over [`Pcg32`]; combinators cover the shapes the CkIO
//! invariants need (ranges, vectors, partitions of a byte range).

use super::rng::Pcg32;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    pub cases: u32,
    pub seed: u64,
    /// Maximum "size" hint passed to generators (scaled up over cases).
    pub max_size: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 256, seed: 0xc1c0 ^ 0x5eed, max_size: 1 << 20 }
    }
}

/// Per-case generation context: RNG + size hint.
pub struct Gen<'a> {
    pub rng: &'a mut Pcg32,
    pub size: u64,
}

impl<'a> Gen<'a> {
    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.gen_in(lo, hi)
    }

    /// Uniform in `[1, size]` — a "scale with case index" quantity.
    pub fn sized(&mut self) -> u64 {
        1 + self.rng.gen_range(self.size.max(1))
    }

    /// A vector of `n` items from `f`.
    pub fn vec<T>(&mut self, n: usize, mut f: impl FnMut(&mut Self) -> T) -> Vec<T> {
        (0..n).map(|_| f(self)).collect()
    }

    /// Random partition of `[0, total)` into `parts` contiguous spans
    /// (some possibly empty). Returns (offset, len) pairs covering the
    /// range exactly — the shape of client read decompositions.
    pub fn partition(&mut self, total: u64, parts: usize) -> Vec<(u64, u64)> {
        assert!(parts > 0);
        let mut cuts: Vec<u64> = (0..parts - 1).map(|_| self.rng.gen_range(total + 1)).collect();
        cuts.sort_unstable();
        let mut out = Vec::with_capacity(parts);
        let mut prev = 0;
        for c in cuts {
            out.push((prev, c - prev));
            prev = c;
        }
        out.push((prev, total - prev));
        out
    }

    /// True with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.gen_f64() < p
    }
}

/// Run `prop` over `cfg.cases` random cases. Panics with the failing
/// seed/case/size on the first failure (after probing smaller sizes).
pub fn forall(cfg: PropConfig, name: &str, mut prop: impl FnMut(&mut Gen) -> Result<(), String>) {
    for case in 0..cfg.cases {
        // Size ramps up so early cases are small.
        let size = (cfg.max_size * (case as u64 + 1) / cfg.cases as u64).max(1);
        let mut rng = Pcg32::new(cfg.seed, case as u64);
        let mut g = Gen { rng: &mut rng, size };
        if let Err(msg) = prop(&mut g) {
            // Probe smaller sizes with the same stream for a simpler report.
            let mut simplest = (size, msg.clone());
            let mut probe = size;
            while probe > 1 {
                probe /= 2;
                let mut rng = Pcg32::new(cfg.seed, case as u64);
                let mut g = Gen { rng: &mut rng, size: probe };
                if let Err(m) = prop(&mut g) {
                    simplest = (probe, m);
                } else {
                    break;
                }
            }
            panic!(
                "property `{name}` failed: case={case} seed={:#x} size={} (simplest size {} -> {})",
                cfg.seed, size, simplest.0, simplest.1
            );
        }
    }
}

/// Assertion helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_covers_exactly() {
        forall(PropConfig { cases: 200, ..Default::default() }, "partition", |g| {
            let total = g.sized();
            let parts = g.range(1, 20) as usize;
            let p = g.partition(total, parts);
            prop_assert!(p.len() == parts, "wrong part count");
            let mut pos = 0;
            for &(o, l) in &p {
                prop_assert!(o == pos, "gap at {o} expected {pos}");
                pos = o + l;
            }
            prop_assert!(pos == total, "covered {pos} of {total}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property `always_fails` failed")]
    fn failures_reported() {
        forall(PropConfig { cases: 4, ..Default::default() }, "always_fails", |g| {
            let v = g.sized();
            prop_assert!(v == 0, "v={v}");
            Ok(())
        });
    }

    #[test]
    fn sized_scales_with_case() {
        let mut max_seen = 0;
        forall(PropConfig { cases: 64, max_size: 1000, ..Default::default() }, "scales", |g| {
            let v = g.sized();
            if v > max_seen {
                max_seen = v;
            }
            Ok(())
        });
        assert!(max_seen > 100, "sizes never grew: {max_seen}");
    }
}
