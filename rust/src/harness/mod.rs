//! Benchmark harness: a small criterion-replacement (`bench`), the
//! background-work chares used by the overlap experiments (`bgwork`), and
//! the per-figure experiment drivers (`experiments`) that regenerate
//! every table/figure of the paper's evaluation.

pub mod bench;
pub mod bgwork;
pub mod experiments;

pub use bench::{BenchResult, Table};
