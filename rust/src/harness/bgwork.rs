//! Background-work chares for the computation-overlap experiments
//! (paper Figs. 8–9).
//!
//! Mirrors the paper's setup: one chare per PE iterating a fixed-duration
//! (~10 µs) compute loop, *yielding to the scheduler after every
//! iteration* so the runtime can interleave I/O completions and other
//! tasks. Two modes:
//!
//! * `quota` — run a fixed number of iterations (Fig. 8's "fixed amount
//!   of background work"), then report.
//! * until-stopped — keep iterating until `EP_BG_STOP`, then report how
//!   many iterations fit (Fig. 9 measures how much background work fits
//!   inside the input time).

use crate::amt::callback::Callback;
use crate::amt::chare::Chare;
use crate::amt::engine::Ctx;
use crate::amt::msg::{Ep, Msg, Payload};
use crate::amt::protocol::{PayloadKind, ProtocolSpec};
use crate::amt::time::Time;
use crate::impl_chare_any;
use crate::metrics::keys;
use crate::{ep_spec, send_spec};

/// Begin iterating.
pub const EP_BG_START: Ep = 1;
/// Self-scheduled next iteration (the yield).
pub const EP_BG_TICK: Ep = 2;
/// Stop (until-stopped mode) and report.
pub const EP_BG_STOP: Ep = 3;

/// One background worker.
pub struct BgWorker {
    /// Compute per iteration (paper: ~10 µs).
    pub slice: Time,
    /// `Some(n)`: stop after n iterations; `None`: run until stopped.
    pub quota: Option<u64>,
    pub iters_done: u64,
    stopped: bool,
    running: bool,
    /// Fired with `iters_done` when finished (quota) or stopped.
    pub report: Callback,
}

impl BgWorker {
    pub fn new(slice: Time, quota: Option<u64>, report: Callback) -> BgWorker {
        BgWorker { slice, quota, iters_done: 0, stopped: false, running: false, report }
    }

    fn step(&mut self, ctx: &mut Ctx<'_>) {
        if self.stopped {
            return;
        }
        if let Some(q) = self.quota {
            if self.iters_done >= q {
                self.stopped = true;
                ctx.fire(self.report.clone(), Payload::new(self.iters_done));
                return;
            }
        }
        self.iters_done += 1;
        ctx.charge(keys::BG_WORK, self.slice);
        // Yield: re-enqueue ourselves so I/O completions and other tasks
        // interleave between iterations.
        let me = ctx.me();
        ctx.signal(me, EP_BG_TICK);
    }
}

/// The worker's declared message protocol (see [`crate::amt::protocol`]).
/// Any change to its EPs, payload types, or send sites must update this
/// spec in the same commit.
pub fn protocol_spec() -> ProtocolSpec {
    ProtocolSpec {
        chare: "BgWorker",
        module: "harness/bgwork.rs",
        handles: vec![
            ep_spec!(EP_BG_START, PayloadKind::Signal),
            ep_spec!(EP_BG_TICK, PayloadKind::Signal),
            ep_spec!(EP_BG_STOP, PayloadKind::Signal),
        ],
        sends: vec![send_spec!("BgWorker", EP_BG_TICK, PayloadKind::Signal)],
    }
}

impl Chare for BgWorker {
    /// Background class (PR 9): iterations run while a PE has an
    /// admission wait open are charged to the overlap counters
    /// (`ckio.overlap.bg_iters`/`bg_time`) — the TASIO measurement of
    /// how much compute fits inside input time.
    fn is_background(&self) -> bool {
        true
    }

    fn receive(&mut self, ctx: &mut Ctx<'_>, msg: Msg) {
        match msg.ep {
            EP_BG_START => {
                if !self.running {
                    self.running = true;
                    self.step(ctx);
                }
            }
            EP_BG_TICK => self.step(ctx),
            EP_BG_STOP => {
                if !self.stopped {
                    self.stopped = true;
                    ctx.fire(self.report.clone(), Payload::new(self.iters_done));
                }
            }
            other => panic!("BgWorker: unknown ep {other}"),
        }
    }
    impl_chare_any!();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amt::chare::ChareRef;
    use crate::amt::engine::{Engine, EngineConfig};
    use crate::amt::time::{MICROS, MILLIS};
    use crate::amt::topology::Pe;

    #[test]
    fn quota_mode_runs_exactly_n() {
        let mut eng = Engine::new(EngineConfig::sim(1, 1));
        let fut = eng.future(1);
        let w = eng
            .create_singleton(Pe(0), BgWorker::new(10 * MICROS, Some(100), Callback::Future(fut)));
        eng.inject_signal(w, EP_BG_START);
        let end = eng.run();
        let mut got = eng.take_future(fut);
        assert_eq!(got[0].1.take::<u64>(), 100);
        assert_eq!(eng.core.metrics.duration(keys::BG_WORK), 1000 * MICROS);
        assert!(end >= MILLIS);
    }

    #[test]
    fn stop_mode_reports_partial() {
        let mut eng = Engine::new(EngineConfig::sim(1, 1));
        let fut = eng.future(1);
        let w =
            eng.create_singleton(Pe(0), BgWorker::new(10 * MICROS, None, Callback::Future(fut)));
        eng.inject_signal(w, EP_BG_START);
        // Stop after some work: inject the stop at time ~0; since
        // injections are immediate, instead drive a bounded quota worker
        // alongside — here we just stop immediately and expect ≥0 iters.
        eng.inject_signal(w, EP_BG_STOP);
        eng.run();
        let mut got = eng.take_future(fut);
        let iters = got[0].1.take::<u64>();
        assert!(iters <= 2, "stop arrived immediately, iters={iters}");
    }

    #[test]
    fn start_is_idempotent() {
        let mut eng = Engine::new(EngineConfig::sim(1, 1));
        let fut = eng.future(1);
        let w = eng.create_singleton(Pe(0), BgWorker::new(MICROS, Some(10), Callback::Future(fut)));
        eng.inject_signal(w, EP_BG_START);
        eng.inject_signal(w, EP_BG_START);
        eng.run();
        let mut got = eng.take_future(fut);
        assert_eq!(got[0].1.take::<u64>(), 10);
    }
}
